"""AOT lowering: jax → HLO *text* artifacts for the rust PJRT runtime.

Emits one artifact per (function, vehicle-count) bucket:

  artifacts/step_{N}.hlo.txt   — full sim step (model.step_geom,
                                 geometry-generic: scenario constants are
                                 an f32[5] runtime operand; destination-
                                 aware: params carry [exit_pos,
                                 exit_flag] columns — schema 3)
  artifacts/rollout{K}_{N}.hlo.txt
                               — fused K-step rollout (model.rollout_geom,
                                 lax.scan over step_geom; one dispatch
                                 per K physics steps — schema 4), one per
                                 K in the ROLLOUT_STEPS ladder
  artifacts/rolloutb{K}_{N}.hlo.txt
                               — vmapped rollout (BATCH co-located
                                 instances × K fused steps per dispatch)
  artifacts/idm_{N}.hlo.txt    — bare L1 IDM kernel (rust microbench target)
  artifacts/radar_{N}.hlo.txt  — bare L1 radar kernel
  artifacts/manifest.json      — shapes, column layout, geometry layout,
                                 rollout entry points + K ladder

HLO TEXT is the interchange format, NOT serialized HloModuleProto: jax
>= 0.5 emits protos with 64-bit instruction ids which xla_extension 0.5.1
(the version behind the `xla` rust crate) rejects (`proto.id() <=
INT_MAX`).  The text parser reassigns ids and round-trips cleanly.  We
lower the stablehlo module and convert with ``return_tuple=True``; the
rust side unwraps with ``to_tuple{k}()``.

Run via ``make artifacts`` (no-op when inputs are unchanged).
"""

from __future__ import annotations

import argparse
import json
import pathlib

import jax
import jax.numpy as jnp
from jax._src.lib import xla_client as xc

from . import model
from .kernels.idm_pairwise import idm_accel
from .kernels.radar import radar_scan

#: vehicle-count buckets lowered ahead of time; the rust runtime picks the
#: smallest bucket >= the live vehicle count and pads with inactive rows.
#: 1024 covers the largest capacity any scenario family suggests
#: (`rust/src/scenario/family.rs` DEFAULT_BUCKET_LADDER), so no scenario
#: point ever falls back to the native stepper.
BUCKETS = (16, 64, 256, 1024)

#: the fused-rollout K ladder lowered per bucket (schema 4).  The rust
#: chunk scheduler (`rust/src/sumo/simulation.rs`) computes the fusible
#: run length until the next due departure and clamps it to this ladder,
#: so the ladder must include 1 (the degenerate chunk) and is kept
#: short: each K costs one more executable per bucket (solo + batched).
#: Pinned against `rust/src/runtime/manifest.rs ROLLOUT_LADDER` by
#: `scripts/check_manifest.py`.
ROLLOUT_STEPS = (1, 8, 32)

#: the whole-run total-steps ladder lowered per bucket (schema 5).  A
#: `run{T}_{N}` entry executes T physics steps with in-kernel demand
#: insertion (`model.run_geom`) — ONE dispatch per run.  Rungs are exact
#: step counts, not upper bounds (a rung never over-steps the horizon),
#: chosen to match the step counts real runs ask for: 1200 and 1800 are
#: the scenario families' horizons (120 s and ring-shockwave's 180 s at
#: DT=0.1, `rust/src/scenario/family.rs`), 200 the short validation
#: horizon the launcher e2e tests use (20 s).  Runs at other horizons
#: fall back to PR 5 chunking.  Pinned against
#: `rust/src/runtime/manifest.rs RUN_LADDER` by `scripts/check_manifest.py`.
RUN_STEPS = (200, 1200, 1800)

#: departure-table row capacity per run entry (schema 5).  Schedules
#: with more due departures than this fall back to host-side chunking;
#: 256 covers every builtin scenario family with >2x headroom (worst
#: case ~150 departures: ring-shockwave at jam density, lane-drop at
#: 3000 vph over 120 s).  Padding rows carry model.DEP_PAD_EPOCH.
DEPARTURE_ROWS = 256


def to_hlo_text(lowered) -> str:
    """stablehlo → XlaComputation → HLO text (see module docstring)."""
    mlir_mod = lowered.compiler_ir("stablehlo")
    comp = xc._xla.mlir.mlir_module_to_xla_computation(
        str(mlir_mod), use_tuple_args=False, return_tuple=True
    )
    return comp.as_hlo_text()


#: geometry-operand width (see model.GEOM_COLUMNS).
GEOM = len(model.GEOM_COLUMNS)
#: params-row width (schema 3: 6 driver columns + [exit_pos, exit_flag]).
PARAMS = len(model.PARAM_COLUMNS)


def lower_step(n: int) -> str:
    """The geometry-generic, destination-aware step: state/params plus
    the f32[GEOM] geometry operand — one executable per bucket serves
    every scenario family AND every per-vehicle route (no per-geometry,
    no per-route recompile)."""
    state = jax.ShapeDtypeStruct((n, 4), jnp.float32)
    params = jax.ShapeDtypeStruct((n, PARAMS), jnp.float32)
    geom = jax.ShapeDtypeStruct((GEOM,), jnp.float32)
    return to_hlo_text(jax.jit(model.step_geom).lower(state, params, geom))


#: batch width of the vmapped step (the engine service's dynamic
#: micro-batcher coalesces concurrent instances up to this many).
BATCH = 8


def lower_step_batched(b: int, n: int) -> str:
    """vmap(step_geom) over a leading instance axis: one PJRT dispatch
    serves `b` co-located simulation instances (perf pass, EXPERIMENTS.md
    §Perf).  The geometry rows are batched too (f32[b, GEOM]), so
    co-located instances running *different* scenario families still
    coalesce into a single dispatch.
    """
    state = jax.ShapeDtypeStruct((b, n, 4), jnp.float32)
    params = jax.ShapeDtypeStruct((b, n, PARAMS), jnp.float32)
    geom = jax.ShapeDtypeStruct((b, GEOM), jnp.float32)
    return to_hlo_text(jax.jit(jax.vmap(model.step_geom)).lower(state, params, geom))


def lower_rollout(n: int, k: int) -> str:
    """The fused K-step rollout: lax.scan over the destination-aware,
    geometry-generic step — one PJRT dispatch advances the world by K
    steps and returns (final_state, obs_trace f32[K, OBS]).  Bit-exact
    with K sequential `step_geom` dispatches (the scan carry IS the
    state, so exit retirement and n_exited happen inside the loop)."""
    state = jax.ShapeDtypeStruct((n, 4), jnp.float32)
    params = jax.ShapeDtypeStruct((n, PARAMS), jnp.float32)
    geom = jax.ShapeDtypeStruct((GEOM,), jnp.float32)
    fn = lambda s, p, g: model.rollout_geom(s, p, g, k)
    return to_hlo_text(jax.jit(fn).lower(state, params, geom))


def lower_rollout_batched(b: int, n: int, k: int) -> str:
    """vmap(rollout_geom) over a leading instance axis: one dispatch
    advances `b` co-located instances by K fused steps each — the
    micro-batcher coalesces same-K rollout requests into this entry
    exactly like single steps coalesce into `stepb` (geometry rows are
    batched, so mixed-family chunks share the dispatch too)."""
    state = jax.ShapeDtypeStruct((b, n, 4), jnp.float32)
    params = jax.ShapeDtypeStruct((b, n, PARAMS), jnp.float32)
    geom = jax.ShapeDtypeStruct((b, GEOM), jnp.float32)
    fn = jax.vmap(lambda s, p, g: model.rollout_geom(s, p, g, k))
    return to_hlo_text(jax.jit(fn).lower(state, params, geom))


def lower_run(n: int, t: int, d: int = DEPARTURE_ROWS) -> str:
    """The whole-run entry: T physics steps AND the demand schedule in
    one executable (schema 5).  The departure table f32[D, DEP_COLS] is
    a runtime operand, so one lowered entry per (bucket, T) serves every
    scenario's schedule; insertion happens in-kernel (model.run_geom),
    bit-exact with the host scheduler.  Returns (final_state,
    final_params, obs_trace f32[T, OBS], inserted f32[D])."""
    state = jax.ShapeDtypeStruct((n, 4), jnp.float32)
    params = jax.ShapeDtypeStruct((n, PARAMS), jnp.float32)
    geom = jax.ShapeDtypeStruct((GEOM,), jnp.float32)
    deps = jax.ShapeDtypeStruct((d, len(model.DEP_COLUMNS)), jnp.float32)
    fn = lambda s, p, g, dep: model.run_geom(s, p, g, dep, t)
    return to_hlo_text(jax.jit(fn).lower(state, params, geom, deps))


def lower_run_batched(b: int, n: int, t: int, d: int = DEPARTURE_ROWS) -> str:
    """vmap(run_geom) over a leading instance axis: one dispatch executes
    `b` co-located WHOLE runs — each lane carries its own geometry row
    and departure table, so the engine service's run lane coalesces
    campaign instances from different scenario points into a single
    PJRT call."""
    state = jax.ShapeDtypeStruct((b, n, 4), jnp.float32)
    params = jax.ShapeDtypeStruct((b, n, PARAMS), jnp.float32)
    geom = jax.ShapeDtypeStruct((b, GEOM), jnp.float32)
    deps = jax.ShapeDtypeStruct((b, d, len(model.DEP_COLUMNS)), jnp.float32)
    fn = jax.vmap(lambda s, p, g, dep: model.run_geom(s, p, g, dep, t))
    return to_hlo_text(jax.jit(fn).lower(state, params, geom, deps))


def lower_idm(n: int) -> str:
    state = jax.ShapeDtypeStruct((n, 4), jnp.float32)
    params = jax.ShapeDtypeStruct((n, PARAMS), jnp.float32)
    fn = lambda s, p: (idm_accel(s, p),)
    return to_hlo_text(jax.jit(fn).lower(state, params))


def lower_radar(n: int) -> str:
    state = jax.ShapeDtypeStruct((n, 4), jnp.float32)
    fn = lambda s: (radar_scan(s),)
    return to_hlo_text(jax.jit(fn).lower(state))


def main() -> None:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--out-dir", default="../artifacts", help="artifact directory")
    ap.add_argument("--buckets", type=int, nargs="*", default=list(BUCKETS))
    args = ap.parse_args()

    out = pathlib.Path(args.out_dir)
    out.mkdir(parents=True, exist_ok=True)

    manifest: dict = {
        "format": "hlo-text",
        # schema 4: everything schema 3 had (geometry operand,
        # destination-aware params row, n_exited observable) PLUS the
        # fused K-step rollout entry points (`rollout{K}_{N}` /
        # `rolloutb{K}_{N}`, K in ROLLOUT_STEPS).  Schema 5 adds the
        # whole-run entries (`run{T}_{N}` / `runb{T}_{N}`, T in
        # RUN_STEPS): demand arrives as a departure-table operand
        # (departure_columns × departure_rows) and insertion happens
        # in-kernel, so an entire run is ONE dispatch.  The rust runtime
        # still executes the single-step entries of schema-3 artifacts;
        # rollouts gate on schema >= 4, runs on schema >= 5
        # (runtime/manifest.rs).
        "schema": 5,
        "state_columns": ["x", "v", "lane", "active"],
        "param_columns": list(model.PARAM_COLUMNS),
        "obs_columns": list(model.OBS_COLUMNS),
        "geometry_columns": list(model.GEOM_COLUMNS),
        # default-geometry constants, kept as the model.py ↔ rust
        # MergeScenario drift check (the artifacts themselves are
        # geometry-generic)
        "dt": model.DT,
        "road_end": model.ROAD_END,
        "merge_start": model.MERGE_START,
        "merge_end": model.MERGE_END,
        "num_main_lanes": model.NUM_MAIN_LANES,
        "buckets": sorted(args.buckets),
        "entries": {},
    }

    manifest["batch"] = BATCH
    # the fused-rollout contract (schema 4): the K ladder plus the entry
    # name stems the runtime resolves `{stem}{K}_{N}` keys against
    manifest["rollout_steps"] = list(ROLLOUT_STEPS)
    manifest["rollout_entry_points"] = ["rollout", "rolloutb"]
    # the whole-run contract (schema 5): the total-steps ladder, the
    # departure-table operand layout, and the entry stems the runtime
    # resolves `{stem}{T}_{N}` keys against
    manifest["run_steps"] = list(RUN_STEPS)
    manifest["run_entry_points"] = ["run", "runb"]
    manifest["departure_columns"] = list(model.DEP_COLUMNS)
    manifest["departure_rows"] = DEPARTURE_ROWS
    operands = {
        "step": 3,
        "stepb": 3,
        "rollout": 3,
        "rolloutb": 3,
        "run": 4,
        "runb": 4,
        "idm": 2,
        "radar": 1,
    }
    for n in sorted(args.buckets):
        for name, lower in (("step", lower_step), ("idm", lower_idm), ("radar", lower_radar)):
            path = out / f"{name}_{n}.hlo.txt"
            text = lower(n)
            path.write_text(text)
            manifest["entries"][f"{name}_{n}"] = {
                "file": path.name,
                "n": n,
                "outputs": 4 if name == "step" else 1,
                "operands": operands[name],
            }
            print(f"wrote {path} ({len(text)} chars)")
        # the batched step (engine-service micro-batching)
        path = out / f"stepb_{n}.hlo.txt"
        text = lower_step_batched(BATCH, n)
        path.write_text(text)
        manifest["entries"][f"stepb_{n}"] = {
            "file": path.name,
            "n": n,
            "outputs": 4,
            "operands": operands["stepb"],
        }
        print(f"wrote {path} ({len(text)} chars, batch={BATCH})")
        # the fused K-step rollouts (solo + micro-batched), one pair per
        # ladder K: what lets the runtime amortize one dispatch over a
        # whole physics chunk
        for k in ROLLOUT_STEPS:
            for stem, text in (
                ("rollout", lower_rollout(n, k)),
                ("rolloutb", lower_rollout_batched(BATCH, n, k)),
            ):
                path = out / f"{stem}{k}_{n}.hlo.txt"
                path.write_text(text)
                manifest["entries"][f"{stem}{k}_{n}"] = {
                    "file": path.name,
                    "n": n,
                    "k": k,
                    "outputs": 2,
                    "operands": operands[stem],
                }
                print(f"wrote {path} ({len(text)} chars, k={k})")
        # the whole-run entries (solo + micro-batched), one pair per
        # total-steps rung: demand compiled into the kernel, one PJRT
        # dispatch per run
        for t in RUN_STEPS:
            for stem, text in (
                ("run", lower_run(n, t)),
                ("runb", lower_run_batched(BATCH, n, t)),
            ):
                path = out / f"{stem}{t}_{n}.hlo.txt"
                path.write_text(text)
                manifest["entries"][f"{stem}{t}_{n}"] = {
                    "file": path.name,
                    "n": n,
                    "k_total": t,
                    "outputs": 4,
                    "operands": operands[stem],
                }
                print(f"wrote {path} ({len(text)} chars, k_total={t})")

    (out / "manifest.json").write_text(json.dumps(manifest, indent=2))
    print(f"wrote {out / 'manifest.json'}")


if __name__ == "__main__":
    main()
