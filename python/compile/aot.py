"""AOT lowering: jax → HLO *text* artifacts for the rust PJRT runtime.

Emits one artifact per (function, vehicle-count) bucket:

  artifacts/step_{N}.hlo.txt   — full sim step (model.step_geom,
                                 geometry-generic: scenario constants are
                                 an f32[5] runtime operand; destination-
                                 aware: params carry [exit_pos,
                                 exit_flag] columns — schema 3)
  artifacts/idm_{N}.hlo.txt    — bare L1 IDM kernel (rust microbench target)
  artifacts/radar_{N}.hlo.txt  — bare L1 radar kernel
  artifacts/manifest.json      — shapes, column layout, geometry layout

HLO TEXT is the interchange format, NOT serialized HloModuleProto: jax
>= 0.5 emits protos with 64-bit instruction ids which xla_extension 0.5.1
(the version behind the `xla` rust crate) rejects (`proto.id() <=
INT_MAX`).  The text parser reassigns ids and round-trips cleanly.  We
lower the stablehlo module and convert with ``return_tuple=True``; the
rust side unwraps with ``to_tuple{k}()``.

Run via ``make artifacts`` (no-op when inputs are unchanged).
"""

from __future__ import annotations

import argparse
import json
import pathlib

import jax
import jax.numpy as jnp
from jax._src.lib import xla_client as xc

from . import model
from .kernels.idm_pairwise import idm_accel
from .kernels.radar import radar_scan

#: vehicle-count buckets lowered ahead of time; the rust runtime picks the
#: smallest bucket >= the live vehicle count and pads with inactive rows.
#: 1024 covers the largest capacity any scenario family suggests
#: (`rust/src/scenario/family.rs` DEFAULT_BUCKET_LADDER), so no scenario
#: point ever falls back to the native stepper.
BUCKETS = (16, 64, 256, 1024)


def to_hlo_text(lowered) -> str:
    """stablehlo → XlaComputation → HLO text (see module docstring)."""
    mlir_mod = lowered.compiler_ir("stablehlo")
    comp = xc._xla.mlir.mlir_module_to_xla_computation(
        str(mlir_mod), use_tuple_args=False, return_tuple=True
    )
    return comp.as_hlo_text()


#: geometry-operand width (see model.GEOM_COLUMNS).
GEOM = len(model.GEOM_COLUMNS)
#: params-row width (schema 3: 6 driver columns + [exit_pos, exit_flag]).
PARAMS = len(model.PARAM_COLUMNS)


def lower_step(n: int) -> str:
    """The geometry-generic, destination-aware step: state/params plus
    the f32[GEOM] geometry operand — one executable per bucket serves
    every scenario family AND every per-vehicle route (no per-geometry,
    no per-route recompile)."""
    state = jax.ShapeDtypeStruct((n, 4), jnp.float32)
    params = jax.ShapeDtypeStruct((n, PARAMS), jnp.float32)
    geom = jax.ShapeDtypeStruct((GEOM,), jnp.float32)
    return to_hlo_text(jax.jit(model.step_geom).lower(state, params, geom))


#: batch width of the vmapped step (the engine service's dynamic
#: micro-batcher coalesces concurrent instances up to this many).
BATCH = 8


def lower_step_batched(b: int, n: int) -> str:
    """vmap(step_geom) over a leading instance axis: one PJRT dispatch
    serves `b` co-located simulation instances (perf pass, EXPERIMENTS.md
    §Perf).  The geometry rows are batched too (f32[b, GEOM]), so
    co-located instances running *different* scenario families still
    coalesce into a single dispatch.
    """
    state = jax.ShapeDtypeStruct((b, n, 4), jnp.float32)
    params = jax.ShapeDtypeStruct((b, n, PARAMS), jnp.float32)
    geom = jax.ShapeDtypeStruct((b, GEOM), jnp.float32)
    return to_hlo_text(jax.jit(jax.vmap(model.step_geom)).lower(state, params, geom))


def lower_idm(n: int) -> str:
    state = jax.ShapeDtypeStruct((n, 4), jnp.float32)
    params = jax.ShapeDtypeStruct((n, PARAMS), jnp.float32)
    fn = lambda s, p: (idm_accel(s, p),)
    return to_hlo_text(jax.jit(fn).lower(state, params))


def lower_radar(n: int) -> str:
    state = jax.ShapeDtypeStruct((n, 4), jnp.float32)
    fn = lambda s: (radar_scan(s),)
    return to_hlo_text(jax.jit(fn).lower(state))


def main() -> None:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--out-dir", default="../artifacts", help="artifact directory")
    ap.add_argument("--buckets", type=int, nargs="*", default=list(BUCKETS))
    args = ap.parse_args()

    out = pathlib.Path(args.out_dir)
    out.mkdir(parents=True, exist_ok=True)

    manifest: dict = {
        "format": "hlo-text",
        # schema 3: step/stepb artifacts take the geometry operand AND
        # the widened destination-aware params row ([exit_pos,
        # exit_flag] columns, obs gains n_exited); the rust runtime
        # (runtime/manifest.rs) refuses older artifacts.
        "schema": 3,
        "state_columns": ["x", "v", "lane", "active"],
        "param_columns": list(model.PARAM_COLUMNS),
        "obs_columns": list(model.OBS_COLUMNS),
        "geometry_columns": list(model.GEOM_COLUMNS),
        # default-geometry constants, kept as the model.py ↔ rust
        # MergeScenario drift check (the artifacts themselves are
        # geometry-generic)
        "dt": model.DT,
        "road_end": model.ROAD_END,
        "merge_start": model.MERGE_START,
        "merge_end": model.MERGE_END,
        "num_main_lanes": model.NUM_MAIN_LANES,
        "buckets": sorted(args.buckets),
        "entries": {},
    }

    manifest["batch"] = BATCH
    operands = {"step": 3, "stepb": 3, "idm": 2, "radar": 1}
    for n in sorted(args.buckets):
        for name, lower in (("step", lower_step), ("idm", lower_idm), ("radar", lower_radar)):
            path = out / f"{name}_{n}.hlo.txt"
            text = lower(n)
            path.write_text(text)
            manifest["entries"][f"{name}_{n}"] = {
                "file": path.name,
                "n": n,
                "outputs": 4 if name == "step" else 1,
                "operands": operands[name],
            }
            print(f"wrote {path} ({len(text)} chars)")
        # the batched step (engine-service micro-batching)
        path = out / f"stepb_{n}.hlo.txt"
        text = lower_step_batched(BATCH, n)
        path.write_text(text)
        manifest["entries"][f"stepb_{n}"] = {
            "file": path.name,
            "n": n,
            "outputs": 4,
            "operands": operands["stepb"],
        }
        print(f"wrote {path} ({len(text)} chars, batch={BATCH})")

    (out / "manifest.json").write_text(json.dumps(manifest, indent=2))
    print(f"wrote {out / 'manifest.json'}")


if __name__ == "__main__":
    main()
