"""L1 Pallas kernel: blocked pairwise leader search + IDM acceleration.

This is the compute hot-spot of the highway-merge physics step (the O(N^2)
neighbour interaction).  The kernel is tiled over the *ego* (i) axis: each
grid step loads a (BI, 4) block of ego state into VMEM together with the
full (N, 4)/(N, 6) j-side arrays, builds the (BI, N) masked distance
matrix, and reduces it to bumper-to-bumper gap, leader speed and IDM
acceleration — all elementwise/reduce ops (VPU work; no gathers, no
scatter).  See DESIGN.md §8 for the VMEM/MXU accounting.

The math mirrors ``ref.py`` exactly (same mask-min tie-breaking) so that
pytest can assert allclose at f32 tolerance.

interpret=True is mandatory on this image: the CPU PJRT plugin cannot run
Mosaic custom-calls, and interpret mode lowers the kernel to plain HLO that
any backend (including the rust-side CPU client) executes.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from .ref import (
    A_MAX,
    ACTIVE,
    B_COMF,
    FREE_GAP,
    LANE,
    LENGTH,
    MIN_GAP,
    S0,
    T_HW,
    V,
    V0,
    X,
)

#: Ego-axis tile.  128 rows keeps the (BI, N) distance matrix comfortably
#: inside a TPU core's VMEM for the N we lower (16..512) — see DESIGN.md §8.
DEFAULT_BLOCK = 128


def _idm_kernel(state_blk, state_all, params_blk, params_all, accel_out):
    """One grid step: egos = rows of ``state_blk``, leaders = all rows."""
    x_i = state_blk[:, X][:, None]          # (BI, 1)
    v_i = state_blk[:, V]                   # (BI,)
    lane_i = state_blk[:, LANE][:, None]
    active_i = state_blk[:, ACTIVE] > 0.5

    x_j = state_all[:, X][None, :]          # (1, N)
    v_j = state_all[:, V][None, :]
    lane_j = state_all[:, LANE][None, :]
    active_j = state_all[:, ACTIVE][None, :] > 0.5
    len_j = params_all[:, LENGTH][None, :]

    dx = x_j - x_i                          # (BI, N)
    valid = (jnp.abs(lane_j - lane_i) < 0.5) & (dx > 1e-6) & active_j

    dist = jnp.where(valid, dx, FREE_GAP)
    center_gap = jnp.min(dist, axis=1)      # (BI,)
    has_leader = center_gap < FREE_GAP * 0.5

    is_leader = valid & (dist <= center_gap[:, None])
    lv = jnp.min(jnp.where(is_leader, v_j, FREE_GAP), axis=1)
    lv = jnp.where(has_leader, lv, v_i)
    llen = jnp.min(jnp.where(is_leader, len_j, FREE_GAP), axis=1)
    llen = jnp.where(has_leader, llen, 0.0)

    gap = jnp.where(has_leader, center_gap - llen, FREE_GAP)
    s = jnp.maximum(gap, MIN_GAP)
    dv = v_i - lv

    v0 = jnp.maximum(params_blk[:, V0], 0.1)
    t_hw = params_blk[:, T_HW]
    a_max = jnp.maximum(params_blk[:, A_MAX], 1e-3)
    b = jnp.maximum(params_blk[:, B_COMF], 1e-3)
    s0 = params_blk[:, S0]

    s_star = jnp.maximum(s0 + v_i * t_hw + v_i * dv / (2.0 * jnp.sqrt(a_max * b)), 0.0)
    free = 1.0 - (v_i / v0) ** 4
    interaction = jnp.where(has_leader, (s_star / s) ** 2, 0.0)
    accel = a_max * (free - interaction)
    accel_out[...] = jnp.where(active_i, accel, 0.0)


@functools.partial(jax.jit, static_argnames=("block",))
def idm_accel(state: jnp.ndarray, params: jnp.ndarray, *, block: int = DEFAULT_BLOCK) -> jnp.ndarray:
    """IDM acceleration via the blocked Pallas kernel.

    ``state`` f32[N, 4], ``params`` f32[N, P] → f32[N] (P >= 6; the
    schema-3 ABI ships P = 8 but the kernel reads the 6 driver columns
    only, so the destination columns are sliced off *before* the
    pallas_call and never streamed into the blocks).  N must be a
    multiple of ``block`` (callers pad with inactive rows; ``model.py``
    does this automatically).
    """
    params = params[:, : LENGTH + 1]
    n = state.shape[0]
    p = params.shape[1]
    bi = min(block, n)
    if n % bi != 0:
        raise ValueError(f"N={n} not a multiple of block={bi}; pad with inactive rows")
    grid = (n // bi,)
    return pl.pallas_call(
        _idm_kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((bi, 4), lambda i: (i, 0)),   # ego block
            pl.BlockSpec((n, 4), lambda i: (0, 0)),    # full j-side state
            pl.BlockSpec((bi, p), lambda i: (i, 0)),   # ego params
            pl.BlockSpec((n, p), lambda i: (0, 0)),    # full j-side params
        ],
        out_specs=pl.BlockSpec((bi,), lambda i: (i,)),
        out_shape=jax.ShapeDtypeStruct((n,), jnp.float32),
        interpret=True,
    )(state, state, params, params)
