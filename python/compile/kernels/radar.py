"""L1 Pallas kernel: forward radar (nearest target ahead, any lane).

Same blocked structure as ``idm_pairwise``: ego-axis tiles against the
full target set, gather-free mask-min selection, mirroring
``ref.radar_ref`` exactly.  This is the sensor model Webots vehicles use
for the CAV merge controller (paper §2.5.3: "Radars ... can all be added
to Webots vehicles").
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from .ref import ACTIVE, FREE_GAP, RADAR_RANGE, V, X

DEFAULT_BLOCK = 128


def _radar_kernel(state_blk, state_all, out, *, max_range: float):
    x_i = state_blk[:, X][:, None]
    v_i = state_blk[:, V]
    active_i = state_blk[:, ACTIVE] > 0.5

    x_j = state_all[:, X][None, :]
    v_j = state_all[:, V][None, :]
    active_j = state_all[:, ACTIVE][None, :] > 0.5

    dx = x_j - x_i
    valid = (dx > 1e-6) & (dx <= max_range) & active_j
    dist = jnp.where(valid, dx, max_range)
    rng = jnp.min(dist, axis=1)
    hit = rng < max_range - 1e-6

    is_tgt = valid & (dist <= rng[:, None])
    tv = jnp.min(jnp.where(is_tgt, v_j, FREE_GAP), axis=1)
    closing = jnp.where(hit, v_i - tv, 0.0)

    rng = jnp.where(active_i, rng, max_range)
    closing = jnp.where(active_i, closing, 0.0)
    out[...] = jnp.stack([rng, closing], axis=1)


@functools.partial(jax.jit, static_argnames=("block", "max_range"))
def radar_scan(
    state: jnp.ndarray,
    *,
    max_range: float = RADAR_RANGE,
    block: int = DEFAULT_BLOCK,
) -> jnp.ndarray:
    """Radar returns f32[N, 2] = [distance, closing_speed]."""
    n = state.shape[0]
    bi = min(block, n)
    if n % bi != 0:
        raise ValueError(f"N={n} not a multiple of block={bi}")
    grid = (n // bi,)
    kernel = functools.partial(_radar_kernel, max_range=max_range)
    return pl.pallas_call(
        kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((bi, 4), lambda i: (i, 0)),
            pl.BlockSpec((n, 4), lambda i: (0, 0)),
        ],
        out_specs=pl.BlockSpec((bi, 2), lambda i: (i, 0)),
        out_shape=jax.ShapeDtypeStruct((n, 2), jnp.float32),
        interpret=True,
    )(state, state)
