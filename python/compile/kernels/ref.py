"""Pure-jnp oracles for the L1 Pallas kernels.

These are the correctness references: slow-but-obvious implementations of
the pairwise leader search + IDM car-following law (``idm_accel_ref``) and
the forward-looking radar model (``radar_ref``).  ``python/tests`` asserts
the Pallas kernels match these to float32 tolerance across
hypothesis-generated states.

Leader selection is formulated as *mask-min* rather than argmin+gather: we
take the row-min of the masked distance matrix, then re-mask on equality
with that min and reduce the leader attribute (speed / length) with a
second min.  This keeps the math gather-free (TPU/VPU friendly — the
Pallas kernel uses the identical formulation) and makes tie-breaking
deterministic in both implementations: among co-located leaders the one
with the smallest speed/length wins.

State layout (shared with model.py and the rust coordinator — see
``rust/src/runtime/engine.rs``):

  state  : f32[N, 4]  columns = [x, v, lane, active]
  params : f32[N, 8]  columns = [v0, T, a_max, b, s0, length,
                                 exit_pos, exit_flag]

The last two params columns are the schema-3 destination intent: a
vehicle with ``exit_flag`` set retires when it crosses its own
``exit_pos`` on lane <= 1 (the off-ramp gore) instead of riding to the
road end.  The L1 kernels never read them — only ``model.step_geom``'s
lane-change and integration blocks do.

Inactive rows (active == 0) are ignored both as egos (accel forced to 0)
and as potential leaders.
"""

from __future__ import annotations

import jax.numpy as jnp

# Column indices — keep in sync with rust/src/runtime/engine.rs
X, V, LANE, ACTIVE = 0, 1, 2, 3
V0, T_HW, A_MAX, B_COMF, S0, LENGTH, EXIT_POS, EXIT_FLAG = 0, 1, 2, 3, 4, 5, 6, 7

#: Distance reported when no leader exists (effectively infinite for IDM).
FREE_GAP = 1.0e6
#: Numerical floor on the gap to avoid division blow-ups when bumper-to-bumper.
MIN_GAP = 0.5
#: Default forward-radar range [m].
RADAR_RANGE = 150.0


def leader_scan_ref(state: jnp.ndarray, params: jnp.ndarray):
    """For each vehicle, find the nearest active vehicle *ahead on the same
    lane*; return ``(gap, leader_speed, has_leader)`` where gap is
    bumper-to-bumper (leader length subtracted).  No-leader rows get
    ``FREE_GAP`` and their own speed (dv = 0).
    """
    x = state[:, X]
    v = state[:, V]
    lane = state[:, LANE]

    dx = x[None, :] - x[:, None]  # dx[i, j] = x_j - x_i
    same_lane = jnp.abs(lane[None, :] - lane[:, None]) < 0.5
    ahead = dx > 1e-6
    valid = same_lane & ahead & (state[:, ACTIVE][None, :] > 0.5)

    dist = jnp.where(valid, dx, FREE_GAP)
    center_gap = jnp.min(dist, axis=1)
    has_leader = center_gap < FREE_GAP * 0.5

    # mask-min leader attribute selection (see module docstring)
    is_leader = valid & (dist <= center_gap[:, None])
    lv = jnp.min(jnp.where(is_leader, v[None, :], FREE_GAP), axis=1)
    lv = jnp.where(has_leader, lv, v)
    llen = jnp.min(jnp.where(is_leader, params[None, :, LENGTH], FREE_GAP), axis=1)
    llen = jnp.where(has_leader, llen, 0.0)

    gap = jnp.where(has_leader, center_gap - llen, FREE_GAP)
    return gap, lv, has_leader


def idm_accel_ref(state: jnp.ndarray, params: jnp.ndarray) -> jnp.ndarray:
    """Intelligent Driver Model acceleration for every vehicle.

    a_i = a_max * (1 - (v/v0)^4 - (s*/s)^2)
    s*  = s0 + v*T + v*dv / (2*sqrt(a_max*b))

    where s is the bumper-to-bumper gap to the same-lane leader.
    Inactive vehicles get 0.
    """
    v = state[:, V]
    active = state[:, ACTIVE] > 0.5

    gap, lv, has_leader = leader_scan_ref(state, params)
    s = jnp.maximum(gap, MIN_GAP)
    dv = v - lv

    v0 = jnp.maximum(params[:, V0], 0.1)
    t_hw = params[:, T_HW]
    a_max = jnp.maximum(params[:, A_MAX], 1e-3)
    b = jnp.maximum(params[:, B_COMF], 1e-3)
    s0 = params[:, S0]

    s_star = jnp.maximum(s0 + v * t_hw + v * dv / (2.0 * jnp.sqrt(a_max * b)), 0.0)
    free = 1.0 - (v / v0) ** 4
    interaction = jnp.where(has_leader, (s_star / s) ** 2, 0.0)
    accel = a_max * (free - interaction)
    return jnp.where(active, accel, 0.0)


def radar_ref(state: jnp.ndarray, max_range: float = RADAR_RANGE) -> jnp.ndarray:
    """Forward radar: nearest active vehicle ahead in ANY lane within
    ``max_range``.  Returns f32[N, 2] = [distance, closing_speed]; when no
    target is in range, [max_range, 0].  Inactive egos report a clear field.
    """
    x = state[:, X]
    v = state[:, V]
    active = state[:, ACTIVE] > 0.5

    dx = x[None, :] - x[:, None]
    valid = (dx > 1e-6) & (dx <= max_range) & active[None, :]
    dist = jnp.where(valid, dx, max_range)
    rng = jnp.min(dist, axis=1)
    hit = rng < max_range - 1e-6

    is_tgt = valid & (dist <= rng[:, None])
    tv = jnp.min(jnp.where(is_tgt, v[None, :], FREE_GAP), axis=1)
    closing = jnp.where(hit, v - tv, 0.0)

    rng = jnp.where(active, rng, max_range)
    closing = jnp.where(active, closing, 0.0)
    return jnp.stack([rng, closing], axis=1)
