"""L2: the sample CAV highway-merge simulation step as a JAX compute graph.

This is the physics/behaviour hot path of the paper's "sample Webots-SUMO
highway merging simulation" (ch. 5).  One call advances the coupled
traffic state by DT seconds:

  * car-following accelerations via the L1 Pallas kernel
    (``kernels.idm_pairwise``),
  * a phantom-wall constraint that forces on-ramp vehicles to stop at the
    end of the acceleration lane,
  * MOBIL-style lane changes (mandatory merge for ramp vehicles inside
    the merge zone, discretionary keep-right/overtake on the mainline),
  * forward radar returns via the L1 ``kernels.radar`` kernel (the sensor
    feed the Webots CAV controller consumes),
  * Euler integration and per-step observables.

The function is lowered ONCE per vehicle-count bucket by ``aot.py`` into
``artifacts/step_{N}.hlo.txt`` and executed from rust via PJRT — python is
never on the request path.

Road geometry: lane 0 is the on-ramp/acceleration lane, lanes
1..num_main_lanes are the mainline.  The merge zone is [merge_start,
merge_end]; ramp vehicles must be in lane >= 1 by merge_end or stop.

Geometry is a **runtime operand**, not a compile-time constant
(``step_geom``): the scenario constants arrive as an f32[5] vector
(layout ``GEOM_COLUMNS``, exported to rust through
``artifacts/manifest.json`` as ``geometry_columns``), so ONE compiled
executable per vehicle-count bucket serves every scenario family —
highway-merge, lane-drop, ramp-weave, ring-shockwave — with no
per-geometry recompile.  ``step`` keeps the classic constant-geometry
signature as a thin wrapper over ``step_geom`` (the python tests' and
the vmapped batched artifact's reference semantics are unchanged for
the default geometry).

Destination intent is per-vehicle, not per-scenario (schema 3): the
params row carries ``[exit_pos, exit_flag]`` columns (``PARAM_COLUMNS``)
compiled from each flow's route, so the same executable retires
off-ramp traffic at its own gore while through traffic rides to
``road_end`` — no per-route Python on the request path.

Rollouts are fused on-device (schema 4): ``rollout_geom`` wraps
``step_geom`` in a ``lax.scan`` over K steps, so one PJRT dispatch
amortizes over an entire K-step chunk instead of paying a host round
trip per step — bit-exact with K sequential steps, per-step observables
preserved as an f32[K, OBS_COLS] trace (``aot.py ROLLOUT_STEPS`` is the
lowered K ladder).
"""

from __future__ import annotations

import jax
import jax.numpy as jnp

from .kernels.idm_pairwise import idm_accel
from .kernels.radar import radar_scan
from .kernels.ref import (
    ACTIVE,
    B_COMF,
    EXIT_FLAG,
    EXIT_POS,
    FREE_GAP,
    LANE,
    LENGTH,
    MIN_GAP,
    RADAR_RANGE,
    S0,
    T_HW,
    V,
    V0,
    X,
)

# --- default road geometry / integration constants (recorded in
# manifest.json as the drift-check reference; the lowered artifacts take
# the live values as the geometry operand) ---
DT = 0.1                 #: integration step [s]
ROAD_END = 1000.0        #: vehicles deactivate past this x [m]
MERGE_START = 300.0      #: start of the acceleration-lane merge zone [m]
MERGE_END = 500.0        #: hard end of the on-ramp [m]
NUM_MAIN_LANES = 2       #: mainline lanes are 1..NUM_MAIN_LANES
RAMP_LANE = 0.0

#: geometry-operand layout — keep in sync with `rust/src/sumo/state.rs`
#: (GEOM_COLS/G_*) and `artifacts/manifest.json` "geometry_columns".
GEOM_COLUMNS = ["road_end", "merge_start", "merge_end", "num_main_lanes", "dt"]
G_ROAD_END, G_MERGE_START, G_MERGE_END, G_NUM_MAIN_LANES, G_DT = range(5)

#: schema-3 params-row layout — keep in sync with `rust/src/sumo/state.rs`
#: (PARAM_COLS/P_*) and `artifacts/manifest.json` "param_columns".  The
#: two destination columns make the compiled kernel route-aware: a
#: vehicle with ``exit_flag`` set retires when it crosses its own
#: ``exit_pos`` on lane <= 1 (the off-ramp gore) instead of at road_end.
PARAM_COLUMNS = ["v0", "T", "a_max", "b", "s0", "length", "exit_pos", "exit_flag"]

#: per-step observables — obs[4] counts off-ramp exits separately from
#: road-end flow so off-ramp completions are visible in aggregates.
OBS_COLUMNS = ["n_active", "mean_speed", "flow", "n_merged", "n_exited"]

#: schema-5 departure-table row layout — keep in sync with
#: `rust/src/sumo/simulation.rs` (DEP_COLS/DEP_*) and
#: `artifacts/manifest.json` "departure_columns".  One row per scheduled
#: departure: the epoch step index at which it becomes due (derived
#: host-side from the same f32 `t += dt` accumulation the sequential
#: scheduler uses) plus the full spawn payload — the state row
#: `[x, v, lane]` and the eight params columns.  Compiling demand into
#: an operand is what lets a whole run execute as ONE dispatch: the
#: host-side insertion queue becomes in-kernel params-driven events.
DEP_COLUMNS = [
    "step", "x", "v", "lane",
    "v0", "T", "a_max", "b", "s0", "length", "exit_pos", "exit_flag",
]
D_STEP, D_X, D_V, D_LANE = range(4)
D_PARAMS = 4  #: params payload starts here (8 columns, PARAM_COLUMNS order)

#: epoch sentinel for table padding rows: never due within any run
#: (2^30 steps ≈ 3.4 sim-years at DT=0.1; exactly representable in f32).
DEP_PAD_EPOCH = float(2**30)


def default_geometry() -> jnp.ndarray:
    """The classic ch. 5 merge geometry as an operand row (f32[5])."""
    return jnp.array(
        [ROAD_END, MERGE_START, MERGE_END, float(NUM_MAIN_LANES), DT],
        dtype=jnp.float32,
    )
#: MOBIL parameters
MOBIL_SAFE_DECEL = 4.0   #: follower in target lane may not brake harder [m/s^2]
MOBIL_THRESHOLD = 0.2    #: discretionary incentive threshold [m/s^2]
MOBIL_POLITENESS = 0.3


def _lane_gap_scan(state, params, target_lane):
    """Mask-min leader/follower scan against a *hypothetical* target lane.

    Returns (lead_gap, lead_v, lag_gap, lag_v): bumper-to-bumper gaps to
    the nearest active vehicle ahead/behind on ``target_lane`` (f32[N]).
    """
    x = state[:, X]
    v = state[:, V]
    lane = state[:, LANE]
    act = state[:, ACTIVE] > 0.5
    length = params[:, LENGTH]

    dx = x[None, :] - x[:, None]
    on_target = jnp.abs(lane[None, :] - target_lane[:, None]) < 0.5
    valid_ahead = on_target & (dx > 1e-6) & act[None, :]
    valid_behind = on_target & (dx < -1e-6) & act[None, :]

    dist_a = jnp.where(valid_ahead, dx, FREE_GAP)
    lead_center = jnp.min(dist_a, axis=1)
    lead_has = lead_center < FREE_GAP * 0.5
    is_lead = valid_ahead & (dist_a <= lead_center[:, None])
    lead_v = jnp.min(jnp.where(is_lead, v[None, :], FREE_GAP), axis=1)
    lead_v = jnp.where(lead_has, lead_v, v)
    lead_len = jnp.min(jnp.where(is_lead, length[None, :], FREE_GAP), axis=1)
    lead_len = jnp.where(lead_has, lead_len, 0.0)
    lead_gap = jnp.where(lead_has, lead_center - lead_len, FREE_GAP)

    dist_b = jnp.where(valid_behind, -dx, FREE_GAP)
    lag_center = jnp.min(dist_b, axis=1)
    lag_has = lag_center < FREE_GAP * 0.5
    is_lag = valid_behind & (-dx <= lag_center[:, None])
    lag_v = jnp.min(jnp.where(is_lag, v[None, :], FREE_GAP), axis=1)
    lag_v = jnp.where(lag_has, lag_v, v)
    # follower's gap is to OUR tail: subtract ego length
    lag_gap = jnp.where(lag_has, lag_center - params[:, LENGTH], FREE_GAP)

    return lead_gap, lead_v, lag_gap, lag_v


def _idm_for(v, gap, dv, params):
    """Scalar-wise IDM used for hypothetical-lane incentives (pure jnp)."""
    s = jnp.maximum(gap, MIN_GAP)
    v0 = jnp.maximum(params[:, V0], 0.1)
    a_max = jnp.maximum(params[:, 2], 1e-3)
    b = jnp.maximum(params[:, B_COMF], 1e-3)
    s_star = jnp.maximum(params[:, S0] + v * params[:, T_HW] + v * dv / (2.0 * jnp.sqrt(a_max * b)), 0.0)
    inter = jnp.where(gap < FREE_GAP * 0.5, (s_star / s) ** 2, 0.0)
    return a_max * (1.0 - (v / v0) ** 4 - inter)


def _wall_accel(state, params, merge_end):
    """IDM deceleration against the phantom wall at ``merge_end`` (ramp
    only).  Exit-flagged vehicles see no wall: their road continues
    through the off-ramp gore at ``exit_pos``, so the lane does not end
    for them."""
    x = state[:, X]
    v = state[:, V]
    has_exit = params[:, EXIT_FLAG] > 0.5
    on_ramp = (jnp.abs(state[:, LANE] - RAMP_LANE) < 0.5) & ~has_exit
    gap = jnp.where(on_ramp, merge_end - x, FREE_GAP)
    gap = jnp.maximum(gap, MIN_GAP * 0.1)
    return _idm_for(v, gap, v, params)  # wall speed = 0 → dv = v


def step_geom(state: jnp.ndarray, params: jnp.ndarray, geom: jnp.ndarray):
    """Advance the simulation by one step under a runtime geometry.

    Inputs : state f32[N,4], params f32[N,8]  (layout in kernels/ref.py;
             params[:, 6:8] = [exit_pos, exit_flag] destination intent)
             geom  f32[5]  = [road_end, merge_start, merge_end,
                              num_main_lanes, dt]  (GEOM_COLUMNS)
    Outputs: (new_state f32[N,4], accel f32[N], radar f32[N,2], obs f32[5])
             obs = [n_active, mean_speed, flow (crossed road_end),
                    n_merged, n_exited (crossed own exit_pos)]

    Destination dynamics (schema 3): a vehicle with exit_flag set works
    toward lane 1 (mandatory down-bias overriding discretionary gain,
    never changing up) and retires when it crosses its own exit_pos
    while on lane <= 1 — the off-ramp gore.  Everyone else retires at
    road_end as before.
    """
    road_end = geom[G_ROAD_END]
    merge_start = geom[G_MERGE_START]
    merge_end = geom[G_MERGE_END]
    num_main_lanes = geom[G_NUM_MAIN_LANES]
    dt = geom[G_DT]

    x = state[:, X]
    v = state[:, V]
    lane = state[:, LANE]
    act = state[:, ACTIVE]
    active = act > 0.5

    # --- L1 kernels -------------------------------------------------------
    a_follow = idm_accel(state, params)
    radar = radar_scan(state)

    # ramp wall constraint
    a_wall = _wall_accel(state, params, merge_end)
    accel = jnp.minimum(a_follow, a_wall)

    # --- MOBIL lane changes ----------------------------------------------
    on_ramp = jnp.abs(lane - RAMP_LANE) < 0.5
    in_merge_zone = on_ramp & (x >= merge_start) & (x <= merge_end)
    # mandatory target for ramp vehicles is lane 1; mainline considers lane+-1
    tgt_up = jnp.where(on_ramp, 1.0, jnp.minimum(lane + 1.0, num_main_lanes))
    tgt_down = jnp.where(on_ramp, 1.0, jnp.maximum(lane - 1.0, 1.0))

    def incentive(target_lane):
        lead_gap, lead_v, lag_gap, lag_v = _lane_gap_scan(state, params, target_lane)
        a_self_new = _idm_for(v, lead_gap, v - lead_v, params)
        # follower safety: if it had to follow us, would it brake too hard?
        a_lag_new = _idm_for(lag_v, lag_gap, lag_v - v, params)
        safe = (lead_gap > params[:, S0]) & (lag_gap > params[:, S0]) & (a_lag_new > -MOBIL_SAFE_DECEL)
        return a_self_new, a_lag_new, safe

    a_up, a_lag_up, safe_up = incentive(tgt_up)
    a_dn, a_lag_dn, safe_dn = incentive(tgt_down)

    # mandatory merge: ramp vehicle inside the zone changes whenever safe
    do_merge = in_merge_zone & safe_up
    # discretionary: mainline, incentive beats threshold + politeness term
    gain_up = a_up - accel - MOBIL_POLITENESS * jnp.maximum(0.0, -a_lag_up)
    gain_dn = a_dn - accel - MOBIL_POLITENESS * jnp.maximum(0.0, -a_lag_dn)
    main = ~on_ramp & active
    has_exit = params[:, EXIT_FLAG] > 0.5
    disc_up = main & ~has_exit & safe_up & (tgt_up > lane + 0.5) & (gain_up > MOBIL_THRESHOLD)
    # mandatory exit-intent bias: an exit-flagged mainline vehicle works
    # toward lane 1 whenever safe, overriding the discretionary gain
    exit_dn = main & has_exit & (tgt_down < lane - 0.5) & safe_dn
    disc_dn = main & ~has_exit & safe_dn & (tgt_down < lane - 0.5) & (gain_dn > MOBIL_THRESHOLD) & ~disc_up

    new_lane = jnp.where(do_merge & active, 1.0, lane)
    new_lane = jnp.where(disc_up, tgt_up, new_lane)
    new_lane = jnp.where(disc_dn | exit_dn, tgt_down, new_lane)

    # --- integration -------------------------------------------------------
    new_v = jnp.maximum(v + accel * dt, 0.0)
    new_v = jnp.where(active, new_v, 0.0)
    new_x = x + new_v * dt
    crossed = active & (new_x >= road_end) & (x < road_end)
    exit_pos = params[:, EXIT_POS]
    exited = (
        active
        & has_exit
        & (new_lane < 1.5)
        & (new_x >= exit_pos)
        & (x < exit_pos)
        & ~crossed
    )
    new_act = jnp.where(crossed | exited, 0.0, act)
    new_x = jnp.where(active, new_x, x)

    new_state = jnp.stack([new_x, new_v, new_lane, new_act], axis=1)

    n_active = jnp.sum(act)
    mean_v = jnp.sum(v * act) / jnp.maximum(n_active, 1.0)
    flow = jnp.sum(crossed.astype(jnp.float32))
    n_merged = jnp.sum((do_merge & active).astype(jnp.float32))
    n_exited = jnp.sum(exited.astype(jnp.float32))
    obs = jnp.stack([n_active, mean_v, flow, n_merged, n_exited])

    return new_state, jnp.where(active, accel, 0.0), radar, obs


def step(state: jnp.ndarray, params: jnp.ndarray):
    """Advance the merge simulation by DT under the default geometry
    (the classic fixed-world signature; see ``step_geom``)."""
    return step_geom(state, params, default_geometry())


def rollout_geom(state: jnp.ndarray, params: jnp.ndarray, geom: jnp.ndarray, k: int):
    """Advance the simulation by ``k`` fused steps in ONE executable.

    Wraps ``step_geom`` in a ``lax.scan`` so an entire K-step rollout
    runs on-device: the state is the scan carry (exit retirement and the
    per-step observables — ``n_exited`` included — happen *inside* the
    loop, exactly as in ``k`` sequential ``step_geom`` calls), and the
    only host traffic is one dispatch and one reply.  ``params`` and
    ``geom`` are loop invariants: per-vehicle destination intent and the
    scenario geometry ride along unchanged, so one lowered rollout per
    (bucket, K) serves every scenario family and route mix.

    Inputs : state f32[N,4], params f32[N,PARAMS], geom f32[GEOM], k >= 1
    Outputs: (final_state f32[N,4], obs_trace f32[k, OBS_COLS])

    The per-step ``accel``/``radar`` outputs of ``step_geom`` are
    dropped from the scan outputs on purpose — the runtime's chunked
    stepper consumes only state + observables, and XLA dead-code
    eliminates the radar scan from the loop body entirely.

    Bit-exactness with ``k`` sequential ``step_geom`` calls is part of
    the ABI (the rust chunk scheduler splices fused chunks into
    step-by-step histories); it is asserted by
    ``tests/test_model.py::test_rollout_matches_sequential_steps`` and
    pre-verified against live artifacts by ``scripts/validate_sweep.py``.
    """

    def body(carry, _):
        new_state, _accel, _radar, obs = step_geom(carry, params, geom)
        return new_state, obs

    final_state, obs_trace = jax.lax.scan(body, state, None, length=k)
    return final_state, obs_trace


def run_geom(
    state: jnp.ndarray,
    params: jnp.ndarray,
    geom: jnp.ndarray,
    departures: jnp.ndarray,
    k_total: int,
):
    """A WHOLE run as one executable: demand compiled into the kernel.

    ``rollout_geom`` still breaks at every departure because insertion
    lives host-side; ``run_geom`` moves it in-kernel.  The departure
    schedule arrives as an operand table ``departures f32[D, DEP_COLS]``
    (rows sorted by epoch; padding rows carry ``DEP_PAD_EPOCH``), and the
    ``lax.scan`` carry grows a spawn cursor + per-row insertion mask so
    each step replays the sequential scheduler's insertion phase exactly:

      * a row is *pending* when its epoch step has been reached and it
        has not yet inserted — exactly the union of the host's insertion
        queue (earlier-blocked rows) and its newly-due departures, in
        the same order, because rows are scanned by ascending index;
      * insertion refuses when any active vehicle sits on the row's lane
        within ``s0 + length`` of the spawn point (the host's
        ``try_insert`` clearance), or when no slot is free — the row
        stays pending and retries next step, i.e. the insertion queue;
      * a successful insertion writes the state row ``[x, v, lane, 1]``
        and the 8-column params row into the FIRST inactive slot (the
        host's ``Traffic::spawn`` order), so slot assignment — and hence
        every subsequent pairwise interaction — is bit-identical.

    The physics after the insertion phase is untouched ``step_geom``, so
    the whole run is bit-exact with chunked/sequential stepping; the
    carry also threads ``params`` (insertions mutate it on-device).

    Inputs : state f32[N,4], params f32[N,PARAMS], geom f32[GEOM],
             departures f32[D, DEP_COLS], k_total >= 1 (static)
    Outputs: (final_state f32[N,4], final_params f32[N,PARAMS],
              obs_trace f32[k_total, OBS_COLS], inserted f32[D])
             ``inserted`` is the end-of-run insertion mask: the host
             reconstructs its departure cursor + insertion queue from it
             when a chunked tail (or a later horizon extension) follows.
    """
    d_rows = departures.shape[0]
    epochs = departures[:, D_STEP]
    row_idx = jnp.arange(d_rows, dtype=jnp.int32)

    def body(carry, step_idx):
        state, params, inserted, cursor = carry
        step_f = step_idx.astype(jnp.float32)

        def try_insert(j, c):
            state, params, inserted = c
            row = departures[j]
            pending = (row[D_STEP] <= step_f) & (inserted[j] < 0.5)
            occupied = state[:, ACTIVE] > 0.5
            same_lane = jnp.abs(state[:, LANE] - row[D_LANE]) < 0.5
            clearance = row[D_PARAMS + S0] + row[D_PARAMS + LENGTH]
            near = jnp.abs(state[:, X] - row[D_X]) < clearance
            blocked = jnp.any(occupied & same_lane & near)
            slot = jnp.argmin(state[:, ACTIVE])  # first inactive slot
            free = state[slot, ACTIVE] < 0.5
            do = pending & ~blocked & free
            spawn_state = jnp.stack(
                [row[D_X], row[D_V], row[D_LANE], jnp.float32(1.0)]
            )
            state = state.at[slot].set(
                jnp.where(do, spawn_state, state[slot])
            )
            params = params.at[slot].set(
                jnp.where(do, row[D_PARAMS:], params[slot])
            )
            inserted = inserted.at[j].set(jnp.where(do, 1.0, inserted[j]))
            return state, params, inserted

        # the pending window: [cursor, hi) — cursor is the spawn cursor
        # (everything before it inserted), hi the count of due rows
        # (epochs ascending, so rows past hi are not yet due)
        hi = jnp.sum(epochs <= step_f).astype(jnp.int32)
        state, params, inserted = jax.lax.fori_loop(
            cursor, hi, try_insert, (state, params, inserted)
        )
        open_rows = (row_idx >= cursor) & (inserted < 0.5)
        cursor = jnp.where(
            jnp.any(open_rows), jnp.argmax(open_rows), d_rows
        ).astype(jnp.int32)
        new_state, _accel, _radar, obs = step_geom(state, params, geom)
        return (new_state, params, inserted, cursor), obs

    inserted0 = jnp.zeros((d_rows,), dtype=jnp.float32)
    carry0 = (state, params, inserted0, jnp.int32(0))
    (final_state, final_params, inserted, _cursor), obs_trace = jax.lax.scan(
        body, carry0, jnp.arange(k_total, dtype=jnp.int32)
    )
    return final_state, final_params, obs_trace, inserted
