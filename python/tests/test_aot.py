"""AOT path: HLO-text artifacts are well-formed and shape-consistent."""

from __future__ import annotations

import json
import pathlib

import pytest

from compile import aot, model

ART = pathlib.Path(__file__).resolve().parents[2] / "artifacts"


def test_lower_step_contains_bucket_shape():
    text = aot.lower_step(16)
    assert "HloModule" in text
    assert "f32[16,4]" in text
    # schema 3: the widened destination-aware params row
    assert "f32[16,8]" in text
    # the geometry operand (schema 2): scenario constants arrive at
    # runtime instead of being baked in
    assert f"f32[{aot.GEOM}]" in text


def test_lower_idm_single_output_tuple():
    text = aot.lower_idm(16)
    # return_tuple=True → ROOT is a tuple even for one output
    assert "f32[16]" in text
    assert "HloModule" in text


def test_lower_radar_output_shape():
    text = aot.lower_radar(16)
    assert "f32[16,2]" in text


def test_step_is_pure_hlo_no_custom_calls():
    """interpret=True must lower pallas to plain HLO — a custom-call here
    would be unloadable by the rust CPU PJRT client."""
    text = aot.lower_step(16)
    assert "custom-call" not in text.lower()


@pytest.mark.skipif(not (ART / "manifest.json").exists(), reason="run `make artifacts` first")
def test_manifest_consistent_with_artifacts():
    manifest = json.loads((ART / "manifest.json").read_text())
    assert manifest["format"] == "hlo-text"
    assert manifest["schema"] == 5
    assert manifest["geometry_columns"] == model.GEOM_COLUMNS
    assert manifest["param_columns"] == model.PARAM_COLUMNS
    assert manifest["obs_columns"] == model.OBS_COLUMNS
    assert manifest["dt"] == model.DT
    assert manifest["merge_end"] == model.MERGE_END
    assert manifest["rollout_steps"] == list(aot.ROLLOUT_STEPS)
    assert manifest["rollout_entry_points"] == ["rollout", "rolloutb"]
    assert manifest["run_steps"] == list(aot.RUN_STEPS)
    assert manifest["run_entry_points"] == ["run", "runb"]
    assert manifest["departure_columns"] == model.DEP_COLUMNS
    assert manifest["departure_rows"] == aot.DEPARTURE_ROWS
    for key, entry in manifest["entries"].items():
        path = ART / entry["file"]
        assert path.exists(), f"missing artifact {path}"
        head = path.read_text()[:200]
        assert "HloModule" in head
        name, n = key.rsplit("_", 1)
        assert entry["n"] == int(n)
        if name.startswith("rollout"):
            stem = "rolloutb" if name.startswith("rolloutb") else "rollout"
            assert entry["k"] == int(name[len(stem):])
            assert entry["outputs"] == 2
            assert entry["operands"] == 3
        elif name.startswith("run"):
            stem = "runb" if name.startswith("runb") else "run"
            t = int(name[len(stem):])
            assert t in aot.RUN_STEPS
            assert entry["k_total"] == t
            # (final_state, final_params, obs_trace, inserted mask)
            assert entry["outputs"] == 4
            # state, params, geom, departures
            assert entry["operands"] == 4


def test_lower_step_batched_shapes():
    text = aot.lower_step_batched(aot.BATCH, 16)
    assert f"f32[{aot.BATCH},16,4]" in text
    assert f"f32[{aot.BATCH},16,8]" in text
    # per-lane geometry rows: mixed-family batches coalesce
    assert f"f32[{aot.BATCH},{aot.GEOM}]" in text
    assert "custom-call" not in text.lower()


def test_batched_step_matches_vmap_of_single():
    """vmap semantics: batched step == per-world single steps."""
    import jax
    import jax.numpy as jnp
    import numpy as np

    from compile import model

    rng = np.random.default_rng(5)
    b, n = 4, 16
    states = []
    params = []
    for _ in range(b):
        x = np.sort(rng.uniform(0, 900, n)).astype(np.float32)
        v = rng.uniform(0, 30, n).astype(np.float32)
        lane = rng.integers(0, 3, n).astype(np.float32)
        act = (rng.uniform(size=n) > 0.3).astype(np.float32)
        states.append(jnp.stack([jnp.asarray(x), jnp.asarray(v), jnp.asarray(lane), jnp.asarray(act)], axis=1))
        params.append(jnp.tile(jnp.array([[30.0, 1.5, 1.5, 2.0, 2.0, 4.5, 0.0, 0.0]], jnp.float32), (n, 1)))
    bs = jnp.stack(states)
    bp = jnp.stack(params)
    batched = jax.vmap(model.step)(bs, bp)
    for i in range(b):
        single = model.step(states[i], params[i])
        for got, want in zip(batched, single):
            np.testing.assert_allclose(np.asarray(got[i]), np.asarray(want), rtol=1e-5, atol=1e-5)


@pytest.mark.skipif(not (ART / "manifest.json").exists(), reason="run `make artifacts` first")
def test_manifest_buckets_cover_entries():
    manifest = json.loads((ART / "manifest.json").read_text())
    ns = {e["n"] for e in manifest["entries"].values()}
    assert ns == set(manifest["buckets"])


def test_lower_rollout_shapes():
    """The fused rollout returns (final_state, obs_trace) only — the
    per-step accel/radar are dropped so XLA can DCE the radar scan out
    of the loop body."""
    k, n = 8, 16
    text = aot.lower_rollout(n, k)
    assert "HloModule" in text
    assert f"f32[{n},4]" in text
    assert f"f32[{n},8]" in text
    assert f"f32[{aot.GEOM}]" in text
    # the stacked per-step observables
    assert f"f32[{k},{len(model.OBS_COLUMNS)}]" in text
    assert "custom-call" not in text.lower()


def test_lower_rollout_batched_shapes():
    k, n, b = 8, 16, aot.BATCH
    text = aot.lower_rollout_batched(b, n, k)
    assert f"f32[{b},{n},4]" in text
    assert f"f32[{b},{aot.GEOM}]" in text
    assert f"f32[{b},{k},{len(model.OBS_COLUMNS)}]" in text
    assert "custom-call" not in text.lower()


def test_lower_run_shapes():
    """The whole-run entry carries the departure table operand and
    returns (final_state, final_params, obs_trace, inserted mask)."""
    t, n, d = 200, 16, aot.DEPARTURE_ROWS
    text = aot.lower_run(n, t)
    assert "HloModule" in text
    assert f"f32[{n},4]" in text
    assert f"f32[{n},8]" in text
    assert f"f32[{aot.GEOM}]" in text
    # the departure table operand and its insertion mask output
    assert f"f32[{d},{len(model.DEP_COLUMNS)}]" in text
    assert f"f32[{d}]" in text
    # the stacked whole-run observables
    assert f"f32[{t},{len(model.OBS_COLUMNS)}]" in text
    assert "custom-call" not in text.lower()


def test_lower_run_batched_shapes():
    t, n, b, d = 200, 16, aot.BATCH, aot.DEPARTURE_ROWS
    text = aot.lower_run_batched(b, n, t)
    assert f"f32[{b},{n},4]" in text
    assert f"f32[{b},{aot.GEOM}]" in text
    assert f"f32[{b},{d},{len(model.DEP_COLUMNS)}]" in text
    assert f"f32[{b},{t},{len(model.OBS_COLUMNS)}]" in text
    assert "custom-call" not in text.lower()


def test_batched_rollout_matches_vmap_of_single():
    """vmap semantics over the fused rollout: each lane's chunk equals
    its own solo rollout (what lets the micro-batcher coalesce same-K
    chunks without contaminating worlds).  Same tolerance discipline as
    `test_batched_step_matches_vmap_of_single`: the batched lowering may
    fuse differently from the solo one, so this is allclose, not
    bit-equal — bit-exactness is claimed fused-vs-sequential (see
    test_model.py), not batched-vs-solo."""
    import jax
    import jax.numpy as jnp
    import numpy as np

    rng = np.random.default_rng(17)
    b, n, k = 4, 16, 8
    states, params = [], []
    for _ in range(b):
        x = np.sort(rng.uniform(0, 900, n)).astype(np.float32)
        v = rng.uniform(0, 30, n).astype(np.float32)
        lane = rng.integers(0, 3, n).astype(np.float32)
        act = (rng.uniform(size=n) > 0.3).astype(np.float32)
        states.append(jnp.stack([jnp.asarray(x), jnp.asarray(v), jnp.asarray(lane), jnp.asarray(act)], axis=1))
        params.append(jnp.tile(jnp.array([[30.0, 1.5, 1.5, 2.0, 2.0, 4.5, 0.0, 0.0]], jnp.float32), (n, 1)))
    bs = jnp.stack(states)
    bp = jnp.stack(params)
    bg = jnp.stack([model.default_geometry()] * b)
    # compare the lowered executables (what PJRT dispatches), not the
    # eager op-by-op path — same discipline as the rust coalescing tests
    batched = jax.jit(jax.vmap(lambda s, p, g: model.rollout_geom(s, p, g, k)))
    solo = jax.jit(lambda s, p, g: model.rollout_geom(s, p, g, k))
    fin_b, trace_b = batched(bs, bp, bg)
    for i in range(b):
        fin, trace = solo(states[i], params[i], model.default_geometry())
        np.testing.assert_allclose(
            np.asarray(fin_b[i]), np.asarray(fin), rtol=1e-5, atol=1e-5
        )
        np.testing.assert_allclose(
            np.asarray(trace_b[i]), np.asarray(trace), rtol=1e-5, atol=1e-5
        )
