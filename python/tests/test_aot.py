"""AOT path: HLO-text artifacts are well-formed and shape-consistent."""

from __future__ import annotations

import json
import pathlib

import pytest

from compile import aot, model

ART = pathlib.Path(__file__).resolve().parents[2] / "artifacts"


def test_lower_step_contains_bucket_shape():
    text = aot.lower_step(16)
    assert "HloModule" in text
    assert "f32[16,4]" in text
    # schema 3: the widened destination-aware params row
    assert "f32[16,8]" in text
    # the geometry operand (schema 2): scenario constants arrive at
    # runtime instead of being baked in
    assert f"f32[{aot.GEOM}]" in text


def test_lower_idm_single_output_tuple():
    text = aot.lower_idm(16)
    # return_tuple=True → ROOT is a tuple even for one output
    assert "f32[16]" in text
    assert "HloModule" in text


def test_lower_radar_output_shape():
    text = aot.lower_radar(16)
    assert "f32[16,2]" in text


def test_step_is_pure_hlo_no_custom_calls():
    """interpret=True must lower pallas to plain HLO — a custom-call here
    would be unloadable by the rust CPU PJRT client."""
    text = aot.lower_step(16)
    assert "custom-call" not in text.lower()


@pytest.mark.skipif(not (ART / "manifest.json").exists(), reason="run `make artifacts` first")
def test_manifest_consistent_with_artifacts():
    manifest = json.loads((ART / "manifest.json").read_text())
    assert manifest["format"] == "hlo-text"
    assert manifest["schema"] == 2
    assert manifest["geometry_columns"] == model.GEOM_COLUMNS
    assert manifest["dt"] == model.DT
    assert manifest["merge_end"] == model.MERGE_END
    for key, entry in manifest["entries"].items():
        path = ART / entry["file"]
        assert path.exists(), f"missing artifact {path}"
        head = path.read_text()[:200]
        assert "HloModule" in head
        name, n = key.rsplit("_", 1)
        assert entry["n"] == int(n)


def test_lower_step_batched_shapes():
    text = aot.lower_step_batched(aot.BATCH, 16)
    assert f"f32[{aot.BATCH},16,4]" in text
    assert f"f32[{aot.BATCH},16,8]" in text
    # per-lane geometry rows: mixed-family batches coalesce
    assert f"f32[{aot.BATCH},{aot.GEOM}]" in text
    assert "custom-call" not in text.lower()


def test_batched_step_matches_vmap_of_single():
    """vmap semantics: batched step == per-world single steps."""
    import jax
    import jax.numpy as jnp
    import numpy as np

    from compile import model

    rng = np.random.default_rng(5)
    b, n = 4, 16
    states = []
    params = []
    for _ in range(b):
        x = np.sort(rng.uniform(0, 900, n)).astype(np.float32)
        v = rng.uniform(0, 30, n).astype(np.float32)
        lane = rng.integers(0, 3, n).astype(np.float32)
        act = (rng.uniform(size=n) > 0.3).astype(np.float32)
        states.append(jnp.stack([jnp.asarray(x), jnp.asarray(v), jnp.asarray(lane), jnp.asarray(act)], axis=1))
        params.append(jnp.tile(jnp.array([[30.0, 1.5, 1.5, 2.0, 2.0, 4.5, 0.0, 0.0]], jnp.float32), (n, 1)))
    bs = jnp.stack(states)
    bp = jnp.stack(params)
    batched = jax.vmap(model.step)(bs, bp)
    for i in range(b):
        single = model.step(states[i], params[i])
        for got, want in zip(batched, single):
            np.testing.assert_allclose(np.asarray(got[i]), np.asarray(want), rtol=1e-5, atol=1e-5)


@pytest.mark.skipif(not (ART / "manifest.json").exists(), reason="run `make artifacts` first")
def test_manifest_buckets_cover_entries():
    manifest = json.loads((ART / "manifest.json").read_text())
    ns = {e["n"] for e in manifest["entries"].values()}
    assert ns == set(manifest["buckets"])
