"""L2 correctness: shapes and physical invariants of the merge-sim step."""

from __future__ import annotations

import jax.numpy as jnp
import numpy as np
import pytest

from compile import model
from tests.test_kernel import given, make_state, settings, st


def run_steps(state, params, k):
    for _ in range(k):
        state, accel, radar, obs = model.step(state, params)
    return state, accel, radar, obs


def test_step_shapes():
    rng = np.random.default_rng(1)
    state, params = make_state(rng, 64)
    ns, accel, radar, obs = model.step(state, params)
    assert ns.shape == (64, 4)
    assert accel.shape == (64,)
    assert radar.shape == (64, 2)
    assert obs.shape == (5,)


@settings(max_examples=25, deadline=None)
@given(seed=st.integers(0, 2**31 - 1), n=st.integers(2, 64))
def test_speeds_never_negative(seed, n):
    rng = np.random.default_rng(seed)
    state, params = make_state(rng, n)
    ns, *_ = run_steps(state, params, 5)
    assert np.all(np.asarray(ns[:, 1]) >= 0.0)


@settings(max_examples=25, deadline=None)
@given(seed=st.integers(0, 2**31 - 1), n=st.integers(2, 64))
def test_inactive_rows_frozen(seed, n):
    """Inactive slots must not move — the rust coordinator reuses them
    as spawn slots and depends on their state being stable."""
    rng = np.random.default_rng(seed)
    state, params = make_state(rng, n, p_active=0.5)
    inactive = np.asarray(state[:, 3]) < 0.5
    ns, *_ = model.step(state, params)
    np.testing.assert_array_equal(
        np.asarray(ns[inactive, 0]), np.asarray(state[inactive, 0])
    )
    assert np.all(np.asarray(ns[inactive, 1]) == 0.0)


@settings(max_examples=25, deadline=None)
@given(seed=st.integers(0, 2**31 - 1))
def test_active_count_never_increases(seed):
    """The model only retires vehicles (at ROAD_END); spawning is the
    coordinator's job."""
    rng = np.random.default_rng(seed)
    state, params = make_state(rng, 48)
    n0 = float(jnp.sum(state[:, 3]))
    ns, *_ = run_steps(state, params, 10)
    assert float(jnp.sum(ns[:, 3])) <= n0 + 1e-6


def test_vehicle_retires_past_road_end():
    state = jnp.array([[model.ROAD_END - 0.5, 30.0, 1.0, 1.0]], dtype=jnp.float32)
    params = jnp.array([[30.0, 1.5, 1.5, 2.0, 2.0, 4.5, 0.0, 0.0]], dtype=jnp.float32)
    ns, _, _, obs = model.step(state, params)
    assert float(ns[0, 3]) == 0.0
    assert float(obs[2]) == 1.0  # flow counter ticked


def test_ramp_vehicle_stops_at_wall():
    """A ramp vehicle that cannot merge must stop before MERGE_END.

    Both mainline lanes are jammed bumper-to-bumper (gap < s0) through the
    whole merge zone, so the MOBIL safety criterion never admits the
    merge; the phantom-wall IDM term must bring the ramp vehicle to a
    stop at the end of the acceleration lane.
    """
    jam_x = np.linspace(model.MERGE_START - 30, model.MERGE_END + 30, 52).astype(np.float32)
    rows = [[model.MERGE_START - 40.0, 25.0, 0.0, 1.0]]  # the ramp vehicle
    rows += [[x, 0.0, 1.0, 1.0] for x in jam_x]
    rows += [[x, 0.0, 2.0, 1.0] for x in jam_x]
    n = len(rows)
    state = jnp.array(rows, dtype=jnp.float32)
    params = jnp.tile(jnp.array([[30.0, 1.5, 1.5, 2.0, 2.0, 4.5, 0.0, 0.0]], jnp.float32), (n, 1))
    for _ in range(400):
        state, *_ = model.step(state, params)
    assert float(state[0, 2]) == 0.0, "merge into a solid jam should be unsafe"
    assert float(state[0, 0]) <= model.MERGE_END + 1.0
    assert float(state[0, 1]) < 2.0  # effectively stopped at the wall


def test_ramp_vehicle_merges_into_empty_mainline():
    state = jnp.array(
        [[model.MERGE_START + 10.0, 20.0, 0.0, 1.0]], dtype=jnp.float32
    )
    params = jnp.array([[30.0, 1.5, 1.5, 2.0, 2.0, 4.5, 0.0, 0.0]], dtype=jnp.float32)
    ns, _, _, obs = model.step(state, params)
    assert float(ns[0, 2]) == 1.0  # merged on the first safe opportunity
    assert float(obs[3]) == 1.0    # n_merged observable


def test_merge_blocked_when_unsafe():
    """Mainline vehicle right alongside → merge must not happen."""
    state = jnp.array(
        [
            [model.MERGE_START + 10.0, 20.0, 0.0, 1.0],
            [model.MERGE_START + 10.5, 20.0, 1.0, 1.0],
        ],
        dtype=jnp.float32,
    )
    params = jnp.tile(jnp.array([[30.0, 1.5, 1.5, 2.0, 2.0, 4.5, 0.0, 0.0]], jnp.float32), (2, 1))
    ns, *_ = model.step(state, params)
    assert float(ns[0, 2]) == 0.0


def test_obs_active_count():
    rng = np.random.default_rng(3)
    state, params = make_state(rng, 32, p_active=0.6)
    _, _, _, obs = model.step(state, params)
    assert float(obs[0]) == pytest.approx(float(jnp.sum(state[:, 3])))


def test_lane_stays_in_range():
    rng = np.random.default_rng(11)
    state, params = make_state(rng, 48)
    ns, *_ = run_steps(state, params, 20)
    lanes = np.asarray(ns[:, 2])
    assert lanes.min() >= 0.0
    assert lanes.max() <= model.NUM_MAIN_LANES


# ------------------------------------------------------- exit dynamics ----


def exit_params(exit_pos, flag=1.0):
    return jnp.array(
        [[30.0, 1.5, 1.5, 2.0, 2.0, 4.5, exit_pos, flag]], dtype=jnp.float32
    )


def test_exit_flagged_vehicle_retires_at_exit_pos():
    """A flagged vehicle on lane 1 retires crossing its own exit_pos —
    well short of ROAD_END — and ticks n_exited, not flow."""
    state = jnp.array([[499.5, 30.0, 1.0, 1.0]], dtype=jnp.float32)
    ns, _, _, obs = model.step(state, exit_params(500.0))
    assert float(ns[0, 3]) == 0.0
    assert float(obs[2]) == 0.0  # flow did NOT tick
    assert float(obs[4]) == 1.0  # n_exited did


def test_unflagged_vehicle_ignores_exit_pos():
    state = jnp.array([[499.5, 30.0, 1.0, 1.0]], dtype=jnp.float32)
    ns, _, _, obs = model.step(state, exit_params(500.0, flag=0.0))
    assert float(ns[0, 3]) == 1.0
    assert float(obs[4]) == 0.0


def test_exit_requires_gore_lane():
    """Crossing exit_pos while pinned on lane 2 (a blocker alongside on
    lane 1 makes the down-change unsafe) is a missed exit: the vehicle
    stays active and will retire at ROAD_END like through traffic."""
    state = jnp.array(
        [[499.5, 30.0, 2.0, 1.0], [499.3, 30.0, 1.0, 1.0]], dtype=jnp.float32
    )
    params = jnp.concatenate([exit_params(500.0), exit_params(0.0, flag=0.0)])
    ns, _, _, obs = model.step(state, params)
    assert float(ns[0, 2]) == 2.0  # pinned: no lane change
    assert float(ns[0, 3]) == 1.0
    assert float(obs[4]) == 0.0


def test_exit_intent_biases_toward_lane_1():
    """A flagged vehicle on lane 2 changes down to lane 1 with NO
    discretionary gain (empty road: gain is ~0, below the threshold) —
    the mandatory exit bias at work; unflagged stays put."""
    state = jnp.array([[100.0, 25.0, 2.0, 1.0]], dtype=jnp.float32)
    ns, *_ = model.step(state, exit_params(900.0))
    assert float(ns[0, 2]) == 1.0
    ns, *_ = model.step(state, exit_params(900.0, flag=0.0))
    assert float(ns[0, 2]) == 2.0


def test_exit_flagged_never_changes_up():
    """Even stuck behind a crawler, a flagged vehicle must not overtake
    away from its exit (the unflagged control does)."""
    state = jnp.array(
        [[100.0, 25.0, 1.0, 1.0], [112.0, 2.0, 1.0, 1.0]], dtype=jnp.float32
    )
    params = jnp.concatenate([exit_params(900.0), exit_params(0.0, flag=0.0)])
    ns, *_ = model.step(state, params)
    assert float(ns[0, 2]) == 1.0
    params = jnp.concatenate(
        [exit_params(0.0, flag=0.0), exit_params(0.0, flag=0.0)]
    )
    ns, *_ = model.step(state, params)
    assert float(ns[0, 2]) == 2.0


# ------------------------------------------------------ fused rollouts ----


def test_rollout_matches_sequential_steps():
    """The tentpole ABI guarantee: a fused K-step rollout is BIT-EXACT
    with K sequential step_geom calls — final state equal, and the obs
    trace row i equal to sequential step i's obs.  Exit-flagged traffic
    is included so exit retirement (and n_exited) is exercised *inside*
    the scan carry, mid-chunk.

    Both sides are jit-compiled: the guarantee is about the lowered
    executables the rust runtime dispatches (the eager op-by-op path
    rounds differently and is not part of the ABI)."""
    import jax

    rng = np.random.default_rng(2024)
    n, k = 48, 32
    state, params = make_state(rng, n)
    # flag a third of the fleet, each for a gore a few car-lengths ahead
    # of its own spawn position, so exits land mid-chunk at varying steps
    # rather than at chunk boundaries
    params = np.asarray(params).copy()
    flagged = rng.uniform(size=n) < 0.35
    gore = np.asarray(state[:, 0]) + rng.uniform(5.0, 60.0, n).astype(np.float32)
    params[:, 6] = np.where(flagged, gore, 0.0)
    params[:, 7] = flagged.astype(np.float32)
    params = jnp.asarray(params)
    geom = model.default_geometry()

    step_jit = jax.jit(model.step_geom)
    roll_jit = jax.jit(model.rollout_geom, static_argnums=3)
    seq_state = state
    seq_obs = []
    for _ in range(k):
        seq_state, _, _, obs = step_jit(seq_state, params, geom)
        seq_obs.append(np.asarray(obs))
    fin, trace = roll_jit(state, params, geom, k)
    np.testing.assert_array_equal(np.asarray(fin), np.asarray(seq_state))
    np.testing.assert_array_equal(np.asarray(trace), np.stack(seq_obs))
    # several exits really happened mid-chunk (the interesting case)
    exits_per_step = np.stack(seq_obs)[:, 4]
    assert float(exits_per_step.sum()) >= 3.0, "too few exits mid-chunk"
    assert float(exits_per_step[1:-1].sum()) > 0.0, "exits only at chunk edges"


def test_rollout_k1_matches_single_step():
    """K=1 (the ladder's degenerate rung) is exactly one step."""
    import jax

    rng = np.random.default_rng(7)
    state, params = make_state(rng, 16)
    geom = model.default_geometry()
    ns, _, _, obs = jax.jit(model.step_geom)(state, params, geom)
    fin, trace = jax.jit(model.rollout_geom, static_argnums=3)(state, params, geom, 1)
    assert trace.shape == (1, len(model.OBS_COLUMNS))
    np.testing.assert_array_equal(np.asarray(fin), np.asarray(ns))
    np.testing.assert_array_equal(np.asarray(trace[0]), np.asarray(obs))


def test_rollout_obs_trace_shape_and_totals():
    """Per-step observables survive fusion: the trace has one row per
    step and its flow/exit columns sum to the sequential totals."""
    rng = np.random.default_rng(5)
    n, k = 32, 8
    state, params = make_state(rng, n)
    geom = model.default_geometry()
    fin, trace = model.rollout_geom(state, params, geom, k)
    assert trace.shape == (k, len(model.OBS_COLUMNS))
    retired = float(jnp.sum(state[:, 3])) - float(jnp.sum(fin[:, 3]))
    trace = np.asarray(trace)
    assert float(trace[:, 2].sum() + trace[:, 4].sum()) == pytest.approx(retired)


def test_exit_flagged_ramp_vehicle_sees_no_wall():
    """The phantom wall at MERGE_END must not stop a lane-0 vehicle whose
    road continues through the gore (exit_flag set)."""
    state = jnp.array([[model.MERGE_END - 10.0, 20.0, 0.0, 1.0]], dtype=jnp.float32)
    # jam lane 1 through the zone so it cannot merge away
    jam = jnp.array(
        [[x, 0.0, 1.0, 1.0] for x in np.linspace(440.0, 520.0, 20)],
        dtype=jnp.float32,
    )
    state = jnp.concatenate([state, jam])
    flagged = jnp.concatenate(
        [exit_params(model.MERGE_END)] + [exit_params(0.0, flag=0.0)] * 20
    )
    plain = jnp.concatenate([exit_params(0.0, flag=0.0)] * 21)
    _, accel_flagged, _, _ = model.step(state, flagged)
    _, accel_plain, _, _ = model.step(state, plain)
    assert float(accel_plain[0]) < -1.0  # wall brakes the unflagged vehicle
    assert float(accel_flagged[0]) > float(accel_plain[0]) + 1.0
