"""L2 correctness: shapes and physical invariants of the merge-sim step."""

from __future__ import annotations

import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings
from hypothesis import strategies as st

from compile import model
from tests.test_kernel import make_state


def run_steps(state, params, k):
    for _ in range(k):
        state, accel, radar, obs = model.step(state, params)
    return state, accel, radar, obs


def test_step_shapes():
    rng = np.random.default_rng(1)
    state, params = make_state(rng, 64)
    ns, accel, radar, obs = model.step(state, params)
    assert ns.shape == (64, 4)
    assert accel.shape == (64,)
    assert radar.shape == (64, 2)
    assert obs.shape == (4,)


@settings(max_examples=25, deadline=None)
@given(seed=st.integers(0, 2**31 - 1), n=st.integers(2, 64))
def test_speeds_never_negative(seed, n):
    rng = np.random.default_rng(seed)
    state, params = make_state(rng, n)
    ns, *_ = run_steps(state, params, 5)
    assert np.all(np.asarray(ns[:, 1]) >= 0.0)


@settings(max_examples=25, deadline=None)
@given(seed=st.integers(0, 2**31 - 1), n=st.integers(2, 64))
def test_inactive_rows_frozen(seed, n):
    """Inactive slots must not move — the rust coordinator reuses them
    as spawn slots and depends on their state being stable."""
    rng = np.random.default_rng(seed)
    state, params = make_state(rng, n, p_active=0.5)
    inactive = np.asarray(state[:, 3]) < 0.5
    ns, *_ = model.step(state, params)
    np.testing.assert_array_equal(
        np.asarray(ns[inactive, 0]), np.asarray(state[inactive, 0])
    )
    assert np.all(np.asarray(ns[inactive, 1]) == 0.0)


@settings(max_examples=25, deadline=None)
@given(seed=st.integers(0, 2**31 - 1))
def test_active_count_never_increases(seed):
    """The model only retires vehicles (at ROAD_END); spawning is the
    coordinator's job."""
    rng = np.random.default_rng(seed)
    state, params = make_state(rng, 48)
    n0 = float(jnp.sum(state[:, 3]))
    ns, *_ = run_steps(state, params, 10)
    assert float(jnp.sum(ns[:, 3])) <= n0 + 1e-6


def test_vehicle_retires_past_road_end():
    state = jnp.array([[model.ROAD_END - 0.5, 30.0, 1.0, 1.0]], dtype=jnp.float32)
    params = jnp.array([[30.0, 1.5, 1.5, 2.0, 2.0, 4.5]], dtype=jnp.float32)
    ns, _, _, obs = model.step(state, params)
    assert float(ns[0, 3]) == 0.0
    assert float(obs[2]) == 1.0  # flow counter ticked


def test_ramp_vehicle_stops_at_wall():
    """A ramp vehicle that cannot merge must stop before MERGE_END.

    Both mainline lanes are jammed bumper-to-bumper (gap < s0) through the
    whole merge zone, so the MOBIL safety criterion never admits the
    merge; the phantom-wall IDM term must bring the ramp vehicle to a
    stop at the end of the acceleration lane.
    """
    jam_x = np.linspace(model.MERGE_START - 30, model.MERGE_END + 30, 52).astype(np.float32)
    rows = [[model.MERGE_START - 40.0, 25.0, 0.0, 1.0]]  # the ramp vehicle
    rows += [[x, 0.0, 1.0, 1.0] for x in jam_x]
    rows += [[x, 0.0, 2.0, 1.0] for x in jam_x]
    n = len(rows)
    state = jnp.array(rows, dtype=jnp.float32)
    params = jnp.tile(jnp.array([[30.0, 1.5, 1.5, 2.0, 2.0, 4.5]], jnp.float32), (n, 1))
    for _ in range(400):
        state, *_ = model.step(state, params)
    assert float(state[0, 2]) == 0.0, "merge into a solid jam should be unsafe"
    assert float(state[0, 0]) <= model.MERGE_END + 1.0
    assert float(state[0, 1]) < 2.0  # effectively stopped at the wall


def test_ramp_vehicle_merges_into_empty_mainline():
    state = jnp.array(
        [[model.MERGE_START + 10.0, 20.0, 0.0, 1.0]], dtype=jnp.float32
    )
    params = jnp.array([[30.0, 1.5, 1.5, 2.0, 2.0, 4.5]], dtype=jnp.float32)
    ns, _, _, obs = model.step(state, params)
    assert float(ns[0, 2]) == 1.0  # merged on the first safe opportunity
    assert float(obs[3]) == 1.0    # n_merged observable


def test_merge_blocked_when_unsafe():
    """Mainline vehicle right alongside → merge must not happen."""
    state = jnp.array(
        [
            [model.MERGE_START + 10.0, 20.0, 0.0, 1.0],
            [model.MERGE_START + 10.5, 20.0, 1.0, 1.0],
        ],
        dtype=jnp.float32,
    )
    params = jnp.tile(jnp.array([[30.0, 1.5, 1.5, 2.0, 2.0, 4.5]], jnp.float32), (2, 1))
    ns, *_ = model.step(state, params)
    assert float(ns[0, 2]) == 0.0


def test_obs_active_count():
    rng = np.random.default_rng(3)
    state, params = make_state(rng, 32, p_active=0.6)
    _, _, _, obs = model.step(state, params)
    assert float(obs[0]) == pytest.approx(float(jnp.sum(state[:, 3])))


def test_lane_stays_in_range():
    rng = np.random.default_rng(11)
    state, params = make_state(rng, 48)
    ns, *_ = run_steps(state, params, 20)
    lanes = np.asarray(ns[:, 2])
    assert lanes.min() >= 0.0
    assert lanes.max() <= model.NUM_MAIN_LANES
