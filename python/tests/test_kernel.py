"""L1 correctness: Pallas kernels vs the pure-jnp oracle (ref.py).

The CORE correctness signal of the compile path: hypothesis sweeps the
state space (vehicle counts, lane layouts, activity masks, parameter
ranges) and asserts the blocked Pallas kernels reproduce the oracle at
f32 tolerance.
"""

from __future__ import annotations

import jax.numpy as jnp
import numpy as np
import pytest

try:
    from hypothesis import given, settings
    from hypothesis import strategies as st
except ImportError:  # slim containers: keep the example-based tests runnable
    def settings(**_kw):
        return lambda f: f

    def given(**_kw):
        return lambda f: pytest.mark.skip(reason="hypothesis not installed")(f)

    class st:  # noqa: N801 — stands in for hypothesis.strategies
        @staticmethod
        def integers(*_a, **_k):
            return None

from compile.kernels import ref
from compile.kernels.idm_pairwise import idm_accel
from compile.kernels.radar import radar_scan

# magnitudes in play reach ~1e5 (bumper-to-bumper IDM decel), so compare
# with a relative tolerance; 1e-4 is ~500 ulp at f32 — roomy but real.
RTOL = 1e-4
ATOL = 1e-4


def make_state(rng: np.random.Generator, n: int, lanes: int = 3, p_active: float = 0.8):
    # positions spaced >= 1e-3 apart so the `dx > 1e-6` ahead-test is stable
    x = np.sort(rng.uniform(0.0, 950.0, n)).astype(np.float32)
    x += np.arange(n, dtype=np.float32) * 1e-2
    v = rng.uniform(0.0, 35.0, n).astype(np.float32)
    lane = rng.integers(0, lanes, n).astype(np.float32)
    act = (rng.uniform(size=n) < p_active).astype(np.float32)
    state = jnp.stack([jnp.asarray(x), jnp.asarray(v), jnp.asarray(lane), jnp.asarray(act)], axis=1)
    params = jnp.stack(
        [
            jnp.asarray(rng.uniform(15.0, 40.0, n).astype(np.float32)),  # v0
            jnp.asarray(rng.uniform(0.8, 2.5, n).astype(np.float32)),    # T
            jnp.asarray(rng.uniform(0.8, 3.0, n).astype(np.float32)),    # a_max
            jnp.asarray(rng.uniform(1.0, 4.0, n).astype(np.float32)),    # b
            jnp.asarray(rng.uniform(1.0, 4.0, n).astype(np.float32)),    # s0
            jnp.asarray(rng.uniform(3.5, 12.0, n).astype(np.float32)),   # length
            jnp.asarray(np.zeros(n, dtype=np.float32)),                  # exit_pos
            jnp.asarray(np.zeros(n, dtype=np.float32)),                  # exit_flag
        ],
        axis=1,
    )
    return state, params


# ---------------------------------------------------------------- IDM ----


@settings(max_examples=40, deadline=None)
@given(seed=st.integers(0, 2**31 - 1), n=st.integers(1, 96), lanes=st.integers(1, 4))
def test_idm_matches_ref_hypothesis(seed, n, lanes):
    rng = np.random.default_rng(seed)
    state, params = make_state(rng, n, lanes=lanes)
    np.testing.assert_allclose(
        np.asarray(idm_accel(state, params)),
        np.asarray(ref.idm_accel_ref(state, params)),
        rtol=RTOL,
        atol=ATOL,
    )


@pytest.mark.parametrize("n", [16, 64, 128, 256, 384])
def test_idm_matches_ref_buckets(n):
    """Every AOT bucket size, including the multi-grid-step 256/384 cases."""
    rng = np.random.default_rng(n)
    state, params = make_state(rng, n)
    np.testing.assert_allclose(
        np.asarray(idm_accel(state, params)),
        np.asarray(ref.idm_accel_ref(state, params)),
        rtol=RTOL,
        atol=ATOL,
    )


def test_idm_single_vehicle_free_road():
    """A lone vehicle accelerates by the free-road term only."""
    state = jnp.array([[100.0, 20.0, 1.0, 1.0]], dtype=jnp.float32)
    params = jnp.array([[30.0, 1.5, 1.5, 2.0, 2.0, 4.5]], dtype=jnp.float32)
    a = float(idm_accel(state, params)[0])
    expect = 1.5 * (1.0 - (20.0 / 30.0) ** 4)
    assert a == pytest.approx(expect, rel=1e-5)


def test_idm_all_inactive_is_zero():
    rng = np.random.default_rng(7)
    state, params = make_state(rng, 32, p_active=0.0)
    assert np.all(np.asarray(idm_accel(state, params)) == 0.0)


def test_idm_inactive_leader_ignored():
    """An inactive vehicle directly ahead must not slow the follower."""
    state = jnp.array(
        [[100.0, 20.0, 1.0, 1.0], [110.0, 0.0, 1.0, 0.0]], dtype=jnp.float32
    )
    params = jnp.tile(jnp.array([[30.0, 1.5, 1.5, 2.0, 2.0, 4.5]], jnp.float32), (2, 1))
    a = float(idm_accel(state, params)[0])
    expect = 1.5 * (1.0 - (20.0 / 30.0) ** 4)
    assert a == pytest.approx(expect, rel=1e-5)


def test_idm_bumper_to_bumper_brakes_hard():
    """Tailgating a stopped leader at < s0 must produce strong braking."""
    state = jnp.array(
        [[100.0, 30.0, 1.0, 1.0], [106.0, 0.0, 1.0, 1.0]], dtype=jnp.float32
    )
    params = jnp.tile(jnp.array([[30.0, 1.5, 1.5, 2.0, 2.0, 4.5]], jnp.float32), (2, 1))
    a = float(idm_accel(state, params)[0])
    assert a < -10.0


def test_idm_other_lane_ignored():
    """A stopped vehicle in another lane must not affect the ego."""
    state = jnp.array(
        [[100.0, 20.0, 1.0, 1.0], [105.0, 0.0, 2.0, 1.0]], dtype=jnp.float32
    )
    params = jnp.tile(jnp.array([[30.0, 1.5, 1.5, 2.0, 2.0, 4.5]], jnp.float32), (2, 1))
    a = float(idm_accel(state, params)[0])
    expect = 1.5 * (1.0 - (20.0 / 30.0) ** 4)
    assert a == pytest.approx(expect, rel=1e-5)


def test_idm_rejects_non_divisible_block():
    state = jnp.zeros((100, 4), jnp.float32)
    params = jnp.zeros((100, 6), jnp.float32)
    with pytest.raises(ValueError, match="multiple of block"):
        idm_accel(state, params, block=64)


# -------------------------------------------------------------- radar ----


@settings(max_examples=40, deadline=None)
@given(seed=st.integers(0, 2**31 - 1), n=st.integers(1, 96))
def test_radar_matches_ref_hypothesis(seed, n):
    rng = np.random.default_rng(seed)
    state, _ = make_state(rng, n)
    np.testing.assert_allclose(
        np.asarray(radar_scan(state)),
        np.asarray(ref.radar_ref(state)),
        rtol=RTOL,
        atol=ATOL,
    )


@pytest.mark.parametrize("n", [16, 64, 256])
def test_radar_matches_ref_buckets(n):
    rng = np.random.default_rng(n + 1)
    state, _ = make_state(rng, n)
    np.testing.assert_allclose(
        np.asarray(radar_scan(state)),
        np.asarray(ref.radar_ref(state)),
        rtol=RTOL,
        atol=ATOL,
    )


def test_radar_no_target_reports_clear():
    state = jnp.array([[0.0, 25.0, 1.0, 1.0]], dtype=jnp.float32)
    out = np.asarray(radar_scan(state))
    assert out[0, 0] == pytest.approx(ref.RADAR_RANGE)
    assert out[0, 1] == 0.0


def test_radar_sees_across_lanes():
    """Radar (unlike the IDM leader scan) sees targets in any lane."""
    state = jnp.array(
        [[100.0, 30.0, 1.0, 1.0], [140.0, 10.0, 2.0, 1.0]], dtype=jnp.float32
    )
    out = np.asarray(radar_scan(state))
    assert out[0, 0] == pytest.approx(40.0)
    assert out[0, 1] == pytest.approx(20.0)  # closing at 30-10


def test_radar_out_of_range_ignored():
    state = jnp.array(
        [[0.0, 30.0, 1.0, 1.0], [500.0, 10.0, 1.0, 1.0]], dtype=jnp.float32
    )
    out = np.asarray(radar_scan(state))
    assert out[0, 0] == pytest.approx(ref.RADAR_RANGE)
    assert out[0, 1] == 0.0
