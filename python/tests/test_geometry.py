"""Geometry-operand semantics: ``model.step_geom`` must honour the
runtime geometry vector exactly where the old constant-geometry ``step``
honoured the module constants.

These tests are hypothesis-free on purpose: they are the pre-flight
oracle for the rust-side scenario-family agreement tests
(`rust/tests/scenario_families.rs`) and run on containers without the
full property-testing stack.
"""

from __future__ import annotations

import jax.numpy as jnp
import numpy as np

from compile import model


def make_state(rng: np.random.Generator, n: int, lanes: int = 3, p_active: float = 0.8):
    """Random-but-plausible traffic (the test_kernel generator, inlined
    so this file stays importable without hypothesis)."""
    x = np.sort(rng.uniform(0.0, 950.0, n)).astype(np.float32)
    x += np.arange(n, dtype=np.float32) * 1e-2
    v = rng.uniform(0.0, 32.0, n).astype(np.float32)
    lane = rng.integers(0, lanes, n).astype(np.float32)
    act = (rng.uniform(size=n) < p_active).astype(np.float32)
    state = jnp.stack(
        [jnp.asarray(x), jnp.asarray(v), jnp.asarray(lane), jnp.asarray(act)], axis=1
    )
    params = jnp.stack(
        [
            jnp.asarray(rng.uniform(20.0, 38.0, n).astype(np.float32)),
            jnp.asarray(rng.uniform(0.9, 2.2, n).astype(np.float32)),
            jnp.asarray(rng.uniform(1.0, 2.5, n).astype(np.float32)),
            jnp.asarray(rng.uniform(1.5, 3.5, n).astype(np.float32)),
            jnp.asarray(rng.uniform(1.5, 3.0, n).astype(np.float32)),
            jnp.asarray(rng.uniform(4.0, 9.0, n).astype(np.float32)),
            jnp.asarray(np.zeros(n, dtype=np.float32)),  # exit_pos
            jnp.asarray(np.zeros(n, dtype=np.float32)),  # exit_flag
        ],
        axis=1,
    )
    return state, params


def geom(road_end, merge_start, merge_end, lanes, dt):
    return jnp.array(
        [road_end, merge_start, merge_end, float(lanes), dt], dtype=jnp.float32
    )


def test_default_geometry_matches_step_wrapper():
    """step() is a thin wrapper: bit-identical to step_geom(default)."""
    rng = np.random.default_rng(7)
    state, params = make_state(rng, 48)
    a = model.step(state, params)
    b = model.step_geom(state, params, model.default_geometry())
    for got, want in zip(a, b):
        np.testing.assert_array_equal(np.asarray(got), np.asarray(want))


def test_retirement_follows_operand_road_end():
    """A vehicle short of the default ROAD_END retires when the operand
    road_end is pulled in front of it (the lane-drop/ring case)."""
    state = jnp.array([[390.0, 30.0, 1.0, 1.0]], dtype=jnp.float32)
    params = jnp.array([[30.0, 1.5, 1.5, 2.0, 2.0, 4.5, 0.0, 0.0]], dtype=jnp.float32)
    # default geometry: 390 m is mid-road, vehicle stays active
    ns, _, _, obs = model.step_geom(state, params, model.default_geometry())
    assert float(ns[0, 3]) == 1.0
    assert float(obs[2]) == 0.0
    # lane-drop-style geometry with road_end just ahead: it retires
    ns, _, _, obs = model.step_geom(state, params, geom(392.0, 100.0, 200.0, 2, 0.1))
    assert float(ns[0, 3]) == 0.0
    assert float(obs[2]) == 1.0


def test_wall_and_merge_zone_follow_operands():
    """The phantom wall and the mandatory-merge window move with the
    merge_start/merge_end operands."""
    params = jnp.array([[30.0, 1.5, 1.5, 2.0, 2.0, 4.5, 0.0, 0.0]], dtype=jnp.float32)
    # ramp vehicle at x=150: outside the default zone (no merge), but
    # inside a shifted [100, 200] zone (merges into the empty mainline)
    state = jnp.array([[150.0, 20.0, 0.0, 1.0]], dtype=jnp.float32)
    ns, *_ = model.step_geom(state, params, model.default_geometry())
    assert float(ns[0, 2]) == 0.0
    ns, _, _, obs = model.step_geom(state, params, geom(1000.0, 100.0, 200.0, 2, 0.1))
    assert float(ns[0, 2]) == 1.0
    assert float(obs[3]) == 1.0
    # the wall follows merge_end: approaching a wall at 200 m from 150 m
    # at speed brakes hard; the default wall at 500 m does not
    state = jnp.array([[150.0, 30.0, 0.0, 1.0]], dtype=jnp.float32)
    # jammed mainline so the merge is unsafe either way
    jam = jnp.array(
        [[x, 0.0, 1.0, 1.0] for x in np.linspace(90.0, 260.0, 40)], dtype=jnp.float32
    )
    state = jnp.concatenate([state, jam])
    params = jnp.tile(params, (state.shape[0], 1))
    _, accel_near, _, _ = model.step_geom(state, params, geom(1000.0, 100.0, 200.0, 2, 0.1))
    _, accel_far, _, _ = model.step_geom(state, params, model.default_geometry())
    assert float(accel_near[0]) < float(accel_far[0]) - 1.0


def test_extra_mainline_lane_opens_with_operand():
    """num_main_lanes as an operand: a vehicle stuck behind a crawler in
    lane 2 may overtake into lane 3 only when the geometry says there is
    a lane 3 (the highway-merge main_lanes axis)."""
    # crawlers block lanes 1 and 2, so the only escape is upward
    state = jnp.array(
        [
            [100.0, 25.0, 2.0, 1.0],
            [112.0, 1.0, 2.0, 1.0],
            [112.0, 1.0, 1.0, 1.0],
        ],
        dtype=jnp.float32,
    )
    params = jnp.tile(
        jnp.array([[30.0, 1.5, 1.5, 2.0, 2.0, 4.5, 0.0, 0.0]], jnp.float32), (3, 1)
    )
    ns, *_ = model.step_geom(state, params, geom(1000.0, 300.0, 500.0, 2, 0.1))
    assert float(ns[0, 2]) == 2.0  # no lane 3 in a 2-lane world
    ns, *_ = model.step_geom(state, params, geom(1000.0, 300.0, 500.0, 3, 0.1))
    assert float(ns[0, 2]) == 3.0  # 3-lane world: overtake up


def test_dt_operand_scales_integration():
    state = jnp.array([[100.0, 20.0, 1.0, 1.0]], dtype=jnp.float32)
    params = jnp.array([[20.0, 1.5, 1.5, 2.0, 2.0, 4.5, 0.0, 0.0]], dtype=jnp.float32)
    # v == v0 → zero accel → displacement is v * dt exactly
    ns1, *_ = model.step_geom(state, params, geom(1000.0, 300.0, 500.0, 2, 0.1))
    ns2, *_ = model.step_geom(state, params, geom(1000.0, 300.0, 500.0, 2, 0.2))
    d1 = float(ns1[0, 0]) - 100.0
    d2 = float(ns2[0, 0]) - 100.0
    assert abs(d1 - 2.0) < 1e-4
    assert abs(d2 - 4.0) < 1e-4


def test_batched_mixed_geometry_matches_singles():
    """vmap over geometry rows: a mixed-family batch must equal per-world
    single steps — the micro-batcher's coalescing contract."""
    import jax

    rng = np.random.default_rng(17)
    geoms = [
        model.default_geometry(),                 # highway-merge default
        geom(700.0, 300.0, 400.0, 3, 0.1),        # lane-drop-ish
        geom(1000.0, 300.0, 650.0, 2, 0.1),       # ramp-weave-ish
        geom(1800.0, 0.0, 0.0, 1, 0.1),           # ring-shockwave-ish
    ]
    states, params = [], []
    for _ in geoms:
        s, p = make_state(rng, 16)
        states.append(s)
        params.append(p)
    bs, bp, bg = jnp.stack(states), jnp.stack(params), jnp.stack(geoms)
    batched = jax.vmap(model.step_geom)(bs, bp, bg)
    for i, g in enumerate(geoms):
        single = model.step_geom(states[i], params[i], g)
        for got, want in zip(batched, single):
            np.testing.assert_allclose(
                np.asarray(got[i]), np.asarray(want), rtol=1e-5, atol=1e-5
            )
