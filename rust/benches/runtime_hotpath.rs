//! Bench: the physics hot path — AOT JAX/Pallas step via PJRT vs the
//! native rust stepper, across vehicle-count buckets, plus the bare L1
//! kernels and the end-to-end coupled instance.
//!
//! ```text
//! make artifacts && cargo bench --bench runtime_hotpath
//! ```
//!
//! This is the §Perf baseline/after harness (EXPERIMENTS.md §Perf).
//! Results are appended to `BENCH_runtime_hotpath.json` at the repo
//! root; set `WEBOTS_HPC_BENCH_LABEL` to tag the run (e.g. "pre-PR1").
//!
//! Paired entries worth watching:
//!   * `native_step_reference/N=*` vs `native_step/N=*` — O(N²)
//!     reference scans vs the sorted-sweep index (PR 1 tentpole).
//!   * `hlo_step_8threads_x10/N=*` (persistent sessions) vs
//!     `hlo_step_8threads_x10_oneshot/N=*` (per-call channels+copies).
//!   * `native_step_scenario/<family>/N=*` vs
//!     `hlo_step_scenario/<family>/N=*` — non-default scenario
//!     geometries on the pooled PJRT fast path (PR 3 tentpole: before
//!     the geometry operand, every scenario-matrix run was native-only).
//!   * `hlo_step_mixed_families_8threads_x10/N=*` — four different
//!     geometries coalescing into single batched dispatches.
//!   * `hlo_rollout/K=1/N=*` vs `hlo_rollout/K={8,32}/N=*` — fused
//!     K-step rollout executables (PR 5 tentpole): one PJRT dispatch
//!     amortized over K physics steps instead of one dispatch per step.
//!   * `hlo_run/T=*/N=*` vs `hlo_rollout/K=32/N=*` — device-resident
//!     whole-run executables (PR 10 tentpole): the ENTIRE horizon in
//!     one dispatch with demand compiled in as the departure-table
//!     operand, vs the K=32 chunk-scheduler ceiling (acceptance: ≥2x
//!     steps/s at N≤64).

mod common;

use webots_hpc::pipeline::ChunkSteps;
use webots_hpc::runtime::EngineService;
use webots_hpc::scenario::{FamilyRegistry, UniformSampler};
use webots_hpc::sumo::mobil::MobilParams;
use webots_hpc::sumo::state::{DriverParams, Traffic};
use webots_hpc::sumo::{NativeIdmStepper, ReferenceIdmStepper, Stepper};
use webots_hpc::util::Rng64;

fn traffic(cap: usize, fill: f64, seed: u64) -> Traffic {
    let mut rng = Rng64::seed_from_u64(seed);
    let mut t = Traffic::new(cap);
    let mut x = 0.0f32;
    for _ in 0..cap {
        if rng.gen_f64() >= fill {
            continue;
        }
        x += 10.0 + rng.gen_range_f32(0.0, 40.0);
        t.spawn(
            x,
            rng.gen_range_f32(5.0, 30.0),
            rng.gen_below(3) as f32,
            DriverParams::default(),
        );
    }
    t
}

fn main() {
    let mut rec = common::Recorder::new("runtime_hotpath");
    let Ok(service) = EngineService::auto() else {
        println!("artifacts missing; run `make artifacts` first");
        // the native steppers need no artifacts — still record them
        for bucket in [16usize, 64, 256] {
            let t = traffic(bucket, 0.7, bucket as u64);
            bench_native(&mut rec, bucket, &t);
        }
        if let Err(e) = rec.write() {
            eprintln!("WARNING: bench results were NOT recorded: {e}");
        }
        return;
    };
    println!("PJRT platform: {}", service.platform());

    for &bucket in &service.manifest().buckets.clone() {
        let t = traffic(bucket, 0.7, bucket as u64);

        // full fused step (the production hot path)
        let s = rec.bench(&format!("hlo_step/N={bucket}"), 200, 1.0, || {
            let _ = service.step(bucket, &t.state, &t.params).unwrap();
        });
        println!(
            "    -> {:.0} steps/s, {:.1} Mveh-steps/s",
            common::throughput(&s, 1.0),
            common::throughput(&s, bucket as f64) / 1e6
        );

        // the same fused step through a persistent session (buffer and
        // channel reuse — the §Perf "after" path)
        let mut sess = service.session(bucket).unwrap();
        rec.bench(&format!("hlo_step_session/N={bucket}"), 200, 1.0, || {
            let _ = sess.step(&t.state, &t.params).unwrap();
        });

        // bare L1 kernels
        rec.bench(&format!("hlo_idm_kernel/N={bucket}"), 200, 1.0, || {
            let _ = service.idm(bucket, &t.state, &t.params).unwrap();
        });
        rec.bench(&format!("hlo_radar_kernel/N={bucket}"), 200, 1.0, || {
            let _ = service.radar(bucket, &t.state).unwrap();
        });

        bench_native(&mut rec, bucket, &t);
    }

    // the batched-step ceiling: one PJRT dispatch for 8 instances
    {
        let bucket = service.manifest().buckets[1];
        let b = service.manifest().batch;
        if b >= 2 {
            let t = traffic(bucket, 0.7, 2);
            let mut states = Vec::new();
            let mut params = Vec::new();
            for _ in 0..b {
                states.extend_from_slice(&t.state);
                params.extend_from_slice(&t.params);
            }
            let s = rec.bench(
                &format!("hlo_step_batched_b{b}/N={bucket}"),
                200,
                b as f64,
                || {
                    let _ = service.step_batched(bucket, &states, &params).unwrap();
                },
            );
            println!(
                "    -> {:.0} amortized steps/s ({} instances per dispatch)",
                common::throughput(&s, b as f64),
                b
            );
        }
    }

    // fused K-step rollouts (PR 5): the SAME physics, K steps per PJRT
    // dispatch — the K=1 case pays the full per-dispatch overhead
    // (channel hop, literal staging, reply) per physics step; K=8/32
    // amortize it.  N=256 is the acceptance case; smaller buckets show
    // the overhead-bound regime where fusion pays hardest.
    if service.manifest().rollouts_available() {
        let ladder = service.manifest().rollout_steps.clone();
        for &bucket in &service.manifest().buckets.clone() {
            if bucket > 256 {
                println!("note: rollout bench capped at N=256 (skipping N={bucket})");
                continue;
            }
            let t = traffic(bucket, 0.7, 0x5CA1E + bucket as u64);
            let mut sess = service.session(bucket).unwrap();
            let mut per_k = Vec::new();
            for &k in &ladder {
                let iters = (400 / k as u32).clamp(20, 200);
                let s = rec.bench(
                    &format!("hlo_rollout/K={k}/N={bucket}"),
                    iters,
                    k as f64,
                    || {
                        let _ = sess.step_many(&t.state, &t.params, k).unwrap();
                    },
                );
                let sps = common::throughput(&s, k as f64);
                println!("    -> {sps:.0} fused steps/s at K={k}");
                per_k.push((k, sps));
            }
            if let (Some((_, k1)), Some((kmax, kbest))) = (per_k.first(), per_k.last()) {
                println!(
                    "    -> K={kmax} amortization: {:.2}x over K=1 at N={bucket}",
                    kbest / k1
                );
            }
        }
    } else {
        println!("note: artifacts predate schema 4 — rollout benches skipped");
    }

    // device-resident whole runs (PR 10): the entire horizon as ONE
    // PJRT dispatch, demand compiled in via the departure-table
    // operand.  The table here is all padding rows (epoch DEP_PAD_EPOCH)
    // so the in-kernel insertion scan runs but never fires — the
    // physics work matches the rollout benches above and the pairing
    // hlo_run/T=* vs hlo_rollout/K=32 isolates the dispatch/ferrying
    // amortization (acceptance: ≥2x steps/s at N≤64).
    if service.manifest().runs_available() {
        let ladder = service.manifest().run_steps.clone();
        let d = service.manifest().departure_rows;
        for &bucket in &service.manifest().buckets.clone() {
            if bucket > 64 {
                println!("note: whole-run bench capped at N=64 (skipping N={bucket})");
                continue;
            }
            let t = traffic(bucket, 0.7, 0xD15 + bucket as u64);
            let mut table = vec![0.0f32; d * webots_hpc::sumo::DEP_COLS];
            for row in table.chunks_exact_mut(webots_hpc::sumo::DEP_COLS) {
                row[0] = webots_hpc::sumo::DEP_PAD_EPOCH;
            }
            let mut sess = service.session(bucket).unwrap();
            for &t_steps in &ladder {
                let iters = (2000 / t_steps as u32).clamp(3, 10);
                let s = rec.bench(
                    &format!("hlo_run/T={t_steps}/N={bucket}"),
                    iters,
                    t_steps as f64,
                    || {
                        let _ = sess.run(&t.state, &t.params, &table, t_steps).unwrap();
                    },
                );
                println!(
                    "    -> {:.0} resident steps/s at T={t_steps}",
                    common::throughput(&s, t_steps as f64)
                );
            }
        }
    } else {
        println!("note: artifacts predate schema 5 — whole-run benches skipped");
    }

    // telemetry overhead on the fused-rollout hot path (ISSUE 7
    // acceptance: ≤2%).  Events fire at dispatch granularity only —
    // the enabled run pays one histogram record plus one guarded emit
    // per K-step dispatch, never anything per physics step.
    if service.manifest().rollouts_available() {
        let buckets = &service.manifest().buckets;
        let bucket = buckets
            .iter()
            .copied()
            .filter(|&b| b <= 256)
            .max()
            .unwrap_or(buckets[0]);
        let k = service
            .manifest()
            .rollout_steps
            .last()
            .copied()
            .unwrap_or(1);
        let t = traffic(bucket, 0.7, 0x7E1E);
        let mut sess = service.session(bucket).unwrap();
        let iters = (400 / k as u32).clamp(20, 200);
        let off = rec.bench(
            &format!("hlo_rollout_telemetry_off/K={k}/N={bucket}"),
            iters,
            k as f64,
            || {
                let _ = sess.step_many(&t.state, &t.params, k).unwrap();
            },
        );
        let sink: std::sync::Arc<dyn webots_hpc::telemetry::EventSink> =
            webots_hpc::telemetry::MemorySink::new();
        webots_hpc::telemetry::install(sink.clone());
        let on = rec.bench(
            &format!("hlo_rollout_telemetry_on/K={k}/N={bucket}"),
            iters,
            k as f64,
            || {
                let _ = sess.step_many(&t.state, &t.params, k).unwrap();
            },
        );
        webots_hpc::telemetry::uninstall(&sink);
        let overhead = (on.median.as_secs_f64() / off.median.as_secs_f64() - 1.0) * 100.0;
        println!("    -> telemetry overhead on hlo_rollout: {overhead:+.2}% (budget 2%)");
    }

    // non-default scenario geometries on the pooled fast path (PR 3):
    // the SAME compiled (step, bucket) executable serves every family —
    // before the geometry operand these runs were native-only
    let registry = FamilyRegistry::builtin();
    for family in ["lane-drop", "ring-shockwave"] {
        let (_, cfg) = registry
            .materialize(family, &UniformSampler, 3, 0)
            .expect("builtin family compiles");
        if !service.manifest().buckets.contains(&cfg.capacity) {
            println!(
                "note: {family} point needs capacity {} (lowered: {:?}); bench skipped",
                cfg.capacity,
                service.manifest().buckets
            );
            continue;
        }
        let bucket = cfg.capacity;
        let t = traffic(bucket, 0.7, 0xFA0 + bucket as u64);
        let mut sess = service
            .session_for(bucket, cfg.geometry.geometry_vec())
            .unwrap();
        let s = rec.bench(
            &format!("hlo_step_scenario/{family}/N={bucket}"),
            200,
            1.0,
            || {
                let _ = sess.step(&t.state, &t.params).unwrap();
            },
        );
        println!(
            "    -> {:.0} steps/s on the {family} geometry (pooled executable)",
            common::throughput(&s, 1.0)
        );
        let mut nat = NativeIdmStepper::new(cfg.geometry, MobilParams::default());
        rec.bench(
            &format!("native_step_scenario/{family}/N={bucket}"),
            200,
            1.0,
            || {
                let mut tt = t.clone();
                let _ = nat.step(&mut tt);
            },
        );
    }

    // mixed-family coalescing: 8 threads, 2 sessions per family, four
    // DIFFERENT geometry rows per batched dispatch
    {
        // single-bucket artifact sets (e.g. `--buckets 16`) fall back to
        // the only bucket instead of panicking on buckets[1]
        let buckets = &service.manifest().buckets;
        let bucket = buckets.get(1).copied().unwrap_or(buckets[0]);
        let t = traffic(bucket, 0.7, 7);
        let geoms: Vec<_> = registry
            .ids()
            .iter()
            .enumerate()
            .map(|(k, id)| {
                registry
                    .materialize(id, &UniformSampler, 5, k as u64)
                    .expect("builtin family compiles")
                    .1
                    .geometry
                    .geometry_vec()
            })
            .collect();
        let mut sessions: Vec<_> = (0..8)
            .map(|k| service.session_for(bucket, geoms[k % geoms.len()]).unwrap())
            .collect();
        const ROUNDS: u32 = 10;
        let s = rec.bench(
            &format!("hlo_step_mixed_families_8threads_x10/N={bucket}"),
            30,
            8.0 * ROUNDS as f64,
            || {
                std::thread::scope(|scope| {
                    for sess in sessions.iter_mut() {
                        let state = &t.state;
                        let params = &t.params;
                        scope.spawn(move || {
                            for _ in 0..ROUNDS {
                                let _ = sess.step(state, params).unwrap();
                            }
                        });
                    }
                });
            },
        );
        println!(
            "    -> {:.0} aggregate steps/s across 8 threads, 4 geometries coalescing",
            common::throughput(&s, 8.0 * ROUNDS as f64)
        );
    }

    // end-to-end coupled instance (webots↔traci↔sumo↔physics): the L3
    // hot loop the §Perf pass optimizes
    for (label, engine) in [
        ("native", webots_hpc::pipeline::PhysicsEngine::Native),
        ("hlo", webots_hpc::pipeline::PhysicsEngine::Hlo(service.clone())),
    ] {
        let env = webots_hpc::container::ExecEnv::new(
            webots_hpc::container::build_webots_hpc_image(
                webots_hpc::container::BuildHost::PersonalComputer,
            )
            .unwrap(),
        );
        let displays = webots_hpc::display::DisplayRegistry::new();
        let s = rec.bench(&format!("coupled_instance_30s/{label}"), 10, 300.0, || {
            let port = std::net::TcpListener::bind("127.0.0.1:0")
                .unwrap()
                .local_addr()
                .unwrap()
                .port();
            let cfg = webots_hpc::pipeline::InstanceConfig {
                run_id: "bench".into(),
                node: 0,
                world: webots_hpc::webots::nodes::sample_merge_world(port),
                flows: webots_hpc::sumo::FlowFile::merge_sample(1200.0, 300.0, 30.0),
                scenario: webots_hpc::sumo::MergeScenario::default(),
                seed: 1,
                capacity: 64,
                horizon_s: 30.0,
                max_steps: 400,
                scenario_run: None,
                chunk_steps: ChunkSteps::Auto,
                faults: None,
                watchdog: Default::default(),
            };
            let _ = webots_hpc::pipeline::launch_instance(&cfg, &displays, &env, &engine)
                .unwrap();
        });
        println!(
            "    -> {:.0} coupled steps/s",
            common::throughput(&s, 300.0)
        );
    }

    // contention: 8 threads sharing the engine service (one node's
    // slots), steady state — 10 lock-step rounds per measurement so the
    // dynamic micro-batcher can coalesce (thread spawn cost amortized)
    let bucket = service.manifest().buckets[1];
    let t = traffic(bucket, 0.7, 1);
    const ROUNDS: u32 = 10;

    // persistent sessions (the production path: no per-call channels or
    // input copies into fresh Vecs)
    let mut sessions: Vec<_> = (0..8)
        .map(|_| service.session(bucket).unwrap())
        .collect();
    let s = rec.bench(
        &format!("hlo_step_8threads_x10/N={bucket}"),
        30,
        8.0 * ROUNDS as f64,
        || {
            std::thread::scope(|scope| {
                for sess in sessions.iter_mut() {
                    let state = &t.state;
                    let params = &t.params;
                    scope.spawn(move || {
                        for _ in 0..ROUNDS {
                            let _ = sess.step(state, params).unwrap();
                        }
                    });
                }
            });
        },
    );
    println!(
        "    -> {:.0} aggregate steps/s across 8 threads (sessions)",
        common::throughput(&s, 8.0 * ROUNDS as f64)
    );

    // one-shot API baseline (fresh channel + to_vec per call)
    let s = rec.bench(
        &format!("hlo_step_8threads_x10_oneshot/N={bucket}"),
        30,
        8.0 * ROUNDS as f64,
        || {
            std::thread::scope(|scope| {
                for _ in 0..8 {
                    let svc = service.clone();
                    let state = &t.state;
                    let params = &t.params;
                    scope.spawn(move || {
                        for _ in 0..ROUNDS {
                            let _ = svc.step(bucket, state, params).unwrap();
                        }
                    });
                }
            });
        },
    );
    println!(
        "    -> {:.0} aggregate steps/s across 8 threads (one-shot)",
        common::throughput(&s, 8.0 * ROUNDS as f64)
    );

    // compile-amortization observability: the whole harness (all
    // geometries included) should have compiled once per (kernel, bucket)
    if let Ok(usage) = service.pool_usage() {
        println!("{}", usage.render());
    }

    if let Err(e) = rec.write() {
        eprintln!("WARNING: bench results were NOT recorded: {e}");
    }
}

/// Native steppers at `bucket`: sorted-sweep production stepper vs the
/// O(N²) reference oracle (the PR 1 before/after pair).
fn bench_native(rec: &mut common::Recorder, bucket: usize, t: &Traffic) {
    let mut nat = NativeIdmStepper::default();
    let s = rec.bench(&format!("native_step/N={bucket}"), 200, 1.0, || {
        let mut tt = t.clone();
        let _ = nat.step(&mut tt);
    });
    println!(
        "    -> {:.0} native steps/s (sorted sweep)",
        common::throughput(&s, 1.0)
    );
    let mut reference = ReferenceIdmStepper::default();
    rec.bench(&format!("native_step_reference/N={bucket}"), 200, 1.0, || {
        let mut tt = t.clone();
        let _ = reference.step(&mut tt);
    });
}
