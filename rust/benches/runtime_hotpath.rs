//! Bench: the physics hot path — AOT JAX/Pallas step via PJRT vs the
//! native rust stepper, across vehicle-count buckets, plus the bare L1
//! kernels and the end-to-end coupled instance.
//!
//! ```text
//! make artifacts && cargo bench --bench runtime_hotpath
//! ```
//!
//! This is the §Perf baseline/after harness (EXPERIMENTS.md §Perf).

mod common;

use webots_hpc::runtime::EngineService;
use webots_hpc::sumo::state::{DriverParams, Traffic};
use webots_hpc::sumo::{NativeIdmStepper, Stepper};
use webots_hpc::util::Rng64;

fn traffic(cap: usize, fill: f64, seed: u64) -> Traffic {
    let mut rng = Rng64::seed_from_u64(seed);
    let mut t = Traffic::new(cap);
    let mut x = 0.0f32;
    for _ in 0..cap {
        if rng.gen_f64() >= fill {
            continue;
        }
        x += 10.0 + rng.gen_range_f32(0.0, 40.0);
        t.spawn(
            x,
            rng.gen_range_f32(5.0, 30.0),
            rng.gen_below(3) as f32,
            DriverParams::default(),
        );
    }
    t
}

fn main() {
    let Ok(service) = EngineService::auto() else {
        println!("artifacts missing; run `make artifacts` first");
        return;
    };
    println!("PJRT platform: {}", service.platform());

    for &bucket in &service.manifest().buckets.clone() {
        let t = traffic(bucket, 0.7, bucket as u64);

        // full fused step (the production hot path)
        let s = common::bench(&format!("hlo_step/N={bucket}"), 200, || {
            let _ = service.step(bucket, &t.state, &t.params).unwrap();
        });
        println!(
            "    -> {:.0} steps/s, {:.1} Mveh-steps/s",
            common::throughput(&s, 1.0),
            common::throughput(&s, bucket as f64) / 1e6
        );

        // bare L1 kernels
        common::bench(&format!("hlo_idm_kernel/N={bucket}"), 200, || {
            let _ = service.idm(bucket, &t.state, &t.params).unwrap();
        });
        common::bench(&format!("hlo_radar_kernel/N={bucket}"), 200, || {
            let _ = service.radar(bucket, &t.state).unwrap();
        });

        // native rust baseline (same physics, no PJRT round trip)
        let mut nat = NativeIdmStepper::default();
        common::bench(&format!("native_step/N={bucket}"), 200, || {
            let mut tt = t.clone();
            let _ = nat.step(&mut tt);
        });
    }

    // the batched-step ceiling: one PJRT dispatch for 8 instances
    {
        let bucket = service.manifest().buckets[1];
        let b = service.manifest().batch;
        if b >= 2 {
            let t = traffic(bucket, 0.7, 2);
            let mut states = Vec::new();
            let mut params = Vec::new();
            for _ in 0..b {
                states.extend_from_slice(&t.state);
                params.extend_from_slice(&t.params);
            }
            let s = common::bench(&format!("hlo_step_batched_b{b}/N={bucket}"), 200, || {
                let _ = service.step_batched(bucket, &states, &params).unwrap();
            });
            println!(
                "    -> {:.0} amortized steps/s ({} instances per dispatch)",
                common::throughput(&s, b as f64),
                b
            );
        }
    }

    // end-to-end coupled instance (webots↔traci↔sumo↔physics): the L3
    // hot loop the §Perf pass optimizes
    for (label, engine) in [
        ("native", webots_hpc::pipeline::PhysicsEngine::Native),
        ("hlo", webots_hpc::pipeline::PhysicsEngine::Hlo(service.clone())),
    ] {
        let env = webots_hpc::container::ExecEnv::new(
            webots_hpc::container::build_webots_hpc_image(
                webots_hpc::container::BuildHost::PersonalComputer,
            )
            .unwrap(),
        );
        let displays = webots_hpc::display::DisplayRegistry::new();
        let s = common::bench(&format!("coupled_instance_30s/{label}"), 10, || {
            let port = std::net::TcpListener::bind("127.0.0.1:0")
                .unwrap()
                .local_addr()
                .unwrap()
                .port();
            let cfg = webots_hpc::pipeline::InstanceConfig {
                run_id: "bench".into(),
                node: 0,
                world: webots_hpc::webots::nodes::sample_merge_world(port),
                flows: webots_hpc::sumo::FlowFile::merge_sample(1200.0, 300.0, 30.0),
                scenario: webots_hpc::sumo::MergeScenario::default(),
                seed: 1,
                capacity: 64,
                horizon_s: 30.0,
                max_steps: 400,
            };
            let _ = webots_hpc::pipeline::launch_instance(&cfg, &displays, &env, &engine)
                .unwrap();
        });
        println!(
            "    -> {:.0} coupled steps/s",
            common::throughput(&s, 300.0)
        );
    }

    // contention: 8 threads sharing the engine service (one node's
    // slots), steady state — 10 lock-step rounds per measurement so the
    // dynamic micro-batcher can coalesce (thread spawn cost amortized)
    let bucket = service.manifest().buckets[1];
    let t = traffic(bucket, 0.7, 1);
    const ROUNDS: u32 = 10;
    let s = common::bench("hlo_step_8threads_x10/N=64", 30, || {
        std::thread::scope(|scope| {
            for _ in 0..8 {
                let svc = service.clone();
                let state = t.state.clone();
                let params = t.params.clone();
                scope.spawn(move || {
                    for _ in 0..ROUNDS {
                        let _ = svc.step(bucket, &state, &params).unwrap();
                    }
                });
            }
        });
    });
    println!(
        "    -> {:.0} aggregate steps/s across 8 threads",
        common::throughput(&s, 8.0 * ROUNDS as f64)
    );
}
