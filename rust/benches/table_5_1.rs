//! Bench: regenerate paper **Table 5.1 / Figure 5.1** — sample simulation
//! throughput, personal computer vs cluster, 12-hour campaign.
//!
//! ```text
//! cargo bench --bench table_5_1
//! ```
//!
//! Asserts the reproduction targets (cluster column exact 48·t, 31×
//! speedup) and reports how long the full virtual-time replay takes.

mod common;

use webots_hpc::harness::{fig_5_1, table_5_1, PAPER_TABLE_5_1};

fn main() {
    let t = table_5_1().expect("table 5.1 generates");
    println!("{}", t.render());
    println!("{}", fig_5_1().expect("fig 5.1 renders"));

    // reproduction checks (same as the test suite, repeated here so the
    // bench is self-validating)
    for (i, &(m, _pc, cl)) in t.rows.iter().enumerate() {
        assert_eq!(cl, PAPER_TABLE_5_1[i].2, "cluster at {m} min");
    }
    assert!((t.speedup - 31.0).abs() < 3.0);

    // cost of regenerating the full 12h campaign in virtual time
    let s = common::bench("table_5_1::regenerate_12h_campaign", 10, || {
        let _ = table_5_1().unwrap();
    });
    println!(
        "virtual-time compression: 12h of campaign replayed in {:?} ({:.0}x real time)",
        s.median,
        12.0 * 3600.0 / s.median.as_secs_f64()
    );
}
