//! Bench: regenerate paper **Figure 5.2** — parallelization performance,
//! 6x8 parallel vs 6x1 serial throughput.
//!
//! ```text
//! cargo bench --bench fig_5_2
//! ```

mod common;

use webots_hpc::pipeline::{run_cluster_campaign, CampaignSpec};
use webots_hpc::simclock::SimDuration;

fn main() {
    println!("{}", webots_hpc::harness::fig_5_2().expect("fig 5.2 renders"));

    // throughput ratio across a sweep of campaign lengths — the figure's
    // claim must be duration-independent
    for hours in [1u64, 2, 4] {
        let mut p = CampaignSpec::paper_cluster();
        p.duration = SimDuration::from_hours(hours);
        let mut s = CampaignSpec::paper_serial_6x1();
        s.duration = SimDuration::from_hours(hours);
        let pt = run_cluster_campaign(&p).unwrap().total_completed();
        let st = run_cluster_campaign(&s).unwrap().total_completed();
        println!(
            "{hours}h: 6x8 = {pt} runs, 6x1 = {st} runs, ratio {:.1}x",
            pt as f64 / st as f64
        );
        assert_eq!(pt, 8 * st, "ratio must equal the slot count");
    }

    common::bench("fig_5_2::regenerate", 10, || {
        let _ = webots_hpc::harness::fig_5_2().unwrap();
    });
}
