//! Ablation benches for the design decisions in DESIGN.md §7:
//!
//! 1. packing policy: first-fit vs round-robin (distribution + cost),
//! 2. backfill on/off under a mixed job load,
//! 3. TraCI port step: 0 (the paper's crash) vs 1 vs 7,
//! 4. PJRT executable pool: per-call compile vs pooled,
//! 5. virtual clock vs scaled-real-time pacing.
//!
//! ```text
//! cargo bench --bench ablations
//! ```

mod common;

use webots_hpc::cluster::{Cluster, ClusterQueue, NodeSpec, QueueSpec};
use webots_hpc::metrics::FixedWorkload;
use webots_hpc::pbs::{
    ArrayRange, Job, JobId, PackingPolicy, ResourceRequest, Scheduler, SchedulerConfig,
};
use webots_hpc::pipeline::{run_cluster_campaign, CampaignSpec, PortAllocator};
use webots_hpc::simclock::SimDuration;

fn main() {
    ablation_packing_policy();
    ablation_backfill();
    ablation_port_step();
    ablation_executable_pool();
    ablation_clock();
}

fn ablation_packing_policy() {
    println!("\n=== ablation 1: packing policy ===");
    for policy in [PackingPolicy::FirstFit, PackingPolicy::RoundRobin] {
        let mut spec = CampaignSpec::paper_cluster();
        spec.policy = policy;
        spec.duration = SimDuration::from_hours(2);
        let r = run_cluster_campaign(&spec).unwrap();
        println!(
            "{policy:?}: completed {} runs, per-node {:?}, even: {}",
            r.total_completed(),
            r.runs_per_node,
            r.distribution_even(0.0)
        );
        // a saturating array of identical chunks is policy-insensitive —
        // the §4.2.2 claim that PBS "just handles it" holds either way
        assert!(r.distribution_even(0.0));
        common::bench(&format!("campaign_2h/{policy:?}"), 10, || {
            let _ = run_cluster_campaign(&spec).unwrap();
        });
    }
}

fn ablation_backfill() {
    println!("\n=== ablation 2: backfill under mixed load ===");
    // big jobs take 35/40 cores, leaving a 5-core hole only backfilled
    // small jobs can use while the second big job blocks the head.
    let big_req = || {
        let mut r = ResourceRequest::whole_node_15min();
        r.chunk.ncpus = 35;
        r.chunk.mem_gb = 600.0;
        r.chunk.scratch_gb = 0.0;
        r
    };
    let small_req = || {
        let mut r = ResourceRequest::experiment_15min();
        r.chunk.scratch_gb = 0.0;
        r.chunk.mem_gb = 90.0;
        r
    };
    for backfill in [false, true] {
        let mut s = Scheduler::new(
            Cluster::uniform("abl", 1, NodeSpec::dice_r740()),
            ClusterQueue::new(QueueSpec::dicelab(1)),
            SchedulerConfig {
                policy: PackingPolicy::FirstFit,
                backfill,
            },
        );
        for _ in 0..2 {
            s.submit(
                Job::new(JobId(0), "big", big_req()),
                Box::new(FixedWorkload::minutes(10)),
            )
            .unwrap();
        }
        s.submit(
            Job::new(JobId(0), "small", small_req())
                .with_array(ArrayRange::new(1, 8).unwrap()),
            Box::new(FixedWorkload::minutes(10)),
        )
        .unwrap();
        let occupied_now: usize = s.occupancy().iter().sum();
        s.run_to_completion();
        println!(
            "backfill={backfill}: {occupied_now} subjobs running immediately after submit (of 10)"
        );
        // with backfill a small job slips into the 5-core hole alongside
        // the first big job even though the second big job blocks the head
        if backfill {
            assert!(occupied_now >= 2, "backfill should start a small job");
        } else {
            assert_eq!(occupied_now, 1, "strict FIFO blocks on the 2nd big job");
        }
    }
}

fn ablation_port_step() {
    println!("\n=== ablation 3: TraCI port step ===");
    for step in [0u16, 1, 7] {
        let plan = PortAllocator::new(8873, step).plan(8);
        match plan {
            Ok(p) => println!("step {step}: OK, ports {:?}", p),
            Err(e) => println!("step {step}: FAILS as in paper §4.2.1 — {e}"),
        }
    }
    assert!(PortAllocator::new(8873, 0).plan(8).is_err());
    assert!(PortAllocator::new(8873, 1).plan(8).is_ok());
    assert!(PortAllocator::new(8873, 7).plan(8).is_ok());
}

fn ablation_executable_pool() {
    println!("\n=== ablation 4: PJRT executable pool ===");
    let Ok(service) = webots_hpc::runtime::EngineService::auto() else {
        println!("artifacts missing; skipping");
        return;
    };
    let bucket = service.manifest().buckets[0];
    let t = {
        let mut t = webots_hpc::sumo::state::Traffic::new(bucket);
        t.spawn(
            10.0,
            20.0,
            1.0,
            webots_hpc::sumo::state::DriverParams::default(),
        );
        t
    };
    // pooled: compile happened once at first call
    let warm = common::bench("pooled_step (compile amortized)", 100, || {
        let _ = service.step(bucket, &t.state, &t.params).unwrap();
    });
    // unpooled: fresh service per call = client + compile every time
    let dir = webots_hpc::runtime::find_artifacts_dir().unwrap();
    let cold = common::bench("fresh_engine_per_call (1 iter)", 3, || {
        let svc = webots_hpc::runtime::EngineService::spawn(dir.clone()).unwrap();
        let _ = svc.step(bucket, &t.state, &t.params).unwrap();
        svc.shutdown();
    });
    println!(
        "    -> pooling wins by {:.0}x on this artifact",
        cold.median.as_secs_f64() / warm.median.as_secs_f64()
    );
}

fn ablation_clock() {
    println!("\n=== ablation 5: virtual clock vs scaled-real-time ===");
    // virtual: the full 12h campaign
    let spec = CampaignSpec::paper_cluster();
    let s = common::bench("virtual_12h_campaign", 5, || {
        let _ = run_cluster_campaign(&spec).unwrap();
    });
    let compression = 12.0 * 3600.0 / s.median.as_secs_f64();
    println!("    -> {compression:.0}x wall-clock compression");
    // scaled-real-time: pace 10 virtual minutes at 6000x (100 ms wall)
    let mut short = CampaignSpec::paper_cluster();
    short.duration = SimDuration::from_minutes(15);
    let scale = 6000.0;
    let t0 = std::time::Instant::now();
    let r = run_cluster_campaign(&short).unwrap();
    // pacing loop: sleep the scaled remainder (demo of realtime mode)
    let virtual_s = short.duration.as_secs_f64();
    let target = std::time::Duration::from_secs_f64(virtual_s / scale);
    if t0.elapsed() < target {
        std::thread::sleep(target - t0.elapsed());
    }
    println!(
        "scaled-real-time at {scale:.0}x: {} runs in {:?} wall",
        r.total_completed(),
        t0.elapsed()
    );
    assert_eq!(r.total_completed(), 48);
}
