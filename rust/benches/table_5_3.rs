//! Bench: regenerate paper **Table 5.2 + Table 5.3** — experimental-setup
//! hardware specs and per-run resource consumption, 6x1 vs 6x8.
//!
//! ```text
//! cargo bench --bench table_5_3
//! ```

mod common;

use webots_hpc::harness::{table_5_2, table_5_3};

fn main() {
    println!("{}", table_5_2().render());
    let t = table_5_3().expect("table 5.3 generates");
    println!("{}", t.render());

    // shape targets (see EXPERIMENTS.md for the CPU% reporting note)
    let shorter = 1.0 - t.serial_6x1.mean_walltime_s / t.parallel_6x8.mean_walltime_s;
    assert!((shorter - 0.335).abs() < 0.07, "walltime advantage {shorter}");
    assert!(t.serial_6x1.mean_cpu_time_s > t.parallel_6x8.mean_cpu_time_s);
    assert!((t.serial_6x1.mean_ram_gb - t.parallel_6x8.mean_ram_gb).abs() < 0.3);

    common::bench("table_5_3::regenerate_both_setups", 10, || {
        let _ = table_5_3().unwrap();
    });
}
