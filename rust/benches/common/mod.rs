//! Shared micro-benchmark harness for the paper benches.
//!
//! The vendored offline crate set has no criterion; this is a small
//! timing harness with warmup, repeated samples and median/mean/stddev
//! reporting — enough rigor for the regeneration benches, whose primary
//! output is the *table content*, not nanosecond precision.
//!
//! Benches that feed the perf trajectory additionally record their
//! samples through a [`Recorder`], which appends a machine-readable run
//! to `BENCH_<bench>.json` at the repo root (EXPERIMENTS.md §Perf) so
//! numbers are comparable across PRs.

use std::time::{Duration, Instant};

pub struct Sample {
    pub label: String,
    pub iters: u32,
    pub mean: Duration,
    pub median: Duration,
    pub stddev_ns: f64,
}

impl Sample {
    pub fn print(&self) {
        println!(
            "bench {:<42} {:>12.3?} median, {:>12.3?} mean ± {:>8.1} µs ({} iters)",
            self.label,
            self.median,
            self.mean,
            self.stddev_ns / 1000.0,
            self.iters
        );
    }
}

/// Time `f` with warmup; returns stats over `iters` samples.
pub fn bench<F: FnMut()>(label: &str, iters: u32, mut f: F) -> Sample {
    // warmup
    for _ in 0..iters.div_ceil(5).max(1) {
        f();
    }
    let mut times: Vec<Duration> = Vec::with_capacity(iters as usize);
    for _ in 0..iters {
        let t0 = Instant::now();
        f();
        times.push(t0.elapsed());
    }
    times.sort();
    let median = times[times.len() / 2];
    let mean_ns = times.iter().map(|d| d.as_nanos() as f64).sum::<f64>() / times.len() as f64;
    let var = times
        .iter()
        .map(|d| (d.as_nanos() as f64 - mean_ns).powi(2))
        .sum::<f64>()
        / times.len() as f64;
    let s = Sample {
        label: label.to_string(),
        iters,
        mean: Duration::from_nanos(mean_ns as u64),
        median,
        stddev_ns: var.sqrt(),
    };
    s.print();
    s
}

/// Throughput helper: ops/second from a sample.
#[allow(dead_code)]
pub fn throughput(sample: &Sample, ops_per_iter: f64) -> f64 {
    ops_per_iter / sample.median.as_secs_f64()
}

/// Collects samples and appends them as one labelled run to
/// `BENCH_<bench>.json` (see EXPERIMENTS.md §Perf for the schema and
/// methodology).  Existing runs in the file are preserved, so the file
/// accumulates the perf trajectory across PRs.
///
/// The run label comes from `WEBOTS_HPC_BENCH_LABEL` (default "run");
/// the output directory from `WEBOTS_HPC_BENCH_DIR` (default: the
/// enclosing repo root, found by walking up to `ROADMAP.md`/`.git`).
#[allow(dead_code)]
pub struct Recorder {
    bench: String,
    rows: Vec<webots_hpc::util::Json>,
}

#[allow(dead_code)]
impl Recorder {
    pub fn new(bench: &str) -> Recorder {
        Recorder {
            bench: bench.to_string(),
            rows: Vec::new(),
        }
    }

    /// Record a sample; `ops_per_iter` scales the derived steps/s (1.0
    /// for plain per-iteration benches).
    pub fn record(&mut self, s: &Sample, ops_per_iter: f64) {
        use webots_hpc::util::Json;
        let mut row = std::collections::BTreeMap::new();
        row.insert("name".to_string(), Json::Str(s.label.clone()));
        row.insert(
            "ns_per_iter".to_string(),
            Json::Num(s.median.as_nanos() as f64),
        );
        row.insert(
            "mean_ns".to_string(),
            Json::Num(s.mean.as_nanos() as f64),
        );
        row.insert("stddev_ns".to_string(), Json::Num(s.stddev_ns));
        row.insert("iters".to_string(), Json::Num(s.iters as f64));
        row.insert(
            "steps_per_s".to_string(),
            Json::Num(throughput(s, ops_per_iter)),
        );
        self.rows.push(Json::Obj(row));
    }

    /// Convenience: time `f` via [`bench`] and record the sample.
    pub fn bench<F: FnMut()>(
        &mut self,
        label: &str,
        iters: u32,
        ops_per_iter: f64,
        f: F,
    ) -> Sample {
        let s = bench(label, iters, f);
        self.record(&s, ops_per_iter);
        s
    }

    fn out_path(&self) -> std::path::PathBuf {
        let file = format!("BENCH_{}.json", self.bench);
        if let Ok(dir) = std::env::var("WEBOTS_HPC_BENCH_DIR") {
            return std::path::PathBuf::from(dir).join(file);
        }
        let mut dir = std::env::current_dir().unwrap_or_else(|_| ".".into());
        loop {
            if dir.join("ROADMAP.md").exists() || dir.join(".git").exists() {
                return dir.join(file);
            }
            if !dir.pop() {
                return std::path::PathBuf::from(file);
            }
        }
    }

    /// Append this run to the trajectory file; returns the path written.
    ///
    /// The existing document is preserved wholesale (its `notes` and any
    /// other keys survive; only `runs` gains an entry).  A file that
    /// exists but doesn't parse is **never overwritten** — losing the
    /// cross-PR trajectory is worse than failing the append.
    pub fn write(&self) -> std::io::Result<std::path::PathBuf> {
        use std::collections::BTreeMap;
        use webots_hpc::util::Json;
        let path = self.out_path();
        let mut top: BTreeMap<String, Json> = match std::fs::read_to_string(&path) {
            Ok(text) => match Json::parse(&text) {
                Ok(Json::Obj(m)) => m,
                _ => {
                    return Err(std::io::Error::new(
                        std::io::ErrorKind::InvalidData,
                        format!(
                            "refusing to overwrite unparseable {} — fix or move it first",
                            path.display()
                        ),
                    ));
                }
            },
            Err(_) => BTreeMap::new(), // absent: start a fresh document
        };
        let mut runs = match top.remove("runs") {
            Some(Json::Arr(a)) => a,
            _ => Vec::new(),
        };
        let label =
            std::env::var("WEBOTS_HPC_BENCH_LABEL").unwrap_or_else(|_| "run".to_string());
        let unix_time = std::time::SystemTime::now()
            .duration_since(std::time::UNIX_EPOCH)
            .map(|d| d.as_secs())
            .unwrap_or(0);
        let mut run = BTreeMap::new();
        run.insert("label".to_string(), Json::Str(label));
        run.insert("unix_time".to_string(), Json::Num(unix_time as f64));
        run.insert("source".to_string(), Json::Str("cargo-bench".to_string()));
        run.insert("results".to_string(), Json::Arr(self.rows.clone()));
        runs.push(Json::Obj(run));
        top.insert("bench".to_string(), Json::Str(self.bench.clone()));
        top.entry("schema".to_string()).or_insert(Json::Num(1.0));
        top.insert("runs".to_string(), Json::Arr(runs));
        // crash-safe append: stage next to the target, then rename over
        // it, so an interrupted bench never truncates the trajectory
        let staged = path.with_extension("json.tmp");
        std::fs::write(&staged, Json::Obj(top).to_pretty_string() + "\n")?;
        std::fs::rename(&staged, &path)?;
        println!("bench results appended to {}", path.display());
        Ok(path)
    }
}
