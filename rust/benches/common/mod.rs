//! Shared micro-benchmark harness for the paper benches.
//!
//! The vendored offline crate set has no criterion; this is a small
//! timing harness with warmup, repeated samples and median/mean/stddev
//! reporting — enough rigor for the regeneration benches, whose primary
//! output is the *table content*, not nanosecond precision.

use std::time::{Duration, Instant};

pub struct Sample {
    pub label: String,
    pub iters: u32,
    pub mean: Duration,
    pub median: Duration,
    pub stddev_ns: f64,
}

impl Sample {
    pub fn print(&self) {
        println!(
            "bench {:<42} {:>12.3?} median, {:>12.3?} mean ± {:>8.1} µs ({} iters)",
            self.label,
            self.median,
            self.mean,
            self.stddev_ns / 1000.0,
            self.iters
        );
    }
}

/// Time `f` with warmup; returns stats over `iters` samples.
pub fn bench<F: FnMut()>(label: &str, iters: u32, mut f: F) -> Sample {
    // warmup
    for _ in 0..iters.div_ceil(5).max(1) {
        f();
    }
    let mut times: Vec<Duration> = Vec::with_capacity(iters as usize);
    for _ in 0..iters {
        let t0 = Instant::now();
        f();
        times.push(t0.elapsed());
    }
    times.sort();
    let median = times[times.len() / 2];
    let mean_ns = times.iter().map(|d| d.as_nanos() as f64).sum::<f64>() / times.len() as f64;
    let var = times
        .iter()
        .map(|d| (d.as_nanos() as f64 - mean_ns).powi(2))
        .sum::<f64>()
        / times.len() as f64;
    let s = Sample {
        label: label.to_string(),
        iters,
        mean: Duration::from_nanos(mean_ns as u64),
        median,
        stddev_ns: var.sqrt(),
    };
    s.print();
    s
}

/// Throughput helper: ops/second from a sample.
#[allow(dead_code)]
pub fn throughput(sample: &Sample, ops_per_iter: f64) -> f64 {
    ops_per_iter / sample.median.as_secs_f64()
}
