//! Bench: regenerate paper **§5.2** — instance-distribution quality
//! (the 48·t law and the perfect 8-per-node packing).
//!
//! ```text
//! cargo bench --bench distribution_5_2
//! ```

mod common;

use webots_hpc::harness::distribution_5_2;

fn main() {
    let d = distribution_5_2().expect("distribution report generates");
    println!("{}", d.render());
    assert!(d.follows_48t, "48·t law must hold");
    assert!(d.perfectly_even, "per-node run counts must be even");
    assert_eq!(d.peak_occupancy, vec![8; 6]);

    common::bench("distribution_5_2::regenerate", 10, || {
        let _ = distribution_5_2().unwrap();
    });
}
