//! The evaluation harness: regenerates every table and figure of the
//! paper's Chapter 5 (plus Table 4.1's challenge matrix) from the
//! simulated pipeline.  Shared by `cargo bench` and the
//! `webots-hpc table ...` CLI.

mod tables;

pub use tables::{
    distribution_5_2, fig_5_1, fig_5_2, scalability_sweep, table_4_1, table_5_1, table_5_2, table_5_3,
    DistributionReport, Table51, Table52, Table53, PAPER_TABLE_5_1, PAPER_TABLE_5_3,
};
