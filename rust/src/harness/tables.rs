//! Table/figure generators.  Paper targets are embedded next to each
//! generator so the renders show paper-vs-measured side by side.

use crate::cluster::NodeSpec;
use crate::metrics::UsageSummary;
use crate::pipeline::{
    pc_campaign, run_cluster_campaign, CampaignSpec, ThroughputSample, PAPER_PC_OVERHEAD_S,
};
use crate::simclock::SimDuration;
use crate::Result;

/// Paper Table 5.1 targets (timestamp minutes, PC runs, cluster runs).
pub const PAPER_TABLE_5_1: [(u64, u64, u64); 7] = [
    (30, 4, 96),
    (60, 7, 192),
    (90, 11, 288),
    (120, 15, 384),
    (240, 26, 768),
    (360, 40, 1152),
    (720, 74, 2304),
];

/// Table 5.1 / Fig 5.1: sample simulation throughput, PC vs cluster.
#[derive(Debug, Clone)]
pub struct Table51 {
    pub rows: Vec<(u64, u64, u64)>, // (minutes, pc, cluster)
    pub speedup: f64,
}

pub fn table_5_1() -> Result<Table51> {
    let spec = CampaignSpec::paper_cluster();
    let cluster = run_cluster_campaign(&spec)?;
    let pc = pc_campaign(
        &spec.cost,
        PAPER_PC_OVERHEAD_S,
        spec.duration,
        &spec.sample_minutes,
    );
    let rows = cluster
        .samples
        .iter()
        .zip(&pc.samples)
        .map(|(c, p)| (c.minutes, p.completed, c.completed))
        .collect::<Vec<_>>();
    let Some(last) = rows.last() else {
        return Err(crate::Error::Config(
            "table 5.1: campaign produced no throughput samples".into(),
        ));
    };
    Ok(Table51 {
        speedup: last.2 as f64 / last.1.max(1) as f64,
        rows,
    })
}

impl Table51 {
    pub fn render(&self) -> String {
        let mut s = String::new();
        s.push_str("Table 5.1 — Sample Simulation Throughput: Personal Computer vs. Palmetto Cluster\n");
        s.push_str("  (paper values in parentheses)\n");
        s.push_str(&format!(
            "{:>10} | {:>20} | {:>20}\n",
            "Timestamp", "Personal Computer", "Palmetto Cluster"
        ));
        s.push_str(&"-".repeat(58));
        s.push('\n');
        for (i, &(m, pc, cl)) in self.rows.iter().enumerate() {
            let (pm, ppc, pcl) = PAPER_TABLE_5_1[i];
            debug_assert_eq!(pm, m);
            s.push_str(&format!(
                "{m:>10} | {:>20} | {:>20}\n",
                format!("{pc} ({ppc})"),
                format!("{cl} ({pcl})")
            ));
        }
        s.push_str(&format!(
            "speedup at 720 min: {:.1}x (paper: ~31x)\n",
            self.speedup
        ));
        s
    }
}

/// Fig 5.1 is the bar-chart form of Table 5.1 — rendered as ASCII bars.
pub fn fig_5_1() -> Result<String> {
    let t = table_5_1()?;
    let max = t.rows.iter().map(|r| r.2).max().unwrap_or(1).max(1);
    let mut s = String::from("Figure 5.1 — Sample Simulation Throughput (runs completed)\n");
    for &(m, pc, cl) in &t.rows {
        let bar = |v: u64| "#".repeat(((v * 40) / max).max(if v > 0 { 1 } else { 0 }) as usize);
        s.push_str(&format!("{m:>4} min  PC      |{:<40}| {pc}\n", bar(pc)));
        s.push_str(&format!("         cluster |{:<40}| {cl}\n", bar(cl)));
    }
    Ok(s)
}

/// Table 5.2: hardware specs of the 6x1 vs 6x8 experimental setups.
#[derive(Debug, Clone)]
pub struct Table52 {
    pub whole_node: NodeSpec,
    pub slot_cores: u32,
    pub slot_ram_gb: f64,
    pub slot_scratch_gb: f64,
}

pub fn table_5_2() -> Table52 {
    let n = NodeSpec::dice_r740();
    Table52 {
        slot_cores: n.cores / 8,
        slot_ram_gb: n.ram_gb / 8.0,
        slot_scratch_gb: n.local_scratch_gb / 8.0,
        whole_node: n,
    }
}

impl Table52 {
    pub fn render(&self) -> String {
        let mut s = String::from("Table 5.2 — Hardware Specifications for Each Experimental Setup\n");
        s.push_str(&format!("{:>15} | {:>10} | {:>10}\n", "Setup", "6x1", "6x8"));
        s.push_str(&"-".repeat(42));
        s.push('\n');
        s.push_str(&format!(
            "{:>15} | {:>10} | {:>10}\n",
            "Cores", self.whole_node.cores, self.slot_cores
        ));
        s.push_str(&format!(
            "{:>15} | {:>10} | {:>10}\n",
            "RAM [GB]", self.whole_node.ram_gb as u64, self.slot_ram_gb.round() as u64
        ));
        s.push_str(&format!(
            "{:>15} | {:>10} | {:>10}\n",
            "Scratch [GB]",
            self.whole_node.local_scratch_gb.round() as u64,
            self.slot_scratch_gb.round() as u64
        ));
        s.push_str(&format!(
            "{:>15} | {:>10} | {:>10}\n",
            "Interconnect",
            self.whole_node.interconnect.as_str(),
            self.whole_node.interconnect.as_str()
        ));
        s
    }
}

/// Table 5.3: per-run resource consumption, 6x1 vs 6x8.
#[derive(Debug, Clone)]
pub struct Table53 {
    pub serial_6x1: UsageSummary,
    pub parallel_6x8: UsageSummary,
}

/// Paper Table 5.3 targets: (walltime, cpu_time, ram, cpu%).
pub const PAPER_TABLE_5_3: [(f64, f64, f64, f64); 2] = [
    (163.0, 720.0, 2.2, 215.0), // 6x1
    (245.0, 690.0, 2.3, 177.0), // 6x8
];

pub fn table_5_3() -> Result<Table53> {
    // shorter campaign — usage statistics converge fast
    let mut parallel = CampaignSpec::paper_cluster();
    parallel.duration = SimDuration::from_hours(2);
    let mut serial = CampaignSpec::paper_serial_6x1();
    serial.duration = SimDuration::from_hours(2);
    Ok(Table53 {
        serial_6x1: run_cluster_campaign(&serial)?.usage,
        parallel_6x8: run_cluster_campaign(&parallel)?.usage,
    })
}

impl Table53 {
    pub fn render(&self) -> String {
        let mut s =
            String::from("Table 5.3 — Simulation Resource Consumption Across Two Experimental Setups\n");
        s.push_str("  (paper values in parentheses; CPU% here = cpu_time/walltime — see EXPERIMENTS.md note)\n");
        s.push_str(&format!(
            "{:>16} | {:>20} | {:>20}\n",
            "Attribute", "6x1 Setup", "6x8 Setup"
        ));
        s.push_str(&"-".repeat(62));
        s.push('\n');
        let rows = [
            (
                "Walltime [s]",
                self.serial_6x1.mean_walltime_s,
                PAPER_TABLE_5_3[0].0,
                self.parallel_6x8.mean_walltime_s,
                PAPER_TABLE_5_3[1].0,
            ),
            (
                "CPU Time [s]",
                self.serial_6x1.mean_cpu_time_s,
                PAPER_TABLE_5_3[0].1,
                self.parallel_6x8.mean_cpu_time_s,
                PAPER_TABLE_5_3[1].1,
            ),
            (
                "RAM Used [GB]",
                self.serial_6x1.mean_ram_gb,
                PAPER_TABLE_5_3[0].2,
                self.parallel_6x8.mean_ram_gb,
                PAPER_TABLE_5_3[1].2,
            ),
            (
                "CPU %",
                self.serial_6x1.mean_cpu_percent,
                PAPER_TABLE_5_3[0].3,
                self.parallel_6x8.mean_cpu_percent,
                PAPER_TABLE_5_3[1].3,
            ),
        ];
        for (name, a, pa, b, pb) in rows {
            s.push_str(&format!(
                "{name:>16} | {:>20} | {:>20}\n",
                format!("{a:.1} ({pa})"),
                format!("{b:.1} ({pb})")
            ));
        }
        let shorter = 1.0 - self.serial_6x1.mean_walltime_s / self.parallel_6x8.mean_walltime_s;
        s.push_str(&format!(
            "6x1 walltime shorter by {:.1}% (paper: 33.5%)\n",
            shorter * 100.0
        ));
        s
    }
}

/// Fig 5.2: parallelization performance across the two setups
/// (throughput over equal campaign durations).
pub fn fig_5_2() -> Result<String> {
    let mut parallel = CampaignSpec::paper_cluster();
    parallel.duration = SimDuration::from_hours(2);
    let mut serial = CampaignSpec::paper_serial_6x1();
    serial.duration = SimDuration::from_hours(2);
    let p = run_cluster_campaign(&parallel)?;
    let s = run_cluster_campaign(&serial)?;
    let pt = p.total_completed();
    let st = s.total_completed();
    let max = pt.max(st).max(1);
    let bar = |v: u64| "#".repeat(((v * 40) / max).max(1) as usize);
    Ok(format!(
        "Figure 5.2 — Parallelization Performance (runs completed, 2h virtual campaign)\n\
         6x8 parallel |{:<40}| {pt}\n\
         6x1 serial   |{:<40}| {st}\n\
         ratio: {:.1}x (paper: 'sizably higher throughput' for 6x8, ~8x by slot count)\n",
        bar(pt),
        bar(st),
        pt as f64 / st.max(1) as f64
    ))
}

/// §5.2: distribution quality — the 48·t law and per-node evenness.
#[derive(Debug, Clone)]
pub struct DistributionReport {
    pub samples: Vec<ThroughputSample>,
    pub follows_48t: bool,
    pub runs_per_node: Vec<u64>,
    pub peak_occupancy: Vec<usize>,
    pub perfectly_even: bool,
}

pub fn distribution_5_2() -> Result<DistributionReport> {
    let spec = CampaignSpec::paper_cluster();
    let r = run_cluster_campaign(&spec)?;
    let follows_48t = r
        .samples
        .iter()
        .all(|s| s.completed == 48 * (s.minutes / 15));
    Ok(DistributionReport {
        samples: r.samples.clone(),
        follows_48t,
        perfectly_even: r.distribution_even(0.0),
        runs_per_node: r.runs_per_node,
        peak_occupancy: r.peak_occupancy,
    })
}

impl DistributionReport {
    pub fn render(&self) -> String {
        let mut s = String::from("§5.2 — Instance Distribution Quality\n");
        s.push_str(&format!(
            "48·t law holds at every sampled timestamp: {}\n",
            self.follows_48t
        ));
        s.push_str(&format!(
            "completed runs per node: {:?} (perfectly even: {})\n",
            self.runs_per_node, self.perfectly_even
        ));
        s.push_str(&format!(
            "peak live instances per node: {:?} (paper: 8 on each of 6 nodes, 100% of the time)\n",
            self.peak_occupancy
        ));
        s
    }
}

/// §6.2.2 future work: scalability sweep — completed runs vs node count
/// over a fixed-duration campaign (expect linearity: the paper predicts
/// "these results should scale with larger amounts of allocated compute
/// nodes").
pub fn scalability_sweep(node_counts: &[usize], hours: u64) -> Result<Vec<(usize, u64)>> {
    let mut rows = Vec::new();
    for &nodes in node_counts {
        let mut spec = CampaignSpec::paper_cluster();
        spec.nodes = nodes;
        spec.duration = SimDuration::from_hours(hours);
        rows.push((nodes, run_cluster_campaign(&spec)?.total_completed()));
    }
    Ok(rows)
}

/// Table 4.1: the development-challenge matrix, each row mapped to the
/// executable test that reproduces it.
pub fn table_4_1() -> String {
    let rows = [
        ("Identifying the best method to run Webots on the cluster", "container::build tests"),
        ("Converting the official Webots docker image to Singularity", "container::build::build_on_pc_succeeds_with_full_stack"),
        ("Modifying the Singularity container", "container::build::converted_sif_is_immutable_on_cluster"),
        ("Installing additional libraries on the Singularity image", "container::build::build_on_cluster_fails_at_pip_bootstrap"),
        ("Enabling GUI capabilities on the pipeline", "display::x11::forward_requires_dash_x"),
        ("Running Webots in headless mode", "display::xvfb::without_dash_a_second_instance_collides"),
        ("Enabling audio output on the cluster", "UNRESOLVED in the paper; out of scope here too"),
        ("Resolving the duplicate-port issue", "traci::server::duplicate_port_is_a_real_error"),
        ("Distributing runs across available nodes", "pbs::scheduler::forty_eight_instances_pack_eight_per_node"),
    ];
    let mut s = String::from("Table 4.1 — Pipeline Development Challenges (→ reproducing test)\n");
    for (challenge, test) in rows {
        s.push_str(&format!("  • {challenge}\n      → {test}\n"));
    }
    s
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn table_5_1_matches_paper_shape() {
        let t = table_5_1().unwrap();
        assert_eq!(t.rows.len(), 7);
        // cluster column exact (48·t), PC column within 15% of paper
        for (i, &(m, pc, cl)) in t.rows.iter().enumerate() {
            let (pm, ppc, pcl) = PAPER_TABLE_5_1[i];
            assert_eq!(m, pm);
            assert_eq!(cl, pcl, "cluster at {m} min");
            // The paper's PC pace drifts (491 s/run at t=90 vs 584 s/run
            // at t=720); our constant-pace model is calibrated on the
            // total. Accept ±3 runs absolute or 15% relative per row —
            // the t=720 total is asserted exactly below via the speedup.
            let abs = (pc as f64 - ppc as f64).abs();
            assert!(
                abs <= 3.0 || abs / (ppc as f64) < 0.15,
                "pc at {m} min: {pc} vs paper {ppc}"
            );
        }
        assert!((t.speedup - 31.0).abs() < 3.0, "speedup {}", t.speedup);
    }

    #[test]
    fn table_5_2_matches_paper() {
        let t = table_5_2();
        assert_eq!(t.whole_node.cores, 40);
        assert_eq!(t.slot_cores, 5);
        assert_eq!(t.slot_ram_gb, 93.0);
        assert!(t.render().contains("6x8"));
    }

    #[test]
    fn table_5_3_shape_holds() {
        let t = table_5_3().unwrap();
        // walltime: 6x1 ~33% shorter
        let shorter = 1.0 - t.serial_6x1.mean_walltime_s / t.parallel_6x8.mean_walltime_s;
        assert!((shorter - 0.335).abs() < 0.07, "shorter = {shorter}");
        // cpu time within ~10%, 6x1 higher
        assert!(t.serial_6x1.mean_cpu_time_s > t.parallel_6x8.mean_cpu_time_s);
        let excess = t.serial_6x1.mean_cpu_time_s / t.parallel_6x8.mean_cpu_time_s - 1.0;
        assert!(excess < 0.10, "excess = {excess}");
        // ram flat
        assert!((t.serial_6x1.mean_ram_gb - t.parallel_6x8.mean_ram_gb).abs() < 0.3);
        // cpu% higher with more cores
        assert!(t.serial_6x1.mean_cpu_percent > t.parallel_6x8.mean_cpu_percent);
    }

    #[test]
    fn distribution_report_is_perfect() {
        let d = distribution_5_2().unwrap();
        assert!(d.follows_48t);
        assert!(d.perfectly_even);
        assert_eq!(d.peak_occupancy, vec![8; 6]);
    }

    #[test]
    fn scalability_is_linear() {
        let rows = scalability_sweep(&[1, 2, 4, 8, 16], 1).unwrap();
        let per_node = rows[0].1;
        for &(n, c) in &rows {
            assert_eq!(c, per_node * n as u64, "at {n} nodes");
        }
    }

    #[test]
    fn renders_do_not_panic() {
        assert!(table_5_1().unwrap().render().contains("31"));
        assert!(fig_5_1().unwrap().contains("cluster"));
        assert!(table_5_3().unwrap().render().contains("CPU"));
        assert!(fig_5_2().unwrap().contains("6x8"));
        assert!(table_4_1().contains("duplicate-port"));
    }
}
