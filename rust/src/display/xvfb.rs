//! Xvfb: X virtual framebuffers, display-number allocation, `xvfb-run -a`.

use std::collections::BTreeSet;
use std::sync::{Arc, Mutex};

use crate::{Error, Result};

/// `xvfb-run`'s default server number.
pub const DEFAULT_DISPLAY: u32 = 99;

/// Per-node registry of X display numbers in use.  Shared by every
/// process on the node (the kernel's abstract-socket namespace, in real
/// life), hence `Arc<Mutex<..>>`.
#[derive(Debug, Clone, Default)]
pub struct DisplayRegistry {
    taken: Arc<Mutex<BTreeSet<u32>>>,
}

/// RAII handle to a bound display; frees the number on drop.
#[derive(Debug)]
pub struct DisplayHandle {
    pub number: u32,
    registry: DisplayRegistry,
}

impl Drop for DisplayHandle {
    fn drop(&mut self) {
        self.registry
            .taken
            .lock()
            .unwrap_or_else(|e| e.into_inner())
            .remove(&self.number);
    }
}

impl DisplayHandle {
    /// `:99`-style display string for the `DISPLAY` env var.
    pub fn display_env(&self) -> String {
        format!(":{}", self.number)
    }
}

impl DisplayRegistry {
    pub fn new() -> Self {
        Self::default()
    }

    /// Bind a specific display number. Fails when taken — the §3.1.5
    /// failure mode of running `xvfb-run` *without* `-a` twice.
    pub fn bind(&self, number: u32) -> Result<DisplayHandle> {
        let mut taken = self.taken.lock().unwrap_or_else(|e| e.into_inner());
        if !taken.insert(number) {
            return Err(Error::DisplayInUse(number));
        }
        Ok(DisplayHandle {
            number,
            registry: self.clone(),
        })
    }

    /// Probe upward from `start` for a free number (`-a` behaviour).
    pub fn bind_auto(&self, start: u32) -> Result<DisplayHandle> {
        let mut taken = self.taken.lock().unwrap_or_else(|e| e.into_inner());
        let mut n = start;
        while taken.contains(&n) {
            n += 1;
        }
        taken.insert(n);
        Ok(DisplayHandle {
            number: n,
            registry: self.clone(),
        })
    }

    pub fn in_use(&self) -> usize {
        self.taken.lock().unwrap_or_else(|e| e.into_inner()).len()
    }
}

/// An `xvfb-run [...] <cmd>` invocation.
#[derive(Debug, Clone)]
pub struct XvfbRun {
    /// The `-a` flag: probe for a free server number starting at 99.
    pub auto_probe: bool,
    /// Explicit `-n N` server number (defaults to 99).
    pub server_number: u32,
}

impl Default for XvfbRun {
    fn default() -> Self {
        XvfbRun {
            auto_probe: false,
            server_number: DEFAULT_DISPLAY,
        }
    }
}

impl XvfbRun {
    /// The pipeline's production invocation: `xvfb-run -a` (§3.1.5).
    pub fn auto() -> Self {
        XvfbRun {
            auto_probe: true,
            server_number: DEFAULT_DISPLAY,
        }
    }

    /// Acquire a framebuffer for the wrapped command.
    pub fn acquire(&self, registry: &DisplayRegistry) -> Result<DisplayHandle> {
        if self.auto_probe {
            registry.bind_auto(self.server_number)
        } else {
            registry.bind(self.server_number)
        }
    }
}

#[cfg(test)]
#[allow(clippy::unwrap_used, clippy::expect_used)]
mod tests {
    use super::*;

    #[test]
    fn without_dash_a_second_instance_collides() {
        // Table 4.1 row: "Running Webots in headless mode" + §3.1.5
        let reg = DisplayRegistry::new();
        let xvfb = XvfbRun::default();
        let _first = xvfb.acquire(&reg).unwrap();
        let err = xvfb.acquire(&reg).unwrap_err();
        assert!(matches!(err, Error::DisplayInUse(99)));
    }

    #[test]
    fn with_dash_a_eight_instances_coexist() {
        // 8 parallel instances per node (the 6x8 setup)
        let reg = DisplayRegistry::new();
        let xvfb = XvfbRun::auto();
        let handles: Vec<_> = (0..8).map(|_| xvfb.acquire(&reg).unwrap()).collect();
        let numbers: BTreeSet<u32> = handles.iter().map(|h| h.number).collect();
        assert_eq!(numbers.len(), 8, "all display numbers distinct");
        assert_eq!(*numbers.iter().next().unwrap(), 99);
        assert_eq!(*numbers.iter().last().unwrap(), 106);
    }

    #[test]
    fn drop_frees_display() {
        let reg = DisplayRegistry::new();
        {
            let _h = XvfbRun::default().acquire(&reg).unwrap();
            assert_eq!(reg.in_use(), 1);
        }
        assert_eq!(reg.in_use(), 0);
        // :99 is reusable after release
        let h = XvfbRun::default().acquire(&reg).unwrap();
        assert_eq!(h.number, 99);
    }

    #[test]
    fn auto_probe_fills_gaps() {
        let reg = DisplayRegistry::new();
        let a = reg.bind_auto(99).unwrap();
        let b = reg.bind_auto(99).unwrap();
        assert_eq!((a.number, b.number), (99, 100));
        drop(a);
        let c = reg.bind_auto(99).unwrap();
        assert_eq!(c.number, 99, "freed display is reused");
        drop(b);
        drop(c);
    }

    #[test]
    fn display_env_format() {
        let reg = DisplayRegistry::new();
        let h = reg.bind(42).unwrap();
        assert_eq!(h.display_env(), ":42");
    }
}
