//! SSH + X11 forwarding: the GUI-enabled path of §3.1.2.
//!
//! A user tunnels into the cluster with `ssh -X`, which allocates a
//! *forwarded* display (conventionally :10 and up, distinct from the
//! Xvfb range) and streams renderings back to the client.  This is a
//! thin model — enough for the `gui_session` example and the mode
//! selection logic in `webots::mode`.

use crate::{Error, Result};

use super::{DisplayHandle, DisplayRegistry};

/// An SSH connection to a login/compute node.
#[derive(Debug, Clone)]
pub struct SshSession {
    pub host: String,
    pub user: String,
    /// `-X` / `-Y` requested at connect time.
    pub x11_forwarding: bool,
}

impl SshSession {
    pub fn connect(user: &str, host: &str, x11_forwarding: bool) -> Self {
        SshSession {
            host: host.to_string(),
            user: user.to_string(),
            x11_forwarding,
        }
    }
}

/// A live forwarded X11 channel over an SSH session.
#[derive(Debug)]
pub struct X11Forward {
    pub session_host: String,
    pub display: DisplayHandle,
    /// Frames streamed to the client so far (the model's observable).
    pub frames_streamed: u64,
}

impl X11Forward {
    /// sshd's X11DisplayOffset default: forwarded displays start at :10.
    pub const FORWARD_BASE: u32 = 10;

    /// Open the forwarded display. Fails when the session was opened
    /// without `-X` — the first GUI mistake everyone makes (§4.1.5).
    pub fn open(session: &SshSession, registry: &DisplayRegistry) -> Result<X11Forward> {
        if !session.x11_forwarding {
            return Err(Error::Config(
                "ssh session opened without -X; cannot forward X11 (paper §3.1.2)".into(),
            ));
        }
        let display = registry.bind_auto(Self::FORWARD_BASE)?;
        Ok(X11Forward {
            session_host: session.host.clone(),
            display,
            frames_streamed: 0,
        })
    }

    /// Stream one rendered frame to the client.
    pub fn stream_frame(&mut self) {
        self.frames_streamed += 1;
    }
}

#[cfg(test)]
#[allow(clippy::unwrap_used, clippy::expect_used)]
mod tests {
    use super::*;

    #[test]
    fn forward_requires_dash_x() {
        let reg = DisplayRegistry::new();
        let plain = SshSession::connect("mfranchi", "login.palmetto", false);
        assert!(X11Forward::open(&plain, &reg).is_err());
        let x = SshSession::connect("mfranchi", "login.palmetto", true);
        let fwd = X11Forward::open(&x, &reg).unwrap();
        assert_eq!(fwd.display.number, 10);
    }

    #[test]
    fn multiple_forwards_get_distinct_displays() {
        let reg = DisplayRegistry::new();
        let s = SshSession::connect("a", "h", true);
        let f1 = X11Forward::open(&s, &reg).unwrap();
        let f2 = X11Forward::open(&s, &reg).unwrap();
        assert_ne!(f1.display.number, f2.display.number);
    }

    #[test]
    fn frames_accumulate() {
        let reg = DisplayRegistry::new();
        let s = SshSession::connect("a", "h", true);
        let mut f = X11Forward::open(&s, &reg).unwrap();
        for _ in 0..3 {
            f.stream_frame();
        }
        assert_eq!(f.frames_streamed, 3);
    }
}
