//! The X11/Xvfb substrate: virtual framebuffer allocation and X11
//! forwarding sessions.
//!
//! Headless Webots still needs an X display; the pipeline runs each
//! instance under `xvfb-run`.  The paper found that running n > 1
//! instances per node requires the `-a` flag ("instructs xvfb to try to
//! get a free server number, starting at 99", §3.1.5) — without it every
//! instance binds display :99 and the second one dies.  That collision
//! and its fix are real code paths here.

#![deny(clippy::unwrap_used, clippy::expect_used)]

mod x11;
mod xvfb;

pub use x11::{SshSession, X11Forward};
pub use xvfb::{DisplayHandle, DisplayRegistry, XvfbRun, DEFAULT_DISPLAY};
