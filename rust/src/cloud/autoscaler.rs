//! The elastic node pool and its autoscaler.

use crate::simclock::{SimDuration, SimInstant};

/// Provider characteristics (an EC2-ish profile).
#[derive(Debug, Clone, Copy)]
pub struct CloudProvider {
    /// Instance boot latency (request → schedulable).
    pub boot_latency: SimDuration,
    /// Cap on concurrently provisioned nodes.
    pub max_nodes: usize,
    /// Billing rate [$ / node-hour].
    pub node_hour_usd: f64,
    /// Scale-down after a node idles this long.
    pub idle_timeout: SimDuration,
}

impl Default for CloudProvider {
    fn default() -> Self {
        CloudProvider {
            boot_latency: SimDuration::from_secs(90),
            max_nodes: 64,
            node_hour_usd: 4.10, // an r5.24xlarge-ish on-demand rate
            idle_timeout: SimDuration::from_minutes(5),
        }
    }
}

/// Lifecycle of one elastic node.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum NodeState {
    /// Requested, still booting until the embedded instant.
    Booting(SimInstant),
    /// Schedulable.
    Ready,
    /// Terminated (kept for billing).
    Terminated,
}

#[derive(Debug, Clone)]
struct CloudNode {
    state: NodeState,
    /// Running instance count.
    busy: usize,
    /// Billing accumulator.
    provisioned_at: SimInstant,
    terminated_at: Option<SimInstant>,
    idle_since: Option<SimInstant>,
}

/// Queue-depth-targeting autoscaler over an elastic pool.
#[derive(Debug)]
pub struct AutoScaler {
    pub provider: CloudProvider,
    pub slots_per_node: usize,
    nodes: Vec<CloudNode>,
}

impl AutoScaler {
    pub fn new(provider: CloudProvider, slots_per_node: usize) -> Self {
        AutoScaler {
            provider,
            slots_per_node,
            nodes: Vec::new(),
        }
    }

    /// Nodes that can accept work right now.
    pub fn ready_nodes(&self) -> usize {
        self.nodes
            .iter()
            .filter(|n| n.state == NodeState::Ready)
            .count()
    }

    pub fn booting_nodes(&self) -> usize {
        self.nodes
            .iter()
            .filter(|n| matches!(n.state, NodeState::Booting(_)))
            .count()
    }

    /// Free slots across ready nodes.
    pub fn free_slots(&self) -> usize {
        self.nodes
            .iter()
            .filter(|n| n.state == NodeState::Ready)
            .map(|n| self.slots_per_node - n.busy)
            .sum()
    }

    /// One control-loop tick: finish boots, scale toward the demand
    /// target, retire idle nodes.  `demand` = queued + running instances.
    pub fn tick(&mut self, now: SimInstant, demand: usize) {
        // boots complete
        for n in &mut self.nodes {
            if let NodeState::Booting(ready_at) = n.state {
                if now >= ready_at {
                    n.state = NodeState::Ready;
                    n.idle_since = Some(now);
                }
            }
        }
        // target: enough nodes for the whole demand
        let target = demand.div_ceil(self.slots_per_node.max(1));
        let live = self.ready_nodes() + self.booting_nodes();
        if target > live {
            let want = (target - live).min(self.provider.max_nodes.saturating_sub(live));
            for _ in 0..want {
                self.nodes.push(CloudNode {
                    state: NodeState::Booting(now + self.provider.boot_latency),
                    busy: 0,
                    provisioned_at: now,
                    terminated_at: None,
                    idle_since: None,
                });
            }
        }
        // retire idle nodes beyond the target
        if live > target {
            let mut excess = live - target;
            for n in &mut self.nodes {
                if excess == 0 {
                    break;
                }
                if n.state == NodeState::Ready && n.busy == 0 {
                    if let Some(idle) = n.idle_since {
                        if now.saturating_sub(idle) >= self.provider.idle_timeout {
                            n.state = NodeState::Terminated;
                            n.terminated_at = Some(now);
                            excess -= 1;
                        }
                    }
                }
            }
        }
    }

    /// Claim one slot on a ready node; returns the node index.
    pub fn claim_slot(&mut self, now: SimInstant) -> Option<usize> {
        let idx = self
            .nodes
            .iter()
            .position(|n| n.state == NodeState::Ready && n.busy < self.slots_per_node)?;
        self.nodes[idx].busy += 1;
        self.nodes[idx].idle_since = None;
        let _ = now;
        Some(idx)
    }

    /// Release a slot claimed earlier.
    pub fn release_slot(&mut self, idx: usize, now: SimInstant) {
        let n = &mut self.nodes[idx];
        n.busy -= 1;
        if n.busy == 0 {
            n.idle_since = Some(now);
        }
    }

    /// Total billed node-hours up to `now`.
    pub fn node_hours(&self, now: SimInstant) -> f64 {
        self.nodes
            .iter()
            .map(|n| {
                let end = n.terminated_at.unwrap_or(now);
                end.saturating_sub(n.provisioned_at).as_secs_f64() / 3600.0
            })
            .sum()
    }

    pub fn cost_usd(&self, now: SimInstant) -> f64 {
        self.node_hours(now) * self.provider.node_hour_usd
    }

    pub fn provisioned_total(&self) -> usize {
        self.nodes.len()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn at(secs: u64) -> SimInstant {
        SimInstant::ZERO + SimDuration::from_secs(secs)
    }

    #[test]
    fn scales_up_to_demand_after_boot_latency() {
        let mut a = AutoScaler::new(CloudProvider::default(), 8);
        a.tick(at(0), 48); // 48 instances → 6 nodes
        assert_eq!(a.booting_nodes(), 6);
        assert_eq!(a.ready_nodes(), 0);
        a.tick(at(89), 48);
        assert_eq!(a.ready_nodes(), 0, "boot latency not elapsed");
        a.tick(at(90), 48);
        assert_eq!(a.ready_nodes(), 6);
        assert_eq!(a.free_slots(), 48);
    }

    #[test]
    fn respects_max_nodes() {
        let mut a = AutoScaler::new(
            CloudProvider {
                max_nodes: 4,
                ..Default::default()
            },
            8,
        );
        a.tick(at(0), 1000);
        assert_eq!(a.booting_nodes(), 4);
    }

    #[test]
    fn claims_and_releases_slots() {
        let mut a = AutoScaler::new(CloudProvider::default(), 2);
        a.tick(at(0), 2);
        a.tick(at(90), 2);
        let s1 = a.claim_slot(at(91)).unwrap();
        let s2 = a.claim_slot(at(91)).unwrap();
        assert_eq!(s1, s2, "packs one node first");
        assert!(a.claim_slot(at(91)).is_none(), "node full");
        a.release_slot(s1, at(100));
        assert!(a.claim_slot(at(101)).is_some());
    }

    #[test]
    fn scales_down_after_idle_timeout() {
        let mut a = AutoScaler::new(CloudProvider::default(), 8);
        a.tick(at(0), 8);
        a.tick(at(90), 8);
        assert_eq!(a.ready_nodes(), 1);
        // demand gone; node idles
        a.tick(at(200), 0);
        assert_eq!(a.ready_nodes(), 1, "idle timeout not reached");
        a.tick(at(90 + 301), 0);
        assert_eq!(a.ready_nodes(), 0, "retired after 5 min idle");
    }

    #[test]
    fn billing_accumulates_until_termination() {
        let mut a = AutoScaler::new(CloudProvider::default(), 8);
        a.tick(at(0), 8);
        a.tick(at(90), 8);
        a.tick(at(3690), 0); // idle long past timeout → terminated
        let hours = a.node_hours(at(7200));
        assert!(hours > 0.9 && hours < 1.2, "≈1 node-hour, got {hours}");
        assert!(a.cost_usd(at(7200)) > 3.0);
    }
}
