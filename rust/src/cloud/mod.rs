//! Cloud conversion of the pipeline — the paper's §6.2.3 future work.
//!
//! "an implementation on Amazon Web Services (AWS) could easily take
//! advantage of autoscaling, eliminating the need for static
//! provisioning of resources through a PBS script."  This module
//! implements that: an elastic node pool with boot latency and
//! per-node-hour cost, an autoscaler targeting the queue depth, and an
//! elastic campaign driver comparable head-to-head with the static PBS
//! cluster (bench `ablations`/`future_work`).

mod autoscaler;
mod elastic;

pub use autoscaler::{AutoScaler, CloudProvider, NodeState};
pub use elastic::{run_elastic_campaign, ElasticReport, ElasticSpec};
