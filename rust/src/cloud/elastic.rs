//! The elastic campaign: the paper's 12-hour experiment on autoscaled
//! cloud capacity instead of a statically provisioned PBS allocation.

use crate::metrics::CostModel;
use crate::simclock::{SimDuration, SimInstant};
use crate::util::Rng64;

use super::autoscaler::{AutoScaler, CloudProvider};

/// Elastic campaign configuration.
#[derive(Debug, Clone)]
pub struct ElasticSpec {
    pub provider: CloudProvider,
    pub slots_per_node: usize,
    /// Cores each slot gets (feeds the cost model).
    pub cores_per_slot: u32,
    /// Total simulation runs to complete.
    pub total_runs: u64,
    /// Control-loop tick.
    pub tick: SimDuration,
    pub cost: CostModel,
    pub seed: u64,
}

impl ElasticSpec {
    /// The paper's campaign, elastically: 2304 runs, 8 slots of 5 cores
    /// per node.
    pub fn paper_equivalent() -> Self {
        ElasticSpec {
            provider: CloudProvider::default(),
            slots_per_node: 8,
            cores_per_slot: 5,
            total_runs: 2304,
            tick: SimDuration::from_secs(10),
            cost: CostModel::paper_merge_sim(),
            seed: 2021,
        }
    }
}

/// What the elastic campaign produced.
#[derive(Debug, Clone, Copy)]
pub struct ElasticReport {
    pub completed: u64,
    pub makespan: SimDuration,
    pub node_hours: f64,
    pub cost_usd: f64,
    pub peak_nodes: usize,
    /// Busy-slot-time / provisioned-slot-time.
    pub utilization: f64,
}

/// Run the campaign: a queue of `total_runs` instances drains through an
/// autoscaled pool; each run's duration comes from the cost model.
pub fn run_elastic_campaign(spec: &ElasticSpec) -> ElasticReport {
    let mut scaler = AutoScaler::new(spec.provider, spec.slots_per_node);
    let mut rng = Rng64::seed_from_u64(spec.seed);
    let mut now = SimInstant::ZERO;
    let mut queued = spec.total_runs;
    let mut running: Vec<(SimInstant, usize)> = Vec::new(); // (finish_at, node)
    let mut completed = 0u64;
    let mut peak_nodes = 0usize;
    let mut busy_slot_s = 0.0f64;

    let per_run_base = spec.cost.walltime_s(spec.cores_per_slot);

    while completed < spec.total_runs {
        // finish due runs
        running.retain(|&(finish_at, node)| {
            if finish_at <= now {
                scaler.release_slot(node, now);
                completed += 1;
                false
            } else {
                true
            }
        });
        // control loop
        scaler.tick(now, (queued + running.len() as u64) as usize);
        peak_nodes = peak_nodes.max(scaler.ready_nodes() + scaler.booting_nodes());
        // dispatch
        while queued > 0 {
            let Some(node) = scaler.claim_slot(now) else { break };
            let dur = per_run_base * (0.97 + 0.06 * rng.gen_f64());
            busy_slot_s += dur;
            running.push((now + SimDuration::from_secs_f64(dur), node));
            queued -= 1;
        }
        now += spec.tick;
        debug_assert!(
            now.as_secs_f64() < 30.0 * 24.0 * 3600.0,
            "elastic campaign did not converge"
        );
    }

    let node_hours = scaler.node_hours(now);
    ElasticReport {
        completed,
        makespan: now - SimInstant::ZERO,
        node_hours,
        cost_usd: scaler.cost_usd(now),
        peak_nodes,
        utilization: busy_slot_s / (node_hours * 3600.0 * spec.slots_per_node as f64).max(1e-9),
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn paper_equivalent_completes_all_runs() {
        let r = run_elastic_campaign(&ElasticSpec::paper_equivalent());
        assert_eq!(r.completed, 2304);
        assert!(r.peak_nodes > 0);
        assert!(r.cost_usd > 0.0);
    }

    #[test]
    fn elastic_beats_epoch_locked_makespan() {
        // the static PBS campaign epoch-locks 48 runs per 15 min → 12 h
        // for 2304 runs; the elastic pool is work-conserving and (with
        // enough capacity) much faster
        let r = run_elastic_campaign(&ElasticSpec::paper_equivalent());
        assert!(
            r.makespan < SimDuration::from_hours(12),
            "elastic makespan {} should beat the epoch-locked 12 h",
            r.makespan
        );
    }

    #[test]
    fn utilization_is_high_without_epoch_locking() {
        // static PBS utilization in the paper's experiment is ~27%
        // (245 s of work per 900 s walltime slot); work-conserving
        // dispatch should do far better
        let r = run_elastic_campaign(&ElasticSpec::paper_equivalent());
        assert!(
            r.utilization > 0.60,
            "elastic utilization {:.2} should far exceed the static 0.27",
            r.utilization
        );
    }

    #[test]
    fn capped_capacity_still_converges() {
        let mut spec = ElasticSpec::paper_equivalent();
        spec.provider.max_nodes = 2;
        spec.total_runs = 200;
        let r = run_elastic_campaign(&spec);
        assert_eq!(r.completed, 200);
        assert!(r.peak_nodes <= 2);
    }

    #[test]
    fn boot_latency_stretches_small_campaigns() {
        let mut fast = ElasticSpec::paper_equivalent();
        fast.total_runs = 8;
        fast.provider.boot_latency = SimDuration::from_secs(1);
        let mut slow = fast.clone();
        slow.provider.boot_latency = SimDuration::from_secs(600);
        let rf = run_elastic_campaign(&fast);
        let rs = run_elastic_campaign(&slow);
        assert!(rs.makespan > rf.makespan);
    }
}
