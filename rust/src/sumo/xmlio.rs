//! Reading/writing the sumo-like configuration files.
//!
//! The pipeline shuttles three files per simulation copy (§3.1.4):
//! `sumo.net.xml` (network), `sumo.flow.xml` (demand) and `sumo.rou.xml`
//! (generated routes).  We serialize a faithful XML-ish subset — enough
//! for the world-copy propagation and the preprocessing step the paper
//! performs "prior to executing the singularity exec command".

use std::path::Path;

use crate::{Error, Result};

use super::flow::{FlowDef, FlowFile, VehicleType};
use super::network::{Edge, Network};

/// Serialize the network to `sumo.net.xml`-style text.
pub fn write_net_xml(net: &Network) -> String {
    let mut s = String::from("<net>\n");
    for e in &net.edges {
        s.push_str(&format!(
            "  <edge id=\"{}\" from=\"{}\" to=\"{}\" length=\"{}\" numLanes=\"{}\" speed=\"{}\"/>\n",
            e.id, e.from, e.to, e.length_m, e.num_lanes, e.speed_limit
        ));
    }
    s.push_str("</net>\n");
    s
}

/// Parse `sumo.net.xml`-style text.
pub fn read_net_xml(text: &str) -> Result<Network> {
    let mut edges = Vec::new();
    for line in text.lines() {
        let line = line.trim();
        if !line.starts_with("<edge ") {
            continue;
        }
        edges.push(Edge {
            id: attr(line, "id")?,
            from: attr(line, "from")?,
            to: attr(line, "to")?,
            length_m: attr(line, "length")?.parse().map_err(bad("length"))?,
            num_lanes: attr(line, "numLanes")?.parse().map_err(bad("numLanes"))?,
            speed_limit: attr(line, "speed")?.parse().map_err(bad("speed"))?,
        });
    }
    if edges.is_empty() {
        return Err(Error::Config("net.xml contains no edges".into()));
    }
    Ok(Network { edges })
}

/// Serialize demand to `sumo.flow.xml`-style text.
pub fn write_flow_xml(flows: &FlowFile) -> String {
    let mut s = String::from("<routes>\n");
    for f in &flows.flows {
        // destination intent rides the flow element only when present,
        // so pre-schema-3 consumers keep parsing unrouted files
        let exit = match f.exit_pos_m {
            Some(gore) => format!(" exitPos=\"{gore}\""),
            None => String::new(),
        };
        s.push_str(&format!(
            "  <flow id=\"{}\" route=\"{}\" vehsPerHour=\"{}\" departSpeed=\"{}\" departLane=\"{}\" departPos=\"{}\" type=\"{}\" begin=\"{}\" end=\"{}\" v0Scale=\"{}\" tScale=\"{}\"{exit}/>\n",
            f.id,
            f.route.join(" "),
            f.vehs_per_hour,
            f.depart_speed,
            f.depart_lane,
            f.depart_pos,
            match f.vtype { VehicleType::Human => "human", VehicleType::Cav => "cav" },
            f.begin_s,
            f.end_s,
            f.v0_scale,
            f.t_scale,
        ));
    }
    s.push_str("</routes>\n");
    s
}

/// Parse `sumo.flow.xml`-style text.
pub fn read_flow_xml(text: &str) -> Result<FlowFile> {
    let mut flows = Vec::new();
    for line in text.lines() {
        let line = line.trim();
        if !line.starts_with("<flow ") {
            continue;
        }
        flows.push(FlowDef {
            id: attr(line, "id")?,
            route: attr(line, "route")?
                .split_whitespace()
                .map(String::from)
                .collect(),
            vehs_per_hour: attr(line, "vehsPerHour")?.parse().map_err(bad("vehsPerHour"))?,
            depart_speed: attr(line, "departSpeed")?.parse().map_err(bad("departSpeed"))?,
            depart_lane: attr(line, "departLane")?.parse().map_err(bad("departLane"))?,
            depart_pos: attr(line, "departPos")?.parse().map_err(bad("departPos"))?,
            vtype: match attr(line, "type")?.as_str() {
                "cav" => VehicleType::Cav,
                _ => VehicleType::Human,
            },
            begin_s: attr(line, "begin")?.parse().map_err(bad("begin"))?,
            end_s: attr(line, "end")?.parse().map_err(bad("end"))?,
            // scenario driver scales; absent in pre-scenario files → 1.0
            v0_scale: attr_or(line, "v0Scale", "1").parse().map_err(bad("v0Scale"))?,
            t_scale: attr_or(line, "tScale", "1").parse().map_err(bad("tScale"))?,
            // destination intent; absent (pre-schema-3 files) → through
            exit_pos_m: match attr(line, "exitPos") {
                Ok(v) => Some(v.parse().map_err(bad("exitPos"))?),
                Err(_) => None,
            },
        });
    }
    Ok(FlowFile { flows })
}

pub fn save(path: &Path, text: &str) -> Result<()> {
    std::fs::write(path, text)?;
    Ok(())
}

pub fn load(path: &Path) -> Result<String> {
    Ok(std::fs::read_to_string(path)?)
}

fn attr(line: &str, name: &str) -> Result<String> {
    let pat = format!("{name}=\"");
    let start = line
        .find(&pat)
        .ok_or_else(|| Error::Config(format!("missing attribute '{name}' in: {line}")))?
        + pat.len();
    let end = line[start..]
        .find('"')
        .ok_or_else(|| Error::Config(format!("unterminated attribute '{name}'")))?;
    Ok(line[start..start + end].to_string())
}

fn attr_or(line: &str, name: &str, default: &str) -> String {
    attr(line, name).unwrap_or_else(|_| default.to_string())
}

fn bad<E: std::fmt::Display>(name: &'static str) -> impl Fn(E) -> Error {
    move |e| Error::Config(format!("bad {name}: {e}"))
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::sumo::network::MergeScenario;

    #[test]
    fn net_xml_roundtrip() {
        let net = MergeScenario::default().network();
        let xml = write_net_xml(&net);
        let back = read_net_xml(&xml).unwrap();
        assert_eq!(net, back);
    }

    #[test]
    fn flow_xml_roundtrip() {
        let flows = FlowFile::merge_sample(1200.0, 300.0, 600.0);
        let xml = write_flow_xml(&flows);
        let back = read_flow_xml(&xml).unwrap();
        assert_eq!(flows, back);
    }

    #[test]
    fn scaled_flow_roundtrip_and_legacy_default() {
        let mut flows = FlowFile::merge_sample(1200.0, 300.0, 600.0);
        flows.flows[0].v0_scale = 0.9;
        flows.flows[0].t_scale = 1.15;
        flows.flows[1].exit_pos_m = Some(612.5);
        let back = read_flow_xml(&write_flow_xml(&flows)).unwrap();
        assert_eq!(flows, back);
        // pre-scenario flow files without the scale attrs parse as 1.0,
        // and pre-schema-3 files without exitPos parse as through
        let legacy = "<routes>\n<flow id=\"a\" route=\"ramp\" vehsPerHour=\"100\" departSpeed=\"10\" departLane=\"0\" departPos=\"0\" type=\"human\" begin=\"0\" end=\"60\"/>\n</routes>\n";
        let f = read_flow_xml(legacy).unwrap();
        assert_eq!(f.flows[0].v0_scale, 1.0);
        assert_eq!(f.flows[0].t_scale, 1.0);
        assert_eq!(f.flows[0].exit_pos_m, None);
    }

    #[test]
    fn missing_attribute_rejected() {
        assert!(read_net_xml("<net>\n<edge id=\"a\"/>\n</net>").is_err());
        assert!(read_net_xml("<net></net>").is_err());
    }

    #[test]
    fn file_roundtrip() {
        let dir = crate::util::TempDir::new("webots-hpc-xmlio").unwrap();
        let p = dir.path().join("sumo.net.xml");
        let net = MergeScenario::default().network();
        save(&p, &write_net_xml(&net)).unwrap();
        let back = read_net_xml(&load(&p).unwrap()).unwrap();
        assert_eq!(net, back);
    }
}
