//! The microsimulation loop: demand insertion + physics stepping +
//! observables.  This is what a TraCI server fronts.

use crate::Result;

use super::duarouter::RouteFile;
use super::network::MergeScenario;
use super::state::{DriverParams, Traffic};

/// Per-step observables — mirrors the `obs` output of the AOT step
/// (`[n_active, mean_speed, flow, n_merged, n_exited]`).  `flow` counts
/// road-end completions only; `n_exited` counts off-ramp completions
/// (vehicles crossing their own `exit_pos`), so ramp-weave throughput
/// is not under-reported in aggregates.
#[derive(Debug, Clone, Copy, PartialEq, Default)]
pub struct StepObs {
    pub n_active: f32,
    pub mean_speed: f32,
    pub flow: f32,
    pub n_merged: f32,
    pub n_exited: f32,
}

/// The number of DT steps covering `horizon_s` — THE step-count
/// derivation, shared by every site that turns a horizon into steps
/// (`SumoSim::run`, the launcher's walltime guard, CLI/example step
/// budgets).  Each site used to round independently (`round` here,
/// `ceil` there, `* 10.0` hardcoded elsewhere), which could drift by a
/// step between planner and runtime; one helper means one rounding.
pub fn steps_for(horizon_s: f32, dt_s: f32) -> u64 {
    (horizon_s / dt_s.max(1e-6)).round().max(0.0) as u64
}

/// A physics engine advancing the traffic state by one DT.
/// Implementations: [`super::NativeIdmStepper`] (pure rust) and
/// `runtime::HloStepper` (the AOT JAX/Pallas artifact via PJRT).
pub trait Stepper: Send {
    fn step(&mut self, traffic: &mut Traffic) -> StepObs;

    /// The fused-chunk sizes this stepper can execute in ONE dispatch,
    /// descending and always ending in 1.  The default — no fusion —
    /// suits steppers with no per-step dispatch overhead (the native
    /// ones); `HloStepper` advertises the artifact manifest's rollout
    /// K ladder so the [`SumoSim`] chunk scheduler can amortize one
    /// PJRT dispatch over a whole run of departure-free steps.
    fn chunk_ladder(&self) -> &[usize] {
        &[1]
    }

    /// Advance `k` steps (a ladder rung), appending one [`StepObs`] per
    /// step — required to be bit-identical to `k` [`Stepper::step`]
    /// calls.  The default executes them sequentially; fused
    /// implementations override with a single dispatch.
    fn step_many(&mut self, traffic: &mut Traffic, k: usize, out: &mut Vec<StepObs>) {
        for _ in 0..k {
            out.push(self.step(traffic));
        }
    }

    /// Engine label for logs/benches.
    fn name(&self) -> &'static str {
        "stepper"
    }
}

/// The simulation: routes in, trajectories out.
pub struct SumoSim {
    pub scenario: MergeScenario,
    pub traffic: Traffic,
    stepper: Box<dyn Stepper>,
    routes: RouteFile,
    next_departure: usize,
    /// Departures that found no free slot and wait for one (SUMO's
    /// insertion queue).
    insertion_queue: Vec<usize>,
    /// Cap on the fused-chunk size the scheduler may hand the stepper
    /// (`usize::MAX` = whatever the stepper's ladder allows; 1 =
    /// step-by-step, e.g. TraCI-attached live-GUI runs).
    chunk_limit: usize,
    time_s: f32,
    step_count: u64,
    /// Totals since start.
    pub total_flow: f32,
    pub total_merged: f32,
    /// Off-ramp completions (exit-flagged vehicles that crossed their
    /// own `exit_pos`) — throughput invisible to `total_flow`.
    pub total_exited: f32,
    pub total_spawned: u64,
}

impl SumoSim {
    pub fn new(
        scenario: MergeScenario,
        capacity: usize,
        routes: RouteFile,
        stepper: Box<dyn Stepper>,
    ) -> Self {
        SumoSim {
            scenario,
            traffic: Traffic::new(capacity),
            stepper,
            routes,
            next_departure: 0,
            insertion_queue: Vec::new(),
            chunk_limit: usize::MAX,
            time_s: 0.0,
            step_count: 0,
            total_flow: 0.0,
            total_merged: 0.0,
            total_exited: 0.0,
            total_spawned: 0,
        }
    }

    pub fn time_s(&self) -> f32 {
        self.time_s
    }

    pub fn step_count(&self) -> u64 {
        self.step_count
    }

    fn try_insert(&mut self, dep_idx: usize) -> bool {
        let d = &self.routes.departures[dep_idx];
        // SUMO refuses insertion on top of another vehicle: require the
        // insertion point clear by s0 + length.
        let clearance = d.params.s0 + d.params.length;
        for i in 0..self.traffic.capacity() {
            if self.traffic.is_active(i)
                && (self.traffic.lane(i) - d.lane as f32).abs() < 0.5
                && (self.traffic.x(i) - d.pos_m).abs() < clearance
            {
                return false;
            }
        }
        let p = DriverParams { ..d.params };
        self.traffic
            .spawn(d.pos_m, d.speed, d.lane as f32, p)
            .is_some()
    }

    /// Cap fused chunks at `k` physics steps per dispatch (validated
    /// against the stepper's ladder by the launcher; 1 = step-by-step,
    /// what TraCI-attached live-GUI runs force so frame streaming never
    /// starves behind a 32-step chunk).
    pub fn set_chunk_limit(&mut self, k: usize) {
        self.chunk_limit = k.max(1);
    }

    pub fn chunk_limit(&self) -> usize {
        self.chunk_limit
    }

    /// The insertion phase of one step: retry queued departures, then
    /// insert newly due ones (shared by [`Self::step`] and the chunk
    /// scheduler — a fused chunk runs it once, for its first step).
    fn insert_due(&mut self) {
        // retry earlier blocked insertions first, compacting the queue
        // in place (keeps order, allocates nothing on the per-step path)
        let mut kept = 0;
        for k in 0..self.insertion_queue.len() {
            let dep = self.insertion_queue[k];
            if self.try_insert(dep) {
                self.total_spawned += 1;
            } else {
                self.insertion_queue[kept] = dep;
                kept += 1;
            }
        }
        self.insertion_queue.truncate(kept);

        // newly due departures
        while self.next_departure < self.routes.departures.len()
            && self.routes.departures[self.next_departure].time_s <= self.time_s
        {
            let idx = self.next_departure;
            self.next_departure += 1;
            if self.try_insert(idx) {
                self.total_spawned += 1;
            } else {
                self.insertion_queue.push(idx);
            }
        }
    }

    /// Per-step bookkeeping after the physics (totals, clock, counter).
    fn account(&mut self, obs: StepObs) {
        self.total_flow += obs.flow;
        self.total_merged += obs.n_merged;
        self.total_exited += obs.n_exited;
        self.time_s += self.scenario.dt_s;
        self.step_count += 1;
    }

    /// Advance one DT: insert due departures, then step physics.
    pub fn step(&mut self) -> StepObs {
        self.insert_due();
        let obs = self.stepper.step(&mut self.traffic);
        self.account(obs);
        obs
    }

    /// How many steps (<= `cap`) may run as ONE fused chunk from here:
    /// the run length until the next step whose insertion phase has
    /// work to do.  A fused chunk replays steps `1..k` without their
    /// insertion phases, so it is bit-identical to sequential stepping
    /// exactly when those phases would have been no-ops — i.e. the
    /// insertion queue is empty (queued departures retry every step)
    /// and no scheduled departure comes due inside the chunk.  The
    /// prospective step times replicate the f32 `time_s += dt`
    /// accumulation, so the due-time comparison is the very one
    /// sequential stepping would make.
    fn fusible_steps(&self, cap: usize) -> usize {
        if cap <= 1 || !self.insertion_queue.is_empty() {
            return 1;
        }
        let Some(dep) = self.routes.departures.get(self.next_departure) else {
            return cap; // demand exhausted: free run to the cap
        };
        let mut t = self.time_s;
        let mut k = 1;
        while k < cap {
            t += self.scenario.dt_s; // start time of step k, as accumulated
            if dep.time_s <= t {
                break;
            }
            k += 1;
        }
        k
    }

    /// Advance `n` steps, appending per-step observables to `out` —
    /// the chunked replacement for `n` × [`Self::step`] (bit-identical
    /// history; asserted by `chunked_run_equals_stepwise` below).
    ///
    /// Each iteration runs the pending insertion phase, computes the
    /// departure-free run length, clamps it to the stepper's fused-chunk
    /// ladder (largest rung first) and the sim's [`Self::chunk_limit`],
    /// and hands the stepper the whole chunk at once.  With the HLO
    /// stepper that is ONE PJRT dispatch per chunk instead of one per
    /// step — the last per-step host synchronization on the hot loop.
    pub fn step_many(&mut self, n: u64, out: &mut Vec<StepObs>) {
        let mut remaining = n;
        while remaining > 0 {
            self.insert_due();
            let cap = self
                .chunk_limit
                .min(usize::try_from(remaining).unwrap_or(usize::MAX));
            let fusible = self.fusible_steps(cap);
            let k = self
                .stepper
                .chunk_ladder()
                .iter()
                .copied()
                .find(|&k| k <= fusible)
                .unwrap_or(1)
                .max(1);
            let start = out.len();
            if k <= 1 {
                out.push(self.stepper.step(&mut self.traffic));
            } else {
                self.stepper.step_many(&mut self.traffic, k, out);
            }
            let produced = out.len() - start;
            for &obs in &out[start..] {
                self.account(obs);
            }
            remaining -= produced as u64;
        }
    }

    /// Run until `horizon_s` sim-seconds, collecting per-step
    /// observables (chunk-scheduled; see [`Self::step_many`]).
    pub fn run(&mut self, horizon_s: f32) -> Result<Vec<StepObs>> {
        let steps = steps_for(horizon_s, self.scenario.dt_s);
        let mut out = Vec::with_capacity(steps as usize);
        self.step_many(steps, &mut out);
        Ok(out)
    }

    /// Has every scheduled departure been inserted and retired?
    pub fn drained(&self) -> bool {
        self.next_departure >= self.routes.departures.len()
            && self.insertion_queue.is_empty()
            && self.traffic.active_count() == 0
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::sumo::duarouter::duarouter;
    use crate::sumo::flow::FlowFile;
    use crate::sumo::idm::NativeIdmStepper;

    fn sim(horizon: f32, seed: u64) -> SumoSim {
        let scenario = MergeScenario::default();
        let net = scenario.network();
        let flows = FlowFile::merge_sample(1200.0, 300.0, horizon);
        let routes = duarouter(&net, &flows, seed).unwrap();
        SumoSim::new(scenario, 64, routes, Box::new(NativeIdmStepper::default()))
    }

    #[test]
    fn vehicles_spawn_and_flow() {
        let mut s = sim(120.0, 3);
        s.run(200.0).unwrap();
        assert!(s.total_spawned > 10, "spawned {}", s.total_spawned);
        assert!(s.total_flow > 0.0, "some vehicles reached the end");
    }

    #[test]
    fn ramp_traffic_merges() {
        let mut s = sim(120.0, 4);
        s.run(200.0).unwrap();
        assert!(s.total_merged > 0.0, "CAV ramp flow must merge");
    }

    #[test]
    fn insertion_respects_clearance() {
        let scenario = MergeScenario::default();
        let net = scenario.network();
        // absurd demand: 36000 vph → most insertions must queue, none
        // may overlap
        let flows = FlowFile::merge_sample(36000.0, 0.0, 10.0);
        let routes = duarouter(&net, &flows, 5).unwrap();
        let mut s = SumoSim::new(scenario, 256, routes, Box::new(NativeIdmStepper::default()));
        for _ in 0..100 {
            s.step();
        }
        // no two active vehicles on the same lane within 2 m
        let t = &s.traffic;
        for i in 0..t.capacity() {
            for j in (i + 1)..t.capacity() {
                if t.is_active(i) && t.is_active(j) && (t.lane(i) - t.lane(j)).abs() < 0.5 {
                    assert!(
                        (t.x(i) - t.x(j)).abs() > 1.0,
                        "vehicles {i} and {j} overlap at {}",
                        t.x(i)
                    );
                }
            }
        }
    }

    #[test]
    fn deterministic_given_seed() {
        let mut a = sim(60.0, 9);
        let mut b = sim(60.0, 9);
        a.run(100.0).unwrap();
        b.run(100.0).unwrap();
        assert_eq!(a.traffic, b.traffic);
        assert_eq!(a.total_flow, b.total_flow);
    }

    #[test]
    fn drains_after_horizon() {
        let mut s = sim(30.0, 11);
        s.run(400.0).unwrap();
        assert!(s.drained(), "active={} queued={}", s.traffic.active_count(), s.insertion_queue.len());
    }

    #[test]
    fn clock_advances_by_dt() {
        let mut s = sim(10.0, 1);
        s.step();
        assert!((s.time_s() - 0.1).abs() < 1e-6);
        assert_eq!(s.step_count(), 1);
    }

    #[test]
    fn steps_for_is_the_single_rounding() {
        assert_eq!(steps_for(200.0, 0.1), 2000);
        assert_eq!(steps_for(30.0, 0.1), 300);
        // the drift case: 0.3 / 0.1 in f32 is 2.9999998 — round, don't
        // truncate, so planner and runtime agree on 3
        assert_eq!(steps_for(0.3, 0.1), 3);
        assert_eq!(steps_for(0.0, 0.1), 0);
        // degenerate dt is clamped rather than dividing by zero
        assert!(steps_for(1.0, 0.0) > 0);
    }

    /// A native stepper that ADVERTISES a fused-chunk ladder but
    /// executes chunks with the trait's default sequential loop — which
    /// is exactly the bit-exactness contract `Stepper::step_many`
    /// demands of real fused implementations.  Driving `SumoSim`
    /// through it exercises every chunk-scheduler path (run-length
    /// computation, ladder clamping, queue/departure barriers) with no
    /// artifacts needed.
    struct LadderedNative {
        inner: NativeIdmStepper,
        ladder: Vec<usize>,
    }

    impl Stepper for LadderedNative {
        fn step(&mut self, traffic: &mut Traffic) -> StepObs {
            self.inner.step(traffic)
        }

        fn chunk_ladder(&self) -> &[usize] {
            &self.ladder
        }

        fn name(&self) -> &'static str {
            "laddered-native"
        }
    }

    fn laddered_sim(horizon: f32, seed: u64, ladder: Vec<usize>) -> SumoSim {
        let scenario = MergeScenario::default();
        let net = scenario.network();
        let flows = FlowFile::merge_sample(1200.0, 300.0, horizon);
        let routes = duarouter(&net, &flows, seed).unwrap();
        SumoSim::new(
            scenario,
            64,
            routes,
            Box::new(LadderedNative {
                inner: NativeIdmStepper::default(),
                ladder,
            }),
        )
    }

    /// THE chunk-scheduler guarantee: a chunked run produces the
    /// bit-identical per-step history, totals, clock and final traffic
    /// state as step-by-step execution — departures, queued insertions
    /// and retirements included.
    #[test]
    fn chunked_run_equals_stepwise() {
        for seed in [3u64, 9, 27] {
            let mut chunked = laddered_sim(120.0, seed, vec![32, 8, 1]);
            let mut stepwise = laddered_sim(120.0, seed, vec![1]);
            let h_chunked = chunked.run(200.0).unwrap();
            let mut h_stepwise = Vec::new();
            for _ in 0..steps_for(200.0, 0.1) {
                h_stepwise.push(stepwise.step());
            }
            assert_eq!(h_chunked, h_stepwise, "seed {seed}: histories diverged");
            assert_eq!(chunked.traffic, stepwise.traffic, "seed {seed}");
            assert_eq!(chunked.total_flow, stepwise.total_flow);
            assert_eq!(chunked.total_merged, stepwise.total_merged);
            assert_eq!(chunked.total_exited, stepwise.total_exited);
            assert_eq!(chunked.total_spawned, stepwise.total_spawned);
            assert_eq!(chunked.step_count(), stepwise.step_count());
            assert_eq!(chunked.time_s().to_bits(), stepwise.time_s().to_bits());
        }
    }

    /// Saturated demand keeps the insertion queue busy — every step's
    /// insertion phase has work, so chunks must degenerate to K=1 and
    /// still match stepwise execution exactly.
    #[test]
    fn chunked_respects_insertion_queue_barrier() {
        let scenario = MergeScenario::default();
        let net = scenario.network();
        let flows = FlowFile::merge_sample(36000.0, 0.0, 10.0);
        let mk = |ladder: Vec<usize>| {
            SumoSim::new(
                scenario,
                256,
                duarouter(&net, &flows, 5).unwrap(),
                Box::new(LadderedNative {
                    inner: NativeIdmStepper::default(),
                    ladder,
                }),
            )
        };
        let mut chunked = mk(vec![32, 8, 1]);
        let mut stepwise = mk(vec![1]);
        let mut h_chunked = Vec::new();
        chunked.step_many(150, &mut h_chunked);
        let h_stepwise: Vec<StepObs> = (0..150).map(|_| stepwise.step()).collect();
        assert_eq!(h_chunked, h_stepwise);
        assert_eq!(chunked.traffic, stepwise.traffic);
        assert_eq!(chunked.total_spawned, stepwise.total_spawned);
    }

    #[test]
    fn chunk_limit_forces_step_by_step() {
        let mut s = laddered_sim(60.0, 4, vec![32, 8, 1]);
        s.set_chunk_limit(1);
        assert_eq!(s.chunk_limit(), 1);
        // with the limit at 1 the fusible window is never consulted;
        // semantics must still match an unlimited chunked run exactly
        let mut unlimited = laddered_sim(60.0, 4, vec![32, 8, 1]);
        let a = s.run(100.0).unwrap();
        let b = unlimited.run(100.0).unwrap();
        assert_eq!(a, b);
        assert_eq!(s.traffic, unlimited.traffic);
    }

    #[test]
    fn fusible_window_stops_at_next_departure() {
        // a single sparse flow: after the first step the next scheduled
        // departure bounds the fusible window at exactly the number of
        // accumulated-dt steps until it comes due
        let scenario = MergeScenario::default();
        let net = scenario.network();
        let mut flows = FlowFile::merge_sample(1200.0, 0.0, 1.0);
        flows.flows.truncate(1);
        let routes = duarouter(&net, &flows, 1).unwrap();
        let mut s = SumoSim::new(scenario, 64, routes, Box::new(NativeIdmStepper::default()));
        // skip any t=0 departures so the queue is empty
        s.step();
        if let Some(next) = s.routes.departures.get(s.next_departure) {
            let window = s.fusible_steps(1000);
            let dt = s.scenario.dt_s;
            // replay the accumulation the scheduler does
            let mut t = s.time_s();
            let mut k = 1;
            while k < 1000 {
                t += dt;
                if next.time_s <= t {
                    break;
                }
                k += 1;
            }
            assert_eq!(window, k);
            assert!(window < 1000, "a pending departure must bound the window");
        }
    }
}
