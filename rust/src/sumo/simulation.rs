//! The microsimulation loop: demand insertion + physics stepping +
//! observables.  This is what a TraCI server fronts.

use crate::Result;

use super::duarouter::{Departure, RouteFile};
use super::network::MergeScenario;
use super::state::{DriverParams, Traffic};

/// Departure-table row width of the schema-5 whole-run artifacts
/// (`model.py DEP_COLUMNS`): the epoch step index, the spawn state
/// `[x, v, lane]`, then the eight driver-params columns.
pub const DEP_COLS: usize = 12;
/// Epoch step index at which the row becomes due (compared `<=` against
/// the in-kernel step counter, exactly like `insert_due`'s clock test).
pub const D_STEP: usize = 0;
pub const D_X: usize = 1;
pub const D_V: usize = 2;
pub const D_LANE: usize = 3;
/// First of the eight params columns (`v0..exit_flag`, state-layout
/// order).
pub const D_PARAMS: usize = 4;
/// Epoch stamped on padding rows: 2^30 is exactly representable in f32
/// and beyond any real step count, so padded rows never come due.
pub const DEP_PAD_EPOCH: f32 = (1u32 << 30) as f32;

/// The step index at which each departure becomes due — THE epoch
/// derivation, shared by the compiled departure table and the host
/// scheduler's bit-exactness tests.  A departure is due at the start of
/// step `s` iff `dep.time_s <= t_s`, where `t_0 = 0` and the clock
/// advances by the same f32 `t += dt` accumulation [`SumoSim::account`]
/// performs — NOT `(time_s / dt).ceil()`, which disagrees with the
/// accumulated clock on representation error and would desynchronize
/// in-kernel insertion from host [`SumoSim::insert_due`] replay.
/// Departures not due within `max_steps` map to `u64::MAX`.  Expects
/// `departures` sorted by `time_s` (what `duarouter` emits).
pub fn departure_epochs(departures: &[Departure], dt_s: f32, max_steps: u64) -> Vec<u64> {
    let mut epochs = vec![u64::MAX; departures.len()];
    let mut next = 0;
    let mut t = 0.0f32;
    for s in 0..max_steps {
        while next < departures.len() && departures[next].time_s <= t {
            epochs[next] = s;
            next += 1;
        }
        if next == departures.len() {
            break;
        }
        t += dt_s;
    }
    epochs
}

/// A compiled-in demand schedule: the `f32[D, DEP_COLS]` operand of the
/// schema-5 whole-run artifacts.  Rows are real departures (epoch
/// ascending, table order = departure order) up to `count`; the rest is
/// padding with [`DEP_PAD_EPOCH`] epochs that never come due.
#[derive(Debug, Clone, PartialEq)]
pub struct DepartureTable {
    /// Flattened row-major `capacity x DEP_COLS`.
    pub rows: Vec<f32>,
    /// Real (non-padding) rows.
    pub count: usize,
    /// Table capacity `D` (the artifact's lowered row count).
    pub capacity: usize,
}

impl DepartureTable {
    /// Build the table for a `t_steps`-step run: every departure due
    /// within the run (epoch `<= t_steps - 1`) becomes a row; later
    /// departures stay host-side for the chunked tail.  `None` when the
    /// due rows exceed `capacity` — the caller falls back to chunking.
    pub fn build(
        departures: &[Departure],
        dt_s: f32,
        t_steps: u64,
        capacity: usize,
    ) -> Option<DepartureTable> {
        let epochs = departure_epochs(departures, dt_s, t_steps);
        let count = epochs.iter().take_while(|&&e| e != u64::MAX).count();
        if count > capacity {
            return None;
        }
        let mut rows = vec![0.0f32; capacity * DEP_COLS];
        for (i, (d, &epoch)) in departures.iter().zip(&epochs).take(count).enumerate() {
            let row = &mut rows[i * DEP_COLS..(i + 1) * DEP_COLS];
            row[D_STEP] = epoch as f32;
            row[D_X] = d.pos_m;
            row[D_V] = d.speed;
            row[D_LANE] = d.lane as f32;
            row[D_PARAMS..].copy_from_slice(&[
                d.params.v0,
                d.params.t_headway,
                d.params.a_max,
                d.params.b_comf,
                d.params.s0,
                d.params.length,
                d.params.exit_pos,
                d.params.exit_flag,
            ]);
        }
        for i in count..capacity {
            rows[i * DEP_COLS + D_STEP] = DEP_PAD_EPOCH;
        }
        Some(DepartureTable {
            rows,
            count,
            capacity,
        })
    }
}

/// Per-step observables — mirrors the `obs` output of the AOT step
/// (`[n_active, mean_speed, flow, n_merged, n_exited]`).  `flow` counts
/// road-end completions only; `n_exited` counts off-ramp completions
/// (vehicles crossing their own `exit_pos`), so ramp-weave throughput
/// is not under-reported in aggregates.
#[derive(Debug, Clone, Copy, PartialEq, Default)]
pub struct StepObs {
    pub n_active: f32,
    pub mean_speed: f32,
    pub flow: f32,
    pub n_merged: f32,
    pub n_exited: f32,
}

/// The number of DT steps covering `horizon_s` — THE step-count
/// derivation, shared by every site that turns a horizon into steps
/// (`SumoSim::run`, the launcher's walltime guard, CLI/example step
/// budgets).  Each site used to round independently (`round` here,
/// `ceil` there, `* 10.0` hardcoded elsewhere), which could drift by a
/// step between planner and runtime; one helper means one rounding.
pub fn steps_for(horizon_s: f32, dt_s: f32) -> u64 {
    (horizon_s / dt_s.max(1e-6)).round().max(0.0) as u64
}

/// A physics engine advancing the traffic state by one DT.
/// Implementations: [`super::NativeIdmStepper`] (pure rust) and
/// `runtime::HloStepper` (the AOT JAX/Pallas artifact via PJRT).
pub trait Stepper: Send {
    fn step(&mut self, traffic: &mut Traffic) -> StepObs;

    /// The fused-chunk sizes this stepper can execute in ONE dispatch,
    /// descending and always ending in 1.  The default — no fusion —
    /// suits steppers with no per-step dispatch overhead (the native
    /// ones); `HloStepper` advertises the artifact manifest's rollout
    /// K ladder so the [`SumoSim`] chunk scheduler can amortize one
    /// PJRT dispatch over a whole run of departure-free steps.
    fn chunk_ladder(&self) -> &[usize] {
        &[1]
    }

    /// Advance `k` steps (a ladder rung), appending one [`StepObs`] per
    /// step — required to be bit-identical to `k` [`Stepper::step`]
    /// calls.  The default executes them sequentially; fused
    /// implementations override with a single dispatch.
    fn step_many(&mut self, traffic: &mut Traffic, k: usize, out: &mut Vec<StepObs>) {
        for _ in 0..k {
            out.push(self.step(traffic));
        }
    }

    /// The whole-run total-steps ladder this stepper can execute as ONE
    /// device-resident dispatch (ascending, schema-5 artifacts; empty =
    /// no whole-run path and [`Self::run_resident`] is never called).
    fn run_ladder(&self) -> &[usize] {
        &[]
    }

    /// Departure-table row capacity of the whole-run entries (0 = no
    /// whole-run path).  Schedules with more due rows fall back to the
    /// chunk scheduler.
    fn run_table_rows(&self) -> usize {
        0
    }

    /// Execute a whole `t_steps`-step run as one dispatch — demand
    /// compiled in from `table`, insertion happening in-kernel —
    /// appending `t_steps` per-step observables and returning the
    /// per-real-row inserted mask (so the host can reconstruct its
    /// insertion queue for the tail).  Required to be bit-identical to
    /// `t_steps` iterations of insert-due-then-step.
    fn run_resident(
        &mut self,
        _traffic: &mut Traffic,
        _table: &DepartureTable,
        _t_steps: usize,
        _out: &mut Vec<StepObs>,
    ) -> Result<Vec<bool>> {
        Err(crate::Error::Runtime(
            "stepper has no whole-run entry points".into(),
        ))
    }

    /// Engine label for logs/benches.
    fn name(&self) -> &'static str {
        "stepper"
    }
}

/// The simulation: routes in, trajectories out.
pub struct SumoSim {
    pub scenario: MergeScenario,
    pub traffic: Traffic,
    stepper: Box<dyn Stepper>,
    routes: RouteFile,
    next_departure: usize,
    /// Departures that found no free slot and wait for one (SUMO's
    /// insertion queue).
    insertion_queue: Vec<usize>,
    /// Cap on the fused-chunk size the scheduler may hand the stepper
    /// (`usize::MAX` = whatever the stepper's ladder allows; 1 =
    /// step-by-step, e.g. TraCI-attached live-GUI runs).
    chunk_limit: usize,
    time_s: f32,
    step_count: u64,
    /// Totals since start.
    pub total_flow: f32,
    pub total_merged: f32,
    /// Off-ramp completions (exit-flagged vehicles that crossed their
    /// own `exit_pos`) — throughput invisible to `total_flow`.
    pub total_exited: f32,
    pub total_spawned: u64,
    /// Steps executed on the device-resident whole-run path (provenance:
    /// 0 = every step went through the host chunk scheduler).
    resident_steps: u64,
}

impl SumoSim {
    pub fn new(
        scenario: MergeScenario,
        capacity: usize,
        routes: RouteFile,
        stepper: Box<dyn Stepper>,
    ) -> Self {
        SumoSim {
            scenario,
            traffic: Traffic::new(capacity),
            stepper,
            routes,
            next_departure: 0,
            insertion_queue: Vec::new(),
            chunk_limit: usize::MAX,
            time_s: 0.0,
            step_count: 0,
            total_flow: 0.0,
            total_merged: 0.0,
            total_exited: 0.0,
            total_spawned: 0,
            resident_steps: 0,
        }
    }

    pub fn time_s(&self) -> f32 {
        self.time_s
    }

    pub fn step_count(&self) -> u64 {
        self.step_count
    }

    /// Steps executed as device-resident whole-run dispatches — dataset
    /// provenance, surfaced over TraCI so the launcher can record which
    /// path produced a run (0 = chunk scheduler / native throughout).
    pub fn resident_steps(&self) -> u64 {
        self.resident_steps
    }

    fn try_insert(&mut self, dep_idx: usize) -> bool {
        let d = &self.routes.departures[dep_idx];
        // SUMO refuses insertion on top of another vehicle: require the
        // insertion point clear by s0 + length.
        let clearance = d.params.s0 + d.params.length;
        for i in 0..self.traffic.capacity() {
            if self.traffic.is_active(i)
                && (self.traffic.lane(i) - d.lane as f32).abs() < 0.5
                && (self.traffic.x(i) - d.pos_m).abs() < clearance
            {
                return false;
            }
        }
        let p = DriverParams { ..d.params };
        self.traffic
            .spawn(d.pos_m, d.speed, d.lane as f32, p)
            .is_some()
    }

    /// Cap fused chunks at `k` physics steps per dispatch (validated
    /// against the stepper's ladder by the launcher; 1 = step-by-step,
    /// what TraCI-attached live-GUI runs force so frame streaming never
    /// starves behind a 32-step chunk).
    pub fn set_chunk_limit(&mut self, k: usize) {
        self.chunk_limit = k.max(1);
    }

    pub fn chunk_limit(&self) -> usize {
        self.chunk_limit
    }

    /// The insertion phase of one step: retry queued departures, then
    /// insert newly due ones (shared by [`Self::step`] and the chunk
    /// scheduler — a fused chunk runs it once, for its first step).
    fn insert_due(&mut self) {
        // retry earlier blocked insertions first, compacting the queue
        // in place (keeps order, allocates nothing on the per-step path)
        let mut kept = 0;
        for k in 0..self.insertion_queue.len() {
            let dep = self.insertion_queue[k];
            if self.try_insert(dep) {
                self.total_spawned += 1;
            } else {
                self.insertion_queue[kept] = dep;
                kept += 1;
            }
        }
        self.insertion_queue.truncate(kept);

        // newly due departures
        while self.next_departure < self.routes.departures.len()
            && self.routes.departures[self.next_departure].time_s <= self.time_s
        {
            let idx = self.next_departure;
            self.next_departure += 1;
            if self.try_insert(idx) {
                self.total_spawned += 1;
            } else {
                self.insertion_queue.push(idx);
            }
        }
    }

    /// Per-step bookkeeping after the physics (totals, clock, counter).
    fn account(&mut self, obs: StepObs) {
        self.total_flow += obs.flow;
        self.total_merged += obs.n_merged;
        self.total_exited += obs.n_exited;
        self.time_s += self.scenario.dt_s;
        self.step_count += 1;
    }

    /// Advance one DT: insert due departures, then step physics.
    pub fn step(&mut self) -> StepObs {
        self.insert_due();
        let obs = self.stepper.step(&mut self.traffic);
        self.account(obs);
        obs
    }

    /// How many steps (<= `cap`) may run as ONE fused chunk from here:
    /// the run length until the next step whose insertion phase has
    /// work to do.  A fused chunk replays steps `1..k` without their
    /// insertion phases, so it is bit-identical to sequential stepping
    /// exactly when those phases would have been no-ops — i.e. the
    /// insertion queue is empty (queued departures retry every step)
    /// and no scheduled departure comes due inside the chunk.  The
    /// prospective step times replicate the f32 `time_s += dt`
    /// accumulation, so the due-time comparison is the very one
    /// sequential stepping would make.
    fn fusible_steps(&self, cap: usize) -> usize {
        if cap <= 1 || !self.insertion_queue.is_empty() {
            return 1;
        }
        let Some(dep) = self.routes.departures.get(self.next_departure) else {
            return cap; // demand exhausted: free run to the cap
        };
        let mut t = self.time_s;
        let mut k = 1;
        while k < cap {
            t += self.scenario.dt_s; // start time of step k, as accumulated
            if dep.time_s <= t {
                break;
            }
            k += 1;
        }
        k
    }

    /// Advance `n` steps, appending per-step observables to `out` —
    /// the chunked replacement for `n` × [`Self::step`] (bit-identical
    /// history; asserted by `chunked_run_equals_stepwise` below).
    ///
    /// Each iteration runs the pending insertion phase, computes the
    /// departure-free run length, clamps it to the stepper's fused-chunk
    /// ladder (largest rung first) and the sim's [`Self::chunk_limit`],
    /// and hands the stepper the whole chunk at once.  With the HLO
    /// stepper that is ONE PJRT dispatch per chunk instead of one per
    /// step — the last per-step host synchronization on the hot loop.
    pub fn step_many(&mut self, n: u64, out: &mut Vec<StepObs>) {
        let mut remaining = n;
        remaining -= self.try_run_resident(remaining, out);
        while remaining > 0 {
            self.insert_due();
            let cap = self
                .chunk_limit
                .min(usize::try_from(remaining).unwrap_or(usize::MAX));
            let fusible = self.fusible_steps(cap);
            let k = self
                .stepper
                .chunk_ladder()
                .iter()
                .copied()
                .find(|&k| k <= fusible)
                .unwrap_or(1)
                .max(1);
            let start = out.len();
            if k <= 1 {
                out.push(self.stepper.step(&mut self.traffic));
            } else {
                self.stepper.step_many(&mut self.traffic, k, out);
            }
            let produced = out.len() - start;
            for &obs in &out[start..] {
                self.account(obs);
            }
            remaining -= produced as u64;
        }
    }

    /// The device-resident fast path: when this sim is at its pristine
    /// start and the stepper lowers whole-run entries, execute the
    /// largest run-ladder rung `T <= min(n, chunk_limit)` whose due
    /// departures fit the compiled table as ONE dispatch — skipping the
    /// host chunk scheduler (and its per-chunk state ferrying) for those
    /// `T` steps entirely.  Returns the steps consumed (0 = path not
    /// taken; the caller falls through to PR-5 chunking for everything
    /// not consumed, including the `n - T` tail of longer bursts).
    ///
    /// Insertion happens in-kernel from the same f32 epoch chain
    /// [`Self::insert_due`] replays ([`departure_epochs`]), and the
    /// returned inserted mask reconstructs the host scheduler's exact
    /// post-run demand state: `next_departure` advances past every due
    /// row, un-inserted due rows re-queue in departure order (the order
    /// the host queue preserves).  Any dispatch error falls back to
    /// chunking with the sim state untouched.
    fn try_run_resident(&mut self, n: u64, out: &mut Vec<StepObs>) -> u64 {
        let fresh = self.step_count == 0 && self.next_departure == 0
            && self.insertion_queue.is_empty();
        let table_rows = self.stepper.run_table_rows();
        if !fresh || table_rows == 0 {
            return 0;
        }
        let cap = self.chunk_limit.min(usize::try_from(n).unwrap_or(usize::MAX));
        let ladder: Vec<usize> = self.stepper.run_ladder().to_vec();
        for &t_steps in ladder.iter().rev() {
            if t_steps > cap || t_steps == 0 {
                continue;
            }
            let Some(table) = DepartureTable::build(
                &self.routes.departures,
                self.scenario.dt_s,
                t_steps as u64,
                table_rows,
            ) else {
                continue; // too much due demand for the lowered table
            };
            let start = out.len();
            let inserted = match self.stepper.run_resident(
                &mut self.traffic,
                &table,
                t_steps,
                out,
            ) {
                Ok(mask) => mask,
                Err(_) => {
                    out.truncate(start);
                    return 0; // dispatch failed: chunk scheduler takes over
                }
            };
            self.next_departure = table.count;
            self.insertion_queue.extend(
                inserted
                    .iter()
                    .enumerate()
                    .filter(|(_, &ok)| !ok)
                    .map(|(i, _)| i),
            );
            self.total_spawned += inserted.iter().filter(|&&ok| ok).count() as u64;
            for i in start..out.len() {
                self.account(out[i]);
            }
            self.resident_steps += t_steps as u64;
            return t_steps as u64;
        }
        0
    }

    /// Run until `horizon_s` sim-seconds, collecting per-step
    /// observables (chunk-scheduled; see [`Self::step_many`]).
    pub fn run(&mut self, horizon_s: f32) -> Result<Vec<StepObs>> {
        let steps = steps_for(horizon_s, self.scenario.dt_s);
        let mut out = Vec::with_capacity(steps as usize);
        self.step_many(steps, &mut out);
        Ok(out)
    }

    /// Has every scheduled departure been inserted and retired?
    pub fn drained(&self) -> bool {
        self.next_departure >= self.routes.departures.len()
            && self.insertion_queue.is_empty()
            && self.traffic.active_count() == 0
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::sumo::duarouter::duarouter;
    use crate::sumo::flow::FlowFile;
    use crate::sumo::idm::NativeIdmStepper;

    fn sim(horizon: f32, seed: u64) -> SumoSim {
        let scenario = MergeScenario::default();
        let net = scenario.network();
        let flows = FlowFile::merge_sample(1200.0, 300.0, horizon);
        let routes = duarouter(&net, &flows, seed).unwrap();
        SumoSim::new(scenario, 64, routes, Box::new(NativeIdmStepper::default()))
    }

    #[test]
    fn vehicles_spawn_and_flow() {
        let mut s = sim(120.0, 3);
        s.run(200.0).unwrap();
        assert!(s.total_spawned > 10, "spawned {}", s.total_spawned);
        assert!(s.total_flow > 0.0, "some vehicles reached the end");
    }

    #[test]
    fn ramp_traffic_merges() {
        let mut s = sim(120.0, 4);
        s.run(200.0).unwrap();
        assert!(s.total_merged > 0.0, "CAV ramp flow must merge");
    }

    #[test]
    fn insertion_respects_clearance() {
        let scenario = MergeScenario::default();
        let net = scenario.network();
        // absurd demand: 36000 vph → most insertions must queue, none
        // may overlap
        let flows = FlowFile::merge_sample(36000.0, 0.0, 10.0);
        let routes = duarouter(&net, &flows, 5).unwrap();
        let mut s = SumoSim::new(scenario, 256, routes, Box::new(NativeIdmStepper::default()));
        for _ in 0..100 {
            s.step();
        }
        // no two active vehicles on the same lane within 2 m
        let t = &s.traffic;
        for i in 0..t.capacity() {
            for j in (i + 1)..t.capacity() {
                if t.is_active(i) && t.is_active(j) && (t.lane(i) - t.lane(j)).abs() < 0.5 {
                    assert!(
                        (t.x(i) - t.x(j)).abs() > 1.0,
                        "vehicles {i} and {j} overlap at {}",
                        t.x(i)
                    );
                }
            }
        }
    }

    #[test]
    fn deterministic_given_seed() {
        let mut a = sim(60.0, 9);
        let mut b = sim(60.0, 9);
        a.run(100.0).unwrap();
        b.run(100.0).unwrap();
        assert_eq!(a.traffic, b.traffic);
        assert_eq!(a.total_flow, b.total_flow);
    }

    #[test]
    fn drains_after_horizon() {
        let mut s = sim(30.0, 11);
        s.run(400.0).unwrap();
        assert!(s.drained(), "active={} queued={}", s.traffic.active_count(), s.insertion_queue.len());
    }

    #[test]
    fn clock_advances_by_dt() {
        let mut s = sim(10.0, 1);
        s.step();
        assert!((s.time_s() - 0.1).abs() < 1e-6);
        assert_eq!(s.step_count(), 1);
    }

    #[test]
    fn steps_for_is_the_single_rounding() {
        assert_eq!(steps_for(200.0, 0.1), 2000);
        assert_eq!(steps_for(30.0, 0.1), 300);
        // the drift case: 0.3 / 0.1 in f32 is 2.9999998 — round, don't
        // truncate, so planner and runtime agree on 3
        assert_eq!(steps_for(0.3, 0.1), 3);
        assert_eq!(steps_for(0.0, 0.1), 0);
        // degenerate dt is clamped rather than dividing by zero
        assert!(steps_for(1.0, 0.0) > 0);
    }

    /// A native stepper that ADVERTISES a fused-chunk ladder but
    /// executes chunks with the trait's default sequential loop — which
    /// is exactly the bit-exactness contract `Stepper::step_many`
    /// demands of real fused implementations.  Driving `SumoSim`
    /// through it exercises every chunk-scheduler path (run-length
    /// computation, ladder clamping, queue/departure barriers) with no
    /// artifacts needed.
    struct LadderedNative {
        inner: NativeIdmStepper,
        ladder: Vec<usize>,
    }

    impl Stepper for LadderedNative {
        fn step(&mut self, traffic: &mut Traffic) -> StepObs {
            self.inner.step(traffic)
        }

        fn chunk_ladder(&self) -> &[usize] {
            &self.ladder
        }

        fn name(&self) -> &'static str {
            "laddered-native"
        }
    }

    fn laddered_sim(horizon: f32, seed: u64, ladder: Vec<usize>) -> SumoSim {
        let scenario = MergeScenario::default();
        let net = scenario.network();
        let flows = FlowFile::merge_sample(1200.0, 300.0, horizon);
        let routes = duarouter(&net, &flows, seed).unwrap();
        SumoSim::new(
            scenario,
            64,
            routes,
            Box::new(LadderedNative {
                inner: NativeIdmStepper::default(),
                ladder,
            }),
        )
    }

    /// THE chunk-scheduler guarantee: a chunked run produces the
    /// bit-identical per-step history, totals, clock and final traffic
    /// state as step-by-step execution — departures, queued insertions
    /// and retirements included.
    #[test]
    fn chunked_run_equals_stepwise() {
        for seed in [3u64, 9, 27] {
            let mut chunked = laddered_sim(120.0, seed, vec![32, 8, 1]);
            let mut stepwise = laddered_sim(120.0, seed, vec![1]);
            let h_chunked = chunked.run(200.0).unwrap();
            let mut h_stepwise = Vec::new();
            for _ in 0..steps_for(200.0, 0.1) {
                h_stepwise.push(stepwise.step());
            }
            assert_eq!(h_chunked, h_stepwise, "seed {seed}: histories diverged");
            assert_eq!(chunked.traffic, stepwise.traffic, "seed {seed}");
            assert_eq!(chunked.total_flow, stepwise.total_flow);
            assert_eq!(chunked.total_merged, stepwise.total_merged);
            assert_eq!(chunked.total_exited, stepwise.total_exited);
            assert_eq!(chunked.total_spawned, stepwise.total_spawned);
            assert_eq!(chunked.step_count(), stepwise.step_count());
            assert_eq!(chunked.time_s().to_bits(), stepwise.time_s().to_bits());
        }
    }

    /// Saturated demand keeps the insertion queue busy — every step's
    /// insertion phase has work, so chunks must degenerate to K=1 and
    /// still match stepwise execution exactly.
    #[test]
    fn chunked_respects_insertion_queue_barrier() {
        let scenario = MergeScenario::default();
        let net = scenario.network();
        let flows = FlowFile::merge_sample(36000.0, 0.0, 10.0);
        let mk = |ladder: Vec<usize>| {
            SumoSim::new(
                scenario,
                256,
                duarouter(&net, &flows, 5).unwrap(),
                Box::new(LadderedNative {
                    inner: NativeIdmStepper::default(),
                    ladder,
                }),
            )
        };
        let mut chunked = mk(vec![32, 8, 1]);
        let mut stepwise = mk(vec![1]);
        let mut h_chunked = Vec::new();
        chunked.step_many(150, &mut h_chunked);
        let h_stepwise: Vec<StepObs> = (0..150).map(|_| stepwise.step()).collect();
        assert_eq!(h_chunked, h_stepwise);
        assert_eq!(chunked.traffic, stepwise.traffic);
        assert_eq!(chunked.total_spawned, stepwise.total_spawned);
    }

    #[test]
    fn chunk_limit_forces_step_by_step() {
        let mut s = laddered_sim(60.0, 4, vec![32, 8, 1]);
        s.set_chunk_limit(1);
        assert_eq!(s.chunk_limit(), 1);
        // with the limit at 1 the fusible window is never consulted;
        // semantics must still match an unlimited chunked run exactly
        let mut unlimited = laddered_sim(60.0, 4, vec![32, 8, 1]);
        let a = s.run(100.0).unwrap();
        let b = unlimited.run(100.0).unwrap();
        assert_eq!(a, b);
        assert_eq!(s.traffic, unlimited.traffic);
    }

    /// THE satellite-1 guard: the compiled departure table's epochs and
    /// the host scheduler's due-step decisions derive from the identical
    /// f32 accumulation chain.  Sweeps demand rates and horizons,
    /// replays a sequential host run recording the step at which each
    /// departure index actually left `next_departure`, and asserts the
    /// two schedules index-identical.  Any rounding divergence (e.g.
    /// `ceil(time/dt)` instead of the accumulated clock) breaks this on
    /// the first departure whose time sits on a representation boundary.
    #[test]
    fn departure_epochs_match_host_schedule() {
        let cases = [
            (1200.0, 300.0, 30.0),
            (1200.0, 300.0, 120.0),
            (3600.0, 900.0, 60.0),
            (600.0, 60.0, 120.0),
            (7200.0, 0.0, 45.0),
        ];
        for (seed, &(main_vph, ramp_vph, horizon)) in cases.iter().enumerate() {
            let scenario = MergeScenario::default();
            let net = scenario.network();
            let flows = FlowFile::merge_sample(main_vph, ramp_vph, horizon);
            let routes = duarouter(&net, &flows, seed as u64 + 1).unwrap();
            // run past the horizon so every departure comes due
            let max_steps = steps_for(horizon + 30.0, scenario.dt_s);
            let epochs = departure_epochs(&routes.departures, scenario.dt_s, max_steps);
            let mut s =
                SumoSim::new(scenario, 64, routes, Box::new(NativeIdmStepper::default()));
            let mut host = vec![u64::MAX; s.routes.departures.len()];
            for step in 0..max_steps {
                let before = s.next_departure;
                s.step();
                for h in &mut host[before..s.next_departure] {
                    *h = step;
                }
            }
            assert_eq!(
                epochs, host,
                "rates {main_vph}/{ramp_vph} horizon {horizon}: table and host schedules diverged"
            );
        }
    }

    #[test]
    fn departure_table_rows_and_padding() {
        let scenario = MergeScenario::default();
        let net = scenario.network();
        let flows = FlowFile::merge_sample(1200.0, 300.0, 60.0);
        let routes = duarouter(&net, &flows, 7).unwrap();
        let t_steps = steps_for(120.0, scenario.dt_s);
        let table =
            DepartureTable::build(&routes.departures, scenario.dt_s, t_steps, 256).unwrap();
        assert_eq!(table.capacity, 256);
        assert_eq!(table.count, routes.departures.len(), "all demand due within 120 s");
        assert_eq!(table.rows.len(), 256 * DEP_COLS);
        let epochs = departure_epochs(&routes.departures, scenario.dt_s, t_steps);
        for (i, d) in routes.departures.iter().enumerate() {
            let row = &table.rows[i * DEP_COLS..(i + 1) * DEP_COLS];
            assert_eq!(row[D_STEP], epochs[i] as f32);
            assert_eq!(row[D_X], d.pos_m);
            assert_eq!(row[D_V], d.speed);
            assert_eq!(row[D_LANE], d.lane as f32);
            assert_eq!(row[D_PARAMS + 4], d.params.s0);
            assert_eq!(row[D_PARAMS + 7], d.params.exit_flag);
        }
        // padding rows never come due
        for i in table.count..table.capacity {
            assert_eq!(table.rows[i * DEP_COLS + D_STEP], DEP_PAD_EPOCH);
        }
        // a table too small for the due demand refuses to build
        assert!(DepartureTable::build(&routes.departures, scenario.dt_s, t_steps, 2).is_none());
        // a short run only tables the rows due within it
        let short = DepartureTable::build(&routes.departures, scenario.dt_s, 50, 256).unwrap();
        assert!(short.count < table.count);
        assert!(short.count > 0);
    }

    /// A native stepper that ALSO implements the whole-run contract by
    /// mirroring the in-kernel insertion semantics (due-row window in
    /// table order, clearance + free-slot checks, retry via the
    /// uninserted mask) over the sequential native physics — the exact
    /// behavior `Stepper::run_resident` demands of the HLO artifact.
    /// Driving `SumoSim` through it exercises the resident fast path,
    /// its queue/next-departure reconstruction, the chunked tail, and
    /// the dispatch-error fallback with no artifacts needed.
    struct ResidentNative {
        inner: NativeIdmStepper,
        run_ladder: Vec<usize>,
        table_rows: usize,
        fail_dispatch: bool,
    }

    impl Stepper for ResidentNative {
        fn step(&mut self, traffic: &mut Traffic) -> StepObs {
            self.inner.step(traffic)
        }

        fn run_ladder(&self) -> &[usize] {
            &self.run_ladder
        }

        fn run_table_rows(&self) -> usize {
            self.table_rows
        }

        fn run_resident(
            &mut self,
            traffic: &mut Traffic,
            table: &DepartureTable,
            t_steps: usize,
            out: &mut Vec<StepObs>,
        ) -> Result<Vec<bool>> {
            if self.fail_dispatch {
                return Err(crate::Error::Runtime("injected dispatch failure".into()));
            }
            let mut inserted = vec![false; table.count];
            let mut cursor = 0;
            for step in 0..t_steps {
                let step_f = step as f32;
                for j in cursor..table.count {
                    let row = &table.rows[j * DEP_COLS..(j + 1) * DEP_COLS];
                    if row[D_STEP] > step_f || inserted[j] {
                        continue;
                    }
                    let clearance = row[D_PARAMS + 4] + row[D_PARAMS + 5];
                    let blocked = (0..traffic.capacity()).any(|i| {
                        traffic.is_active(i)
                            && (traffic.lane(i) - row[D_LANE]).abs() < 0.5
                            && (traffic.x(i) - row[D_X]).abs() < clearance
                    });
                    if blocked {
                        continue;
                    }
                    let p = DriverParams {
                        v0: row[D_PARAMS],
                        t_headway: row[D_PARAMS + 1],
                        a_max: row[D_PARAMS + 2],
                        b_comf: row[D_PARAMS + 3],
                        s0: row[D_PARAMS + 4],
                        length: row[D_PARAMS + 5],
                        exit_pos: row[D_PARAMS + 6],
                        exit_flag: row[D_PARAMS + 7],
                    };
                    if traffic.spawn(row[D_X], row[D_V], row[D_LANE], p).is_some() {
                        inserted[j] = true;
                    }
                }
                while cursor < table.count && inserted[cursor] {
                    cursor += 1;
                }
                out.push(self.inner.step(traffic));
            }
            Ok(inserted)
        }

        fn name(&self) -> &'static str {
            "resident-native"
        }
    }

    fn resident_sim(
        horizon: f32,
        seed: u64,
        run_ladder: Vec<usize>,
        table_rows: usize,
        fail_dispatch: bool,
    ) -> SumoSim {
        let scenario = MergeScenario::default();
        let net = scenario.network();
        let flows = FlowFile::merge_sample(1200.0, 300.0, horizon);
        let routes = duarouter(&net, &flows, seed).unwrap();
        SumoSim::new(
            scenario,
            64,
            routes,
            Box::new(ResidentNative {
                inner: NativeIdmStepper::default(),
                run_ladder,
                table_rows,
                fail_dispatch,
            }),
        )
    }

    /// THE whole-run guarantee at scheduler level: a run served by one
    /// resident dispatch (plus a chunked tail past the rung) produces
    /// the bit-identical history, totals, clock and final state as
    /// step-by-step execution — mid-run departures, queued insertions
    /// and retirements included.
    #[test]
    fn resident_run_equals_stepwise() {
        for seed in [3u64, 9, 27] {
            // 200-s run = 2000 steps: rung 1200 resident + 800 chunked tail
            let mut resident = resident_sim(120.0, seed, vec![200, 1200], 256, false);
            let mut stepwise = resident_sim(120.0, seed, vec![], 0, false);
            let h_resident = resident.run(200.0).unwrap();
            let mut h_stepwise = Vec::new();
            for _ in 0..steps_for(200.0, 0.1) {
                h_stepwise.push(stepwise.step());
            }
            assert_eq!(resident.resident_steps(), 1200, "seed {seed}: largest fitting rung");
            assert_eq!(stepwise.resident_steps(), 0);
            assert_eq!(h_resident, h_stepwise, "seed {seed}: histories diverged");
            assert_eq!(resident.traffic, stepwise.traffic, "seed {seed}");
            assert_eq!(resident.total_flow, stepwise.total_flow);
            assert_eq!(resident.total_merged, stepwise.total_merged);
            assert_eq!(resident.total_exited, stepwise.total_exited);
            assert_eq!(resident.total_spawned, stepwise.total_spawned);
            assert_eq!(resident.step_count(), stepwise.step_count());
            assert_eq!(resident.time_s().to_bits(), stepwise.time_s().to_bits());
        }
    }

    /// Saturated demand: due rows that found no slot must come back as
    /// the host insertion queue (in departure order) so the chunked tail
    /// retries them exactly like sequential stepping would.
    #[test]
    fn resident_run_reconstructs_insertion_queue() {
        let scenario = MergeScenario::default();
        let net = scenario.network();
        let flows = FlowFile::merge_sample(36000.0, 0.0, 10.0);
        let mk = |ladder: Vec<usize>, rows: usize| {
            SumoSim::new(
                scenario,
                256,
                duarouter(&net, &flows, 5).unwrap(),
                Box::new(ResidentNative {
                    inner: NativeIdmStepper::default(),
                    run_ladder: ladder,
                    table_rows: rows,
                    fail_dispatch: false,
                }),
            )
        };
        let mut resident = mk(vec![100], 256);
        let mut stepwise = mk(vec![], 0);
        let mut h_resident = Vec::new();
        resident.step_many(150, &mut h_resident);
        let h_stepwise: Vec<StepObs> = (0..150).map(|_| stepwise.step()).collect();
        assert_eq!(resident.resident_steps(), 100);
        assert_eq!(h_resident, h_stepwise);
        assert_eq!(resident.insertion_queue, stepwise.insertion_queue);
        assert_eq!(resident.next_departure, stepwise.next_departure);
        assert_eq!(resident.traffic, stepwise.traffic);
        assert_eq!(resident.total_spawned, stepwise.total_spawned);
    }

    /// A failed resident dispatch must leave no trace: the run falls
    /// back to the chunk scheduler and still matches stepwise exactly.
    #[test]
    fn resident_dispatch_failure_falls_back_to_chunking() {
        let mut failing = resident_sim(60.0, 4, vec![200, 1200], 256, true);
        let mut stepwise = resident_sim(60.0, 4, vec![], 0, false);
        let a = failing.run(100.0).unwrap();
        let b = stepwise.run(100.0).unwrap();
        assert_eq!(failing.resident_steps(), 0, "failed dispatch recorded no resident steps");
        assert_eq!(a, b);
        assert_eq!(failing.traffic, stepwise.traffic);
    }

    /// The fast path only engages from the pristine start, never
    /// mid-run, and an over-full table or a chunk limit below every
    /// rung disables it.
    #[test]
    fn resident_fast_path_gating() {
        // chunk_limit below the smallest rung: no resident dispatch
        let mut limited = resident_sim(60.0, 4, vec![200], 256, false);
        limited.set_chunk_limit(32);
        limited.run(100.0).unwrap();
        assert_eq!(limited.resident_steps(), 0);
        // a table too small for the due demand: no resident dispatch
        let mut tiny = resident_sim(60.0, 4, vec![200], 1, false);
        tiny.run(100.0).unwrap();
        assert_eq!(tiny.resident_steps(), 0);
        // not fresh: a stepped sim never re-enters the resident path
        let mut stepped = resident_sim(60.0, 4, vec![200], 256, false);
        stepped.step();
        let mut out = Vec::new();
        stepped.step_many(400, &mut out);
        assert_eq!(stepped.resident_steps(), 0);
        // ...and both gated runs still match stepwise exactly
        let mut stepwise = resident_sim(60.0, 4, vec![], 0, false);
        stepwise.step();
        let mut sw = Vec::new();
        stepwise.step_many(400, &mut sw);
        assert_eq!(out, sw);
        assert_eq!(stepped.traffic, stepwise.traffic);
    }

    #[test]
    fn fusible_window_stops_at_next_departure() {
        // a single sparse flow: after the first step the next scheduled
        // departure bounds the fusible window at exactly the number of
        // accumulated-dt steps until it comes due
        let scenario = MergeScenario::default();
        let net = scenario.network();
        let mut flows = FlowFile::merge_sample(1200.0, 0.0, 1.0);
        flows.flows.truncate(1);
        let routes = duarouter(&net, &flows, 1).unwrap();
        let mut s = SumoSim::new(scenario, 64, routes, Box::new(NativeIdmStepper::default()));
        // skip any t=0 departures so the queue is empty
        s.step();
        if let Some(next) = s.routes.departures.get(s.next_departure) {
            let window = s.fusible_steps(1000);
            let dt = s.scenario.dt_s;
            // replay the accumulation the scheduler does
            let mut t = s.time_s();
            let mut k = 1;
            while k < 1000 {
                t += dt;
                if next.time_s <= t {
                    break;
                }
                k += 1;
            }
            assert_eq!(window, k);
            assert!(window < 1000, "a pending departure must bound the window");
        }
    }
}
