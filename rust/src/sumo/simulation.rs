//! The microsimulation loop: demand insertion + physics stepping +
//! observables.  This is what a TraCI server fronts.

use crate::Result;

use super::duarouter::RouteFile;
use super::network::MergeScenario;
use super::state::{DriverParams, Traffic};

/// Per-step observables — mirrors the `obs` output of the AOT step
/// (`[n_active, mean_speed, flow, n_merged, n_exited]`).  `flow` counts
/// road-end completions only; `n_exited` counts off-ramp completions
/// (vehicles crossing their own `exit_pos`), so ramp-weave throughput
/// is not under-reported in aggregates.
#[derive(Debug, Clone, Copy, PartialEq, Default)]
pub struct StepObs {
    pub n_active: f32,
    pub mean_speed: f32,
    pub flow: f32,
    pub n_merged: f32,
    pub n_exited: f32,
}

/// A physics engine advancing the traffic state by one DT.
/// Implementations: [`super::NativeIdmStepper`] (pure rust) and
/// `runtime::HloStepper` (the AOT JAX/Pallas artifact via PJRT).
pub trait Stepper: Send {
    fn step(&mut self, traffic: &mut Traffic) -> StepObs;

    /// Engine label for logs/benches.
    fn name(&self) -> &'static str {
        "stepper"
    }
}

/// The simulation: routes in, trajectories out.
pub struct SumoSim {
    pub scenario: MergeScenario,
    pub traffic: Traffic,
    stepper: Box<dyn Stepper>,
    routes: RouteFile,
    next_departure: usize,
    /// Departures that found no free slot and wait for one (SUMO's
    /// insertion queue).
    insertion_queue: Vec<usize>,
    time_s: f32,
    step_count: u64,
    /// Totals since start.
    pub total_flow: f32,
    pub total_merged: f32,
    /// Off-ramp completions (exit-flagged vehicles that crossed their
    /// own `exit_pos`) — throughput invisible to `total_flow`.
    pub total_exited: f32,
    pub total_spawned: u64,
}

impl SumoSim {
    pub fn new(
        scenario: MergeScenario,
        capacity: usize,
        routes: RouteFile,
        stepper: Box<dyn Stepper>,
    ) -> Self {
        SumoSim {
            scenario,
            traffic: Traffic::new(capacity),
            stepper,
            routes,
            next_departure: 0,
            insertion_queue: Vec::new(),
            time_s: 0.0,
            step_count: 0,
            total_flow: 0.0,
            total_merged: 0.0,
            total_exited: 0.0,
            total_spawned: 0,
        }
    }

    pub fn time_s(&self) -> f32 {
        self.time_s
    }

    pub fn step_count(&self) -> u64 {
        self.step_count
    }

    fn try_insert(&mut self, dep_idx: usize) -> bool {
        let d = &self.routes.departures[dep_idx];
        // SUMO refuses insertion on top of another vehicle: require the
        // insertion point clear by s0 + length.
        let clearance = d.params.s0 + d.params.length;
        for i in 0..self.traffic.capacity() {
            if self.traffic.is_active(i)
                && (self.traffic.lane(i) - d.lane as f32).abs() < 0.5
                && (self.traffic.x(i) - d.pos_m).abs() < clearance
            {
                return false;
            }
        }
        let p = DriverParams { ..d.params };
        self.traffic
            .spawn(d.pos_m, d.speed, d.lane as f32, p)
            .is_some()
    }

    /// Advance one DT: insert due departures, then step physics.
    pub fn step(&mut self) -> StepObs {
        // retry earlier blocked insertions first, compacting the queue
        // in place (keeps order, allocates nothing on the per-step path)
        let mut kept = 0;
        for k in 0..self.insertion_queue.len() {
            let dep = self.insertion_queue[k];
            if self.try_insert(dep) {
                self.total_spawned += 1;
            } else {
                self.insertion_queue[kept] = dep;
                kept += 1;
            }
        }
        self.insertion_queue.truncate(kept);

        // newly due departures
        while self.next_departure < self.routes.departures.len()
            && self.routes.departures[self.next_departure].time_s <= self.time_s
        {
            let idx = self.next_departure;
            self.next_departure += 1;
            if self.try_insert(idx) {
                self.total_spawned += 1;
            } else {
                self.insertion_queue.push(idx);
            }
        }

        let obs = self.stepper.step(&mut self.traffic);
        self.total_flow += obs.flow;
        self.total_merged += obs.n_merged;
        self.total_exited += obs.n_exited;
        self.time_s += self.scenario.dt_s;
        self.step_count += 1;
        obs
    }

    /// Run until `horizon_s` sim-seconds, collecting per-step observables.
    pub fn run(&mut self, horizon_s: f32) -> Result<Vec<StepObs>> {
        let steps = (horizon_s / self.scenario.dt_s).round() as u64;
        let mut out = Vec::with_capacity(steps as usize);
        for _ in 0..steps {
            out.push(self.step());
        }
        Ok(out)
    }

    /// Has every scheduled departure been inserted and retired?
    pub fn drained(&self) -> bool {
        self.next_departure >= self.routes.departures.len()
            && self.insertion_queue.is_empty()
            && self.traffic.active_count() == 0
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::sumo::duarouter::duarouter;
    use crate::sumo::flow::FlowFile;
    use crate::sumo::idm::NativeIdmStepper;

    fn sim(horizon: f32, seed: u64) -> SumoSim {
        let scenario = MergeScenario::default();
        let net = scenario.network();
        let flows = FlowFile::merge_sample(1200.0, 300.0, horizon);
        let routes = duarouter(&net, &flows, seed).unwrap();
        SumoSim::new(scenario, 64, routes, Box::new(NativeIdmStepper::default()))
    }

    #[test]
    fn vehicles_spawn_and_flow() {
        let mut s = sim(120.0, 3);
        s.run(200.0).unwrap();
        assert!(s.total_spawned > 10, "spawned {}", s.total_spawned);
        assert!(s.total_flow > 0.0, "some vehicles reached the end");
    }

    #[test]
    fn ramp_traffic_merges() {
        let mut s = sim(120.0, 4);
        s.run(200.0).unwrap();
        assert!(s.total_merged > 0.0, "CAV ramp flow must merge");
    }

    #[test]
    fn insertion_respects_clearance() {
        let scenario = MergeScenario::default();
        let net = scenario.network();
        // absurd demand: 36000 vph → most insertions must queue, none
        // may overlap
        let flows = FlowFile::merge_sample(36000.0, 0.0, 10.0);
        let routes = duarouter(&net, &flows, 5).unwrap();
        let mut s = SumoSim::new(scenario, 256, routes, Box::new(NativeIdmStepper::default()));
        for _ in 0..100 {
            s.step();
        }
        // no two active vehicles on the same lane within 2 m
        let t = &s.traffic;
        for i in 0..t.capacity() {
            for j in (i + 1)..t.capacity() {
                if t.is_active(i) && t.is_active(j) && (t.lane(i) - t.lane(j)).abs() < 0.5 {
                    assert!(
                        (t.x(i) - t.x(j)).abs() > 1.0,
                        "vehicles {i} and {j} overlap at {}",
                        t.x(i)
                    );
                }
            }
        }
    }

    #[test]
    fn deterministic_given_seed() {
        let mut a = sim(60.0, 9);
        let mut b = sim(60.0, 9);
        a.run(100.0).unwrap();
        b.run(100.0).unwrap();
        assert_eq!(a.traffic, b.traffic);
        assert_eq!(a.total_flow, b.total_flow);
    }

    #[test]
    fn drains_after_horizon() {
        let mut s = sim(30.0, 11);
        s.run(400.0).unwrap();
        assert!(s.drained(), "active={} queued={}", s.traffic.active_count(), s.insertion_queue.len());
    }

    #[test]
    fn clock_advances_by_dt() {
        let mut s = sim(10.0, 1);
        s.step();
        assert!((s.time_s() - 0.1).abs() < 1e-6);
        assert_eq!(s.step_count(), 1);
    }
}
