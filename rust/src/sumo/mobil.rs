//! MOBIL-style lane-change decisions — mirrors the lane-change block of
//! `python/compile/model.py` (mandatory merge for ramp vehicles inside
//! the merge zone, discretionary changes on the mainline, and the
//! schema-3 mandatory exit-intent bias: an exit-flagged vehicle works
//! toward lane 1 whenever safe, overriding discretionary gain and never
//! changing up).

use super::idm::{idm_law, params_row, FREE_GAP};
use super::network::MergeScenario;
use super::state::{Traffic, P_EXIT_FLAG, P_LEN, P_S0};
use super::sweep::LaneIndex;

/// MOBIL tuning — constants shared with `model.py`.
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct MobilParams {
    /// Max deceleration imposed on the new follower [m/s²].
    pub safe_decel: f32,
    /// Discretionary incentive threshold [m/s²].
    pub threshold: f32,
    /// Politeness factor.
    pub politeness: f32,
}

impl Default for MobilParams {
    fn default() -> Self {
        MobilParams {
            safe_decel: 4.0,
            threshold: 0.2,
            politeness: 0.3,
        }
    }
}

/// Lead/lag situation in a hypothetical target lane.
#[derive(Debug, Clone, Copy)]
pub struct LaneGaps {
    pub lead_gap: f32,
    pub lead_v: f32,
    pub lag_gap: f32,
    pub lag_v: f32,
}

/// Mirror of `model._lane_gap_scan` for one ego and target lane.
pub fn lane_gap_scan(t: &Traffic, i: usize, target_lane: f32) -> LaneGaps {
    let xi = t.x(i);
    let mut lead_center = FREE_GAP;
    let mut lag_center = FREE_GAP;
    for j in 0..t.capacity() {
        if !t.is_active(j) || (t.lane(j) - target_lane).abs() >= 0.5 {
            continue;
        }
        let dx = t.x(j) - xi;
        if dx > 1e-6 {
            lead_center = lead_center.min(dx);
        } else if dx < -1e-6 {
            lag_center = lag_center.min(-dx);
        }
    }
    // mask-min attribute selection (tie-break identical to the model)
    let (mut lead_v, mut lead_len, mut lag_v) = (FREE_GAP, FREE_GAP, FREE_GAP);
    for j in 0..t.capacity() {
        if !t.is_active(j) || (t.lane(j) - target_lane).abs() >= 0.5 {
            continue;
        }
        let dx = t.x(j) - xi;
        if dx > 1e-6 && dx <= lead_center {
            lead_v = lead_v.min(t.v(j));
            lead_len = lead_len.min(t.param(j, P_LEN));
        } else if dx < -1e-6 && -dx <= lag_center {
            lag_v = lag_v.min(t.v(j));
        }
    }
    let lead_has = lead_center < FREE_GAP * 0.5;
    let lag_has = lag_center < FREE_GAP * 0.5;
    LaneGaps {
        lead_gap: if lead_has {
            lead_center - lead_len
        } else {
            FREE_GAP
        },
        lead_v: if lead_has { lead_v } else { t.v(i) },
        lag_gap: if lag_has {
            lag_center - t.param(i, P_LEN)
        } else {
            FREE_GAP
        },
        lag_v: if lag_has { lag_v } else { t.v(i) },
    }
}

struct Incentive {
    a_self_new: f32,
    a_lag_new: f32,
    safe: bool,
}

/// Incentive math over precomputed lane gaps — shared by the reference
/// scan path and the sorted-sweep path so both are bit-identical by
/// construction.
fn incentive_from_gaps(t: &Traffic, i: usize, g: LaneGaps, m: &MobilParams) -> Incentive {
    let p = params_row(t, i);
    let v = t.v(i);
    let a_self_new = idm_law(v, g.lead_gap, v - g.lead_v, g.lead_gap < FREE_GAP * 0.5, &p);
    // the follower's hypothetical accel if it had to follow us (the model
    // evaluates it with the *ego's* params row — mirror that exactly)
    let a_lag_new = idm_law(
        g.lag_v,
        g.lag_gap,
        g.lag_v - v,
        g.lag_gap < FREE_GAP * 0.5,
        &p,
    );
    let s0 = t.param(i, P_S0);
    let safe = g.lead_gap > s0 && g.lag_gap > s0 && a_lag_new > -m.safe_decel;
    Incentive {
        a_self_new,
        a_lag_new,
        safe,
    }
}

/// One vehicle's lane decision against the pre-step state, generic over
/// the gap provider (reference scan or sorted-sweep index).
fn decide_one<G>(
    t: &Traffic,
    i: usize,
    accel_i: f32,
    scenario: &MergeScenario,
    m: &MobilParams,
    gaps: &G,
) -> Option<f32>
where
    G: Fn(&Traffic, usize, f32) -> LaneGaps,
{
    let max_lane = scenario.num_main_lanes as f32;
    let lane = t.lane(i);
    let x = t.x(i);
    let on_ramp = (lane - MergeScenario::RAMP_LANE).abs() < 0.5;

    if on_ramp {
        // mandatory merge inside the zone, whenever safe
        let in_zone = x >= scenario.merge_start_m && x <= scenario.merge_end_m;
        if in_zone && incentive_from_gaps(t, i, gaps(t, i, 1.0), m).safe {
            return Some(1.0);
        }
        return None;
    }

    // mandatory exit-intent bias (schema 3): an exit-flagged mainline
    // vehicle works toward lane 1 whenever safe — no gain requirement,
    // and never a discretionary move away from its exit
    let tgt_down = (lane - 1.0).max(1.0);
    if t.param(i, P_EXIT_FLAG) > 0.5 {
        if tgt_down < lane - 0.5 && incentive_from_gaps(t, i, gaps(t, i, tgt_down), m).safe {
            return Some(tgt_down);
        }
        return None;
    }

    // discretionary: up first, then down (model's priority)
    let tgt_up = (lane + 1.0).min(max_lane);
    if tgt_up > lane + 0.5 {
        let inc = incentive_from_gaps(t, i, gaps(t, i, tgt_up), m);
        let gain = inc.a_self_new - accel_i - m.politeness * (-inc.a_lag_new).max(0.0);
        if inc.safe && gain > m.threshold {
            return Some(tgt_up);
        }
    }
    if tgt_down < lane - 0.5 {
        let inc = incentive_from_gaps(t, i, gaps(t, i, tgt_down), m);
        let gain = inc.a_self_new - accel_i - m.politeness * (-inc.a_lag_new).max(0.0);
        if inc.safe && gain > m.threshold {
            return Some(tgt_down);
        }
    }
    None
}

/// Decide lane changes for every vehicle against the pre-step state via
/// the O(N) reference scans.  Returns `Some(new_lane)` for changers,
/// `None` otherwise.  Allocates; oracle/test use — the hot path is
/// [`decide_all_into`].
pub fn decide_all(
    t: &Traffic,
    accel: &[f32],
    scenario: &MergeScenario,
    m: &MobilParams,
) -> Vec<Option<f32>> {
    (0..t.capacity())
        .map(|i| {
            if !t.is_active(i) {
                return None;
            }
            decide_one(t, i, accel[i], scenario, m, &lane_gap_scan)
        })
        .collect()
}

/// Decide lane changes via the sorted-sweep index, written into a reused
/// buffer.  Bit-exact with [`decide_all`]; `index` must have been
/// rebuilt from `t`.
pub fn decide_all_into(
    t: &Traffic,
    accel: &[f32],
    scenario: &MergeScenario,
    m: &MobilParams,
    index: &LaneIndex,
    out: &mut Vec<Option<f32>>,
) {
    out.clear();
    for i in 0..t.capacity() {
        if !t.is_active(i) {
            out.push(None);
            continue;
        }
        out.push(decide_one(
            t,
            i,
            accel[i],
            scenario,
            m,
            &|t: &Traffic, i: usize, lane: f32| index.lane_gaps(t, i, lane),
        ));
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::sumo::idm::idm_accel_all;
    use crate::sumo::state::DriverParams;

    fn traffic(rows: &[(f32, f32, f32)]) -> Traffic {
        let mut t = Traffic::new(rows.len());
        for &(x, v, lane) in rows {
            t.spawn(x, v, lane, DriverParams::default());
        }
        t
    }

    fn decide(t: &Traffic) -> Vec<Option<f32>> {
        let accel = idm_accel_all(t);
        decide_all(t, &accel, &MergeScenario::default(), &MobilParams::default())
    }

    #[test]
    fn ramp_vehicle_merges_into_empty_mainline() {
        let t = traffic(&[(350.0, 20.0, 0.0)]);
        assert_eq!(decide(&t)[0], Some(1.0));
    }

    #[test]
    fn ramp_vehicle_waits_outside_zone() {
        let t = traffic(&[(100.0, 20.0, 0.0)]);
        assert_eq!(decide(&t)[0], None);
    }

    #[test]
    fn merge_blocked_by_alongside_vehicle() {
        let t = traffic(&[(350.0, 20.0, 0.0), (350.4, 20.0, 1.0)]);
        assert_eq!(decide(&t)[0], None);
    }

    #[test]
    fn overtake_slow_leader() {
        // ego stuck behind a crawler in lane 1, lane 2 empty → move up
        let t = traffic(&[(100.0, 25.0, 1.0), (112.0, 2.0, 1.0)]);
        assert_eq!(decide(&t)[0], Some(2.0));
    }

    #[test]
    fn no_change_without_incentive() {
        // free road: staying put is fine
        let t = traffic(&[(100.0, 25.0, 1.0)]);
        assert_eq!(decide(&t)[0], None);
    }

    #[test]
    fn exit_intent_biases_down_without_gain() {
        // empty road: no discretionary gain anywhere, yet the flagged
        // vehicle on lane 2 must still work toward lane 1
        let mut t = Traffic::new(1);
        t.spawn(100.0, 25.0, 2.0, DriverParams::default().with_exit(900.0));
        assert_eq!(decide(&t)[0], Some(1.0));
    }

    #[test]
    fn exit_intent_never_changes_up() {
        // stuck behind a crawler: an unflagged vehicle overtakes, the
        // flagged one stays in the gore-adjacent lane
        let mut t = Traffic::new(2);
        t.spawn(100.0, 25.0, 1.0, DriverParams::default().with_exit(900.0));
        t.spawn(112.0, 2.0, 1.0, DriverParams::default());
        assert_eq!(decide(&t)[0], None);
        let plain = traffic(&[(100.0, 25.0, 1.0), (112.0, 2.0, 1.0)]);
        assert_eq!(decide(&plain)[0], Some(2.0));
    }

    #[test]
    fn exit_bias_respects_safety() {
        // a blocker alongside on lane 1 makes the down-change unsafe
        let mut t = Traffic::new(2);
        t.spawn(100.0, 25.0, 2.0, DriverParams::default().with_exit(900.0));
        t.spawn(100.4, 25.0, 1.0, DriverParams::default());
        assert_eq!(decide(&t)[0], None);
    }

    #[test]
    fn sweep_decisions_match_reference() {
        let t = traffic(&[
            (100.0, 25.0, 1.0),
            (112.0, 2.0, 1.0),
            (350.0, 20.0, 0.0),
            (350.4, 20.0, 1.0),
            (80.0, 30.0, 2.0),
        ]);
        let accel = idm_accel_all(&t);
        let (scenario, m) = (MergeScenario::default(), MobilParams::default());
        let mut idx = LaneIndex::new();
        idx.rebuild(&t);
        let mut fast = Vec::new();
        decide_all_into(&t, &accel, &scenario, &m, &idx, &mut fast);
        assert_eq!(fast, decide_all(&t, &accel, &scenario, &m));
    }

    #[test]
    fn lane_gap_scan_sees_lead_and_lag() {
        let t = traffic(&[(100.0, 20.0, 0.0), (120.0, 15.0, 1.0), (80.0, 10.0, 1.0)]);
        let g = lane_gap_scan(&t, 0, 1.0);
        assert!((g.lead_gap - (20.0 - 4.5)).abs() < 1e-4);
        assert_eq!(g.lead_v, 15.0);
        assert!((g.lag_gap - (20.0 - 4.5)).abs() < 1e-4);
        assert_eq!(g.lag_v, 10.0);
    }
}
