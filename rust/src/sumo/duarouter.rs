//! `duarouter`: seeded, randomized demand → concrete departures.
//!
//! The Appendix-B script regenerates routes before every run:
//!
//! ```text
//! duarouter --route-files sumo.flow.xml --net-file sumo.net.xml \
//!           --output-file sumo.rou.xml --randomize-flows true --seed $RANDOM
//! ```
//!
//! This is where the paper's "sources of randomization into each
//! simulation run" come from: each run draws fresh exponential headways
//! and jittered driver parameters from its seed, so a thousand runs give
//! a thousand distinct trajectories.

use crate::util::Rng64;
use crate::Result;

use super::flow::{FlowFile, VehicleType};
use super::network::Network;
use super::state::DriverParams;

/// One scheduled departure (a `<vehicle>` element of `sumo.rou.xml`).
#[derive(Debug, Clone, PartialEq)]
pub struct Departure {
    pub id: String,
    pub time_s: f32,
    pub route: Vec<String>,
    pub lane: u32,
    pub pos_m: f32,
    pub speed: f32,
    pub params: DriverParams,
    pub vtype: VehicleType,
}

/// The generated `sumo.rou.xml` content.
#[derive(Debug, Clone, PartialEq, Default)]
pub struct RouteFile {
    pub seed: u64,
    pub departures: Vec<Departure>,
}

/// Randomize flows into concrete departures. Deterministic per seed.
pub fn duarouter(net: &Network, flows: &FlowFile, seed: u64) -> Result<RouteFile> {
    let mut rng = Rng64::seed_from_u64(seed);
    let mut departures = Vec::new();

    for flow in &flows.flows {
        net.validate_route(&flow.route)?;
        if flow.vehs_per_hour <= 0.0 {
            continue;
        }
        let mean_gap_s = 3600.0 / flow.vehs_per_hour;
        let mut t = flow.begin_s;
        let mut k = 0u32;
        loop {
            // exponential headway (randomize-flows true)
            let u: f32 = rng.gen_range_f32(1e-6, 1.0);
            t += -mean_gap_s * u.ln();
            if t >= flow.end_s {
                break;
            }
            // scenario-level perturbation (flow scales) under per-driver
            // heterogeneity: ±10% on desired speed & headway
            let base = flow.base_params();
            let jig = |v: f32, r: &mut Rng64| v * (0.9 + 0.2 * r.gen_f32());
            let params = DriverParams {
                v0: jig(base.v0, &mut rng),
                t_headway: jig(base.t_headway, &mut rng),
                ..base
            };
            departures.push(Departure {
                id: format!("{}.{}", flow.id, k),
                time_s: t,
                route: flow.route.clone(),
                lane: flow.depart_lane,
                pos_m: flow.depart_pos,
                speed: flow.depart_speed,
                params,
                vtype: flow.vtype,
            });
            k += 1;
        }
    }

    departures.sort_by(|a, b| a.time_s.total_cmp(&b.time_s));
    Ok(RouteFile { seed, departures })
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::sumo::network::MergeScenario;

    fn setup() -> (Network, FlowFile) {
        (
            MergeScenario::default().network(),
            FlowFile::merge_sample(1200.0, 300.0, 600.0),
        )
    }

    #[test]
    fn deterministic_per_seed() {
        let (net, flows) = setup();
        let a = duarouter(&net, &flows, 42).unwrap();
        let b = duarouter(&net, &flows, 42).unwrap();
        assert_eq!(a, b);
    }

    #[test]
    fn different_seeds_differ() {
        // the whole point of the per-run $RANDOM seed
        let (net, flows) = setup();
        let a = duarouter(&net, &flows, 1).unwrap();
        let b = duarouter(&net, &flows, 2).unwrap();
        assert_ne!(a.departures, b.departures);
    }

    #[test]
    fn rate_roughly_matches_demand() {
        let (net, flows) = setup();
        let r = duarouter(&net, &flows, 7).unwrap();
        let expect = flows.total_expected_vehicles();
        let got = r.departures.len() as f32;
        assert!(
            (got - expect).abs() < expect * 0.35,
            "got {got}, expected ~{expect}"
        );
    }

    #[test]
    fn departures_sorted_by_time() {
        let (net, flows) = setup();
        let r = duarouter(&net, &flows, 9).unwrap();
        assert!(r
            .departures
            .windows(2)
            .all(|w| w[0].time_s <= w[1].time_s));
    }

    #[test]
    fn invalid_route_rejected() {
        let (net, mut flows) = setup();
        flows.flows[0].route = vec!["nonexistent".into()];
        assert!(duarouter(&net, &flows, 1).is_err());
    }

    #[test]
    fn flow_scales_shift_departure_params() {
        let (net, mut flows) = setup();
        for f in &mut flows.flows {
            f.v0_scale = 0.5;
        }
        let r = duarouter(&net, &flows, 3).unwrap();
        // jitter is ±10%, so every halved v0 stays well below stock
        assert!(r.departures.iter().all(|d| d.params.v0 < 30.0 * 0.5 * 1.11));
    }

    #[test]
    fn exit_intent_survives_routing_jitter() {
        let (net, mut flows) = setup();
        flows.flows[0].exit_pos_m = Some(500.0);
        let r = duarouter(&net, &flows, 3).unwrap();
        let exiting: Vec<_> = r
            .departures
            .iter()
            .filter(|d| d.id.starts_with("main_l1"))
            .collect();
        assert!(!exiting.is_empty());
        // per-driver jitter touches v0/T, never the destination columns
        assert!(exiting
            .iter()
            .all(|d| d.params.exits() && d.params.exit_pos == 500.0));
        assert!(r
            .departures
            .iter()
            .filter(|d| d.id.starts_with("ramp"))
            .all(|d| !d.params.exits()));
    }

    #[test]
    fn driver_params_are_heterogeneous() {
        let (net, flows) = setup();
        let r = duarouter(&net, &flows, 11).unwrap();
        let v0s: Vec<f32> = r.departures.iter().map(|d| d.params.v0).collect();
        let min = v0s.iter().cloned().fold(f32::INFINITY, f32::min);
        let max = v0s.iter().cloned().fold(f32::NEG_INFINITY, f32::max);
        assert!(max - min > 1.0, "v0 spread {min}..{max}");
    }
}
