//! Pure-rust IDM car-following — the native baseline stepper.
//!
//! A line-for-line port of `python/compile/model.py` (same mask-min
//! leader selection, same constants), used (a) as the baseline
//! comparator the HLO path is validated against
//! (`rust/tests/runtime_numerics.rs`), and (b) as the physics engine for
//! runs that don't need PJRT.  All math in f32 to mirror the artifact.
//!
//! Two steppers share the same integration and law:
//!
//! * [`NativeIdmStepper`] — the production stepper: neighbor queries go
//!   through the per-step sorted-sweep index ([`super::sweep::LaneIndex`],
//!   O(N log N) per step) and all per-step buffers live in reusable
//!   scratch, so steady-state stepping performs **zero heap
//!   allocations** (EXPERIMENTS.md §Perf).
//! * [`ReferenceIdmStepper`] — the O(N²) reference scans, kept as the
//!   bit-exactness oracle (`rust/tests/sweep_props.rs`) and the §Perf
//!   "before" baseline in `cargo bench --bench runtime_hotpath`.

use super::mobil::{self, MobilParams};
use super::network::MergeScenario;
use super::simulation::{StepObs, Stepper};
use super::state::{
    Traffic, PARAM_COLS, P_AMAX, P_B, P_EXIT_FLAG, P_EXIT_POS, P_LEN, P_S0, P_T, P_V0,
};
use super::sweep::LaneIndex;

/// "Infinite" gap sentinel — matches `ref.FREE_GAP`.
pub const FREE_GAP: f32 = 1.0e6;
/// Gap floor — matches `ref.MIN_GAP`.
pub const MIN_GAP: f32 = 0.5;

/// Leader scan result for one ego.
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct Leader {
    /// Bumper-to-bumper gap (FREE_GAP when none).
    pub gap: f32,
    /// Leader speed (own speed when none).
    pub v: f32,
    pub exists: bool,
}

/// Nearest active vehicle ahead on the same lane, mask-min tie-breaking
/// (smallest speed/length among co-located leaders) — mirrors
/// `ref.leader_scan_ref`.
///
/// This is the O(N) reference scan; the production stepper answers the
/// same query through [`LaneIndex::leader`], bit-exactly.
pub fn leader_scan(t: &Traffic, i: usize) -> Leader {
    let xi = t.x(i);
    let li = t.lane(i);
    let mut center = FREE_GAP;
    for j in 0..t.capacity() {
        if !t.is_active(j) {
            continue;
        }
        let dx = t.x(j) - xi;
        if dx > 1e-6 && (t.lane(j) - li).abs() < 0.5 && dx < center {
            center = dx;
        }
    }
    if center >= FREE_GAP * 0.5 {
        return Leader {
            gap: FREE_GAP,
            v: t.v(i),
            exists: false,
        };
    }
    // mask-min attribute selection among exact ties
    let mut lv = FREE_GAP;
    let mut llen = FREE_GAP;
    for j in 0..t.capacity() {
        if !t.is_active(j) {
            continue;
        }
        let dx = t.x(j) - xi;
        if dx > 1e-6 && (t.lane(j) - li).abs() < 0.5 && dx <= center {
            lv = lv.min(t.v(j));
            llen = llen.min(t.param(j, P_LEN));
        }
    }
    Leader {
        gap: center - llen,
        v: lv,
        exists: true,
    }
}

/// The IDM law — mirrors `ref.idm_accel_ref` for one vehicle.
pub fn idm_law(v: f32, gap: f32, dv: f32, has_leader: bool, p: &[f32; PARAM_COLS]) -> f32 {
    let s = gap.max(MIN_GAP);
    let v0 = p[P_V0].max(0.1);
    let a_max = p[P_AMAX].max(1e-3);
    let b = p[P_B].max(1e-3);
    let s_star = (p[P_S0] + v * p[P_T] + v * dv / (2.0 * (a_max * b).sqrt())).max(0.0);
    let free = 1.0 - (v / v0).powi(4);
    let interaction = if has_leader { (s_star / s).powi(2) } else { 0.0 };
    a_max * (free - interaction)
}

/// One vehicle's full params row (driver calibration + exit intent),
/// shared with `mobil.rs` so both read the identical layout.
pub(crate) fn params_row(t: &Traffic, i: usize) -> [f32; PARAM_COLS] {
    [
        t.param(i, P_V0),
        t.param(i, P_T),
        t.param(i, P_AMAX),
        t.param(i, P_B),
        t.param(i, P_S0),
        t.param(i, P_LEN),
        t.param(i, P_EXIT_POS),
        t.param(i, P_EXIT_FLAG),
    ]
}

/// Car-following acceleration for every vehicle (inactive → 0), via the
/// O(N²) reference scan.  Allocates; test/oracle use only — the hot path
/// is [`idm_accel_all_into`].
pub fn idm_accel_all(t: &Traffic) -> Vec<f32> {
    (0..t.capacity())
        .map(|i| {
            if !t.is_active(i) {
                return 0.0;
            }
            let l = leader_scan(t, i);
            let p = params_row(t, i);
            idm_law(t.v(i), l.gap, t.v(i) - l.v, l.exists, &p)
        })
        .collect()
}

/// Car-following acceleration for every vehicle via the sorted-sweep
/// index, written into a reused buffer.  Bit-exact with
/// [`idm_accel_all`]; `index` must have been rebuilt from `t`.
pub fn idm_accel_all_into(t: &Traffic, index: &LaneIndex, out: &mut Vec<f32>) {
    out.clear();
    for i in 0..t.capacity() {
        if !t.is_active(i) {
            out.push(0.0);
            continue;
        }
        let l = index.leader(t, i);
        let p = params_row(t, i);
        out.push(idm_law(t.v(i), l.gap, t.v(i) - l.v, l.exists, &p));
    }
}

/// Phantom-wall deceleration for ramp vehicles approaching MERGE_END —
/// mirrors `model._wall_accel`.  Exit-flagged vehicles see no wall:
/// their road continues through the off-ramp gore at `exit_pos`.
pub fn wall_accel(t: &Traffic, i: usize, scenario: &MergeScenario) -> f32 {
    let on_ramp = (t.lane(i) - MergeScenario::RAMP_LANE).abs() < 0.5
        && t.param(i, P_EXIT_FLAG) <= 0.5;
    let gap = if on_ramp {
        (scenario.merge_end_m - t.x(i)).max(MIN_GAP * 0.1)
    } else {
        FREE_GAP
    };
    let p = params_row(t, i);
    let v = t.v(i);
    // wall speed = 0 → dv = v; `model._idm_for` treats any gap < FREE/2
    // as an interaction
    let has = gap < FREE_GAP * 0.5;
    idm_law(v, gap, v, has, &p)
}

/// Shared semi-implicit Euler integration + observables — the back half
/// of `model.step`, common to both steppers so bit-exactness of the
/// neighbor scans implies bit-exactness of whole trajectories.
fn integrate(
    t: &mut Traffic,
    accel: &[f32],
    decisions: &[Option<f32>],
    scenario: &MergeScenario,
) -> StepObs {
    let n = t.capacity();
    let dt = scenario.dt_s;
    let mut flow = 0.0f32;
    let mut n_merged = 0.0f32;
    let mut n_exited = 0.0f32;
    let (n_active, mean_v_before) = t.census();
    let n_active_before = n_active as f32;

    for i in 0..n {
        if !t.is_active(i) {
            // mirror the vectorized model exactly: inactive rows hold
            // position but their speed is forced to zero
            let (x, lane) = (t.x(i), t.lane(i));
            t.set_state_row(i, x, 0.0, lane, false);
            continue;
        }
        let new_lane = decisions[i].unwrap_or(t.lane(i));
        if decisions[i].is_some() && (t.lane(i) - MergeScenario::RAMP_LANE).abs() < 0.5 {
            n_merged += 1.0;
        }
        let new_v = (t.v(i) + accel[i] * dt).max(0.0);
        let x_old = t.x(i);
        let new_x = x_old + new_v * dt;
        let crossed = new_x >= scenario.road_end_m && x_old < scenario.road_end_m;
        // destination retirement: an exit-flagged vehicle leaves when it
        // crosses its own exit_pos on lane <= 1 (the off-ramp gore) —
        // evaluated against the post-decision lane, like the model
        let exited = !crossed
            && t.param(i, P_EXIT_FLAG) > 0.5
            && new_lane < 1.5
            && new_x >= t.param(i, P_EXIT_POS)
            && x_old < t.param(i, P_EXIT_POS);
        if crossed {
            flow += 1.0;
        }
        if exited {
            n_exited += 1.0;
        }
        t.set_state_row(i, new_x, new_v, new_lane, !(crossed || exited));
    }

    StepObs {
        n_active: n_active_before,
        mean_speed: mean_v_before,
        flow,
        n_merged,
        n_exited,
    }
}

/// Reusable per-step buffers for [`NativeIdmStepper`] — kept across
/// steps so steady-state stepping allocates nothing.
#[derive(Debug, Clone, Default)]
pub struct StepScratch {
    index: LaneIndex,
    accel: Vec<f32>,
    decisions: Vec<Option<f32>>,
}

/// The native stepper: full merge-sim step (IDM + wall + MOBIL +
/// integration), mirroring `model.step`, with O(N log N) sorted-sweep
/// neighbor queries and zero steady-state allocation.
#[derive(Debug, Clone)]
pub struct NativeIdmStepper {
    pub scenario: MergeScenario,
    pub mobil: MobilParams,
    /// Reused per-step buffers (an implementation detail; public only so
    /// struct-literal construction with `..Default::default()` keeps
    /// working for callers).
    pub scratch: StepScratch,
}

impl Default for NativeIdmStepper {
    fn default() -> Self {
        NativeIdmStepper {
            scenario: MergeScenario::default(),
            mobil: MobilParams::default(),
            scratch: StepScratch::default(),
        }
    }
}

impl NativeIdmStepper {
    pub fn new(scenario: MergeScenario, mobil: MobilParams) -> Self {
        NativeIdmStepper {
            scenario,
            mobil,
            scratch: StepScratch::default(),
        }
    }
}

impl Stepper for NativeIdmStepper {
    fn step(&mut self, t: &mut Traffic) -> StepObs {
        let scratch = &mut self.scratch;
        scratch.index.rebuild(t);

        // accelerations: car-following (sorted sweep) min phantom wall
        idm_accel_all_into(t, &scratch.index, &mut scratch.accel);
        for i in 0..t.capacity() {
            if t.is_active(i) {
                scratch.accel[i] = scratch.accel[i].min(wall_accel(t, i, &self.scenario));
            }
        }

        // lane decisions (computed against the pre-step state, like the
        // vectorized model)
        mobil::decide_all_into(
            t,
            &scratch.accel,
            &self.scenario,
            &self.mobil,
            &scratch.index,
            &mut scratch.decisions,
        );

        integrate(t, &scratch.accel, &scratch.decisions, &self.scenario)
    }

    fn name(&self) -> &'static str {
        "native-sweep"
    }
}

/// The O(N²) reference stepper — identical physics through the reference
/// scans.  The bit-exactness oracle for [`NativeIdmStepper`] and the
/// §Perf "before" baseline; not for production stepping.
#[derive(Debug, Clone)]
pub struct ReferenceIdmStepper {
    pub scenario: MergeScenario,
    pub mobil: MobilParams,
}

impl Default for ReferenceIdmStepper {
    fn default() -> Self {
        ReferenceIdmStepper {
            scenario: MergeScenario::default(),
            mobil: MobilParams::default(),
        }
    }
}

impl Stepper for ReferenceIdmStepper {
    fn step(&mut self, t: &mut Traffic) -> StepObs {
        let n = t.capacity();
        let a_follow = idm_accel_all(t);
        let accel: Vec<f32> = (0..n)
            .map(|i| {
                if !t.is_active(i) {
                    return 0.0;
                }
                a_follow[i].min(wall_accel(t, i, &self.scenario))
            })
            .collect();
        let decisions = mobil::decide_all(t, &accel, &self.scenario, &self.mobil);
        integrate(t, &accel, &decisions, &self.scenario)
    }

    fn name(&self) -> &'static str {
        "native-reference"
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::sumo::state::DriverParams;

    fn traffic(rows: &[(f32, f32, f32)]) -> Traffic {
        let mut t = Traffic::new(rows.len());
        for &(x, v, lane) in rows {
            t.spawn(x, v, lane, DriverParams::default());
        }
        t
    }

    #[test]
    fn lone_vehicle_free_accelerates() {
        let t = traffic(&[(100.0, 20.0, 1.0)]);
        let a = idm_accel_all(&t);
        let expect = 1.5 * (1.0 - (20.0f32 / 30.0).powi(4));
        assert!((a[0] - expect).abs() < 1e-5);
    }

    #[test]
    fn leader_scan_finds_nearest_same_lane() {
        let t = traffic(&[(100.0, 20.0, 1.0), (150.0, 10.0, 1.0), (120.0, 5.0, 2.0)]);
        let l = leader_scan(&t, 0);
        assert!(l.exists);
        assert!((l.gap - (50.0 - 4.5)).abs() < 1e-4);
        assert_eq!(l.v, 10.0);
    }

    #[test]
    fn tailgater_brakes() {
        let t = traffic(&[(100.0, 30.0, 1.0), (106.0, 0.0, 1.0)]);
        let a = idm_accel_all(&t);
        assert!(a[0] < -10.0);
    }

    #[test]
    fn sweep_accel_matches_reference() {
        let t = traffic(&[
            (100.0, 30.0, 1.0),
            (106.0, 0.0, 1.0),
            (106.0, 5.0, 1.0),
            (90.0, 12.0, 2.0),
        ]);
        let mut index = LaneIndex::new();
        index.rebuild(&t);
        let mut fast = Vec::new();
        idm_accel_all_into(&t, &index, &mut fast);
        assert_eq!(fast, idm_accel_all(&t));
    }

    #[test]
    fn wall_stops_ramp_vehicle() {
        let scenario = MergeScenario::default();
        let mut t = Traffic::new(1);
        t.spawn(450.0, 20.0, 0.0, DriverParams::default());
        let a = wall_accel(&t, 0, &scenario);
        assert!(a < -1.0, "approaching wall at 20 m/s from 50 m: {a}");
        // mainline vehicle sees no wall
        let mut t2 = Traffic::new(1);
        t2.spawn(450.0, 20.0, 1.0, DriverParams::default());
        assert!(wall_accel(&t2, 0, &scenario) > 0.0);
    }

    #[test]
    fn step_retires_at_road_end() {
        let mut s = NativeIdmStepper::default();
        let mut t = traffic(&[(999.5, 30.0, 1.0)]);
        let obs = s.step(&mut t);
        assert_eq!(obs.flow, 1.0);
        assert!(!t.is_active(0));
    }

    #[test]
    fn step_retires_at_exit_pos_and_counts_exits_not_flow() {
        let mut s = NativeIdmStepper::default();
        let mut t = Traffic::new(1);
        t.spawn(449.5, 30.0, 1.0, DriverParams::default().with_exit(450.0));
        let obs = s.step(&mut t);
        assert_eq!(obs.flow, 0.0);
        assert_eq!(obs.n_exited, 1.0);
        assert!(!t.is_active(0));
    }

    #[test]
    fn unflagged_vehicle_ignores_exit_pos() {
        let mut s = NativeIdmStepper::default();
        let mut t = Traffic::new(1);
        t.spawn(449.5, 30.0, 1.0, DriverParams::default());
        let obs = s.step(&mut t);
        assert_eq!(obs.n_exited, 0.0);
        assert!(t.is_active(0));
    }

    #[test]
    fn exit_flagged_ramp_vehicle_sees_no_wall() {
        let scenario = MergeScenario::default();
        let mut t = Traffic::new(1);
        t.spawn(450.0, 20.0, 0.0, DriverParams::default().with_exit(500.0));
        // the lane does not end for a vehicle bound for the gore
        assert!(wall_accel(&t, 0, &scenario) > 0.0);
    }

    #[test]
    fn step_speed_never_negative() {
        let mut s = NativeIdmStepper::default();
        let mut t = traffic(&[(100.0, 0.5, 1.0), (103.0, 0.0, 1.0)]);
        for _ in 0..50 {
            s.step(&mut t);
        }
        assert!(t.v(0) >= 0.0);
    }

    #[test]
    fn native_and_reference_steppers_agree_exactly() {
        let mut fast = NativeIdmStepper::default();
        let mut oracle = ReferenceIdmStepper::default();
        let mut ta = traffic(&[
            (100.0, 20.0, 1.0),
            (130.0, 10.0, 1.0),
            (350.0, 22.0, 0.0),
            (355.0, 21.0, 1.0),
            (90.0, 25.0, 2.0),
        ]);
        let mut tb = ta.clone();
        for step in 0..200 {
            let oa = fast.step(&mut ta);
            let ob = oracle.step(&mut tb);
            assert_eq!(oa, ob, "obs diverged at step {step}");
            assert_eq!(ta, tb, "state diverged at step {step}");
        }
    }
}
