//! The SUMO substrate: a traffic microsimulator with SUMO's moving parts.
//!
//! SUMO is the simulation *back-end* of the paper's pipeline ("think of
//! Webots as a puppet and SUMO as the puppeteer", §2.5.3).  We implement
//! the slice the pipeline exercises:
//!
//! * [`network`] — road networks (the `sumo.net.xml` side): edges, lanes,
//!   and the highway-merge geometry of the sample simulation,
//! * [`xmlio`] — reading/writing the sumo-like config files
//!   (`sumo.net.xml`, `sumo.flow.xml`, `sumo.rou.xml`),
//! * [`flow`]/[`duarouter`] — demand: flow definitions and the seeded
//!   randomized route/departure generation the paper invokes per run
//!   (`duarouter --randomize-flows true --seed $RANDOM`),
//! * [`state`] — the flat vehicle-state arrays shared with the AOT HLO
//!   physics (layout fixed by `python/compile/kernels/ref.py`),
//! * [`idm`]/[`mobil`] — a pure-rust IDM + MOBIL stepper: the baseline
//!   comparator for the HLO path and the engine for runs that don't
//!   need PJRT,
//! * [`sweep`] — the sorted-sweep neighbor index that makes the native
//!   step O(N log N) and allocation-free (bit-exact with the reference
//!   scans),
//! * [`simulation`] — the microsim loop: spawning from demand, stepping,
//!   observables; serves TraCI queries.  Chunk-scheduled: departure-free
//!   runs of steps are handed to the stepper as ONE fused chunk
//!   (`Stepper::step_many`), which the HLO stepper executes as a single
//!   PJRT rollout dispatch.  Schema-5 artifacts go further: when the
//!   demand schedule fits the compiled departure table, a WHOLE run is
//!   one device-resident dispatch (`Stepper::run_resident`) and the
//!   host chunk scheduler is skipped entirely.

pub mod duarouter;
pub mod flow;
pub mod idm;
pub mod mobil;
pub mod network;
pub mod simulation;
pub mod state;
pub mod sweep;
pub mod xmlio;

pub use duarouter::{duarouter, Departure, RouteFile};
pub use flow::{FlowDef, FlowFile, VehicleType};
pub use idm::{NativeIdmStepper, ReferenceIdmStepper};
pub use sweep::LaneIndex;
pub use network::{Edge, MergeScenario, Network};
pub use simulation::{
    departure_epochs, steps_for, DepartureTable, StepObs, Stepper, SumoSim, DEP_COLS,
    DEP_PAD_EPOCH, D_LANE, D_PARAMS, D_STEP, D_V, D_X,
};
pub use state::{
    DriverParams, GeometryVec, Traffic, ACTIVE, GEOM_COLS, LANE, PARAM_COLS, STATE_COLS, V, X,
};
