//! Flat vehicle-state arrays — the ABI shared with the AOT physics.
//!
//! Layout is fixed by `python/compile/kernels/ref.py` and recorded in
//! `artifacts/manifest.json`:
//!
//! ```text
//! state  f32[N, 4]: [x, v, lane, active]
//! params f32[N, 8]: [v0, T, a_max, b, s0, length, exit_pos, exit_flag]
//! geom   f32[5]   : [road_end, merge_start, merge_end, num_main_lanes, dt]
//! obs    f32[5]   : [n_active, mean_speed, flow, n_merged, n_exited]
//! ```
//!
//! `N` is a *bucket capacity*, not the live vehicle count: inactive rows
//! (active == 0) are spawn slots the coordinator writes into.  The
//! geometry row is the schema-2 runtime operand that makes the AOT
//! artifacts scenario-generic (`python/compile/model.py GEOM_COLUMNS`);
//! the `[exit_pos, exit_flag]` params columns are the schema-3
//! destination intent (`model.py PARAM_COLUMNS`) that makes them
//! route-aware: a flagged vehicle retires when it crosses its own
//! `exit_pos` on lane <= 1 (the off-ramp gore) instead of riding to
//! `road_end`, and `obs[4]` counts those exits separately from the
//! road-end `flow`.

pub const STATE_COLS: usize = 4;
pub const PARAM_COLS: usize = 8;
pub const GEOM_COLS: usize = 5;
pub const OBS_COLS: usize = 5;

// state columns
pub const X: usize = 0;
pub const V: usize = 1;
pub const LANE: usize = 2;
pub const ACTIVE: usize = 3;

// param columns
pub const P_V0: usize = 0;
pub const P_T: usize = 1;
pub const P_AMAX: usize = 2;
pub const P_B: usize = 3;
pub const P_S0: usize = 4;
pub const P_LEN: usize = 5;
pub const P_EXIT_POS: usize = 6;
pub const P_EXIT_FLAG: usize = 7;

// geometry columns (manifest `geometry_columns`)
pub const G_ROAD_END: usize = 0;
pub const G_MERGE_START: usize = 1;
pub const G_MERGE_END: usize = 2;
pub const G_NUM_MAIN_LANES: usize = 3;
pub const G_DT: usize = 4;

/// One scenario geometry as the f32 operand row the geometry-generic
/// AOT artifacts consume — derived from a
/// [`MergeScenario`](super::network::MergeScenario) via
/// `MergeScenario::geometry_vec`.  `Copy` on purpose: geometry rows
/// travel per-request through the engine service exactly like
/// [`DriverParams`] rows travel per-lane, without touching the
/// allocation-free hot path.
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct GeometryVec(pub [f32; GEOM_COLS]);

impl GeometryVec {
    #[inline]
    pub fn as_slice(&self) -> &[f32] {
        &self.0
    }
}

impl Default for GeometryVec {
    /// The default merge scenario's geometry row.
    fn default() -> Self {
        super::network::MergeScenario::default().geometry_vec()
    }
}

/// Per-vehicle driver/vehicle parameters plus destination intent (one
/// `params` row).  `exit_pos`/`exit_flag` are the schema-3 route
/// columns: a vehicle with `exit_flag > 0.5` retires when it crosses
/// `exit_pos` on lane <= 1 (the off-ramp gore) — both steppers and the
/// AOT kernel read them straight off this row.
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct DriverParams {
    pub v0: f32,
    pub t_headway: f32,
    pub a_max: f32,
    pub b_comf: f32,
    pub s0: f32,
    pub length: f32,
    /// Off-ramp gore position [m]; meaningful only when `exit_flag` set.
    pub exit_pos: f32,
    /// 1.0 = this vehicle leaves at `exit_pos`, 0.0 = rides to road end.
    pub exit_flag: f32,
}

impl Default for DriverParams {
    fn default() -> Self {
        // standard IDM passenger-car calibration; no exit intent
        DriverParams {
            v0: 30.0,
            t_headway: 1.5,
            a_max: 1.5,
            b_comf: 2.0,
            s0: 2.0,
            length: 4.5,
            exit_pos: 0.0,
            exit_flag: 0.0,
        }
    }
}

impl DriverParams {
    /// A connected-autonomous-vehicle profile: tighter headway, smoother
    /// accelerations (the CAV of the Phase-II merge scenario).
    pub fn cav() -> Self {
        DriverParams {
            v0: 30.0,
            t_headway: 0.9,
            a_max: 1.8,
            b_comf: 2.5,
            s0: 1.5,
            length: 4.5,
            ..DriverParams::default()
        }
    }

    /// This profile, destined for the off-ramp gore at `exit_pos`.
    pub fn with_exit(self, exit_pos: f32) -> Self {
        DriverParams {
            exit_pos,
            exit_flag: 1.0,
            ..self
        }
    }

    /// Does this row carry exit intent?
    pub fn exits(&self) -> bool {
        self.exit_flag > 0.5
    }
}

/// The traffic state: `cap` slots of state+params, flat row-major f32 —
/// exactly what the PJRT executable consumes.
#[derive(Debug, Clone, PartialEq)]
pub struct Traffic {
    cap: usize,
    pub state: Vec<f32>,
    pub params: Vec<f32>,
}

impl Traffic {
    pub fn new(cap: usize) -> Self {
        Traffic {
            cap,
            state: vec![0.0; cap * STATE_COLS],
            params: vec![0.0; cap * PARAM_COLS],
        }
    }

    pub fn capacity(&self) -> usize {
        self.cap
    }

    #[inline]
    pub fn x(&self, i: usize) -> f32 {
        self.state[i * STATE_COLS + X]
    }

    #[inline]
    pub fn v(&self, i: usize) -> f32 {
        self.state[i * STATE_COLS + V]
    }

    #[inline]
    pub fn lane(&self, i: usize) -> f32 {
        self.state[i * STATE_COLS + LANE]
    }

    #[inline]
    pub fn is_active(&self, i: usize) -> bool {
        self.state[i * STATE_COLS + ACTIVE] > 0.5
    }

    #[inline]
    pub fn param(&self, i: usize, col: usize) -> f32 {
        self.params[i * PARAM_COLS + col]
    }

    pub fn set_state_row(&mut self, i: usize, x: f32, v: f32, lane: f32, active: bool) {
        let o = i * STATE_COLS;
        self.state[o + X] = x;
        self.state[o + V] = v;
        self.state[o + LANE] = lane;
        self.state[o + ACTIVE] = if active { 1.0 } else { 0.0 };
    }

    pub fn set_params_row(&mut self, i: usize, p: DriverParams) {
        let o = i * PARAM_COLS;
        self.params[o + P_V0] = p.v0;
        self.params[o + P_T] = p.t_headway;
        self.params[o + P_AMAX] = p.a_max;
        self.params[o + P_B] = p.b_comf;
        self.params[o + P_S0] = p.s0;
        self.params[o + P_LEN] = p.length;
        self.params[o + P_EXIT_POS] = p.exit_pos;
        self.params[o + P_EXIT_FLAG] = p.exit_flag;
    }

    /// First inactive slot, if any — where the next departure spawns.
    pub fn free_slot(&self) -> Option<usize> {
        (0..self.cap).find(|&i| !self.is_active(i))
    }

    pub fn active_count(&self) -> usize {
        (0..self.cap).filter(|&i| self.is_active(i)).count()
    }

    /// Spawn a vehicle into a free slot; `None` when the bucket is full
    /// (the demand generator backs off — matching SUMO's insertion queue).
    pub fn spawn(&mut self, x: f32, v: f32, lane: f32, p: DriverParams) -> Option<usize> {
        let slot = self.free_slot()?;
        self.set_state_row(slot, x, v, lane, true);
        self.set_params_row(slot, p);
        Some(slot)
    }

    pub fn deactivate(&mut self, i: usize) {
        self.state[i * STATE_COLS + ACTIVE] = 0.0;
    }

    /// Mean speed over active vehicles (0 when empty).
    pub fn mean_speed(&self) -> f32 {
        self.census().1
    }

    /// `(active_count, mean_speed)` in a single pass over the slots —
    /// the per-step observables, fused so the stepper doesn't scan the
    /// state twice.  Identical accumulation order to [`Self::mean_speed`]
    /// (bit-exact).
    pub fn census(&self) -> (usize, f32) {
        let mut sum = 0.0f32;
        let mut n = 0u32;
        for i in 0..self.cap {
            if self.is_active(i) {
                sum += self.v(i);
                n += 1;
            }
        }
        let mean = if n == 0 { 0.0 } else { sum / n as f32 };
        (n as usize, mean)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn spawn_fills_slots_in_order() {
        let mut t = Traffic::new(3);
        assert_eq!(t.spawn(0.0, 10.0, 1.0, DriverParams::default()), Some(0));
        assert_eq!(t.spawn(5.0, 10.0, 1.0, DriverParams::default()), Some(1));
        assert_eq!(t.spawn(9.0, 10.0, 2.0, DriverParams::default()), Some(2));
        assert_eq!(t.spawn(9.0, 10.0, 2.0, DriverParams::default()), None);
        assert_eq!(t.active_count(), 3);
    }

    #[test]
    fn deactivated_slot_is_reused() {
        let mut t = Traffic::new(2);
        t.spawn(0.0, 10.0, 1.0, DriverParams::default());
        t.spawn(5.0, 10.0, 1.0, DriverParams::default());
        t.deactivate(0);
        assert_eq!(t.free_slot(), Some(0));
        assert_eq!(t.spawn(1.0, 2.0, 0.0, DriverParams::cav()), Some(0));
        assert_eq!(t.lane(0), 0.0);
    }

    #[test]
    fn rows_are_flat_and_contiguous() {
        let mut t = Traffic::new(2);
        t.set_state_row(1, 7.0, 8.0, 2.0, true);
        assert_eq!(&t.state[4..8], &[7.0, 8.0, 2.0, 1.0]);
        assert_eq!(t.state.len(), 8);
        assert_eq!(t.params.len(), 2 * PARAM_COLS);
    }

    #[test]
    fn exit_columns_round_trip_through_the_row() {
        let mut t = Traffic::new(2);
        t.spawn(0.0, 10.0, 1.0, DriverParams::default().with_exit(450.0));
        assert_eq!(t.param(0, P_EXIT_POS), 450.0);
        assert_eq!(t.param(0, P_EXIT_FLAG), 1.0);
        // a through vehicle reusing the slot clears the stale intent
        t.deactivate(0);
        t.spawn(5.0, 10.0, 1.0, DriverParams::default());
        assert_eq!(t.param(0, P_EXIT_POS), 0.0);
        assert_eq!(t.param(0, P_EXIT_FLAG), 0.0);
        assert!(!DriverParams::default().exits());
        assert!(DriverParams::cav().with_exit(1.0).exits());
    }

    #[test]
    fn mean_speed_ignores_inactive() {
        let mut t = Traffic::new(3);
        t.spawn(0.0, 10.0, 1.0, DriverParams::default());
        t.spawn(5.0, 20.0, 1.0, DriverParams::default());
        t.deactivate(1);
        assert_eq!(t.mean_speed(), 10.0);
    }
}
