//! Road networks: the `sumo.net.xml` side of the config tuple.
//!
//! The geometry the AOT physics bakes in (merge zone, road end, lane
//! count) lives in [`MergeScenario`]; the general [`Network`] model
//! supports arbitrary edge graphs for non-merge worlds.


use crate::{Error, Result};

/// One directed road edge.
#[derive(Debug, Clone, PartialEq)]
pub struct Edge {
    pub id: String,
    pub from: String,
    pub to: String,
    pub length_m: f32,
    pub num_lanes: u32,
    pub speed_limit: f32,
}

/// A road network (nodes are implicit in edge endpoints).
#[derive(Debug, Clone, PartialEq, Default)]
pub struct Network {
    pub edges: Vec<Edge>,
}

impl Network {
    pub fn edge(&self, id: &str) -> Result<&Edge> {
        self.edges
            .iter()
            .find(|e| e.id == id)
            .ok_or_else(|| Error::Config(format!("no such edge '{id}'")))
    }

    pub fn total_length_m(&self) -> f32 {
        self.edges.iter().map(|e| e.length_m).sum()
    }

    /// Validate referential integrity of a route (edge ids exist and are
    /// head-to-tail connected).
    pub fn validate_route(&self, edge_ids: &[String]) -> Result<()> {
        if edge_ids.is_empty() {
            return Err(Error::Config("empty route".into()));
        }
        for id in edge_ids {
            self.edge(id)?;
        }
        for pair in edge_ids.windows(2) {
            let a = self.edge(&pair[0])?;
            let b = self.edge(&pair[1])?;
            if a.to != b.from {
                return Err(Error::Config(format!(
                    "route discontinuity: {} ends at '{}' but {} starts at '{}'",
                    a.id, a.to, b.id, b.from
                )));
            }
        }
        Ok(())
    }
}

/// The sample highway-merge scenario of ch. 5: a 2-lane mainline with an
/// on-ramp acceleration lane.  Constants MUST match `python/compile/
/// model.py` (asserted against `artifacts/manifest.json` by the runtime
/// tests).
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct MergeScenario {
    pub road_end_m: f32,
    pub merge_start_m: f32,
    pub merge_end_m: f32,
    pub num_main_lanes: u32,
    pub dt_s: f32,
}

impl Default for MergeScenario {
    fn default() -> Self {
        MergeScenario {
            road_end_m: 1000.0,
            merge_start_m: 300.0,
            merge_end_m: 500.0,
            num_main_lanes: 2,
            dt_s: 0.1,
        }
    }
}

impl MergeScenario {
    /// Lane index of the on-ramp/acceleration lane.
    pub const RAMP_LANE: f32 = 0.0;

    /// This geometry as the f32 operand row the geometry-generic AOT
    /// artifacts consume (layout: `sumo::state::G_*`, recorded as
    /// `geometry_columns` in `artifacts/manifest.json`).
    pub fn geometry_vec(&self) -> super::state::GeometryVec {
        super::state::GeometryVec([
            self.road_end_m,
            self.merge_start_m,
            self.merge_end_m,
            self.num_main_lanes as f32,
            self.dt_s,
        ])
    }

    /// Build the network graph form (for xml round-trips and TraCI).
    pub fn network(&self) -> Network {
        self.network_with_speeds(30.0, 20.0)
    }

    /// The merge network with explicit mainline/ramp speed limits — the
    /// parametric form the scenario subsystem compiles against.
    pub fn network_with_speeds(&self, main_speed: f32, ramp_speed: f32) -> Network {
        Network {
            edges: vec![
                Edge {
                    id: "main_in".into(),
                    from: "west".into(),
                    to: "merge_a".into(),
                    length_m: self.merge_start_m,
                    num_lanes: self.num_main_lanes,
                    speed_limit: main_speed,
                },
                Edge {
                    id: "merge_zone".into(),
                    from: "merge_a".into(),
                    to: "merge_b".into(),
                    length_m: self.merge_end_m - self.merge_start_m,
                    num_lanes: self.num_main_lanes + 1, // + acceleration lane
                    speed_limit: main_speed,
                },
                Edge {
                    id: "main_out".into(),
                    from: "merge_b".into(),
                    to: "east".into(),
                    length_m: self.road_end_m - self.merge_end_m,
                    num_lanes: self.num_main_lanes,
                    speed_limit: main_speed,
                },
                Edge {
                    id: "ramp".into(),
                    from: "ramp_start".into(),
                    to: "merge_a".into(),
                    length_m: self.merge_start_m,
                    num_lanes: 1,
                    speed_limit: ramp_speed,
                },
            ],
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn merge_network_geometry() {
        let s = MergeScenario::default();
        let n = s.network();
        assert_eq!(n.edges.len(), 4);
        assert_eq!(n.edge("merge_zone").unwrap().num_lanes, 3);
        assert_eq!(n.total_length_m(), 1000.0 + 300.0);
    }

    #[test]
    fn speeds_are_parametric() {
        let n = MergeScenario::default().network_with_speeds(33.0, 21.0);
        assert_eq!(n.edge("main_in").unwrap().speed_limit, 33.0);
        assert_eq!(n.edge("ramp").unwrap().speed_limit, 21.0);
        // the default form is the (30, 20) instance
        assert_eq!(
            MergeScenario::default().network(),
            MergeScenario::default().network_with_speeds(30.0, 20.0)
        );
    }

    #[test]
    fn route_validation() {
        let n = MergeScenario::default().network();
        let ok = ["main_in", "merge_zone", "main_out"].map(String::from);
        n.validate_route(&ok).unwrap();
        let ramp = ["ramp", "merge_zone", "main_out"].map(String::from);
        n.validate_route(&ramp).unwrap();
        let bad = ["main_in", "main_out"].map(String::from);
        assert!(n.validate_route(&bad).is_err());
        assert!(n.validate_route(&["nope".to_string()]).is_err());
        assert!(n.validate_route(&[]).is_err());
    }

    #[test]
    fn geometry_vec_layout_matches_manifest_columns() {
        use crate::sumo::state::{G_DT, G_MERGE_END, G_MERGE_START, G_NUM_MAIN_LANES, G_ROAD_END};
        let s = MergeScenario {
            road_end_m: 700.0,
            merge_start_m: 150.0,
            merge_end_m: 400.0,
            num_main_lanes: 3,
            dt_s: 0.05,
        };
        let g = s.geometry_vec();
        assert_eq!(g.0[G_ROAD_END], 700.0);
        assert_eq!(g.0[G_MERGE_START], 150.0);
        assert_eq!(g.0[G_MERGE_END], 400.0);
        assert_eq!(g.0[G_NUM_MAIN_LANES], 3.0);
        assert_eq!(g.0[G_DT], 0.05);
        // the Default geometry row is the default scenario's
        assert_eq!(
            crate::sumo::state::GeometryVec::default(),
            MergeScenario::default().geometry_vec()
        );
    }

    #[test]
    fn constants_match_model_py() {
        // duplicated from python/compile/model.py; the runtime test
        // cross-checks against artifacts/manifest.json too.
        let s = MergeScenario::default();
        assert_eq!(s.road_end_m, 1000.0);
        assert_eq!(s.merge_start_m, 300.0);
        assert_eq!(s.merge_end_m, 500.0);
        assert_eq!(s.num_main_lanes, 2);
        assert!((s.dt_s - 0.1).abs() < 1e-9);
    }
}
