//! Demand definitions: the `sumo.flow.xml` side.


use super::state::DriverParams;

/// Vehicle type: parameter template + CAV flag.
#[derive(Debug, Clone, Copy, PartialEq)]
pub enum VehicleType {
    /// Human-driven passenger car (IDM defaults).
    Human,
    /// Connected autonomous vehicle (tighter headway profile).
    Cav,
}

impl VehicleType {
    pub fn params(&self) -> DriverParams {
        match self {
            VehicleType::Human => DriverParams::default(),
            VehicleType::Cav => DriverParams::cav(),
        }
    }
}

/// One `<flow>` element: a stream of departures on a route.
#[derive(Debug, Clone, PartialEq)]
pub struct FlowDef {
    pub id: String,
    /// Route as edge ids (validated against the net).
    pub route: Vec<String>,
    /// Demand rate [vehicles/hour].
    pub vehs_per_hour: f32,
    /// Initial speed at insertion [m/s].
    pub depart_speed: f32,
    /// Lane at insertion (the merge scenario: 0 = ramp, 1.. = mainline).
    pub depart_lane: u32,
    /// Insertion position [m].
    pub depart_pos: f32,
    pub vtype: VehicleType,
    /// Flow window [s].
    pub begin_s: f32,
    pub end_s: f32,
}

/// The full `sumo.flow.xml` content.
#[derive(Debug, Clone, PartialEq, Default)]
pub struct FlowFile {
    pub flows: Vec<FlowDef>,
}

impl FlowFile {
    /// The sample merge workload: mainline traffic on both lanes plus a
    /// CAV-bearing ramp flow.
    pub fn merge_sample(mainline_vph: f32, ramp_vph: f32, horizon_s: f32) -> Self {
        let main_route = vec![
            "main_in".to_string(),
            "merge_zone".to_string(),
            "main_out".to_string(),
        ];
        let ramp_route = vec![
            "ramp".to_string(),
            "merge_zone".to_string(),
            "main_out".to_string(),
        ];
        FlowFile {
            flows: vec![
                FlowDef {
                    id: "main_l1".into(),
                    route: main_route.clone(),
                    vehs_per_hour: mainline_vph / 2.0,
                    depart_speed: 25.0,
                    depart_lane: 1,
                    depart_pos: 0.0,
                    vtype: VehicleType::Human,
                    begin_s: 0.0,
                    end_s: horizon_s,
                },
                FlowDef {
                    id: "main_l2".into(),
                    route: main_route,
                    vehs_per_hour: mainline_vph / 2.0,
                    depart_speed: 25.0,
                    depart_lane: 2,
                    depart_pos: 0.0,
                    vtype: VehicleType::Human,
                    begin_s: 0.0,
                    end_s: horizon_s,
                },
                FlowDef {
                    id: "ramp_cav".into(),
                    route: ramp_route,
                    vehs_per_hour: ramp_vph,
                    depart_speed: 15.0,
                    depart_lane: 0,
                    depart_pos: 50.0,
                    vtype: VehicleType::Cav,
                    begin_s: 0.0,
                    end_s: horizon_s,
                },
            ],
        }
    }

    pub fn total_expected_vehicles(&self) -> f32 {
        self.flows
            .iter()
            .map(|f| f.vehs_per_hour * (f.end_s - f.begin_s) / 3600.0)
            .sum()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn merge_sample_has_three_flows() {
        let f = FlowFile::merge_sample(1200.0, 300.0, 300.0);
        assert_eq!(f.flows.len(), 3);
        assert_eq!(f.flows[2].vtype, VehicleType::Cav);
        assert_eq!(f.flows[2].depart_lane, 0);
    }

    #[test]
    fn expected_vehicle_count() {
        let f = FlowFile::merge_sample(1200.0, 300.0, 3600.0);
        assert!((f.total_expected_vehicles() - 1500.0).abs() < 1e-3);
    }

    #[test]
    fn vehicle_types_have_distinct_params() {
        assert!(VehicleType::Cav.params().t_headway < VehicleType::Human.params().t_headway);
    }
}
