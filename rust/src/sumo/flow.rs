//! Demand definitions: the `sumo.flow.xml` side.


use super::network::Network;
use super::state::DriverParams;
use crate::Result;

/// Vehicle type: parameter template + CAV flag.
#[derive(Debug, Clone, Copy, PartialEq)]
pub enum VehicleType {
    /// Human-driven passenger car (IDM defaults).
    Human,
    /// Connected autonomous vehicle (tighter headway profile).
    Cav,
}

impl VehicleType {
    pub fn params(&self) -> DriverParams {
        match self {
            VehicleType::Human => DriverParams::default(),
            VehicleType::Cav => DriverParams::cav(),
        }
    }
}

/// One `<flow>` element: a stream of departures on a route.
#[derive(Debug, Clone, PartialEq)]
pub struct FlowDef {
    pub id: String,
    /// Route as edge ids (validated against the net).
    pub route: Vec<String>,
    /// Demand rate [vehicles/hour].
    pub vehs_per_hour: f32,
    /// Initial speed at insertion [m/s].
    pub depart_speed: f32,
    /// Lane at insertion (the merge scenario: 0 = ramp, 1.. = mainline).
    pub depart_lane: u32,
    /// Insertion position [m].
    pub depart_pos: f32,
    pub vtype: VehicleType,
    /// Flow window [s].
    pub begin_s: f32,
    pub end_s: f32,
    /// Scenario-level desired-speed multiplier applied on the vtype's
    /// calibration before per-driver jitter (1.0 = unperturbed) — how a
    /// scenario point's speed-limit axis reaches the IDM dynamics.
    pub v0_scale: f32,
    /// Scenario-level headway multiplier, same mechanism (the IDM/MOBIL
    /// driver-param perturbation axis).
    pub t_scale: f32,
    /// Destination intent (schema 3): `Some(gore_x)` routes this flow's
    /// vehicles off at the off-ramp gore — compiled into the params
    /// rows' `[exit_pos, exit_flag]` columns; `None` = ride to road end.
    pub exit_pos_m: Option<f32>,
}

impl FlowDef {
    /// The per-flow driver baseline: the vtype template with the
    /// scenario scales applied, carrying the flow's destination intent.
    /// `duarouter` jitters per driver on top (never touching the exit
    /// columns).
    pub fn base_params(&self) -> DriverParams {
        let b = self.vtype.params();
        DriverParams {
            v0: b.v0 * self.v0_scale,
            t_headway: b.t_headway * self.t_scale,
            exit_pos: self.exit_pos_m.unwrap_or(0.0),
            exit_flag: if self.exit_pos_m.is_some() { 1.0 } else { 0.0 },
            ..b
        }
    }
}

/// The full `sumo.flow.xml` content.
#[derive(Debug, Clone, PartialEq, Default)]
pub struct FlowFile {
    pub flows: Vec<FlowDef>,
}

impl FlowFile {
    /// The sample merge workload: mainline traffic on both lanes plus a
    /// CAV-bearing ramp flow.
    pub fn merge_sample(mainline_vph: f32, ramp_vph: f32, horizon_s: f32) -> Self {
        let main_route = vec![
            "main_in".to_string(),
            "merge_zone".to_string(),
            "main_out".to_string(),
        ];
        let ramp_route = vec![
            "ramp".to_string(),
            "merge_zone".to_string(),
            "main_out".to_string(),
        ];
        FlowFile {
            flows: vec![
                FlowDef {
                    id: "main_l1".into(),
                    route: main_route.clone(),
                    vehs_per_hour: mainline_vph / 2.0,
                    depart_speed: 25.0,
                    depart_lane: 1,
                    depart_pos: 0.0,
                    vtype: VehicleType::Human,
                    begin_s: 0.0,
                    end_s: horizon_s,
                    v0_scale: 1.0,
                    t_scale: 1.0,
                    exit_pos_m: None,
                },
                FlowDef {
                    id: "main_l2".into(),
                    route: main_route,
                    vehs_per_hour: mainline_vph / 2.0,
                    depart_speed: 25.0,
                    depart_lane: 2,
                    depart_pos: 0.0,
                    vtype: VehicleType::Human,
                    begin_s: 0.0,
                    end_s: horizon_s,
                    v0_scale: 1.0,
                    t_scale: 1.0,
                    exit_pos_m: None,
                },
                FlowDef {
                    id: "ramp_cav".into(),
                    route: ramp_route,
                    vehs_per_hour: ramp_vph,
                    depart_speed: 15.0,
                    depart_lane: 0,
                    depart_pos: 50.0,
                    vtype: VehicleType::Cav,
                    begin_s: 0.0,
                    end_s: horizon_s,
                    v0_scale: 1.0,
                    t_scale: 1.0,
                    exit_pos_m: None,
                },
            ],
        }
    }

    pub fn total_expected_vehicles(&self) -> f32 {
        self.flows
            .iter()
            .map(|f| f.vehs_per_hour * (f.end_s - f.begin_s) / 3600.0)
            .sum()
    }

    /// Validate every flow against the network: routes must exist and
    /// connect, rates must be finite and non-negative, windows must be
    /// non-empty, scales must be positive.  The scenario compiler runs
    /// this on every generated config.
    pub fn validate(&self, net: &Network) -> Result<()> {
        for f in &self.flows {
            net.validate_route(&f.route)?;
            if !f.vehs_per_hour.is_finite() || f.vehs_per_hour < 0.0 {
                return Err(crate::Error::Config(format!(
                    "flow '{}': bad rate {} vph",
                    f.id, f.vehs_per_hour
                )));
            }
            if f.end_s <= f.begin_s {
                return Err(crate::Error::Config(format!(
                    "flow '{}': empty window [{}, {}]",
                    f.id, f.begin_s, f.end_s
                )));
            }
            if f.v0_scale <= 0.0 || f.t_scale <= 0.0 {
                return Err(crate::Error::Config(format!(
                    "flow '{}': non-positive driver scale",
                    f.id
                )));
            }
            if let Some(gore) = f.exit_pos_m {
                if !gore.is_finite() || gore <= 0.0 {
                    return Err(crate::Error::Config(format!(
                        "flow '{}': bad exit position {gore} m",
                        f.id
                    )));
                }
            }
        }
        Ok(())
    }

    /// Validate destination intent against the stepper's road: an exit
    /// position at or beyond `road_end_m` can never be crossed before
    /// road-end retirement wins, silently degenerating into the
    /// "exiting traffic rides to the road end" mislabeling — refuse it.
    /// Scenario compilers run this alongside [`Self::validate`].
    pub fn validate_exits(&self, road_end_m: f32) -> Result<()> {
        for f in &self.flows {
            if let Some(gore) = f.exit_pos_m {
                if gore >= road_end_m {
                    return Err(crate::Error::Config(format!(
                        "flow '{}': exit position {gore} m is not before the \
                         road end at {road_end_m} m — exits would never fire",
                        f.id
                    )));
                }
            }
        }
        Ok(())
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn merge_sample_has_three_flows() {
        let f = FlowFile::merge_sample(1200.0, 300.0, 300.0);
        assert_eq!(f.flows.len(), 3);
        assert_eq!(f.flows[2].vtype, VehicleType::Cav);
        assert_eq!(f.flows[2].depart_lane, 0);
    }

    #[test]
    fn expected_vehicle_count() {
        let f = FlowFile::merge_sample(1200.0, 300.0, 3600.0);
        assert!((f.total_expected_vehicles() - 1500.0).abs() < 1e-3);
    }

    #[test]
    fn vehicle_types_have_distinct_params() {
        assert!(VehicleType::Cav.params().t_headway < VehicleType::Human.params().t_headway);
    }

    #[test]
    fn scales_perturb_base_params() {
        let mut f = FlowFile::merge_sample(1200.0, 300.0, 60.0).flows[0].clone();
        assert_eq!(f.base_params(), f.vtype.params());
        f.v0_scale = 0.9;
        f.t_scale = 1.2;
        let p = f.base_params();
        assert!((p.v0 - 27.0).abs() < 1e-4);
        assert!((p.t_headway - 1.8).abs() < 1e-4);
        assert_eq!(p.a_max, f.vtype.params().a_max);
    }

    #[test]
    fn exit_intent_reaches_base_params() {
        let mut f = FlowFile::merge_sample(1200.0, 300.0, 60.0).flows[0].clone();
        assert_eq!(f.base_params().exit_flag, 0.0);
        f.exit_pos_m = Some(650.0);
        let p = f.base_params();
        assert_eq!(p.exit_pos, 650.0);
        assert_eq!(p.exit_flag, 1.0);
        assert!(p.exits());
        // the driver calibration itself is untouched by the intent
        assert_eq!(p.a_max, f.vtype.params().a_max);
    }

    #[test]
    fn validate_catches_bad_flows() {
        let net = crate::sumo::MergeScenario::default().network();
        let good = FlowFile::merge_sample(1200.0, 300.0, 60.0);
        good.validate(&net).unwrap();

        let mut bad_route = good.clone();
        bad_route.flows[0].route = vec!["nope".into()];
        assert!(bad_route.validate(&net).is_err());

        let mut bad_rate = good.clone();
        bad_rate.flows[0].vehs_per_hour = -5.0;
        assert!(bad_rate.validate(&net).is_err());

        let mut bad_window = good.clone();
        bad_window.flows[0].end_s = bad_window.flows[0].begin_s;
        assert!(bad_window.validate(&net).is_err());

        let mut bad_exit = good.clone();
        bad_exit.flows[0].exit_pos_m = Some(-1.0);
        assert!(bad_exit.validate(&net).is_err());

        let mut dead_exit = good.clone();
        dead_exit.flows[0].exit_pos_m = Some(650.0);
        dead_exit.validate(&net).unwrap();
        dead_exit.validate_exits(1000.0).unwrap();
        // a gore at/past the road end can never fire
        assert!(dead_exit.validate_exits(650.0).is_err());
        assert!(dead_exit.validate_exits(600.0).is_err());

        let mut bad_scale = good;
        bad_scale.flows[0].t_scale = 0.0;
        assert!(bad_scale.validate(&net).is_err());
    }
}
