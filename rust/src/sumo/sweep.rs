//! Sorted-sweep neighbor index — the O(N log N) replacement for the
//! O(N²)-per-step reference scans (`idm::leader_scan`,
//! `mobil::lane_gap_scan`).
//!
//! Once per step the active slots are bucketed by lane and sorted by
//! position (`rebuild`); every subsequent neighbor query is a partition
//! point into the ego's (or target) lane's sorted run plus a walk over
//! the contiguous equal-`dx` tie run, which reproduces the reference
//! mask-min tie-breaking **bit-exactly** (asserted by
//! `rust/tests/sweep_props.rs` and pre-validated by
//! `scripts/validate_sweep.py`).
//!
//! Why exact: f32 subtraction `x_j - x_i` is monotone non-decreasing in
//! `x_j` for fixed `x_i`, so within a lane sorted by `x` the predicate
//! `dx > 1e-6` is a prefix/suffix property and the set `dx == min dx`
//! (the reference's `dx <= center` mask under `dx >= center` from
//! sortedness) is a contiguous run.
//!
//! The index buffers are owned scratch, reused across steps with no
//! steady-state allocation (`rebuild` only clears and refills).
//!
//! The schema-3 destination columns (`exit_pos`/`exit_flag`) ride the
//! params row and never influence neighbor *queries* — only the MOBIL
//! decision and retirement layers read them — so the index needs no
//! route awareness and stays bit-exact with the reference scans for
//! flagged and unflagged traffic alike (`tests/sweep_props.rs` mixes
//! both).
//!
//! Invariant: lane values must be integral (they are everywhere in the
//! simulation — spawns use `lane as f32`, MOBIL emits `lane ± 1.0`);
//! `rebuild` debug-asserts it.  Under that invariant, grouping by
//! `lane.round()` is exactly the reference's `|lane_j - lane_i| < 0.5`
//! same-lane test.

use super::idm::{Leader, FREE_GAP};
use super::mobil::LaneGaps;
use super::state::{Traffic, P_LEN};

/// Co-location epsilon — matches the reference scans' `1e-6`.
const EPS: f32 = 1e-6;

#[derive(Debug, Clone, Default)]
struct LaneGroup {
    key: i32,
    /// `(x, slot)` for every active vehicle on this lane, sorted by `x`.
    slots: Vec<(f32, u32)>,
}

/// The per-step sorted position index (one sorted run per lane).
#[derive(Debug, Clone, Default)]
pub struct LaneIndex {
    groups: Vec<LaneGroup>,
}

impl LaneIndex {
    pub fn new() -> LaneIndex {
        LaneIndex::default()
    }

    /// Re-bucket and re-sort the active slots.  Reuses all buffers; the
    /// only allocation ever is growth on first use / first sight of a
    /// new lane.
    pub fn rebuild(&mut self, t: &Traffic) {
        for g in &mut self.groups {
            g.slots.clear();
        }
        for i in 0..t.capacity() {
            if !t.is_active(i) {
                continue;
            }
            let lane = t.lane(i);
            debug_assert!(
                lane == lane.round(),
                "sorted sweep requires integral lane values, got {lane}"
            );
            let key = lane.round() as i32;
            let gi = match self.groups.iter().position(|g| g.key == key) {
                Some(gi) => gi,
                None => {
                    self.groups.push(LaneGroup {
                        key,
                        slots: Vec::new(),
                    });
                    self.groups.len() - 1
                }
            };
            self.groups[gi].slots.push((t.x(i), i as u32));
        }
        for g in &mut self.groups {
            g.slots.sort_unstable_by(|a, b| a.0.total_cmp(&b.0));
        }
    }

    fn group(&self, target_lane: f32) -> Option<&LaneGroup> {
        let key = target_lane.round() as i32;
        self.groups.iter().find(|g| g.key == key)
    }

    /// Nearest-ahead scan on `target_lane` from position `xi`:
    /// `(center, v, len)` where `center` is the minimal `dx > EPS`
    /// (`FREE_GAP` when none) and `v`/`len` are the mask-min speed and
    /// length over the exact `dx == center` tie run.
    fn scan_ahead(&self, t: &Traffic, target_lane: f32, xi: f32) -> (f32, f32, f32) {
        let Some(g) = self.group(target_lane) else {
            return (FREE_GAP, FREE_GAP, FREE_GAP);
        };
        let s = &g.slots;
        let start = s.partition_point(|&(x, _)| x - xi <= EPS);
        if start == s.len() {
            return (FREE_GAP, FREE_GAP, FREE_GAP);
        }
        let center = s[start].0 - xi;
        let mut lv = FREE_GAP;
        let mut llen = FREE_GAP;
        for &(x, slot) in &s[start..] {
            if x - xi > center {
                break;
            }
            lv = lv.min(t.v(slot as usize));
            llen = llen.min(t.param(slot as usize, P_LEN));
        }
        (center, lv, llen)
    }

    /// Nearest-behind scan on `target_lane` from position `xi`:
    /// `(lag_center, v)` where `lag_center` is the minimal `-dx` over
    /// `dx < -EPS` (`FREE_GAP` when none) and `v` is the mask-min speed
    /// over the exact tie run.
    fn scan_behind(&self, t: &Traffic, target_lane: f32, xi: f32) -> (f32, f32) {
        let Some(g) = self.group(target_lane) else {
            return (FREE_GAP, FREE_GAP);
        };
        let s = &g.slots;
        let end = s.partition_point(|&(x, _)| x - xi < -EPS);
        if end == 0 {
            return (FREE_GAP, FREE_GAP);
        }
        let dx_last = s[end - 1].0 - xi;
        let lag_center = -dx_last;
        let mut lag_v = FREE_GAP;
        for &(x, slot) in s[..end].iter().rev() {
            if x - xi != dx_last {
                break;
            }
            lag_v = lag_v.min(t.v(slot as usize));
        }
        (lag_center, lag_v)
    }

    /// Drop-in for [`super::idm::leader_scan`] — identical result, bit
    /// for bit.  `i` must be an active slot of the `t` this index was
    /// rebuilt from.
    pub fn leader(&self, t: &Traffic, i: usize) -> Leader {
        let xi = t.x(i);
        let (center, lv, llen) = self.scan_ahead(t, t.lane(i), xi);
        if center >= FREE_GAP * 0.5 {
            return Leader {
                gap: FREE_GAP,
                v: t.v(i),
                exists: false,
            };
        }
        Leader {
            gap: center - llen,
            v: lv,
            exists: true,
        }
    }

    /// Drop-in for [`super::mobil::lane_gap_scan`] — identical result,
    /// bit for bit.
    pub fn lane_gaps(&self, t: &Traffic, i: usize, target_lane: f32) -> LaneGaps {
        let xi = t.x(i);
        let (lead_center, lead_v, lead_len) = self.scan_ahead(t, target_lane, xi);
        let (lag_center, lag_v) = self.scan_behind(t, target_lane, xi);
        let lead_has = lead_center < FREE_GAP * 0.5;
        let lag_has = lag_center < FREE_GAP * 0.5;
        LaneGaps {
            lead_gap: if lead_has {
                lead_center - lead_len
            } else {
                FREE_GAP
            },
            lead_v: if lead_has { lead_v } else { t.v(i) },
            lag_gap: if lag_has {
                lag_center - t.param(i, P_LEN)
            } else {
                FREE_GAP
            },
            lag_v: if lag_has { lag_v } else { t.v(i) },
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::sumo::idm::leader_scan;
    use crate::sumo::mobil::lane_gap_scan;
    use crate::sumo::state::DriverParams;

    fn traffic(rows: &[(f32, f32, f32)]) -> Traffic {
        let mut t = Traffic::new(rows.len());
        for &(x, v, lane) in rows {
            t.spawn(x, v, lane, DriverParams::default());
        }
        t
    }

    #[test]
    fn matches_reference_on_small_scene() {
        let t = traffic(&[
            (100.0, 20.0, 1.0),
            (150.0, 10.0, 1.0),
            (120.0, 5.0, 2.0),
            (80.0, 12.0, 1.0),
        ]);
        let mut idx = LaneIndex::new();
        idx.rebuild(&t);
        for i in 0..t.capacity() {
            assert_eq!(idx.leader(&t, i), leader_scan(&t, i), "slot {i}");
            for target in [0.0f32, 1.0, 2.0] {
                let a = idx.lane_gaps(&t, i, target);
                let b = lane_gap_scan(&t, i, target);
                assert_eq!(
                    (a.lead_gap, a.lead_v, a.lag_gap, a.lag_v),
                    (b.lead_gap, b.lead_v, b.lag_gap, b.lag_v),
                    "slot {i} target {target}"
                );
            }
        }
    }

    #[test]
    fn colocated_ties_use_mask_min() {
        // two leaders at the same x: mask-min picks the smaller speed
        let t = traffic(&[(100.0, 20.0, 1.0), (150.0, 18.0, 1.0), (150.0, 3.0, 1.0)]);
        let mut idx = LaneIndex::new();
        idx.rebuild(&t);
        let l = idx.leader(&t, 0);
        assert_eq!(l, leader_scan(&t, 0));
        assert_eq!(l.v, 3.0);
    }

    #[test]
    fn empty_lane_has_no_neighbors() {
        let t = traffic(&[(100.0, 20.0, 1.0)]);
        let mut idx = LaneIndex::new();
        idx.rebuild(&t);
        let g = idx.lane_gaps(&t, 0, 2.0);
        assert_eq!(g.lead_gap, FREE_GAP);
        assert_eq!(g.lag_gap, FREE_GAP);
        assert!(!idx.leader(&t, 0).exists);
    }

    #[test]
    fn rebuild_reuses_buffers_across_steps() {
        let mut t = traffic(&[(100.0, 20.0, 1.0), (150.0, 10.0, 1.0)]);
        let mut idx = LaneIndex::new();
        idx.rebuild(&t);
        assert!(idx.leader(&t, 0).exists);
        t.deactivate(1);
        idx.rebuild(&t);
        assert!(!idx.leader(&t, 0).exists);
    }
}
