//! The TraCI server: SUMO's side of the socket.
//!
//! One server per simulation instance, bound to the instance's unique
//! port.  Binding an already-used port returns [`crate::Error::PortInUse`]
//! — the paper's §4.2.1 crash, straight from the kernel.
//!
//! The server runs the [`SumoSim`] loop on a std thread (blocking I/O is
//! fine: one client per server, tiny frames).

use std::io::Write;
use std::net::{TcpListener, TcpStream};
use std::thread::JoinHandle;

use crate::sumo::SumoSim;
use crate::{Error, Result};

use super::protocol::{read_frame, Command, Response};

/// A bound, running TraCI server.
#[derive(Debug)]
pub struct TraciServer {
    pub port: u16,
    handle: Option<JoinHandle<Result<()>>>,
}

impl TraciServer {
    /// Bind `127.0.0.1:port` and serve `sim` until the client closes.
    ///
    /// The bind happens *synchronously* so the duplicate-port failure
    /// surfaces at spawn time, exactly like SUMO aborting at startup.
    pub fn spawn(port: u16, sim: SumoSim) -> Result<TraciServer> {
        let listener = TcpListener::bind(("127.0.0.1", port)).map_err(|e| {
            if e.kind() == std::io::ErrorKind::AddrInUse {
                Error::PortInUse(port)
            } else {
                Error::Io(e)
            }
        })?;
        let handle = std::thread::spawn(move || serve(listener, sim));
        Ok(TraciServer {
            port,
            handle: Some(handle),
        })
    }

    /// Serve `sim` on an already-bound listener — the redemption path
    /// for [`crate::pipeline::PortLease`], where the port was never
    /// released between allocation and serving (no rebind, no TOCTOU
    /// window).
    pub fn spawn_on(listener: TcpListener, sim: SumoSim) -> Result<TraciServer> {
        let port = listener.local_addr()?.port();
        let handle = std::thread::spawn(move || serve(listener, sim));
        Ok(TraciServer {
            port,
            handle: Some(handle),
        })
    }

    /// Wait for the serving thread to finish (client sent Close).
    pub fn join(mut self) -> Result<()> {
        match self.handle.take() {
            Some(h) => h
                .join()
                .map_err(|_| Error::Protocol("traci server thread panicked".into()))?,
            None => Ok(()),
        }
    }
}

/// Drop guard: a server the launcher never [`TraciServer::join`]ed (an
/// early-error path between spawn and the front-end handshake, or an
/// unwinding panic) must not leak its serving thread.  The thread is
/// either blocked in `accept()` — no client ever connected — or already
/// winding down after its client vanished; a one-shot connection
/// carrying `Close` unblocks the former, and joining reaps the thread
/// so the port and stack are released before the error propagates.
impl Drop for TraciServer {
    fn drop(&mut self) {
        let Some(handle) = self.handle.take() else {
            return;
        };
        let nudge = TcpStream::connect(("127.0.0.1", self.port)).ok();
        if let Some(mut s) = nudge.as_ref() {
            // best-effort: the thread may already be past accept()
            let _ = s.write_all(&Command::Close.encode());
        }
        let _ = handle.join();
        // `nudge` stays open until after the join so the server's reply
        // write cannot race a closed socket
        drop(nudge);
    }
}

fn serve(listener: TcpListener, mut sim: SumoSim) -> Result<()> {
    let (stream, _) = listener.accept()?;
    handle_client(stream, &mut sim)
}

fn handle_client(mut stream: TcpStream, sim: &mut SumoSim) -> Result<()> {
    stream.set_nodelay(true)?;
    loop {
        let body = read_frame(&mut stream)?;
        let cmd = match Command::decode(&body) {
            Ok(c) => c,
            Err(e) => {
                stream.write_all(&Response::Err(e.to_string()).encode())?;
                continue;
            }
        };
        let resp = match cmd {
            Command::GetVersion => Response::Version {
                major: super::protocol::PROTOCOL_MAJOR,
                minor: super::protocol::PROTOCOL_MINOR,
            },
            Command::SimStep => {
                let o = sim.step();
                Response::Stepped {
                    n_active: o.n_active,
                    mean_speed: o.mean_speed,
                    flow: o.flow,
                    n_merged: o.n_merged,
                    n_exited: o.n_exited,
                }
            }
            Command::SimStepN { n } => {
                let n = n.min(10_000); // sanity cap
                // chunk-scheduled: departure-free runs inside the burst
                // become single fused dispatches on the HLO stepper,
                // with the per-step obs trace preserved for the frame
                let mut burst = Vec::with_capacity(n as usize);
                sim.step_many(n as u64, &mut burst);
                let mut obs = Vec::with_capacity(burst.len() * super::protocol::OBS_STRIDE);
                for o in &burst {
                    obs.extend_from_slice(&[
                        o.n_active,
                        o.mean_speed,
                        o.flow,
                        o.n_merged,
                        o.n_exited,
                    ]);
                }
                Response::SteppedN(obs)
            }
            Command::GetVehicleCount => {
                Response::VehicleCount(sim.traffic.active_count() as u32)
            }
            Command::GetState => Response::State(sim.traffic.state.clone()),
            Command::SetSpeed { slot, speed } => {
                let i = slot as usize;
                if i < sim.traffic.capacity() && sim.traffic.is_active(i) {
                    let (x, lane) = (sim.traffic.x(i), sim.traffic.lane(i));
                    sim.traffic.set_state_row(i, x, speed.max(0.0), lane, true);
                    Response::Ok
                } else {
                    Response::Err(format!("no active vehicle in slot {slot}"))
                }
            }
            Command::GetTotals => Response::Totals {
                flow: sim.total_flow,
                merged: sim.total_merged,
                exited: sim.total_exited,
                spawned: sim.total_spawned,
            },
            Command::GetRunStats => Response::RunStats {
                steps: sim.step_count(),
                resident_steps: sim.resident_steps(),
            },
            Command::Close => {
                stream.write_all(&Response::Closing.encode())?;
                return Ok(());
            }
        };
        stream.write_all(&resp.encode())?;
    }
}

#[cfg(test)]
#[allow(clippy::unwrap_used, clippy::expect_used)]
mod tests {
    use super::*;
    use crate::sumo::{duarouter, FlowFile, MergeScenario, NativeIdmStepper, SumoSim};
    use crate::traci::TraciClient;

    fn test_sim() -> SumoSim {
        let scenario = MergeScenario::default();
        let net = scenario.network();
        let flows = FlowFile::merge_sample(1200.0, 300.0, 60.0);
        let routes = duarouter(&net, &flows, 1).unwrap();
        SumoSim::new(scenario, 64, routes, Box::new(NativeIdmStepper::default()))
    }

    /// Ephemeral test port (kernel-assigned to avoid collisions between
    /// parallel test binaries).
    fn free_port() -> u16 {
        TcpListener::bind("127.0.0.1:0")
            .unwrap()
            .local_addr()
            .unwrap()
            .port()
    }

    #[test]
    fn duplicate_port_is_a_real_error() {
        // §4.2.1, mechanically: second bind on one port fails
        let port = free_port();
        let s1 = TraciServer::spawn(port, test_sim()).unwrap();
        let err = TraciServer::spawn(port, test_sim()).unwrap_err();
        assert!(matches!(err, Error::PortInUse(p) if p == port));
        // clean shutdown of the survivor
        let mut c = TraciClient::connect(port).unwrap();
        c.close().unwrap();
        s1.join().unwrap();
    }

    #[test]
    fn dropped_unjoined_server_releases_port_and_thread() {
        // the early-error launcher path: spawned, but the front-end
        // never connected and nobody called join()
        let port = free_port();
        {
            let _server = TraciServer::spawn(port, test_sim()).unwrap();
        }
        // the drop guard reaped the serving thread → port re-bindable
        assert!(
            TcpListener::bind(("127.0.0.1", port)).is_ok(),
            "port must be released by the drop guard"
        );
    }

    #[test]
    fn full_session_roundtrip() {
        let port = free_port();
        let server = TraciServer::spawn(port, test_sim()).unwrap();
        let mut c = TraciClient::connect(port).unwrap();

        let (maj, min) = c.get_version().unwrap();
        assert_eq!(
            (maj, min),
            (
                super::super::protocol::PROTOCOL_MAJOR,
                super::super::protocol::PROTOCOL_MINOR
            )
        );
        c.check_version().unwrap();

        // drive 100 steps; traffic must appear
        for _ in 0..100 {
            c.sim_step().unwrap();
        }
        assert!(c.get_vehicle_count().unwrap() > 0);

        let state = c.get_state().unwrap();
        assert_eq!(state.len(), 64 * 4);

        let totals = c.get_totals().unwrap();
        assert!(totals.3 > 0, "spawned someone");

        c.close().unwrap();
        server.join().unwrap();
    }

    #[test]
    fn set_speed_actuates() {
        let port = free_port();
        let server = TraciServer::spawn(port, test_sim()).unwrap();
        let mut c = TraciClient::connect(port).unwrap();
        for _ in 0..100 {
            c.sim_step().unwrap();
        }
        // find an active slot from the snapshot
        let state = c.get_state().unwrap();
        let slot = (0..64).find(|i| state[i * 4 + 3] > 0.5).expect("some active");
        c.set_speed(slot as u32, 3.25).unwrap();
        let state2 = c.get_state().unwrap();
        assert_eq!(state2[slot * 4 + 1], 3.25);
        // inactive slot errors
        let free = (0..64).find(|i| state[i * 4 + 3] < 0.5).expect("some free");
        assert!(c.set_speed(free as u32, 1.0).is_err());
        c.close().unwrap();
        server.join().unwrap();
    }
}
