//! TraCI wire protocol: length-prefixed binary frames.
//!
//! Frame layout (simplified from SUMO's): `u32 len | u8 cmd | payload`.
//! All integers little-endian; vehicle state payloads are the flat f32
//! rows of [`crate::sumo::Traffic`].

use crate::{Error, Result};

/// SUMO's default TraCI port; the paper's world files shipped with 8873
/// and the pipeline "tended to increment the default port value of 8873
/// by 7 for each successive parallel simulation" (§4.2.1).
pub const DEFAULT_PORT: u16 = 8873;
/// The paper's increment between parallel copies.
pub const PORT_STEP: u16 = 7;

/// f32s per step in `Stepped`/`SteppedN` frames — the [`crate::sumo::StepObs`]
/// field count ([n_active, mean_speed, flow, n_merged, n_exited]).
pub const OBS_STRIDE: usize = 5;

/// Protocol version, negotiated via `GetVersion`.  Minor 1 = the
/// schema-3 wire widening (5-f32 obs stride in `Stepped`/`SteppedN`,
/// `exited` in `Totals`): a version-skewed peer would *misparse* those
/// payloads rather than error, so [`super::TraciClient::check_version`]
/// fails the handshake loudly instead.  Minor 2 adds
/// `GetRunStats`/`RunStats` (device-resident whole-run provenance) — a
/// 1.1 server would answer it with an unknown-opcode error mid-run, so
/// the skew is still refused at the handshake.
pub const PROTOCOL_MAJOR: u32 = 1;
pub const PROTOCOL_MINOR: u32 = 2;

/// Client → server commands.
#[derive(Debug, Clone, PartialEq)]
pub enum Command {
    /// Protocol handshake.
    GetVersion,
    /// Advance the simulation one DT.
    SimStep,
    /// Advance the simulation `n` DTs in one round trip (§Perf: batches
    /// socket round-trips between controller sampling points).
    SimStepN { n: u32 },
    /// Number of active vehicles.
    GetVehicleCount,
    /// Full state snapshot (x, v, lane, active per slot).
    GetState,
    /// Override a vehicle's speed (the CAV controller's actuation path).
    SetSpeed { slot: u32, speed: f32 },
    /// Cumulative totals (flow, merged, spawned).
    GetTotals,
    /// Execution-path provenance: how many steps ran, and how many of
    /// them rode the device-resident whole-run dispatch path.
    GetRunStats,
    /// Orderly shutdown.
    Close,
}

impl Command {
    pub fn opcode(&self) -> u8 {
        match self {
            Command::GetVersion => 0x00,
            Command::SimStep => 0x02,
            Command::SimStepN { .. } => 0x03,
            Command::GetVehicleCount => 0x10,
            Command::GetState => 0x11,
            Command::SetSpeed { .. } => 0x31,
            Command::GetTotals => 0x12,
            Command::GetRunStats => 0x13,
            Command::Close => 0x7f,
        }
    }

    pub fn encode(&self) -> Vec<u8> {
        let mut payload = vec![self.opcode()];
        match self {
            Command::SetSpeed { slot, speed } => {
                payload.extend_from_slice(&slot.to_le_bytes());
                payload.extend_from_slice(&speed.to_le_bytes());
            }
            Command::SimStepN { n } => payload.extend_from_slice(&n.to_le_bytes()),
            _ => {}
        }
        frame(payload)
    }

    pub fn decode(buf: &[u8]) -> Result<Command> {
        let (op, rest) = buf
            .split_first()
            .ok_or_else(|| Error::Protocol("empty command frame".into()))?;
        Ok(match op {
            0x00 => Command::GetVersion,
            0x02 => Command::SimStep,
            0x03 => {
                if rest.len() != 4 {
                    return Err(Error::Protocol(format!(
                        "SimStepN payload {} bytes, want 4",
                        rest.len()
                    )));
                }
                Command::SimStepN {
                    n: le_u32(rest, 0)?,
                }
            }
            0x10 => Command::GetVehicleCount,
            0x11 => Command::GetState,
            0x31 => {
                if rest.len() != 8 {
                    return Err(Error::Protocol(format!(
                        "SetSpeed payload {} bytes, want 8",
                        rest.len()
                    )));
                }
                Command::SetSpeed {
                    slot: le_u32(rest, 0)?,
                    speed: le_f32(rest, 4)?,
                }
            }
            0x12 => Command::GetTotals,
            0x13 => Command::GetRunStats,
            0x7f => Command::Close,
            other => return Err(Error::Protocol(format!("unknown opcode {other:#x}"))),
        })
    }
}

/// Server → client responses.
#[derive(Debug, Clone, PartialEq)]
pub enum Response {
    Version { major: u32, minor: u32 },
    /// Step acknowledged; per-step observables.
    Stepped {
        n_active: f32,
        mean_speed: f32,
        flow: f32,
        n_merged: f32,
        n_exited: f32,
    },
    /// N steps acknowledged; per-step observables, flat
    /// [n_active, mean_speed, flow, n_merged, n_exited] × n.
    SteppedN(Vec<f32>),
    VehicleCount(u32),
    /// Flat state rows (len = slots * 4).
    State(Vec<f32>),
    Ok,
    Totals {
        flow: f32,
        merged: f32,
        exited: f32,
        spawned: u64,
    },
    /// Execution-path provenance (`steps` total, of which
    /// `resident_steps` were device-resident whole-run dispatches).
    RunStats { steps: u64, resident_steps: u64 },
    Closing,
    Err(String),
}

impl Response {
    pub fn opcode(&self) -> u8 {
        match self {
            Response::Version { .. } => 0x80,
            Response::Stepped { .. } => 0x82,
            Response::SteppedN(_) => 0x83,
            Response::VehicleCount(_) => 0x90,
            Response::State(_) => 0x91,
            Response::Ok => 0xa0,
            Response::Totals { .. } => 0x92,
            Response::RunStats { .. } => 0x93,
            Response::Closing => 0xff,
            Response::Err(_) => 0xee,
        }
    }

    pub fn encode(&self) -> Vec<u8> {
        let mut p = vec![self.opcode()];
        match self {
            Response::Version { major, minor } => {
                p.extend_from_slice(&major.to_le_bytes());
                p.extend_from_slice(&minor.to_le_bytes());
            }
            Response::Stepped {
                n_active,
                mean_speed,
                flow,
                n_merged,
                n_exited,
            } => {
                for v in [n_active, mean_speed, flow, n_merged, n_exited] {
                    p.extend_from_slice(&v.to_le_bytes());
                }
            }
            Response::SteppedN(obs) => {
                p.extend_from_slice(&((obs.len() / OBS_STRIDE) as u32).to_le_bytes());
                for v in obs {
                    p.extend_from_slice(&v.to_le_bytes());
                }
            }
            Response::VehicleCount(n) => p.extend_from_slice(&n.to_le_bytes()),
            Response::State(rows) => {
                p.extend_from_slice(&(rows.len() as u32).to_le_bytes());
                for v in rows {
                    p.extend_from_slice(&v.to_le_bytes());
                }
            }
            Response::Ok | Response::Closing => {}
            Response::Totals {
                flow,
                merged,
                exited,
                spawned,
            } => {
                p.extend_from_slice(&flow.to_le_bytes());
                p.extend_from_slice(&merged.to_le_bytes());
                p.extend_from_slice(&exited.to_le_bytes());
                p.extend_from_slice(&spawned.to_le_bytes());
            }
            Response::RunStats {
                steps,
                resident_steps,
            } => {
                p.extend_from_slice(&steps.to_le_bytes());
                p.extend_from_slice(&resident_steps.to_le_bytes());
            }
            Response::Err(msg) => {
                let b = msg.as_bytes();
                p.extend_from_slice(&(b.len() as u32).to_le_bytes());
                p.extend_from_slice(b);
            }
        }
        frame(p)
    }

    pub fn decode(buf: &[u8]) -> Result<Response> {
        let (op, r) = buf
            .split_first()
            .ok_or_else(|| Error::Protocol("empty response frame".into()))?;
        let need = |n: usize| -> Result<()> {
            if r.len() < n {
                Err(Error::Protocol(format!(
                    "short response: {} bytes, need {n}",
                    r.len()
                )))
            } else {
                Ok(())
            }
        };
        Ok(match op {
            0x80 => {
                need(8)?;
                Response::Version {
                    major: le_u32(r, 0)?,
                    minor: le_u32(r, 4)?,
                }
            }
            0x82 => {
                need(OBS_STRIDE * 4)?;
                Response::Stepped {
                    n_active: le_f32(r, 0)?,
                    mean_speed: le_f32(r, 4)?,
                    flow: le_f32(r, 8)?,
                    n_merged: le_f32(r, 12)?,
                    n_exited: le_f32(r, 16)?,
                }
            }
            0x83 => {
                need(4)?;
                let n = le_u32(r, 0)? as usize;
                need(4 + n * OBS_STRIDE * 4)?;
                let obs = (0..n * OBS_STRIDE)
                    .map(|i| le_f32(r, 4 + i * 4))
                    .collect::<Result<_>>()?;
                Response::SteppedN(obs)
            }
            0x90 => {
                need(4)?;
                Response::VehicleCount(le_u32(r, 0)?)
            }
            0x91 => {
                need(4)?;
                let n = le_u32(r, 0)? as usize;
                need(4 + n * 4)?;
                let rows = (0..n)
                    .map(|i| le_f32(r, 4 + i * 4))
                    .collect::<Result<_>>()?;
                Response::State(rows)
            }
            0xa0 => Response::Ok,
            0x92 => {
                need(20)?;
                Response::Totals {
                    flow: le_f32(r, 0)?,
                    merged: le_f32(r, 4)?,
                    exited: le_f32(r, 8)?,
                    spawned: le_u64(r, 12)?,
                }
            }
            0x93 => {
                need(16)?;
                Response::RunStats {
                    steps: le_u64(r, 0)?,
                    resident_steps: le_u64(r, 8)?,
                }
            }
            0xff => Response::Closing,
            0xee => {
                need(4)?;
                let n = le_u32(r, 0)? as usize;
                need(4 + n)?;
                Response::Err(String::from_utf8_lossy(&r[4..4 + n]).into_owned())
            }
            other => return Err(Error::Protocol(format!("unknown response opcode {other:#x}"))),
        })
    }
}

/// Fallible little-endian field readers: these frames arrive off the
/// wire, so a short slice is a protocol error, never a panic — even
/// after a `need()` length check (the lint denies the panic path, and
/// the check and the read can drift apart under maintenance).
fn le_u32(buf: &[u8], at: usize) -> Result<u32> {
    match buf.get(at..at + 4).and_then(|b| b.try_into().ok()) {
        Some(b) => Ok(u32::from_le_bytes(b)),
        None => Err(Error::Protocol(format!("short frame: no u32 at {at}"))),
    }
}

fn le_u64(buf: &[u8], at: usize) -> Result<u64> {
    match buf.get(at..at + 8).and_then(|b| b.try_into().ok()) {
        Some(b) => Ok(u64::from_le_bytes(b)),
        None => Err(Error::Protocol(format!("short frame: no u64 at {at}"))),
    }
}

fn le_f32(buf: &[u8], at: usize) -> Result<f32> {
    match buf.get(at..at + 4).and_then(|b| b.try_into().ok()) {
        Some(b) => Ok(f32::from_le_bytes(b)),
        None => Err(Error::Protocol(format!("short frame: no f32 at {at}"))),
    }
}

/// Prefix a payload with its u32 length.
fn frame(payload: Vec<u8>) -> Vec<u8> {
    let mut out = Vec::with_capacity(4 + payload.len());
    out.extend_from_slice(&(payload.len() as u32).to_le_bytes());
    out.extend(payload);
    out
}

/// Read one `u32 len | payload` frame from a stream.
pub fn read_frame<R: std::io::Read>(r: &mut R) -> Result<Vec<u8>> {
    let mut len = [0u8; 4];
    r.read_exact(&mut len)?;
    let len = u32::from_le_bytes(len) as usize;
    if len > 64 * 1024 * 1024 {
        return Err(Error::Protocol(format!("frame too large: {len}")));
    }
    let mut buf = vec![0u8; len];
    r.read_exact(&mut buf)?;
    Ok(buf)
}

#[cfg(test)]
#[allow(clippy::unwrap_used, clippy::expect_used)]
mod tests {
    use super::*;

    fn roundtrip_cmd(c: Command) {
        let enc = c.encode();
        let body = &enc[4..];
        assert_eq!(Command::decode(body).unwrap(), c);
        // frame length prefix correct
        assert_eq!(u32::from_le_bytes(enc[0..4].try_into().unwrap()) as usize, body.len());
    }

    #[test]
    fn command_roundtrips() {
        roundtrip_cmd(Command::GetVersion);
        roundtrip_cmd(Command::SimStep);
        roundtrip_cmd(Command::SimStepN { n: 5 });
        roundtrip_cmd(Command::GetVehicleCount);
        roundtrip_cmd(Command::GetState);
        roundtrip_cmd(Command::SetSpeed { slot: 7, speed: 13.5 });
        roundtrip_cmd(Command::GetTotals);
        roundtrip_cmd(Command::GetRunStats);
        roundtrip_cmd(Command::Close);
    }

    fn roundtrip_resp(r: Response) {
        let enc = r.encode();
        assert_eq!(Response::decode(&enc[4..]).unwrap(), r);
    }

    #[test]
    fn response_roundtrips() {
        roundtrip_resp(Response::Version { major: 1, minor: 0 });
        roundtrip_resp(Response::Stepped {
            n_active: 12.0,
            mean_speed: 21.5,
            flow: 1.0,
            n_merged: 0.0,
            n_exited: 2.0,
        });
        roundtrip_resp(Response::SteppedN(vec![1.0; 2 * OBS_STRIDE]));
        roundtrip_resp(Response::VehicleCount(48));
        roundtrip_resp(Response::State(vec![1.0, 2.0, 3.0, 1.0]));
        roundtrip_resp(Response::Ok);
        roundtrip_resp(Response::Totals {
            flow: 40.0,
            merged: 8.0,
            exited: 5.0,
            spawned: 52,
        });
        roundtrip_resp(Response::RunStats {
            steps: 1800,
            resident_steps: 1200,
        });
        roundtrip_resp(Response::Closing);
        roundtrip_resp(Response::Err("boom".into()));
    }

    #[test]
    fn decode_rejects_garbage() {
        assert!(Command::decode(&[]).is_err());
        assert!(Command::decode(&[0x55]).is_err());
        assert!(Command::decode(&[0x31, 1, 2]).is_err()); // short SetSpeed
        assert!(Response::decode(&[0x91, 10, 0, 0, 0]).is_err()); // short state
    }

    #[test]
    fn read_frame_from_stream() {
        let enc = Command::SimStep.encode();
        let mut cur = std::io::Cursor::new(enc);
        let body = read_frame(&mut cur).unwrap();
        assert_eq!(Command::decode(&body).unwrap(), Command::SimStep);
    }

    #[test]
    fn paper_port_arithmetic() {
        assert_eq!(DEFAULT_PORT, 8873);
        assert_eq!(DEFAULT_PORT + 3 * PORT_STEP, 8894);
    }
}
