//! The TraCI client: Webots' side of the socket (the SUMO Interface
//! node connects through this).

use std::io::Write;
use std::net::TcpStream;

use crate::{Error, Result};

use super::protocol::{read_frame, Command, Response};

/// A connected TraCI client.
pub struct TraciClient {
    stream: TcpStream,
}

impl TraciClient {
    /// Connect and handshake: a version-skewed peer is refused here, at
    /// every consumer, because it would silently *misparse* the wire
    /// frames rather than error (see [`Self::check_version`]).
    pub fn connect(port: u16) -> Result<TraciClient> {
        let stream = TcpStream::connect(("127.0.0.1", port))?;
        stream.set_nodelay(true)?;
        let mut client = TraciClient { stream };
        client.check_version()?;
        Ok(client)
    }

    fn call(&mut self, cmd: Command) -> Result<Response> {
        self.stream.write_all(&cmd.encode())?;
        let body = read_frame(&mut self.stream)?;
        let resp = Response::decode(&body)?;
        if let Response::Err(msg) = &resp {
            return Err(Error::Protocol(format!("server error: {msg}")));
        }
        Ok(resp)
    }

    pub fn get_version(&mut self) -> Result<(u32, u32)> {
        match self.call(Command::GetVersion)? {
            Response::Version { major, minor } => Ok((major, minor)),
            other => Err(unexpected("Version", &other)),
        }
    }

    /// Handshake: refuse a version-skewed peer.  The schema-3 wire
    /// widening (protocol 1.1: 5-f32 obs stride, `exited` totals) would
    /// be silently *misparsed* by an older/newer peer, so skew must
    /// fail loudly here instead of scrambling every observable.
    pub fn check_version(&mut self) -> Result<()> {
        use super::protocol::{PROTOCOL_MAJOR, PROTOCOL_MINOR};
        let (major, minor) = self.get_version()?;
        if (major, minor) != (PROTOCOL_MAJOR, PROTOCOL_MINOR) {
            return Err(Error::Protocol(format!(
                "TraCI version skew: server speaks {major}.{minor}, client \
                 speaks {PROTOCOL_MAJOR}.{PROTOCOL_MINOR} (schema-3 obs stride)"
            )));
        }
        Ok(())
    }

    /// Advance the back-end one DT; returns the per-step observables
    /// `(n_active, mean_speed, flow, n_merged, n_exited)`.
    pub fn sim_step(&mut self) -> Result<(f32, f32, f32, f32, f32)> {
        match self.call(Command::SimStep)? {
            Response::Stepped {
                n_active,
                mean_speed,
                flow,
                n_merged,
                n_exited,
            } => Ok((n_active, mean_speed, flow, n_merged, n_exited)),
            other => Err(unexpected("Stepped", &other)),
        }
    }

    /// Advance `n` DTs in one round trip; returns per-step observables.
    pub fn sim_step_n(&mut self, n: u32) -> Result<Vec<(f32, f32, f32, f32, f32)>> {
        match self.call(Command::SimStepN { n })? {
            Response::SteppedN(flat) => Ok(flat
                .chunks_exact(super::protocol::OBS_STRIDE)
                .map(|c| (c[0], c[1], c[2], c[3], c[4]))
                .collect()),
            other => Err(unexpected("SteppedN", &other)),
        }
    }

    pub fn get_vehicle_count(&mut self) -> Result<u32> {
        match self.call(Command::GetVehicleCount)? {
            Response::VehicleCount(n) => Ok(n),
            other => Err(unexpected("VehicleCount", &other)),
        }
    }

    /// Flat state rows (slots × [x, v, lane, active]).
    pub fn get_state(&mut self) -> Result<Vec<f32>> {
        match self.call(Command::GetState)? {
            Response::State(rows) => Ok(rows),
            other => Err(unexpected("State", &other)),
        }
    }

    pub fn set_speed(&mut self, slot: u32, speed: f32) -> Result<()> {
        match self.call(Command::SetSpeed { slot, speed })? {
            Response::Ok => Ok(()),
            other => Err(unexpected("Ok", &other)),
        }
    }

    /// `(total_flow, total_merged, total_exited, total_spawned)`.
    pub fn get_totals(&mut self) -> Result<(f32, f32, f32, u64)> {
        match self.call(Command::GetTotals)? {
            Response::Totals {
                flow,
                merged,
                exited,
                spawned,
            } => Ok((flow, merged, exited, spawned)),
            other => Err(unexpected("Totals", &other)),
        }
    }

    /// `(steps, resident_steps)` — execution-path provenance: how many
    /// of the run's steps were device-resident whole-run dispatches.
    pub fn get_run_stats(&mut self) -> Result<(u64, u64)> {
        match self.call(Command::GetRunStats)? {
            Response::RunStats {
                steps,
                resident_steps,
            } => Ok((steps, resident_steps)),
            other => Err(unexpected("RunStats", &other)),
        }
    }

    pub fn close(&mut self) -> Result<()> {
        match self.call(Command::Close)? {
            Response::Closing => Ok(()),
            other => Err(unexpected("Closing", &other)),
        }
    }
}

fn unexpected(want: &str, got: &Response) -> Error {
    Error::Protocol(format!("expected {want}, got {got:?}"))
}
