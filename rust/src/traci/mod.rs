//! TraCI — the Traffic Control Interface.
//!
//! SUMO's "remote control" protocol (§2.5.2): the Webots SUMO Interface
//! node connects to a per-simulation TraCI server over TCP and drives the
//! traffic back-end step by step.  We implement a compact binary protocol
//! over **real sockets** — which is exactly why the paper's duplicate-port
//! crash (§4.2.1) reproduces here as a genuine `AddrInUse`: two servers
//! on one port is a kernel-level impossibility, not a simulated rule.
//!
//! * [`protocol`] — message framing and command encoding,
//! * [`server`] — the SUMO-side listener (one per simulation instance),
//! * [`client`] — the Webots-side connector.

#![deny(clippy::unwrap_used, clippy::expect_used)]

pub mod client;
pub mod protocol;
pub mod server;

pub use client::TraciClient;
pub use protocol::{Command, Response, DEFAULT_PORT, PORT_STEP};
pub use server::TraciServer;
