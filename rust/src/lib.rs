//! # webots-hpc
//!
//! A from-scratch reproduction of **Webots.HPC** (Franchi, Clemson
//! University, 2021): a parallel robotics-simulation pipeline that runs
//! thousands of Webots(+SUMO) autonomous-vehicle simulation instances as
//! PBS job arrays across HPC compute nodes.
//!
//! The paper's artifact is a deployment recipe on hardware we do not have
//! (the Palmetto cluster, a Webots install); this crate therefore builds
//! **every substrate the pipeline touches** as a faithful simulation (see
//! `DESIGN.md` §2 for the substitution table):
//!
//! * [`cluster`] — the compute cluster (DICE-lab node inventory, resource
//!   accounting),
//! * [`pbs`] — the Portable Batch System: job scripts, job arrays,
//!   first-fit scheduling, walltime enforcement, qstat-style accounting,
//! * [`container`] — Docker→Singularity image conversion with the paper's
//!   §4.1 failure modes (immutable SIF, missing pip, no sudo),
//! * [`display`] — X11/Xvfb virtual framebuffer allocation (`xvfb-run -a`),
//! * [`sumo`] — a SUMO-like traffic microsimulator (networks, seeded
//!   `duarouter` demand, IDM/MOBIL baseline stepper),
//! * [`traci`] — the TraCI control protocol over real TCP sockets (so the
//!   paper's duplicate-port failure reproduces mechanically),
//! * [`webots`] — a Webots-like simulator: `.wbt` world parsing, robots,
//!   controllers, sensors, physics stepping modes,
//! * [`runtime`] — the PJRT bridge that loads the AOT-compiled JAX/Pallas
//!   physics (`artifacts/*.hlo.txt`) and executes it on the hot path,
//! * [`pipeline`] — the paper's contribution: the campaign launcher that
//!   wires all of the above together (port allocation, world-copy
//!   propagation, job generation, output collection),
//! * [`scenario`] — parametric scenario spaces, seeded samplers and the
//!   campaign-wide scenario matrix: the "many scenarios" axis on top of
//!   the paper's "many seeds" randomization,
//! * [`output`] / [`metrics`] — big-data aggregation and per-run resource
//!   accounting,
//! * [`telemetry`] — always-on observability: lock-free metrics, the
//!   structured run-lifecycle event stream, and Chrome-trace export,
//! * [`harness`] — regenerates every table and figure of the paper's
//!   ch. 5 evaluation.
//!
//! Python/JAX runs only at build time (`make artifacts`); the request path
//! is pure rust + PJRT.

// Under `--cfg loom` (the exhaustive-interleaving model checker lane,
// `rust/tests/loom_models.rs`) only the concurrency-relevant core
// compiles: `util` (sync facade + shared cache), `telemetry::metrics`,
// and `fabric::lease`.  Everything else is std-I/O-heavy and outside
// what loom models, so it is gated out to keep the model build small.
#[cfg(not(loom))]
pub mod cloud;
#[cfg(not(loom))]
pub mod cluster;
#[cfg(not(loom))]
pub mod container;
#[cfg(not(loom))]
pub mod display;
pub mod fabric;
#[cfg(not(loom))]
pub mod harness;
#[cfg(not(loom))]
pub mod metrics;
#[cfg(not(loom))]
pub mod output;
#[cfg(not(loom))]
pub mod pbs;
#[cfg(not(loom))]
pub mod pipeline;
#[cfg(not(loom))]
pub mod runtime;
#[cfg(not(loom))]
pub mod scenario;
#[cfg(not(loom))]
pub mod simclock;
#[cfg(not(loom))]
pub mod sumo;
pub mod telemetry;
#[cfg(not(loom))]
pub mod traci;
pub mod util;
#[cfg(not(loom))]
pub mod webots;

/// Crate-wide result alias.
pub type Result<T> = std::result::Result<T, Error>;

/// Crate-wide error type. Each subsystem contributes a variant; the
/// variants mirror the *paper's* failure taxonomy (Table 4.1) where one
/// exists — e.g. [`Error::PortInUse`] is §4.2.1, [`Error::DisplayInUse`]
/// is §3.1.5, [`Error::ImmutableImage`] is §4.1.3.
#[derive(Debug, thiserror::Error)]
pub enum Error {
    /// SUMO TraCI server could not bind its TCP port (§4.2.1: "SUMO is
    /// unable to support more than one TraCI server on the same port").
    #[error("TraCI port {0} already in use (duplicate-port issue, paper §4.2.1)")]
    PortInUse(u16),

    /// X display number already taken (fixed by `xvfb-run -a`, §3.1.5).
    #[error("X display :{0} already in use (run xvfb with auto-probe, paper §3.1.5)")]
    DisplayInUse(u32),

    /// Singularity images are read-only once built (§4.1.3).
    #[error("singularity image '{0}' is immutable on the cluster (paper §4.1.3)")]
    ImmutableImage(String),

    /// Unprivileged cluster users cannot install system packages (§4.1.4).
    #[error("permission denied: {0} (paper §4.1.4: no sudo on the cluster)")]
    PermissionDenied(String),

    /// Requested executable/package missing from the image (§4.1.4: pip
    /// absent from the official Webots docker image).
    #[error("'{0}' not found in container image (paper §4.1.4)")]
    MissingInImage(String),

    /// Scheduler could not satisfy a resource request.
    #[error("unschedulable: {0}")]
    Unschedulable(String),

    /// Job exceeded its walltime and was killed by PBS.
    #[error("job {0} killed: walltime exceeded")]
    WalltimeExceeded(String),

    /// Route regeneration exited nonzero (`duarouter --seed $RANDOM`
    /// flaking mid-campaign — a transient the supervisor retries).
    #[error("duarouter failed: {0}")]
    DuarouterFailed(String),

    /// The run's stall watchdog fired: no step progress within the
    /// configured window (payload = steps completed before the stall).
    #[error("run stalled after {0} steps (stall watchdog)")]
    Stalled(u64),

    /// A contained panic from a launch thread (`catch_unwind` in the
    /// run supervisor — a crash becomes a per-slot error instead of a
    /// node-wide abort).
    #[error("instance panicked: {0}")]
    Panic(String),

    #[error("no such job: {0}")]
    NoSuchJob(String),

    #[error("world file error: {0}")]
    World(String),

    #[error("traci protocol error: {0}")]
    Protocol(String),

    #[error("runtime (PJRT) error: {0}")]
    Runtime(String),

    #[error("artifact error: {0}")]
    Artifact(String),

    #[error("config error: {0}")]
    Config(String),

    #[error(transparent)]
    Io(#[from] std::io::Error),
}

impl Error {
    /// Convenience constructor used by the xla-crate boundary.
    pub fn runtime(e: impl std::fmt::Display) -> Self {
        Error::Runtime(e.to_string())
    }
}
