//! The containerization substrate: Docker images, Singularity conversion,
//! and container execution environments.
//!
//! Chapter 4 of the paper is largely a war story about this layer:
//! converting the official Webots Docker image to Singularity (§4.1.2),
//! the immutability of SIF images on the cluster (§4.1.3), pip missing
//! from the official image and `sudo apt-get` being impossible without
//! admin rights (§4.1.4).  Those failure modes are implemented as real
//! error paths here and exercised by `rust/tests/challenges.rs` — each
//! row of Table 4.1 is an executable test.

mod build;
mod exec;
mod image;

pub use build::{build_webots_hpc_image, modify_sif_on_cluster, singularity_build, BuildHost};
pub use exec::{BindMount, ExecEnv, ExecOutcome};
pub use image::{DockerImage, PackageManager, SifImage};
