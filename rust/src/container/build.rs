//! `singularity build`: Docker → SIF conversion.
//!
//! The conversion workflow the paper converged on (§4.1.2–4.1.3):
//! pulling/modifying the Docker image **must** happen on a host with
//! admin rights (a personal computer); the cluster can only convert and
//! run.  [`BuildHost`] encodes where an operation is attempted.

use crate::Result;
#[cfg(test)]
use crate::Error;

use super::{DockerImage, SifImage};

/// Where a build/modify operation runs.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum BuildHost {
    /// A machine with admin/root (the paper's "personal computer").
    PersonalComputer,
    /// A cluster login/compute node: unprivileged.
    Cluster,
}

impl BuildHost {
    pub fn has_admin(self) -> bool {
        matches!(self, BuildHost::PersonalComputer)
    }
}

/// `singularity build webots_sumo.sif docker://...`.
///
/// Conversion itself works on either host (Singularity is designed for
/// unprivileged HPC use), but *pulling a modified docker image* to the
/// cluster first requires it to have been pushed from an admin host —
/// we model that by accepting the [`DockerImage`] by value: whatever
/// state it carries is what gets frozen.
pub fn singularity_build(image: &DockerImage, sandbox: bool) -> SifImage {
    SifImage {
        name: format!("{}_{}.sif", image.name.replace('/', "_"), image.tag),
        binaries: image.binaries.clone(),
        python_packages: image.python_packages.clone(),
        sandbox,
        built_from: format!("{}:{}", image.name, image.tag),
    }
}

/// The full §4.1 publication loop: (1) pull on admin host, (2) modify,
/// (3) push, (4) convert on the cluster.  Returns the deployable SIF
/// loaded with pip + the data-science stack the paper added.
pub fn build_webots_hpc_image(host: BuildHost) -> Result<SifImage> {
    let mut docker = DockerImage::official_webots();
    // steps 1-2 need admin; on the cluster they fail like they did for
    // the authors.
    docker.install_pip(host.has_admin())?;
    for pkg in ["numpy", "pandas"] {
        docker.pip_install(pkg)?;
    }
    // step 4: conversion is fine anywhere.
    Ok(singularity_build(&docker, false))
}

/// Modifying an already-converted SIF on the cluster — the dead end the
/// paper hit before settling on the loop above.
pub fn modify_sif_on_cluster(sif: &mut SifImage, pkg: &str) -> Result<()> {
    sif.pip_install(pkg)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn build_on_pc_succeeds_with_full_stack() {
        let sif = build_webots_hpc_image(BuildHost::PersonalComputer).unwrap();
        assert!(sif.has_binary("webots"));
        assert!(sif.has_binary("pip"));
        assert!(sif.has_python_package("numpy"));
        assert!(sif.has_python_package("pandas"));
        assert!(!sif.sandbox);
        assert_eq!(sif.built_from, "cyberbotics/webots:R2021a");
    }

    #[test]
    fn build_on_cluster_fails_at_pip_bootstrap() {
        // §4.1.4: "we were unsuccessful in running the command in sudo
        // mode due to permissions limitations"
        let err = build_webots_hpc_image(BuildHost::Cluster).unwrap_err();
        assert!(matches!(err, Error::PermissionDenied(_)));
    }

    #[test]
    fn converted_sif_is_immutable_on_cluster() {
        let sif0 = singularity_build(&DockerImage::official_webots(), false);
        let mut sif = sif0;
        let err = modify_sif_on_cluster(&mut sif, "numpy").unwrap_err();
        assert!(matches!(err, Error::ImmutableImage(_)));
    }

    #[test]
    fn sandbox_sif_writable_but_pipless() {
        // the paper's sandbox detour: writable, yet pip is still missing
        let mut sif = singularity_build(&DockerImage::official_webots(), true);
        let err = sif.pip_install("numpy").unwrap_err();
        assert!(matches!(err, Error::MissingInImage(_)));
    }

    #[test]
    fn sandbox_of_fixed_image_works() {
        let mut docker = DockerImage::official_webots();
        docker.install_pip(true).unwrap();
        let mut sif = singularity_build(&docker, true);
        sif.pip_install("numpy").unwrap();
        assert!(sif.has_python_package("numpy"));
    }
}
