//! `singularity exec`: running commands inside a container environment.
//!
//! Models the `-B $TMPDIR:$TMPDIR` bind-mount plumbing and binary
//! resolution against the image content.  The launcher
//! (`pipeline::launcher`) builds an [`ExecEnv`] per simulation instance.

use std::collections::BTreeMap;

use crate::{Error, Result};

use super::SifImage;

/// A `-B src:dst` bind mount.
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct BindMount {
    pub src: String,
    pub dst: String,
}

/// The execution environment of one `singularity exec` invocation.
#[derive(Debug, Clone)]
pub struct ExecEnv {
    pub image: SifImage,
    pub binds: Vec<BindMount>,
    pub env: BTreeMap<String, String>,
}

/// What happened when a command ran.
#[derive(Debug, Clone, PartialEq)]
pub struct ExecOutcome {
    pub binary: String,
    pub args: Vec<String>,
    pub exit_code: i32,
}

impl ExecEnv {
    pub fn new(image: SifImage) -> Self {
        ExecEnv {
            image,
            binds: Vec::new(),
            env: BTreeMap::new(),
        }
    }

    pub fn bind(mut self, src: impl Into<String>, dst: impl Into<String>) -> Self {
        self.binds.push(BindMount {
            src: src.into(),
            dst: dst.into(),
        });
        self
    }

    pub fn env_var(mut self, k: impl Into<String>, v: impl Into<String>) -> Self {
        self.env.insert(k.into(), v.into());
        self
    }

    /// Resolve and "run" a binary from the image. Fails with the paper's
    /// `MissingInImage` error when the tool isn't on the image — the
    /// runtime analogue of the §4.1.4 missing-pip discovery.
    pub fn exec(&self, binary: &str, args: &[&str]) -> Result<ExecOutcome> {
        if !self.image.has_binary(binary) {
            return Err(Error::MissingInImage(binary.to_string()));
        }
        Ok(ExecOutcome {
            binary: binary.to_string(),
            args: args.iter().map(|s| s.to_string()).collect(),
            exit_code: 0,
        })
    }

    /// A path is visible inside the container iff some bind covers it
    /// (host $TMPDIR content is invisible without `-B $TMPDIR:$TMPDIR`).
    pub fn path_visible(&self, path: &str) -> bool {
        self.binds.iter().any(|b| path.starts_with(&b.dst))
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::container::{singularity_build, DockerImage};

    fn env() -> ExecEnv {
        let sif = singularity_build(&DockerImage::official_webots(), false);
        ExecEnv::new(sif).bind("/tmp/job123", "/tmp/job123")
    }

    #[test]
    fn exec_resolves_image_binaries() {
        let e = env();
        assert!(e.exec("webots", &["--batch"]).is_ok());
        assert!(e.exec("duarouter", &[]).is_ok());
        let err = e.exec("pip", &["install", "numpy"]).unwrap_err();
        assert!(matches!(err, Error::MissingInImage(_)));
    }

    #[test]
    fn tmpdir_visibility_requires_bind() {
        let e = env();
        assert!(e.path_visible("/tmp/job123/sim.wbt"));
        assert!(!e.path_visible("/scratch/other"));
    }

    #[test]
    fn env_vars_carry() {
        let e = env().env_var("DISPLAY", ":99");
        assert_eq!(e.env.get("DISPLAY").map(String::as_str), Some(":99"));
    }
}
