//! Container image models: mutable Docker images, immutable SIF images.

use std::collections::BTreeSet;


use crate::{Error, Result};

/// A Docker image: layered, mutable where you have admin rights.
#[derive(Debug, Clone, PartialEq)]
pub struct DockerImage {
    pub name: String,
    pub tag: String,
    /// Binaries/tools present on the image.
    pub binaries: BTreeSet<String>,
    /// Installed python packages.
    pub python_packages: BTreeSet<String>,
    /// Layer history (audit trail of modifications).
    pub layers: Vec<String>,
}

impl DockerImage {
    /// The official `cyberbotics/webots` image as the paper found it:
    /// Webots + SUMO + Xvfb present, **pip absent** ("We were surprised
    /// that pip was not pre-installed on the Webots Docker image",
    /// §4.1.4).
    pub fn official_webots() -> Self {
        DockerImage {
            name: "cyberbotics/webots".into(),
            tag: "R2021a".into(),
            binaries: ["webots", "sumo", "duarouter", "xvfb-run", "python3"]
                .into_iter()
                .map(String::from)
                .collect(),
            python_packages: BTreeSet::new(),
            layers: vec!["FROM cyberbotics/webots:R2021a".into()],
        }
    }

    pub fn has_binary(&self, name: &str) -> bool {
        self.binaries.contains(name)
    }

    pub fn has_python_package(&self, name: &str) -> bool {
        self.python_packages.contains(name)
    }

    /// Install pip via the official `get-pip.py` script — only possible on
    /// a host with admin rights (the paper did this on a personal
    /// computer, §4.1.4).
    pub fn install_pip(&mut self, admin: bool) -> Result<()> {
        if !admin {
            return Err(Error::PermissionDenied(
                "python get-pip.py requires admin rights".into(),
            ));
        }
        self.binaries.insert("pip".into());
        self.layers.push("RUN python3 get-pip.py".into());
        Ok(())
    }

    /// `pip install <pkg>` — needs pip on the image.
    pub fn pip_install(&mut self, pkg: &str) -> Result<()> {
        if !self.has_binary("pip") {
            return Err(Error::MissingInImage("pip".into()));
        }
        self.python_packages.insert(pkg.to_string());
        self.layers.push(format!("RUN pip install {pkg}"));
        Ok(())
    }

    /// `sudo apt-get install` — requires admin on the executing host.
    pub fn apt_get_install(&mut self, pkg: &str, admin: bool) -> Result<()> {
        if !admin {
            return Err(Error::PermissionDenied(format!(
                "sudo apt-get install {pkg}"
            )));
        }
        self.binaries.insert(pkg.to_string());
        self.layers.push(format!("RUN apt-get install -y {pkg}"));
        Ok(())
    }
}

/// Package-manager flavors relevant to §4.1.4.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum PackageManager {
    Pip,
    Apt,
}

/// A Singularity image (SIF): a frozen snapshot of a Docker image.
/// Immutable at normal cluster privilege; a `sandbox` build is writable
/// *where created* but still can't bootstrap missing tooling (§4.1.4).
#[derive(Debug, Clone, PartialEq)]
pub struct SifImage {
    pub name: String,
    /// Snapshot of the source Docker image content.
    pub binaries: BTreeSet<String>,
    pub python_packages: BTreeSet<String>,
    pub sandbox: bool,
    /// Provenance: docker image name:tag it was built from.
    pub built_from: String,
}

impl SifImage {
    pub fn has_binary(&self, name: &str) -> bool {
        self.binaries.contains(name)
    }

    pub fn has_python_package(&self, name: &str) -> bool {
        self.python_packages.contains(name)
    }

    /// Any in-place modification of a non-sandbox SIF fails — the §4.1.3
    /// problem ("once a Singularity container is on the Palmetto Cluster,
    /// it is immutable, at least at our access level").
    pub fn pip_install(&mut self, pkg: &str) -> Result<()> {
        if !self.sandbox {
            return Err(Error::ImmutableImage(self.name.clone()));
        }
        // sandbox mode: writable, but pip must exist on the image — the
        // paper's sandbox attempt died exactly here (§4.1.4).
        if !self.has_binary("pip") {
            return Err(Error::MissingInImage("pip".into()));
        }
        self.python_packages.insert(pkg.to_string());
        Ok(())
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn official_image_lacks_pip() {
        let img = DockerImage::official_webots();
        assert!(img.has_binary("webots"));
        assert!(img.has_binary("sumo"));
        assert!(!img.has_binary("pip"));
    }

    #[test]
    fn pip_install_without_pip_fails() {
        let mut img = DockerImage::official_webots();
        let err = img.pip_install("numpy").unwrap_err();
        assert!(matches!(err, Error::MissingInImage(_)));
    }

    #[test]
    fn install_pip_requires_admin() {
        let mut img = DockerImage::official_webots();
        assert!(matches!(
            img.install_pip(false),
            Err(Error::PermissionDenied(_))
        ));
        img.install_pip(true).unwrap();
        img.pip_install("numpy").unwrap();
        img.pip_install("pandas").unwrap();
        assert!(img.has_python_package("pandas"));
    }

    #[test]
    fn apt_needs_admin() {
        let mut img = DockerImage::official_webots();
        assert!(img.apt_get_install("python3-pip", false).is_err());
        assert!(img.apt_get_install("python3-pip", true).is_ok());
    }

    #[test]
    fn layers_record_provenance() {
        let mut img = DockerImage::official_webots();
        img.install_pip(true).unwrap();
        img.pip_install("numpy").unwrap();
        assert_eq!(img.layers.len(), 3);
        assert!(img.layers[2].contains("numpy"));
    }
}
