//! Resource-consumption modelling and reporting.
//!
//! The paper's Table 5.3 compares per-run walltime, CPU time, RAM and
//! CPU% between the 6x1 (whole-node) and 6x8 (5-core slot) setups.  We
//! have neither Palmetto nor Webots, so per-run consumption comes from a
//! calibrated [`CostModel`] (an Amdahl-style split of the simulation's
//! work between a serial part and a part parallelized over Webots'
//! physics threads) — the *shape* claims of §5.3 (walltime ~33% shorter
//! on a whole node, CPU time within ~5%, RAM flat) fall out of the model
//! rather than being hard-coded.

mod reporter;
mod usage;

pub use reporter::{PoolUsage, UsageReporter, UsageSummary};
pub use usage::{CostModel, FixedWorkload, ResourceUsage, SimWorkload, WorkloadModel};
