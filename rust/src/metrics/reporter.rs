//! Aggregation of job records into the per-setup averages of Table 5.3,
//! plus runtime-engine observability ([`PoolUsage`]).

use crate::pbs::JobRecord;

/// Executable-pool hit/miss counters surfaced from the PJRT engine
/// (`runtime::ExecutablePool::stats`) — the compile-amortization
/// observable of the pooled fast path.  A healthy campaign compiles
/// each (kernel, bucket) pair once and then hits for every step; a
/// growing miss count means the pool key space is fragmenting (or the
/// pool was bypassed).
#[derive(Debug, Clone, Copy, PartialEq, Default)]
pub struct PoolUsage {
    /// Steady-state cache hits (read-lock + `Arc` clone).
    pub hits: u64,
    /// Compilations (tens of milliseconds each).
    pub misses: u64,
    /// Distinct executables resident in the pool.
    pub compiled: usize,
}

impl PoolUsage {
    /// Fraction of lookups served from the pool (1.0 when there were no
    /// lookups at all — an idle pool is not a cold pool).
    pub fn hit_rate(&self) -> f64 {
        let total = self.hits + self.misses;
        if total == 0 {
            1.0
        } else {
            self.hits as f64 / total as f64
        }
    }

    /// One-line campaign-summary form.
    pub fn render(&self) -> String {
        format!(
            "engine pool: {} hits / {} misses ({:.1}% hit rate), {} executables resident",
            self.hits,
            self.misses,
            100.0 * self.hit_rate(),
            self.compiled
        )
    }
}

/// Averaged resource consumption over a set of runs — one column of the
/// paper's Table 5.3.
#[derive(Debug, Clone, Copy, PartialEq, Default)]
pub struct UsageSummary {
    pub runs: usize,
    pub mean_walltime_s: f64,
    pub mean_cpu_time_s: f64,
    pub mean_ram_gb: f64,
    pub mean_cpu_percent: f64,
}

/// Computes usage summaries from scheduler records.
pub struct UsageReporter;

impl UsageReporter {
    pub fn summarize(records: &[JobRecord]) -> UsageSummary {
        if records.is_empty() {
            return UsageSummary::default();
        }
        let n = records.len() as f64;
        UsageSummary {
            runs: records.len(),
            mean_walltime_s: records
                .iter()
                .map(|r| r.usage.walltime.as_secs_f64())
                .sum::<f64>()
                / n,
            mean_cpu_time_s: records.iter().map(|r| r.usage.cpu_time_s).sum::<f64>() / n,
            mean_ram_gb: records.iter().map(|r| r.usage.max_ram_gb).sum::<f64>() / n,
            mean_cpu_percent: records.iter().map(|r| r.cpu_percent()).sum::<f64>() / n,
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::metrics::ResourceUsage;
    use crate::pbs::{JobId, JobState, SubJobId};
    use crate::simclock::{SimDuration, SimInstant};

    fn rec(wall_s: u64, cpu: f64, ram: f64) -> JobRecord {
        JobRecord {
            sub: SubJobId {
                job: JobId(1),
                array_index: 0,
            },
            node: 0,
            state: JobState::Completed,
            queued_at: SimInstant::ZERO,
            started_at: SimInstant::ZERO,
            finished_at: SimInstant::ZERO + SimDuration::from_secs(wall_s),
            usage: ResourceUsage {
                walltime: SimDuration::from_secs(wall_s),
                cpu_time_s: cpu,
                max_ram_gb: ram,
            },
        }
    }

    #[test]
    fn summary_averages() {
        let s = UsageReporter::summarize(&[rec(100, 200.0, 2.0), rec(300, 400.0, 3.0)]);
        assert_eq!(s.runs, 2);
        assert_eq!(s.mean_walltime_s, 200.0);
        assert_eq!(s.mean_cpu_time_s, 300.0);
        assert_eq!(s.mean_ram_gb, 2.5);
        // mean of per-run percents: (200 + 133.3)/2
        assert!((s.mean_cpu_percent - (200.0 + 400.0 / 3.0) / 2.0).abs() < 1e-6);
    }

    #[test]
    fn empty_records() {
        assert_eq!(UsageReporter::summarize(&[]).runs, 0);
    }

    #[test]
    fn pool_usage_hit_rate_and_render() {
        let idle = PoolUsage::default();
        assert_eq!(idle.hit_rate(), 1.0);
        let p = PoolUsage {
            hits: 99,
            misses: 1,
            compiled: 1,
        };
        assert!((p.hit_rate() - 0.99).abs() < 1e-12);
        let line = p.render();
        assert!(line.contains("99 hits"), "{line}");
        assert!(line.contains("99.0% hit rate"), "{line}");
        assert!(line.contains("1 executables resident"), "{line}");
    }
}
