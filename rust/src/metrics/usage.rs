//! Per-run resource usage and the calibrated cost model.

use crate::cluster::{NodeSpec, ResourceDemand};
use crate::pbs::SubJobId;
use crate::simclock::SimDuration;
use crate::util::Rng64;

/// What one simulation run consumed (Table 5.3 row).
#[derive(Debug, Clone, Copy, PartialEq, Default)]
pub struct ResourceUsage {
    /// Elapsed run time.
    pub walltime: SimDuration,
    /// Total CPU time across all threads [core-seconds].
    pub cpu_time_s: f64,
    /// Peak resident memory [GB].
    pub max_ram_gb: f64,
}

/// How long a subjob runs and what it consumes, given where it landed.
/// The scheduler calls this once per subjob at dispatch time.
pub trait WorkloadModel: Send {
    fn usage(&mut self, sub: SubJobId, node: &NodeSpec, demand: &ResourceDemand) -> ResourceUsage;
}

/// Constant-duration workload (unit tests, simple campaigns).
#[derive(Debug, Clone, Copy)]
pub struct FixedWorkload {
    pub duration: SimDuration,
    pub cpu_time_s: f64,
    pub ram_gb: f64,
}

impl FixedWorkload {
    pub fn minutes(m: u64) -> Self {
        FixedWorkload {
            duration: SimDuration::from_minutes(m),
            cpu_time_s: SimDuration::from_minutes(m).as_secs_f64(),
            ram_gb: 2.3,
        }
    }

    pub fn seconds(s: u64) -> Self {
        FixedWorkload {
            duration: SimDuration::from_secs(s),
            cpu_time_s: s as f64,
            ram_gb: 2.3,
        }
    }
}

impl WorkloadModel for FixedWorkload {
    fn usage(&mut self, _: SubJobId, _: &NodeSpec, _: &ResourceDemand) -> ResourceUsage {
        ResourceUsage {
            walltime: self.duration,
            cpu_time_s: self.cpu_time_s,
            max_ram_gb: self.ram_gb,
        }
    }
}

/// Amdahl-style cost model of one Webots-SUMO merge-simulation run,
/// calibrated against the paper's Table 5.3 (see module docs).
///
/// * wall(c)  = serial + parallel / e(c),  e(c) = c^thread_scaling_exp
/// * cpu(c)   = serial + parallel * (overhead_base + overhead_slope·e(c))
///
/// The overhead term grows with effective threads — the paper observed
/// the whole-node (6x1) runs burning ~4% *more* CPU time than the 5-core
/// (6x8) runs and attributed it to "poor native multi-threading
/// capabilities in Webots"; the slope reproduces that.
#[derive(Debug, Clone, Copy)]
pub struct CostModel {
    /// Serial fraction of one run [s].
    pub serial_s: f64,
    /// Parallelizable work [core-seconds].
    pub parallel_core_s: f64,
    /// e(c) = c^exp — Webots physics threads scale sub-linearly.
    pub thread_scaling_exp: f64,
    /// CPU-time overhead multiplier: base + slope * e(c).
    pub overhead_base: f64,
    pub overhead_slope: f64,
    /// Peak RAM per run — ~2.2–2.3 GB regardless of the setup (Table 5.3).
    pub ram_gb: f64,
    /// Relative jitter applied per run (|N(0, jitter)|-ish, deterministic
    /// per subjob id).
    pub jitter: f64,
    /// The WorldInfo 'Optimal Thread Count' cap — threads beyond this do
    /// not help (paper §5.3 quotes the Webots documentation).
    pub optimal_thread_count: u32,
}

impl Default for CostModel {
    fn default() -> Self {
        Self::paper_merge_sim()
    }
}

impl CostModel {
    /// Calibration that lands on the paper's Table 5.3 numbers:
    /// wall(5) ≈ 245 s, wall(40) ≈ 160 s, cpu within ~5% of each other.
    pub fn paper_merge_sim() -> Self {
        // Solved from Table 5.3 with the 20-thread cap active on the
        // whole-node setup:
        //   wall(5)  = S + P/5^0.6        = 245 s
        //   wall(40) = S + P/20^0.6       = 163 s
        //   cpu(5)   = S + P(ob + os·5^0.6)  = 690 core-s
        //   cpu(40)  = S + P(ob + os·20^0.6) = 720 core-s
        CostModel {
            serial_s: 99.4,
            parallel_core_s: 383.0,
            thread_scaling_exp: 0.6,
            overhead_base: 1.482,
            overhead_slope: 0.023,
            ram_gb: 2.25,
            jitter: 0.03,
            optimal_thread_count: 20,
        }
    }

    /// Effective parallelism at `cores` allocated cores.
    pub fn effective_threads(&self, cores: u32) -> f64 {
        let c = cores.min(self.optimal_thread_count).max(1) as f64;
        c.powf(self.thread_scaling_exp)
    }

    /// Expected walltime of one run on `cores` cores [s].
    pub fn walltime_s(&self, cores: u32) -> f64 {
        self.serial_s + self.parallel_core_s / self.effective_threads(cores)
    }

    /// Expected total CPU time of one run on `cores` cores [core-s].
    pub fn cpu_time_s(&self, cores: u32) -> f64 {
        let e = self.effective_threads(cores);
        self.serial_s + self.parallel_core_s * (self.overhead_base + self.overhead_slope * e)
    }

    fn jittered(&self, base: f64, rng: &mut Rng64) -> f64 {
        let f = 1.0 + self.jitter * (rng.gen_f64() * 2.0 - 1.0);
        base * f
    }
}

/// [`WorkloadModel`] over a [`CostModel`], deterministic per subjob.
#[derive(Debug, Clone)]
pub struct SimWorkload {
    pub cost: CostModel,
    pub seed: u64,
    /// Scale factor on the run length (longer/shorter scenarios).
    pub length_scale: f64,
}

impl SimWorkload {
    pub fn new(cost: CostModel, seed: u64) -> Self {
        SimWorkload {
            cost,
            seed,
            length_scale: 1.0,
        }
    }

    pub fn with_length_scale(mut self, s: f64) -> Self {
        self.length_scale = s;
        self
    }
}

impl WorkloadModel for SimWorkload {
    fn usage(&mut self, sub: SubJobId, _node: &NodeSpec, demand: &ResourceDemand) -> ResourceUsage {
        let mut rng = Rng64::seed_from_u64(
            self.seed ^ (sub.job.0 << 20) ^ sub.array_index as u64,
        );
        let wall = self.cost.jittered(
            self.cost.walltime_s(demand.ncpus) * self.length_scale,
            &mut rng,
        );
        let cpu = self.cost.jittered(
            self.cost.cpu_time_s(demand.ncpus) * self.length_scale,
            &mut rng,
        );
        let ram = self.cost.jittered(self.cost.ram_gb, &mut rng);
        ResourceUsage {
            walltime: SimDuration::from_secs_f64(wall),
            cpu_time_s: cpu,
            max_ram_gb: ram,
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::pbs::JobId;

    fn sub(i: u32) -> SubJobId {
        SubJobId {
            job: JobId(1),
            array_index: i,
        }
    }

    #[test]
    fn calibration_matches_table_5_3_walltimes() {
        let m = CostModel::paper_merge_sim();
        let w5 = m.walltime_s(5);
        let w40 = m.walltime_s(40);
        // paper: 245 s (6x8) vs 163 s (6x1) — accept ±10%
        assert!((w5 - 245.0).abs() / 245.0 < 0.10, "wall(5) = {w5}");
        assert!((w40 - 163.0).abs() / 163.0 < 0.10, "wall(40) = {w40}");
        // "the nx1 setup has a 33.5% shorter walltime"
        let shorter = 1.0 - w40 / w5;
        assert!((shorter - 0.335).abs() < 0.05, "shorter = {shorter}");
    }

    #[test]
    fn calibration_matches_table_5_3_cpu_times() {
        let m = CostModel::paper_merge_sim();
        let c5 = m.cpu_time_s(5);
        let c40 = m.cpu_time_s(40);
        // paper: 690 (6x8) vs 720 (6x1) — whole node burns ~4% MORE cpu
        assert!(c40 > c5, "more threads must burn more total cpu");
        let excess = c40 / c5 - 1.0;
        assert!((excess - 0.04).abs() < 0.03, "excess = {excess}");
    }

    #[test]
    fn ram_flat_across_setups() {
        let m = CostModel::paper_merge_sim();
        assert!((m.ram_gb - 2.25).abs() < 0.1);
    }

    #[test]
    fn workload_is_deterministic_per_subjob() {
        let mut w1 = SimWorkload::new(CostModel::paper_merge_sim(), 42);
        let mut w2 = SimWorkload::new(CostModel::paper_merge_sim(), 42);
        let node = NodeSpec::dice_r740();
        let d = ResourceDemand::paper_slot();
        assert_eq!(w1.usage(sub(3), &node, &d), w2.usage(sub(3), &node, &d));
        assert_ne!(w1.usage(sub(3), &node, &d), w1.usage(sub(4), &node, &d));
    }

    #[test]
    fn optimal_thread_count_caps_scaling() {
        let m = CostModel::paper_merge_sim();
        assert_eq!(m.effective_threads(20), m.effective_threads(40));
        assert!(m.effective_threads(5) < m.effective_threads(20));
    }

    #[test]
    fn fixed_workload_constant() {
        let mut w = FixedWorkload::minutes(15);
        let node = NodeSpec::dice_r740();
        let d = ResourceDemand::paper_slot();
        let u = w.usage(sub(0), &node, &d);
        assert_eq!(u.walltime.as_minutes(), 15);
    }
}
