//! A deterministic priority event queue for the discrete-event scheduler.
//!
//! Ties on the timestamp are broken by insertion sequence, which makes
//! campaign replays bit-for-bit deterministic — a property the proptest
//! suite (`rust/tests/scheduler_props.rs`) relies on.

use std::cmp::Ordering;
use std::collections::BinaryHeap;

use super::SimInstant;

/// An event carrying a payload `T`, ordered by `(at, seq)` ascending.
#[derive(Debug, Clone)]
pub struct Event<T> {
    pub at: SimInstant,
    pub seq: u64,
    pub payload: T,
}

impl<T> PartialEq for Event<T> {
    fn eq(&self, other: &Self) -> bool {
        self.at == other.at && self.seq == other.seq
    }
}
impl<T> Eq for Event<T> {}

impl<T> PartialOrd for Event<T> {
    fn partial_cmp(&self, other: &Self) -> Option<Ordering> {
        Some(self.cmp(other))
    }
}

impl<T> Ord for Event<T> {
    fn cmp(&self, other: &Self) -> Ordering {
        // BinaryHeap is a max-heap; invert for earliest-first.
        other
            .at
            .cmp(&self.at)
            .then_with(|| other.seq.cmp(&self.seq))
    }
}

/// Earliest-first event queue with stable tie-breaking.
#[derive(Debug)]
pub struct EventQueue<T> {
    heap: BinaryHeap<Event<T>>,
    next_seq: u64,
}

impl<T> Default for EventQueue<T> {
    fn default() -> Self {
        Self {
            heap: BinaryHeap::new(),
            next_seq: 0,
        }
    }
}

impl<T> EventQueue<T> {
    pub fn new() -> Self {
        Self::default()
    }

    pub fn push(&mut self, at: SimInstant, payload: T) {
        let seq = self.next_seq;
        self.next_seq += 1;
        self.heap.push(Event { at, seq, payload });
    }

    pub fn pop(&mut self) -> Option<Event<T>> {
        self.heap.pop()
    }

    /// Time of the next event without popping it.
    pub fn peek_time(&self) -> Option<SimInstant> {
        self.heap.peek().map(|e| e.at)
    }

    pub fn is_empty(&self) -> bool {
        self.heap.is_empty()
    }

    pub fn len(&self) -> usize {
        self.heap.len()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn pops_in_time_order() {
        let mut q = EventQueue::new();
        q.push(SimInstant(30), "c");
        q.push(SimInstant(10), "a");
        q.push(SimInstant(20), "b");
        let order: Vec<_> = std::iter::from_fn(|| q.pop().map(|e| e.payload)).collect();
        assert_eq!(order, vec!["a", "b", "c"]);
    }

    #[test]
    fn ties_break_by_insertion_order() {
        let mut q = EventQueue::new();
        q.push(SimInstant(10), 1);
        q.push(SimInstant(10), 2);
        q.push(SimInstant(10), 3);
        let order: Vec<_> = std::iter::from_fn(|| q.pop().map(|e| e.payload)).collect();
        assert_eq!(order, vec![1, 2, 3]);
    }

    #[test]
    fn peek_does_not_consume() {
        let mut q = EventQueue::new();
        q.push(SimInstant(5), ());
        assert_eq!(q.peek_time(), Some(SimInstant(5)));
        assert_eq!(q.len(), 1);
    }
}
