//! Virtual instants and durations with millisecond resolution.

use std::fmt;
use std::ops::{Add, AddAssign, Sub};

/// A point in virtual time (milliseconds since campaign start).
#[derive(Debug, Clone, Copy, PartialEq, Eq, PartialOrd, Ord, Hash, Default)]
pub struct SimInstant(pub u64);

/// A span of virtual time (milliseconds).
#[derive(Debug, Clone, Copy, PartialEq, Eq, PartialOrd, Ord, Hash, Default)]
pub struct SimDuration(pub u64);

impl SimInstant {
    pub const ZERO: SimInstant = SimInstant(0);

    pub fn as_millis(self) -> u64 {
        self.0
    }

    pub fn as_secs_f64(self) -> f64 {
        self.0 as f64 / 1000.0
    }

    /// Minutes since campaign start, rounded down — the unit of the
    /// paper's Table 5.1 "Timestamp" column.
    pub fn as_minutes(self) -> u64 {
        self.0 / 60_000
    }

    pub fn saturating_sub(self, other: SimInstant) -> SimDuration {
        SimDuration(self.0.saturating_sub(other.0))
    }
}

impl SimDuration {
    pub const ZERO: SimDuration = SimDuration(0);

    pub fn from_secs(s: u64) -> Self {
        SimDuration(s * 1000)
    }

    pub fn from_secs_f64(s: f64) -> Self {
        SimDuration((s * 1000.0).round().max(0.0) as u64)
    }

    pub fn from_minutes(m: u64) -> Self {
        SimDuration(m * 60_000)
    }

    pub fn from_hours(h: u64) -> Self {
        SimDuration(h * 3_600_000)
    }

    pub fn as_millis(self) -> u64 {
        self.0
    }

    pub fn as_secs_f64(self) -> f64 {
        self.0 as f64 / 1000.0
    }

    pub fn as_minutes(self) -> u64 {
        self.0 / 60_000
    }
}

impl Add<SimDuration> for SimInstant {
    type Output = SimInstant;
    fn add(self, d: SimDuration) -> SimInstant {
        SimInstant(self.0 + d.0)
    }
}

impl AddAssign<SimDuration> for SimInstant {
    fn add_assign(&mut self, d: SimDuration) {
        self.0 += d.0;
    }
}

impl Sub<SimInstant> for SimInstant {
    type Output = SimDuration;
    fn sub(self, other: SimInstant) -> SimDuration {
        SimDuration(self.0 - other.0)
    }
}

impl Add for SimDuration {
    type Output = SimDuration;
    fn add(self, other: SimDuration) -> SimDuration {
        SimDuration(self.0 + other.0)
    }
}

impl fmt::Display for SimInstant {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        let s = self.0 / 1000;
        write!(f, "{:02}:{:02}:{:02}", s / 3600, (s / 60) % 60, s % 60)
    }
}

impl fmt::Display for SimDuration {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(f, "{:.1}s", self.as_secs_f64())
    }
}

/// The virtual clock itself: monotone, explicitly advanced by the
/// discrete-event loop.  Never reads the OS clock.
#[derive(Debug, Clone, Default)]
pub struct SimClock {
    now: SimInstant,
}

impl SimClock {
    pub fn new() -> Self {
        Self::default()
    }

    pub fn now(&self) -> SimInstant {
        self.now
    }

    /// Advance to `t`. Panics on time travel — the event loop must pop
    /// events in order.
    pub fn advance_to(&mut self, t: SimInstant) {
        assert!(t >= self.now, "clock went backwards: {t:?} < {:?}", self.now);
        self.now = t;
    }

    pub fn advance_by(&mut self, d: SimDuration) {
        self.now += d;
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn instant_arithmetic() {
        let t = SimInstant::ZERO + SimDuration::from_secs(90);
        assert_eq!(t.as_millis(), 90_000);
        assert_eq!(t.as_minutes(), 1);
        assert_eq!((t - SimInstant(30_000)).as_secs_f64(), 60.0);
    }

    #[test]
    fn duration_constructors_agree() {
        assert_eq!(SimDuration::from_minutes(15), SimDuration::from_secs(900));
        assert_eq!(SimDuration::from_hours(12), SimDuration::from_minutes(720));
        assert_eq!(SimDuration::from_secs_f64(1.5).as_millis(), 1500);
    }

    #[test]
    fn clock_advances_monotonically() {
        let mut c = SimClock::new();
        c.advance_by(SimDuration::from_secs(5));
        c.advance_to(SimInstant(10_000));
        assert_eq!(c.now(), SimInstant(10_000));
    }

    #[test]
    #[should_panic(expected = "clock went backwards")]
    fn clock_rejects_time_travel() {
        let mut c = SimClock::new();
        c.advance_to(SimInstant(10_000));
        c.advance_to(SimInstant(5_000));
    }

    #[test]
    fn display_formats() {
        assert_eq!(SimInstant(3_661_000).to_string(), "01:01:01");
        assert_eq!(SimDuration::from_secs(90).to_string(), "90.0s");
    }
}
