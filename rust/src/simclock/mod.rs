//! Discrete-event virtual clock.
//!
//! The paper's headline experiment is a **12-hour wall-clock** campaign
//! (Table 5.1 / Fig 5.1).  Reproducing it in real time is pointless — every
//! reported number is a *ratio* against elapsed time (31× throughput,
//! 48·t output datasets) — so the scheduler and launcher run against this
//! virtual clock and the benches replay the full 12 hours in milliseconds.
//! `DESIGN.md` §7 lists the clock as an ablation candidate;
//! `rust/benches/ablations.rs` compares virtual vs scaled-real-time runs.

mod clock;
mod events;

pub use clock::{SimClock, SimDuration, SimInstant};
pub use events::{Event, EventQueue};
