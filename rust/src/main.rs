//! `webots-hpc` — the pipeline CLI (the leader entrypoint).
//!
//! ```text
//! webots-hpc info                      # artifacts + PJRT platform
//! webots-hpc table 5.1|5.2|5.3|4.1     # regenerate a paper table
//! webots-hpc fig 5.1|5.2               # regenerate a paper figure
//! webots-hpc dist                      # §5.2 distribution report
//! webots-hpc campaign [--nodes 6] [--slots 8] [--hours 12] [--policy first-fit]
//! webots-hpc submit <script.pbs> [--nodes 6]
//! webots-hpc run-local [--instances 8] [--engine hlo|native] [--horizon 30] [--chunk auto|K]
//! webots-hpc supervise [--nodes 2] [--slots 4] [--fault-rate 0.15] [--ledger DIR]
//! webots-hpc coordinate [--port 0] [--ledger DIR]   # lease out a campaign over TCP
//! webots-hpc work --addr host:port [--name w1]      # execute leases for a coordinator
//! webots-hpc report <events.jsonl> [more shards...] # summarize telemetry stream(s)
//! ```
//!
//! Argument parsing is hand-rolled (the vendored offline crate set has
//! no clap); see [`Args`].

#[cfg(not(loom))]
use anyhow::{anyhow, bail, Result};

#[cfg(not(loom))]
use webots_hpc::cluster::ResourceDemand;
#[cfg(not(loom))]
use webots_hpc::harness;
#[cfg(not(loom))]
use webots_hpc::metrics::{CostModel, SimWorkload};
#[cfg(not(loom))]
use webots_hpc::output::CampaignDataset;
#[cfg(not(loom))]
use webots_hpc::pbs::{script::PbsScript, JobId, PackingPolicy, Scheduler, SchedulerConfig};
#[cfg(not(loom))]
use webots_hpc::pipeline::ChunkSteps;
#[cfg(not(loom))]
use webots_hpc::pipeline::{
    propagate_copies, run_cluster_campaign, CampaignSpec, InstanceConfig, PhysicsEngine,
    PortAllocator,
};
#[cfg(not(loom))]
use webots_hpc::runtime::{Engine, EngineService};
#[cfg(not(loom))]
use webots_hpc::simclock::SimDuration;
#[cfg(not(loom))]
use webots_hpc::sumo::{FlowFile, MergeScenario};
#[cfg(not(loom))]
use webots_hpc::telemetry;
#[cfg(not(loom))]
use webots_hpc::webots::nodes::sample_merge_world;

#[cfg(not(loom))]
const USAGE: &str = "usage: webots-hpc <info|table|fig|dist|campaign|submit|run-local|supervise|coordinate|work|report> [args]
  info                         artifacts + PJRT platform
  table <5.1|5.2|5.3|4.1>      regenerate a paper table
  fig <5.1|5.2>                regenerate a paper figure
  dist                         §5.2 distribution report
  campaign [--nodes N] [--slots S] [--hours H] [--policy first-fit|round-robin]
  submit <script.pbs> [--nodes N]
  run-local [--instances N] [--engine hlo|native] [--horizon S]
            [--capacity C] [--seed K] [--chunk auto|K] [--trace-out file.json]
  scale [--max N] [--hours H]        §6.2.2: scalability sweep
  cloud [--runs N]                   §6.2.3: elastic (autoscaled) campaign
  config-init [path]                 §6.2.1: write an example campaign config
  scenarios [--families a,b] [--samples N] [--sampler grid|uniform|lhs]
            [--seed K] [--out file]  scenario-matrix manifest (the dataset codebook)
  supervise [--nodes N] [--slots S] [--epochs E] [--engine native|hlo]
            [--horizon S] [--seed K] [--retries R] [--walltime SECS]
            [--ledger DIR] [--fault-rate P] [--fault-seed K] [--config path]
            [--retry-failed true] [--trace-out file.json]
            supervised campaign: crash-safe ledger + retry/backoff +
            watchdogs (reuse --ledger to resume a killed campaign;
            permanent failures stay settled unless --retry-failed true).
            Telemetry always streams to <ledger>/events.jsonl;
            --trace-out additionally exports a Chrome/Perfetto trace
  coordinate [--port P] [--heartbeat-ms H] [--lease-ttl-ms T]
            [campaign flags as for supervise]
            own the campaign ledger and lease runs to TCP workers;
            a killed coordinator resumes on the same --ledger dir.
            Missed heartbeats revoke leases and re-dispatch the run
  work --addr host:port [--name w1] [--forward-events true]
            [campaign flags as for supervise — must match the
            coordinator's, or the handshake is refused]
            execute leases through the local run supervisor
  report <events.jsonl> [shard2.jsonl ...]
            summarize one or more telemetry event shards (merged
            timestamp-ordered, duplicate- and torn-tail-tolerant):
            completion, retry taxonomy, dispatch latency, lane
            occupancy, fabric lease/worker accounting";

/// Tiny flag parser: positional args + `--key value` pairs.
#[cfg(not(loom))]
struct Args {
    positional: Vec<String>,
    flags: std::collections::HashMap<String, String>,
}

#[cfg(not(loom))]
impl Args {
    fn parse(argv: &[String]) -> Result<Args> {
        let mut positional = Vec::new();
        let mut flags = std::collections::HashMap::new();
        let mut i = 0;
        while i < argv.len() {
            let a = &argv[i];
            if let Some(key) = a.strip_prefix("--") {
                let v = argv
                    .get(i + 1)
                    .ok_or_else(|| anyhow!("flag --{key} needs a value"))?;
                flags.insert(key.to_string(), v.clone());
                i += 2;
            } else {
                positional.push(a.clone());
                i += 1;
            }
        }
        Ok(Args { positional, flags })
    }

    fn get<T: std::str::FromStr>(&self, key: &str, default: T) -> Result<T>
    where
        T::Err: std::fmt::Display,
    {
        match self.flags.get(key) {
            None => Ok(default),
            Some(v) => v
                .parse()
                .map_err(|e| anyhow!("bad value for --{key}: {e}")),
        }
    }

    fn get_str(&self, key: &str, default: &str) -> String {
        self.flags
            .get(key)
            .cloned()
            .unwrap_or_else(|| default.to_string())
    }
}

#[cfg(not(loom))]
fn main() -> Result<()> {
    let argv: Vec<String> = std::env::args().skip(1).collect();
    let Some(cmd) = argv.first() else {
        println!("{USAGE}");
        return Ok(());
    };
    let rest = Args::parse(&argv[1..])?;
    match cmd.as_str() {
        "info" => info(),
        "table" => table(rest.positional.first().map(String::as_str).unwrap_or("")),
        "fig" => fig(rest.positional.first().map(String::as_str).unwrap_or("")),
        "dist" => {
            println!("{}", harness::distribution_5_2()?.render());
            Ok(())
        }
        "campaign" => campaign(&rest),
        "scale" => scale(&rest),
        "cloud" => cloud(&rest),
        "config-init" => config_init(&rest),
        "scenarios" => scenarios(&rest),
        "submit" => submit(&rest),
        "run-local" => run_local(&rest),
        "supervise" => supervise(&rest),
        "coordinate" => coordinate(&rest),
        "work" => work(&rest),
        "report" => report(&rest),
        "--help" | "-h" | "help" => {
            println!("{USAGE}");
            Ok(())
        }
        other => bail!("unknown command '{other}'\n{USAGE}"),
    }
}

#[cfg(not(loom))]
fn info() -> Result<()> {
    match Engine::auto() {
        Ok(e) => {
            println!("PJRT platform : {}", e.platform());
            let m = e.manifest();
            println!("artifact fmt  : {}", m.format);
            println!("buckets       : {:?}", m.buckets);
            println!("dt            : {} s", m.dt);
            println!(
                "merge zone    : [{}, {}] m, road end {} m, {} main lanes",
                m.merge_start, m.merge_end, m.road_end, m.num_main_lanes
            );
            println!("entries       : {}", m.entries.len());
        }
        Err(e) => println!("runtime unavailable: {e}\nrun `make artifacts` first"),
    }
    Ok(())
}

#[cfg(not(loom))]
fn table(id: &str) -> Result<()> {
    match id {
        "5.1" => println!("{}", harness::table_5_1()?.render()),
        "5.2" => println!("{}", harness::table_5_2().render()),
        "5.3" => println!("{}", harness::table_5_3()?.render()),
        "4.1" => println!("{}", harness::table_4_1()),
        other => bail!("unknown table '{other}' (have 5.1, 5.2, 5.3, 4.1)"),
    }
    Ok(())
}

#[cfg(not(loom))]
fn fig(id: &str) -> Result<()> {
    match id {
        "5.1" => println!("{}", harness::fig_5_1()?),
        "5.2" => println!("{}", harness::fig_5_2()?),
        other => bail!("unknown figure '{other}' (have 5.1, 5.2)"),
    }
    Ok(())
}

#[cfg(not(loom))]
fn scale(args: &Args) -> Result<()> {
    let max: usize = args.get("max", 32)?;
    let hours: u64 = args.get("hours", 1)?;
    let mut counts = vec![1usize];
    while *counts.last().expect("non-empty") * 2 <= max {
        counts.push(counts.last().expect("non-empty") * 2);
    }
    println!("scalability sweep ({hours}h virtual campaign per point):");
    let rows = webots_hpc::harness::scalability_sweep(&counts, hours)?;
    let max_c = rows.last().map(|r| r.1).unwrap_or(1).max(1);
    for (n, c) in rows {
        let bar = "#".repeat(((c * 40) / max_c).max(1) as usize);
        println!("{n:>4} nodes |{bar:<40}| {c} runs");
    }
    println!("(paper §5.1: \"these results should scale with larger amounts of allocated compute nodes\")");
    Ok(())
}

#[cfg(not(loom))]
fn cloud(args: &Args) -> Result<()> {
    let runs: u64 = args.get("runs", 2304)?;
    let mut spec = webots_hpc::cloud::ElasticSpec::paper_equivalent();
    spec.total_runs = runs;
    let r = webots_hpc::cloud::run_elastic_campaign(&spec);
    println!("elastic cloud campaign (paper §6.2.3 future work):");
    println!("  completed   : {} runs", r.completed);
    println!("  makespan    : {} (static PBS epoch-locked: 12h for 2304)", r.makespan);
    println!("  peak nodes  : {}", r.peak_nodes);
    println!("  node-hours  : {:.1} (static: 6 nodes x 12 h = 72)", r.node_hours);
    println!("  est. cost   : ${:.2} at ${}/node-hour", r.cost_usd, spec.provider.node_hour_usd);
    println!("  utilization : {:.0}% (static epoch-locked: ~27%)", 100.0 * r.utilization);
    Ok(())
}

#[cfg(not(loom))]
fn config_init(args: &Args) -> Result<()> {
    let path = args
        .positional
        .first()
        .map(String::as_str)
        .unwrap_or("campaign.conf");
    std::fs::write(path, webots_hpc::pipeline::CampaignConfig::example())?;
    println!("wrote {path}; run: webots-hpc campaign --config {path}");
    Ok(())
}

#[cfg(not(loom))]
fn scenarios(args: &Args) -> Result<()> {
    use webots_hpc::scenario::{scenarios_manifest, FamilyRegistry, SamplerKind, ScenarioMatrix};
    // the scenarios codebook carries spaces/points, never capacities —
    // bucket-ladder enforcement happens node-side, where
    // `ScenarioMatrix::materialize` rebuckets against the loaded
    // artifact manifest (see FamilyRegistry::with_buckets)
    let registry = FamilyRegistry::builtin();
    let families: Vec<String> = match args.flags.get("families") {
        Some(list) => list
            .split(',')
            .map(|s| s.trim().to_string())
            .filter(|s| !s.is_empty())
            .collect(),
        None => registry.ids(),
    };
    let samples: usize = args.get("samples", 16)?;
    let seed: u64 = args.get("seed", 2021)?;
    let kind = SamplerKind::parse(&args.get_str("sampler", "lhs"), samples)?;
    let matrix = ScenarioMatrix::new(families, kind, samples, seed);
    let manifest = scenarios_manifest(&registry, &matrix)?;
    let text = manifest.to_pretty_string();
    match args.flags.get("out") {
        Some(path) => {
            std::fs::write(path, &text)?;
            println!(
                "wrote {path}: {} families x {samples} points ({} runs per full pass)",
                matrix.families.len(),
                matrix.total_points()
            );
        }
        None => println!("{text}"),
    }
    Ok(())
}

#[cfg(not(loom))]
fn campaign(args: &Args) -> Result<()> {
    if let Some(cfg_path) = args.flags.get("config") {
        let cfg = webots_hpc::pipeline::CampaignConfig::parse(&std::fs::read_to_string(cfg_path)?)?;
        println!("campaign config '{}':\n{}", cfg.name, cfg.to_pbs_script()?.render());
        let r = run_cluster_campaign(&cfg.to_spec()?)?;
        println!(
            "completed {} / {} runs ({:.1}%), per-node {:?}",
            r.stats.completed,
            r.stats.submitted,
            100.0 * r.stats.completion_rate(),
            r.runs_per_node
        );
        return Ok(());
    }
    let nodes: usize = args.get("nodes", 6)?;
    let slots: u32 = args.get("slots", 8)?;
    let hours: u64 = args.get("hours", 12)?;
    let policy = match args.get_str("policy", "first-fit").as_str() {
        "first-fit" => PackingPolicy::FirstFit,
        "round-robin" => PackingPolicy::RoundRobin,
        other => bail!("unknown policy '{other}'"),
    };
    let spec = CampaignSpec {
        nodes,
        slots_per_node: slots,
        chunk: if slots == 1 {
            ResourceDemand::whole_node()
        } else {
            ResourceDemand::paper_slot()
        },
        duration: SimDuration::from_hours(hours),
        policy,
        ..CampaignSpec::paper_cluster()
    };
    let r = run_cluster_campaign(&spec)?;
    println!("campaign: {nodes} nodes x {slots} slots, {hours}h virtual");
    println!(
        "completed {} / {} runs ({:.1}% completion)",
        r.stats.completed,
        r.stats.submitted,
        100.0 * r.stats.completion_rate()
    );
    println!("runs per node: {:?}", r.runs_per_node);
    println!("peak occupancy: {:?}", r.peak_occupancy);
    println!(
        "mean per-run: wall {:.0}s cpu {:.0}s ram {:.1}GB cpu% {:.0}",
        r.usage.mean_walltime_s,
        r.usage.mean_cpu_time_s,
        r.usage.mean_ram_gb,
        r.usage.mean_cpu_percent
    );
    for s in &r.samples {
        println!("  t={:>4} min  completed={}", s.minutes, s.completed);
    }
    Ok(())
}

#[cfg(not(loom))]
fn submit(args: &Args) -> Result<()> {
    let path = args
        .positional
        .first()
        .ok_or_else(|| anyhow!("submit needs a script path"))?;
    let nodes: usize = args.get("nodes", 6)?;
    let text = std::fs::read_to_string(path)?;
    let script = PbsScript::parse(&text)?;
    println!(
        "parsed '{}': queue={} chunk={}c/{}gb walltime={} array={:?}",
        script.name,
        script.queue,
        script.request.chunk.ncpus,
        script.request.chunk.mem_gb,
        script.request.walltime,
        script.array.map(|a| a.to_string())
    );
    let cluster = webots_hpc::cluster::Cluster::uniform(
        "palmetto",
        nodes,
        webots_hpc::cluster::NodeSpec::dice_r740(),
    );
    let queue =
        webots_hpc::cluster::ClusterQueue::new(webots_hpc::cluster::QueueSpec::dicelab(nodes));
    let mut sched = Scheduler::new(cluster, queue, SchedulerConfig::default());
    let job = script.to_job(JobId(0));
    let workload = SimWorkload::new(CostModel::paper_merge_sim(), 42);
    let id = sched.submit(job, Box::new(workload))?;
    println!("submitted as {id}; occupancy {:?}", sched.occupancy());
    sched.run_to_completion();
    println!("{}", sched.qstat().render());
    println!(
        "completion rate: {:.1}%",
        100.0 * sched.stats().completion_rate()
    );
    Ok(())
}

/// Build the campaign spec + supervision policy shared by `supervise`,
/// `coordinate`, and `work` — one construction so the coordinator and
/// its workers hash-agree on the campaign shape when given the same
/// flags/config file.
#[cfg(not(loom))]
fn build_supervised_spec(args: &Args) -> Result<webots_hpc::pipeline::SupervisedCampaignSpec> {
    use webots_hpc::pipeline::{FaultPlan, RetryPolicy, SupervisedCampaignSpec, SupervisorSpec};
    use webots_hpc::webots::WatchdogSpec;

    // --config supplies name + supervision policy (retry/backoff/
    // watchdog keys); flags fill the campaign shape and can inject
    // faults for a soak
    let (name, mut supervisor) = match args.flags.get("config") {
        Some(path) => {
            let cfg =
                webots_hpc::pipeline::CampaignConfig::parse(&std::fs::read_to_string(path)?)?;
            (cfg.name.clone(), cfg.to_supervisor_spec())
        }
        None => {
            let retries: u32 = args.get("retries", 3)?;
            let walltime_s: u64 = args.get("walltime", 0)?;
            (
                "supervised".to_string(),
                SupervisorSpec {
                    retry: RetryPolicy {
                        max_attempts: retries + 1,
                        ..RetryPolicy::default()
                    },
                    watchdog: WatchdogSpec {
                        walltime: (walltime_s > 0)
                            .then(|| std::time::Duration::from_secs(walltime_s)),
                        stall_window: None,
                    },
                    degrade: true,
                    fault_plan: None,
                },
            )
        }
    };
    let fault_rate: f64 = args.get("fault-rate", 0.0)?;
    if fault_rate > 0.0 {
        let fault_seed: u64 = args.get("fault-seed", 99)?;
        supervisor.fault_plan = Some(FaultPlan::transient_only(fault_seed, fault_rate));
    }

    Ok(SupervisedCampaignSpec {
        name,
        nodes: args.get("nodes", 2)?,
        slots_per_node: args.get("slots", 4)?,
        epochs: args.get("epochs", 1)?,
        horizon_s: args.get("horizon", 10.0)?,
        capacity: args.get("capacity", 64)?,
        seed: args.get("seed", 2021)?,
        matrix: None,
        supervisor,
        ledger_dir: args.get_str("ledger", "supervised-ledger").into(),
        retry_failed: args.get("retry-failed", false)?,
        stop_after_runs: None,
    })
}

#[cfg(not(loom))]
fn parse_engine(args: &Args) -> Result<(String, PhysicsEngine)> {
    let engine = args.get_str("engine", "native");
    let physics = match engine.as_str() {
        "native" => PhysicsEngine::Native,
        "hlo" => PhysicsEngine::Hlo(EngineService::auto()?),
        other => bail!("unknown engine '{other}' (native|hlo)"),
    };
    Ok((engine, physics))
}

#[cfg(not(loom))]
fn supervise(args: &Args) -> Result<()> {
    use webots_hpc::pipeline::run_supervised_campaign;

    let spec = build_supervised_spec(args)?;
    let (engine, physics) = parse_engine(args)?;

    // the event stream rides next to the ledger — same append-only,
    // torn-tail-tolerant discipline, so a resumed campaign extends it
    let events_path = spec.ledger_dir.join("events.jsonl");
    let sink: std::sync::Arc<dyn telemetry::EventSink> =
        std::sync::Arc::new(telemetry::JsonlSink::append(&events_path)?);
    telemetry::install(sink.clone());

    println!(
        "supervised campaign '{}': {} nodes x {} slots x {} epochs = {} runs, engine={engine}",
        spec.name,
        spec.nodes,
        spec.slots_per_node,
        spec.epochs,
        spec.total_runs()
    );
    println!("ledger: {} (reuse to resume)", spec.ledger_dir.display());
    if let Some(plan) = &spec.supervisor.fault_plan {
        println!(
            "fault injection: seed {}, {:.0}% per transient site per attempt",
            plan.seed,
            100.0 * plan.rate(webots_hpc::pipeline::FaultSite::Duarouter)
        );
    }

    let outcome = run_supervised_campaign(&spec, &physics);
    telemetry::uninstall(&sink);
    let outcome = outcome?;
    for report in outcome.reports.iter().filter(|r| !r.failures.is_empty()) {
        println!("run {} took {} attempts:", report.run_id, report.attempts);
        for f in &report.failures {
            println!(
                "  attempt {}: [{}] {} (backoff {}ms)",
                f.attempt,
                f.class.name(),
                f.error,
                f.backoff_ms
            );
        }
    }
    let stats = outcome
        .result
        .robustness
        .ok_or_else(|| anyhow!("supervised campaign reported no robustness accounting"))?;
    println!(
        "runs {} | completed {} | failed {} | attempts {} | retries {} | degraded {}",
        stats.runs, stats.completed, stats.failed, stats.attempts, stats.retries, stats.degraded
    );
    println!(
        "attempt timeline: {} extra attempts over {} runs | backoff slept {} ms | {} degraded finishes",
        stats.retries, stats.runs, stats.backoff_ms_total, stats.degraded
    );
    println!(
        "kills: walltime {} stall {} | resumed skips {}",
        stats.killed_walltime, stats.killed_stall, stats.resumed_skips
    );
    if let PhysicsEngine::Hlo(service) = &physics {
        match service.pool_usage() {
            Ok(usage) => println!("{}", usage.render()),
            Err(e) => println!("engine pool stats unavailable: {e}"),
        }
    }
    println!(
        "completion rate: {:.1}% | aggregate: {} runs, {} rows, run_ids unique: {}",
        100.0 * stats.completion_rate(),
        outcome.dataset.num_runs(),
        outcome.dataset.total_rows(),
        outcome.dataset.run_ids_unique()
    );
    println!("telemetry: {}", events_path.display());
    if let Some(trace_path) = args.flags.get("trace-out") {
        let events = telemetry::read_events(&events_path)?;
        let trace = telemetry::to_chrome_trace(&events);
        std::fs::write(trace_path, trace.to_pretty_string())?;
        println!(
            "trace: {trace_path} ({} events; open in chrome://tracing or Perfetto)",
            events.len()
        );
    }
    Ok(())
}

/// `webots-hpc coordinate` — own a campaign's ledger and lease its
/// runs out to TCP workers until every run settles.  Reuse --ledger to
/// resume a killed coordinator.
#[cfg(not(loom))]
fn coordinate(args: &Args) -> Result<()> {
    use webots_hpc::fabric::{Coordinator, FabricConfig};

    let spec = build_supervised_spec(args)?;
    let fabric = FabricConfig {
        port: args.get("port", 0)?,
        heartbeat_ms: args.get("heartbeat-ms", 500)?,
        lease_ttl_ms: args.get("lease-ttl-ms", 3000)?,
        stop_after_completions: None,
    };
    if fabric.lease_ttl_ms < 2 * fabric.heartbeat_ms {
        bail!(
            "--lease-ttl-ms ({}) must be at least twice --heartbeat-ms ({}): \
             a healthy worker would miss its own lease",
            fabric.lease_ttl_ms,
            fabric.heartbeat_ms
        );
    }

    // coordinator telemetry rides next to the ledger; worker shards
    // (events-*.jsonl) land in the same dir for `report` to merge
    let events_path = spec.ledger_dir.join("events.jsonl");
    let sink: std::sync::Arc<dyn telemetry::EventSink> =
        std::sync::Arc::new(telemetry::JsonlSink::append(&events_path)?);
    telemetry::install(sink.clone());

    let total = spec.total_runs();
    let name = spec.name.clone();
    let ledger_dir = spec.ledger_dir.clone();
    let coordinator = Coordinator::bind(spec, fabric)?;
    println!(
        "coordinating campaign '{name}': {total} runs, ledger {} (reuse to resume)",
        ledger_dir.display()
    );
    println!(
        "listening on 127.0.0.1:{} — start workers with:\n  webots-hpc work --addr 127.0.0.1:{} [same campaign flags]",
        coordinator.port(),
        coordinator.port()
    );
    let outcome = coordinator.run();
    telemetry::uninstall(&sink);
    let outcome = outcome?;

    let f = &outcome.fabric;
    println!(
        "fabric: {} worker joins | {} leaves | {} refused | {} leases granted | {} expired",
        f.workers_joined, f.workers_left, f.workers_refused, f.leases_granted, f.leases_expired
    );
    println!(
        "results: {} accepted | {} rejected by duplicate guard | {} remote failures",
        f.completions_accepted, f.completions_rejected, f.remote_failures
    );
    let stats = outcome
        .result
        .robustness
        .ok_or_else(|| anyhow!("coordinator reported no robustness accounting"))?;
    println!(
        "runs {} | completed {} | failed {} | resumed skips {} | completion rate {:.1}%",
        stats.runs,
        stats.completed,
        stats.failed,
        stats.resumed_skips,
        100.0 * stats.completion_rate()
    );
    println!(
        "aggregate: {} runs, {} rows, run_ids unique: {}",
        outcome.dataset.num_runs(),
        outcome.dataset.total_rows(),
        outcome.dataset.run_ids_unique()
    );
    if outcome.interrupted {
        println!("campaign interrupted with unsettled runs — re-run coordinate on the same --ledger to resume");
    }
    println!("telemetry: {}", events_path.display());
    Ok(())
}

/// `webots-hpc work` — dial a coordinator and execute leased runs
/// through the local run supervisor until drained.
#[cfg(not(loom))]
fn work(args: &Args) -> Result<()> {
    use webots_hpc::fabric::{run_worker, WorkerConfig};

    let addr = args
        .flags
        .get("addr")
        .ok_or_else(|| anyhow!("work needs --addr host:port (printed by coordinate)"))?
        .clone();
    let spec = build_supervised_spec(args)?;
    let (engine, physics) = parse_engine(args)?;
    let mut cfg = WorkerConfig::new(args.get_str("name", "worker"), addr, spec);
    cfg.forward_events = args.get("forward-events", false)?;
    cfg.reconnect_attempts = args.get("reconnect", 8)?;

    println!(
        "worker '{}' dialing {} (campaign '{}', engine={engine}, forward-events={})",
        cfg.name, cfg.addr, cfg.spec.name, cfg.forward_events
    );
    let outcome = run_worker(&cfg, &physics)?;
    if let Some(reason) = &outcome.refused {
        bail!("coordinator refused handshake: {reason}");
    }
    println!(
        "worker '{}' done: {} completions | {} failures | drained: {}",
        cfg.name, outcome.completions, outcome.failures, outcome.drained
    );
    Ok(())
}

/// `webots-hpc report <shard.jsonl> [more...]` — fold one or more
/// telemetry event shards back into the §5.1/§5.3 operational facts.
/// Multiple shards (a coordinator's stream plus per-worker forwarded
/// shards) merge timestamp-ordered with duplicates collapsed.
#[cfg(not(loom))]
fn report(args: &Args) -> Result<()> {
    if args.positional.is_empty() {
        bail!("report needs at least one events.jsonl path");
    }
    let events = telemetry::merge_event_shards(&args.positional)?;
    if events.is_empty() {
        println!("{}: no events", args.positional.join(", "));
        return Ok(());
    }
    if args.positional.len() > 1 {
        println!(
            "merged {} shards -> {} events",
            args.positional.len(),
            events.len()
        );
    }
    print!("{}", telemetry::summarize(&events).render());
    Ok(())
}

#[cfg(not(loom))]
fn run_local(args: &Args) -> Result<()> {
    let instances: u16 = args.get("instances", 2)?;
    let engine = args.get_str("engine", "hlo");
    let horizon: f32 = args.get("horizon", 30.0)?;
    let capacity: usize = args.get("capacity", 64)?;
    let seed: u64 = args.get("seed", 2021)?;
    // fused-chunk policy (auto | K); explicit K is validated against
    // the manifest's rollout ladder inside launch_instance
    let chunk = ChunkSteps::parse(&args.get_str("chunk", "auto"))?;

    let physics = match engine.as_str() {
        "native" => PhysicsEngine::Native,
        "hlo" => PhysicsEngine::Hlo(EngineService::auto()?),
        other => bail!("unknown engine '{other}' (native|hlo)"),
    };
    // keep a handle for the post-campaign pool-observability summary
    let service = match &physics {
        PhysicsEngine::Hlo(s) => Some(s.clone()),
        PhysicsEngine::Native => None,
    };
    // pick a free base port so repeated invocations don't collide
    let base = std::net::TcpListener::bind("127.0.0.1:0")?
        .local_addr()?
        .port();
    let root = sample_merge_world(base);
    let copies = propagate_copies(&root, instances, &PortAllocator::new(base, 7))?;
    let configs: Vec<InstanceConfig> = copies
        .into_iter()
        .map(|c| InstanceConfig {
            run_id: format!("local[{}]", c.index),
            node: 0,
            world: c.world,
            flows: FlowFile::merge_sample(1200.0, 300.0, horizon),
            scenario: MergeScenario::default(),
            seed: seed + c.index as u64,
            capacity,
            horizon_s: horizon,
            max_steps: webots_hpc::sumo::steps_for(horizon, MergeScenario::default().dt_s) + 100,
            scenario_run: None,
            chunk_steps: chunk,
            faults: None,
            watchdog: Default::default(),
        })
        .collect();

    // --trace-out: stream events to a sibling .jsonl, convert at exit
    let trace = match args.flags.get("trace-out") {
        Some(out) => {
            let events_path = std::path::Path::new(out).with_extension("jsonl");
            let sink: std::sync::Arc<dyn telemetry::EventSink> =
                std::sync::Arc::new(telemetry::JsonlSink::append(&events_path)?);
            telemetry::install(sink.clone());
            Some((out.clone(), events_path, sink))
        }
        None => None,
    };

    let t0 = std::time::Instant::now();
    let results = webots_hpc::pipeline::launch_node_slots(configs, &physics);
    let elapsed = t0.elapsed();

    if let Some((out, events_path, sink)) = trace {
        telemetry::uninstall(&sink);
        let events = telemetry::read_events(&events_path)?;
        let chrome = telemetry::to_chrome_trace(&events);
        std::fs::write(&out, chrome.to_pretty_string())?;
        println!(
            "trace: {out} ({} events; stream at {})",
            events.len(),
            events_path.display()
        );
    }

    let mut dataset = CampaignDataset::new();
    let mut failed = 0;
    for r in results {
        match r {
            Ok(ok) => {
                println!(
                    "run {:<10} display :{} port {} steps {} flow {} spawned {} ctrl-cmds {}",
                    ok.dataset.run_id,
                    ok.display,
                    ok.port,
                    ok.steps,
                    ok.dataset.total_flow,
                    ok.dataset.total_spawned,
                    ok.controller_cmds
                );
                dataset.add(ok.dataset);
            }
            Err(e) => {
                failed += 1;
                println!("run FAILED: {e}");
            }
        }
    }
    println!(
        "{} runs ok, {} failed, engine={engine}, wall {:.2}s",
        dataset.num_runs(),
        failed,
        elapsed.as_secs_f64()
    );
    println!(
        "aggregate dataset: {} rows, {} bytes, seeds unique: {}",
        dataset.total_rows(),
        dataset.total_bytes(),
        dataset.seeds_unique()
    );
    if let Some(s) = service {
        // compile-amortization observability: hundreds of instances
        // should miss once per (kernel, bucket) and hit ever after
        match s.pool_usage() {
            Ok(usage) => println!("{}", usage.render()),
            Err(e) => println!("engine pool stats unavailable: {e}"),
        }
    }
    Ok(())
}

/// Under `--cfg loom` the lib compiles a reduced module set (lib.rs
/// gates out every subsystem this CLI drives), but cargo still builds
/// the bin target when the loom lane builds `tests/loom_models.rs` —
/// so the CLI reduces to a stub there.
#[cfg(loom)]
fn main() {}
