//! Deterministic seeded samplers over a [`ScenarioSpace`].
//!
//! The contract every implementation honors: **`(space, seed, index) →
//! point` is a pure function.**  No sampler keeps state between calls,
//! so a PBS array job can hand each node nothing but the campaign seed
//! and its own array index and every node materializes exactly the
//! point the plan assigned it — no rendezvous, no shared files
//! (property-tested in `rust/tests/scenario_props.rs`).
//!
//! Three samplers ship:
//!
//! * [`GridSampler`] — a full-factorial lattice in mixed-radix index
//!   order (first axis varies fastest); exhaustive but exponential in
//!   the axis count,
//! * [`UniformSampler`] — independent uniform draws per axis from a
//!   per-`(index, axis)` substream,
//! * [`LatinHypercubeSampler`] — `n` stratified samples per axis with a
//!   seeded per-axis permutation: across indices `0..n` every stratum
//!   of every continuous axis is covered exactly once.

use crate::util::Rng64;

use super::space::{ScenarioPoint, ScenarioSpace};

/// Derive an independent RNG stream for lane `(a, b)` of `seed` — pure.
/// SplitMix64's output mix decorrelates the neighboring lane seeds.
fn stream(seed: u64, a: u64, b: u64) -> Rng64 {
    Rng64::seed_from_u64(
        seed ^ a.wrapping_mul(0x9E37_79B9_7F4A_7C15)
            ^ b.wrapping_mul(0xC2B2_AE3D_27D4_EB4F),
    )
}

/// A deterministic seeded sampler: `(space, seed, index) → point`,
/// pure per the module contract.
pub trait Sampler: Send + Sync {
    fn sample(&self, space: &ScenarioSpace, seed: u64, index: u64) -> ScenarioPoint;

    /// Sampler label for manifests/logs.
    fn name(&self) -> &'static str;
}

/// Full-factorial lattice.  `points_per_axis` positions on continuous
/// axes (endpoints inclusive); integer axes enumerate their range (or
/// `points_per_axis` evenly spaced values when the range is larger);
/// choice axes enumerate their options.  The index walks the lattice in
/// mixed radix, first axis fastest, wrapping modulo the lattice size.
/// Ignores `seed` (a grid is already fully determined).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct GridSampler {
    pub points_per_axis: usize,
}

impl GridSampler {
    /// Lattice size for `space`.
    pub fn total_points(&self, space: &ScenarioSpace) -> u64 {
        space
            .axes
            .iter()
            .map(|a| a.grid_cardinality(self.points_per_axis) as u64)
            .product::<u64>()
            .max(1)
    }
}

impl Sampler for GridSampler {
    fn sample(&self, space: &ScenarioSpace, seed: u64, index: u64) -> ScenarioPoint {
        let mut rem = index % self.total_points(space);
        let values = space
            .axes
            .iter()
            .map(|ax| {
                let m = ax.grid_cardinality(self.points_per_axis) as u64;
                let k = rem % m;
                rem /= m;
                ax.grid_value(k as usize, m as usize)
            })
            .collect();
        ScenarioPoint {
            family: space.family.clone(),
            index,
            seed,
            values,
        }
    }

    fn name(&self) -> &'static str {
        "grid"
    }
}

/// Independent uniform draws, one substream per `(index, axis)` so the
/// sampled value of an axis does not shift when other axes are added.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Default)]
pub struct UniformSampler;

impl Sampler for UniformSampler {
    fn sample(&self, space: &ScenarioSpace, seed: u64, index: u64) -> ScenarioPoint {
        let values = space
            .axes
            .iter()
            .enumerate()
            .map(|(ai, ax)| {
                let mut rng = stream(seed, index, ai as u64);
                ax.value_at(rng.gen_f64())
            })
            .collect();
        ScenarioPoint {
            family: space.family.clone(),
            index,
            seed,
            values,
        }
    }

    fn name(&self) -> &'static str {
        "uniform"
    }
}

/// Latin-hypercube sampling with `strata` samples per axis.
///
/// Per axis, a seeded Fisher–Yates permutation of the strata assigns
/// index `i` (taken modulo `strata`) its stratum; the point jitters
/// uniformly inside it.  Every node recomputes the (deterministic)
/// permutation locally — O(strata) work, no coordination.  Indices
/// beyond `strata` revisit strata with fresh per-index jitter.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct LatinHypercubeSampler {
    pub strata: usize,
}

/// Salt distinguishing the permutation stream from the jitter streams.
const LHS_PERM_SALT: u64 = 0x5CE2_AA2D_0000_0001;

impl LatinHypercubeSampler {
    /// The stratum axis `axis` assigns to sample `i` — i.e. `perm[i]`
    /// of the seeded per-axis permutation.
    fn stratum_of(&self, seed: u64, axis: u64, i: u64) -> u64 {
        let n = self.strata.max(1) as u64;
        let mut perm: Vec<u64> = (0..n).collect();
        let mut rng = stream(seed, LHS_PERM_SALT, axis);
        for j in (1..n as usize).rev() {
            let k = rng.gen_below(j as u64 + 1) as usize;
            perm.swap(j, k);
        }
        perm[(i % n) as usize]
    }
}

impl Sampler for LatinHypercubeSampler {
    fn sample(&self, space: &ScenarioSpace, seed: u64, index: u64) -> ScenarioPoint {
        let n = self.strata.max(1) as u64;
        let values = space
            .axes
            .iter()
            .enumerate()
            .map(|(ai, ax)| {
                let stratum = self.stratum_of(seed, ai as u64, index);
                let mut rng = stream(seed, index.wrapping_add(1), ai as u64);
                let u = (stratum as f64 + rng.gen_f64()) / n as f64;
                ax.value_at(u)
            })
            .collect();
        ScenarioPoint {
            family: space.family.clone(),
            index,
            seed,
            values,
        }
    }

    fn name(&self) -> &'static str {
        "latin-hypercube"
    }
}

/// Plain-data sampler selector — what campaign configs and the
/// scenarios manifest store.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum SamplerKind {
    Grid { points_per_axis: usize },
    Uniform,
    Lhs { strata: usize },
}

impl SamplerKind {
    /// Parse `grid`, `grid:<k>`, `uniform`, `lhs`, or `lhs:<n>`.
    /// `default_strata` fills in the per-axis/strata count when the
    /// suffix is omitted (campaign configs pass samples-per-family).
    pub fn parse(text: &str, default_strata: usize) -> crate::Result<SamplerKind> {
        let (head, arg) = match text.split_once(':') {
            Some((h, a)) => (h, Some(a)),
            None => (text, None),
        };
        let parsed_arg = match arg {
            Some(a) => Some(a.parse::<usize>().map_err(|e| {
                crate::Error::Config(format!("bad sampler arg '{a}': {e}"))
            })?),
            None => None,
        };
        match head {
            "grid" => Ok(SamplerKind::Grid {
                points_per_axis: parsed_arg.unwrap_or(3).max(1),
            }),
            "uniform" => Ok(SamplerKind::Uniform),
            "lhs" | "latin-hypercube" => Ok(SamplerKind::Lhs {
                strata: parsed_arg.unwrap_or(default_strata).max(1),
            }),
            other => Err(crate::Error::Config(format!(
                "unknown sampler '{other}' (grid|uniform|lhs)"
            ))),
        }
    }

    pub fn name(&self) -> &'static str {
        match self {
            SamplerKind::Grid { .. } => "grid",
            SamplerKind::Uniform => "uniform",
            SamplerKind::Lhs { .. } => "latin-hypercube",
        }
    }

    /// Sample without boxing — dispatches to the matching sampler.
    pub fn sample(&self, space: &ScenarioSpace, seed: u64, index: u64) -> ScenarioPoint {
        match self {
            SamplerKind::Grid { points_per_axis } => GridSampler {
                points_per_axis: *points_per_axis,
            }
            .sample(space, seed, index),
            SamplerKind::Uniform => UniformSampler.sample(space, seed, index),
            SamplerKind::Lhs { strata } => LatinHypercubeSampler { strata: *strata }
                .sample(space, seed, index),
        }
    }

    /// Boxed form for callers that need a trait object.
    pub fn build(&self) -> Box<dyn Sampler> {
        match self {
            SamplerKind::Grid { points_per_axis } => Box::new(GridSampler {
                points_per_axis: *points_per_axis,
            }),
            SamplerKind::Uniform => Box::new(UniformSampler),
            SamplerKind::Lhs { strata } => {
                Box::new(LatinHypercubeSampler { strata: *strata })
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::scenario::space::{Axis, AxisValue};

    fn space() -> ScenarioSpace {
        ScenarioSpace::new(
            "s",
            vec![
                Axis::continuous("a", 0.0, 1.0),
                Axis::integer("b", 10, 12),
                Axis::choice("c", &["x", "y"]),
            ],
        )
    }

    #[test]
    fn grid_walks_the_lattice() {
        let s = space();
        let g = GridSampler { points_per_axis: 2 };
        assert_eq!(g.total_points(&s), 2 * 3 * 2);
        // first axis varies fastest
        let p0 = g.sample(&s, 0, 0);
        let p1 = g.sample(&s, 0, 1);
        assert_eq!(p0.values[0], AxisValue::Num(0.0));
        assert_eq!(p1.values[0], AxisValue::Num(1.0));
        assert_eq!(p0.values[1], p1.values[1]);
        // wraps modulo the lattice
        assert_eq!(g.sample(&s, 0, 12).values, p0.values);
    }

    #[test]
    fn uniform_is_pure_and_in_bounds() {
        let s = space();
        let u = UniformSampler;
        for i in 0..32 {
            let p = u.sample(&s, 42, i);
            assert_eq!(p, u.sample(&s, 42, i));
            match &p.values[0] {
                AxisValue::Num(v) => assert!((0.0..1.0).contains(v)),
                other => panic!("{other:?}"),
            }
            match &p.values[1] {
                AxisValue::Int(v) => assert!((10..=12).contains(v)),
                other => panic!("{other:?}"),
            }
        }
    }

    #[test]
    fn lhs_strata_cover_exactly_once() {
        let s = space();
        let n = 16;
        let l = LatinHypercubeSampler { strata: n };
        let mut strata: Vec<u64> = (0..n as u64)
            .map(|i| {
                let p = l.sample(&s, 7, i);
                match p.values[0] {
                    AxisValue::Num(v) => (v * n as f64) as u64,
                    _ => unreachable!(),
                }
            })
            .collect();
        strata.sort_unstable();
        assert_eq!(strata, (0..n as u64).collect::<Vec<_>>());
    }

    #[test]
    fn kind_parses_and_dispatches() {
        let s = space();
        assert_eq!(
            SamplerKind::parse("grid:4", 8).unwrap(),
            SamplerKind::Grid { points_per_axis: 4 }
        );
        assert_eq!(SamplerKind::parse("lhs", 8).unwrap(), SamplerKind::Lhs { strata: 8 });
        assert_eq!(SamplerKind::parse("uniform", 8).unwrap(), SamplerKind::Uniform);
        assert!(SamplerKind::parse("sobol", 8).is_err());
        assert!(SamplerKind::parse("lhs:x", 8).is_err());
        let k = SamplerKind::Lhs { strata: 4 };
        assert_eq!(k.sample(&s, 1, 2), k.build().sample(&s, 1, 2));
    }
}
