//! Scenario families: compiling sampled points into runnable configs.
//!
//! A [`ScenarioFamily`] owns a [`ScenarioSpace`] (what varies) and a
//! `compile` step mapping any [`ScenarioPoint`] to a [`ScenarioConfig`]
//! — the existing `(Network, Vec<FlowDef>, DriverParams)` config tuple
//! plus the stepper geometry, so the compiled scenario runs unchanged
//! through `SumoSim` with either `NativeIdmStepper` or
//! `ReferenceIdmStepper`.
//!
//! Four families ship in [`FamilyRegistry::builtin`]:
//!
//! * `highway-merge` — the paper's ch. 5 on-ramp merge, parametrized,
//! * `lane-drop` — a bottleneck where lane 0 ends at a taper; its
//!   traffic must merge out before the drop (the merge-zone machinery
//!   reused: mandatory lane change inside the taper, phantom wall at
//!   the drop point),
//! * `ramp-weave` — on-ramp plus downstream off-ramp around a shared
//!   auxiliary lane; off-route flows carry schema-3 destination intent
//!   (`FlowDef::exit_pos_m` = the gore), so exiting traffic actually
//!   leaves at the off-ramp instead of riding to the road end,
//! * `ring-shockwave` — stop-and-go waves: a dense departure burst on a
//!   closed ring (unrolled over enough laps that density is conserved
//!   for the whole horizon), low desired speeds, wide headway
//!   heterogeneity.
//!
//! Speed-limit axes reach the dynamics through per-flow `v0_scale`
//! (desired speed = scale × the vtype's calibration); headway
//! perturbation axes through `t_scale` — see `sumo::FlowDef`.  Route
//! destinations reach them through `exit_pos_m` → the params rows'
//! `[exit_pos, exit_flag]` columns.

use crate::sumo::state::DriverParams;
use crate::sumo::{
    duarouter, steps_for, DepartureTable, Edge, FlowDef, FlowFile, MergeScenario, Network,
    VehicleType,
};
use crate::{Error, Result};

use super::sampler::Sampler;
use super::space::{Axis, ScenarioId, ScenarioPoint, ScenarioSpace, ScenarioTag};

/// A compiled, runnable scenario: the config tuple the pipeline already
/// consumes, plus provenance and sizing hints.
#[derive(Debug, Clone, PartialEq)]
pub struct ScenarioConfig {
    /// Which point generated this config (lands in `RunDataset`).
    pub tag: ScenarioTag,
    /// Stepper constants (road end, merge/mandatory-zone window, lane
    /// count, DT) — consumed by `NativeIdmStepper`/`ReferenceIdmStepper`.
    pub geometry: MergeScenario,
    /// The `sumo.net.xml` side.
    pub network: Network,
    /// The `sumo.flow.xml` side (routes validated against `network`).
    pub flows: FlowFile,
    /// The perturbed human driver baseline this point encodes (the
    /// per-flow scales carry it into `duarouter`).
    pub driver: DriverParams,
    /// Suggested traffic slot capacity (next AOT-style bucket above the
    /// expected vehicle count).  A bare `ScenarioFamily::compile` fills
    /// this from [`DEFAULT_BUCKET_LADDER`] (clamped — compile is
    /// infallible across the space by contract); registry
    /// materialization re-derives it against the real lowered ladder
    /// and REFUSES overflowing points ([`FamilyRegistry::rebucket`] is
    /// the enforcement point).
    pub capacity: usize,
    /// Suggested simulated horizon [s].
    pub horizon_s: f32,
}

impl ScenarioConfig {
    /// Total steps of the configured horizon — the run-ladder rung a
    /// whole-run dispatch needs to cover this config end to end (the
    /// same `steps_for` derivation the launcher's walltime guard uses).
    pub fn horizon_steps(&self) -> u64 {
        steps_for(self.horizon_s, self.geometry.dt_s)
    }

    /// Emit the schema-5 departure table at plan time: route this
    /// config's demand with `seed` (the identical `duarouter` call the
    /// launcher makes) and compile it into the flattened `f32[D, 12]`
    /// table the whole-run entry points take as an operand.  Epoch
    /// indices derive from the same f32 time-accumulation chain as the
    /// host scheduler's `insert_due` clock (`departure_epochs`), so
    /// in-kernel insertion steps agree bit-exactly with host stepping.
    /// Returns `Ok(None)` when the demand due within `t_steps`
    /// overflows `table_rows` — the run then stays on host chunking.
    pub fn departure_table(
        &self,
        seed: u64,
        t_steps: u64,
        table_rows: usize,
    ) -> Result<Option<DepartureTable>> {
        let routes = duarouter(&self.network, &self.flows, seed)?;
        Ok(DepartureTable::build(
            &routes.departures,
            self.geometry.dt_s,
            t_steps,
            table_rows,
        ))
    }
}

/// What the launcher threads through an instance beyond the classic
/// fields: provenance for the dataset and the compiled network for
/// route generation.
#[derive(Debug, Clone)]
pub struct ScenarioRun {
    pub tag: ScenarioTag,
    pub network: Network,
}

impl From<&ScenarioConfig> for ScenarioRun {
    fn from(c: &ScenarioConfig) -> Self {
        ScenarioRun {
            tag: c.tag.clone(),
            network: c.network.clone(),
        }
    }
}

/// A parametric scenario family: a space plus its compiler.
pub trait ScenarioFamily: Send + Sync {
    fn id(&self) -> ScenarioId;

    /// The family's parameter axes.
    fn space(&self) -> ScenarioSpace;

    /// Compile one sampled point into a runnable config.  Pure; must
    /// succeed anywhere inside the space (extremes included —
    /// `rust/tests/scenario_families.rs` holds it to that).
    fn compile(&self, point: &ScenarioPoint) -> Result<ScenarioConfig>;
}

/// Registry of known families — the lookup the campaign matrix and the
/// CLI resolve `ScenarioId`s through.  It also owns the bucket ladder
/// capacities are suggested from: [`DEFAULT_BUCKET_LADDER`] out of the
/// box, or the *actually lowered* buckets of a loaded artifact manifest
/// via [`FamilyRegistry::with_buckets`], so every materialized point
/// rides the PJRT path.
pub struct FamilyRegistry {
    families: Vec<Box<dyn ScenarioFamily>>,
    /// Sorted capacity ladder; never empty.
    buckets: Vec<usize>,
}

impl Default for FamilyRegistry {
    fn default() -> Self {
        FamilyRegistry::new()
    }
}

impl FamilyRegistry {
    /// An empty registry (register your own families).
    pub fn new() -> Self {
        FamilyRegistry {
            families: Vec::new(),
            buckets: DEFAULT_BUCKET_LADDER.to_vec(),
        }
    }

    /// The four built-in families.
    pub fn builtin() -> Self {
        let mut r = FamilyRegistry::new();
        r.register(Box::new(HighwayMergeFamily));
        r.register(Box::new(LaneDropFamily));
        r.register(Box::new(RampWeaveFamily));
        r.register(Box::new(RingShockwaveFamily));
        r
    }

    /// Suggest capacities from this bucket ladder instead of the
    /// hard-coded default — pass the loaded manifest's `buckets` so a
    /// family-suggested capacity is always a lowered PJRT executable.
    /// Empty ladders are ignored.
    pub fn with_buckets(mut self, buckets: &[usize]) -> Self {
        if !buckets.is_empty() {
            self.buckets = buckets.to_vec();
            self.buckets.sort_unstable();
            self.buckets.dedup();
        }
        self
    }

    /// The capacity ladder this registry suggests from.
    pub fn buckets(&self) -> &[usize] {
        &self.buckets
    }

    /// Re-derive a compiled config's suggested capacity against this
    /// registry's ladder (families compile with the default ladder).
    /// A point whose expected demand overflows even the largest bucket
    /// is REFUSED rather than clamped: a silently truncated bucket
    /// queues spawns forever and corrupts the run's flow/exit metrics,
    /// and a stderr warning is invisible to a PBS array — better to
    /// fail the run loudly and keep the dataset trustworthy.
    pub fn rebucket(&self, config: &mut ScenarioConfig) -> Result<()> {
        let expected = config.flows.total_expected_vehicles();
        let largest = match self.buckets.last() {
            Some(&b) => b,
            None => return Err(Error::Config("registry bucket ladder is empty".into())),
        };
        if bucket_need(expected) > largest as f32 {
            return Err(Error::Config(format!(
                "scenario '{}' #{} expects ~{expected:.0} vehicles (needs \
                 ~{:.0} slots) but the largest lowered bucket is {largest}; \
                 lower a bigger bucket or shrink the point",
                config.tag.id,
                config.tag.sample_index,
                bucket_need(expected),
            )));
        }
        config.capacity = bucket_capacity_in(expected, &self.buckets);
        Ok(())
    }

    pub fn register(&mut self, family: Box<dyn ScenarioFamily>) {
        self.families.push(family);
    }

    pub fn ids(&self) -> Vec<String> {
        self.families.iter().map(|f| f.id().0).collect()
    }

    pub fn get(&self, id: &str) -> Result<&dyn ScenarioFamily> {
        self.families
            .iter()
            .map(|f| f.as_ref())
            .find(|f| f.id().as_str() == id)
            .ok_or_else(|| {
                Error::Config(format!(
                    "unknown scenario family '{id}' (known: {})",
                    self.ids().join(", ")
                ))
            })
    }

    /// Sample + compile in one step: the `(family, seed, index) →
    /// runnable config` pure function PBS array nodes call.  The
    /// suggested capacity comes from this registry's bucket ladder.
    pub fn materialize(
        &self,
        family: &str,
        sampler: &dyn Sampler,
        seed: u64,
        index: u64,
    ) -> Result<(ScenarioPoint, ScenarioConfig)> {
        let fam = self.get(family)?;
        let point = sampler.sample(&fam.space(), seed, index);
        let mut config = fam.compile(&point)?;
        self.rebucket(&mut config)?;
        Ok((point, config))
    }
}

/// Demand/placement parameters of one flow to split by CAV penetration.
struct FlowSpec<'a> {
    id: &'a str,
    route: &'a [String],
    vph: f32,
    depart_speed: f32,
    depart_lane: u32,
    depart_pos: f32,
    /// Destination intent compiled from the route: `Some(gore_x)` for
    /// off-ramp routes, `None` for through/on routes (exit at road end).
    exit_pos: Option<f32>,
}

/// Split `spec` into a human and a CAV flow by penetration, applying
/// the scenario-level driver scales; near-zero flows are dropped.
fn push_split(
    out: &mut Vec<FlowDef>,
    spec: FlowSpec<'_>,
    cav_penetration: f32,
    window: (f32, f32),
    scales: (f32, f32),
) {
    let (v0_scale, t_scale) = scales;
    let parts = [
        (VehicleType::Human, 1.0 - cav_penetration, ""),
        (VehicleType::Cav, cav_penetration, "_cav"),
    ];
    for (vtype, share, suffix) in parts {
        let vph = spec.vph * share;
        if vph < 1e-3 {
            continue;
        }
        out.push(FlowDef {
            id: format!("{}{suffix}", spec.id),
            route: spec.route.to_vec(),
            vehs_per_hour: vph,
            depart_speed: spec.depart_speed,
            depart_lane: spec.depart_lane,
            depart_pos: spec.depart_pos,
            vtype,
            begin_s: window.0,
            end_s: window.1,
            v0_scale,
            t_scale,
            exit_pos_m: spec.exit_pos,
        });
    }
}

/// The AOT bucket ladder assumed when no artifact manifest is loaded —
/// MUST mirror `python/compile/aot.py BUCKETS` (pinned by
/// `scripts/check_manifest.py`), so a family-suggested capacity always
/// has a PJRT executable.
pub const DEFAULT_BUCKET_LADDER: [usize; 4] = [16, 64, 256, 1024];

/// Slot demand a bucket must hold for `expected_vehicles`: the expected
/// count with slack for arrival bursts — the single formula both the
/// ladder walk and the clamp warning in [`FamilyRegistry::rebucket`]
/// decide from.
fn bucket_need(expected_vehicles: f32) -> f32 {
    expected_vehicles * 1.3 + 8.0
}

/// Next bucket in `ladder` above the expected vehicle count; clamps to
/// the largest lowered bucket.
fn bucket_capacity_in(expected_vehicles: f32, ladder: &[usize]) -> usize {
    let need = bucket_need(expected_vehicles);
    ladder
        .iter()
        .copied()
        .find(|&b| need <= b as f32)
        .unwrap_or_else(|| ladder.last().copied().unwrap_or(16))
}

/// [`bucket_capacity_in`] over the default ladder — what a bare
/// `ScenarioFamily::compile` (no registry context) suggests.  This path
/// clamps (compile is infallible by contract); the refuse-on-overflow
/// policy lives in [`FamilyRegistry::rebucket`], which registry/matrix
/// materialization always runs.
fn bucket_capacity(expected_vehicles: f32) -> usize {
    bucket_capacity_in(expected_vehicles, &DEFAULT_BUCKET_LADDER)
}

/// The perturbed human driver baseline a point encodes.
fn perturbed_driver(v0_scale: f32, t_scale: f32) -> DriverParams {
    let base = DriverParams::default();
    DriverParams {
        v0: base.v0 * v0_scale,
        t_headway: base.t_headway * t_scale,
        ..base
    }
}

fn route(ids: &[&str]) -> Vec<String> {
    ids.iter().map(|s| s.to_string()).collect()
}

// ---------------------------------------------------------------------
// highway-merge
// ---------------------------------------------------------------------

/// The paper's ch. 5 on-ramp merge, parametrized.
pub struct HighwayMergeFamily;

impl ScenarioFamily for HighwayMergeFamily {
    fn id(&self) -> ScenarioId {
        ScenarioId::new("highway-merge")
    }

    fn space(&self) -> ScenarioSpace {
        ScenarioSpace::new(
            "highway-merge",
            vec![
                Axis::continuous("demand_vph", 600.0, 2400.0),
                Axis::continuous("ramp_vph", 120.0, 600.0),
                Axis::continuous("cav_penetration", 0.0, 1.0),
                Axis::integer("main_lanes", 1, 3),
                Axis::continuous("speed_limit", 25.0, 35.0),
                Axis::continuous("merge_len_m", 150.0, 300.0),
                Axis::continuous("t_scale", 0.85, 1.15),
            ],
        )
    }

    fn compile(&self, point: &ScenarioPoint) -> Result<ScenarioConfig> {
        let space = self.space();
        let demand = point.num(&space, "demand_vph")? as f32;
        let ramp_vph = point.num(&space, "ramp_vph")? as f32;
        let p_cav = point.num(&space, "cav_penetration")? as f32;
        let lanes = point.int(&space, "main_lanes")? as u32;
        let speed = point.num(&space, "speed_limit")? as f32;
        let merge_len = point.num(&space, "merge_len_m")? as f32;
        let t_scale = point.num(&space, "t_scale")? as f32;
        let v0_scale = speed / DriverParams::default().v0;

        let geometry = MergeScenario {
            road_end_m: 1000.0,
            merge_start_m: 300.0,
            merge_end_m: 300.0 + merge_len,
            num_main_lanes: lanes,
            dt_s: 0.1,
        };
        let network = geometry.network_with_speeds(speed, speed * 0.7);
        let horizon_s = 120.0;

        let main_route = route(&["main_in", "merge_zone", "main_out"]);
        let ramp_route = route(&["ramp", "merge_zone", "main_out"]);
        let mut flows = Vec::new();
        for lane in 1..=lanes {
            push_split(
                &mut flows,
                FlowSpec {
                    id: &format!("main_l{lane}"),
                    route: &main_route,
                    vph: demand / lanes as f32,
                    depart_speed: speed * 0.8,
                    depart_lane: lane,
                    depart_pos: 0.0,
                    exit_pos: None,
                },
                p_cav,
                (0.0, horizon_s),
                (v0_scale, t_scale),
            );
        }
        push_split(
            &mut flows,
            FlowSpec {
                id: "ramp",
                route: &ramp_route,
                vph: ramp_vph,
                depart_speed: 15.0,
                depart_lane: 0,
                depart_pos: 50.0,
                exit_pos: None,
            },
            p_cav,
            (0.0, horizon_s),
            (v0_scale, t_scale),
        );

        let flows = FlowFile { flows };
        flows.validate(&network)?;
        flows.validate_exits(geometry.road_end_m)?;
        let capacity = bucket_capacity(flows.total_expected_vehicles());
        Ok(ScenarioConfig {
            tag: point.provenance(&space),
            geometry,
            network,
            flows,
            driver: perturbed_driver(v0_scale, t_scale),
            capacity,
            horizon_s,
        })
    }
}

// ---------------------------------------------------------------------
// lane-drop
// ---------------------------------------------------------------------

/// A lane-drop bottleneck: lane 0 ends at `drop_pos_m`; its traffic
/// must merge out inside the taper (mandatory-merge zone), with the
/// phantom wall standing in for the physical end of the lane.
pub struct LaneDropFamily;

impl ScenarioFamily for LaneDropFamily {
    fn id(&self) -> ScenarioId {
        ScenarioId::new("lane-drop")
    }

    fn space(&self) -> ScenarioSpace {
        ScenarioSpace::new(
            "lane-drop",
            vec![
                Axis::continuous("demand_vph", 800.0, 3000.0),
                Axis::integer("upstream_lanes", 2, 4),
                Axis::continuous("drop_pos_m", 400.0, 700.0),
                Axis::continuous("taper_len_m", 100.0, 250.0),
                Axis::continuous("cav_penetration", 0.0, 1.0),
                Axis::continuous("speed_limit", 25.0, 33.0),
                Axis::continuous("t_scale", 0.85, 1.15),
            ],
        )
    }

    fn compile(&self, point: &ScenarioPoint) -> Result<ScenarioConfig> {
        let space = self.space();
        let demand = point.num(&space, "demand_vph")? as f32;
        let upstream = point.int(&space, "upstream_lanes")? as u32;
        let drop_pos = point.num(&space, "drop_pos_m")? as f32;
        let taper = point.num(&space, "taper_len_m")? as f32;
        let p_cav = point.num(&space, "cav_penetration")? as f32;
        let speed = point.num(&space, "speed_limit")? as f32;
        let t_scale = point.num(&space, "t_scale")? as f32;
        let v0_scale = speed / DriverParams::default().v0;

        let geometry = MergeScenario {
            road_end_m: drop_pos + 300.0,
            merge_start_m: drop_pos - taper,
            merge_end_m: drop_pos,
            num_main_lanes: upstream - 1,
            dt_s: 0.1,
        };
        let network = Network {
            edges: vec![
                Edge {
                    id: "approach".into(),
                    from: "west".into(),
                    to: "taper_a".into(),
                    length_m: geometry.merge_start_m,
                    num_lanes: upstream,
                    speed_limit: speed,
                },
                Edge {
                    id: "taper".into(),
                    from: "taper_a".into(),
                    to: "taper_b".into(),
                    length_m: taper,
                    num_lanes: upstream,
                    speed_limit: speed,
                },
                Edge {
                    id: "downstream".into(),
                    from: "taper_b".into(),
                    to: "east".into(),
                    length_m: 300.0,
                    num_lanes: upstream - 1,
                    speed_limit: speed,
                },
            ],
        };
        let horizon_s = 120.0;
        let full_route = route(&["approach", "taper", "downstream"]);

        let mut flows = Vec::new();
        let per_lane = demand / upstream as f32;
        // lane 0 is the dropping lane — its flow is what the bottleneck
        // squeezes out
        push_split(
            &mut flows,
            FlowSpec {
                id: "drop_lane",
                route: &full_route,
                vph: per_lane,
                depart_speed: speed * 0.8,
                depart_lane: 0,
                depart_pos: 0.0,
                exit_pos: None,
            },
            p_cav,
            (0.0, horizon_s),
            (v0_scale, t_scale),
        );
        for lane in 1..upstream {
            push_split(
                &mut flows,
                FlowSpec {
                    id: &format!("main_l{lane}"),
                    route: &full_route,
                    vph: per_lane,
                    depart_speed: speed * 0.8,
                    depart_lane: lane,
                    depart_pos: 0.0,
                    exit_pos: None,
                },
                p_cav,
                (0.0, horizon_s),
                (v0_scale, t_scale),
            );
        }

        let flows = FlowFile { flows };
        flows.validate(&network)?;
        flows.validate_exits(geometry.road_end_m)?;
        let capacity = bucket_capacity(flows.total_expected_vehicles());
        Ok(ScenarioConfig {
            tag: point.provenance(&space),
            geometry,
            network,
            flows,
            driver: perturbed_driver(v0_scale, t_scale),
            capacity,
            horizon_s,
        })
    }
}

// ---------------------------------------------------------------------
// ramp-weave
// ---------------------------------------------------------------------

/// On-ramp + downstream off-ramp around a shared auxiliary lane.  The
/// on-ramp stream enters on the auxiliary lane and must merge before
/// the weave ends; the off-ramp stream carries schema-3 destination
/// intent (`exit_pos` = the gore at the weave end), so the steppers
/// bias it toward lane 1 and retire it at the off-ramp — through/on
/// traffic still retires at the road end.
pub struct RampWeaveFamily;

impl ScenarioFamily for RampWeaveFamily {
    fn id(&self) -> ScenarioId {
        ScenarioId::new("ramp-weave")
    }

    fn space(&self) -> ScenarioSpace {
        ScenarioSpace::new(
            "ramp-weave",
            vec![
                Axis::continuous("main_vph", 800.0, 2400.0),
                Axis::continuous("on_vph", 150.0, 600.0),
                Axis::continuous("off_share", 0.0, 0.3),
                Axis::integer("main_lanes", 2, 3),
                Axis::continuous("weave_len_m", 150.0, 350.0),
                Axis::continuous("cav_penetration", 0.0, 1.0),
                Axis::continuous("speed_limit", 25.0, 35.0),
                Axis::continuous("t_scale", 0.85, 1.15),
            ],
        )
    }

    fn compile(&self, point: &ScenarioPoint) -> Result<ScenarioConfig> {
        let space = self.space();
        let main_vph = point.num(&space, "main_vph")? as f32;
        let on_vph = point.num(&space, "on_vph")? as f32;
        let off_share = point.num(&space, "off_share")? as f32;
        let lanes = point.int(&space, "main_lanes")? as u32;
        let weave_len = point.num(&space, "weave_len_m")? as f32;
        let p_cav = point.num(&space, "cav_penetration")? as f32;
        let speed = point.num(&space, "speed_limit")? as f32;
        let t_scale = point.num(&space, "t_scale")? as f32;
        let v0_scale = speed / DriverParams::default().v0;

        let geometry = MergeScenario {
            road_end_m: 1000.0,
            merge_start_m: 300.0,
            merge_end_m: 300.0 + weave_len,
            num_main_lanes: lanes,
            dt_s: 0.1,
        };
        let network = Network {
            edges: vec![
                Edge {
                    id: "main_in".into(),
                    from: "west".into(),
                    to: "weave_a".into(),
                    length_m: 300.0,
                    num_lanes: lanes,
                    speed_limit: speed,
                },
                Edge {
                    id: "weave".into(),
                    from: "weave_a".into(),
                    to: "weave_b".into(),
                    length_m: weave_len,
                    num_lanes: lanes + 1, // + auxiliary lane
                    speed_limit: speed,
                },
                Edge {
                    id: "main_out".into(),
                    from: "weave_b".into(),
                    to: "east".into(),
                    length_m: 1000.0 - (300.0 + weave_len),
                    num_lanes: lanes,
                    speed_limit: speed,
                },
                Edge {
                    id: "on_ramp".into(),
                    from: "on_start".into(),
                    to: "weave_a".into(),
                    length_m: 300.0,
                    num_lanes: 1,
                    speed_limit: speed * 0.7,
                },
                Edge {
                    id: "off_ramp".into(),
                    from: "weave_b".into(),
                    to: "off_end".into(),
                    length_m: 150.0,
                    num_lanes: 1,
                    speed_limit: speed * 0.7,
                },
            ],
        };
        let horizon_s = 120.0;
        let through_route = route(&["main_in", "weave", "main_out"]);
        let on_route = route(&["on_ramp", "weave", "main_out"]);
        let off_route = route(&["main_in", "weave", "off_ramp"]);

        let mut flows = Vec::new();
        let through_vph = main_vph * (1.0 - off_share);
        for lane in 1..=lanes {
            push_split(
                &mut flows,
                FlowSpec {
                    id: &format!("through_l{lane}"),
                    route: &through_route,
                    vph: through_vph / lanes as f32,
                    depart_speed: speed * 0.8,
                    depart_lane: lane,
                    depart_pos: 0.0,
                    exit_pos: None,
                },
                p_cav,
                (0.0, horizon_s),
                (v0_scale, t_scale),
            );
        }
        // exiting traffic rides lane 1 toward the off-ramp and leaves
        // at the gore (the weave end), compiled into the schema-3
        // destination columns — no longer the "retire at road end"
        // approximation
        push_split(
            &mut flows,
            FlowSpec {
                id: "off",
                route: &off_route,
                vph: main_vph * off_share,
                depart_speed: speed * 0.8,
                depart_lane: 1,
                depart_pos: 0.0,
                exit_pos: Some(geometry.merge_end_m),
            },
            p_cav,
            (0.0, horizon_s),
            (v0_scale, t_scale),
        );
        push_split(
            &mut flows,
            FlowSpec {
                id: "on",
                route: &on_route,
                vph: on_vph,
                depart_speed: 15.0,
                depart_lane: 0,
                depart_pos: 50.0,
                exit_pos: None,
            },
            p_cav,
            (0.0, horizon_s),
            (v0_scale, t_scale),
        );

        let flows = FlowFile { flows };
        flows.validate(&network)?;
        flows.validate_exits(geometry.road_end_m)?;
        let capacity = bucket_capacity(flows.total_expected_vehicles());
        Ok(ScenarioConfig {
            tag: point.provenance(&space),
            geometry,
            network,
            flows,
            driver: perturbed_driver(v0_scale, t_scale),
            capacity,
            horizon_s,
        })
    }
}

// ---------------------------------------------------------------------
// ring-shockwave
// ---------------------------------------------------------------------

/// Stop-and-go shockwaves: a dense departure burst on a closed ring
/// (modeled as the ring unrolled over enough laps that **no vehicle
/// reaches the road end inside the horizon** — see
/// [`RingShockwaveFamily::laps_for`] — since the steppers integrate a
/// linear road), low desired speeds and wide headway heterogeneity —
/// the classic instability setup.  No lane 0 is used, so the merge wall
/// is inert.
pub struct RingShockwaveFamily;

impl RingShockwaveFamily {
    /// Departure burst window [s] that packs the ring.
    pub const BURST_S: f32 = 30.0;
    /// Simulated horizon [s].
    pub const HORIZON_S: f32 = 180.0;

    /// Laps the ring is unrolled over: enough road that a vehicle at
    /// the desired speed (plus the duarouter's +10% jitter headroom)
    /// cannot reach `road_end` within the horizon, so the platoon
    /// density is conserved for the whole run instead of draining
    /// mid-horizon.  Floor of 3 keeps short/slow configs multi-lap.
    pub fn laps_for(circumference_m: f32, speed_limit: f32, horizon_s: f32) -> f32 {
        let reach = horizon_s * speed_limit * 1.2;
        (reach / circumference_m).ceil().max(3.0)
    }
}

impl ScenarioFamily for RingShockwaveFamily {
    fn id(&self) -> ScenarioId {
        ScenarioId::new("ring-shockwave")
    }

    fn space(&self) -> ScenarioSpace {
        ScenarioSpace::new(
            "ring-shockwave",
            vec![
                Axis::continuous("circumference_m", 400.0, 1200.0),
                Axis::integer("lanes", 1, 2),
                Axis::continuous("density_veh_km", 20.0, 60.0),
                Axis::continuous("speed_limit", 18.0, 30.0),
                Axis::continuous("cav_penetration", 0.0, 1.0),
                Axis::continuous("t_scale", 0.9, 1.3),
            ],
        )
    }

    fn compile(&self, point: &ScenarioPoint) -> Result<ScenarioConfig> {
        let space = self.space();
        let circ = point.num(&space, "circumference_m")? as f32;
        let lanes = point.int(&space, "lanes")? as u32;
        let density = point.num(&space, "density_veh_km")? as f32;
        let speed = point.num(&space, "speed_limit")? as f32;
        let p_cav = point.num(&space, "cav_penetration")? as f32;
        let t_scale = point.num(&space, "t_scale")? as f32;
        let v0_scale = speed / DriverParams::default().v0;

        let horizon_s = Self::HORIZON_S;
        let geometry = MergeScenario {
            road_end_m: circ * Self::laps_for(circ, speed, horizon_s),
            // no mandatory-merge zone and no lane 0 → the wall is inert
            merge_start_m: 0.0,
            merge_end_m: 0.0,
            num_main_lanes: lanes,
            dt_s: 0.1,
        };
        let arc = circ / 4.0;
        let network = Network {
            edges: vec![
                Edge {
                    id: "ring_n".into(),
                    from: "n0".into(),
                    to: "n1".into(),
                    length_m: arc,
                    num_lanes: lanes,
                    speed_limit: speed,
                },
                Edge {
                    id: "ring_e".into(),
                    from: "n1".into(),
                    to: "n2".into(),
                    length_m: arc,
                    num_lanes: lanes,
                    speed_limit: speed,
                },
                Edge {
                    id: "ring_s".into(),
                    from: "n2".into(),
                    to: "n3".into(),
                    length_m: arc,
                    num_lanes: lanes,
                    speed_limit: speed,
                },
                Edge {
                    id: "ring_w".into(),
                    from: "n3".into(),
                    to: "n0".into(), // closes the loop
                    length_m: arc,
                    num_lanes: lanes,
                    speed_limit: speed,
                },
            ],
        };
        let lap_route = route(&["ring_n", "ring_e", "ring_s", "ring_w"]);

        // pack `density × circ` vehicles per lane inside the burst window
        let veh_per_lane = density * circ / 1000.0;
        let burst_vph = veh_per_lane * 3600.0 / Self::BURST_S;
        let mut flows = Vec::new();
        for lane in 1..=lanes {
            push_split(
                &mut flows,
                FlowSpec {
                    id: &format!("ring_l{lane}"),
                    route: &lap_route,
                    vph: burst_vph,
                    depart_speed: 5.0,
                    depart_lane: lane,
                    depart_pos: 0.0,
                    exit_pos: None,
                },
                p_cav,
                (0.0, Self::BURST_S),
                (v0_scale, t_scale),
            );
        }

        let flows = FlowFile { flows };
        flows.validate(&network)?;
        flows.validate_exits(geometry.road_end_m)?;
        let capacity = bucket_capacity(flows.total_expected_vehicles());
        Ok(ScenarioConfig {
            tag: point.provenance(&space),
            geometry,
            network,
            flows,
            driver: perturbed_driver(v0_scale, t_scale),
            capacity,
            horizon_s,
        })
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::scenario::sampler::UniformSampler;

    #[test]
    fn registry_resolves_builtins() {
        let r = FamilyRegistry::builtin();
        assert_eq!(
            r.ids(),
            vec!["highway-merge", "lane-drop", "ramp-weave", "ring-shockwave"]
        );
        assert!(r.get("lane-drop").is_ok());
        assert!(r.get("nope").is_err());
    }

    #[test]
    fn materialize_is_deterministic() {
        let r = FamilyRegistry::builtin();
        let s = UniformSampler;
        let (p1, c1) = r.materialize("ring-shockwave", &s, 11, 3).unwrap();
        let (p2, c2) = r.materialize("ring-shockwave", &s, 11, 3).unwrap();
        assert_eq!(p1, p2);
        assert_eq!(c1, c2);
        let (p3, _) = r.materialize("ring-shockwave", &s, 12, 3).unwrap();
        assert_ne!(p1.values, p3.values);
    }

    #[test]
    fn compiled_config_is_internally_consistent() {
        let r = FamilyRegistry::builtin();
        for id in r.ids() {
            let (point, cfg) = r.materialize(&id, &UniformSampler, 5, 0).unwrap();
            assert_eq!(cfg.tag.id.as_str(), id);
            assert_eq!(cfg.tag.sample_index, point.index);
            assert!(cfg.geometry.num_main_lanes >= 1, "{id}");
            assert!(cfg.capacity >= 16, "{id}");
            assert!(cfg.horizon_s > 0.0, "{id}");
            assert!(cfg.flows.total_expected_vehicles() > 0.0, "{id}");
            cfg.flows.validate(&cfg.network).unwrap();
            // cfg.driver is the summary form of the per-flow scales:
            // every human flow's base params must equal it exactly —
            // modulo the per-flow destination columns, which are route
            // intent rather than driver calibration
            for flow in &cfg.flows.flows {
                if flow.vtype == VehicleType::Human {
                    let behavioral = DriverParams {
                        exit_pos: 0.0,
                        exit_flag: 0.0,
                        ..flow.base_params()
                    };
                    assert_eq!(behavioral, cfg.driver, "{id}");
                }
            }
        }
    }

    #[test]
    fn ramp_weave_off_flows_exit_at_the_gore() {
        let r = FamilyRegistry::builtin();
        let (_, cfg) = r.materialize("ramp-weave", &UniformSampler, 5, 0).unwrap();
        let off: Vec<_> = cfg
            .flows
            .flows
            .iter()
            .filter(|f| f.id.starts_with("off"))
            .collect();
        assert!(!off.is_empty(), "off_share > 0 at this point");
        for f in &off {
            assert_eq!(f.exit_pos_m, Some(cfg.geometry.merge_end_m), "{}", f.id);
            assert!(f.base_params().exits());
        }
        // through/on routes ride to the road end
        for f in cfg.flows.flows.iter().filter(|f| !f.id.starts_with("off")) {
            assert_eq!(f.exit_pos_m, None, "{}", f.id);
        }
    }

    #[test]
    fn ring_road_end_outruns_the_horizon() {
        // density conservation: no vehicle can reach road_end within the
        // horizon even at desired speed + jitter headroom
        let r = FamilyRegistry::builtin();
        for idx in 0..6u64 {
            let (point, cfg) = r
                .materialize("ring-shockwave", &UniformSampler, 9, idx)
                .unwrap();
            let space = r.get("ring-shockwave").unwrap().space();
            let speed = point.num(&space, "speed_limit").unwrap() as f32;
            assert!(
                cfg.geometry.road_end_m > cfg.horizon_s * speed * 1.1,
                "idx {idx}: road_end {} vs reach {}",
                cfg.geometry.road_end_m,
                cfg.horizon_s * speed * 1.1
            );
        }
    }

    #[test]
    fn cav_penetration_splits_flows() {
        let mut out = Vec::new();
        let r = route(&["a"]);
        push_split(
            &mut out,
            FlowSpec {
                id: "f",
                route: &r,
                vph: 1000.0,
                depart_speed: 20.0,
                depart_lane: 1,
                depart_pos: 0.0,
                exit_pos: None,
            },
            0.25,
            (0.0, 60.0),
            (1.0, 1.0),
        );
        assert_eq!(out.len(), 2);
        assert_eq!(out[0].vtype, VehicleType::Human);
        assert!((out[0].vehs_per_hour - 750.0).abs() < 1e-3);
        assert_eq!(out[1].vtype, VehicleType::Cav);
        assert!((out[1].vehs_per_hour - 250.0).abs() < 1e-3);
        // pure extremes collapse to one flow
        let mut lone = Vec::new();
        push_split(
            &mut lone,
            FlowSpec {
                id: "f",
                route: &r,
                vph: 1000.0,
                depart_speed: 20.0,
                depart_lane: 1,
                depart_pos: 0.0,
                exit_pos: None,
            },
            0.0,
            (0.0, 60.0),
            (1.0, 1.0),
        );
        assert_eq!(lone.len(), 1);
        assert_eq!(lone[0].vtype, VehicleType::Human);
    }

    #[test]
    fn plan_time_departure_tables_for_all_families() {
        use crate::sumo::{departure_epochs, DEP_COLS, DEP_PAD_EPOCH, D_STEP};
        let r = FamilyRegistry::builtin();
        for id in r.ids() {
            let (_, cfg) = r.materialize(&id, &UniformSampler, 3, 1).unwrap();
            let t_steps = cfg.horizon_steps();
            let table = cfg
                .departure_table(42, t_steps, 1024)
                .unwrap()
                .unwrap_or_else(|| panic!("{id}: demand overflowed 1024 rows"));
            assert!(table.count > 0, "{id}: no demand tabled");
            // plan-time epochs come from the identical routing + f32
            // accumulation chain the host scheduler uses
            let routes = duarouter(&cfg.network, &cfg.flows, 42).unwrap();
            let epochs = departure_epochs(&routes.departures, cfg.geometry.dt_s, t_steps);
            for (i, &e) in epochs.iter().take(table.count).enumerate() {
                assert_eq!(table.rows[i * DEP_COLS + D_STEP], e as f32, "{id} row {i}");
            }
            for i in table.count..table.capacity {
                assert_eq!(table.rows[i * DEP_COLS + D_STEP], DEP_PAD_EPOCH, "{id}");
            }
            // a capacity too small for the due demand refuses rather
            // than truncating the schedule
            assert!(cfg.departure_table(42, t_steps, 1).unwrap().is_none(), "{id}");
        }
    }

    #[test]
    fn bucket_capacity_steps() {
        assert_eq!(bucket_capacity(0.0), 16);
        assert_eq!(bucket_capacity(40.0), 64);
        assert_eq!(bucket_capacity(150.0), 256);
        assert_eq!(bucket_capacity(5000.0), 1024);
    }

    #[test]
    fn registry_ladder_drives_suggested_capacity() {
        let (_, cfg) = FamilyRegistry::builtin()
            .materialize("lane-drop", &UniformSampler, 11, 0)
            .unwrap();
        let expected = cfg.flows.total_expected_vehicles();
        // lane-drop demand floor is 800 vph over 120 s (~27 vehicles),
        // so even the lightest point overflows a [16]-only ladder
        assert!(expected > 10.0, "test premise: a non-trivial point");

        // a manifest that only lowered a too-small ladder must REFUSE
        // the point (a clamped bucket silently corrupts the run), not
        // quietly cap it
        let small = FamilyRegistry::builtin().with_buckets(&[16]);
        assert_eq!(small.buckets(), &[16]);
        let err = small
            .materialize("lane-drop", &UniformSampler, 11, 0)
            .unwrap_err()
            .to_string();
        assert!(err.contains("largest lowered bucket"), "{err}");

        // a ladder with headroom picks the matching bucket
        let wide = FamilyRegistry::builtin().with_buckets(&[1024, 16, 256, 64]);
        let (_, cfg2) = wide
            .materialize("lane-drop", &UniformSampler, 11, 0)
            .unwrap();
        assert_eq!(cfg2.capacity, cfg.capacity);

        // the default ladder mirrors aot.py BUCKETS
        assert_eq!(FamilyRegistry::builtin().buckets(), &DEFAULT_BUCKET_LADDER);
        // empty ladders are ignored, not adopted
        assert_eq!(
            FamilyRegistry::builtin().with_buckets(&[]).buckets(),
            &DEFAULT_BUCKET_LADDER
        );
    }
}
