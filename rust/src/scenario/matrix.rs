//! The scenario matrix: fanning sampled points across a campaign's
//! nodes × slots.
//!
//! A [`ScenarioMatrix`] turns "one experiment, many seeds" into "a
//! scenario space, many points": given only the campaign seed and a
//! global run index (epoch × instances-per-epoch + array index), any
//! node computes its own `(family, sample index, run seed)` assignment
//! — [`ScenarioMatrix::assignment`] is pure, so a PBS array needs no
//! coordination, exactly like the per-run `duarouter --seed $RANDOM` it
//! generalizes.
//!
//! Fan-out order is family-major round-robin: consecutive run indices
//! cycle through the families, then advance the sample index, so every
//! epoch of a campaign spreads evenly over the matrix.  Campaigns
//! longer than `families × samples_per_family` wrap around the same
//! points with fresh (still unique) per-run duarouter seeds — more
//! trajectories per point, the paper's §1.2 randomization axis on top
//! of the scenario axis.

use crate::Result;

use super::family::FamilyRegistry;
use super::sampler::SamplerKind;
use super::space::ScenarioPoint;
use super::ScenarioConfig;

/// Odd multiplier making `run_index → run_seed` injective.
const SEED_MIX: u64 = 0x9E37_79B9_7F4A_7C15;

/// A campaign-wide scenario sweep: which families, how they are
/// sampled, and how many points per family.
#[derive(Debug, Clone, PartialEq)]
pub struct ScenarioMatrix {
    /// Family ids, resolved through a [`FamilyRegistry`].
    pub families: Vec<String>,
    pub sampler: SamplerKind,
    pub samples_per_family: usize,
    /// Matrix seed: drives the samplers and derives per-run seeds.
    pub seed: u64,
}

/// One run's slice of the matrix.
#[derive(Debug, Clone, PartialEq)]
pub struct RunAssignment {
    pub family: String,
    /// Sample index into the family's space.
    pub sample_index: u64,
    /// Per-run duarouter seed — unique per run index even when the
    /// matrix wraps.
    pub run_seed: u64,
}

/// A fully materialized run: assignment + sampled point + compiled
/// config, ready to become an `InstanceConfig`.
#[derive(Debug, Clone, PartialEq)]
pub struct PlannedRun {
    pub assignment: RunAssignment,
    pub point: ScenarioPoint,
    pub config: ScenarioConfig,
}

impl ScenarioMatrix {
    pub fn new(
        families: Vec<String>,
        sampler: SamplerKind,
        samples_per_family: usize,
        seed: u64,
    ) -> Self {
        debug_assert!(!families.is_empty(), "scenario matrix needs >= 1 family");
        ScenarioMatrix {
            families,
            sampler,
            samples_per_family: samples_per_family.max(1),
            seed,
        }
    }

    /// Distinct (family, sample) cells in the matrix.
    pub fn total_points(&self) -> u64 {
        self.families.len() as u64 * self.samples_per_family.max(1) as u64
    }

    /// Pure: global run index → this run's matrix cell + seed.  Any
    /// node evaluates it locally from campaign constants.
    ///
    /// Panics on an empty `families` list (checked in [`Self::new`],
    /// but `families` is a public field).
    pub fn assignment(&self, run_index: u64) -> RunAssignment {
        assert!(
            !self.families.is_empty(),
            "scenario matrix has no families to assign from"
        );
        let nf = self.families.len() as u64;
        let family = self.families[(run_index % nf) as usize].clone();
        let sample_index = (run_index / nf) % self.samples_per_family.max(1) as u64;
        RunAssignment {
            family,
            sample_index,
            run_seed: self.seed ^ run_index.wrapping_mul(SEED_MIX),
        }
    }

    /// Assignment + sample + compile in one call — what a node runs to
    /// stand up its instance.  The suggested capacity comes from the
    /// registry's bucket ladder (the loaded manifest's buckets when the
    /// caller built the registry with `with_buckets`).
    pub fn materialize(&self, registry: &FamilyRegistry, run_index: u64) -> Result<PlannedRun> {
        let assignment = self.assignment(run_index);
        let family = registry.get(&assignment.family)?;
        let point = self
            .sampler
            .sample(&family.space(), self.seed, assignment.sample_index);
        let mut config = family.compile(&point)?;
        registry.rebucket(&mut config)?;
        Ok(PlannedRun {
            assignment,
            point,
            config,
        })
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn matrix() -> ScenarioMatrix {
        ScenarioMatrix::new(
            vec![
                "highway-merge".into(),
                "lane-drop".into(),
                "ring-shockwave".into(),
            ],
            SamplerKind::Lhs { strata: 4 },
            4,
            2021,
        )
    }

    #[test]
    fn round_robin_over_families() {
        let m = matrix();
        assert_eq!(m.total_points(), 12);
        assert_eq!(m.assignment(0).family, "highway-merge");
        assert_eq!(m.assignment(1).family, "lane-drop");
        assert_eq!(m.assignment(2).family, "ring-shockwave");
        assert_eq!(m.assignment(3).family, "highway-merge");
        assert_eq!(m.assignment(0).sample_index, 0);
        assert_eq!(m.assignment(3).sample_index, 1);
        // wraps back onto the first cell with a fresh seed
        let a0 = m.assignment(0);
        let a12 = m.assignment(12);
        assert_eq!(a12.family, a0.family);
        assert_eq!(a12.sample_index, a0.sample_index);
        assert_ne!(a12.run_seed, a0.run_seed);
    }

    #[test]
    fn run_seeds_are_unique() {
        let m = matrix();
        let mut seeds: Vec<u64> = (0..2304).map(|i| m.assignment(i).run_seed).collect();
        seeds.sort_unstable();
        seeds.dedup();
        assert_eq!(seeds.len(), 2304);
    }

    #[test]
    fn materialize_is_pure() {
        let m = matrix();
        let r = FamilyRegistry::builtin();
        let a = m.materialize(&r, 7).unwrap();
        let b = m.materialize(&r, 7).unwrap();
        assert_eq!(a, b);
        assert_eq!(a.point.index, a.assignment.sample_index);
        assert_eq!(a.config.tag.id.as_str(), a.assignment.family);
    }

    #[test]
    fn unknown_family_is_rejected() {
        let mut m = matrix();
        m.families = vec!["warp-drive".into()];
        assert!(m.materialize(&FamilyRegistry::builtin(), 0).is_err());
    }
}
