//! The `scenarios` manifest: a machine-readable (`util::Json`)
//! description of a campaign's scenario matrix — families, axes,
//! sampler, and every sampled point with its parameter vector.
//!
//! The manifest is the dataset's codebook: dropped next to the
//! aggregated output, it lets downstream ML consumers decode the
//! parameter columns of every row without the generating binary
//! (`CampaignDataset::to_ml_csv` writes the rows; this writes the
//! schema).  Round-trips through [`Json::parse`].

use crate::util::Json;
use crate::Result;

use super::family::FamilyRegistry;
use super::matrix::ScenarioMatrix;
use super::space::{Axis, AxisKind, AxisValue};

fn axis_value_json(v: &AxisValue) -> Json {
    match v {
        AxisValue::Num(n) => Json::num(*n),
        AxisValue::Int(i) => Json::num(*i as f64),
        AxisValue::Tag(t) => Json::str(t.clone()),
    }
}

fn axis_json(axis: &Axis) -> Json {
    match &axis.kind {
        AxisKind::Continuous { lo, hi } => Json::obj(vec![
            ("name", Json::str(axis.name.clone())),
            ("kind", Json::str("continuous")),
            ("lo", Json::num(*lo)),
            ("hi", Json::num(*hi)),
        ]),
        AxisKind::Integer { lo, hi } => Json::obj(vec![
            ("name", Json::str(axis.name.clone())),
            ("kind", Json::str("integer")),
            ("lo", Json::num(*lo as f64)),
            ("hi", Json::num(*hi as f64)),
        ]),
        AxisKind::Choice { options } => Json::obj(vec![
            ("name", Json::str(axis.name.clone())),
            ("kind", Json::str("choice")),
            (
                "options",
                Json::arr(options.iter().map(|o| Json::str(o.clone())).collect()),
            ),
        ]),
    }
}

/// Build the scenarios manifest for `matrix`, enumerating every
/// `(family, sample index)` cell with the exact parameter vector the
/// samplers reproduce on the nodes.
pub fn scenarios_manifest(registry: &FamilyRegistry, matrix: &ScenarioMatrix) -> Result<Json> {
    let mut families = Vec::new();
    for id in &matrix.families {
        let family = registry.get(id)?;
        let space = family.space();
        let axes: Vec<Json> = space.axes.iter().map(axis_json).collect();
        let mut points = Vec::new();
        for index in 0..matrix.samples_per_family as u64 {
            let point = matrix.sampler.sample(&space, matrix.seed, index);
            let params: Vec<(String, Json)> = space
                .axes
                .iter()
                .zip(point.values.iter())
                .map(|(a, v)| (a.name.clone(), axis_value_json(v)))
                .collect();
            points.push(Json::obj(vec![
                ("index", Json::num(index as f64)),
                ("params", Json::obj(params)),
            ]));
        }
        families.push(Json::obj(vec![
            ("id", Json::str(id.clone())),
            ("axes", Json::arr(axes)),
            ("points", Json::arr(points)),
        ]));
    }
    Ok(Json::obj(vec![
        ("version", Json::num(1.0)),
        ("seed", Json::num(matrix.seed as f64)),
        ("sampler", Json::str(matrix.sampler.name())),
        (
            "samples_per_family",
            Json::num(matrix.samples_per_family as f64),
        ),
        ("families", Json::arr(families)),
    ]))
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::scenario::sampler::SamplerKind;

    fn matrix() -> ScenarioMatrix {
        ScenarioMatrix::new(
            vec!["lane-drop".into(), "ring-shockwave".into()],
            SamplerKind::Lhs { strata: 3 },
            3,
            7,
        )
    }

    #[test]
    fn manifest_round_trips_and_describes_points() {
        let m = matrix();
        let j = scenarios_manifest(&FamilyRegistry::builtin(), &m).unwrap();
        let text = j.to_pretty_string();
        assert_eq!(Json::parse(&text).unwrap(), j);

        assert_eq!(j.get("sampler").unwrap().as_str().unwrap(), "latin-hypercube");
        let fams = j.get("families").unwrap().as_arr().unwrap();
        assert_eq!(fams.len(), 2);
        let points = fams[0].get("points").unwrap().as_arr().unwrap();
        assert_eq!(points.len(), 3);
        // manifest params match what the node-side sampler reproduces
        let registry = FamilyRegistry::builtin();
        let space = registry.get("lane-drop").unwrap().space();
        let p1 = m.sampler.sample(&space, m.seed, 1);
        let demand = points[1]
            .get("params")
            .unwrap()
            .get("demand_vph")
            .unwrap()
            .as_f64()
            .unwrap();
        assert_eq!(demand, p1.num(&space, "demand_vph").unwrap());
    }

    #[test]
    fn unknown_family_fails() {
        let mut m = matrix();
        m.families.push("warp".into());
        assert!(scenarios_manifest(&FamilyRegistry::builtin(), &m).is_err());
    }
}
