//! Parametric scenario spaces: named parameter axes with ranges or
//! choices, and the sampled points that index into them.
//!
//! A [`ScenarioSpace`] is the declarative description of *what can
//! vary* in a scenario family (demand, CAV penetration, geometry, lane
//! count, speed limit, driver-parameter perturbations).  A
//! [`ScenarioPoint`] is one concrete assignment of every axis, produced
//! by a seeded [`super::Sampler`]; `(space, seed, index) → point` is a
//! pure function, so any node of a PBS array materializes its own point
//! without coordination (the §3.1.5 principle applied to scenario
//! diversity instead of demand randomization).

use crate::{Error, Result};

/// Identifier of a scenario family.  Stable across runs — it lands in
/// `RunDataset` provenance and the scenarios manifest, so aggregated
/// rows stay attributable to their generating scenario.
#[derive(Debug, Clone, PartialEq, Eq, Hash, PartialOrd, Ord)]
pub struct ScenarioId(pub String);

impl ScenarioId {
    pub fn new(s: impl Into<String>) -> Self {
        ScenarioId(s.into())
    }

    pub fn as_str(&self) -> &str {
        &self.0
    }
}

impl std::fmt::Display for ScenarioId {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        f.write_str(&self.0)
    }
}

/// The shape of one parameter axis.
#[derive(Debug, Clone, PartialEq)]
pub enum AxisKind {
    /// Real-valued range `[lo, hi]` (both ends reachable by the grid
    /// sampler; random samplers draw from `[lo, hi)`).
    Continuous { lo: f64, hi: f64 },
    /// Integer range `lo..=hi`.
    Integer { lo: i64, hi: i64 },
    /// Categorical choice among named options.
    Choice { options: Vec<String> },
}

/// One named parameter axis of a scenario space.
#[derive(Debug, Clone, PartialEq)]
pub struct Axis {
    pub name: String,
    pub kind: AxisKind,
}

impl Axis {
    pub fn continuous(name: impl Into<String>, lo: f64, hi: f64) -> Axis {
        Axis {
            name: name.into(),
            kind: AxisKind::Continuous { lo, hi },
        }
    }

    pub fn integer(name: impl Into<String>, lo: i64, hi: i64) -> Axis {
        Axis {
            name: name.into(),
            kind: AxisKind::Integer { lo, hi },
        }
    }

    pub fn choice(name: impl Into<String>, options: &[&str]) -> Axis {
        Axis {
            name: name.into(),
            kind: AxisKind::Choice {
                options: options.iter().map(|s| s.to_string()).collect(),
            },
        }
    }

    /// Map a unit sample `u ∈ [0, 1)` onto this axis.
    pub fn value_at(&self, u: f64) -> AxisValue {
        match &self.kind {
            AxisKind::Continuous { lo, hi } => AxisValue::Num(lo + (hi - lo) * u),
            AxisKind::Integer { lo, hi } => {
                let count = (hi - lo + 1).max(1);
                let off = ((count as f64 * u) as i64).clamp(0, count - 1);
                AxisValue::Int(lo + off)
            }
            AxisKind::Choice { options } => {
                let k = ((options.len() as f64 * u) as usize).min(options.len() - 1);
                AxisValue::Tag(options[k].clone())
            }
        }
    }

    /// How many distinct grid positions this axis contributes when the
    /// grid sampler places `per_axis` points on continuous axes.
    pub fn grid_cardinality(&self, per_axis: usize) -> usize {
        let per_axis = per_axis.max(1);
        match &self.kind {
            AxisKind::Continuous { .. } => per_axis,
            AxisKind::Integer { lo, hi } => ((hi - lo + 1).max(1) as usize).min(per_axis),
            AxisKind::Choice { options } => options.len().max(1),
        }
    }

    /// The `k`-th of `m` grid positions on this axis (endpoints
    /// inclusive on continuous axes; `m == 1` takes the midpoint).
    pub fn grid_value(&self, k: usize, m: usize) -> AxisValue {
        let m = m.max(1);
        match &self.kind {
            AxisKind::Continuous { lo, hi } => {
                if m == 1 {
                    AxisValue::Num((lo + hi) / 2.0)
                } else {
                    AxisValue::Num(lo + (hi - lo) * k as f64 / (m - 1) as f64)
                }
            }
            AxisKind::Integer { lo, hi } => {
                let count = (hi - lo + 1).max(1);
                if m == 1 {
                    AxisValue::Int(lo + (count - 1) / 2)
                } else {
                    let off = ((k as f64 * (count - 1) as f64 / (m - 1) as f64).round() as i64)
                        .clamp(0, count - 1);
                    AxisValue::Int(lo + off)
                }
            }
            AxisKind::Choice { options } => AxisValue::Tag(options[k.min(options.len() - 1)].clone()),
        }
    }
}

/// One sampled axis value.
#[derive(Debug, Clone, PartialEq)]
pub enum AxisValue {
    Num(f64),
    Int(i64),
    Tag(String),
}

impl AxisValue {
    /// Numeric view (integers widen; tags have none).
    pub fn as_f64(&self) -> Result<f64> {
        match self {
            AxisValue::Num(v) => Ok(*v),
            AxisValue::Int(v) => Ok(*v as f64),
            AxisValue::Tag(t) => Err(Error::Config(format!(
                "axis value '{t}' is categorical, not numeric"
            ))),
        }
    }

    /// Compact cell rendering for CSV/manifest output.
    pub fn render(&self) -> String {
        match self {
            AxisValue::Num(v) => {
                let s = format!("{v:.6}");
                let s = s.trim_end_matches('0').trim_end_matches('.');
                if s.is_empty() || s == "-" {
                    "0".to_string()
                } else {
                    s.to_string()
                }
            }
            AxisValue::Int(v) => format!("{v}"),
            AxisValue::Tag(t) => t.clone(),
        }
    }
}

/// A scenario space: the parameter axes of one family.
#[derive(Debug, Clone, PartialEq)]
pub struct ScenarioSpace {
    pub family: ScenarioId,
    pub axes: Vec<Axis>,
}

impl ScenarioSpace {
    pub fn new(family: impl Into<String>, axes: Vec<Axis>) -> Self {
        ScenarioSpace {
            family: ScenarioId::new(family),
            axes,
        }
    }

    pub fn axis_index(&self, name: &str) -> Result<usize> {
        self.axes
            .iter()
            .position(|a| a.name == name)
            .ok_or_else(|| {
                Error::Config(format!(
                    "scenario space '{}' has no axis '{name}'",
                    self.family
                ))
            })
    }

    pub fn axis(&self, name: &str) -> Result<&Axis> {
        Ok(&self.axes[self.axis_index(name)?])
    }
}

/// One sampled point of a scenario space: a full assignment of every
/// axis, plus the `(seed, index)` coordinates that reproduce it.
#[derive(Debug, Clone, PartialEq)]
pub struct ScenarioPoint {
    pub family: ScenarioId,
    /// Sample index within the space (the point's coordinate).
    pub index: u64,
    /// Sampler seed the point was drawn with.
    pub seed: u64,
    /// One value per space axis, in axis order.
    pub values: Vec<AxisValue>,
}

impl ScenarioPoint {
    pub fn value(&self, space: &ScenarioSpace, name: &str) -> Result<&AxisValue> {
        let i = space.axis_index(name)?;
        self.values.get(i).ok_or_else(|| {
            Error::Config(format!(
                "scenario point for '{}' has {} values but axis '{name}' is #{i}",
                self.family,
                self.values.len()
            ))
        })
    }

    /// Numeric axis accessor (continuous or integer axes).
    pub fn num(&self, space: &ScenarioSpace, name: &str) -> Result<f64> {
        self.value(space, name)?.as_f64()
    }

    /// Integer axis accessor.
    pub fn int(&self, space: &ScenarioSpace, name: &str) -> Result<i64> {
        match self.value(space, name)? {
            AxisValue::Int(v) => Ok(*v),
            AxisValue::Num(v) => Ok(v.round() as i64),
            AxisValue::Tag(t) => Err(Error::Config(format!(
                "axis '{name}' holds tag '{t}', not an integer"
            ))),
        }
    }

    /// Categorical axis accessor.
    pub fn tag(&self, space: &ScenarioSpace, name: &str) -> Result<&str> {
        match self.value(space, name)? {
            AxisValue::Tag(t) => Ok(t),
            other => Err(Error::Config(format!(
                "axis '{name}' holds {other:?}, not a choice"
            ))),
        }
    }

    /// Dataset provenance for this point: `(axis name, value)` pairs in
    /// axis order — what `RunDataset` carries so every aggregated row
    /// knows its generating parameters.
    pub fn provenance(&self, space: &ScenarioSpace) -> ScenarioTag {
        ScenarioTag {
            id: self.family.clone(),
            sample_index: self.index,
            params: space
                .axes
                .iter()
                .zip(self.values.iter())
                .map(|(a, v)| (a.name.clone(), v.clone()))
                .collect(),
        }
    }
}

/// Run provenance: which scenario point generated a run.  Attached to
/// `output::RunDataset` so the emitted dataset is self-describing
/// (ML-ready rows carry the parameters that generated them).
#[derive(Debug, Clone, PartialEq)]
pub struct ScenarioTag {
    pub id: ScenarioId,
    pub sample_index: u64,
    /// `(axis name, sampled value)` — the generating parameter vector.
    pub params: Vec<(String, AxisValue)>,
}

impl ScenarioTag {
    pub fn param(&self, name: &str) -> Option<&AxisValue> {
        self.params
            .iter()
            .find(|(n, _)| n == name)
            .map(|(_, v)| v)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn space() -> ScenarioSpace {
        ScenarioSpace::new(
            "test",
            vec![
                Axis::continuous("demand", 600.0, 2400.0),
                Axis::integer("lanes", 1, 3),
                Axis::choice("profile", &["calm", "aggressive"]),
            ],
        )
    }

    #[test]
    fn value_at_respects_bounds() {
        let s = space();
        match s.axes[0].value_at(0.0) {
            AxisValue::Num(v) => assert_eq!(v, 600.0),
            other => panic!("{other:?}"),
        }
        match s.axes[0].value_at(0.999_999) {
            AxisValue::Num(v) => assert!(v < 2400.0),
            other => panic!("{other:?}"),
        }
        assert_eq!(s.axes[1].value_at(0.0), AxisValue::Int(1));
        assert_eq!(s.axes[1].value_at(0.999), AxisValue::Int(3));
        assert_eq!(s.axes[2].value_at(0.6), AxisValue::Tag("aggressive".into()));
    }

    #[test]
    fn grid_values_hit_endpoints() {
        let s = space();
        assert_eq!(s.axes[0].grid_value(0, 3), AxisValue::Num(600.0));
        assert_eq!(s.axes[0].grid_value(2, 3), AxisValue::Num(2400.0));
        assert_eq!(s.axes[0].grid_value(0, 1), AxisValue::Num(1500.0));
        assert_eq!(s.axes[1].grid_cardinality(5), 3);
        assert_eq!(s.axes[1].grid_value(0, 3), AxisValue::Int(1));
        assert_eq!(s.axes[1].grid_value(2, 3), AxisValue::Int(3));
        assert_eq!(s.axes[2].grid_cardinality(9), 2);
    }

    #[test]
    fn point_accessors() {
        let s = space();
        let p = ScenarioPoint {
            family: s.family.clone(),
            index: 4,
            seed: 9,
            values: vec![
                AxisValue::Num(1200.0),
                AxisValue::Int(2),
                AxisValue::Tag("calm".into()),
            ],
        };
        assert_eq!(p.num(&s, "demand").unwrap(), 1200.0);
        assert_eq!(p.int(&s, "lanes").unwrap(), 2);
        assert_eq!(p.tag(&s, "profile").unwrap(), "calm");
        assert!(p.num(&s, "profile").is_err());
        assert!(p.value(&s, "nope").is_err());
        let tag = p.provenance(&s);
        assert_eq!(tag.sample_index, 4);
        assert_eq!(tag.param("lanes"), Some(&AxisValue::Int(2)));
        assert_eq!(tag.param("absent"), None);
    }

    #[test]
    fn render_is_compact() {
        assert_eq!(AxisValue::Num(1200.0).render(), "1200");
        assert_eq!(AxisValue::Num(0.25).render(), "0.25");
        assert_eq!(AxisValue::Num(0.0).render(), "0");
        assert_eq!(AxisValue::Int(-3).render(), "-3");
        assert_eq!(AxisValue::Tag("calm".into()).render(), "calm");
    }
}
