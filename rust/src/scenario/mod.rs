//! Scenario generation: parametric scenario spaces, seeded samplers,
//! and campaign-wide sweeps.
//!
//! The paper's pipeline produces "aggregated output datasets from
//! thousands of simulation runs" (§1, §5) — but its thousand runs all
//! explore the *same* highway-merge world under different duarouter
//! seeds.  This subsystem adds the missing axis: scenario diversity.
//!
//! * [`space`] — [`ScenarioSpace`]: named parameter axes (demand, CAV
//!   penetration, geometry, lane count, speed limit, driver-parameter
//!   perturbations) with ranges/choices, and the sampled
//!   [`ScenarioPoint`]s that index into them,
//! * [`sampler`] — deterministic seeded samplers behind the [`Sampler`]
//!   trait (grid, uniform-random, Latin-hypercube); `(space, seed,
//!   index) → point` is a **pure function**, so every node of a PBS
//!   array materializes its own point with no coordination,
//! * [`family`] — the [`ScenarioFamily`] registry compiling points into
//!   the existing `(Network, Vec<FlowDef>, DriverParams)` config tuple;
//!   four built-ins: `highway-merge`, `lane-drop`, `ramp-weave`,
//!   `ring-shockwave`,
//! * [`matrix`] — [`ScenarioMatrix`]: fanning `families ×
//!   samples_per_family` points across a campaign's nodes × slots
//!   (`CampaignSpec::scenario_assignment`),
//! * [`manifest`] — the `scenarios` manifest (`util::Json`): the
//!   dataset's codebook, pairing `CampaignDataset::to_ml_csv`'s
//!   parameter columns with their generating axes.

pub mod family;
pub mod manifest;
pub mod matrix;
pub mod sampler;
pub mod space;

pub use family::{
    FamilyRegistry, HighwayMergeFamily, LaneDropFamily, RampWeaveFamily, RingShockwaveFamily,
    ScenarioConfig, ScenarioFamily, ScenarioRun, DEFAULT_BUCKET_LADDER,
};
pub use manifest::scenarios_manifest;
pub use matrix::{PlannedRun, RunAssignment, ScenarioMatrix};
pub use sampler::{GridSampler, LatinHypercubeSampler, Sampler, SamplerKind, UniformSampler};
pub use space::{Axis, AxisKind, AxisValue, ScenarioId, ScenarioPoint, ScenarioSpace, ScenarioTag};
