//! PBS job arrays (`#PBS -J first-last`).
//!
//! The pipeline's distribution mechanism: one submission fans out into
//! `last - first + 1` subjobs, each seeing its own `$PBS_ARRAY_INDEX`.
//! The paper's Appendix-B script uses `-J 1-48` and derives the world-copy
//! index as `PBS_ARRAY_INDEX % 8`.


use crate::{Error, Result};

use super::JobId;

/// Inclusive index range of an array job.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct ArrayRange {
    pub first: u32,
    pub last: u32,
}

impl ArrayRange {
    pub fn new(first: u32, last: u32) -> Result<Self> {
        if last < first {
            return Err(Error::Config(format!(
                "invalid array range {first}-{last}"
            )));
        }
        Ok(ArrayRange { first, last })
    }

    pub fn len(&self) -> u32 {
        self.last - self.first + 1
    }

    pub fn is_empty(&self) -> bool {
        false // by construction: last >= first
    }

    pub fn indices(&self) -> impl Iterator<Item = u32> {
        self.first..=self.last
    }

    /// Parse the `-J` argument (`"1-48"`).
    pub fn parse(s: &str) -> Result<Self> {
        let (a, b) = s
            .split_once('-')
            .ok_or_else(|| Error::Config(format!("malformed -J range '{s}'")))?;
        let first = a
            .trim()
            .parse::<u32>()
            .map_err(|e| Error::Config(format!("bad array index '{a}': {e}")))?;
        let last = b
            .trim()
            .parse::<u32>()
            .map_err(|e| Error::Config(format!("bad array index '{b}': {e}")))?;
        ArrayRange::new(first, last)
    }
}

impl std::fmt::Display for ArrayRange {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        write!(f, "{}-{}", self.first, self.last)
    }
}

/// Identifier of one element of an array job (`1234[7].pbs`-style).
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash, PartialOrd, Ord)]
pub struct SubJobId {
    pub job: JobId,
    /// `$PBS_ARRAY_INDEX`; 0 for non-array jobs.
    pub array_index: u32,
}

impl std::fmt::Display for SubJobId {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        write!(f, "{}[{}]", self.job.0, self.array_index)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn parse_appendix_b_range() {
        let r = ArrayRange::parse("1-48").unwrap();
        assert_eq!(r.len(), 48);
        assert_eq!(r.indices().count(), 48);
        assert_eq!(r.to_string(), "1-48");
    }

    #[test]
    fn parse_rejects_garbage() {
        assert!(ArrayRange::parse("48").is_err());
        assert!(ArrayRange::parse("8-1").is_err());
        assert!(ArrayRange::parse("a-b").is_err());
    }

    #[test]
    fn singleton_range() {
        let r = ArrayRange::parse("5-5").unwrap();
        assert_eq!(r.len(), 1);
        assert_eq!(r.indices().collect::<Vec<_>>(), vec![5]);
    }

    #[test]
    fn subjob_display() {
        let s = SubJobId {
            job: JobId(12),
            array_index: 7,
        };
        assert_eq!(s.to_string(), "12[7]");
    }
}
