//! The discrete-event batch scheduler.
//!
//! Implements the slice of PBS behaviour the paper's evaluation measures:
//! FIFO dispatch with first-fit (or round-robin — an ablation, DESIGN.md
//! §7) node packing, per-chunk resource booking against the [`Cluster`],
//! walltime enforcement, and a completion timeline from which the ch. 5
//! throughput/distribution results are computed.
//!
//! Time is virtual ([`SimClock`]): `run_until` replays hours of campaign
//! in microseconds, deterministically (stable event ordering).

use std::collections::{HashMap, VecDeque};

use crate::cluster::{AllocationId, Cluster, ClusterQueue, NodeSpec, ResourceDemand};
use crate::metrics::{ResourceUsage, WorkloadModel};
use crate::simclock::{EventQueue, SimClock, SimDuration, SimInstant};
use crate::{Error, Result};

use super::{Job, JobId, JobState, SubJobId};

/// Node-packing policy (ablation: DESIGN.md §7).
#[derive(Debug, Clone, Copy, PartialEq, Eq, Default)]
pub enum PackingPolicy {
    /// Scan nodes in index order, place on the first that fits (what PBS
    /// effectively does for a saturating array of identical chunks).
    #[default]
    FirstFit,
    /// Rotate a cursor across nodes, spreading load breadth-first.
    RoundRobin,
}

/// Static scheduler configuration.
#[derive(Debug, Clone, Default)]
pub struct SchedulerConfig {
    pub policy: PackingPolicy,
    /// When true, a blocked head-of-queue subjob does not stall later
    /// subjobs that do fit (simple backfill). PBS does this; strict FIFO
    /// is kept for the ablation bench.
    pub backfill: bool,
}

/// Internal: a subjob waiting for resources.
#[derive(Debug)]
struct Pending {
    sub: SubJobId,
    demand: ResourceDemand,
    interconnect: Option<crate::cluster::Interconnect>,
    walltime: SimDuration,
}

/// Internal: a subjob occupying a node.
#[derive(Debug)]
struct Running {
    node: usize,
    alloc: AllocationId,
    started: SimInstant,
    usage: ResourceUsage,
    /// Virtual instant the job *would* finish if not killed.
    finish_at: SimInstant,
    kill_at: SimInstant,
}

/// One entry of the completion timeline (drives Table 5.1 / Fig 5.1).
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct Completion {
    pub sub: SubJobId,
    pub node: usize,
    pub at: SimInstant,
    pub state: JobState,
}

#[derive(Debug)]
enum SchedEvent {
    Finish(SubJobId),
    WalltimeKill(SubJobId),
}

/// Aggregate counters.
#[derive(Debug, Clone, Copy, Default, PartialEq)]
pub struct SchedulerStats {
    pub submitted: u64,
    pub completed: u64,
    pub killed_walltime: u64,
    pub failed: u64,
}

impl SchedulerStats {
    /// The paper's headline reliability claim: "100% simulation completion
    /// rate after 12 hours of runs".
    pub fn completion_rate(&self) -> f64 {
        if self.submitted == 0 {
            return 1.0;
        }
        self.completed as f64 / self.submitted as f64
    }
}

/// The scheduler itself. Owns the cluster, the clock, and a per-job
/// workload model that tells it how long each subjob runs and what it
/// consumes (the launcher/cost-model plugs in here).
pub struct Scheduler {
    clock: SimClock,
    cluster: Cluster,
    queue: ClusterQueue,
    config: SchedulerConfig,
    pending: VecDeque<Pending>,
    running: HashMap<SubJobId, Running>,
    workloads: HashMap<JobId, Box<dyn WorkloadModel>>,
    jobs: HashMap<JobId, Job>,
    states: HashMap<SubJobId, JobState>,
    events: EventQueue<SchedEvent>,
    completions: Vec<Completion>,
    records: Vec<super::JobRecord>,
    stats: SchedulerStats,
    next_job_id: u64,
    rr_cursor: usize,
}

impl Scheduler {
    pub fn new(cluster: Cluster, queue: ClusterQueue, config: SchedulerConfig) -> Self {
        Scheduler {
            clock: SimClock::new(),
            cluster,
            queue,
            config,
            pending: VecDeque::new(),
            running: HashMap::new(),
            workloads: HashMap::new(),
            jobs: HashMap::new(),
            states: HashMap::new(),
            events: EventQueue::new(),
            completions: Vec::new(),
            records: Vec::new(),
            stats: SchedulerStats::default(),
            next_job_id: 1,
            rr_cursor: 0,
        }
    }

    pub fn now(&self) -> SimInstant {
        self.clock.now()
    }

    pub fn cluster(&self) -> &Cluster {
        &self.cluster
    }

    pub fn stats(&self) -> SchedulerStats {
        self.stats
    }

    pub fn completions(&self) -> &[Completion] {
        &self.completions
    }

    pub fn records(&self) -> &[super::JobRecord] {
        &self.records
    }

    pub fn state_of(&self, sub: SubJobId) -> Option<JobState> {
        self.states.get(&sub).copied()
    }

    /// Submit a job with its workload model. Returns the assigned id.
    pub fn submit(&mut self, mut job: Job, workload: Box<dyn WorkloadModel>) -> Result<JobId> {
        self.queue
            .admit(job.request.walltime.as_millis() / 1000, job.request.select as usize)?;
        let id = JobId(self.next_job_id);
        self.next_job_id += 1;
        job.id = id;

        let indices: Vec<u32> = match job.array {
            Some(a) => a.indices().collect(),
            None => vec![0],
        };
        for ai in indices {
            let sub = SubJobId {
                job: id,
                array_index: ai,
            };
            self.pending.push_back(Pending {
                sub,
                demand: job.request.chunk,
                interconnect: job.request.interconnect,
                walltime: job.request.walltime,
            });
            self.states.insert(sub, JobState::Queued);
            self.stats.submitted += 1;
        }
        self.workloads.insert(id, workload);
        self.jobs.insert(id, job);
        self.dispatch();
        Ok(id)
    }

    /// Try to start pending subjobs. FIFO order; with backfill enabled a
    /// blocked head does not stall the rest.
    fn dispatch(&mut self) {
        let mut i = 0;
        while i < self.pending.len() {
            let p = &self.pending[i];
            let cands = self.cluster.candidates(&p.demand, p.interconnect);
            if cands.is_empty() {
                if self.config.backfill {
                    i += 1;
                    continue;
                } else {
                    break;
                }
            }
            let node = match self.config.policy {
                PackingPolicy::FirstFit => cands[0],
                PackingPolicy::RoundRobin => {
                    // first candidate at or after the cursor, cyclically
                    let pick = cands
                        .iter()
                        .copied()
                        .find(|&c| c >= self.rr_cursor)
                        .unwrap_or(cands[0]);
                    self.rr_cursor = (pick + 1) % self.cluster.len();
                    pick
                }
            };
            let Some(p) = self.pending.remove(i) else {
                break; // unreachable: `i < len` is the loop guard
            };
            self.start(p, node);
            // restart the scan: resources changed
            i = 0;
        }
    }

    /// Start `p` on `node` (a candidate that fits).  The impossible
    /// paths — candidate refuses the allocation, workload vanished —
    /// settle the subjob as Failed instead of panicking: a scheduler
    /// that aborts mid-simulation loses the whole virtual campaign.
    fn start(&mut self, p: Pending, node: usize) {
        let Ok(alloc) = self.cluster.allocate_on(node, p.demand) else {
            self.states.insert(p.sub, JobState::Failed);
            self.stats.failed += 1;
            return;
        };
        let node_spec: NodeSpec = self.cluster.node(node).spec.clone();
        let Some(workload) = self.workloads.get_mut(&p.sub.job) else {
            let _ = self.cluster.release_on(node, alloc);
            self.states.insert(p.sub, JobState::Failed);
            self.stats.failed += 1;
            return;
        };
        let usage = workload.usage(p.sub, &node_spec, &p.demand);
        let now = self.clock.now();
        let finish_at = now + usage.walltime;
        let kill_at = now + p.walltime;
        self.events.push(
            finish_at.min(kill_at),
            if finish_at <= kill_at {
                SchedEvent::Finish(p.sub)
            } else {
                SchedEvent::WalltimeKill(p.sub)
            },
        );
        self.states.insert(p.sub, JobState::Running);
        self.running.insert(
            p.sub,
            Running {
                node,
                alloc,
                started: now,
                usage,
                finish_at,
                kill_at,
            },
        );
    }

    /// Advance virtual time to `until`, processing every event on the way.
    pub fn run_until(&mut self, until: SimInstant) {
        while let Some(t) = self.events.peek_time() {
            if t > until {
                break;
            }
            let Some(ev) = self.events.pop() else {
                break; // unreachable: peek_time just saw an event
            };
            self.clock.advance_to(ev.at);
            match ev.payload {
                SchedEvent::Finish(sub) => self.finish(sub, JobState::Completed),
                SchedEvent::WalltimeKill(sub) => self.finish(sub, JobState::KilledWalltime),
            }
            self.dispatch();
        }
        self.clock.advance_to(until);
    }

    /// Run until every submitted subjob reached a terminal state.
    pub fn run_to_completion(&mut self) {
        while let Some(t) = self.events.peek_time() {
            let Some(ev) = self.events.pop() else {
                break; // unreachable: peek_time just saw an event
            };
            self.clock.advance_to(t);
            match ev.payload {
                SchedEvent::Finish(sub) => self.finish(sub, JobState::Completed),
                SchedEvent::WalltimeKill(sub) => self.finish(sub, JobState::KilledWalltime),
            }
            self.dispatch();
        }
    }

    fn finish(&mut self, sub: SubJobId, state: JobState) {
        let r = match self.running.remove(&sub) {
            Some(r) => r,
            None => return, // stale event (already finished)
        };
        // a release can only fail for an untracked allocation; leaking
        // the (virtual) resources beats aborting the simulation
        let _ = self.cluster.release_on(r.node, r.alloc);
        self.states.insert(sub, state);
        match state {
            JobState::Completed => self.stats.completed += 1,
            JobState::KilledWalltime => self.stats.killed_walltime += 1,
            JobState::Failed => self.stats.failed += 1,
            _ => {}
        }
        let now = self.clock.now();
        self.completions.push(Completion {
            sub,
            node: r.node,
            at: now,
            state,
        });
        self.records.push(super::JobRecord {
            sub,
            node: r.node,
            state,
            queued_at: SimInstant::ZERO, // refined below if needed
            started_at: r.started,
            finished_at: now,
            usage: ResourceUsage {
                // a killed job burned the full walltime window
                walltime: now - r.started,
                ..r.usage
            },
        });
        let _ = (r.finish_at, r.kill_at);
    }

    /// Cumulative completed-run counts at each sampled timestamp — the
    /// exact quantity of Table 5.1.
    pub fn completed_at(&self, t: SimInstant) -> u64 {
        self.completions
            .iter()
            .filter(|c| c.at <= t && c.state == JobState::Completed)
            .count() as u64
    }

    /// Per-node running-instance counts right now (§5.2).
    pub fn occupancy(&self) -> Vec<usize> {
        self.cluster.occupancy()
    }

    /// qstat-style snapshot.
    pub fn qstat(&self) -> super::QstatReport {
        super::QstatReport::from_states(self.clock.now(), &self.states)
    }

    /// Error if a job id was never submitted.
    pub fn job(&self, id: JobId) -> Result<&Job> {
        self.jobs.get(&id).ok_or_else(|| Error::NoSuchJob(id.to_string()))
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::cluster::QueueSpec;
    use crate::metrics::FixedWorkload;
    use crate::pbs::{ArrayRange, ResourceRequest};

    fn six_node_sched(config: SchedulerConfig) -> Scheduler {
        let cluster = Cluster::uniform("t", 6, NodeSpec::dice_r740());
        let queue = ClusterQueue::new(QueueSpec::dicelab(6));
        Scheduler::new(cluster, queue, config)
    }

    fn array_job(n: u32, req: ResourceRequest) -> Job {
        Job::new(JobId(0), "webots", req).with_array(ArrayRange::new(1, n).unwrap())
    }

    #[test]
    fn forty_eight_instances_pack_eight_per_node() {
        // the paper's exact configuration: 48 instances, 6 nodes, 8 slots
        let mut s = six_node_sched(SchedulerConfig::default());
        s.submit(
            array_job(48, ResourceRequest::experiment_15min()),
            Box::new(FixedWorkload::minutes(10)),
        )
        .unwrap();
        assert_eq!(s.occupancy(), vec![8, 8, 8, 8, 8, 8]);
    }

    #[test]
    fn all_complete_within_walltime() {
        let mut s = six_node_sched(SchedulerConfig::default());
        s.submit(
            array_job(48, ResourceRequest::experiment_15min()),
            Box::new(FixedWorkload::minutes(10)),
        )
        .unwrap();
        s.run_to_completion();
        let st = s.stats();
        assert_eq!(st.completed, 48);
        assert_eq!(st.completion_rate(), 1.0);
        assert_eq!(s.occupancy(), vec![0; 6]);
    }

    #[test]
    fn walltime_kill_fires() {
        let mut s = six_node_sched(SchedulerConfig::default());
        s.submit(
            array_job(4, ResourceRequest::experiment_15min()),
            Box::new(FixedWorkload::minutes(20)), // > 15-minute walltime
        )
        .unwrap();
        s.run_to_completion();
        let st = s.stats();
        assert_eq!(st.killed_walltime, 4);
        assert_eq!(st.completed, 0);
        // killed jobs still release their nodes
        assert_eq!(s.cluster().total_free_cores(), 6 * 40);
    }

    #[test]
    fn excess_instances_queue_then_run() {
        // 96 instances on 48 slots: second wave starts when first finishes
        let mut s = six_node_sched(SchedulerConfig::default());
        s.submit(
            array_job(96, ResourceRequest::experiment_15min()),
            Box::new(FixedWorkload::minutes(10)),
        )
        .unwrap();
        assert_eq!(s.occupancy().iter().sum::<usize>(), 48);
        s.run_until(SimInstant::ZERO + SimDuration::from_minutes(10));
        // first wave done, second wave started
        assert_eq!(s.stats().completed, 48);
        assert_eq!(s.occupancy().iter().sum::<usize>(), 48);
        s.run_to_completion();
        assert_eq!(s.stats().completed, 96);
    }

    #[test]
    fn round_robin_spreads_breadth_first() {
        let mut s = six_node_sched(SchedulerConfig {
            policy: PackingPolicy::RoundRobin,
            backfill: false,
        });
        s.submit(
            array_job(6, ResourceRequest::experiment_15min()),
            Box::new(FixedWorkload::minutes(10)),
        )
        .unwrap();
        assert_eq!(s.occupancy(), vec![1, 1, 1, 1, 1, 1]);
    }

    #[test]
    fn first_fit_packs_depth_first() {
        let mut s = six_node_sched(SchedulerConfig::default());
        s.submit(
            array_job(6, ResourceRequest::experiment_15min()),
            Box::new(FixedWorkload::minutes(10)),
        )
        .unwrap();
        assert_eq!(s.occupancy(), vec![6, 0, 0, 0, 0, 0]);
    }

    #[test]
    fn backfill_lets_small_jobs_jump_blocked_head() {
        let cluster = Cluster::uniform("t", 1, NodeSpec::dice_r740());
        let queue = ClusterQueue::new(QueueSpec::dicelab(1));
        let mut s = Scheduler::new(
            cluster,
            queue,
            SchedulerConfig {
                policy: PackingPolicy::FirstFit,
                backfill: true,
            },
        );
        // whole-node job occupies the node...
        s.submit(
            Job::new(JobId(0), "big", ResourceRequest::whole_node_15min()),
            Box::new(FixedWorkload::minutes(10)),
        )
        .unwrap();
        // ...a second whole-node job blocks at the head...
        s.submit(
            Job::new(JobId(0), "big2", ResourceRequest::whole_node_15min()),
            Box::new(FixedWorkload::minutes(10)),
        )
        .unwrap();
        // ...but nothing fits alongside, so occupancy is 1 either way; now
        // when the first finishes, the queue drains in order.
        s.run_to_completion();
        assert_eq!(s.stats().completed, 2);
    }

    #[test]
    fn timeline_counts_match_table_5_1_shape() {
        // 15-min walltime epochs of 48 → completed(t) == 48 * floor(t/15m)
        // when the per-run time equals the walltime budget's epoch.
        let mut s = six_node_sched(SchedulerConfig::default());
        for _ in 0..4 {
            s.submit(
                array_job(48, ResourceRequest::experiment_15min()),
                Box::new(FixedWorkload::minutes(15)),
            )
            .unwrap();
        }
        s.run_to_completion();
        for (minutes, want) in [(15u64, 48u64), (30, 96), (45, 144), (60, 192)] {
            let t = SimInstant::ZERO + SimDuration::from_minutes(minutes);
            assert_eq!(s.completed_at(t), want, "at {minutes} min");
        }
    }

    #[test]
    fn queue_cap_rejects_oversized_walltime() {
        let mut s = six_node_sched(SchedulerConfig::default());
        let mut req = ResourceRequest::experiment_15min();
        req.walltime = SimDuration::from_hours(100);
        assert!(s
            .submit(array_job(1, req), Box::new(FixedWorkload::minutes(1)))
            .is_err());
    }
}
