//! Job specifications and lifecycle states.


use crate::cluster::{Interconnect, ResourceDemand};
use crate::simclock::SimDuration;

use super::ArrayRange;

/// PBS job identifier (`1234.pbs02`-style, simplified to a counter).
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash, PartialOrd, Ord)]
pub struct JobId(pub u64);

impl std::fmt::Display for JobId {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        write!(f, "{}.pbs", self.0)
    }
}

/// The `-l select=...,walltime=...` line.
#[derive(Debug, Clone, PartialEq)]
pub struct ResourceRequest {
    /// Number of chunks (`select=1` in the paper's script — each array
    /// element asks for one chunk).
    pub select: u32,
    /// Per-chunk demand.
    pub chunk: ResourceDemand,
    /// Required interconnect class, if any.
    pub interconnect: Option<Interconnect>,
    /// Walltime limit per (sub)job.
    pub walltime: SimDuration,
}

impl ResourceRequest {
    /// The Appendix-B request: `select=1:ncpus=5:mem=93gb:interconnect=hdr,
    /// walltime=00:45:00`.
    pub fn appendix_b() -> Self {
        ResourceRequest {
            select: 1,
            chunk: ResourceDemand::paper_slot(),
            interconnect: Some(Interconnect::Hdr),
            walltime: SimDuration::from_minutes(45),
        }
    }

    /// The ch.5 experiment variant: 15-minute walltime per job ("the
    /// pipeline implemented a 15-minute walltime for each triggered job",
    /// §5.2).
    pub fn experiment_15min() -> Self {
        ResourceRequest {
            walltime: SimDuration::from_minutes(15),
            ..Self::appendix_b()
        }
    }

    /// Whole-node request used by the 6x1 serial setup of §5.3.
    pub fn whole_node_15min() -> Self {
        ResourceRequest {
            select: 1,
            chunk: ResourceDemand::whole_node(),
            interconnect: Some(Interconnect::Hdr),
            walltime: SimDuration::from_minutes(15),
        }
    }
}

/// Lifecycle of a (sub)job, mirroring qstat's Q/R/E/F states.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum JobState {
    /// Waiting in the queue.
    Queued,
    /// Executing on a node.
    Running,
    /// Finished within walltime.
    Completed,
    /// Killed by PBS for exceeding walltime.
    KilledWalltime,
    /// Failed for another reason (e.g. the §4.2.1 duplicate-port crash).
    Failed,
}

impl JobState {
    pub fn is_terminal(self) -> bool {
        matches!(
            self,
            JobState::Completed | JobState::KilledWalltime | JobState::Failed
        )
    }

    /// One-letter qstat code.
    pub fn code(self) -> char {
        match self {
            JobState::Queued => 'Q',
            JobState::Running => 'R',
            JobState::Completed => 'F',
            JobState::KilledWalltime => 'K',
            JobState::Failed => 'E',
        }
    }
}

/// A submitted job: either a single job or an array parent.
#[derive(Debug, Clone)]
pub struct Job {
    pub id: JobId,
    pub name: String,
    pub queue: String,
    pub request: ResourceRequest,
    /// `Some` for `#PBS -J first-last` array jobs.
    pub array: Option<ArrayRange>,
}

impl Job {
    pub fn new(id: JobId, name: impl Into<String>, request: ResourceRequest) -> Self {
        Job {
            id,
            name: name.into(),
            queue: "dicelab".into(),
            request,
            array: None,
        }
    }

    pub fn with_array(mut self, range: ArrayRange) -> Self {
        self.array = Some(range);
        self
    }

    /// Number of schedulable units this job expands to.
    pub fn num_subjobs(&self) -> u32 {
        self.array.map_or(1, |a| a.len())
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn appendix_b_request_matches_paper() {
        let r = ResourceRequest::appendix_b();
        assert_eq!(r.chunk.ncpus, 5);
        assert_eq!(r.chunk.mem_gb, 93.0);
        assert_eq!(r.walltime.as_minutes(), 45);
        assert_eq!(r.interconnect, Some(Interconnect::Hdr));
    }

    #[test]
    fn array_job_expands() {
        let j = Job::new(JobId(1), "webots", ResourceRequest::experiment_15min())
            .with_array(ArrayRange::new(1, 48).unwrap());
        assert_eq!(j.num_subjobs(), 48);
        let plain = Job::new(JobId(2), "webots", ResourceRequest::experiment_15min());
        assert_eq!(plain.num_subjobs(), 1);
    }

    #[test]
    fn state_terminality() {
        assert!(!JobState::Queued.is_terminal());
        assert!(!JobState::Running.is_terminal());
        assert!(JobState::Completed.is_terminal());
        assert!(JobState::KilledWalltime.is_terminal());
        assert!(JobState::Failed.is_terminal());
    }
}
