//! `#PBS` job-script parsing.
//!
//! The user-facing artifact of the whole pipeline is a shell script with
//! `#PBS` directives (Appendix B).  This parser understands the subset the
//! pipeline uses — `-N`, `-l select=...:...,walltime=HH:MM:SS`, `-J`,
//! `-q` — plus the body commands, and turns it into a [`Job`] spec.

use crate::cluster::{Interconnect, ResourceDemand};
use crate::simclock::SimDuration;
use crate::{Error, Result};

use super::{ArrayRange, Job, JobId, ResourceRequest};

/// Parsed form of a PBS job script.
#[derive(Debug, Clone, PartialEq)]
pub struct PbsScript {
    pub name: String,
    pub queue: String,
    pub request: ResourceRequest,
    pub array: Option<ArrayRange>,
    /// Non-directive body lines (the singularity/xvfb commands).
    pub body: Vec<String>,
}

impl PbsScript {
    /// Parse script text. Unknown directives are rejected loudly — silent
    /// misconfiguration is how walltime kills eat a 12-hour campaign.
    pub fn parse(text: &str) -> Result<Self> {
        let mut name = "STDIN".to_string();
        let mut queue = "default".to_string();
        let mut request: Option<ResourceRequest> = None;
        let mut array = None;
        let mut body = Vec::new();

        for raw in text.lines() {
            let line = raw.trim();
            if line.is_empty() || line == "#!/bin/bash" || line == "#!/bin/sh" {
                continue;
            }
            if let Some(directive) = line.strip_prefix("#PBS") {
                let directive = directive.trim();
                let (flag, rest) = directive
                    .split_once(|c: char| c.is_whitespace())
                    .map(|(f, r)| (f, r.trim()))
                    .unwrap_or((directive, ""));
                match flag {
                    "-N" => name = rest.to_string(),
                    "-q" => queue = rest.to_string(),
                    "-J" => array = Some(ArrayRange::parse(rest)?),
                    "-l" => request = Some(parse_resource_list(rest)?),
                    other => {
                        return Err(Error::Config(format!(
                            "unsupported #PBS directive '{other}'"
                        )))
                    }
                }
            } else if !line.starts_with('#') {
                body.push(line.to_string());
            }
        }

        let request = request
            .ok_or_else(|| Error::Config("script missing '#PBS -l' resource line".into()))?;
        Ok(PbsScript {
            name,
            queue,
            request,
            array,
            body,
        })
    }

    /// Turn the parsed script into a submittable [`Job`].
    pub fn to_job(&self, id: JobId) -> Job {
        let mut j = Job::new(id, self.name.clone(), self.request.clone());
        j.queue = self.queue.clone();
        if let Some(a) = self.array {
            j = j.with_array(a);
        }
        j
    }

    /// Render back to script text (used by the pipeline's script
    /// generator; `parse(render(s)) == s` up to comments).
    pub fn render(&self) -> String {
        let mut out = String::from("#!/bin/bash\n");
        out.push_str(&format!("#PBS -N {}\n", self.name));
        let chunk = &self.request.chunk;
        let mut l = format!(
            "#PBS -l select={}:ncpus={}:mem={}gb",
            self.request.select, chunk.ncpus, chunk.mem_gb as u64
        );
        if let Some(ic) = self.request.interconnect {
            l.push_str(&format!(":interconnect={}", ic.as_str()));
        }
        let secs = self.request.walltime.as_millis() / 1000;
        l.push_str(&format!(
            ",walltime={:02}:{:02}:{:02}\n",
            secs / 3600,
            (secs / 60) % 60,
            secs % 60
        ));
        out.push_str(&l);
        if let Some(a) = self.array {
            out.push_str(&format!("#PBS -J {a}\n"));
        }
        out.push_str(&format!("#PBS -q {}\n", self.queue));
        for b in &self.body {
            out.push_str(b);
            out.push('\n');
        }
        out
    }
}

/// Parse `select=1:ncpus=5:mem=93gb:interconnect=hdr,walltime=00:45:00`.
fn parse_resource_list(s: &str) -> Result<ResourceRequest> {
    let mut select = 1u32;
    let mut ncpus = 1u32;
    let mut mem_gb = 1.0f64;
    let mut interconnect = None;
    let mut walltime = None;

    for part in s.split(',') {
        let part = part.trim();
        if let Some(w) = part.strip_prefix("walltime=") {
            walltime = Some(parse_walltime(w)?);
            continue;
        }
        // a select chain: select=1:ncpus=5:mem=93gb:interconnect=hdr
        for term in part.split(':') {
            let (k, v) = term
                .split_once('=')
                .ok_or_else(|| Error::Config(format!("malformed -l term '{term}'")))?;
            match k.trim() {
                "select" => {
                    select = v
                        .parse()
                        .map_err(|e| Error::Config(format!("bad select '{v}': {e}")))?
                }
                "ncpus" => {
                    ncpus = v
                        .parse()
                        .map_err(|e| Error::Config(format!("bad ncpus '{v}': {e}")))?
                }
                "mem" => mem_gb = parse_mem_gb(v)?,
                "interconnect" => interconnect = Some(Interconnect::parse(v)?),
                other => {
                    return Err(Error::Config(format!("unsupported -l key '{other}'")));
                }
            }
        }
    }

    let walltime =
        walltime.ok_or_else(|| Error::Config("resource list missing walltime".into()))?;
    Ok(ResourceRequest {
        select,
        chunk: ResourceDemand {
            ncpus,
            mem_gb,
            scratch_gb: 0.0,
            ngpus: 0,
        },
        interconnect,
        walltime,
    })
}

/// `93gb`, `512mb`.
fn parse_mem_gb(v: &str) -> Result<f64> {
    let v = v.to_ascii_lowercase();
    if let Some(n) = v.strip_suffix("gb") {
        n.parse::<f64>()
            .map_err(|e| Error::Config(format!("bad mem '{v}': {e}")))
    } else if let Some(n) = v.strip_suffix("mb") {
        Ok(n.parse::<f64>()
            .map_err(|e| Error::Config(format!("bad mem '{v}': {e}")))?
            / 1024.0)
    } else {
        Err(Error::Config(format!("mem '{v}' needs gb/mb suffix")))
    }
}

/// `HH:MM:SS`.
fn parse_walltime(v: &str) -> Result<SimDuration> {
    let parts: Vec<&str> = v.split(':').collect();
    if parts.len() != 3 {
        return Err(Error::Config(format!("walltime '{v}' not HH:MM:SS")));
    }
    let nums: Vec<u64> = parts
        .iter()
        .map(|p| {
            p.parse::<u64>()
                .map_err(|e| Error::Config(format!("walltime '{v}': {e}")))
        })
        .collect::<Result<_>>()?;
    Ok(SimDuration::from_secs(
        nums[0] * 3600 + nums[1] * 60 + nums[2],
    ))
}

/// The paper's Appendix-B script, reproduced as the canonical test input
/// and the template the pipeline's generator specializes.
pub fn appendix_b_script() -> String {
    r#"#!/bin/bash
#PBS -N webots
#PBS -l select=1:ncpus=5:mem=93gb:interconnect=hdr,walltime=00:45:00
#PBS -J 1-48
#PBS -q dicelab
echo Generating new random routes...
singularity exec -B $TMPDIR:$TMPDIR webots_sumo.sif duarouter --route-files SIM_$(($PBS_ARRAY_INDEX % 8))_net/sumo.flow.xml --net-file SIM_$(($PBS_ARRAY_INDEX % 8))_net/sumo.net.xml --output-file SIM_$(($PBS_ARRAY_INDEX % 8))_net/sumo.rou.xml --randomize-flows true --seed $RANDOM
echo Starting Webots on `hostname`
singularity exec -B $TMPDIR:$TMPDIR webots_sumo.sif xvfb-run -a webots --stdout --stderr --batch --mode=realtime SIM_$(($PBS_ARRAY_INDEX % 8)).wbt
"#
    .to_string()
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn parses_appendix_b() {
        let s = PbsScript::parse(&appendix_b_script()).unwrap();
        assert_eq!(s.name, "webots");
        assert_eq!(s.queue, "dicelab");
        assert_eq!(s.request.chunk.ncpus, 5);
        assert_eq!(s.request.chunk.mem_gb, 93.0);
        assert_eq!(s.request.interconnect, Some(Interconnect::Hdr));
        assert_eq!(s.request.walltime.as_minutes(), 45);
        assert_eq!(s.array.unwrap().len(), 48);
        assert_eq!(s.body.len(), 4); // 2 echos + 2 singularity execs
    }

    #[test]
    fn render_parse_roundtrip() {
        let s = PbsScript::parse(&appendix_b_script()).unwrap();
        let s2 = PbsScript::parse(&s.render()).unwrap();
        assert_eq!(s, s2);
    }

    #[test]
    fn missing_resource_line_rejected() {
        let err = PbsScript::parse("#!/bin/bash\n#PBS -N x\necho hi\n").unwrap_err();
        assert!(err.to_string().contains("-l"));
    }

    #[test]
    fn rejects_unknown_directive() {
        assert!(PbsScript::parse("#PBS -Z whatever\n").is_err());
    }

    #[test]
    fn walltime_formats() {
        assert_eq!(parse_walltime("00:15:00").unwrap().as_minutes(), 15);
        assert_eq!(parse_walltime("12:00:00").unwrap().as_minutes(), 720);
        assert!(parse_walltime("15:00").is_err());
        assert!(parse_walltime("aa:bb:cc").is_err());
    }

    #[test]
    fn mem_suffixes() {
        assert_eq!(parse_mem_gb("93gb").unwrap(), 93.0);
        assert_eq!(parse_mem_gb("512mb").unwrap(), 0.5);
        assert!(parse_mem_gb("93").is_err());
    }

    #[test]
    fn to_job_carries_array() {
        let s = PbsScript::parse(&appendix_b_script()).unwrap();
        let j = s.to_job(JobId(9));
        assert_eq!(j.num_subjobs(), 48);
        assert_eq!(j.queue, "dicelab");
    }
}
