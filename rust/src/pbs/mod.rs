//! The Portable Batch System substrate.
//!
//! PBS is the job scheduler the paper leans on for distribution ("the PBS
//! algorithms are likely much more effective than any homegrown algorithm
//! we could have developed", §4.2.2).  This module implements the slice
//! of PBS the pipeline exercises:
//!
//! * [`script`] — parsing `#PBS` directives out of a job script
//!   (Appendix B is the canonical input),
//! * [`job`] — job specs, resource requests (`-l select=...`), states,
//! * [`array`] — job arrays (`-J 1-48`) and `$PBS_ARRAY_INDEX` expansion,
//! * [`scheduler`] — a discrete-event scheduler over the virtual clock:
//!   FIFO + first-fit (or round-robin) node packing, walltime kill,
//! * [`accounting`] — per-(sub)job usage records, qstat-style reporting.

mod accounting;
mod array;
mod job;
mod scheduler;
pub mod script;

pub use accounting::{JobRecord, QstatReport};
pub use array::{ArrayRange, SubJobId};
pub use job::{Job, JobId, JobState, ResourceRequest};
pub use scheduler::{PackingPolicy, Scheduler, SchedulerConfig, SchedulerStats};
