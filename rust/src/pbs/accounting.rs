//! Job accounting: per-subjob usage records and qstat-style reports.

use std::collections::HashMap;

use crate::metrics::ResourceUsage;
use crate::simclock::SimInstant;

use super::{JobState, SubJobId};

/// The terminal record of one subjob — what `qstat -fx` would show.
#[derive(Debug, Clone, PartialEq)]
pub struct JobRecord {
    pub sub: SubJobId,
    pub node: usize,
    pub state: JobState,
    pub queued_at: SimInstant,
    pub started_at: SimInstant,
    pub finished_at: SimInstant,
    pub usage: ResourceUsage,
}

impl JobRecord {
    /// Mean parallelism = cpu_time / walltime, reported as a percentage —
    /// the "CPU %" row of the paper's Table 5.3.
    pub fn cpu_percent(&self) -> f64 {
        let wall = self.usage.walltime.as_secs_f64();
        if wall <= 0.0 {
            return 0.0;
        }
        100.0 * self.usage.cpu_time_s / wall
    }
}

/// A live queue snapshot: counts by state (what `qstat` prints per job).
#[derive(Debug, Clone, PartialEq)]
pub struct QstatReport {
    pub at: SimInstant,
    pub queued: u64,
    pub running: u64,
    pub completed: u64,
    pub killed: u64,
    pub failed: u64,
}

impl QstatReport {
    pub fn from_states(at: SimInstant, states: &HashMap<SubJobId, JobState>) -> Self {
        let mut r = QstatReport {
            at,
            queued: 0,
            running: 0,
            completed: 0,
            killed: 0,
            failed: 0,
        };
        for s in states.values() {
            match s {
                JobState::Queued => r.queued += 1,
                JobState::Running => r.running += 1,
                JobState::Completed => r.completed += 1,
                JobState::KilledWalltime => r.killed += 1,
                JobState::Failed => r.failed += 1,
            }
        }
        r
    }

    pub fn total(&self) -> u64 {
        self.queued + self.running + self.completed + self.killed + self.failed
    }

    /// Render as the familiar one-line summary.
    pub fn render(&self) -> String {
        format!(
            "[{}] Q:{} R:{} F:{} K:{} E:{} (total {})",
            self.at,
            self.queued,
            self.running,
            self.completed,
            self.killed,
            self.failed,
            self.total()
        )
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::pbs::JobId;
    use crate::simclock::SimDuration;

    #[test]
    fn cpu_percent_is_mean_parallelism() {
        let rec = JobRecord {
            sub: SubJobId {
                job: JobId(1),
                array_index: 0,
            },
            node: 0,
            state: JobState::Completed,
            queued_at: SimInstant::ZERO,
            started_at: SimInstant::ZERO,
            finished_at: SimInstant::ZERO + SimDuration::from_secs(100),
            usage: ResourceUsage {
                walltime: SimDuration::from_secs(100),
                cpu_time_s: 215.0,
                max_ram_gb: 2.2,
            },
        };
        assert!((rec.cpu_percent() - 215.0).abs() < 1e-9);
    }

    #[test]
    fn qstat_counts_by_state() {
        let mut states = HashMap::new();
        for i in 0..3 {
            states.insert(
                SubJobId {
                    job: JobId(1),
                    array_index: i,
                },
                JobState::Running,
            );
        }
        states.insert(
            SubJobId {
                job: JobId(1),
                array_index: 3,
            },
            JobState::Completed,
        );
        let r = QstatReport::from_states(SimInstant::ZERO, &states);
        assert_eq!(r.running, 3);
        assert_eq!(r.completed, 1);
        assert_eq!(r.total(), 4);
        assert!(r.render().contains("R:3"));
    }
}
