//! Output datasets and big-data aggregation.
//!
//! The pipeline's raison d'être is the "massive output dataset" (§1.2):
//! every run emits per-step observables; a campaign merges thousands of
//! runs into one analysis-ready dataset ("a simulation with a 10 MB
//! output dataset, after being run 100,000 times in sequence, would then
//! swell to a 1 TB size", §2.10).

mod aggregate;
mod dataset;
mod stats;

pub use aggregate::CampaignDataset;
pub use dataset::{ObsRow, RunDataset};
pub use stats::{mean, percentile, stddev};
