//! Campaign-level dataset aggregation.


use super::dataset::RunDataset;
use super::stats;

/// The merged output of a whole campaign.
#[derive(Debug, Clone, Default)]
pub struct CampaignDataset {
    pub runs: Vec<RunDataset>,
}

impl CampaignDataset {
    pub fn new() -> Self {
        Self::default()
    }

    pub fn add(&mut self, run: RunDataset) {
        self.runs.push(run);
    }

    pub fn merge(&mut self, other: CampaignDataset) {
        self.runs.extend(other.runs);
    }

    pub fn num_runs(&self) -> usize {
        self.runs.len()
    }

    pub fn total_rows(&self) -> u64 {
        self.runs.iter().map(|r| r.rows.len() as u64).sum()
    }

    /// Aggregate dataset size — the §2.10 "big data" observable.
    pub fn total_bytes(&self) -> u64 {
        self.runs.iter().map(|r| r.size_bytes()).sum()
    }

    /// Distribution of per-run throughput (total vehicles that finished).
    pub fn flow_stats(&self) -> (f64, f64) {
        let flows: Vec<f64> = self.runs.iter().map(|r| r.total_flow as f64).collect();
        (stats::mean(&flows), stats::stddev(&flows))
    }

    /// Per-node run counts — feeds the §5.2 distribution analysis.
    pub fn runs_per_node(&self, num_nodes: usize) -> Vec<usize> {
        let mut counts = vec![0usize; num_nodes];
        for r in &self.runs {
            if r.node < num_nodes {
                counts[r.node] += 1;
            }
        }
        counts
    }

    /// Seeds must be unique across runs — duplicate seeds silently halve
    /// the dataset's information content (the whole point of §1.2's
    /// "sources of randomization").
    pub fn seeds_unique(&self) -> bool {
        let mut seeds: Vec<u64> = self.runs.iter().map(|r| r.seed).collect();
        seeds.sort_unstable();
        seeds.windows(2).all(|w| w[0] != w[1])
    }

    /// Qualified run ids must be unique — the ledger-resume idempotence
    /// invariant: a retried or resumed run must *replace* its slot's
    /// output, never add a second copy of it.
    pub fn run_ids_unique(&self) -> bool {
        let mut ids: Vec<&str> = self.runs.iter().map(|r| r.run_id.as_str()).collect();
        ids.sort_unstable();
        ids.windows(2).all(|w| w[0] != w[1])
    }

    /// Per-scenario run counts (scenario-matrix campaigns; untagged
    /// runs group under `"-"`).  Sorted by scenario id.
    pub fn runs_per_scenario(&self) -> Vec<(String, usize)> {
        let mut counts = std::collections::BTreeMap::new();
        for r in &self.runs {
            let key = r
                .scenario
                .as_ref()
                .map(|t| t.id.as_str().to_string())
                .unwrap_or_else(|| "-".to_string());
            *counts.entry(key).or_insert(0usize) += 1;
        }
        counts.into_iter().collect()
    }

    /// Sorted union of scenario parameter names across runs — the
    /// parameter columns of [`Self::to_ml_csv`].
    pub fn param_columns(&self) -> Vec<String> {
        let mut names = std::collections::BTreeSet::new();
        for r in &self.runs {
            if let Some(tag) = &r.scenario {
                for (name, _) in &tag.params {
                    names.insert(name.clone());
                }
            }
        }
        names.into_iter().collect()
    }

    /// Stream the ML-ready long-form export into `w`: one CSV row per
    /// logged step, each carrying its run provenance (qualified run id,
    /// scenario id, sample index, node, seed) **and the generating
    /// parameter vector** — the §1 promise ("aggregated output datasets
    /// ... for ML applications") made self-describing.  Parameter cells
    /// are empty for runs whose scenario lacks that axis (and for
    /// untagged runs); the scenarios manifest is the matching codebook.
    ///
    /// Streaming on purpose: a 12-hour campaign logs millions of rows,
    /// and materializing them as one giant `String` doubled the peak
    /// memory of the export.  Per-run constants (provenance prefix and
    /// parameter cells) are rendered once per run, not once per row, and
    /// the sink is wrapped in a [`std::io::BufWriter`] so a raw `File`
    /// doesn't pay one syscall per row.
    pub fn write_ml_csv<W: std::io::Write>(&self, w: &mut W) -> std::io::Result<()> {
        let mut w = std::io::BufWriter::new(w);
        let w = &mut w;
        let params = self.param_columns();
        write!(
            w,
            "run_id,scenario,sample_index,node,seed,degraded,time_s,n_active,mean_speed,flow,n_merged,n_exited"
        )?;
        for p in &params {
            write!(w, ",{p}")?;
        }
        writeln!(w)?;
        let mut cells = String::new();
        for r in &self.runs {
            let (scenario, sample): (String, String) = match &r.scenario {
                Some(t) => (t.id.as_str().to_string(), t.sample_index.to_string()),
                None => (String::new(), String::new()),
            };
            cells.clear();
            for p in &params {
                cells.push(',');
                if let Some(v) = r.param(p) {
                    cells.push_str(&v.render());
                }
            }
            let degraded = r.degraded as u8;
            for row in &r.rows {
                writeln!(
                    w,
                    "{},{scenario},{sample},{},{},{degraded},{:.1},{},{:.3},{},{},{}{cells}",
                    r.run_id, r.node, r.seed, row.time_s, row.n_active, row.mean_speed,
                    row.flow, row.n_merged, row.n_exited
                )?;
            }
        }
        // surface flush errors here — BufWriter's Drop swallows them
        w.flush()
    }

    /// The export as one in-memory `String` — a thin wrapper over
    /// [`Self::write_ml_csv`] for small datasets and tests; campaign
    /// exports should stream to a file/socket instead.
    pub fn to_ml_csv(&self) -> String {
        let mut buf = Vec::new();
        // writing into a Vec cannot fail; if it somehow does, an empty
        // export (callers validate row counts) beats a panic
        if self.write_ml_csv(&mut buf).is_err() {
            return String::new();
        }
        String::from_utf8_lossy(&buf).into_owned()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::sumo::StepObs;

    fn run(id: &str, node: usize, seed: u64, flow: f32) -> RunDataset {
        let mut d = RunDataset::new(id, node, seed);
        d.push(
            0.1,
            &StepObs {
                n_active: 1.0,
                mean_speed: 10.0,
                flow,
                n_merged: 0.0,
                n_exited: 0.0,
            },
        );
        d
    }

    #[test]
    fn aggregation_counts() {
        let mut c = CampaignDataset::new();
        for i in 0..10 {
            c.add(run(&format!("1[{i}]"), i % 3, i as u64, 2.0));
        }
        assert_eq!(c.num_runs(), 10);
        assert_eq!(c.total_rows(), 10);
        assert_eq!(c.runs_per_node(3), vec![4, 3, 3]);
        assert!(c.seeds_unique());
        let (m, s) = c.flow_stats();
        assert_eq!(m, 2.0);
        assert_eq!(s, 0.0);
    }

    #[test]
    fn duplicate_seeds_detected() {
        let mut c = CampaignDataset::new();
        c.add(run("a", 0, 7, 1.0));
        c.add(run("b", 0, 7, 1.0));
        assert!(!c.seeds_unique());
    }

    #[test]
    fn ml_csv_carries_scenario_params() {
        use crate::scenario::{AxisValue, ScenarioId, ScenarioTag};
        let mut c = CampaignDataset::new();
        c.add(run("e0[0]", 0, 1, 2.0)); // untagged
        let mut tagged = run("e0[1]", 1, 2, 3.0);
        tagged = tagged.with_scenario(ScenarioTag {
            id: ScenarioId::new("ring-shockwave"),
            sample_index: 5,
            params: vec![
                ("circumference_m".into(), AxisValue::Num(800.0)),
                ("lanes".into(), AxisValue::Int(2)),
            ],
        });
        c.add(tagged);

        assert_eq!(c.param_columns(), vec!["circumference_m", "lanes"]);
        assert_eq!(
            c.runs_per_scenario(),
            vec![("-".to_string(), 1), ("ring-shockwave".to_string(), 1)]
        );

        let csv = c.to_ml_csv();
        let lines: Vec<&str> = csv.lines().collect();
        assert_eq!(
            lines[0],
            "run_id,scenario,sample_index,node,seed,degraded,time_s,n_active,mean_speed,flow,n_merged,n_exited,circumference_m,lanes"
        );
        // untagged run: empty scenario + param cells
        assert!(lines[1].starts_with("e0[0],,,0,1,0,"));
        assert!(lines[1].ends_with(",,"));
        // tagged run: qualified id + params
        assert!(lines[2].starts_with("e0[1]@ring-shockwave#5,ring-shockwave,5,1,2,0,"));
        assert!(lines[2].ends_with(",800,2"));
    }

    #[test]
    fn degraded_flag_lands_in_every_row() {
        let mut c = CampaignDataset::new();
        let mut d = run("g[0]", 0, 9, 1.0);
        d.degraded = true;
        c.add(d);
        let csv = c.to_ml_csv();
        assert!(csv.lines().nth(1).unwrap().starts_with("g[0],,,0,9,1,"));
    }

    #[test]
    fn duplicate_run_ids_detected() {
        let mut c = CampaignDataset::new();
        c.add(run("e0[0]", 0, 1, 1.0));
        c.add(run("e0[1]", 0, 2, 1.0));
        assert!(c.run_ids_unique());
        c.add(run("e0[0]", 0, 3, 1.0));
        assert!(!c.run_ids_unique());
    }

    #[test]
    fn streaming_csv_matches_string_form() {
        use crate::scenario::{AxisValue, ScenarioId, ScenarioTag};
        let mut c = CampaignDataset::new();
        c.add(run("s[0]", 0, 3, 1.0));
        c.add(run("s[1]", 1, 4, 2.0).with_scenario(ScenarioTag {
            id: ScenarioId::new("lane-drop"),
            sample_index: 2,
            params: vec![("drop_pos_m".into(), AxisValue::Num(550.0))],
        }));
        let mut streamed = Vec::new();
        c.write_ml_csv(&mut streamed).unwrap();
        assert_eq!(String::from_utf8(streamed).unwrap(), c.to_ml_csv());
    }

    #[test]
    fn streaming_csv_propagates_io_errors() {
        /// A sink that rejects every write — the campaign-export failure
        /// mode (disk full mid-stream) must surface, not panic, even
        /// when the internal BufWriter defers the failure to flush time.
        struct FullSink;
        impl std::io::Write for FullSink {
            fn write(&mut self, _buf: &[u8]) -> std::io::Result<usize> {
                Err(std::io::Error::other("sink full"))
            }
            fn flush(&mut self) -> std::io::Result<()> {
                Ok(())
            }
        }
        let mut c = CampaignDataset::new();
        c.add(run("a", 0, 1, 1.0));
        assert!(c.write_ml_csv(&mut FullSink).is_err());
    }

    #[test]
    fn merge_concatenates() {
        let mut a = CampaignDataset::new();
        a.add(run("a", 0, 1, 1.0));
        let mut b = CampaignDataset::new();
        b.add(run("b", 0, 2, 1.0));
        a.merge(b);
        assert_eq!(a.num_runs(), 2);
    }
}
