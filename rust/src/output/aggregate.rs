//! Campaign-level dataset aggregation.


use super::dataset::RunDataset;
use super::stats;

/// The merged output of a whole campaign.
#[derive(Debug, Clone, Default)]
pub struct CampaignDataset {
    pub runs: Vec<RunDataset>,
}

impl CampaignDataset {
    pub fn new() -> Self {
        Self::default()
    }

    pub fn add(&mut self, run: RunDataset) {
        self.runs.push(run);
    }

    pub fn merge(&mut self, other: CampaignDataset) {
        self.runs.extend(other.runs);
    }

    pub fn num_runs(&self) -> usize {
        self.runs.len()
    }

    pub fn total_rows(&self) -> u64 {
        self.runs.iter().map(|r| r.rows.len() as u64).sum()
    }

    /// Aggregate dataset size — the §2.10 "big data" observable.
    pub fn total_bytes(&self) -> u64 {
        self.runs.iter().map(|r| r.size_bytes()).sum()
    }

    /// Distribution of per-run throughput (total vehicles that finished).
    pub fn flow_stats(&self) -> (f64, f64) {
        let flows: Vec<f64> = self.runs.iter().map(|r| r.total_flow as f64).collect();
        (stats::mean(&flows), stats::stddev(&flows))
    }

    /// Per-node run counts — feeds the §5.2 distribution analysis.
    pub fn runs_per_node(&self, num_nodes: usize) -> Vec<usize> {
        let mut counts = vec![0usize; num_nodes];
        for r in &self.runs {
            if r.node < num_nodes {
                counts[r.node] += 1;
            }
        }
        counts
    }

    /// Seeds must be unique across runs — duplicate seeds silently halve
    /// the dataset's information content (the whole point of §1.2's
    /// "sources of randomization").
    pub fn seeds_unique(&self) -> bool {
        let mut seeds: Vec<u64> = self.runs.iter().map(|r| r.seed).collect();
        seeds.sort_unstable();
        seeds.windows(2).all(|w| w[0] != w[1])
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::sumo::StepObs;

    fn run(id: &str, node: usize, seed: u64, flow: f32) -> RunDataset {
        let mut d = RunDataset::new(id, node, seed);
        d.push(
            0.1,
            &StepObs {
                n_active: 1.0,
                mean_speed: 10.0,
                flow,
                n_merged: 0.0,
            },
        );
        d
    }

    #[test]
    fn aggregation_counts() {
        let mut c = CampaignDataset::new();
        for i in 0..10 {
            c.add(run(&format!("1[{i}]"), i % 3, i as u64, 2.0));
        }
        assert_eq!(c.num_runs(), 10);
        assert_eq!(c.total_rows(), 10);
        assert_eq!(c.runs_per_node(3), vec![4, 3, 3]);
        assert!(c.seeds_unique());
        let (m, s) = c.flow_stats();
        assert_eq!(m, 2.0);
        assert_eq!(s, 0.0);
    }

    #[test]
    fn duplicate_seeds_detected() {
        let mut c = CampaignDataset::new();
        c.add(run("a", 0, 7, 1.0));
        c.add(run("b", 0, 7, 1.0));
        assert!(!c.seeds_unique());
    }

    #[test]
    fn merge_concatenates() {
        let mut a = CampaignDataset::new();
        a.add(run("a", 0, 1, 1.0));
        let mut b = CampaignDataset::new();
        b.add(run("b", 0, 2, 1.0));
        a.merge(b);
        assert_eq!(a.num_runs(), 2);
    }
}
