//! Per-run output datasets.


use crate::scenario::{AxisValue, ScenarioTag};
use crate::sumo::StepObs;

/// One logged step (a row of the run's CSV).
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct ObsRow {
    pub time_s: f32,
    pub n_active: f32,
    pub mean_speed: f32,
    pub flow: f32,
    pub n_merged: f32,
    /// Off-ramp completions this step (exit-flagged vehicles crossing
    /// their own exit_pos) — throughput that `flow` deliberately does
    /// not count.
    pub n_exited: f32,
}

impl ObsRow {
    pub fn from_obs(time_s: f32, o: &StepObs) -> Self {
        ObsRow {
            time_s,
            n_active: o.n_active,
            mean_speed: o.mean_speed,
            flow: o.flow,
            n_merged: o.n_merged,
            n_exited: o.n_exited,
        }
    }
}

/// The output dataset of one simulation run.
#[derive(Debug, Clone, PartialEq)]
pub struct RunDataset {
    /// `{job}[{array_index}]`-style identifier; scenario-matrix runs
    /// append `@{scenario}#{sample_index}` (see [`Self::with_scenario`])
    /// so aggregated rows from different scenario points stay
    /// distinguishable.
    pub run_id: String,
    /// Node the run executed on.
    pub node: usize,
    /// duarouter seed — the run's source of randomization.
    pub seed: u64,
    /// Scenario provenance: which point of which family generated this
    /// run (None for classic fixed-scenario runs).
    pub scenario: Option<ScenarioTag>,
    /// Supervision provenance: true when the run completed on the
    /// native-stepper fallback after its HLO engine failed (graceful
    /// degradation) — ML consumers can filter or stratify on it.
    pub degraded: bool,
    /// Execution-path provenance: steps that ran as device-resident
    /// whole-run dispatches (schema 5).  0 = the host chunk scheduler
    /// (or the native stepper) produced every step; equality with
    /// `rows.len()` means the entire horizon was one fused run.  Like
    /// `degraded`, ML consumers can stratify on it.
    pub resident_steps: u64,
    pub rows: Vec<ObsRow>,
    /// Totals for quick aggregation.
    pub total_flow: f32,
    pub total_merged: f32,
    /// Off-ramp completions — the ramp-weave throughput that
    /// `total_flow` alone under-reports.
    pub total_exited: f32,
    pub total_spawned: u64,
}

impl RunDataset {
    pub fn new(run_id: impl Into<String>, node: usize, seed: u64) -> Self {
        RunDataset {
            run_id: run_id.into(),
            node,
            seed,
            scenario: None,
            degraded: false,
            resident_steps: 0,
            rows: Vec::new(),
            total_flow: 0.0,
            total_merged: 0.0,
            total_exited: 0.0,
            total_spawned: 0,
        }
    }

    /// Attach scenario provenance, qualifying the run id with the
    /// scenario id + sample index (`{job}[{i}]@{scenario}#{sample}`).
    pub fn with_scenario(mut self, tag: ScenarioTag) -> Self {
        self.run_id = format!("{}@{}#{}", self.run_id, tag.id, tag.sample_index);
        self.scenario = Some(tag);
        self
    }

    /// A generating parameter of this run, when scenario-tagged.
    pub fn param(&self, name: &str) -> Option<&AxisValue> {
        self.scenario.as_ref().and_then(|t| t.param(name))
    }

    pub fn push(&mut self, time_s: f32, obs: &StepObs) {
        self.rows.push(ObsRow::from_obs(time_s, obs));
        self.total_flow += obs.flow;
        self.total_merged += obs.n_merged;
        self.total_exited += obs.n_exited;
    }

    /// On-disk size estimate [bytes] (CSV encoding).
    pub fn size_bytes(&self) -> u64 {
        // header + ~48 bytes/row measured from the csv encoding
        64 + self.rows.len() as u64 * 48
    }

    /// Render as CSV.
    pub fn to_csv(&self) -> String {
        let mut s = String::from("time_s,n_active,mean_speed,flow,n_merged,n_exited\n");
        for r in &self.rows {
            s.push_str(&format!(
                "{:.1},{},{:.3},{},{},{}\n",
                r.time_s, r.n_active, r.mean_speed, r.flow, r.n_merged, r.n_exited
            ));
        }
        s
    }

    /// Parse back from CSV.
    pub fn from_csv(run_id: &str, node: usize, seed: u64, csv: &str) -> crate::Result<Self> {
        let mut ds = RunDataset::new(run_id, node, seed);
        for (i, line) in csv.lines().enumerate() {
            if i == 0 || line.is_empty() {
                continue;
            }
            let f: Vec<f32> = line
                .split(',')
                .map(|v| {
                    v.parse::<f32>()
                        .map_err(|e| crate::Error::Config(format!("bad csv field '{v}': {e}")))
                })
                .collect::<crate::Result<_>>()?;
            if f.len() != 6 {
                return Err(crate::Error::Config(format!(
                    "csv row {i} has {} fields, want 6",
                    f.len()
                )));
            }
            ds.push(
                f[0],
                &StepObs {
                    n_active: f[1],
                    mean_speed: f[2],
                    flow: f[3],
                    n_merged: f[4],
                    n_exited: f[5],
                },
            );
        }
        Ok(ds)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn sample() -> RunDataset {
        let mut d = RunDataset::new("1[3]", 2, 42);
        for i in 0..10 {
            d.push(
                i as f32 * 0.1,
                &StepObs {
                    n_active: 5.0,
                    mean_speed: 20.0,
                    flow: if i == 9 { 1.0 } else { 0.0 },
                    n_merged: 0.0,
                    n_exited: if i == 4 { 1.0 } else { 0.0 },
                },
            );
        }
        d
    }

    #[test]
    fn totals_accumulate() {
        let d = sample();
        assert_eq!(d.total_flow, 1.0);
        assert_eq!(d.total_exited, 1.0);
        assert_eq!(d.rows.len(), 10);
    }

    #[test]
    fn csv_roundtrip() {
        let d = sample();
        let csv = d.to_csv();
        let back = RunDataset::from_csv("1[3]", 2, 42, &csv).unwrap();
        assert_eq!(back.rows.len(), d.rows.len());
        assert_eq!(back.total_flow, d.total_flow);
        assert_eq!(back.total_exited, d.total_exited);
    }

    #[test]
    fn size_scales_with_rows() {
        let d = sample();
        assert!(d.size_bytes() > 10 * 40);
        assert!(d.size_bytes() < 10_000);
    }

    #[test]
    fn bad_csv_rejected() {
        assert!(RunDataset::from_csv("x", 0, 0, "h\n1,2\n").is_err());
        assert!(RunDataset::from_csv("x", 0, 0, "h\na,b,c,d,e,f\n").is_err());
        // pre-schema-3 five-field rows are refused, not misparsed
        assert!(RunDataset::from_csv("x", 0, 0, "h\n1,2,3,4,5\n").is_err());
    }

    #[test]
    fn scenario_tag_qualifies_run_id() {
        use crate::scenario::{AxisValue, ScenarioId, ScenarioTag};
        let tag = ScenarioTag {
            id: ScenarioId::new("lane-drop"),
            sample_index: 7,
            params: vec![("demand_vph".into(), AxisValue::Num(1800.0))],
        };
        let d = RunDataset::new("e0[3]", 1, 42).with_scenario(tag.clone());
        assert_eq!(d.run_id, "e0[3]@lane-drop#7");
        assert_eq!(d.scenario, Some(tag));
        assert_eq!(d.param("demand_vph"), Some(&AxisValue::Num(1800.0)));
        assert_eq!(d.param("absent"), None);
        // same job form, different point → distinguishable ids
        let tag2 = ScenarioTag {
            id: ScenarioId::new("lane-drop"),
            sample_index: 8,
            params: vec![],
        };
        let d2 = RunDataset::new("e0[3]", 1, 43).with_scenario(tag2);
        assert_ne!(d.run_id, d2.run_id);
    }
}
