//! Small statistics helpers for reports and benches.

/// Arithmetic mean (0 for empty input).
pub fn mean(xs: &[f64]) -> f64 {
    if xs.is_empty() {
        return 0.0;
    }
    xs.iter().sum::<f64>() / xs.len() as f64
}

/// Population standard deviation (0 for < 2 elements).
pub fn stddev(xs: &[f64]) -> f64 {
    if xs.len() < 2 {
        return 0.0;
    }
    let m = mean(xs);
    (xs.iter().map(|x| (x - m).powi(2)).sum::<f64>() / xs.len() as f64).sqrt()
}

/// Nearest-rank percentile; `p` in [0, 100]. 0 for empty input.
pub fn percentile(xs: &[f64], p: f64) -> f64 {
    if xs.is_empty() {
        return 0.0;
    }
    let mut sorted = xs.to_vec();
    sorted.sort_by(|a, b| a.total_cmp(b));
    let rank = ((p / 100.0) * (sorted.len() as f64 - 1.0)).round() as usize;
    sorted[rank.min(sorted.len() - 1)]
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn mean_basic() {
        assert_eq!(mean(&[1.0, 2.0, 3.0]), 2.0);
        assert_eq!(mean(&[]), 0.0);
    }

    #[test]
    fn stddev_basic() {
        assert_eq!(stddev(&[2.0, 2.0, 2.0]), 0.0);
        assert!((stddev(&[1.0, 3.0]) - 1.0).abs() < 1e-12);
        assert_eq!(stddev(&[5.0]), 0.0);
    }

    #[test]
    fn percentile_basic() {
        let xs = [1.0, 2.0, 3.0, 4.0, 5.0];
        assert_eq!(percentile(&xs, 0.0), 1.0);
        assert_eq!(percentile(&xs, 50.0), 3.0);
        assert_eq!(percentile(&xs, 100.0), 5.0);
        assert_eq!(percentile(&[], 50.0), 0.0);
    }
}
