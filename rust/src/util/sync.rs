//! Sync-primitive facade: `std::sync` normally, `loom::sync` under
//! `--cfg loom`.
//!
//! The control plane's concurrency-relevant types ([`crate::telemetry`]
//! metrics, [`crate::util::cache`]) import their primitives from here
//! instead of `std::sync`, so the loom models in
//! `rust/tests/loom_models.rs` exhaustively model the *real* code, not
//! a transliteration.  Normal builds see a pure re-export of std —
//! zero cost, zero behavior change; `--cfg loom` builds swap in loom's
//! instrumented twins (same API surface, including lock poisoning).
//!
//! Modules that stay std-only (everything gated `#[cfg(not(loom))]` in
//! lib.rs) keep importing `std::sync` directly — the facade is for
//! code that a loom model actually exercises.

#[cfg(loom)]
pub use loom::sync::atomic::{AtomicI64, AtomicU64, Ordering};
#[cfg(loom)]
pub use loom::sync::{Arc, Mutex, MutexGuard, RwLock};

#[cfg(not(loom))]
pub use std::sync::atomic::{AtomicI64, AtomicU64, Ordering};
#[cfg(not(loom))]
pub use std::sync::{Arc, Mutex, MutexGuard, RwLock};
