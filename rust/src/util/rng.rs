//! A small, fast, deterministic PRNG (SplitMix64 core).
//!
//! Statistical quality is far beyond what seeded traffic randomization
//! needs, and determinism-per-seed is the property the pipeline actually
//! depends on (`duarouter --seed $RANDOM`, per-subjob workload jitter).

/// SplitMix64 generator.
#[derive(Debug, Clone)]
pub struct Rng64 {
    state: u64,
}

impl Rng64 {
    pub fn seed_from_u64(seed: u64) -> Self {
        Rng64 { state: seed }
    }

    /// Next raw u64 (SplitMix64 step).
    pub fn next_u64(&mut self) -> u64 {
        self.state = self.state.wrapping_add(0x9E37_79B9_7F4A_7C15);
        let mut z = self.state;
        z = (z ^ (z >> 30)).wrapping_mul(0xBF58_476D_1CE4_E5B9);
        z = (z ^ (z >> 27)).wrapping_mul(0x94D0_49BB_1331_11EB);
        z ^ (z >> 31)
    }

    /// Uniform f64 in [0, 1).
    pub fn gen_f64(&mut self) -> f64 {
        // 53 mantissa bits
        (self.next_u64() >> 11) as f64 * (1.0 / (1u64 << 53) as f64)
    }

    /// Uniform f32 in [0, 1).
    pub fn gen_f32(&mut self) -> f32 {
        (self.next_u64() >> 40) as f32 * (1.0 / (1u64 << 24) as f32)
    }

    /// Uniform f32 in [lo, hi).
    pub fn gen_range_f32(&mut self, lo: f32, hi: f32) -> f32 {
        lo + (hi - lo) * self.gen_f32()
    }

    /// Uniform f64 in [lo, hi).
    pub fn gen_range_f64(&mut self, lo: f64, hi: f64) -> f64 {
        lo + (hi - lo) * self.gen_f64()
    }

    /// Uniform u64 in [0, n) (modulo bias negligible for n << 2^64).
    pub fn gen_below(&mut self, n: u64) -> u64 {
        debug_assert!(n > 0);
        self.next_u64() % n
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn deterministic_per_seed() {
        let mut a = Rng64::seed_from_u64(42);
        let mut b = Rng64::seed_from_u64(42);
        for _ in 0..100 {
            assert_eq!(a.next_u64(), b.next_u64());
        }
        let mut c = Rng64::seed_from_u64(43);
        assert_ne!(Rng64::seed_from_u64(42).next_u64(), c.next_u64());
    }

    #[test]
    fn floats_in_unit_interval() {
        let mut r = Rng64::seed_from_u64(7);
        for _ in 0..10_000 {
            let f = r.gen_f64();
            assert!((0.0..1.0).contains(&f));
            let g = r.gen_f32();
            assert!((0.0..1.0).contains(&g));
        }
    }

    #[test]
    fn range_respected() {
        let mut r = Rng64::seed_from_u64(9);
        for _ in 0..1000 {
            let v = r.gen_range_f32(5.0, 6.0);
            assert!((5.0..6.0).contains(&v));
            let u = r.gen_below(10);
            assert!(u < 10);
        }
    }

    #[test]
    fn mean_is_roughly_half() {
        let mut r = Rng64::seed_from_u64(1);
        let n = 100_000;
        let sum: f64 = (0..n).map(|_| r.gen_f64()).sum();
        let mean = sum / n as f64;
        assert!((mean - 0.5).abs() < 0.01, "mean = {mean}");
    }
}
