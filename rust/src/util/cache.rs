//! A read-mostly get-or-insert cache — the concurrency core of
//! [`crate::runtime::ExecutablePool`], extracted so the loom model in
//! `rust/tests/loom_models.rs` checks the code the hot path runs.
//!
//! Protocol (and why it's safe):
//!
//! 1. **read-lock probe** — the steady state; many readers, no
//!    contention with other probes,
//! 2. **build outside any lock** — construction (an HLO compile) is
//!    slow, and other keys must not stall behind it,
//! 3. **write-lock insert** — a racing double-build of the same key is
//!    benign: last writer wins, both values are valid and both callers
//!    keep the `Arc` they built, so nothing is ever torn or lost.
//!
//! Poisoned locks are recovered (`into_inner`): every write is a
//! single whole-entry insert, so a panicked builder thread leaves the
//! map structurally sound.

use std::collections::HashMap;
use std::hash::Hash;

use crate::util::sync::{Arc, RwLock};

/// Key → `Arc<V>` cache with the probe/build/insert protocol above.
pub struct SharedCache<K, V> {
    map: RwLock<HashMap<K, Arc<V>>>,
}

impl<K: Eq + Hash, V> Default for SharedCache<K, V> {
    fn default() -> Self {
        Self::new()
    }
}

impl<K: Eq + Hash, V> SharedCache<K, V> {
    pub fn new() -> Self {
        SharedCache {
            map: RwLock::new(HashMap::new()),
        }
    }

    /// The cached value for `key`, if present.
    pub fn get(&self, key: &K) -> Option<Arc<V>> {
        self.map
            .read()
            .unwrap_or_else(|e| e.into_inner())
            .get(key)
            .cloned()
    }

    /// Fetch `key`, building with `make` on miss.  Returns the value
    /// plus whether it was a hit.  `make` runs outside any lock; on
    /// `Err` nothing is inserted and the cache is unchanged.
    pub fn get_or_try_insert<E, F>(&self, key: K, make: F) -> Result<(Arc<V>, bool), E>
    where
        F: FnOnce() -> Result<V, E>,
    {
        if let Some(v) = self.get(&key) {
            return Ok((v, true));
        }
        let v = Arc::new(make()?);
        self.map
            .write()
            .unwrap_or_else(|e| e.into_inner())
            .insert(key, v.clone());
        Ok((v, false))
    }

    pub fn len(&self) -> usize {
        self.map.read().unwrap_or_else(|e| e.into_inner()).len()
    }

    pub fn is_empty(&self) -> bool {
        self.len() == 0
    }
}

#[cfg(test)]
#[allow(clippy::unwrap_used, clippy::expect_used)]
mod tests {
    use super::*;

    #[test]
    fn hit_miss_and_error_paths() {
        let c: SharedCache<&str, u64> = SharedCache::new();
        assert!(c.is_empty());
        assert!(c.get(&"a").is_none());

        let (v, hit) = c.get_or_try_insert::<(), _>("a", || Ok(7)).unwrap();
        assert_eq!((*v, hit), (7, false));
        let (v, hit) = c.get_or_try_insert::<(), _>("a", || Ok(999)).unwrap();
        assert_eq!((*v, hit), (7, true), "hit returns the cached value");

        // a failed build inserts nothing and doesn't wedge the key
        assert!(c.get_or_try_insert::<&str, _>("b", || Err("boom")).is_err());
        assert!(c.get(&"b").is_none());
        let (v, hit) = c.get_or_try_insert::<(), _>("b", || Ok(8)).unwrap();
        assert_eq!((*v, hit), (8, false));
        assert_eq!(c.len(), 2);
    }
}
