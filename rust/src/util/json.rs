//! A minimal JSON parser + writer — just enough for
//! `artifacts/manifest.json` and the bench-result trajectory files
//! (`BENCH_*.json`).
//!
//! Supports objects, arrays, strings (with standard escapes), numbers,
//! booleans and null.

use std::collections::BTreeMap;

use crate::{Error, Result};

/// A parsed JSON value.
#[derive(Debug, Clone, PartialEq)]
pub enum Json {
    Null,
    Bool(bool),
    Num(f64),
    Str(String),
    Arr(Vec<Json>),
    Obj(BTreeMap<String, Json>),
}

impl Json {
    /// Builder convenience: a string value.
    pub fn str(s: impl Into<String>) -> Json {
        Json::Str(s.into())
    }

    /// Builder convenience: a number value.
    pub fn num(n: f64) -> Json {
        Json::Num(n)
    }

    /// Builder convenience: an array value.
    pub fn arr(items: Vec<Json>) -> Json {
        Json::Arr(items)
    }

    /// Builder convenience: an object from `(key, value)` pairs (later
    /// duplicates win, matching [`Json::parse`]).
    pub fn obj<K: Into<String>>(pairs: Vec<(K, Json)>) -> Json {
        Json::Obj(pairs.into_iter().map(|(k, v)| (k.into(), v)).collect())
    }

    pub fn parse(text: &str) -> Result<Json> {
        let mut p = Parser {
            bytes: text.as_bytes(),
            pos: 0,
        };
        p.skip_ws();
        let v = p.value()?;
        p.skip_ws();
        if p.pos != p.bytes.len() {
            return Err(Error::Config(format!(
                "trailing JSON content at byte {}",
                p.pos
            )));
        }
        Ok(v)
    }

    pub fn get(&self, key: &str) -> Result<&Json> {
        match self {
            Json::Obj(m) => m
                .get(key)
                .ok_or_else(|| Error::Config(format!("missing JSON key '{key}'"))),
            _ => Err(Error::Config(format!("expected object for key '{key}'"))),
        }
    }

    pub fn as_str(&self) -> Result<&str> {
        match self {
            Json::Str(s) => Ok(s),
            other => Err(Error::Config(format!("expected string, got {other:?}"))),
        }
    }

    pub fn as_f64(&self) -> Result<f64> {
        match self {
            Json::Num(n) => Ok(*n),
            other => Err(Error::Config(format!("expected number, got {other:?}"))),
        }
    }

    pub fn as_usize(&self) -> Result<usize> {
        Ok(self.as_f64()? as usize)
    }

    pub fn as_arr(&self) -> Result<&[Json]> {
        match self {
            Json::Arr(a) => Ok(a),
            other => Err(Error::Config(format!("expected array, got {other:?}"))),
        }
    }

    pub fn as_obj(&self) -> Result<&BTreeMap<String, Json>> {
        match self {
            Json::Obj(m) => Ok(m),
            other => Err(Error::Config(format!("expected object, got {other:?}"))),
        }
    }

    /// Serialize with 2-space indentation (round-trips through
    /// [`Json::parse`]).
    pub fn to_pretty_string(&self) -> String {
        let mut s = String::new();
        self.write(&mut s, 0);
        s
    }

    /// Serialize on one line, no whitespace — the JSONL form the
    /// campaign ledger appends (one record per line; round-trips
    /// through [`Json::parse`]).
    pub fn to_compact_string(&self) -> String {
        let mut s = String::new();
        self.write_compact(&mut s);
        s
    }

    fn write_compact(&self, out: &mut String) {
        match self {
            Json::Arr(a) => {
                out.push('[');
                for (i, v) in a.iter().enumerate() {
                    if i > 0 {
                        out.push(',');
                    }
                    v.write_compact(out);
                }
                out.push(']');
            }
            Json::Obj(m) => {
                out.push('{');
                for (i, (k, v)) in m.iter().enumerate() {
                    if i > 0 {
                        out.push(',');
                    }
                    write_escaped(out, k);
                    out.push(':');
                    v.write_compact(out);
                }
                out.push('}');
            }
            scalar => scalar.write(out, 0),
        }
    }

    fn write(&self, out: &mut String, indent: usize) {
        match self {
            Json::Null => out.push_str("null"),
            Json::Bool(b) => out.push_str(if *b { "true" } else { "false" }),
            Json::Num(n) => {
                if n.is_finite() {
                    // integers print without a trailing ".0" (matches
                    // what the python side writes into manifests)
                    if n.fract() == 0.0 && n.abs() < 1e15 {
                        out.push_str(&format!("{}", *n as i64));
                    } else {
                        out.push_str(&format!("{n}"));
                    }
                } else {
                    out.push_str("null"); // JSON has no Inf/NaN
                }
            }
            Json::Str(s) => write_escaped(out, s),
            Json::Arr(a) => {
                if a.is_empty() {
                    out.push_str("[]");
                    return;
                }
                out.push('[');
                for (i, v) in a.iter().enumerate() {
                    if i > 0 {
                        out.push(',');
                    }
                    out.push('\n');
                    push_indent(out, indent + 1);
                    v.write(out, indent + 1);
                }
                out.push('\n');
                push_indent(out, indent);
                out.push(']');
            }
            Json::Obj(m) => {
                if m.is_empty() {
                    out.push_str("{}");
                    return;
                }
                out.push('{');
                for (i, (k, v)) in m.iter().enumerate() {
                    if i > 0 {
                        out.push(',');
                    }
                    out.push('\n');
                    push_indent(out, indent + 1);
                    write_escaped(out, k);
                    out.push_str(": ");
                    v.write(out, indent + 1);
                }
                out.push('\n');
                push_indent(out, indent);
                out.push('}');
            }
        }
    }
}

fn push_indent(out: &mut String, levels: usize) {
    for _ in 0..levels {
        out.push_str("  ");
    }
}

fn write_escaped(out: &mut String, s: &str) {
    out.push('"');
    for c in s.chars() {
        match c {
            '"' => out.push_str("\\\""),
            '\\' => out.push_str("\\\\"),
            '\n' => out.push_str("\\n"),
            '\t' => out.push_str("\\t"),
            '\r' => out.push_str("\\r"),
            c if (c as u32) < 0x20 => out.push_str(&format!("\\u{:04x}", c as u32)),
            c => out.push(c),
        }
    }
    out.push('"');
}

struct Parser<'a> {
    bytes: &'a [u8],
    pos: usize,
}

impl<'a> Parser<'a> {
    fn skip_ws(&mut self) {
        while self.pos < self.bytes.len() && self.bytes[self.pos].is_ascii_whitespace() {
            self.pos += 1;
        }
    }

    fn peek(&self) -> Result<u8> {
        self.bytes
            .get(self.pos)
            .copied()
            .ok_or_else(|| Error::Config("unexpected end of JSON".into()))
    }

    fn expect_byte(&mut self, b: u8) -> Result<()> {
        if self.peek()? != b {
            return Err(Error::Config(format!(
                "expected '{}' at byte {}, found '{}'",
                b as char, self.pos, self.bytes[self.pos] as char
            )));
        }
        self.pos += 1;
        Ok(())
    }

    fn value(&mut self) -> Result<Json> {
        self.skip_ws();
        match self.peek()? {
            b'{' => self.object(),
            b'[' => self.array(),
            b'"' => Ok(Json::Str(self.string()?)),
            b't' => self.literal("true", Json::Bool(true)),
            b'f' => self.literal("false", Json::Bool(false)),
            b'n' => self.literal("null", Json::Null),
            _ => self.number(),
        }
    }

    fn literal(&mut self, word: &str, v: Json) -> Result<Json> {
        if self.bytes[self.pos..].starts_with(word.as_bytes()) {
            self.pos += word.len();
            Ok(v)
        } else {
            Err(Error::Config(format!("bad JSON literal at {}", self.pos)))
        }
    }

    fn object(&mut self) -> Result<Json> {
        self.expect_byte(b'{')?;
        let mut m = BTreeMap::new();
        self.skip_ws();
        if self.peek()? == b'}' {
            self.pos += 1;
            return Ok(Json::Obj(m));
        }
        loop {
            self.skip_ws();
            let key = self.string()?;
            self.skip_ws();
            self.expect_byte(b':')?;
            let v = self.value()?;
            m.insert(key, v);
            self.skip_ws();
            match self.peek()? {
                b',' => self.pos += 1,
                b'}' => {
                    self.pos += 1;
                    return Ok(Json::Obj(m));
                }
                c => {
                    return Err(Error::Config(format!(
                        "expected ',' or '}}' in object, found '{}'",
                        c as char
                    )))
                }
            }
        }
    }

    fn array(&mut self) -> Result<Json> {
        self.expect_byte(b'[')?;
        let mut a = Vec::new();
        self.skip_ws();
        if self.peek()? == b']' {
            self.pos += 1;
            return Ok(Json::Arr(a));
        }
        loop {
            a.push(self.value()?);
            self.skip_ws();
            match self.peek()? {
                b',' => self.pos += 1,
                b']' => {
                    self.pos += 1;
                    return Ok(Json::Arr(a));
                }
                c => {
                    return Err(Error::Config(format!(
                        "expected ',' or ']' in array, found '{}'",
                        c as char
                    )))
                }
            }
        }
    }

    fn string(&mut self) -> Result<String> {
        self.expect_byte(b'"')?;
        let mut s = String::new();
        loop {
            let c = self.peek()?;
            self.pos += 1;
            match c {
                b'"' => return Ok(s),
                b'\\' => {
                    let e = self.peek()?;
                    self.pos += 1;
                    match e {
                        b'"' => s.push('"'),
                        b'\\' => s.push('\\'),
                        b'/' => s.push('/'),
                        b'n' => s.push('\n'),
                        b't' => s.push('\t'),
                        b'r' => s.push('\r'),
                        b'b' => s.push('\u{8}'),
                        b'f' => s.push('\u{c}'),
                        b'u' => {
                            if self.pos + 4 > self.bytes.len() {
                                return Err(Error::Config("truncated \\u escape".into()));
                            }
                            let hex =
                                std::str::from_utf8(&self.bytes[self.pos..self.pos + 4])
                                    .map_err(|_| Error::Config("bad \\u escape".into()))?;
                            let code = u32::from_str_radix(hex, 16)
                                .map_err(|_| Error::Config("bad \\u escape".into()))?;
                            s.push(char::from_u32(code).unwrap_or('\u{fffd}'));
                            self.pos += 4;
                        }
                        other => {
                            return Err(Error::Config(format!(
                                "bad escape '\\{}'",
                                other as char
                            )))
                        }
                    }
                }
                _ => {
                    // collect a full utf-8 sequence
                    let start = self.pos - 1;
                    let len = utf8_len(c);
                    self.pos = start + len;
                    if self.pos > self.bytes.len() {
                        return Err(Error::Config("truncated utf-8 in string".into()));
                    }
                    s.push_str(
                        std::str::from_utf8(&self.bytes[start..self.pos])
                            .map_err(|_| Error::Config("invalid utf-8 in string".into()))?,
                    );
                }
            }
        }
    }

    fn number(&mut self) -> Result<Json> {
        let start = self.pos;
        while self.pos < self.bytes.len()
            && matches!(self.bytes[self.pos], b'0'..=b'9' | b'-' | b'+' | b'.' | b'e' | b'E')
        {
            self.pos += 1;
        }
        let text = std::str::from_utf8(&self.bytes[start..self.pos])
            .map_err(|_| Error::Config("bad number".into()))?;
        text.parse::<f64>()
            .map(Json::Num)
            .map_err(|e| Error::Config(format!("bad JSON number '{text}': {e}")))
    }
}

fn utf8_len(first: u8) -> usize {
    match first {
        0x00..=0x7f => 1,
        0xc0..=0xdf => 2,
        0xe0..=0xef => 3,
        _ => 4,
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn compact_form_is_one_line_and_round_trips() {
        let j = Json::obj(vec![
            ("run_id", Json::str("soak-e0[3]")),
            ("state", Json::str("completed")),
            ("attempts", Json::num(2.0)),
            ("degraded", Json::Bool(false)),
            ("extra", Json::arr(vec![Json::Null, Json::num(1.5)])),
        ]);
        let line = j.to_compact_string();
        assert!(!line.contains('\n'), "JSONL record must be one line: {line}");
        assert_eq!(Json::parse(&line).unwrap(), j);
        assert_eq!(
            line,
            r#"{"attempts":2,"degraded":false,"extra":[null,1.5],"run_id":"soak-e0[3]","state":"completed"}"#
        );
    }

    #[test]
    fn parses_manifest_shaped_json() {
        let text = r#"{
          "format": "hlo-text",
          "dt": 0.1,
          "buckets": [16, 64, 256],
          "entries": {"step_16": {"file": "step_16.hlo.txt", "n": 16, "outputs": 4}}
        }"#;
        let j = Json::parse(text).unwrap();
        assert_eq!(j.get("format").unwrap().as_str().unwrap(), "hlo-text");
        assert_eq!(j.get("dt").unwrap().as_f64().unwrap(), 0.1);
        let buckets = j.get("buckets").unwrap().as_arr().unwrap();
        assert_eq!(buckets.len(), 3);
        assert_eq!(buckets[2].as_usize().unwrap(), 256);
        let e = j.get("entries").unwrap().get("step_16").unwrap();
        assert_eq!(e.get("outputs").unwrap().as_usize().unwrap(), 4);
    }

    #[test]
    fn parses_scalars_and_escapes() {
        assert_eq!(Json::parse("true").unwrap(), Json::Bool(true));
        assert_eq!(Json::parse("null").unwrap(), Json::Null);
        assert_eq!(Json::parse("-1.5e2").unwrap(), Json::Num(-150.0));
        assert_eq!(
            Json::parse(r#""a\nb\"cA""#).unwrap(),
            Json::Str("a\nb\"cA".into())
        );
        assert_eq!(Json::parse("[]").unwrap(), Json::Arr(vec![]));
        assert_eq!(Json::parse("{}").unwrap(), Json::Obj(Default::default()));
    }

    #[test]
    fn rejects_garbage() {
        assert!(Json::parse("").is_err());
        assert!(Json::parse("{").is_err());
        assert!(Json::parse("[1,]").is_err());
        assert!(Json::parse("{\"a\" 1}").is_err());
        assert!(Json::parse("1 2").is_err());
        assert!(Json::parse("tru").is_err());
    }

    #[test]
    fn pretty_round_trips() {
        let text = r#"{
          "bench": "runtime_hotpath",
          "runs": [{"label": "pre", "results": [{"name": "a/b=1", "ns": 1250.5}]}],
          "n": 3, "neg": -1.5, "esc": "a\"b\nc", "flag": true, "none": null,
          "empty_arr": [], "empty_obj": {}
        }"#;
        let j = Json::parse(text).unwrap();
        let pretty = j.to_pretty_string();
        assert_eq!(Json::parse(&pretty).unwrap(), j);
        // integers stay integers
        assert!(pretty.contains("\"n\": 3"), "{pretty}");
    }

    #[test]
    fn builders_compose() {
        let j = Json::obj(vec![
            ("name", Json::str("merge")),
            ("n", Json::num(3.0)),
            ("axes", Json::arr(vec![Json::str("demand"), Json::num(0.5)])),
        ]);
        let text = j.to_pretty_string();
        assert_eq!(Json::parse(&text).unwrap(), j);
        assert_eq!(j.get("name").unwrap().as_str().unwrap(), "merge");
        assert_eq!(j.get("axes").unwrap().as_arr().unwrap().len(), 2);
    }

    #[test]
    fn accessor_errors() {
        let j = Json::parse(r#"{"a": 1}"#).unwrap();
        assert!(j.get("b").is_err());
        assert!(j.get("a").unwrap().as_str().is_err());
        assert!(Json::Num(1.0).get("x").is_err());
    }
}
