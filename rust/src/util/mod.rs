//! Dependency-free utilities: a deterministic PRNG, a minimal JSON
//! parser, a test tempdir helper, the loom-checkable sync facade
//! ([`sync`]) and the shared get-or-insert cache ([`cache`]).
//!
//! This repo builds fully offline against a vendored crate set that has
//! no `rand`/`serde_json`/`tempfile`; these small, tested replacements
//! cover the three needs (seeded randomization for duarouter/workloads,
//! the artifact manifest, and filesystem tests).

pub mod cache;
pub mod json;
pub mod rng;
pub mod sync;
pub mod tmp;

pub use cache::SharedCache;
pub use json::Json;
pub use rng::Rng64;
pub use tmp::TempDir;
