//! Dependency-free utilities: a deterministic PRNG, a minimal JSON
//! parser, and a test tempdir helper.
//!
//! This repo builds fully offline against a vendored crate set that has
//! no `rand`/`serde_json`/`tempfile`; these small, tested replacements
//! cover the three needs (seeded randomization for duarouter/workloads,
//! the artifact manifest, and filesystem tests).

pub mod json;
pub mod rng;
pub mod tmp;

pub use json::Json;
pub use rng::Rng64;
pub use tmp::TempDir;
