//! The structured run-lifecycle event model.
//!
//! One [`Event`] per interesting transition, stamped with a monotonic
//! microsecond timestamp ([`crate::telemetry::now_us`]) and serialized
//! as one compact JSON object per line — the same JSONL discipline as
//! the campaign ledger, so the stream survives torn tails and replays
//! deterministically.  Events are emitted at *dispatch* granularity,
//! never inside the per-step inner loop (the ≤ 2% hot-path overhead
//! bar of ISSUE 7).

use crate::util::Json;
use crate::{Error, Result};

/// A timestamped telemetry event.
#[derive(Debug, Clone, PartialEq)]
pub struct Event {
    /// Microseconds since the process telemetry epoch (monotonic).
    pub t_us: u64,
    pub kind: EventKind,
}

/// Everything the pipeline reports about itself.
///
/// Naming: `*Begin`/`*End` pairs become Chrome-trace spans; the rest
/// become instant markers.  `DispatchEnd` carries its own `dur_us` so
/// consumers never need to pair it with the matching `DispatchBegin`
/// (the engine thread is serial, but the stream may be truncated).
#[derive(Debug, Clone, PartialEq)]
pub enum EventKind {
    CampaignBegin {
        name: String,
        nodes: u64,
        slots_per_node: u64,
        epochs: u64,
        runs: u64,
    },
    CampaignEnd {
        name: String,
        completed: u64,
        failed: u64,
    },
    RunBegin {
        run_id: String,
        epoch: u64,
        slot: u64,
        node: u64,
    },
    RunEnd {
        run_id: String,
        ok: bool,
        attempts: u64,
        degraded: bool,
    },
    AttemptBegin {
        run_id: String,
        attempt: u64,
        engine: String,
    },
    AttemptEnd {
        run_id: String,
        attempt: u64,
        ok: bool,
    },
    Retry {
        run_id: String,
        attempt: u64,
        class: String,
        error: String,
        backoff_ms: u64,
    },
    Degraded {
        run_id: String,
        attempt: u64,
        error: String,
    },
    WatchdogFire {
        run_id: String,
        kind: String,
        detail: String,
    },
    LedgerTransition {
        run_id: String,
        state: String,
    },
    SlotBegin {
        node: u64,
        slot: u64,
        run_id: String,
    },
    SlotEnd {
        node: u64,
        slot: u64,
        run_id: String,
        ok: bool,
    },
    DispatchBegin {
        kind: String,
        bucket: u64,
        k: u64,
        batch: u64,
    },
    DispatchEnd {
        kind: String,
        bucket: u64,
        k: u64,
        batch: u64,
        dur_us: u64,
    },
    Coalesced {
        kind: String,
        bucket: u64,
        k: u64,
        batch: u64,
    },
    SerialFallback {
        kind: String,
        bucket: u64,
        k: u64,
        batch: u64,
        error: String,
    },
    PoolDelta {
        run_id: String,
        hits: u64,
        misses: u64,
        compiled: u64,
    },
    /// A worker passed the fabric handshake and joined the pool.
    WorkerJoin {
        worker: String,
    },
    /// A worker's connection ended (graceful drain, crash, or torn
    /// frame — `reason` says which).
    WorkerLeave {
        worker: String,
        reason: String,
    },
    /// The coordinator leased `(epoch, slot)` to a worker.
    LeaseGrant {
        run_id: String,
        worker: String,
        lease: u64,
        attempt: u64,
    },
    /// The reaper revoked a lease whose heartbeat deadline passed; the
    /// slot goes back on the queue.
    LeaseExpired {
        run_id: String,
        worker: String,
        lease: u64,
    },
    /// A completion arrived for a run the ledger already settled (a
    /// zombie worker's late report or a duplicated frame) — rejected
    /// idempotently.
    CompletionRejected {
        run_id: String,
        worker: String,
    },
}

impl EventKind {
    /// The `"ev"` tag this kind serializes under.
    pub fn tag(&self) -> &'static str {
        match self {
            EventKind::CampaignBegin { .. } => "campaign_begin",
            EventKind::CampaignEnd { .. } => "campaign_end",
            EventKind::RunBegin { .. } => "run_begin",
            EventKind::RunEnd { .. } => "run_end",
            EventKind::AttemptBegin { .. } => "attempt_begin",
            EventKind::AttemptEnd { .. } => "attempt_end",
            EventKind::Retry { .. } => "retry",
            EventKind::Degraded { .. } => "degraded",
            EventKind::WatchdogFire { .. } => "watchdog_fire",
            EventKind::LedgerTransition { .. } => "ledger_transition",
            EventKind::SlotBegin { .. } => "slot_begin",
            EventKind::SlotEnd { .. } => "slot_end",
            EventKind::DispatchBegin { .. } => "dispatch_begin",
            EventKind::DispatchEnd { .. } => "dispatch_end",
            EventKind::Coalesced { .. } => "coalesced",
            EventKind::SerialFallback { .. } => "serial_fallback",
            EventKind::PoolDelta { .. } => "pool_delta",
            EventKind::WorkerJoin { .. } => "worker_join",
            EventKind::WorkerLeave { .. } => "worker_leave",
            EventKind::LeaseGrant { .. } => "lease_grant",
            EventKind::LeaseExpired { .. } => "lease_expired",
            EventKind::CompletionRejected { .. } => "completion_rejected",
        }
    }
}

fn num(n: u64) -> Json {
    Json::num(n as f64)
}

impl Event {
    /// One compact JSON object: `{"ev": <tag>, "t_us": N, ...fields}`.
    pub fn to_json(&self) -> Json {
        let mut pairs: Vec<(&str, Json)> = vec![
            ("t_us", num(self.t_us)),
            ("ev", Json::str(self.kind.tag())),
        ];
        match &self.kind {
            EventKind::CampaignBegin {
                name,
                nodes,
                slots_per_node,
                epochs,
                runs,
            } => {
                pairs.push(("name", Json::str(name.clone())));
                pairs.push(("nodes", num(*nodes)));
                pairs.push(("slots_per_node", num(*slots_per_node)));
                pairs.push(("epochs", num(*epochs)));
                pairs.push(("runs", num(*runs)));
            }
            EventKind::CampaignEnd {
                name,
                completed,
                failed,
            } => {
                pairs.push(("name", Json::str(name.clone())));
                pairs.push(("completed", num(*completed)));
                pairs.push(("failed", num(*failed)));
            }
            EventKind::RunBegin {
                run_id,
                epoch,
                slot,
                node,
            } => {
                pairs.push(("run_id", Json::str(run_id.clone())));
                pairs.push(("epoch", num(*epoch)));
                pairs.push(("slot", num(*slot)));
                pairs.push(("node", num(*node)));
            }
            EventKind::RunEnd {
                run_id,
                ok,
                attempts,
                degraded,
            } => {
                pairs.push(("run_id", Json::str(run_id.clone())));
                pairs.push(("ok", Json::Bool(*ok)));
                pairs.push(("attempts", num(*attempts)));
                pairs.push(("degraded", Json::Bool(*degraded)));
            }
            EventKind::AttemptBegin {
                run_id,
                attempt,
                engine,
            } => {
                pairs.push(("run_id", Json::str(run_id.clone())));
                pairs.push(("attempt", num(*attempt)));
                pairs.push(("engine", Json::str(engine.clone())));
            }
            EventKind::AttemptEnd {
                run_id,
                attempt,
                ok,
            } => {
                pairs.push(("run_id", Json::str(run_id.clone())));
                pairs.push(("attempt", num(*attempt)));
                pairs.push(("ok", Json::Bool(*ok)));
            }
            EventKind::Retry {
                run_id,
                attempt,
                class,
                error,
                backoff_ms,
            } => {
                pairs.push(("run_id", Json::str(run_id.clone())));
                pairs.push(("attempt", num(*attempt)));
                pairs.push(("class", Json::str(class.clone())));
                pairs.push(("error", Json::str(error.clone())));
                pairs.push(("backoff_ms", num(*backoff_ms)));
            }
            EventKind::Degraded {
                run_id,
                attempt,
                error,
            } => {
                pairs.push(("run_id", Json::str(run_id.clone())));
                pairs.push(("attempt", num(*attempt)));
                pairs.push(("error", Json::str(error.clone())));
            }
            EventKind::WatchdogFire {
                run_id,
                kind,
                detail,
            } => {
                pairs.push(("run_id", Json::str(run_id.clone())));
                pairs.push(("kind", Json::str(kind.clone())));
                pairs.push(("detail", Json::str(detail.clone())));
            }
            EventKind::LedgerTransition { run_id, state } => {
                pairs.push(("run_id", Json::str(run_id.clone())));
                pairs.push(("state", Json::str(state.clone())));
            }
            EventKind::SlotBegin { node, slot, run_id } => {
                pairs.push(("node", num(*node)));
                pairs.push(("slot", num(*slot)));
                pairs.push(("run_id", Json::str(run_id.clone())));
            }
            EventKind::SlotEnd {
                node,
                slot,
                run_id,
                ok,
            } => {
                pairs.push(("node", num(*node)));
                pairs.push(("slot", num(*slot)));
                pairs.push(("run_id", Json::str(run_id.clone())));
                pairs.push(("ok", Json::Bool(*ok)));
            }
            EventKind::DispatchBegin {
                kind,
                bucket,
                k,
                batch,
            } => {
                pairs.push(("kind", Json::str(kind.clone())));
                pairs.push(("bucket", num(*bucket)));
                pairs.push(("k", num(*k)));
                pairs.push(("batch", num(*batch)));
            }
            EventKind::DispatchEnd {
                kind,
                bucket,
                k,
                batch,
                dur_us,
            } => {
                pairs.push(("kind", Json::str(kind.clone())));
                pairs.push(("bucket", num(*bucket)));
                pairs.push(("k", num(*k)));
                pairs.push(("batch", num(*batch)));
                pairs.push(("dur_us", num(*dur_us)));
            }
            EventKind::Coalesced {
                kind,
                bucket,
                k,
                batch,
            } => {
                pairs.push(("kind", Json::str(kind.clone())));
                pairs.push(("bucket", num(*bucket)));
                pairs.push(("k", num(*k)));
                pairs.push(("batch", num(*batch)));
            }
            EventKind::SerialFallback {
                kind,
                bucket,
                k,
                batch,
                error,
            } => {
                pairs.push(("kind", Json::str(kind.clone())));
                pairs.push(("bucket", num(*bucket)));
                pairs.push(("k", num(*k)));
                pairs.push(("batch", num(*batch)));
                pairs.push(("error", Json::str(error.clone())));
            }
            EventKind::PoolDelta {
                run_id,
                hits,
                misses,
                compiled,
            } => {
                pairs.push(("run_id", Json::str(run_id.clone())));
                pairs.push(("hits", num(*hits)));
                pairs.push(("misses", num(*misses)));
                pairs.push(("compiled", num(*compiled)));
            }
            EventKind::WorkerJoin { worker } => {
                pairs.push(("worker", Json::str(worker.clone())));
            }
            EventKind::WorkerLeave { worker, reason } => {
                pairs.push(("worker", Json::str(worker.clone())));
                pairs.push(("reason", Json::str(reason.clone())));
            }
            EventKind::LeaseGrant {
                run_id,
                worker,
                lease,
                attempt,
            } => {
                pairs.push(("run_id", Json::str(run_id.clone())));
                pairs.push(("worker", Json::str(worker.clone())));
                pairs.push(("lease", num(*lease)));
                pairs.push(("attempt", num(*attempt)));
            }
            EventKind::LeaseExpired {
                run_id,
                worker,
                lease,
            } => {
                pairs.push(("run_id", Json::str(run_id.clone())));
                pairs.push(("worker", Json::str(worker.clone())));
                pairs.push(("lease", num(*lease)));
            }
            EventKind::CompletionRejected { run_id, worker } => {
                pairs.push(("run_id", Json::str(run_id.clone())));
                pairs.push(("worker", Json::str(worker.clone())));
            }
        }
        Json::obj(pairs)
    }

    /// Inverse of [`Event::to_json`] — rejects unknown tags and missing
    /// fields (a mid-file garbage line must fail loudly; only the final
    /// torn line is forgiven, by [`crate::telemetry::read_events`]).
    pub fn from_json(j: &Json) -> Result<Event> {
        let t_us = get_u64(j, "t_us")?;
        let tag = j.get("ev")?.as_str()?.to_string();
        let kind = match tag.as_str() {
            "campaign_begin" => EventKind::CampaignBegin {
                name: get_str(j, "name")?,
                nodes: get_u64(j, "nodes")?,
                slots_per_node: get_u64(j, "slots_per_node")?,
                epochs: get_u64(j, "epochs")?,
                runs: get_u64(j, "runs")?,
            },
            "campaign_end" => EventKind::CampaignEnd {
                name: get_str(j, "name")?,
                completed: get_u64(j, "completed")?,
                failed: get_u64(j, "failed")?,
            },
            "run_begin" => EventKind::RunBegin {
                run_id: get_str(j, "run_id")?,
                epoch: get_u64(j, "epoch")?,
                slot: get_u64(j, "slot")?,
                node: get_u64(j, "node")?,
            },
            "run_end" => EventKind::RunEnd {
                run_id: get_str(j, "run_id")?,
                ok: get_bool(j, "ok")?,
                attempts: get_u64(j, "attempts")?,
                degraded: get_bool(j, "degraded")?,
            },
            "attempt_begin" => EventKind::AttemptBegin {
                run_id: get_str(j, "run_id")?,
                attempt: get_u64(j, "attempt")?,
                engine: get_str(j, "engine")?,
            },
            "attempt_end" => EventKind::AttemptEnd {
                run_id: get_str(j, "run_id")?,
                attempt: get_u64(j, "attempt")?,
                ok: get_bool(j, "ok")?,
            },
            "retry" => EventKind::Retry {
                run_id: get_str(j, "run_id")?,
                attempt: get_u64(j, "attempt")?,
                class: get_str(j, "class")?,
                error: get_str(j, "error")?,
                backoff_ms: get_u64(j, "backoff_ms")?,
            },
            "degraded" => EventKind::Degraded {
                run_id: get_str(j, "run_id")?,
                attempt: get_u64(j, "attempt")?,
                error: get_str(j, "error")?,
            },
            "watchdog_fire" => EventKind::WatchdogFire {
                run_id: get_str(j, "run_id")?,
                kind: get_str(j, "kind")?,
                detail: get_str(j, "detail")?,
            },
            "ledger_transition" => EventKind::LedgerTransition {
                run_id: get_str(j, "run_id")?,
                state: get_str(j, "state")?,
            },
            "slot_begin" => EventKind::SlotBegin {
                node: get_u64(j, "node")?,
                slot: get_u64(j, "slot")?,
                run_id: get_str(j, "run_id")?,
            },
            "slot_end" => EventKind::SlotEnd {
                node: get_u64(j, "node")?,
                slot: get_u64(j, "slot")?,
                run_id: get_str(j, "run_id")?,
                ok: get_bool(j, "ok")?,
            },
            "dispatch_begin" => EventKind::DispatchBegin {
                kind: get_str(j, "kind")?,
                bucket: get_u64(j, "bucket")?,
                k: get_u64(j, "k")?,
                batch: get_u64(j, "batch")?,
            },
            "dispatch_end" => EventKind::DispatchEnd {
                kind: get_str(j, "kind")?,
                bucket: get_u64(j, "bucket")?,
                k: get_u64(j, "k")?,
                batch: get_u64(j, "batch")?,
                dur_us: get_u64(j, "dur_us")?,
            },
            "coalesced" => EventKind::Coalesced {
                kind: get_str(j, "kind")?,
                bucket: get_u64(j, "bucket")?,
                k: get_u64(j, "k")?,
                batch: get_u64(j, "batch")?,
            },
            "serial_fallback" => EventKind::SerialFallback {
                kind: get_str(j, "kind")?,
                bucket: get_u64(j, "bucket")?,
                k: get_u64(j, "k")?,
                batch: get_u64(j, "batch")?,
                error: get_str(j, "error")?,
            },
            "pool_delta" => EventKind::PoolDelta {
                run_id: get_str(j, "run_id")?,
                hits: get_u64(j, "hits")?,
                misses: get_u64(j, "misses")?,
                compiled: get_u64(j, "compiled")?,
            },
            "worker_join" => EventKind::WorkerJoin {
                worker: get_str(j, "worker")?,
            },
            "worker_leave" => EventKind::WorkerLeave {
                worker: get_str(j, "worker")?,
                reason: get_str(j, "reason")?,
            },
            "lease_grant" => EventKind::LeaseGrant {
                run_id: get_str(j, "run_id")?,
                worker: get_str(j, "worker")?,
                lease: get_u64(j, "lease")?,
                attempt: get_u64(j, "attempt")?,
            },
            "lease_expired" => EventKind::LeaseExpired {
                run_id: get_str(j, "run_id")?,
                worker: get_str(j, "worker")?,
                lease: get_u64(j, "lease")?,
            },
            "completion_rejected" => EventKind::CompletionRejected {
                run_id: get_str(j, "run_id")?,
                worker: get_str(j, "worker")?,
            },
            other => {
                return Err(Error::Config(format!("unknown telemetry event '{other}'")));
            }
        };
        Ok(Event { t_us, kind })
    }
}

fn get_str(j: &Json, key: &str) -> Result<String> {
    Ok(j.get(key)?.as_str()?.to_string())
}

fn get_u64(j: &Json, key: &str) -> Result<u64> {
    Ok(j.get(key)?.as_f64()? as u64)
}

fn get_bool(j: &Json, key: &str) -> Result<bool> {
    match j.get(key)? {
        Json::Bool(b) => Ok(*b),
        other => Err(Error::Config(format!(
            "expected bool for '{key}', got {other:?}"
        ))),
    }
}

#[cfg(test)]
#[allow(clippy::unwrap_used, clippy::expect_used)]
mod tests {
    use super::*;

    fn round_trip(kind: EventKind) {
        let ev = Event { t_us: 42, kind };
        let j = ev.to_json();
        let line = j.to_compact_string();
        assert!(!line.contains('\n'), "one line per event: {line}");
        let back = Event::from_json(&Json::parse(&line).unwrap()).unwrap();
        assert_eq!(back, ev);
    }

    #[test]
    fn every_event_kind_round_trips() {
        round_trip(EventKind::CampaignBegin {
            name: "soak".into(),
            nodes: 2,
            slots_per_node: 4,
            epochs: 1,
            runs: 8,
        });
        round_trip(EventKind::CampaignEnd {
            name: "soak".into(),
            completed: 8,
            failed: 0,
        });
        round_trip(EventKind::RunBegin {
            run_id: "soak-e0[3]".into(),
            epoch: 0,
            slot: 3,
            node: 0,
        });
        round_trip(EventKind::RunEnd {
            run_id: "soak-e0[3]".into(),
            ok: true,
            attempts: 2,
            degraded: false,
        });
        round_trip(EventKind::AttemptBegin {
            run_id: "soak-e0[3]".into(),
            attempt: 1,
            engine: "hlo".into(),
        });
        round_trip(EventKind::AttemptEnd {
            run_id: "soak-e0[3]".into(),
            attempt: 1,
            ok: false,
        });
        round_trip(EventKind::Retry {
            run_id: "soak-e0[3]".into(),
            attempt: 1,
            class: "transient".into(),
            error: "duarouter failed: exit 1".into(),
            backoff_ms: 250,
        });
        round_trip(EventKind::Degraded {
            run_id: "soak-e0[3]".into(),
            attempt: 1,
            error: "runtime (PJRT) error: injected".into(),
        });
        round_trip(EventKind::WatchdogFire {
            run_id: "soak-e0[3]".into(),
            kind: "walltime".into(),
            detail: "120s".into(),
        });
        round_trip(EventKind::LedgerTransition {
            run_id: "soak-e0[3]".into(),
            state: "completed".into(),
        });
        round_trip(EventKind::SlotBegin {
            node: 0,
            slot: 3,
            run_id: "soak-e0[3]".into(),
        });
        round_trip(EventKind::SlotEnd {
            node: 0,
            slot: 3,
            run_id: "soak-e0[3]".into(),
            ok: true,
        });
        round_trip(EventKind::DispatchBegin {
            kind: "rollout".into(),
            bucket: 64,
            k: 32,
            batch: 2,
        });
        round_trip(EventKind::DispatchEnd {
            kind: "rollout".into(),
            bucket: 64,
            k: 32,
            batch: 2,
            dur_us: 1730,
        });
        round_trip(EventKind::Coalesced {
            kind: "step".into(),
            bucket: 16,
            k: 0,
            batch: 4,
        });
        round_trip(EventKind::SerialFallback {
            kind: "step".into(),
            bucket: 16,
            k: 0,
            batch: 4,
            error: "bad literal".into(),
        });
        round_trip(EventKind::PoolDelta {
            run_id: "soak-e0[3]".into(),
            hits: 120,
            misses: 2,
            compiled: 5,
        });
        round_trip(EventKind::WorkerJoin {
            worker: "w1#3".into(),
        });
        round_trip(EventKind::WorkerLeave {
            worker: "w1#3".into(),
            reason: "connection lost".into(),
        });
        round_trip(EventKind::LeaseGrant {
            run_id: "soak-e0[3]".into(),
            worker: "w1#3".into(),
            lease: 17,
            attempt: 1,
        });
        round_trip(EventKind::LeaseExpired {
            run_id: "soak-e0[3]".into(),
            worker: "w1#3".into(),
            lease: 17,
        });
        round_trip(EventKind::CompletionRejected {
            run_id: "soak-e0[3]".into(),
            worker: "w2#1".into(),
        });
    }

    #[test]
    fn unknown_tag_and_missing_field_are_rejected() {
        let j = Json::parse(r#"{"ev":"warp_core_breach","t_us":1}"#).unwrap();
        assert!(Event::from_json(&j).is_err());
        let j = Json::parse(r#"{"ev":"retry","t_us":1,"run_id":"x"}"#).unwrap();
        assert!(Event::from_json(&j).is_err());
        let j = Json::parse(r#"{"ev":"run_end","t_us":1,"run_id":"x","ok":1,"attempts":1,"degraded":false}"#)
            .unwrap();
        assert!(Event::from_json(&j).is_err(), "ok must be a real bool");
    }
}
