//! Pluggable event sinks and the process-global emit path.
//!
//! `emit()` is always compiled in ("always-on observability"), but
//! costs a single relaxed atomic load while no sink is installed —
//! cheap enough to leave in the engine-service dispatch path (the
//! per-*step* inner loop is never instrumented at all).
//!
//! The JSONL sink is buffered and does **not** fsync per event: unlike
//! the ledger (whose records are the source of truth for resume),
//! telemetry tolerates losing a tail on a crash — the reader applies
//! the same torn-final-line forgiveness the ledger replay does.

use std::fs::{File, OpenOptions};
use std::io::{BufWriter, Write};
use std::path::{Path, PathBuf};
use std::sync::atomic::{AtomicBool, Ordering};
use std::sync::{Arc, Mutex, OnceLock, RwLock};

use super::events::{Event, EventKind};
use super::now_us;
use crate::util::Json;
use crate::{Error, Result};

/// Where events go.  Implementations must be cheap and non-blocking in
/// spirit — `emit` runs on the engine-service thread.
pub trait EventSink: Send + Sync {
    fn emit(&self, ev: &Event);
    /// Push buffered events to durable storage (end of campaign / test
    /// assertion points — not per event).
    fn flush(&self);
}

static ACTIVE: AtomicBool = AtomicBool::new(false);

/// The registry holds an `Arc` snapshot of the sink list so readers
/// can clone it out and fan events out with the lock RELEASED: a slow
/// sink flush must never stall `install`/`uninstall` or other emitters
/// on the registry lock (lock-discipline lint, `telemetry/sink.rs`).
fn sinks() -> &'static RwLock<Arc<Vec<Arc<dyn EventSink>>>> {
    static SINKS: OnceLock<RwLock<Arc<Vec<Arc<dyn EventSink>>>>> = OnceLock::new();
    SINKS.get_or_init(|| RwLock::new(Arc::new(Vec::new())))
}

/// Snapshot the installed sinks — one Arc bump, no allocation; the
/// caller iterates with no registry guard live.
fn installed() -> Arc<Vec<Arc<dyn EventSink>>> {
    sinks().read().unwrap_or_else(|e| e.into_inner()).clone()
}

fn with_sinks<R>(f: impl FnOnce(&mut Vec<Arc<dyn EventSink>>) -> R) -> R {
    let mut guard = sinks().write().unwrap_or_else(|e| e.into_inner());
    let mut v = (**guard).clone();
    let r = f(&mut v);
    *guard = Arc::new(v);
    r
}

/// Install a sink; `emit` fans out to every installed sink.
pub fn install(sink: Arc<dyn EventSink>) {
    with_sinks(|v| {
        v.push(sink);
        ACTIVE.store(true, Ordering::Relaxed);
    });
}

/// Remove a previously installed sink (pointer identity).  Flushes it
/// on the way out.
pub fn uninstall(sink: &Arc<dyn EventSink>) {
    sink.flush();
    with_sinks(|v| {
        v.retain(|s| !Arc::ptr_eq(s, sink));
        ACTIVE.store(!v.is_empty(), Ordering::Relaxed);
    });
}

/// True when at least one sink is installed — the bench toggle.
pub fn enabled() -> bool {
    ACTIVE.load(Ordering::Relaxed)
}

/// Stamp `kind` with the monotonic clock and fan it out.  One relaxed
/// atomic load when disabled.
pub fn emit(kind: EventKind) {
    if !ACTIVE.load(Ordering::Relaxed) {
        return;
    }
    let ev = Event {
        t_us: now_us(),
        kind,
    };
    for s in installed().iter() {
        s.emit(&ev);
    }
}

/// Flush every installed sink (campaign end, CLI exit).
pub fn flush_all() {
    for s in installed().iter() {
        s.flush();
    }
}

/// Buffered JSONL sink — one compact object per line, appended so a
/// resumed campaign extends the same stream its ledger extends.
pub struct JsonlSink {
    path: PathBuf,
    file: Mutex<BufWriter<File>>,
}

impl JsonlSink {
    /// Open (append) the stream at `path`, creating parents as needed.
    pub fn append(path: impl Into<PathBuf>) -> Result<JsonlSink> {
        let path = path.into();
        if let Some(parent) = path.parent() {
            if !parent.as_os_str().is_empty() {
                std::fs::create_dir_all(parent)?;
            }
        }
        let file = OpenOptions::new().create(true).append(true).open(&path)?;
        Ok(JsonlSink {
            path,
            file: Mutex::new(BufWriter::new(file)),
        })
    }

    pub fn path(&self) -> &Path {
        &self.path
    }
}

impl EventSink for JsonlSink {
    fn emit(&self, ev: &Event) {
        let mut f = self.file.lock().unwrap_or_else(|e| e.into_inner());
        // an I/O error on a telemetry line must not fail the campaign;
        // the stream just loses a record
        let _ = writeln!(f, "{}", ev.to_json().to_compact_string());
    }

    fn flush(&self) {
        let mut f = self.file.lock().unwrap_or_else(|e| e.into_inner());
        let _ = f.flush();
        let _ = f.get_ref().sync_data();
    }
}

impl Drop for JsonlSink {
    fn drop(&mut self) {
        self.flush();
    }
}

/// In-memory sink for tests.
#[derive(Default)]
pub struct MemorySink {
    events: Mutex<Vec<Event>>,
}

impl MemorySink {
    pub fn new() -> Arc<MemorySink> {
        Arc::new(MemorySink::default())
    }

    pub fn snapshot(&self) -> Vec<Event> {
        self.events
            .lock()
            .unwrap_or_else(|e| e.into_inner())
            .clone()
    }

    pub fn take(&self) -> Vec<Event> {
        std::mem::take(&mut self.events.lock().unwrap_or_else(|e| e.into_inner()))
    }
}

impl EventSink for MemorySink {
    fn emit(&self, ev: &Event) {
        self.events
            .lock()
            .unwrap_or_else(|e| e.into_inner())
            .push(ev.clone());
    }

    fn flush(&self) {}
}

/// Read an event stream back, forgiving exactly one torn final line
/// (the crash-mid-append case).  A malformed line anywhere *else* is a
/// hard error — same policy as the ledger replay.
pub fn read_events(path: impl AsRef<Path>) -> Result<Vec<Event>> {
    let text = std::fs::read_to_string(path.as_ref())?;
    let lines: Vec<&str> = text.lines().filter(|l| !l.trim().is_empty()).collect();
    let mut events = Vec::with_capacity(lines.len());
    for (i, line) in lines.iter().enumerate() {
        let last = i + 1 == lines.len();
        match Json::parse(line).and_then(|j| Event::from_json(&j)) {
            Ok(ev) => events.push(ev),
            Err(_) if last => break, // torn tail: the crash ate the newline
            Err(e) => {
                return Err(Error::Config(format!(
                    "{}:{}: bad telemetry record: {e}",
                    path.as_ref().display(),
                    i + 1
                )));
            }
        }
    }
    Ok(events)
}

/// Merge per-worker event shards into one timestamp-ordered stream.
///
/// A fabric campaign writes one `events-*.jsonl` per worker connection
/// plus the coordinator's own stream; `webots-hpc report` hands them
/// all here.  Each shard gets [`read_events`]' torn-tail forgiveness
/// independently; the merge is ordered by `t_us` (ties keep shard
/// order, stably) and exact duplicate records — a retransmitted frame
/// landing in two shards — collapse to one.
pub fn merge_event_shards(paths: &[impl AsRef<Path>]) -> Result<Vec<Event>> {
    let mut merged: Vec<Event> = Vec::new();
    for path in paths {
        merged.extend(read_events(path)?);
    }
    merged.sort_by_key(|e| e.t_us);
    let mut seen = std::collections::BTreeSet::new();
    merged.retain(|e| seen.insert(e.to_json().to_compact_string()));
    Ok(merged)
}

#[cfg(test)]
#[allow(clippy::unwrap_used, clippy::expect_used)]
mod tests {
    use super::*;
    use crate::util::TempDir;

    fn ev(t_us: u64, run_id: &str, state: &str) -> Event {
        Event {
            t_us,
            kind: EventKind::LedgerTransition {
                run_id: run_id.into(),
                state: state.into(),
            },
        }
    }

    #[test]
    fn jsonl_sink_round_trips_and_appends() {
        let dir = TempDir::new("telemetry-sink").unwrap();
        let path = dir.path().join("events.jsonl");
        {
            let sink = JsonlSink::append(&path).unwrap();
            sink.emit(&ev(1, "a", "running"));
            sink.emit(&ev(2, "a", "completed"));
        } // drop flushes
        {
            let sink = JsonlSink::append(&path).unwrap();
            sink.emit(&ev(3, "b", "running"));
            sink.flush();
        }
        let events = read_events(&path).unwrap();
        assert_eq!(events.len(), 3, "append mode extends the stream");
        assert_eq!(events[0], ev(1, "a", "running"));
        assert_eq!(events[2], ev(3, "b", "running"));
    }

    #[test]
    fn torn_tail_is_forgiven_but_mid_file_garbage_is_not() {
        let dir = TempDir::new("telemetry-torn").unwrap();
        let path = dir.path().join("events.jsonl");
        let sink = JsonlSink::append(&path).unwrap();
        sink.emit(&ev(1, "a", "running"));
        sink.emit(&ev(2, "a", "completed"));
        sink.flush();
        drop(sink);

        // a crash tears the final line mid-append
        {
            use std::io::Write;
            let mut f = OpenOptions::new().append(true).open(&path).unwrap();
            f.write_all(b"{\"ev\":\"ledger_transition\",\"run").unwrap();
        }
        let events = read_events(&path).unwrap();
        assert_eq!(events.len(), 2, "torn tail dropped, prefix intact");

        // but garbage *before* valid records refuses the whole stream
        let bad = dir.path().join("bad.jsonl");
        std::fs::write(
            &bad,
            format!(
                "{}\nnot json at all\n{}\n",
                ev(1, "a", "running").to_json().to_compact_string(),
                ev(2, "a", "completed").to_json().to_compact_string()
            ),
        )
        .unwrap();
        assert!(read_events(&bad).is_err());
    }

    #[test]
    fn shard_merge_orders_dedupes_and_forgives_torn_tails() {
        let dir = TempDir::new("telemetry-merge").unwrap();
        let a = dir.path().join("events-w1.jsonl");
        let b = dir.path().join("events-w2.jsonl");
        {
            let sink = JsonlSink::append(&a).unwrap();
            sink.emit(&ev(5, "x", "running"));
            sink.emit(&ev(9, "x", "completed"));
            // duplicate of a record shard b also carries
            sink.emit(&ev(7, "y", "running"));
        }
        {
            let sink = JsonlSink::append(&b).unwrap();
            sink.emit(&ev(7, "y", "running"));
            sink.emit(&ev(12, "y", "completed"));
        }
        // shard b gains a torn tail — forgiven per shard
        {
            use std::io::Write;
            let mut f = OpenOptions::new().append(true).open(&b).unwrap();
            f.write_all(b"{\"ev\":\"run_end\",\"t_us").unwrap();
        }
        let merged = merge_event_shards(&[&a, &b]).unwrap();
        let ts: Vec<u64> = merged.iter().map(|e| e.t_us).collect();
        assert_eq!(ts, vec![5, 7, 9, 12], "ordered, duplicate collapsed");
        assert_eq!(merged[1], ev(7, "y", "running"));
    }

    #[test]
    fn global_emit_reaches_installed_sinks_only_while_installed() {
        let mem = MemorySink::new();
        let marker = "telemetry-sink-test-install";
        emit(EventKind::LedgerTransition {
            run_id: marker.into(),
            state: "before".into(),
        });
        let sink: Arc<dyn EventSink> = mem.clone();
        install(sink.clone());
        assert!(enabled());
        emit(EventKind::LedgerTransition {
            run_id: marker.into(),
            state: "during".into(),
        });
        uninstall(&sink);
        emit(EventKind::LedgerTransition {
            run_id: marker.into(),
            state: "after".into(),
        });
        // other tests share the global sink list: filter to our marker
        let seen: Vec<Event> = mem
            .take()
            .into_iter()
            .filter(|e| matches!(&e.kind, EventKind::LedgerTransition { run_id, .. } if run_id == marker))
            .collect();
        assert_eq!(seen.len(), 1);
        assert!(matches!(
            &seen[0].kind,
            EventKind::LedgerTransition { state, .. } if state == "during"
        ));
    }

    #[test]
    fn timestamps_are_monotonic() {
        let a = now_us();
        let b = now_us();
        assert!(b >= a);
    }
}
