//! Event stream → Chrome / Perfetto trace-event JSON.
//!
//! Layout: one process row per node (`pid = node`), one thread lane
//! per slot (`tid = slot`); run and attempt spans nest on the slot
//! lane.  Engine dispatches render on a synthetic "engine" process
//! (`pid = 99`) with one lane per rollout depth (`tid = K`, step = 0).
//! Retries, watchdog kills, degradations and ledger transitions are
//! instant markers on the lane of the run they belong to.
//!
//! Timestamps are already microseconds (the trace-event unit), so the
//! conversion is arithmetic-free; `DispatchEnd` carries `dur_us`, so
//! no Begin/End pairing is needed for engine spans and a truncated
//! stream still converts.  Open the output at `chrome://tracing` or
//! <https://ui.perfetto.dev>.

use std::collections::BTreeMap;

use super::events::{Event, EventKind};
use crate::util::Json;

/// The synthetic pid engine-dispatch lanes render under.
pub const ENGINE_PID: u64 = 99;

fn num(n: u64) -> Json {
    Json::num(n as f64)
}

fn span(
    name: &str,
    cat: &str,
    ts: u64,
    dur: u64,
    pid: u64,
    tid: u64,
    args: Vec<(&str, Json)>,
) -> Json {
    Json::obj(vec![
        ("name", Json::str(name)),
        ("cat", Json::str(cat)),
        ("ph", Json::str("X")),
        ("ts", num(ts)),
        ("dur", num(dur)),
        ("pid", num(pid)),
        ("tid", num(tid)),
        ("args", Json::obj(args)),
    ])
}

fn instant(name: &str, cat: &str, ts: u64, pid: u64, tid: u64, args: Vec<(&str, Json)>) -> Json {
    Json::obj(vec![
        ("name", Json::str(name)),
        ("cat", Json::str(cat)),
        ("ph", Json::str("i")),
        ("s", Json::str("t")),
        ("ts", num(ts)),
        ("pid", num(pid)),
        ("tid", num(tid)),
        ("args", Json::obj(args)),
    ])
}

fn metadata(name: &str, pid: u64, tid: Option<u64>, label: String) -> Json {
    let mut pairs = vec![
        ("name", Json::str(name)),
        ("ph", Json::str("M")),
        ("pid", num(pid)),
        ("args", Json::obj(vec![("name", Json::str(label))])),
    ];
    if let Some(tid) = tid {
        pairs.push(("tid", num(tid)));
    }
    Json::obj(pairs)
}

/// Convert an event stream into a trace-event JSON document.
///
/// Unpaired `*Begin` events (a stream truncated mid-run) are dropped
/// rather than invented; everything that did pair converts.
pub fn to_chrome_trace(events: &[Event]) -> Json {
    // run_id → (node, slot, begin timestamp)
    let mut runs_open: BTreeMap<String, (u64, u64, u64)> = BTreeMap::new();
    // run_id → (node, slot): lane lookup for instants after RunEnd too
    let mut lanes: BTreeMap<String, (u64, u64)> = BTreeMap::new();
    // (run_id, attempt) → begin timestamp + engine label
    let mut attempts_open: BTreeMap<(String, u64), (u64, String)> = BTreeMap::new();

    let mut out: Vec<Json> = Vec::new();
    for ev in events {
        match &ev.kind {
            EventKind::RunBegin {
                run_id,
                slot,
                node,
                ..
            } => {
                runs_open.insert(run_id.clone(), (*node, *slot, ev.t_us));
                lanes.insert(run_id.clone(), (*node, *slot));
            }
            EventKind::RunEnd {
                run_id,
                ok,
                attempts,
                degraded,
            } => {
                if let Some((node, slot, t0)) = runs_open.remove(run_id) {
                    out.push(span(
                        run_id,
                        "run",
                        t0,
                        ev.t_us.saturating_sub(t0),
                        node,
                        slot,
                        vec![
                            ("ok", Json::Bool(*ok)),
                            ("attempts", num(*attempts)),
                            ("degraded", Json::Bool(*degraded)),
                        ],
                    ));
                }
            }
            EventKind::AttemptBegin {
                run_id,
                attempt,
                engine,
            } => {
                attempts_open.insert((run_id.clone(), *attempt), (ev.t_us, engine.clone()));
            }
            EventKind::AttemptEnd {
                run_id,
                attempt,
                ok,
            } => {
                if let Some((t0, engine)) = attempts_open.remove(&(run_id.clone(), *attempt)) {
                    let (node, slot) = lanes.get(run_id).copied().unwrap_or((0, 0));
                    out.push(span(
                        &format!("attempt {attempt}"),
                        "attempt",
                        t0,
                        ev.t_us.saturating_sub(t0),
                        node,
                        slot,
                        vec![("engine", Json::str(engine)), ("ok", Json::Bool(*ok))],
                    ));
                }
            }
            EventKind::DispatchEnd {
                kind,
                bucket,
                k,
                batch,
                dur_us,
            } => {
                let name = if *k > 0 {
                    format!("{kind} K={k} N={bucket}")
                } else {
                    format!("{kind} N={bucket}")
                };
                out.push(span(
                    &name,
                    "dispatch",
                    ev.t_us.saturating_sub(*dur_us),
                    *dur_us,
                    ENGINE_PID,
                    *k,
                    vec![("batch", num(*batch))],
                ));
            }
            EventKind::Retry {
                run_id,
                attempt,
                class,
                backoff_ms,
                ..
            } => {
                let (node, slot) = lanes.get(run_id).copied().unwrap_or((0, 0));
                out.push(instant(
                    &format!("retry ({class})"),
                    "retry",
                    ev.t_us,
                    node,
                    slot,
                    vec![
                        ("run_id", Json::str(run_id.clone())),
                        ("attempt", num(*attempt)),
                        ("backoff_ms", num(*backoff_ms)),
                    ],
                ));
            }
            EventKind::WatchdogFire {
                run_id,
                kind,
                detail,
            } => {
                let (node, slot) = lanes.get(run_id).copied().unwrap_or((0, 0));
                out.push(instant(
                    &format!("watchdog ({kind})"),
                    "watchdog",
                    ev.t_us,
                    node,
                    slot,
                    vec![
                        ("run_id", Json::str(run_id.clone())),
                        ("detail", Json::str(detail.clone())),
                    ],
                ));
            }
            EventKind::Degraded { run_id, attempt, .. } => {
                let (node, slot) = lanes.get(run_id).copied().unwrap_or((0, 0));
                out.push(instant(
                    "degraded to native",
                    "degrade",
                    ev.t_us,
                    node,
                    slot,
                    vec![
                        ("run_id", Json::str(run_id.clone())),
                        ("attempt", num(*attempt)),
                    ],
                ));
            }
            EventKind::LedgerTransition { run_id, state } => {
                let (node, slot) = lanes.get(run_id).copied().unwrap_or((0, 0));
                out.push(instant(
                    &format!("ledger: {state}"),
                    "ledger",
                    ev.t_us,
                    node,
                    slot,
                    vec![("run_id", Json::str(run_id.clone()))],
                ));
            }
            // campaign/slot bookkeeping, dispatch begins and batcher
            // details don't need their own trace rows
            _ => {}
        }
    }

    // name the lanes: one process per node, the engine process, one
    // thread per slot — sorted, so the document is deterministic
    let mut meta: Vec<Json> = Vec::new();
    let nodes: std::collections::BTreeSet<u64> = lanes.values().map(|(n, _)| *n).collect();
    for node in &nodes {
        meta.push(metadata("process_name", *node, None, format!("node {node}")));
    }
    let slots: std::collections::BTreeSet<(u64, u64)> = lanes.values().copied().collect();
    for (node, slot) in &slots {
        meta.push(metadata(
            "thread_name",
            *node,
            Some(*slot),
            format!("slot {slot}"),
        ));
    }
    if events
        .iter()
        .any(|e| matches!(e.kind, EventKind::DispatchEnd { .. }))
    {
        meta.push(metadata("process_name", ENGINE_PID, None, "engine".into()));
    }
    meta.extend(out);

    Json::obj(vec![
        ("displayTimeUnit", Json::str("ms")),
        ("traceEvents", Json::arr(meta)),
    ])
}

#[cfg(test)]
#[allow(clippy::unwrap_used, clippy::expect_used)]
mod tests {
    use super::*;

    fn ev(t_us: u64, kind: EventKind) -> Event {
        Event { t_us, kind }
    }

    #[test]
    fn runs_nest_attempts_and_dispatches_get_the_engine_lane() {
        let events = vec![
            ev(
                100,
                EventKind::RunBegin {
                    run_id: "c-e0[1]".into(),
                    epoch: 0,
                    slot: 1,
                    node: 0,
                },
            ),
            ev(
                110,
                EventKind::AttemptBegin {
                    run_id: "c-e0[1]".into(),
                    attempt: 0,
                    engine: "hlo".into(),
                },
            ),
            ev(
                500,
                EventKind::DispatchEnd {
                    kind: "rollout".into(),
                    bucket: 64,
                    k: 32,
                    batch: 1,
                    dur_us: 50,
                },
            ),
            ev(
                900,
                EventKind::AttemptEnd {
                    run_id: "c-e0[1]".into(),
                    attempt: 0,
                    ok: true,
                },
            ),
            ev(
                1000,
                EventKind::RunEnd {
                    run_id: "c-e0[1]".into(),
                    ok: true,
                    attempts: 1,
                    degraded: false,
                },
            ),
        ];
        let doc = to_chrome_trace(&events);
        let rows = doc.get("traceEvents").unwrap().as_arr().unwrap();
        // 2 metadata (node, slot) + 1 engine metadata + 3 spans
        assert_eq!(rows.len(), 6);
        let run = rows
            .iter()
            .find(|r| r.get("cat").map(|c| c.as_str().unwrap_or("")) == Ok("run"))
            .unwrap();
        assert_eq!(run.get("ts").unwrap().as_usize().unwrap(), 100);
        assert_eq!(run.get("dur").unwrap().as_usize().unwrap(), 900);
        let dispatch = rows
            .iter()
            .find(|r| r.get("cat").map(|c| c.as_str().unwrap_or("")) == Ok("dispatch"))
            .unwrap();
        assert_eq!(
            dispatch.get("pid").unwrap().as_usize().unwrap(),
            ENGINE_PID as usize
        );
        assert_eq!(dispatch.get("ts").unwrap().as_usize().unwrap(), 450);
        assert_eq!(dispatch.get("tid").unwrap().as_usize().unwrap(), 32);
    }

    #[test]
    fn truncated_stream_drops_unpaired_begins() {
        let events = vec![ev(
            100,
            EventKind::RunBegin {
                run_id: "c-e0[0]".into(),
                epoch: 0,
                slot: 0,
                node: 0,
            },
        )];
        let doc = to_chrome_trace(&events);
        let rows = doc.get("traceEvents").unwrap().as_arr().unwrap();
        // metadata rows only — no invented span
        assert!(rows
            .iter()
            .all(|r| r.get("ph").unwrap().as_str().unwrap() == "M"));
    }
}
