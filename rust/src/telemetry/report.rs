//! `webots-hpc report` — aggregate an event stream back into the
//! operational facts the paper reports (§5.1 completion rate, §5.3
//! resource use): completion counts, retry taxonomy, per-family/per-K
//! dispatch latency percentiles, and per-lane occupancy.
//!
//! The report is derived *only* from the event stream, so the e2e test
//! can assert it reconstructs the ledger's completion set exactly —
//! the property the future coordinator/worker fabric relies on
//! (workers stream events; the coordinator must not need the ledger
//! file to know campaign state).

use std::collections::{BTreeMap, BTreeSet};

use super::events::{Event, EventKind};

/// Exact dispatch-latency aggregate for one `(kind, K)` family.
#[derive(Debug, Clone, Default, PartialEq)]
pub struct DispatchStats {
    pub count: u64,
    pub sum_us: u64,
    /// Sorted on demand by [`summarize`] — percentiles are exact, not
    /// bucketed (the stream carries every duration).
    pub durs_us: Vec<u64>,
    pub batched: u64,
    pub serial_fallbacks: u64,
}

impl DispatchStats {
    fn record(&mut self, dur_us: u64, batch: u64) {
        self.count += 1;
        self.sum_us += dur_us;
        self.durs_us.push(dur_us);
        if batch >= 2 {
            self.batched += 1;
        }
    }

    pub fn percentile(&self, p: f64) -> u64 {
        if self.durs_us.is_empty() {
            return 0;
        }
        let rank = ((p * self.durs_us.len() as f64).ceil() as usize).clamp(1, self.durs_us.len());
        self.durs_us[rank - 1]
    }

    pub fn mean_us(&self) -> f64 {
        if self.count == 0 {
            0.0
        } else {
            self.sum_us as f64 / self.count as f64
        }
    }
}

/// One node/slot lane's busy time.
#[derive(Debug, Clone, Default, PartialEq)]
pub struct LaneUsage {
    pub busy_us: u64,
    pub runs: u64,
}

/// Everything `webots-hpc report` prints, as data.
#[derive(Debug, Clone, Default, PartialEq)]
pub struct Report {
    pub campaign: Option<String>,
    /// run_ids that reached a `running` ledger state (or RunBegin).
    pub runs_seen: u64,
    /// Unique run_ids whose latest ledger transition is `completed`.
    pub completed: u64,
    /// Unique run_ids whose latest ledger transition is `failed`.
    pub failed: u64,
    pub attempts: u64,
    /// Retry taxonomy: error class → count.
    pub retries: BTreeMap<String, u64>,
    pub backoff_ms_total: u64,
    pub degraded: u64,
    /// Watchdog kind (`walltime` / `stall`) → fires.
    pub watchdog: BTreeMap<String, u64>,
    /// `(kind, K)` → exact latency stats (K = 0 for step dispatches).
    pub dispatch: BTreeMap<(String, u64), DispatchStats>,
    pub pool_hits: u64,
    pub pool_misses: u64,
    /// `(node, slot)` → lane usage over the campaign span.
    pub lanes: BTreeMap<(u64, u64), LaneUsage>,
    /// Last event timestamp minus first — the denominator for
    /// occupancy.
    pub span_us: u64,
    /// Fabric worker joins (a reconnecting worker counts again).
    pub workers_joined: u64,
    /// Worker departures by reason (drain / connection lost / ...).
    pub worker_leaves: BTreeMap<String, u64>,
    /// Leases the coordinator granted.
    pub leases_granted: u64,
    /// Leases the reaper revoked on a missed heartbeat deadline.
    pub leases_expired: u64,
    /// Late/duplicate completions the ledger rejected idempotently.
    pub completions_rejected: u64,
}

impl Report {
    /// The §5.1 headline: completed / runs_seen (1.0 for an idle
    /// stream so a fresh campaign doesn't report failure).
    pub fn completion_rate(&self) -> f64 {
        if self.runs_seen == 0 {
            1.0
        } else {
            self.completed as f64 / self.runs_seen as f64
        }
    }

    /// Render the table the CLI prints.
    pub fn render(&self) -> String {
        let mut out = String::new();
        let name = self.campaign.as_deref().unwrap_or("(unnamed)");
        out.push_str(&format!(
            "campaign {name}: {} runs | {} completed | {} failed | completion rate {:.1}%\n",
            self.runs_seen,
            self.completed,
            self.failed,
            self.completion_rate() * 100.0
        ));
        out.push_str(&format!(
            "attempts {} | degraded {} | backoff slept {} ms\n",
            self.attempts, self.degraded, self.backoff_ms_total
        ));
        if self.retries.is_empty() {
            out.push_str("retries: none\n");
        } else {
            out.push_str("retries by class:\n");
            for (class, n) in &self.retries {
                out.push_str(&format!("  {class:<12} {n}\n"));
            }
        }
        for (kind, n) in &self.watchdog {
            out.push_str(&format!("watchdog {kind}: {n} fires\n"));
        }
        if self.pool_hits + self.pool_misses > 0 {
            out.push_str(&format!(
                "engine pool: {} hits / {} misses across runs\n",
                self.pool_hits, self.pool_misses
            ));
        }
        if !self.dispatch.is_empty() {
            out.push_str("engine dispatch latency (exact, us):\n");
            for ((kind, k), stats) in &self.dispatch {
                let family = if *k > 0 {
                    format!("{kind}/K={k}")
                } else {
                    kind.clone()
                };
                out.push_str(&format!(
                    "  {family:<16} n={:<6} mean={:<8.1} p50={} p90={} p99={} batched={} fallbacks={}\n",
                    stats.count,
                    stats.mean_us(),
                    stats.percentile(0.50),
                    stats.percentile(0.90),
                    stats.percentile(0.99),
                    stats.batched,
                    stats.serial_fallbacks
                ));
            }
        }
        if self.workers_joined > 0 {
            let leaves: u64 = self.worker_leaves.values().sum();
            out.push_str(&format!(
                "fabric: {} worker joins | {} leaves | {} leases granted | {} expired | {} completions rejected\n",
                self.workers_joined,
                leaves,
                self.leases_granted,
                self.leases_expired,
                self.completions_rejected
            ));
            for (reason, n) in &self.worker_leaves {
                out.push_str(&format!("  leave ({reason}): {n}\n"));
            }
        }
        if !self.lanes.is_empty() && self.span_us > 0 {
            out.push_str("lane occupancy (busy / campaign span):\n");
            for ((node, slot), lane) in &self.lanes {
                out.push_str(&format!(
                    "  node {node} slot {slot}: {} runs, {:.1}%\n",
                    lane.runs,
                    lane.busy_us as f64 / self.span_us as f64 * 100.0
                ));
            }
        }
        out
    }
}

/// Fold an event stream into a [`Report`].
pub fn summarize(events: &[Event]) -> Report {
    let mut report = Report::default();
    let mut latest_state: BTreeMap<String, String> = BTreeMap::new();
    let mut begun: BTreeSet<String> = BTreeSet::new();
    let mut run_open: BTreeMap<String, u64> = BTreeMap::new();
    let mut lanes_of: BTreeMap<String, (u64, u64)> = BTreeMap::new();
    let (mut t_min, mut t_max) = (u64::MAX, 0u64);

    for ev in events {
        t_min = t_min.min(ev.t_us);
        t_max = t_max.max(ev.t_us);
        match &ev.kind {
            EventKind::CampaignBegin { name, .. } => {
                report.campaign.get_or_insert_with(|| name.clone());
            }
            EventKind::RunBegin {
                run_id, slot, node, ..
            } => {
                begun.insert(run_id.clone());
                run_open.insert(run_id.clone(), ev.t_us);
                lanes_of.insert(run_id.clone(), (*node, *slot));
            }
            EventKind::RunEnd { run_id, .. } => {
                if let Some(t0) = run_open.remove(run_id) {
                    let lane = lanes_of.get(run_id).copied().unwrap_or((0, 0));
                    let usage = report.lanes.entry(lane).or_default();
                    usage.busy_us += ev.t_us.saturating_sub(t0);
                    usage.runs += 1;
                }
            }
            EventKind::AttemptBegin { .. } => report.attempts += 1,
            EventKind::Retry {
                class, backoff_ms, ..
            } => {
                *report.retries.entry(class.clone()).or_insert(0) += 1;
                report.backoff_ms_total += backoff_ms;
            }
            EventKind::Degraded { .. } => report.degraded += 1,
            EventKind::WatchdogFire { kind, .. } => {
                *report.watchdog.entry(kind.clone()).or_insert(0) += 1;
            }
            EventKind::LedgerTransition { run_id, state } => {
                begun.insert(run_id.clone());
                latest_state.insert(run_id.clone(), state.clone());
            }
            EventKind::DispatchEnd {
                kind,
                k,
                batch,
                dur_us,
                ..
            } => {
                report
                    .dispatch
                    .entry((kind.clone(), *k))
                    .or_default()
                    .record(*dur_us, *batch);
            }
            EventKind::SerialFallback { kind, k, .. } => {
                report
                    .dispatch
                    .entry((kind.clone(), *k))
                    .or_default()
                    .serial_fallbacks += 1;
            }
            EventKind::PoolDelta { hits, misses, .. } => {
                report.pool_hits += hits;
                report.pool_misses += misses;
            }
            EventKind::WorkerJoin { .. } => report.workers_joined += 1,
            EventKind::WorkerLeave { reason, .. } => {
                *report.worker_leaves.entry(reason.clone()).or_insert(0) += 1;
            }
            EventKind::LeaseGrant { .. } => report.leases_granted += 1,
            EventKind::LeaseExpired { .. } => report.leases_expired += 1,
            EventKind::CompletionRejected { .. } => report.completions_rejected += 1,
            _ => {}
        }
    }

    report.runs_seen = begun.len() as u64;
    report.completed = latest_state.values().filter(|s| *s == "completed").count() as u64;
    report.failed = latest_state.values().filter(|s| *s == "failed").count() as u64;
    report.span_us = if t_min == u64::MAX {
        0
    } else {
        t_max - t_min
    };
    for stats in report.dispatch.values_mut() {
        stats.durs_us.sort_unstable();
    }
    report
}

#[cfg(test)]
#[allow(clippy::unwrap_used, clippy::expect_used)]
mod tests {
    use super::*;
    use crate::telemetry::Event;

    fn ev(t_us: u64, kind: EventKind) -> Event {
        Event { t_us, kind }
    }

    #[test]
    fn report_reconstructs_completion_and_taxonomy() {
        let events = vec![
            ev(
                0,
                EventKind::CampaignBegin {
                    name: "rep".into(),
                    nodes: 1,
                    slots_per_node: 2,
                    epochs: 1,
                    runs: 2,
                },
            ),
            ev(
                10,
                EventKind::LedgerTransition {
                    run_id: "rep-e0[0]".into(),
                    state: "running".into(),
                },
            ),
            ev(
                12,
                EventKind::RunBegin {
                    run_id: "rep-e0[0]".into(),
                    epoch: 0,
                    slot: 0,
                    node: 0,
                },
            ),
            ev(
                20,
                EventKind::Retry {
                    run_id: "rep-e0[0]".into(),
                    attempt: 1,
                    class: "transient".into(),
                    error: "duarouter failed".into(),
                    backoff_ms: 5,
                },
            ),
            ev(
                40,
                EventKind::RunEnd {
                    run_id: "rep-e0[0]".into(),
                    ok: true,
                    attempts: 2,
                    degraded: false,
                },
            ),
            ev(
                41,
                EventKind::LedgerTransition {
                    run_id: "rep-e0[0]".into(),
                    state: "completed".into(),
                },
            ),
            ev(
                50,
                EventKind::LedgerTransition {
                    run_id: "rep-e0[1]".into(),
                    state: "running".into(),
                },
            ),
            ev(
                60,
                EventKind::LedgerTransition {
                    run_id: "rep-e0[1]".into(),
                    state: "failed".into(),
                },
            ),
        ];
        let r = summarize(&events);
        assert_eq!(r.campaign.as_deref(), Some("rep"));
        assert_eq!(r.runs_seen, 2);
        assert_eq!(r.completed, 1);
        assert_eq!(r.failed, 1);
        assert_eq!(r.completion_rate(), 0.5);
        assert_eq!(r.retries["transient"], 1);
        assert_eq!(r.backoff_ms_total, 5);
        assert_eq!(r.span_us, 60);
        let lane = &r.lanes[&(0, 0)];
        assert_eq!(lane.runs, 1);
        assert_eq!(lane.busy_us, 28);
        let text = r.render();
        assert!(text.contains("completion rate 50.0%"), "{text}");
        assert!(text.contains("transient"), "{text}");
    }

    #[test]
    fn fabric_counters_fold_from_worker_and_lease_events() {
        let events = vec![
            ev(0, EventKind::WorkerJoin { worker: "a#1".into() }),
            ev(1, EventKind::WorkerJoin { worker: "b#1".into() }),
            ev(
                2,
                EventKind::LeaseGrant {
                    run_id: "f-e0[0]".into(),
                    worker: "a#1".into(),
                    lease: 1,
                    attempt: 0,
                },
            ),
            ev(
                3,
                EventKind::LeaseExpired {
                    run_id: "f-e0[0]".into(),
                    worker: "a#1".into(),
                    lease: 1,
                },
            ),
            ev(
                4,
                EventKind::WorkerLeave {
                    worker: "a#1".into(),
                    reason: "connection lost".into(),
                },
            ),
            ev(
                5,
                EventKind::CompletionRejected {
                    run_id: "f-e0[0]".into(),
                    worker: "a#1".into(),
                },
            ),
            ev(
                6,
                EventKind::WorkerLeave {
                    worker: "b#1".into(),
                    reason: "drained".into(),
                },
            ),
        ];
        let r = summarize(&events);
        assert_eq!(r.workers_joined, 2);
        assert_eq!(r.leases_granted, 1);
        assert_eq!(r.leases_expired, 1);
        assert_eq!(r.completions_rejected, 1);
        assert_eq!(r.worker_leaves["connection lost"], 1);
        assert_eq!(r.worker_leaves["drained"], 1);
        let text = r.render();
        assert!(text.contains("2 worker joins"), "{text}");
        assert!(text.contains("1 expired"), "{text}");
    }

    #[test]
    fn dispatch_percentiles_are_exact() {
        let mut events = Vec::new();
        for dur in 1..=100u64 {
            events.push(ev(
                dur * 10,
                EventKind::DispatchEnd {
                    kind: "rollout".into(),
                    bucket: 64,
                    k: 32,
                    batch: if dur % 2 == 0 { 2 } else { 1 },
                    dur_us: dur,
                },
            ));
        }
        let r = summarize(&events);
        let stats = &r.dispatch[&("rollout".to_string(), 32)];
        assert_eq!(stats.count, 100);
        assert_eq!(stats.percentile(0.50), 50);
        assert_eq!(stats.percentile(0.90), 90);
        assert_eq!(stats.percentile(0.99), 99);
        assert_eq!(stats.batched, 50);
        assert_eq!(stats.mean_us(), 50.5);
        // empty report: rate defaults to 1.0, not 0/0
        assert_eq!(Report::default().completion_rate(), 1.0);
    }
}
