//! Lock-free metrics: counters, gauges, and log2 latency histograms
//! behind a process-global, hierarchically named [`Registry`].
//!
//! Design constraints (ISSUE 7 / ROADMAP "deadline-aware engine
//! scheduling"):
//!
//! * the hot path must be a handful of relaxed atomic ops — no locks,
//!   no allocation.  Registration (`Registry::counter` etc.) takes a
//!   mutex once and hands back an `Arc` handle; callers cache the
//!   handle and never touch the registry again,
//! * snapshots are cheap, mergeable across threads/processes, and
//!   serialize through [`crate::util::Json`] so they ride the same
//!   JSONL discipline as the campaign ledger,
//! * histograms use fixed log2 buckets (bucket `i ≥ 1` covers
//!   `[2^(i-1), 2^i - 1]`), so a 64-slot array covers the full `u64`
//!   range with zero configuration — microseconds to hours.

use std::collections::BTreeMap;
#[cfg(not(loom))]
use std::sync::OnceLock;

// primitives come from the facade so the loom models in
// rust/tests/loom_models.rs exhaustively check this exact code
use crate::util::sync::{Arc, AtomicI64, AtomicU64, Mutex, MutexGuard, Ordering};
use crate::util::Json;

/// Number of log2 buckets — enough for the whole `u64` range.
pub const HIST_BUCKETS: usize = 64;

/// A monotonically increasing counter (relaxed atomics throughout).
// Default/Debug are manual: loom's atomics don't promise std's derives.
pub struct Counter(AtomicU64);

impl Default for Counter {
    fn default() -> Self {
        Counter(AtomicU64::new(0))
    }
}

impl std::fmt::Debug for Counter {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        f.debug_tuple("Counter").field(&self.get()).finish()
    }
}

impl Counter {
    pub fn inc(&self) {
        self.add(1);
    }

    pub fn add(&self, n: u64) {
        self.0.fetch_add(n, Ordering::Relaxed);
    }

    pub fn get(&self) -> u64 {
        self.0.load(Ordering::Relaxed)
    }
}

/// A last-value-wins gauge (e.g. queue depth, lane occupancy).
pub struct Gauge(AtomicI64);

impl Default for Gauge {
    fn default() -> Self {
        Gauge(AtomicI64::new(0))
    }
}

impl std::fmt::Debug for Gauge {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        f.debug_tuple("Gauge").field(&self.get()).finish()
    }
}

impl Gauge {
    pub fn set(&self, v: i64) {
        self.0.store(v, Ordering::Relaxed);
    }

    pub fn add(&self, d: i64) {
        self.0.fetch_add(d, Ordering::Relaxed);
    }

    pub fn get(&self) -> i64 {
        self.0.load(Ordering::Relaxed)
    }
}

/// A fixed-bucket log2 histogram.  `record` is 3 relaxed atomic adds;
/// concurrent recorders never lose a sample (each add is independent,
/// so a merged snapshot is exact even under contention).
pub struct Histogram {
    count: AtomicU64,
    sum: AtomicU64,
    buckets: [AtomicU64; HIST_BUCKETS],
}

impl Default for Histogram {
    fn default() -> Self {
        Histogram {
            count: AtomicU64::new(0),
            sum: AtomicU64::new(0),
            buckets: std::array::from_fn(|_| AtomicU64::new(0)),
        }
    }
}

impl Histogram {
    /// log2 bucket index: 0 holds exactly 0, bucket `i ≥ 1` covers
    /// `[2^(i-1), 2^i - 1]`.  Clamped so `u64::MAX` (65 would-be
    /// buckets) still lands inside the array.
    pub fn bucket_index(v: u64) -> usize {
        if v == 0 {
            0
        } else {
            (64 - v.leading_zeros() as usize).min(HIST_BUCKETS - 1)
        }
    }

    /// Upper edge (inclusive) of bucket `i` — what `quantile` reports.
    pub fn bucket_edge(i: usize) -> u64 {
        if i == 0 {
            0
        } else if i >= HIST_BUCKETS - 1 {
            u64::MAX
        } else {
            (1u64 << i) - 1
        }
    }

    pub fn record(&self, v: u64) {
        self.count.fetch_add(1, Ordering::Relaxed);
        self.sum.fetch_add(v, Ordering::Relaxed);
        self.buckets[Self::bucket_index(v)].fetch_add(1, Ordering::Relaxed);
    }

    pub fn snapshot(&self) -> HistSnapshot {
        HistSnapshot {
            count: self.count.load(Ordering::Relaxed),
            sum: self.sum.load(Ordering::Relaxed),
            buckets: std::array::from_fn(|i| self.buckets[i].load(Ordering::Relaxed)),
        }
    }
}

/// An owned, mergeable point-in-time copy of a [`Histogram`].
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct HistSnapshot {
    pub count: u64,
    pub sum: u64,
    pub buckets: [u64; HIST_BUCKETS],
}

impl Default for HistSnapshot {
    fn default() -> Self {
        HistSnapshot {
            count: 0,
            sum: 0,
            buckets: [0; HIST_BUCKETS],
        }
    }
}

impl HistSnapshot {
    pub fn merge(&mut self, other: &HistSnapshot) {
        self.count += other.count;
        self.sum += other.sum;
        for (b, o) in self.buckets.iter_mut().zip(other.buckets.iter()) {
            *b += o;
        }
    }

    pub fn mean(&self) -> f64 {
        if self.count == 0 {
            0.0
        } else {
            self.sum as f64 / self.count as f64
        }
    }

    /// Quantile estimate: the inclusive upper edge of the bucket where
    /// the cumulative count crosses `q * count` (conservative — never
    /// under-reports a latency).
    pub fn quantile(&self, q: f64) -> u64 {
        if self.count == 0 {
            return 0;
        }
        let target = ((q * self.count as f64).ceil() as u64).clamp(1, self.count);
        let mut seen = 0u64;
        for (i, &b) in self.buckets.iter().enumerate() {
            seen += b;
            if seen >= target {
                return Histogram::bucket_edge(i);
            }
        }
        Histogram::bucket_edge(HIST_BUCKETS - 1)
    }

    fn to_json(&self) -> Json {
        Json::obj(vec![
            ("count", Json::num(self.count as f64)),
            ("sum", Json::num(self.sum as f64)),
            ("mean", Json::num(self.mean())),
            ("p50", Json::num(self.quantile(0.50) as f64)),
            ("p90", Json::num(self.quantile(0.90) as f64)),
            ("p99", Json::num(self.quantile(0.99) as f64)),
        ])
    }
}

/// Names instruments hierarchically (`engine.dispatch.step.latency_us`,
/// `service.lane.batch_size`, `supervisor.retry.count`) and hands out
/// shared handles.  One mutex per instrument *kind*, taken only at
/// registration — never on the record path.
pub struct Registry {
    counters: Mutex<BTreeMap<String, Arc<Counter>>>,
    gauges: Mutex<BTreeMap<String, Arc<Gauge>>>,
    histograms: Mutex<BTreeMap<String, Arc<Histogram>>>,
}

impl Default for Registry {
    fn default() -> Self {
        Registry {
            counters: Mutex::new(BTreeMap::new()),
            gauges: Mutex::new(BTreeMap::new()),
            histograms: Mutex::new(BTreeMap::new()),
        }
    }
}

fn relock<T>(m: &Mutex<T>) -> MutexGuard<'_, T> {
    // a poisoned metrics map is still structurally sound (every write
    // is a whole-entry insert); recover rather than cascade the panic
    m.lock().unwrap_or_else(|e| e.into_inner())
}

impl Registry {
    /// The process-global registry every instrumented subsystem shares.
    /// (Not under loom: loom models need per-iteration state, and loom
    /// has no `OnceLock` — models construct `Registry::default()`.)
    #[cfg(not(loom))]
    pub fn global() -> &'static Registry {
        static GLOBAL: OnceLock<Registry> = OnceLock::new();
        GLOBAL.get_or_init(Registry::default)
    }

    // explicit Arc::new over `or_default()`: loom's Arc doesn't
    // promise a `Default` impl, and these build under both cfgs
    pub fn counter(&self, name: &str) -> Arc<Counter> {
        relock(&self.counters)
            .entry(name.to_string())
            .or_insert_with(|| Arc::new(Counter::default()))
            .clone()
    }

    pub fn gauge(&self, name: &str) -> Arc<Gauge> {
        relock(&self.gauges)
            .entry(name.to_string())
            .or_insert_with(|| Arc::new(Gauge::default()))
            .clone()
    }

    pub fn histogram(&self, name: &str) -> Arc<Histogram> {
        relock(&self.histograms)
            .entry(name.to_string())
            .or_insert_with(|| Arc::new(Histogram::default()))
            .clone()
    }

    pub fn snapshot(&self) -> RegistrySnapshot {
        RegistrySnapshot {
            counters: relock(&self.counters)
                .iter()
                .map(|(k, v)| (k.clone(), v.get()))
                .collect(),
            gauges: relock(&self.gauges)
                .iter()
                .map(|(k, v)| (k.clone(), v.get()))
                .collect(),
            histograms: relock(&self.histograms)
                .iter()
                .map(|(k, v)| (k.clone(), v.snapshot()))
                .collect(),
        }
    }
}

/// Shorthand for `Registry::global().counter(name)`.
#[cfg(not(loom))]
pub fn counter(name: &str) -> Arc<Counter> {
    Registry::global().counter(name)
}

/// Shorthand for `Registry::global().gauge(name)`.
#[cfg(not(loom))]
pub fn gauge(name: &str) -> Arc<Gauge> {
    Registry::global().gauge(name)
}

/// Shorthand for `Registry::global().histogram(name)`.
#[cfg(not(loom))]
pub fn histogram(name: &str) -> Arc<Histogram> {
    Registry::global().histogram(name)
}

/// A mergeable point-in-time copy of a whole registry.
#[derive(Debug, Clone, Default, PartialEq)]
pub struct RegistrySnapshot {
    pub counters: BTreeMap<String, u64>,
    pub gauges: BTreeMap<String, i64>,
    pub histograms: BTreeMap<String, HistSnapshot>,
}

impl RegistrySnapshot {
    /// Fold `other` in: counters/histograms add, gauges last-wins.
    pub fn merge(&mut self, other: &RegistrySnapshot) {
        for (k, v) in &other.counters {
            *self.counters.entry(k.clone()).or_insert(0) += v;
        }
        for (k, v) in &other.gauges {
            self.gauges.insert(k.clone(), *v);
        }
        for (k, v) in &other.histograms {
            self.histograms.entry(k.clone()).or_default().merge(v);
        }
    }

    pub fn to_json(&self) -> Json {
        Json::obj(vec![
            (
                "counters",
                Json::Obj(
                    self.counters
                        .iter()
                        .map(|(k, v)| (k.clone(), Json::num(*v as f64)))
                        .collect(),
                ),
            ),
            (
                "gauges",
                Json::Obj(
                    self.gauges
                        .iter()
                        .map(|(k, v)| (k.clone(), Json::num(*v as f64)))
                        .collect(),
                ),
            ),
            (
                "histograms",
                Json::Obj(
                    self.histograms
                        .iter()
                        .map(|(k, v)| (k.clone(), v.to_json()))
                        .collect(),
                ),
            ),
        ])
    }
}

#[cfg(test)]
#[allow(clippy::unwrap_used, clippy::expect_used)]
mod tests {
    use super::*;

    #[test]
    fn bucket_index_covers_the_u64_range() {
        assert_eq!(Histogram::bucket_index(0), 0);
        assert_eq!(Histogram::bucket_index(1), 1);
        assert_eq!(Histogram::bucket_index(2), 2);
        assert_eq!(Histogram::bucket_index(3), 2);
        assert_eq!(Histogram::bucket_index(4), 3);
        assert_eq!(Histogram::bucket_index(1023), 10);
        assert_eq!(Histogram::bucket_index(1024), 11);
        assert_eq!(Histogram::bucket_index(u64::MAX), HIST_BUCKETS - 1);
        // every bucket's upper edge maps back into that bucket
        for i in 0..HIST_BUCKETS {
            assert_eq!(Histogram::bucket_index(Histogram::bucket_edge(i)), i);
        }
    }

    #[test]
    fn concurrent_recording_is_exact() {
        // the ISSUE acceptance test: N threads × M increments, merged
        // snapshot exact — relaxed atomics must not lose a sample
        const THREADS: usize = 8;
        const PER: u64 = 5000;
        let reg = Registry::default();
        let h = reg.histogram("t.lat_us");
        let c = reg.counter("t.ops");
        std::thread::scope(|s| {
            for t in 0..THREADS {
                let h = h.clone();
                let c = c.clone();
                s.spawn(move || {
                    for i in 0..PER {
                        h.record(t as u64 * 1000 + i % 100);
                        c.inc();
                    }
                });
            }
        });
        assert_eq!(c.get(), THREADS as u64 * PER);
        let snap = h.snapshot();
        assert_eq!(snap.count, THREADS as u64 * PER);
        let expected_sum: u64 = (0..THREADS as u64)
            .map(|t| (0..PER).map(|i| t * 1000 + i % 100).sum::<u64>())
            .sum();
        assert_eq!(snap.sum, expected_sum);
        assert_eq!(snap.buckets.iter().sum::<u64>(), snap.count);
    }

    #[test]
    fn quantiles_report_bucket_upper_edges() {
        let h = Histogram::default();
        for v in [1u64, 2, 3, 4, 100, 1000] {
            h.record(v);
        }
        let s = h.snapshot();
        assert_eq!(s.count, 6);
        assert_eq!(s.sum, 1110);
        // p50 lands in the bucket holding 3 (bucket 2 → edge 3)
        assert_eq!(s.quantile(0.5), 3);
        // p99 lands in 1000's bucket (bucket 10 → edge 1023)
        assert_eq!(s.quantile(0.99), 1023);
        assert_eq!(HistSnapshot::default().quantile(0.5), 0);
    }

    #[test]
    fn snapshots_merge_and_serialize() {
        let a = Histogram::default();
        let b = Histogram::default();
        a.record(5);
        a.record(7);
        b.record(9);
        let mut m = a.snapshot();
        m.merge(&b.snapshot());
        assert_eq!(m.count, 3);
        assert_eq!(m.sum, 21);
        assert_eq!(m.mean(), 7.0);

        let reg = Registry::default();
        reg.counter("x.hits").add(3);
        reg.gauge("x.depth").set(-2);
        reg.histogram("x.lat").record(12);
        let mut snap = reg.snapshot();
        snap.merge(&reg.snapshot());
        assert_eq!(snap.counters["x.hits"], 6);
        assert_eq!(snap.gauges["x.depth"], -2);
        assert_eq!(snap.histograms["x.lat"].count, 2);
        let j = snap.to_json();
        let line = j.to_compact_string();
        assert_eq!(crate::util::Json::parse(&line).unwrap(), j);
    }

    #[test]
    fn registry_handles_are_shared() {
        let reg = Registry::default();
        let a = reg.counter("same.name");
        let b = reg.counter("same.name");
        a.inc();
        b.inc();
        assert_eq!(reg.counter("same.name").get(), 2);
        // the process-global registry returns stable handles too
        let g1 = Registry::global().counter("telemetry.test.shared");
        Registry::global().counter("telemetry.test.shared").inc();
        assert!(g1.get() >= 1);
    }
}
