//! Always-on campaign observability (ISSUE 7).
//!
//! The paper's evidence is operational — a 100% completion rate over
//! 12 hours of distributed runs (§5.1) and per-setup resource tables
//! (§5.3) — but a ledger replay can only establish those facts after
//! the fact.  This module records *how* a campaign got there while it
//! runs, at a cost low enough to leave enabled everywhere:
//!
//! * [`metrics`] — lock-free counters/gauges/log2-histograms behind a
//!   process-global hierarchical [`Registry`] (the per-lane latency
//!   and occupancy series the deadline-scheduler ROADMAP item will be
//!   judged on),
//! * [`events`] + [`sink`] — the structured run-lifecycle event
//!   stream (campaign → run → attempt → dispatch), emitted to a
//!   buffered JSONL sink with the ledger's torn-tail discipline (the
//!   stream the coordinator/worker fabric item will transport),
//! * [`trace`] — event stream → Chrome/Perfetto trace-event JSON,
//! * [`report`] — event stream → completion/retry/latency/occupancy
//!   summary (`webots-hpc report`).
//!
//! Overhead discipline: nothing emits inside the per-step inner loop;
//! instrumentation stops at engine-*dispatch* granularity, and a
//! disabled `emit()` is one relaxed atomic load.

#![deny(clippy::unwrap_used, clippy::expect_used)]

// Only the metrics registry compiles under `--cfg loom` — the
// histogram-exactness model in rust/tests/loom_models.rs checks it.
#[cfg(not(loom))]
pub mod events;
pub mod metrics;
#[cfg(not(loom))]
pub mod report;
#[cfg(not(loom))]
pub mod sink;
#[cfg(not(loom))]
pub mod trace;

#[cfg(not(loom))]
pub use events::{Event, EventKind};
pub use metrics::{
    Counter, Gauge, HistSnapshot, Histogram, Registry, RegistrySnapshot, HIST_BUCKETS,
};
#[cfg(not(loom))]
pub use report::{summarize, DispatchStats, LaneUsage, Report};
#[cfg(not(loom))]
pub use sink::{
    emit, enabled, flush_all, install, merge_event_shards, read_events, uninstall, EventSink,
    JsonlSink, MemorySink,
};
#[cfg(not(loom))]
pub use trace::{to_chrome_trace, ENGINE_PID};

#[cfg(not(loom))]
use std::sync::OnceLock;
#[cfg(not(loom))]
use std::time::Instant;

/// Microseconds since the process's telemetry epoch (the first call).
/// Monotonic — safe to subtract — and shared by every event stamp so
/// one campaign's streams are mutually ordered.
#[cfg(not(loom))]
pub fn now_us() -> u64 {
    static EPOCH: OnceLock<Instant> = OnceLock::new();
    EPOCH.get_or_init(Instant::now).elapsed().as_micros() as u64
}
