//! Always-on campaign observability (ISSUE 7).
//!
//! The paper's evidence is operational — a 100% completion rate over
//! 12 hours of distributed runs (§5.1) and per-setup resource tables
//! (§5.3) — but a ledger replay can only establish those facts after
//! the fact.  This module records *how* a campaign got there while it
//! runs, at a cost low enough to leave enabled everywhere:
//!
//! * [`metrics`] — lock-free counters/gauges/log2-histograms behind a
//!   process-global hierarchical [`Registry`] (the per-lane latency
//!   and occupancy series the deadline-scheduler ROADMAP item will be
//!   judged on),
//! * [`events`] + [`sink`] — the structured run-lifecycle event
//!   stream (campaign → run → attempt → dispatch), emitted to a
//!   buffered JSONL sink with the ledger's torn-tail discipline (the
//!   stream the coordinator/worker fabric item will transport),
//! * [`trace`] — event stream → Chrome/Perfetto trace-event JSON,
//! * [`report`] — event stream → completion/retry/latency/occupancy
//!   summary (`webots-hpc report`).
//!
//! Overhead discipline: nothing emits inside the per-step inner loop;
//! instrumentation stops at engine-*dispatch* granularity, and a
//! disabled `emit()` is one relaxed atomic load.

pub mod events;
pub mod metrics;
pub mod report;
pub mod sink;
pub mod trace;

pub use events::{Event, EventKind};
pub use metrics::{
    Counter, Gauge, HistSnapshot, Histogram, Registry, RegistrySnapshot, HIST_BUCKETS,
};
pub use report::{summarize, DispatchStats, LaneUsage, Report};
pub use sink::{
    emit, enabled, flush_all, install, merge_event_shards, read_events, uninstall, EventSink,
    JsonlSink, MemorySink,
};
pub use trace::{to_chrome_trace, ENGINE_PID};

use std::sync::OnceLock;
use std::time::Instant;

/// Microseconds since the process's telemetry epoch (the first call).
/// Monotonic — safe to subtract — and shared by every event stamp so
/// one campaign's streams are mutually ordered.
pub fn now_us() -> u64 {
    static EPOCH: OnceLock<Instant> = OnceLock::new();
    EPOCH.get_or_init(Instant::now).elapsed().as_micros() as u64
}
