//! Named submission queues (`#PBS -q dicelab`).
//!
//! A queue is a policy surface over a subset of the cluster: which nodes
//! it may use, the walltime cap, and the per-user node limit.  The paper
//! submits everything to the DICE-lab queue.


use crate::{Error, Result};

/// Static queue configuration.
#[derive(Debug, Clone)]
pub struct QueueSpec {
    pub name: String,
    /// Node indices (into the owning [`super::Cluster`]) this queue may use.
    pub node_indices: Vec<usize>,
    /// Hard walltime cap in seconds (requests above this are rejected at
    /// submission, like PBS's `qsub: Job exceeds queue resource limits`).
    pub max_walltime_secs: u64,
    /// Max nodes one job may span.
    pub max_nodes_per_job: usize,
}

impl QueueSpec {
    /// The `dicelab` queue over the first `n` nodes of the cluster.
    pub fn dicelab(n: usize) -> Self {
        QueueSpec {
            name: "dicelab".into(),
            node_indices: (0..n).collect(),
            max_walltime_secs: 72 * 3600,
            max_nodes_per_job: n,
        }
    }
}

/// A queue bound to runtime state (currently just validation; the
/// scheduler owns the dynamic state).
#[derive(Debug, Clone)]
pub struct ClusterQueue {
    pub spec: QueueSpec,
}

impl ClusterQueue {
    pub fn new(spec: QueueSpec) -> Self {
        ClusterQueue { spec }
    }

    /// Validate a submission against queue limits.
    pub fn admit(&self, walltime_secs: u64, nodes: usize) -> Result<()> {
        if walltime_secs > self.spec.max_walltime_secs {
            return Err(Error::Unschedulable(format!(
                "queue {}: walltime {}s exceeds cap {}s",
                self.spec.name, walltime_secs, self.spec.max_walltime_secs
            )));
        }
        if nodes > self.spec.max_nodes_per_job {
            return Err(Error::Unschedulable(format!(
                "queue {}: {} nodes exceeds cap {}",
                self.spec.name, nodes, self.spec.max_nodes_per_job
            )));
        }
        Ok(())
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn dicelab_covers_requested_nodes() {
        let q = QueueSpec::dicelab(11);
        assert_eq!(q.node_indices.len(), 11);
        assert_eq!(q.name, "dicelab");
    }

    #[test]
    fn admit_enforces_walltime_cap() {
        let q = ClusterQueue::new(QueueSpec::dicelab(6));
        assert!(q.admit(900, 6).is_ok());
        assert!(q.admit(100 * 3600, 1).is_err());
        assert!(q.admit(900, 7).is_err());
    }
}
