//! The cluster: a named collection of nodes plus interconnect metadata.


use crate::{Error, Result};

use super::{AllocationId, Node, NodeSpec, ResourceDemand};

/// Interconnect classes present on the DICE queue (Table 2.2 lists
/// "100g, HDR, 25GE").
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash)]
pub enum Interconnect {
    /// InfiniBand HDR (200 Gb/s) — what the paper's `-l interconnect=hdr`
    /// selects.
    Hdr,
    /// 100 GbE.
    Ethernet100G,
    /// 25 GbE.
    Ethernet25G,
}

impl Interconnect {
    pub fn parse(s: &str) -> Result<Self> {
        match s.to_ascii_lowercase().as_str() {
            "hdr" => Ok(Interconnect::Hdr),
            "100g" | "100ge" => Ok(Interconnect::Ethernet100G),
            "25g" | "25ge" => Ok(Interconnect::Ethernet25G),
            other => Err(Error::Config(format!("unknown interconnect '{other}'"))),
        }
    }

    pub fn as_str(&self) -> &'static str {
        match self {
            Interconnect::Hdr => "hdr",
            Interconnect::Ethernet100G => "100g",
            Interconnect::Ethernet25G => "25ge",
        }
    }
}

/// The whole machine room.
#[derive(Debug, Clone)]
pub struct Cluster {
    pub name: String,
    nodes: Vec<Node>,
}

impl Cluster {
    pub fn new(name: impl Into<String>) -> Self {
        Cluster {
            name: name.into(),
            nodes: Vec::new(),
        }
    }

    /// The Palmetto DICE-lab queue: 11 R740s (paper §2.6).
    pub fn palmetto_dice() -> Self {
        Self::uniform("palmetto-dice", 11, NodeSpec::dice_r740())
    }

    /// `count` identical nodes named `{name}-nodeNN`.
    pub fn uniform(name: &str, count: usize, spec: NodeSpec) -> Self {
        let mut c = Cluster::new(name);
        for i in 0..count {
            c.add_node(Node::new(format!("{name}-node{i:02}"), spec.clone()));
        }
        c
    }

    pub fn add_node(&mut self, node: Node) {
        self.nodes.push(node);
    }

    pub fn nodes(&self) -> &[Node] {
        &self.nodes
    }

    pub fn node(&self, idx: usize) -> &Node {
        &self.nodes[idx]
    }

    pub fn node_mut(&mut self, idx: usize) -> &mut Node {
        &mut self.nodes[idx]
    }

    pub fn len(&self) -> usize {
        self.nodes.len()
    }

    pub fn is_empty(&self) -> bool {
        self.nodes.is_empty()
    }

    /// Indices of nodes that can host `demand` right now, restricted to an
    /// interconnect class when requested (`-l interconnect=hdr`).
    pub fn candidates(
        &self,
        demand: &ResourceDemand,
        interconnect: Option<Interconnect>,
    ) -> Vec<usize> {
        self.nodes
            .iter()
            .enumerate()
            .filter(|(_, n)| interconnect.map_or(true, |ic| n.spec.interconnect == ic))
            .filter(|(_, n)| n.fits(demand))
            .map(|(i, _)| i)
            .collect()
    }

    pub fn allocate_on(&mut self, idx: usize, demand: ResourceDemand) -> Result<AllocationId> {
        self.nodes[idx].allocate(demand)
    }

    pub fn release_on(&mut self, idx: usize, id: AllocationId) -> Result<()> {
        self.nodes[idx].release(id)
    }

    /// Total free cores across the cluster (capacity signal for benches).
    pub fn total_free_cores(&self) -> u32 {
        self.nodes.iter().map(|n| n.free_cores()).sum()
    }

    /// Per-node running-instance counts — the §5.2 distribution metric.
    pub fn occupancy(&self) -> Vec<usize> {
        self.nodes.iter().map(|n| n.num_running()).collect()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn palmetto_dice_has_eleven_nodes() {
        let c = Cluster::palmetto_dice();
        assert_eq!(c.len(), 11);
        assert_eq!(c.total_free_cores(), 11 * 40);
    }

    #[test]
    fn candidates_respect_interconnect() {
        let mut c = Cluster::uniform("t", 2, NodeSpec::dice_r740());
        c.add_node(Node::new("eth", NodeSpec::personal_computer()));
        let d = ResourceDemand {
            ncpus: 1,
            mem_gb: 1.0,
            scratch_gb: 0.0,
            ngpus: 0,
        };
        assert_eq!(c.candidates(&d, Some(Interconnect::Hdr)).len(), 2);
        assert_eq!(c.candidates(&d, None).len(), 3);
    }

    #[test]
    fn candidates_shrink_as_cluster_fills() {
        let mut c = Cluster::uniform("t", 2, NodeSpec::dice_r740());
        let d = ResourceDemand::whole_node();
        let cands = c.candidates(&d, None);
        assert_eq!(cands.len(), 2);
        c.allocate_on(cands[0], d).unwrap();
        assert_eq!(c.candidates(&d, None).len(), 1);
    }

    #[test]
    fn interconnect_parse_roundtrip() {
        for s in ["hdr", "100g", "25ge"] {
            let ic = Interconnect::parse(s).unwrap();
            assert_eq!(Interconnect::parse(ic.as_str()).unwrap(), ic);
        }
        assert!(Interconnect::parse("token-ring").is_err());
    }
}
