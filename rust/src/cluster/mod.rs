//! The compute-cluster substrate: nodes, resource accounting, queues.
//!
//! The paper runs on Clemson's Palmetto cluster, specifically the 11-node
//! **DICE Lab queue** of Dell R740s (Table 2.2: 40 cores, 744 GB RAM,
//! 1.8 TB local scratch, HDR interconnect, 2× V100).  We model the node
//! inventory and resource bookkeeping faithfully — the throughput and
//! distribution results of ch. 5 are functions of this inventory plus the
//! PBS packing policy, not of the silicon.

mod node;
mod queue;
mod topology;

pub use node::{Allocation, AllocationId, Node, NodeSpec, ResourceDemand};
pub use queue::{ClusterQueue, QueueSpec};
pub use topology::{Cluster, Interconnect};
