//! A single compute node: hardware spec + live allocations.


use crate::{Error, Result};

use super::Interconnect;

/// Hardware description of one node (paper Table 2.2).
#[derive(Debug, Clone, PartialEq)]
pub struct NodeSpec {
    pub make: String,
    pub model: String,
    pub chip: String,
    pub cores: u32,
    pub ram_gb: f64,
    pub local_scratch_gb: f64,
    pub interconnect: Interconnect,
    pub gpus: u32,
    pub gpu_model: String,
}

impl NodeSpec {
    /// The DICE-lab Dell R740 of paper Table 2.2 (Phase 18b).
    pub fn dice_r740() -> Self {
        NodeSpec {
            make: "Dell".into(),
            model: "R740".into(),
            chip: "Intel Xeon".into(),
            cores: 40,
            ram_gb: 744.0,
            local_scratch_gb: 1843.2, // 1.8 TB
            interconnect: Interconnect::Hdr,
            gpus: 2,
            gpu_model: "Nvidia Tesla V100".into(),
        }
    }

    /// The "personal computer of comparable hardware" baseline of §5.1.
    /// The paper sections each cluster node into 8 slots of 5 cores /
    /// 93 GB (Table 5.2) and calls that "specifications reminiscent of a
    /// personal computer"; the PC baseline uses the same slice.
    pub fn personal_computer() -> Self {
        NodeSpec {
            make: "Generic".into(),
            model: "Desktop".into(),
            chip: "Intel Core".into(),
            cores: 5,
            ram_gb: 93.0,
            local_scratch_gb: 225.0,
            interconnect: Interconnect::Ethernet25G,
            gpus: 0,
            gpu_model: String::new(),
        }
    }
}

/// What one job chunk asks of a node — the `-l select=...` terms.
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct ResourceDemand {
    pub ncpus: u32,
    pub mem_gb: f64,
    pub scratch_gb: f64,
    pub ngpus: u32,
}

impl ResourceDemand {
    /// The paper's per-instance request (Appendix B / Table 5.2, 6x8
    /// setup): `ncpus=5:mem=93gb`.
    pub fn paper_slot() -> Self {
        ResourceDemand {
            ncpus: 5,
            mem_gb: 93.0,
            scratch_gb: 225.0,
            ngpus: 0,
        }
    }

    /// Whole-node request (Table 5.2, 6x1 setup): 40 cores / 744 GB.
    pub fn whole_node() -> Self {
        ResourceDemand {
            ncpus: 40,
            mem_gb: 744.0,
            scratch_gb: 1843.2,
            ngpus: 0,
        }
    }
}

/// Handle to a live allocation on a node.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash, PartialOrd, Ord)]
pub struct AllocationId(pub u64);

/// A booked slice of a node.
#[derive(Debug, Clone, PartialEq)]
pub struct Allocation {
    pub id: AllocationId,
    pub demand: ResourceDemand,
}

/// A node with live resource bookkeeping.
#[derive(Debug, Clone)]
pub struct Node {
    pub name: String,
    pub spec: NodeSpec,
    allocations: Vec<Allocation>,
    next_alloc: u64,
}

impl Node {
    pub fn new(name: impl Into<String>, spec: NodeSpec) -> Self {
        Node {
            name: name.into(),
            spec,
            allocations: Vec::new(),
            next_alloc: 0,
        }
    }

    pub fn allocations(&self) -> &[Allocation] {
        &self.allocations
    }

    pub fn free_cores(&self) -> u32 {
        self.spec.cores
            - self
                .allocations
                .iter()
                .map(|a| a.demand.ncpus)
                .sum::<u32>()
    }

    pub fn free_ram_gb(&self) -> f64 {
        self.spec.ram_gb - self.allocations.iter().map(|a| a.demand.mem_gb).sum::<f64>()
    }

    pub fn free_scratch_gb(&self) -> f64 {
        self.spec.local_scratch_gb
            - self
                .allocations
                .iter()
                .map(|a| a.demand.scratch_gb)
                .sum::<f64>()
    }

    pub fn free_gpus(&self) -> u32 {
        self.spec.gpus - self.allocations.iter().map(|a| a.demand.ngpus).sum::<u32>()
    }

    /// Can this node host `demand` *right now*?
    pub fn fits(&self, demand: &ResourceDemand) -> bool {
        self.free_cores() >= demand.ncpus
            && self.free_ram_gb() >= demand.mem_gb - 1e-9
            && self.free_scratch_gb() >= demand.scratch_gb - 1e-9
            && self.free_gpus() >= demand.ngpus
    }

    /// Book resources; fails (never oversubscribes) when they don't fit.
    pub fn allocate(&mut self, demand: ResourceDemand) -> Result<AllocationId> {
        if !self.fits(&demand) {
            return Err(Error::Unschedulable(format!(
                "node {} cannot fit ncpus={} mem={}gb (free: {} cores, {:.0} gb)",
                self.name,
                demand.ncpus,
                demand.mem_gb,
                self.free_cores(),
                self.free_ram_gb()
            )));
        }
        let id = AllocationId(self.next_alloc);
        self.next_alloc += 1;
        self.allocations.push(Allocation { id, demand });
        Ok(id)
    }

    /// Release a booking. Idempotent release is an error — the scheduler
    /// must not double-free.
    pub fn release(&mut self, id: AllocationId) -> Result<()> {
        let before = self.allocations.len();
        self.allocations.retain(|a| a.id != id);
        if self.allocations.len() == before {
            return Err(Error::Unschedulable(format!(
                "release of unknown allocation {id:?} on node {}",
                self.name
            )));
        }
        Ok(())
    }

    pub fn num_running(&self) -> usize {
        self.allocations.len()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn dice_spec_matches_table_2_2() {
        let s = NodeSpec::dice_r740();
        assert_eq!(s.cores, 40);
        assert_eq!(s.ram_gb, 744.0);
        assert_eq!(s.gpus, 2);
        assert_eq!(s.interconnect, Interconnect::Hdr);
    }

    #[test]
    fn eight_paper_slots_fit_one_dice_node() {
        // the 6x8 experimental setup: 8 × (5 cores, 93 GB) per node
        let mut n = Node::new("node1", NodeSpec::dice_r740());
        for _ in 0..8 {
            n.allocate(ResourceDemand::paper_slot()).unwrap();
        }
        assert_eq!(n.free_cores(), 0);
        assert!(n.free_ram_gb() < 1.0); // 744 - 8*93 = 0
        assert!(n.allocate(ResourceDemand::paper_slot()).is_err());
    }

    #[test]
    fn whole_node_excludes_everything_else() {
        let mut n = Node::new("node1", NodeSpec::dice_r740());
        n.allocate(ResourceDemand::whole_node()).unwrap();
        assert!(!n.fits(&ResourceDemand::paper_slot()));
    }

    #[test]
    fn release_frees_resources() {
        let mut n = Node::new("node1", NodeSpec::dice_r740());
        let id = n.allocate(ResourceDemand::whole_node()).unwrap();
        n.release(id).unwrap();
        assert_eq!(n.free_cores(), 40);
        assert!(n.release(id).is_err(), "double free must fail");
    }

    #[test]
    fn never_oversubscribes_cores() {
        let mut n = Node::new("node1", NodeSpec::dice_r740());
        let d = ResourceDemand {
            ncpus: 30,
            mem_gb: 10.0,
            scratch_gb: 0.0,
            ngpus: 0,
        };
        n.allocate(d).unwrap();
        assert!(n.allocate(d).is_err());
    }

    #[test]
    fn gpu_accounting() {
        let mut n = Node::new("node1", NodeSpec::dice_r740());
        let d = ResourceDemand {
            ncpus: 1,
            mem_gb: 1.0,
            scratch_gb: 0.0,
            ngpus: 2,
        };
        n.allocate(d).unwrap();
        assert_eq!(n.free_gpus(), 0);
        assert!(!n.fits(&d));
    }
}
