//! Deterministic fault injection — the harness behind the robustness
//! claim.
//!
//! The paper reports a 100% completion rate over 12 unattended hours
//! (§5.1); reproducing that number on a fault-free simulator proves
//! nothing.  A [`FaultPlan`] is a *seeded schedule* of faults at the
//! pipeline's real failure sites (duarouter, display acquisition, the
//! TraCI accept, PJRT dispatch, in-run panics, back-end stalls): whether
//! site S fires for run R on attempt A is a pure function of
//! `(plan seed, S, R, A)`, so a soak test is exactly reproducible, a
//! retried attempt redraws its faults, and a resumed campaign injects
//! the identical faults the interrupted one would have.

use std::time::Duration;

use crate::sumo::{StepObs, Stepper, Traffic};
use crate::util::Rng64;

/// Where in an instance's lifecycle a fault can be injected.  Each site
/// maps to the error the real failure produces (see
/// [`super::launch_instance`]).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum FaultSite {
    /// Route regeneration exits nonzero → [`crate::Error::DuarouterFailed`].
    Duarouter,
    /// Display acquisition loses the race → [`crate::Error::DisplayInUse`].
    Display,
    /// The TraCI server cannot bind/accept → [`crate::Error::PortInUse`].
    TraciAccept,
    /// The PJRT engine fails at dispatch setup → [`crate::Error::Runtime`]
    /// (only meaningful for `PhysicsEngine::Hlo`; the supervisor's
    /// graceful-degradation path answers it).
    PjrtDispatch,
    /// The launch thread panics mid-run → contained to
    /// [`crate::Error::Panic`] by the supervisor.
    InRunPanic,
    /// The back-end stepper wedges mid-run (a finite injected sleep) →
    /// the stall watchdog kills the run with [`crate::Error::Stalled`].
    Stall,
    /// Fabric transport: the worker drops its coordinator connection
    /// instead of reporting a finished run — the lease expires and the
    /// slot is re-dispatched.
    FabricDrop,
    /// Fabric transport: the worker dies mid-frame, leaving a
    /// half-written line on the coordinator's socket.
    FabricTorn,
    /// Fabric transport: the worker reports the same completion twice
    /// (a retransmit after a lost ack) — the ledger's duplicate guard
    /// must reject the second idempotently.
    FabricDuplicate,
}

impl FaultSite {
    /// All sites, in schedule order (the index keys the rate table).
    pub const ALL: [FaultSite; 9] = [
        FaultSite::Duarouter,
        FaultSite::Display,
        FaultSite::TraciAccept,
        FaultSite::PjrtDispatch,
        FaultSite::InRunPanic,
        FaultSite::Stall,
        FaultSite::FabricDrop,
        FaultSite::FabricTorn,
        FaultSite::FabricDuplicate,
    ];

    fn index(self) -> usize {
        match self {
            FaultSite::Duarouter => 0,
            FaultSite::Display => 1,
            FaultSite::TraciAccept => 2,
            FaultSite::PjrtDispatch => 3,
            FaultSite::InRunPanic => 4,
            FaultSite::Stall => 5,
            FaultSite::FabricDrop => 6,
            FaultSite::FabricTorn => 7,
            FaultSite::FabricDuplicate => 8,
        }
    }
}

/// A seeded per-site fault schedule for a whole campaign.
#[derive(Debug, Clone, PartialEq)]
pub struct FaultPlan {
    /// Schedule seed — independent of the runs' physics seeds, so the
    /// same scenario campaign can be soaked under different fault
    /// histories.
    pub seed: u64,
    rates: [f64; 9],
    /// Step at which an injected stall wedges the back-end.
    pub stall_at_step: u64,
    /// How long the injected stall sleeps [ms] — finite, so the burst
    /// returns and the stall window can judge it.
    pub stall_ms: u64,
}

impl FaultPlan {
    /// A plan that never fires (the fault-free baseline).
    pub fn none(seed: u64) -> FaultPlan {
        FaultPlan {
            seed,
            rates: [0.0; 9],
            stall_at_step: 5,
            stall_ms: 100,
        }
    }

    /// Transient faults only — duarouter, display, TraCI accept and
    /// in-run panics all at `rate` — the soak-test schedule: every
    /// injected fault is retryable, so a correct supervisor converges
    /// to 100% completion.
    pub fn transient_only(seed: u64, rate: f64) -> FaultPlan {
        FaultPlan::none(seed)
            .with_rate(FaultSite::Duarouter, rate)
            .with_rate(FaultSite::Display, rate)
            .with_rate(FaultSite::TraciAccept, rate)
            .with_rate(FaultSite::InRunPanic, rate)
    }

    /// Fabric transport faults only — connection drops, torn frames and
    /// duplicate completions all at `rate` — the distributed-soak
    /// schedule: every injected fault is survivable by the
    /// lease/reaper/idempotent-completion machinery, so a correct
    /// fabric converges to 100% completion.
    pub fn transport_only(seed: u64, rate: f64) -> FaultPlan {
        FaultPlan::none(seed)
            .with_rate(FaultSite::FabricDrop, rate)
            .with_rate(FaultSite::FabricTorn, rate)
            .with_rate(FaultSite::FabricDuplicate, rate)
    }

    /// Set one site's fault probability (clamped to [0, 1]).
    pub fn with_rate(mut self, site: FaultSite, rate: f64) -> FaultPlan {
        self.rates[site.index()] = rate.clamp(0.0, 1.0);
        self
    }

    /// The configured probability for `site`.
    pub fn rate(&self, site: FaultSite) -> f64 {
        self.rates[site.index()]
    }

    /// Does `site` fire for `run_seed` on `attempt`?  Pure: reseeded
    /// SplitMix64 draws keyed on every input, so retries redraw and any
    /// process recomputes the identical schedule.
    pub fn fires(&self, site: FaultSite, run_seed: u64, attempt: u32) -> bool {
        let rate = self.rates[site.index()];
        if rate <= 0.0 {
            return false;
        }
        if rate >= 1.0 {
            return true;
        }
        self.draw(site, run_seed, attempt) < rate
    }

    /// One uniform draw in [0, 1) for `(site, run_seed, attempt)`.
    fn draw(&self, site: FaultSite, run_seed: u64, attempt: u32) -> f64 {
        let site_key = (site.index() as u64 + 1).wrapping_mul(0x9E37_79B9_7F4A_7C15);
        let mut r = Rng64::seed_from_u64(self.seed ^ site_key);
        let s1 = r.next_u64() ^ run_seed.wrapping_mul(0xBF58_476D_1CE4_E5B9);
        let mut r = Rng64::seed_from_u64(s1 ^ (attempt as u64).wrapping_mul(0x94D0_49BB_1331_11EB));
        r.gen_f64()
    }

    /// Wrap a stepper so the back-end wedges (sleeps `stall_ms`) once
    /// it reaches `stall_at_step` — the [`FaultSite::Stall`] payload.
    pub fn stall_wrap(&self, inner: Box<dyn Stepper>) -> Box<dyn Stepper> {
        Box::new(StallingStepper {
            inner,
            at_step: self.stall_at_step,
            duration: Duration::from_millis(self.stall_ms),
            steps: 0,
            fired: false,
        })
    }
}

/// A plan bound to one launch attempt — what the launcher consults
/// (the supervisor increments `attempt` on every retry so each attempt
/// redraws its schedule).
#[derive(Debug, Clone, PartialEq)]
pub struct FaultInjection {
    pub plan: FaultPlan,
    pub attempt: u32,
}

impl FaultInjection {
    pub fn fires(&self, site: FaultSite, run_seed: u64) -> bool {
        self.plan.fires(site, run_seed, self.attempt)
    }
}

/// Stepper wrapper that injects one finite mid-run stall.  Delegates
/// physics to the inner stepper unchanged; `step_many`'s default
/// per-step loop keeps the per-step obs trace identical to the inner
/// engine's.
struct StallingStepper {
    inner: Box<dyn Stepper>,
    at_step: u64,
    duration: Duration,
    steps: u64,
    fired: bool,
}

impl Stepper for StallingStepper {
    fn step(&mut self, traffic: &mut Traffic) -> StepObs {
        self.steps += 1;
        if !self.fired && self.steps >= self.at_step {
            self.fired = true;
            std::thread::sleep(self.duration);
        }
        self.inner.step(traffic)
    }

    fn name(&self) -> &'static str {
        "stall-inject"
    }
}

#[cfg(test)]
#[allow(clippy::unwrap_used, clippy::expect_used)]
mod tests {
    use super::*;

    #[test]
    fn schedule_is_deterministic_and_rate_bounded() {
        let plan = FaultPlan::transient_only(2021, 0.1);
        let mut fired = 0u32;
        for run_seed in 0..1000u64 {
            let a = plan.fires(FaultSite::Duarouter, run_seed, 0);
            let b = plan.fires(FaultSite::Duarouter, run_seed, 0);
            assert_eq!(a, b, "pure function of (seed, site, run, attempt)");
            fired += a as u32;
        }
        // ~10% ± sampling noise over 1000 draws
        assert!((50..200).contains(&fired), "fired = {fired}");
    }

    #[test]
    fn rate_zero_never_fires_rate_one_always() {
        let none = FaultPlan::none(7);
        let sure = FaultPlan::none(7).with_rate(FaultSite::Stall, 1.0);
        for run_seed in 0..100u64 {
            for site in FaultSite::ALL {
                assert!(!none.fires(site, run_seed, 0));
            }
            assert!(sure.fires(FaultSite::Stall, run_seed, 0));
            assert!(!sure.fires(FaultSite::Duarouter, run_seed, 0));
        }
    }

    #[test]
    fn retried_attempts_redraw() {
        let plan = FaultPlan::transient_only(42, 0.5);
        // at a 50% rate, 64 (run, site) pairs must disagree across
        // attempts somewhere — identical schedules would mean attempt
        // is not keyed into the draw
        let differs = (0..64u64).any(|run_seed| {
            FaultSite::ALL.iter().any(|&s| {
                plan.fires(s, run_seed, 0) != plan.fires(s, run_seed, 1)
            })
        });
        assert!(differs, "attempt must rekey the schedule");
    }

    #[test]
    fn transient_only_leaves_engine_and_stall_quiet() {
        let plan = FaultPlan::transient_only(1, 0.9);
        assert_eq!(plan.rate(FaultSite::PjrtDispatch), 0.0);
        assert_eq!(plan.rate(FaultSite::Stall), 0.0);
        assert_eq!(plan.rate(FaultSite::Duarouter), 0.9);
    }

    #[test]
    fn transport_only_touches_only_the_fabric_sites() {
        let plan = FaultPlan::transport_only(5, 0.25);
        assert_eq!(plan.rate(FaultSite::FabricDrop), 0.25);
        assert_eq!(plan.rate(FaultSite::FabricTorn), 0.25);
        assert_eq!(plan.rate(FaultSite::FabricDuplicate), 0.25);
        assert_eq!(plan.rate(FaultSite::Duarouter), 0.0);
        assert_eq!(plan.rate(FaultSite::Stall), 0.0);
        // and the in-run schedule leaves the fabric quiet
        let inrun = FaultPlan::transient_only(5, 0.25);
        assert_eq!(inrun.rate(FaultSite::FabricDrop), 0.0);
        assert_eq!(inrun.rate(FaultSite::FabricDuplicate), 0.0);
    }

    #[test]
    fn stalling_stepper_delegates_physics() {
        use crate::sumo::{DriverParams, NativeIdmStepper};
        let mut plain: Box<dyn Stepper> = Box::new(NativeIdmStepper::default());
        let mut plan = FaultPlan::none(0);
        plan.stall_ms = 1;
        plan.stall_at_step = 2;
        let mut stalled = plan.stall_wrap(Box::new(NativeIdmStepper::default()));
        let mut ta = Traffic::new(8);
        ta.spawn(100.0, 20.0, 1.0, DriverParams::default());
        ta.spawn(130.0, 10.0, 1.0, DriverParams::default());
        let mut tb = ta.clone();
        for _ in 0..4 {
            let a = plain.step(&mut ta);
            let b = stalled.step(&mut tb);
            assert_eq!(a, b, "stall injection must not change the physics");
        }
        assert_eq!(ta.state, tb.state);
        assert_eq!(stalled.name(), "stall-inject");
    }
}
