//! World-copy propagation.
//!
//! §3.1.5: to run n > 1 SUMO-coupled instances per node, the pipeline
//! needs "n copies of the simulation on the local filesystem ...
//! identical except for one deviation: each copy must have a unique
//! value for the port option on the Webots SUMO Interface node".  The
//! paper did this by hand and suggested scripting it; this module is
//! that script.

use std::path::Path;

use crate::sumo::network::Network;
use crate::sumo::xmlio;
use crate::sumo::FlowFile;
use crate::webots::World;
use crate::{Error, Result};

use super::ports::PortAllocator;

/// One propagated simulation copy: world + SUMO config set.
#[derive(Debug, Clone, PartialEq)]
pub struct SimCopy {
    /// Copy index (the `SIM_$(($PBS_ARRAY_INDEX % 8))` number).
    pub index: u16,
    pub port: u16,
    pub world: World,
}

/// Clone the root world n times, rewriting each copy's SumoInterface
/// port per the allocator.  Fails when the root world has no
/// SumoInterface node (non-SUMO worlds don't need copies — §3.1.5 says
/// plain-Webots parallelism only needs `xvfb-run -a`).
pub fn propagate_copies(root: &World, n: u16, ports: &PortAllocator) -> Result<Vec<SimCopy>> {
    if root.find("SumoInterface").is_none() {
        return Err(Error::World(
            "world has no SumoInterface node; copies are only needed for SUMO-coupled sims"
                .into(),
        ));
    }
    let plan = ports.plan(n)?;
    let mut out = Vec::with_capacity(n as usize);
    for (i, &port) in plan.iter().enumerate() {
        let mut w = root.clone();
        w.find_mut("SumoInterface")
            .ok_or_else(|| {
                Error::World("SumoInterface vanished between find and find_mut".into())
            })?
            .set_field("port", port.to_string());
        out.push(SimCopy {
            index: i as u16,
            port,
            world: w,
        });
    }
    Ok(out)
}

/// Materialize the copy tree on disk the way the PBS script expects it:
///
/// ```text
/// dir/SIM_0.wbt  dir/SIM_0_net/sumo.net.xml  dir/SIM_0_net/sumo.flow.xml
/// dir/SIM_1.wbt  ...
/// ```
pub fn write_copy_tree(
    dir: &Path,
    copies: &[SimCopy],
    net: &Network,
    flows: &FlowFile,
) -> Result<()> {
    for c in copies {
        c.world.save(&dir.join(format!("SIM_{}.wbt", c.index)))?;
        let net_dir = dir.join(format!("SIM_{}_net", c.index));
        std::fs::create_dir_all(&net_dir)?;
        xmlio::save(&net_dir.join("sumo.net.xml"), &xmlio::write_net_xml(net))?;
        xmlio::save(&net_dir.join("sumo.flow.xml"), &xmlio::write_flow_xml(flows))?;
    }
    Ok(())
}

#[cfg(test)]
#[allow(clippy::unwrap_used, clippy::expect_used)]
mod tests {
    use super::*;
    use crate::sumo::MergeScenario;
    use crate::webots::nodes::sample_merge_world;

    #[test]
    fn copies_get_unique_ports() {
        let root = sample_merge_world(8873);
        let copies = propagate_copies(&root, 8, &PortAllocator::default()).unwrap();
        assert_eq!(copies.len(), 8);
        let mut ports: Vec<u16> = copies.iter().map(|c| c.port).collect();
        assert_eq!(ports[0], 8873);
        ports.dedup();
        assert_eq!(ports.len(), 8, "all ports unique");
        // worlds differ ONLY in the port field
        for c in &copies {
            let mut w = c.world.clone();
            w.find_mut("SumoInterface").unwrap().set_field("port", "8873");
            assert_eq!(w, root);
        }
    }

    #[test]
    fn non_sumo_world_rejected() {
        let mut w = World::new();
        w.nodes.push(
            crate::webots::nodes::WorldInfo {
                basic_time_step_ms: 100,
                optimal_thread_count: 1,
            }
            .to_node(),
        );
        assert!(propagate_copies(&w, 2, &PortAllocator::default()).is_err());
    }

    #[test]
    fn copy_tree_layout_matches_pbs_script() {
        let dir = crate::util::TempDir::new("webots-hpc-copies").unwrap();
        let root = sample_merge_world(8873);
        let copies = propagate_copies(&root, 3, &PortAllocator::default()).unwrap();
        let scenario = MergeScenario::default();
        let flows = FlowFile::merge_sample(1200.0, 300.0, 300.0);
        write_copy_tree(dir.path(), &copies, &scenario.network(), &flows).unwrap();
        for i in 0..3 {
            assert!(dir.path().join(format!("SIM_{i}.wbt")).exists());
            assert!(dir.path().join(format!("SIM_{i}_net/sumo.net.xml")).exists());
            assert!(dir.path().join(format!("SIM_{i}_net/sumo.flow.xml")).exists());
        }
        // reload a copy and check its port survived the disk trip
        let w = World::load(&dir.path().join("SIM_2.wbt")).unwrap();
        assert_eq!(
            w.find("SumoInterface").unwrap().field_u32("port"),
            Some(8887)
        );
    }
}
