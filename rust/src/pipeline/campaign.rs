//! Campaign driver: the ch. 5 experiments as discrete-event runs.
//!
//! A *campaign* is a long sequence of simulation runs.  The cluster form
//! submits one PBS array per walltime epoch (the paper's "each job
//! contains 48 instances" with a 15-minute walltime, §5.2); the
//! personal-computer baseline runs instances back-to-back on a single
//! machine with manual-triggering overhead between runs.

use crate::cluster::{Cluster, ClusterQueue, NodeSpec, QueueSpec, ResourceDemand};
use crate::metrics::{CostModel, SimWorkload, UsageReporter, UsageSummary};
use crate::pbs::{
    ArrayRange, Job, JobId, PackingPolicy, ResourceRequest, Scheduler, SchedulerConfig,
    SchedulerStats,
};
use crate::scenario::{RunAssignment, ScenarioMatrix};
use crate::simclock::{SimDuration, SimInstant};
use crate::Result;

/// A throughput sample: cumulative completed runs at a timestamp — one
/// row-cell of Table 5.1.
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct ThroughputSample {
    pub minutes: u64,
    pub completed: u64,
}

/// Campaign configuration.
#[derive(Debug, Clone)]
pub struct CampaignSpec {
    /// Compute nodes allocated.
    pub nodes: usize,
    /// Parallel instances per node (8 in the paper's 6x8 setup).
    pub slots_per_node: u32,
    /// Per-instance resource chunk.
    pub chunk: ResourceDemand,
    /// Per-job walltime (also the epoch length).
    pub walltime: SimDuration,
    /// Total campaign duration.
    pub duration: SimDuration,
    /// Cost model of one run.
    pub cost: CostModel,
    /// Workload seed.
    pub seed: u64,
    /// Packing policy (ablation).
    pub policy: PackingPolicy,
    /// Timestamps (minutes) at which to sample throughput.
    pub sample_minutes: Vec<u64>,
    /// Scenario-matrix mode: fan sampled scenario points across the
    /// campaign's nodes × slots (None = the classic single-scenario
    /// campaign, where every run is the same world under a fresh seed).
    pub matrix: Option<ScenarioMatrix>,
}

impl CampaignSpec {
    /// The paper's cluster experiment: 6 nodes × 8 slots, 15-minute
    /// epochs, 12 hours (§5.1).
    pub fn paper_cluster() -> Self {
        CampaignSpec {
            nodes: 6,
            slots_per_node: 8,
            chunk: ResourceDemand::paper_slot(),
            walltime: SimDuration::from_minutes(15),
            duration: SimDuration::from_hours(12),
            cost: CostModel::paper_merge_sim(),
            seed: 2021,
            policy: PackingPolicy::FirstFit,
            sample_minutes: vec![30, 60, 90, 120, 240, 360, 720],
            matrix: None,
        }
    }

    /// The 6x1 serial configuration of §5.3.
    pub fn paper_serial_6x1() -> Self {
        CampaignSpec {
            slots_per_node: 1,
            chunk: ResourceDemand::whole_node(),
            ..Self::paper_cluster()
        }
    }

    pub fn instances_per_epoch(&self) -> u32 {
        self.nodes as u32 * self.slots_per_node
    }

    pub fn epochs(&self) -> u64 {
        self.duration.as_millis() / self.walltime.as_millis()
    }

    /// Switch the campaign into scenario-matrix mode.
    pub fn with_matrix(mut self, matrix: ScenarioMatrix) -> Self {
        self.matrix = Some(matrix);
        self
    }

    /// Total runs the campaign will launch over its lifetime.
    pub fn total_runs(&self) -> u64 {
        self.epochs() * self.instances_per_epoch() as u64
    }

    /// Scenario-matrix mode's per-slot fan-out: the assignment slot
    /// `array_index` of epoch `epoch` materializes.  Pure — a node
    /// needs only the campaign constants and its own coordinates, no
    /// coordination (mirrors the per-run `--seed $RANDOM` mechanism).
    pub fn scenario_assignment(&self, epoch: u64, array_index: u32) -> Option<RunAssignment> {
        self.matrix.as_ref().map(|m| {
            m.assignment(epoch * self.instances_per_epoch() as u64 + array_index as u64)
        })
    }
}

/// What a campaign produced.
#[derive(Debug, Clone)]
pub struct CampaignResult {
    pub samples: Vec<ThroughputSample>,
    pub stats: SchedulerStats,
    pub usage: UsageSummary,
    /// Per-node completed-run counts (distribution quality, §5.2).
    pub runs_per_node: Vec<u64>,
    /// Max per-node live occupancy observed right after each submission.
    pub peak_occupancy: Vec<usize>,
    /// Supervision accounting (attempts, retries, kills, degradations)
    /// — populated by `run_supervised_campaign`; None for the
    /// discrete-event drivers, which model no faults.
    pub robustness: Option<super::RobustnessStats>,
}

impl CampaignResult {
    /// Completed runs at the final sample (the Table 5.1 bottom row).
    pub fn total_completed(&self) -> u64 {
        self.stats.completed
    }

    /// §5.2's distribution-evenness check: all nodes within `tol` of the
    /// mean run count.
    pub fn distribution_even(&self, tol: f64) -> bool {
        if self.runs_per_node.is_empty() {
            return true;
        }
        let mean = self.runs_per_node.iter().sum::<u64>() as f64
            / self.runs_per_node.len() as f64;
        self.runs_per_node
            .iter()
            .all(|&c| (c as f64 - mean).abs() <= tol * mean.max(1.0))
    }
}

/// Fold supervision accounting + the assembled dataset into a
/// [`CampaignResult`] — one construction shared by the in-process
/// supervised driver and the fabric coordinator, so both report the
/// same shape for the same campaign.
pub(crate) fn supervised_result(
    stats: super::RobustnessStats,
    walltimes_s: &[f64],
    dataset: &crate::output::CampaignDataset,
    nodes: usize,
) -> CampaignResult {
    let mean = |v: &[f64]| {
        if v.is_empty() {
            0.0
        } else {
            v.iter().sum::<f64>() / v.len() as f64
        }
    };
    CampaignResult {
        samples: Vec::new(),
        stats: SchedulerStats {
            submitted: stats.runs,
            completed: stats.completed,
            killed_walltime: stats.killed_walltime,
            failed: stats.failed,
        },
        usage: UsageSummary {
            runs: walltimes_s.len(),
            mean_walltime_s: mean(walltimes_s),
            // the supervised drivers have no cgroup accounting; walltime
            // is the honest stand-in (single-threaded instances)
            mean_cpu_time_s: mean(walltimes_s),
            mean_ram_gb: 0.0,
            mean_cpu_percent: 100.0,
        },
        runs_per_node: dataset
            .runs_per_node(nodes)
            .into_iter()
            .map(|c| c as u64)
            .collect(),
        peak_occupancy: vec![1; nodes],
        robustness: Some(stats),
    }
}

/// Run the epoch-locked cluster campaign.
pub fn run_cluster_campaign(spec: &CampaignSpec) -> Result<CampaignResult> {
    let cluster = Cluster::uniform("campaign", spec.nodes, NodeSpec::dice_r740());
    let queue = ClusterQueue::new(QueueSpec::dicelab(spec.nodes));
    let mut sched = Scheduler::new(
        cluster,
        queue,
        SchedulerConfig {
            policy: spec.policy,
            backfill: true,
        },
    );

    let request = ResourceRequest {
        select: 1,
        chunk: spec.chunk,
        interconnect: None,
        walltime: spec.walltime,
    };

    let mut peak_occupancy = vec![0usize; spec.nodes];
    for epoch in 0..spec.epochs() {
        let at = SimInstant::ZERO + SimDuration(epoch * spec.walltime.as_millis());
        sched.run_until(at);
        let job = Job::new(JobId(0), format!("webots-e{epoch}"), request.clone())
            .with_array(ArrayRange::new(1, spec.instances_per_epoch())?);
        let workload = SimWorkload::new(spec.cost, spec.seed.wrapping_add(epoch));
        sched.submit(job, Box::new(workload))?;
        for (peak, &o) in peak_occupancy.iter_mut().zip(sched.occupancy().iter()) {
            *peak = (*peak).max(o);
        }
    }
    let end = SimInstant::ZERO + spec.duration;
    sched.run_until(end);

    let samples = spec
        .sample_minutes
        .iter()
        .map(|&m| ThroughputSample {
            minutes: m,
            completed: sched.completed_at(SimInstant::ZERO + SimDuration::from_minutes(m)),
        })
        .collect();

    let mut runs_per_node = vec![0u64; spec.nodes];
    for c in sched.completions() {
        if c.state != crate::pbs::JobState::Completed {
            continue;
        }
        if let Some(n) = runs_per_node.get_mut(c.node) {
            *n += 1;
        }
    }

    Ok(CampaignResult {
        samples,
        stats: sched.stats(),
        usage: UsageReporter::summarize(sched.records()),
        runs_per_node,
        peak_occupancy,
        robustness: None,
    })
}

/// The personal-computer baseline of §5.1: one machine, strictly
/// sequential runs, plus per-run manual-triggering overhead.
///
/// Calibration note (documented in EXPERIMENTS.md): the paper's PC
/// column averages ~9.7 min/run while its own Table 5.3 measures the
/// simulation at ~4 min on identical hardware; the difference is the
/// un-pipelined overhead of one-off, manually-triggered runs (session
/// setup, route regeneration, result collection).  We model that as a
/// fixed `manual_overhead_s` per run.
pub fn pc_campaign(
    cost: &CostModel,
    manual_overhead_s: f64,
    duration: SimDuration,
    sample_minutes: &[u64],
) -> CampaignResult {
    let pc = NodeSpec::personal_computer();
    let per_run_s = cost.walltime_s(pc.cores) + manual_overhead_s;
    let total_s = duration.as_secs_f64();
    let completed = (total_s / per_run_s).floor() as u64;

    let samples = sample_minutes
        .iter()
        .map(|&m| ThroughputSample {
            minutes: m,
            completed: ((m * 60) as f64 / per_run_s).floor() as u64,
        })
        .collect();

    let usage = UsageSummary {
        runs: completed as usize,
        mean_walltime_s: cost.walltime_s(pc.cores),
        mean_cpu_time_s: cost.cpu_time_s(pc.cores),
        mean_ram_gb: cost.ram_gb,
        mean_cpu_percent: 100.0 * cost.cpu_time_s(pc.cores) / cost.walltime_s(pc.cores),
    };

    CampaignResult {
        samples,
        stats: SchedulerStats {
            submitted: completed,
            completed,
            killed_walltime: 0,
            failed: 0,
        },
        usage,
        runs_per_node: vec![completed],
        peak_occupancy: vec![1],
        robustness: None,
    }
}

/// The paper's observed PC pace: ~74 runs in 720 minutes → ≈583 s/run;
/// the cost model gives ≈245 s of compute, so the calibrated overhead is
/// the remainder.
pub const PAPER_PC_OVERHEAD_S: f64 = 338.0;

#[cfg(test)]
#[allow(clippy::unwrap_used, clippy::expect_used)]
mod tests {
    use super::*;

    #[test]
    fn cluster_campaign_matches_table_5_1() {
        let result = run_cluster_campaign(&CampaignSpec::paper_cluster()).unwrap();
        // 48 instances per 15-min epoch → 48·t completed datasets
        for s in &result.samples {
            let t = s.minutes / 15;
            assert_eq!(s.completed, 48 * t, "at {} min", s.minutes);
        }
        assert_eq!(result.total_completed(), 2304);
        // the paper's headline: 100% completion
        assert_eq!(result.stats.completion_rate(), 1.0);
    }

    #[test]
    fn distribution_is_perfectly_even() {
        let result = run_cluster_campaign(&CampaignSpec::paper_cluster()).unwrap();
        assert_eq!(result.runs_per_node, vec![384; 6]);
        assert!(result.distribution_even(0.0));
        assert_eq!(result.peak_occupancy, vec![8; 6]);
    }

    #[test]
    fn pc_baseline_matches_paper_pace() {
        let r = pc_campaign(
            &CostModel::paper_merge_sim(),
            PAPER_PC_OVERHEAD_S,
            SimDuration::from_hours(12),
            &[30, 60, 90, 120, 240, 360, 720],
        );
        // paper: 74 runs after 720 min — accept ±10%
        let total = r.total_completed() as f64;
        assert!((total - 74.0).abs() / 74.0 < 0.10, "total = {total}");
    }

    #[test]
    fn speedup_is_about_31x() {
        let cluster = run_cluster_campaign(&CampaignSpec::paper_cluster()).unwrap();
        let pc = pc_campaign(
            &CostModel::paper_merge_sim(),
            PAPER_PC_OVERHEAD_S,
            SimDuration::from_hours(12),
            &[720],
        );
        let speedup = cluster.total_completed() as f64 / pc.total_completed() as f64;
        assert!(
            (speedup - 31.0).abs() < 3.0,
            "speedup = {speedup} (paper: ~31x)"
        );
    }

    #[test]
    fn serial_6x1_campaign_runs() {
        let mut spec = CampaignSpec::paper_serial_6x1();
        spec.duration = SimDuration::from_hours(1);
        let r = run_cluster_campaign(&spec).unwrap();
        assert_eq!(r.peak_occupancy, vec![1; 6]);
        // 6 instances per epoch, 4 epochs
        assert_eq!(r.total_completed(), 24);
    }

    #[test]
    fn scenario_matrix_fans_evenly_without_coordination() {
        use crate::scenario::{SamplerKind, ScenarioMatrix};
        let spec = CampaignSpec::paper_cluster().with_matrix(ScenarioMatrix::new(
            vec![
                "highway-merge".into(),
                "lane-drop".into(),
                "ramp-weave".into(),
                "ring-shockwave".into(),
            ],
            SamplerKind::Lhs { strata: 16 },
            16,
            2021,
        ));
        // one epoch = 48 instances → 12 per family, round-robin
        let mut per_family = std::collections::BTreeMap::new();
        for slot in 0..spec.instances_per_epoch() {
            let a = spec.scenario_assignment(0, slot).unwrap();
            *per_family.entry(a.family).or_insert(0u32) += 1;
        }
        assert_eq!(per_family.len(), 4);
        assert!(per_family.values().all(|&c| c == 12));

        // pure: any node recomputes its own assignment identically
        assert_eq!(
            spec.scenario_assignment(3, 17),
            spec.scenario_assignment(3, 17)
        );
        // every run of the full 12-hour campaign gets a unique seed
        let mut seeds: Vec<u64> = (0..spec.epochs())
            .flat_map(|e| {
                (0..spec.instances_per_epoch())
                    .map(move |s| (e, s))
            })
            .map(|(e, s)| spec.scenario_assignment(e, s).unwrap().run_seed)
            .collect();
        assert_eq!(seeds.len() as u64, spec.total_runs());
        seeds.sort_unstable();
        seeds.dedup();
        assert_eq!(seeds.len() as u64, spec.total_runs());

        // classic campaigns stay matrix-free
        assert!(CampaignSpec::paper_cluster()
            .scenario_assignment(0, 0)
            .is_none());
    }

    #[test]
    fn scaling_doubles_with_nodes() {
        // §5.1's scaling prediction: 12 nodes → ~2x the runs
        let mut spec = CampaignSpec::paper_cluster();
        spec.duration = SimDuration::from_hours(2);
        let six = run_cluster_campaign(&spec).unwrap();
        spec.nodes = 12;
        let twelve = run_cluster_campaign(&spec).unwrap();
        assert_eq!(twelve.total_completed(), 2 * six.total_completed());
    }
}
