//! Walltime selection.
//!
//! §5.2: "the pipeline implemented a 15-minute walltime for each
//! triggered job ... This walltime is specific to the simulation running
//! on the pipeline and will thus need to be determined prior to running
//! a large sequence."  We determine it from the cost model plus a safety
//! margin, rounded up to the scheduler's granularity.

use crate::metrics::CostModel;
use crate::simclock::SimDuration;

/// How much headroom to leave over the expected run time.
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct WalltimePolicy {
    /// Multiplier on the expected walltime (jitter + cold caches).
    pub safety_factor: f64,
    /// Round up to a multiple of this many minutes (PBS convention).
    pub granularity_min: u64,
}

impl Default for WalltimePolicy {
    fn default() -> Self {
        WalltimePolicy {
            safety_factor: 2.0,
            granularity_min: 15,
        }
    }
}

/// Pick the per-job walltime for a run on `cores` cores.
pub fn pick_walltime(cost: &CostModel, cores: u32, policy: &WalltimePolicy) -> SimDuration {
    let expected_s = cost.walltime_s(cores) * policy.safety_factor;
    let gran_s = (policy.granularity_min * 60) as f64;
    let rounded = (expected_s / gran_s).ceil() * gran_s;
    SimDuration::from_secs_f64(rounded.max(gran_s))
}

#[cfg(test)]
#[allow(clippy::unwrap_used, clippy::expect_used)]
mod tests {
    use super::*;

    #[test]
    fn paper_slot_gets_15_minutes() {
        // expected ≈ 245 s; ×2 safety ≈ 490 s → rounds to 900 s = 15 min,
        // exactly the paper's experimental walltime.
        let w = pick_walltime(
            &CostModel::paper_merge_sim(),
            5,
            &WalltimePolicy::default(),
        );
        assert_eq!(w.as_minutes(), 15);
    }

    #[test]
    fn whole_node_also_15_minutes() {
        let w = pick_walltime(
            &CostModel::paper_merge_sim(),
            40,
            &WalltimePolicy::default(),
        );
        assert_eq!(w.as_minutes(), 15);
    }

    #[test]
    fn long_sims_round_up() {
        let mut cost = CostModel::paper_merge_sim();
        cost.serial_s = 1000.0;
        let w = pick_walltime(&cost, 5, &WalltimePolicy::default());
        assert_eq!(w.as_millis() % (15 * 60 * 1000), 0);
        assert!(w.as_minutes() >= 30);
    }

    #[test]
    fn minimum_one_granule() {
        let mut cost = CostModel::paper_merge_sim();
        cost.serial_s = 0.1;
        cost.parallel_core_s = 0.1;
        let w = pick_walltime(&cost, 40, &WalltimePolicy::default());
        assert_eq!(w.as_minutes(), 15);
    }
}
