//! Crash-safe campaign ledger: the pipeline's source of truth for which
//! runs are done.
//!
//! §5.1's "100% completion" is only checkable if completion is *recorded
//! somewhere that survives the recorder*.  The ledger is an append-only
//! JSONL file — one compact [`crate::util::Json`] object per line, one
//! line per state transition — fsynced after every append, so a
//! `qdel`-ed job, an OOM kill or a node reboot loses at most the line
//! being written.  On reopen the ledger replays the file; a torn final
//! line (the crash's half-written record) is dropped, every earlier
//! transition is intact, and the supervised campaign re-materializes
//! only the runs without a `completed` record.
//!
//! Transitions per `(epoch, slot)` run:
//! `pending` (absent) → `running {attempt}` → `completed {attempts,
//! degraded}` | `failed {attempts, class, error}`.  A `running` record
//! with no terminal record marks the run the crash interrupted — it is
//! re-run on resume (re-running a half-finished instance is safe: result
//! CSVs are written atomically before `completed` is appended, and
//! run_ids are deterministic so the rewrite is byte-identical).

use std::collections::BTreeMap;
use std::fs::{File, OpenOptions};
use std::io::Write;
use std::path::{Path, PathBuf};

use crate::telemetry::{self, EventKind};
use crate::util::Json;
use crate::{Error, Result};

/// Replayed state of one run.
#[derive(Debug, Clone, PartialEq)]
pub enum LedgerState {
    /// A `running` record with no terminal record — in flight when the
    /// process died; must be re-run.
    Running { attempt: u32 },
    /// Terminal success.
    Completed { attempts: u32, degraded: bool },
    /// Terminal failure (permanent error or retry budget exhausted).
    Failed {
        attempts: u32,
        class: String,
        error: String,
    },
}

/// One replayed run entry: where it sits in the campaign grid plus its
/// latest state.
#[derive(Debug, Clone, PartialEq)]
pub struct LedgerEntry {
    pub epoch: u32,
    pub slot: u32,
    pub state: LedgerState,
}

/// Append-only JSONL ledger for one campaign.
#[derive(Debug)]
pub struct CampaignLedger {
    path: PathBuf,
    file: File,
    entries: BTreeMap<String, LedgerEntry>,
    header: Option<Json>,
}

/// One replayed ledger line: the campaign header or a run transition.
enum Replayed {
    Header(Json),
    Entry(String, LedgerEntry),
}

impl CampaignLedger {
    /// Open (creating if absent) and replay the ledger at `path`.
    ///
    /// Replay is tolerant of exactly one torn line — the *final* one, a
    /// crash mid-append.  The torn fragment is truncated off the file
    /// before the ledger reopens for append: leaving it in place would
    /// glue the resumed session's first record onto the fragment,
    /// producing a merged garbage line that is no longer final once
    /// more records follow — and every later `open` would then refuse
    /// the whole ledger as corrupt.  A malformed line followed by more
    /// records means the file was corrupted some other way, and the
    /// ledger refuses to guess.
    pub fn open(path: impl Into<PathBuf>) -> Result<CampaignLedger> {
        let path = path.into();
        if let Some(dir) = path.parent() {
            std::fs::create_dir_all(dir)?;
        }
        let mut entries = BTreeMap::new();
        let mut header: Option<Json> = None;
        let mut torn_at: Option<u64> = None;
        if path.exists() {
            let content = std::fs::read_to_string(&path)?;
            let raw_lines: Vec<&str> = content.split_inclusive('\n').collect();
            let mut offset: u64 = 0;
            for (i, raw) in raw_lines.iter().enumerate() {
                let line_start = offset;
                offset += raw.len() as u64;
                let line = raw.trim_end_matches('\n');
                if line.trim().is_empty() {
                    continue;
                }
                match Json::parse(line).and_then(replay_line) {
                    Ok(Replayed::Header(h)) => {
                        header = Some(h);
                    }
                    Ok(Replayed::Entry(run_id, entry)) => {
                        entries.insert(run_id, entry);
                    }
                    Err(e) if i + 1 == raw_lines.len() => {
                        // torn final line: the crash this ledger exists
                        // to survive — drop it (the run re-runs) and
                        // remember where it starts, for truncation
                        let _ = e;
                        torn_at = Some(line_start);
                    }
                    Err(e) => {
                        return Err(Error::Artifact(format!(
                            "ledger {} corrupt at line {}: {e}",
                            path.display(),
                            i + 1
                        )));
                    }
                }
            }
        }
        let file = OpenOptions::new().create(true).append(true).open(&path)?;
        if let Some(len) = torn_at {
            // cut the fragment off so the next append starts a clean
            // line (O_APPEND writes land at the new, truncated EOF)
            file.set_len(len)?;
            file.sync_data()?;
        }
        Ok(CampaignLedger {
            path,
            file,
            entries,
            header,
        })
    }

    /// The ledger file location (for operator messages).
    pub fn path(&self) -> &Path {
        &self.path
    }

    /// The replayed campaign header, if one was written.
    pub fn header(&self) -> Option<&Json> {
        self.header.as_ref()
    }

    /// Bind this ledger to a campaign shape.
    ///
    /// The first open writes `fingerprint` (tagged `"state":
    /// "campaign"`) as the ledger's header record; every later open
    /// must present the identical fingerprint or the ledger refuses to
    /// resume.  Without this guard, resuming a ledger dir under a
    /// changed spec would reuse matching run_ids/CSV paths while
    /// recomputing seeds and `(epoch, slot)` coordinates under the new
    /// grid — silently mislabeling the rebuilt aggregate.
    pub fn ensure_header(&mut self, fingerprint: &Json) -> Result<()> {
        let record = fingerprint.clone().with("state", Json::str("campaign"));
        match &self.header {
            Some(existing) => {
                if existing.to_compact_string() != record.to_compact_string() {
                    return Err(Error::Artifact(format!(
                        "ledger {} belongs to a different campaign shape:\n  \
                         recorded:  {}\n  requested: {}\n\
                         use a fresh ledger dir for a changed campaign",
                        self.path.display(),
                        existing.to_compact_string(),
                        record.to_compact_string()
                    )));
                }
                Ok(())
            }
            None => {
                let mut line = record.to_compact_string();
                line.push('\n');
                self.file.write_all(line.as_bytes())?;
                self.file.flush()?;
                self.file.sync_data()?;
                self.header = Some(record);
                Ok(())
            }
        }
    }

    /// Latest replayed state for `run_id` (`None` = pending, never
    /// attempted).
    pub fn state(&self, run_id: &str) -> Option<&LedgerEntry> {
        self.entries.get(run_id)
    }

    /// Has `run_id` a terminal `completed` record?  The resume
    /// predicate: completed runs are skipped, everything else
    /// re-materializes.
    pub fn is_completed(&self, run_id: &str) -> bool {
        matches!(
            self.entries.get(run_id),
            Some(LedgerEntry {
                state: LedgerState::Completed { .. },
                ..
            })
        )
    }

    /// Completed runs in `(epoch, slot)` order — the resume-side view
    /// used to rebuild the aggregate dataset.
    pub fn completed(&self) -> Vec<(String, LedgerEntry)> {
        let mut done: Vec<(String, LedgerEntry)> = self
            .entries
            .iter()
            .filter(|(_, e)| matches!(e.state, LedgerState::Completed { .. }))
            .map(|(k, e)| (k.clone(), e.clone()))
            .collect();
        done.sort_by_key(|(_, e)| (e.epoch, e.slot));
        done
    }

    /// Record `run_id` entering attempt `attempt`.
    ///
    /// `Completed` is terminal: once a run has a durable completion
    /// record, a late `running` write (a re-dispatch decided before
    /// the completion settled, landing after it) is silently dropped —
    /// otherwise replay would regress the run to `Running` and the
    /// aggregate walk would drop its row.
    pub fn mark_running(
        &mut self,
        run_id: &str,
        epoch: u32,
        slot: u32,
        attempt: u32,
    ) -> Result<()> {
        if self.is_completed(run_id) {
            return Ok(());
        }
        let record = base_record(run_id, epoch, slot, "running")
            .with("attempt", Json::num(attempt as f64));
        self.append(
            run_id,
            LedgerEntry {
                epoch,
                slot,
                state: LedgerState::Running { attempt },
            },
            record,
        )
    }

    /// Record terminal success after `attempts` launch attempts.
    pub fn mark_completed(
        &mut self,
        run_id: &str,
        epoch: u32,
        slot: u32,
        attempts: u32,
        degraded: bool,
    ) -> Result<()> {
        let record = base_record(run_id, epoch, slot, "completed")
            .with("attempts", Json::num(attempts as f64))
            .with("degraded", Json::Bool(degraded));
        self.append(
            run_id,
            LedgerEntry {
                epoch,
                slot,
                state: LedgerState::Completed { attempts, degraded },
            },
            record,
        )
    }

    /// Record terminal failure with its error class and message.
    ///
    /// Like [`mark_running`](Self::mark_running), this never regresses
    /// a `Completed` run: completion is terminal.
    pub fn mark_failed(
        &mut self,
        run_id: &str,
        epoch: u32,
        slot: u32,
        attempts: u32,
        class: &str,
        error: &str,
    ) -> Result<()> {
        if self.is_completed(run_id) {
            return Ok(());
        }
        let record = base_record(run_id, epoch, slot, "failed")
            .with("attempts", Json::num(attempts as f64))
            .with("class", Json::str(class))
            .with("error", Json::str(error));
        self.append(
            run_id,
            LedgerEntry {
                epoch,
                slot,
                state: LedgerState::Failed {
                    attempts,
                    class: class.to_string(),
                    error: error.to_string(),
                },
            },
            record,
        )
    }

    fn append(&mut self, run_id: &str, entry: LedgerEntry, record: Json) -> Result<()> {
        let mut line = record.to_compact_string();
        line.push('\n');
        self.file.write_all(line.as_bytes())?;
        self.file.flush()?;
        // durability is the whole point: one fsync per transition
        self.file.sync_data()?;
        // mirror the durable transition into the event stream — the e2e
        // contract is events ⊇ ledger, so emit only after the fsync
        if telemetry::enabled() {
            let state = match &entry.state {
                LedgerState::Running { .. } => "running",
                LedgerState::Completed { .. } => "completed",
                LedgerState::Failed { .. } => "failed",
            };
            telemetry::emit(EventKind::LedgerTransition {
                run_id: run_id.to_string(),
                state: state.to_string(),
            });
        }
        self.entries.insert(run_id.to_string(), entry);
        Ok(())
    }
}

/// Builder sugar for the record objects.
trait WithField {
    fn with(self, key: &str, value: Json) -> Json;
}

impl WithField for Json {
    fn with(self, key: &str, value: Json) -> Json {
        match self {
            Json::Obj(mut m) => {
                m.insert(key.to_string(), value);
                Json::Obj(m)
            }
            other => other,
        }
    }
}

fn base_record(run_id: &str, epoch: u32, slot: u32, state: &str) -> Json {
    Json::obj(vec![
        ("run_id", Json::str(run_id)),
        ("epoch", Json::num(epoch as f64)),
        ("slot", Json::num(slot as f64)),
        ("state", Json::str(state)),
    ])
}

fn replay_line(j: Json) -> Result<Replayed> {
    if matches!(j.get("state").and_then(Json::as_str), Ok("campaign")) {
        return Ok(Replayed::Header(j));
    }
    let (run_id, entry) = replay_record(&j)?;
    Ok(Replayed::Entry(run_id, entry))
}

fn replay_record(j: &Json) -> Result<(String, LedgerEntry)> {
    let run_id = j.get("run_id")?.as_str()?.to_string();
    let epoch = j.get("epoch")?.as_f64()? as u32;
    let slot = j.get("slot")?.as_f64()? as u32;
    let state = match j.get("state")?.as_str()? {
        "running" => LedgerState::Running {
            attempt: j.get("attempt")?.as_f64()? as u32,
        },
        "completed" => LedgerState::Completed {
            attempts: j.get("attempts")?.as_f64()? as u32,
            degraded: matches!(j.get("degraded")?, Json::Bool(true)),
        },
        "failed" => LedgerState::Failed {
            attempts: j.get("attempts")?.as_f64()? as u32,
            class: j.get("class")?.as_str()?.to_string(),
            error: j.get("error")?.as_str()?.to_string(),
        },
        other => {
            return Err(Error::Artifact(format!("unknown ledger state {other:?}")));
        }
    };
    Ok((run_id, LedgerEntry { epoch, slot, state }))
}

#[cfg(test)]
#[allow(clippy::unwrap_used, clippy::expect_used)]
mod tests {
    use super::*;

    fn tmp(name: &str) -> PathBuf {
        let dir = std::env::temp_dir().join("webots_hpc_ledger_tests");
        std::fs::create_dir_all(&dir).unwrap();
        let path = dir.join(format!("{name}_{}.jsonl", std::process::id()));
        let _ = std::fs::remove_file(&path);
        path
    }

    /// Completed is terminal: late `running`/`failed` writes for a run
    /// that already settled (a fabric re-dispatch whose original result
    /// landed first) must not regress the replayed state — in memory or
    /// across reopen.
    #[test]
    fn completed_is_terminal() {
        let path = tmp("terminal");
        {
            let mut l = CampaignLedger::open(&path).unwrap();
            l.mark_running("a-e0[0]", 0, 0, 1).unwrap();
            l.mark_completed("a-e0[0]", 0, 0, 1, false).unwrap();
            l.mark_running("a-e0[0]", 0, 0, 2).unwrap();
            l.mark_failed("a-e0[0]", 0, 0, 2, "transient", "zombie").unwrap();
            assert!(l.is_completed("a-e0[0]"));
            // a plain failed run can still be retried (resume contract)
            l.mark_running("a-e0[1]", 0, 1, 1).unwrap();
            l.mark_failed("a-e0[1]", 0, 1, 1, "transient", "boom").unwrap();
            l.mark_running("a-e0[1]", 0, 1, 2).unwrap();
            assert_eq!(
                l.state("a-e0[1]").unwrap().state,
                LedgerState::Running { attempt: 2 }
            );
        }
        let l = CampaignLedger::open(&path).unwrap();
        assert_eq!(
            l.state("a-e0[0]").unwrap().state,
            LedgerState::Completed {
                attempts: 1,
                degraded: false
            }
        );
    }

    #[test]
    fn transitions_replay_across_reopen() {
        let path = tmp("replay");
        {
            let mut l = CampaignLedger::open(&path).unwrap();
            l.mark_running("a-e0[0]", 0, 0, 0).unwrap();
            l.mark_completed("a-e0[0]", 0, 0, 1, false).unwrap();
            l.mark_running("a-e0[1]", 0, 1, 0).unwrap();
            l.mark_running("a-e0[1]", 0, 1, 1).unwrap();
            l.mark_failed("a-e0[1]", 0, 1, 2, "permanent", "bad config")
                .unwrap();
            l.mark_running("a-e1[0]", 1, 0, 0).unwrap();
            // a-e1[0] left running: the crash-interrupted run
        }
        let l = CampaignLedger::open(&path).unwrap();
        assert!(l.is_completed("a-e0[0]"));
        assert!(!l.is_completed("a-e0[1]"));
        assert!(!l.is_completed("a-e1[0]"));
        assert_eq!(
            l.state("a-e0[0]").unwrap().state,
            LedgerState::Completed {
                attempts: 1,
                degraded: false
            }
        );
        assert_eq!(
            l.state("a-e0[1]").unwrap().state,
            LedgerState::Failed {
                attempts: 2,
                class: "permanent".into(),
                error: "bad config".into()
            }
        );
        assert_eq!(
            l.state("a-e1[0]").unwrap().state,
            LedgerState::Running { attempt: 0 }
        );
        assert_eq!(l.completed().len(), 1);
        let _ = std::fs::remove_file(&path);
    }

    #[test]
    fn torn_final_line_is_dropped_not_fatal() {
        let path = tmp("torn");
        {
            let mut l = CampaignLedger::open(&path).unwrap();
            l.mark_running("r-e0[0]", 0, 0, 0).unwrap();
            l.mark_completed("r-e0[0]", 0, 0, 1, true).unwrap();
        }
        // simulate a crash mid-append: half a record, no newline
        {
            let mut f = OpenOptions::new().append(true).open(&path).unwrap();
            f.write_all(b"{\"run_id\":\"r-e0[1]\",\"ep").unwrap();
        }
        let l = CampaignLedger::open(&path).unwrap();
        assert!(l.is_completed("r-e0[0]"));
        assert_eq!(
            l.state("r-e0[0]").unwrap().state,
            LedgerState::Completed {
                attempts: 1,
                degraded: true
            }
        );
        assert!(l.state("r-e0[1]").is_none(), "torn record must vanish");
        let _ = std::fs::remove_file(&path);
    }

    #[test]
    fn torn_tail_then_resume_then_reopen_keeps_every_record() {
        let path = tmp("torn_resume");
        {
            let mut l = CampaignLedger::open(&path).unwrap();
            l.mark_running("t-e0[0]", 0, 0, 0).unwrap();
            l.mark_completed("t-e0[0]", 0, 0, 1, false).unwrap();
        }
        // crash mid-append: half a record, no newline
        {
            let mut f = OpenOptions::new().append(true).open(&path).unwrap();
            f.write_all(b"{\"run_id\":\"t-e0[1]\",\"ep").unwrap();
        }
        // resumed session: the torn fragment must be truncated, so
        // these appends start clean lines instead of gluing onto it
        {
            let mut l = CampaignLedger::open(&path).unwrap();
            l.mark_running("t-e0[1]", 0, 1, 0).unwrap();
            l.mark_completed("t-e0[1]", 0, 1, 1, false).unwrap();
        }
        // a third open must replay every record — before truncation,
        // the glued garbage line sat mid-file and poisoned the ledger
        let l = CampaignLedger::open(&path).unwrap();
        assert!(l.is_completed("t-e0[0]"));
        assert!(l.is_completed("t-e0[1]"));
        assert_eq!(l.completed().len(), 2);
        let _ = std::fs::remove_file(&path);
    }

    #[test]
    fn header_binds_the_ledger_to_one_campaign_shape() {
        let path = tmp("header");
        let shape = |nodes: f64| {
            Json::obj(vec![
                ("name", Json::str("camp")),
                ("nodes", Json::num(nodes)),
                ("seed", Json::str("2021")),
            ])
        };
        {
            let mut l = CampaignLedger::open(&path).unwrap();
            assert!(l.header().is_none());
            l.ensure_header(&shape(2.0)).unwrap();
            l.mark_completed("camp-e0[0]", 0, 0, 1, false).unwrap();
        }
        // same shape: resumes, entries intact
        {
            let mut l = CampaignLedger::open(&path).unwrap();
            assert!(l.header().is_some());
            l.ensure_header(&shape(2.0)).unwrap();
            assert!(l.is_completed("camp-e0[0]"));
        }
        // changed shape: refused, nothing silently relabeled
        let mut l = CampaignLedger::open(&path).unwrap();
        let err = l.ensure_header(&shape(3.0)).unwrap_err();
        assert!(
            err.to_string().contains("different campaign shape"),
            "{err}"
        );
        let _ = std::fs::remove_file(&path);
    }

    #[test]
    fn corruption_before_the_end_is_fatal() {
        let path = tmp("corrupt");
        std::fs::write(
            &path,
            "not json at all\n{\"run_id\":\"x\",\"epoch\":0,\"slot\":0,\"state\":\"running\",\"attempt\":0}\n",
        )
        .unwrap();
        assert!(CampaignLedger::open(&path).is_err());
        let _ = std::fs::remove_file(&path);
    }

    #[test]
    fn latest_transition_wins() {
        let path = tmp("latest");
        let mut l = CampaignLedger::open(&path).unwrap();
        l.mark_running("w-e0[0]", 0, 0, 0).unwrap();
        assert!(!l.is_completed("w-e0[0]"));
        l.mark_completed("w-e0[0]", 0, 0, 3, false).unwrap();
        assert!(l.is_completed("w-e0[0]"));
        let _ = std::fs::remove_file(&path);
    }

    #[test]
    fn interleaved_remote_completions_replay_cleanly() {
        // the fabric coordinator interleaves transitions from many
        // workers into ONE ledger: runs go running in dispatch order
        // but settle in whatever order workers finish, with lease
        // expiry re-marking a run running at a higher attempt before
        // the re-dispatch settles it
        let path = tmp("interleaved");
        {
            let mut l = CampaignLedger::open(&path).unwrap();
            l.mark_running("f-e0[0]", 0, 0, 1).unwrap(); // leased to w1
            l.mark_running("f-e0[1]", 0, 1, 1).unwrap(); // leased to w2
            l.mark_completed("f-e0[1]", 0, 1, 1, false).unwrap(); // w2 first
            l.mark_running("f-e0[0]", 0, 0, 2).unwrap(); // w1 reaped, re-dispatched
            l.mark_running("f-e0[2]", 0, 2, 1).unwrap(); // w3 joins mid-flight
            l.mark_completed("f-e0[0]", 0, 0, 1, false).unwrap(); // re-dispatch lands
            l.mark_completed("f-e0[2]", 0, 2, 2, true).unwrap();
        }
        // a fresh coordinator replays the exact same terminal picture
        let l = CampaignLedger::open(&path).unwrap();
        assert!(l.is_completed("f-e0[0]"));
        assert!(l.is_completed("f-e0[1]"));
        assert!(l.is_completed("f-e0[2]"));
        assert_eq!(l.completed().len(), 3);
        let order: Vec<(u32, u32)> = l
            .completed()
            .iter()
            .map(|(_, e)| (e.epoch, e.slot))
            .collect();
        assert_eq!(order, vec![(0, 0), (0, 1), (0, 2)], "grid order, not settle order");
        assert_eq!(
            l.state("f-e0[2]").unwrap().state,
            LedgerState::Completed {
                attempts: 2,
                degraded: true
            }
        );
        let _ = std::fs::remove_file(&path);
    }

    #[test]
    fn completed_sorted_by_epoch_then_slot() {
        let path = tmp("sorted");
        let mut l = CampaignLedger::open(&path).unwrap();
        l.mark_completed("c-e1[0]", 1, 0, 1, false).unwrap();
        l.mark_completed("c-e0[2]", 0, 2, 1, false).unwrap();
        l.mark_completed("c-e0[1]", 0, 1, 1, false).unwrap();
        let order: Vec<(u32, u32)> = l
            .completed()
            .iter()
            .map(|(_, e)| (e.epoch, e.slot))
            .collect();
        assert_eq!(order, vec![(0, 1), (0, 2), (1, 0)]);
        let _ = std::fs::remove_file(&path);
    }
}
