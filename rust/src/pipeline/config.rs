//! Campaign configuration files — the paper's §6.2.1 future work.
//!
//! "Several parameters, like the name of the job, the number of
//! instances, the job queue, and the hardware requirements of the PBS
//! script could be inputted into a user interface, rather than the
//! current process of manually editing the script."  This is that
//! interface: a `key = value` config file that generates both the
//! [`CampaignSpec`] and the PBS script, so users never hand-edit either.

use crate::cluster::ResourceDemand;
use crate::pbs::script::PbsScript;
use crate::pbs::{ArrayRange, PackingPolicy, ResourceRequest};
use crate::simclock::SimDuration;
use crate::{Error, Result};

use super::campaign::CampaignSpec;

/// User-facing campaign parameters (see [`CampaignConfig::example`]).
#[derive(Debug, Clone, PartialEq)]
pub struct CampaignConfig {
    pub name: String,
    pub queue: String,
    pub nodes: usize,
    pub slots_per_node: u32,
    pub ncpus_per_slot: u32,
    pub mem_gb_per_slot: f64,
    pub walltime_min: u64,
    pub duration_hours: u64,
    pub seed: u64,
    pub policy: PackingPolicy,
}

impl Default for CampaignConfig {
    fn default() -> Self {
        CampaignConfig {
            name: "webots".into(),
            queue: "dicelab".into(),
            nodes: 6,
            slots_per_node: 8,
            ncpus_per_slot: 5,
            mem_gb_per_slot: 93.0,
            walltime_min: 15,
            duration_hours: 12,
            seed: 2021,
            policy: PackingPolicy::FirstFit,
        }
    }
}

impl CampaignConfig {
    /// An annotated example config (what `webots-hpc config-init` writes).
    pub fn example() -> String {
        r#"# Webots.HPC campaign configuration
# (generates the PBS script AND the campaign spec — paper §6.2.1)
name = webots
queue = dicelab
nodes = 6
slots_per_node = 8
ncpus_per_slot = 5
mem_gb_per_slot = 93
walltime_min = 15
duration_hours = 12
seed = 2021
policy = first-fit
"#
        .to_string()
    }

    /// Parse `key = value` text (comments with `#`).
    pub fn parse(text: &str) -> Result<CampaignConfig> {
        let mut cfg = CampaignConfig::default();
        for (lineno, raw) in text.lines().enumerate() {
            let line = raw.split('#').next().unwrap_or("").trim();
            if line.is_empty() {
                continue;
            }
            let (k, v) = line
                .split_once('=')
                .ok_or_else(|| Error::Config(format!("line {}: expected key = value", lineno + 1)))?;
            let (k, v) = (k.trim(), v.trim());
            let bad = |e: &dyn std::fmt::Display| {
                Error::Config(format!("line {}: bad {k}: {e}", lineno + 1))
            };
            match k {
                "name" => cfg.name = v.to_string(),
                "queue" => cfg.queue = v.to_string(),
                "nodes" => cfg.nodes = v.parse().map_err(|e| bad(&e))?,
                "slots_per_node" => cfg.slots_per_node = v.parse().map_err(|e| bad(&e))?,
                "ncpus_per_slot" => cfg.ncpus_per_slot = v.parse().map_err(|e| bad(&e))?,
                "mem_gb_per_slot" => cfg.mem_gb_per_slot = v.parse().map_err(|e| bad(&e))?,
                "walltime_min" => cfg.walltime_min = v.parse().map_err(|e| bad(&e))?,
                "duration_hours" => cfg.duration_hours = v.parse().map_err(|e| bad(&e))?,
                "seed" => cfg.seed = v.parse().map_err(|e| bad(&e))?,
                "policy" => {
                    cfg.policy = match v {
                        "first-fit" => PackingPolicy::FirstFit,
                        "round-robin" => PackingPolicy::RoundRobin,
                        other => return Err(Error::Config(format!("unknown policy '{other}'"))),
                    }
                }
                other => return Err(Error::Config(format!("unknown config key '{other}'"))),
            }
        }
        cfg.validate()?;
        Ok(cfg)
    }

    pub fn validate(&self) -> Result<()> {
        if self.nodes == 0 || self.slots_per_node == 0 {
            return Err(Error::Config("nodes and slots_per_node must be > 0".into()));
        }
        if self.ncpus_per_slot * self.slots_per_node > 40 {
            return Err(Error::Config(format!(
                "{} slots x {} cpus oversubscribes a 40-core node",
                self.slots_per_node, self.ncpus_per_slot
            )));
        }
        Ok(())
    }

    /// Derive the campaign spec the scheduler consumes.
    pub fn to_spec(&self) -> CampaignSpec {
        CampaignSpec {
            nodes: self.nodes,
            slots_per_node: self.slots_per_node,
            chunk: ResourceDemand {
                ncpus: self.ncpus_per_slot,
                mem_gb: self.mem_gb_per_slot,
                scratch_gb: 0.0,
                ngpus: 0,
            },
            walltime: SimDuration::from_minutes(self.walltime_min),
            duration: SimDuration::from_hours(self.duration_hours),
            policy: self.policy,
            seed: self.seed,
            ..CampaignSpec::paper_cluster()
        }
    }

    /// Derive the PBS script (the artifact users used to hand-edit).
    pub fn to_pbs_script(&self) -> Result<PbsScript> {
        let array = ArrayRange::new(1, self.nodes as u32 * self.slots_per_node)?;
        Ok(PbsScript {
            name: self.name.clone(),
            queue: self.queue.clone(),
            request: ResourceRequest {
                select: 1,
                chunk: ResourceDemand {
                    ncpus: self.ncpus_per_slot,
                    mem_gb: self.mem_gb_per_slot,
                    scratch_gb: 0.0,
                    ngpus: 0,
                },
                interconnect: None,
                walltime: SimDuration::from_minutes(self.walltime_min),
            },
            array: Some(array),
            body: vec![
                "echo Generating new random routes...".into(),
                format!(
                    "singularity exec -B $TMPDIR:$TMPDIR webots_sumo.sif duarouter --route-files SIM_$(($PBS_ARRAY_INDEX % {s}))_net/sumo.flow.xml --net-file SIM_$(($PBS_ARRAY_INDEX % {s}))_net/sumo.net.xml --output-file SIM_$(($PBS_ARRAY_INDEX % {s}))_net/sumo.rou.xml --randomize-flows true --seed $RANDOM",
                    s = self.slots_per_node
                ),
                "echo Starting Webots on `hostname`".into(),
                format!(
                    "singularity exec -B $TMPDIR:$TMPDIR webots_sumo.sif xvfb-run -a webots --stdout --stderr --batch --mode=realtime SIM_$(($PBS_ARRAY_INDEX % {})).wbt",
                    self.slots_per_node
                ),
            ],
        })
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::pipeline::run_cluster_campaign;

    #[test]
    fn example_parses_to_paper_defaults() {
        let cfg = CampaignConfig::parse(&CampaignConfig::example()).unwrap();
        assert_eq!(cfg, CampaignConfig::default());
    }

    #[test]
    fn spec_and_script_agree() {
        let cfg = CampaignConfig::default();
        let spec = cfg.to_spec();
        let script = cfg.to_pbs_script().unwrap();
        assert_eq!(spec.instances_per_epoch(), script.array.unwrap().len());
        assert_eq!(
            spec.walltime.as_minutes() * 60,
            script.request.walltime.as_millis() / 1000
        );
        // the generated script parses back
        let reparsed = PbsScript::parse(&script.render()).unwrap();
        assert_eq!(reparsed.request.chunk.ncpus, 5);
    }

    #[test]
    fn config_driven_campaign_runs() {
        let mut cfg = CampaignConfig::default();
        cfg.duration_hours = 1;
        let r = run_cluster_campaign(&cfg.to_spec()).unwrap();
        assert_eq!(r.total_completed(), 4 * 48);
    }

    #[test]
    fn rejects_bad_configs() {
        assert!(CampaignConfig::parse("nodes = zero").is_err());
        assert!(CampaignConfig::parse("warp = 9").is_err());
        assert!(CampaignConfig::parse("nodes 6").is_err());
        // oversubscription guard
        assert!(CampaignConfig::parse("slots_per_node = 16\nncpus_per_slot = 5").is_err());
    }

    #[test]
    fn comments_and_blanks_ignored() {
        let cfg = CampaignConfig::parse("# hi\n\nnodes = 3 # trailing\n").unwrap();
        assert_eq!(cfg.nodes, 3);
    }
}
