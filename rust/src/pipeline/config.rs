//! Campaign configuration files — the paper's §6.2.1 future work.
//!
//! "Several parameters, like the name of the job, the number of
//! instances, the job queue, and the hardware requirements of the PBS
//! script could be inputted into a user interface, rather than the
//! current process of manually editing the script."  This is that
//! interface: a `key = value` config file that generates both the
//! [`CampaignSpec`] and the PBS script, so users never hand-edit either.

use crate::cluster::ResourceDemand;
use crate::pbs::script::PbsScript;
use crate::pbs::{ArrayRange, PackingPolicy, ResourceRequest};
use crate::scenario::{FamilyRegistry, SamplerKind, ScenarioMatrix};
use crate::simclock::SimDuration;
use crate::{Error, Result};

use super::campaign::CampaignSpec;

/// Fused-chunk policy for an instance's physics stepping (the
/// `chunk_steps` campaign key): how many physics steps the `SumoSim`
/// chunk scheduler may hand the stepper as ONE dispatch.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Default)]
pub enum ChunkSteps {
    /// Use the artifact manifest's whole rollout K ladder (the
    /// default — the scheduler picks the largest fusible rung).
    #[default]
    Auto,
    /// Clamp fused chunks to exactly K steps.  K must be 1 or a lowered
    /// ladder rung — validated against the live manifest at launch
    /// ([`super::launch_instance`]), not at parse time, because the
    /// ladder is the artifact's to declare.  K = 1 is what
    /// TraCI-attached live-GUI runs force so frame streaming never
    /// starves behind a 32-step chunk.
    Fixed(u32),
}

impl ChunkSteps {
    /// Parse the config value: `auto` or an explicit step count.
    pub fn parse(v: &str) -> Result<ChunkSteps> {
        if v.eq_ignore_ascii_case("auto") {
            return Ok(ChunkSteps::Auto);
        }
        let k: u32 = v
            .parse()
            .map_err(|e| Error::Config(format!("bad chunk_steps '{v}': {e}")))?;
        if k == 0 {
            return Err(Error::Config(
                "chunk_steps must be 'auto' or a step count >= 1".into(),
            ));
        }
        Ok(ChunkSteps::Fixed(k))
    }

    /// The chunk cap this policy imposes on a simulation.
    pub fn limit(&self) -> usize {
        match self {
            ChunkSteps::Auto => usize::MAX,
            ChunkSteps::Fixed(k) => *k as usize,
        }
    }
}

/// User-facing campaign parameters (see [`CampaignConfig::example`]).
#[derive(Debug, Clone, PartialEq)]
pub struct CampaignConfig {
    pub name: String,
    pub queue: String,
    pub nodes: usize,
    pub slots_per_node: u32,
    pub ncpus_per_slot: u32,
    pub mem_gb_per_slot: f64,
    pub walltime_min: u64,
    pub duration_hours: u64,
    pub seed: u64,
    pub policy: PackingPolicy,
    /// Scenario-matrix mode: family ids to sweep (empty = classic
    /// single-scenario campaign).
    pub scenarios: Vec<String>,
    /// Sampled points per family.
    pub scenario_samples: usize,
    /// Sampler name: `grid[:k]`, `uniform`, or `lhs[:n]`.
    pub sampler: String,
    /// Fused-chunk policy (`auto` | explicit K, validated against the
    /// manifest's rollout ladder at launch).  Consumed by the real
    /// instance launchers — thread it into each instance with
    /// [`super::InstanceConfig::with_chunk_steps`] (the CLI's
    /// `run-local --chunk` does; the simulated PBS campaign launches
    /// no real instances, so there it only documents intent).
    pub chunk_steps: ChunkSteps,
    /// Retries per run beyond the first attempt (transient failures
    /// only — permanent errors never retry).
    pub max_retries: u32,
    /// Backoff before the first retry [ms]; doubles per retry.
    pub backoff_base_ms: u64,
    /// Backoff ceiling [ms].
    pub backoff_cap_ms: u64,
    /// Stall watchdog: max wall time one TraCI burst may take [ms]
    /// (0 = disabled).
    pub stall_window_ms: u64,
    /// Per-instance walltime deadline [s] (0 = disabled).
    pub instance_walltime_s: u64,
    /// Fabric: heartbeat cadence workers keep per lease [ms].
    pub heartbeat_ms: u64,
    /// Fabric: lease TTL the coordinator's reaper enforces [ms]; a
    /// lease silent this long is revoked and re-dispatched.
    pub lease_ttl_ms: u64,
}

impl Default for CampaignConfig {
    fn default() -> Self {
        CampaignConfig {
            name: "webots".into(),
            queue: "dicelab".into(),
            nodes: 6,
            slots_per_node: 8,
            ncpus_per_slot: 5,
            mem_gb_per_slot: 93.0,
            walltime_min: 15,
            duration_hours: 12,
            seed: 2021,
            policy: PackingPolicy::FirstFit,
            scenarios: Vec::new(),
            scenario_samples: 16,
            sampler: "lhs".into(),
            chunk_steps: ChunkSteps::Auto,
            max_retries: 3,
            backoff_base_ms: 250,
            backoff_cap_ms: 5000,
            stall_window_ms: 0,
            instance_walltime_s: 0,
            heartbeat_ms: 500,
            lease_ttl_ms: 3000,
        }
    }
}

impl CampaignConfig {
    /// An annotated example config (what `webots-hpc config-init` writes).
    pub fn example() -> String {
        r#"# Webots.HPC campaign configuration
# (generates the PBS script AND the campaign spec — paper §6.2.1)
name = webots
queue = dicelab
nodes = 6
slots_per_node = 8
ncpus_per_slot = 5
mem_gb_per_slot = 93
walltime_min = 15
duration_hours = 12
seed = 2021
policy = first-fit

# fused physics chunks: how many steps one PJRT dispatch may advance a
# run (auto = the artifact manifest's whole rollout K ladder; an
# explicit K is validated against that ladder at launch; live-GUI runs
# force 1 regardless so frame streaming never starves)
chunk_steps = auto

# run supervision (see EXPERIMENTS.md §Robustness): transient failures
# retry under exponential backoff with seeded jitter; permanent
# (config/manifest) errors never retry.  Watchdogs are opt-in: 0
# disables (the step budget stays the only guard)
max_retries = 3
backoff_base_ms = 250
backoff_cap_ms = 5000
stall_window_ms = 0
instance_walltime_s = 0

# distributed fabric (webots-hpc coordinate / work): workers heartbeat
# each held lease every heartbeat_ms; the coordinator's reaper revokes
# and re-dispatches any lease silent for lease_ttl_ms (must be at least
# twice the heartbeat, or a healthy worker would miss its own lease)
heartbeat_ms = 500
lease_ttl_ms = 3000

# scenario-matrix mode — uncomment to sweep a scenario space across
# the array instead of re-running one world (see EXPERIMENTS.md
# §Scenario sweeps):
# scenarios = highway-merge,lane-drop,ramp-weave,ring-shockwave
# sampler = lhs
# scenario_samples = 16
"#
        .to_string()
    }

    /// Parse `key = value` text (comments with `#`).
    pub fn parse(text: &str) -> Result<CampaignConfig> {
        let mut cfg = CampaignConfig::default();
        for (lineno, raw) in text.lines().enumerate() {
            let line = raw.split('#').next().unwrap_or("").trim();
            if line.is_empty() {
                continue;
            }
            let (k, v) = line
                .split_once('=')
                .ok_or_else(|| Error::Config(format!("line {}: expected key = value", lineno + 1)))?;
            let (k, v) = (k.trim(), v.trim());
            let bad = |e: &dyn std::fmt::Display| {
                Error::Config(format!("line {}: bad {k}: {e}", lineno + 1))
            };
            match k {
                "name" => cfg.name = v.to_string(),
                "queue" => cfg.queue = v.to_string(),
                "nodes" => cfg.nodes = v.parse().map_err(|e| bad(&e))?,
                "slots_per_node" => cfg.slots_per_node = v.parse().map_err(|e| bad(&e))?,
                "ncpus_per_slot" => cfg.ncpus_per_slot = v.parse().map_err(|e| bad(&e))?,
                "mem_gb_per_slot" => cfg.mem_gb_per_slot = v.parse().map_err(|e| bad(&e))?,
                "walltime_min" => cfg.walltime_min = v.parse().map_err(|e| bad(&e))?,
                "duration_hours" => cfg.duration_hours = v.parse().map_err(|e| bad(&e))?,
                "seed" => cfg.seed = v.parse().map_err(|e| bad(&e))?,
                "scenarios" => {
                    cfg.scenarios = v
                        .split(',')
                        .map(|s| s.trim().to_string())
                        .filter(|s| !s.is_empty())
                        .collect()
                }
                "scenario_samples" => cfg.scenario_samples = v.parse().map_err(|e| bad(&e))?,
                "sampler" => cfg.sampler = v.to_string(),
                "chunk_steps" => cfg.chunk_steps = ChunkSteps::parse(v)?,
                "max_retries" => cfg.max_retries = v.parse().map_err(|e| bad(&e))?,
                "backoff_base_ms" => cfg.backoff_base_ms = v.parse().map_err(|e| bad(&e))?,
                "backoff_cap_ms" => cfg.backoff_cap_ms = v.parse().map_err(|e| bad(&e))?,
                "stall_window_ms" => cfg.stall_window_ms = v.parse().map_err(|e| bad(&e))?,
                "instance_walltime_s" => {
                    cfg.instance_walltime_s = v.parse().map_err(|e| bad(&e))?
                }
                "heartbeat_ms" => cfg.heartbeat_ms = v.parse().map_err(|e| bad(&e))?,
                "lease_ttl_ms" => cfg.lease_ttl_ms = v.parse().map_err(|e| bad(&e))?,
                "policy" => {
                    cfg.policy = match v {
                        "first-fit" => PackingPolicy::FirstFit,
                        "round-robin" => PackingPolicy::RoundRobin,
                        other => return Err(Error::Config(format!("unknown policy '{other}'"))),
                    }
                }
                other => return Err(Error::Config(format!("unknown config key '{other}'"))),
            }
        }
        cfg.validate()?;
        Ok(cfg)
    }

    pub fn validate(&self) -> Result<()> {
        if self.nodes == 0 || self.slots_per_node == 0 {
            return Err(Error::Config("nodes and slots_per_node must be > 0".into()));
        }
        if self.ncpus_per_slot * self.slots_per_node > 40 {
            return Err(Error::Config(format!(
                "{} slots x {} cpus oversubscribes a 40-core node",
                self.slots_per_node, self.ncpus_per_slot
            )));
        }
        if self.heartbeat_ms == 0 || self.lease_ttl_ms == 0 {
            return Err(Error::Config(
                "heartbeat_ms and lease_ttl_ms must be > 0".into(),
            ));
        }
        if self.lease_ttl_ms < 2 * self.heartbeat_ms {
            return Err(Error::Config(format!(
                "lease_ttl_ms ({}) must be at least twice heartbeat_ms ({}): \
                 a healthy worker would miss its own lease",
                self.lease_ttl_ms, self.heartbeat_ms
            )));
        }
        if !self.scenarios.is_empty() {
            let registry = FamilyRegistry::builtin();
            for id in &self.scenarios {
                registry.get(id)?;
            }
            if self.scenario_samples == 0 {
                return Err(Error::Config("scenario_samples must be > 0".into()));
            }
            let kind = self.sampler_kind()?;
            // a grid sweep that enumerates fewer points than the lattice
            // silently pins the trailing axes at their low endpoints —
            // refuse the misconfiguration instead
            if let SamplerKind::Grid { points_per_axis } = kind {
                for id in &self.scenarios {
                    let space = registry.get(id)?.space();
                    let lattice =
                        crate::scenario::GridSampler { points_per_axis }.total_points(&space);
                    if (self.scenario_samples as u64) < lattice {
                        return Err(Error::Config(format!(
                            "grid sweep of '{id}' has {lattice} lattice points but \
                             scenario_samples = {}; raise scenario_samples or shrink \
                             the grid (sampler = grid:<k>)",
                            self.scenario_samples
                        )));
                    }
                }
            }
        }
        Ok(())
    }

    /// The parsed sampler selector (`lhs` defaults its strata to
    /// `scenario_samples`).
    pub fn sampler_kind(&self) -> Result<SamplerKind> {
        SamplerKind::parse(&self.sampler, self.scenario_samples)
    }

    /// The supervision policy these keys describe (fault plan stays
    /// None — injection is a test seam, never config-reachable).
    pub fn to_supervisor_spec(&self) -> super::SupervisorSpec {
        use std::time::Duration;
        super::SupervisorSpec {
            retry: super::RetryPolicy {
                max_attempts: self.max_retries + 1,
                base_ms: self.backoff_base_ms,
                cap_ms: self.backoff_cap_ms,
            },
            watchdog: crate::webots::WatchdogSpec {
                walltime: (self.instance_walltime_s > 0)
                    .then(|| Duration::from_secs(self.instance_walltime_s)),
                stall_window: (self.stall_window_ms > 0)
                    .then(|| Duration::from_millis(self.stall_window_ms)),
            },
            degrade: true,
            fault_plan: None,
        }
    }

    /// The fabric knobs these keys describe (port 0 = OS-assigned;
    /// the kill seam is a test seam, never config-reachable).
    pub fn to_fabric_config(&self) -> crate::fabric::FabricConfig {
        crate::fabric::FabricConfig {
            port: 0,
            heartbeat_ms: self.heartbeat_ms,
            lease_ttl_ms: self.lease_ttl_ms,
            stop_after_completions: None,
        }
    }

    /// The scenario matrix this config describes, if any.
    pub fn to_matrix(&self) -> Result<Option<ScenarioMatrix>> {
        if self.scenarios.is_empty() {
            return Ok(None);
        }
        Ok(Some(ScenarioMatrix::new(
            self.scenarios.clone(),
            self.sampler_kind()?,
            self.scenario_samples,
            self.seed,
        )))
    }

    /// Derive the campaign spec the scheduler consumes.  Errors when
    /// the scenario-matrix keys are inconsistent (programmatic configs
    /// that skipped [`Self::validate`]) — a campaign must never
    /// silently degrade from a scenario sweep to the classic
    /// single-scenario mode.
    pub fn to_spec(&self) -> Result<CampaignSpec> {
        Ok(CampaignSpec {
            matrix: self.to_matrix()?,
            nodes: self.nodes,
            slots_per_node: self.slots_per_node,
            chunk: ResourceDemand {
                ncpus: self.ncpus_per_slot,
                mem_gb: self.mem_gb_per_slot,
                scratch_gb: 0.0,
                ngpus: 0,
            },
            walltime: SimDuration::from_minutes(self.walltime_min),
            duration: SimDuration::from_hours(self.duration_hours),
            policy: self.policy,
            seed: self.seed,
            ..CampaignSpec::paper_cluster()
        })
    }

    /// Derive the PBS script (the artifact users used to hand-edit).
    pub fn to_pbs_script(&self) -> Result<PbsScript> {
        let array = ArrayRange::new(1, self.nodes as u32 * self.slots_per_node)?;
        Ok(PbsScript {
            name: self.name.clone(),
            queue: self.queue.clone(),
            request: ResourceRequest {
                select: 1,
                chunk: ResourceDemand {
                    ncpus: self.ncpus_per_slot,
                    mem_gb: self.mem_gb_per_slot,
                    scratch_gb: 0.0,
                    ngpus: 0,
                },
                interconnect: None,
                walltime: SimDuration::from_minutes(self.walltime_min),
            },
            array: Some(array),
            body: vec![
                "echo Generating new random routes...".into(),
                format!(
                    "singularity exec -B $TMPDIR:$TMPDIR webots_sumo.sif duarouter --route-files SIM_$(($PBS_ARRAY_INDEX % {s}))_net/sumo.flow.xml --net-file SIM_$(($PBS_ARRAY_INDEX % {s}))_net/sumo.net.xml --output-file SIM_$(($PBS_ARRAY_INDEX % {s}))_net/sumo.rou.xml --randomize-flows true --seed $RANDOM",
                    s = self.slots_per_node
                ),
                "echo Starting Webots on `hostname`".into(),
                format!(
                    "singularity exec -B $TMPDIR:$TMPDIR webots_sumo.sif xvfb-run -a webots --stdout --stderr --batch --mode=realtime SIM_$(($PBS_ARRAY_INDEX % {})).wbt",
                    self.slots_per_node
                ),
            ],
        })
    }
}

#[cfg(test)]
#[allow(clippy::unwrap_used, clippy::expect_used)]
mod tests {
    use super::*;
    use crate::pipeline::run_cluster_campaign;

    #[test]
    fn example_parses_to_paper_defaults() {
        let cfg = CampaignConfig::parse(&CampaignConfig::example()).unwrap();
        assert_eq!(cfg, CampaignConfig::default());
    }

    #[test]
    fn spec_and_script_agree() {
        let cfg = CampaignConfig::default();
        let spec = cfg.to_spec().unwrap();
        let script = cfg.to_pbs_script().unwrap();
        assert_eq!(spec.instances_per_epoch(), script.array.unwrap().len());
        assert_eq!(
            spec.walltime.as_minutes() * 60,
            script.request.walltime.as_millis() / 1000
        );
        // the generated script parses back
        let reparsed = PbsScript::parse(&script.render()).unwrap();
        assert_eq!(reparsed.request.chunk.ncpus, 5);
    }

    #[test]
    fn config_driven_campaign_runs() {
        let mut cfg = CampaignConfig::default();
        cfg.duration_hours = 1;
        let r = run_cluster_campaign(&cfg.to_spec().unwrap()).unwrap();
        assert_eq!(r.total_completed(), 4 * 48);
    }

    #[test]
    fn rejects_bad_configs() {
        assert!(CampaignConfig::parse("nodes = zero").is_err());
        assert!(CampaignConfig::parse("warp = 9").is_err());
        assert!(CampaignConfig::parse("nodes 6").is_err());
        // oversubscription guard
        assert!(CampaignConfig::parse("slots_per_node = 16\nncpus_per_slot = 5").is_err());
    }

    #[test]
    fn scenario_matrix_config_roundtrip() {
        let cfg = CampaignConfig::parse(
            "scenarios = lane-drop, ring-shockwave\nsampler = lhs\nscenario_samples = 8\n",
        )
        .unwrap();
        assert_eq!(cfg.scenarios, vec!["lane-drop", "ring-shockwave"]);
        let m = cfg.to_matrix().unwrap().unwrap();
        assert_eq!(m.samples_per_family, 8);
        assert_eq!(m.total_points(), 16);
        let spec = cfg.to_spec().unwrap();
        assert!(spec.scenario_assignment(0, 0).is_some());
        // classic configs stay matrix-free
        assert!(CampaignConfig::default().to_matrix().unwrap().is_none());
        assert!(CampaignConfig::default().to_spec().unwrap().matrix.is_none());
    }

    #[test]
    fn unknown_scenario_family_rejected() {
        assert!(CampaignConfig::parse("scenarios = warp-drive").is_err());
        assert!(CampaignConfig::parse("scenarios = lane-drop\nsampler = sobol").is_err());
        assert!(
            CampaignConfig::parse("scenarios = lane-drop\nscenario_samples = 0").is_err()
        );
    }

    #[test]
    fn under_covering_grid_rejected() {
        // lane-drop's grid:2 lattice is 2^7 = 128 points; 16 samples
        // would silently pin the trailing axes at their low endpoints
        assert!(CampaignConfig::parse("scenarios = lane-drop\nsampler = grid:2").is_err());
        let ok = CampaignConfig::parse(
            "scenarios = lane-drop\nsampler = grid:2\nscenario_samples = 128",
        )
        .unwrap();
        assert_eq!(ok.to_matrix().unwrap().unwrap().total_points(), 128);
    }

    #[test]
    fn chunk_steps_key_roundtrip() {
        let cfg = CampaignConfig::parse("chunk_steps = auto").unwrap();
        assert_eq!(cfg.chunk_steps, ChunkSteps::Auto);
        assert_eq!(cfg.chunk_steps.limit(), usize::MAX);
        let cfg = CampaignConfig::parse("chunk_steps = 8").unwrap();
        assert_eq!(cfg.chunk_steps, ChunkSteps::Fixed(8));
        assert_eq!(cfg.chunk_steps.limit(), 8);
        // K=0 and junk are parse errors; ladder membership is a LAUNCH
        // check (the manifest owns the ladder), not a parse check
        assert!(CampaignConfig::parse("chunk_steps = 0").is_err());
        assert!(CampaignConfig::parse("chunk_steps = fast").is_err());
        assert_eq!(CampaignConfig::default().chunk_steps, ChunkSteps::Auto);
    }

    #[test]
    fn supervision_keys_roundtrip() {
        use std::time::Duration;
        let cfg = CampaignConfig::parse(
            "max_retries = 5\nbackoff_base_ms = 10\nbackoff_cap_ms = 100\n\
             stall_window_ms = 250\ninstance_walltime_s = 600\n",
        )
        .unwrap();
        let spec = cfg.to_supervisor_spec();
        assert_eq!(spec.retry.max_attempts, 6, "retries + the first attempt");
        assert_eq!(spec.retry.base_ms, 10);
        assert_eq!(spec.retry.cap_ms, 100);
        assert_eq!(spec.watchdog.stall_window, Some(Duration::from_millis(250)));
        assert_eq!(spec.watchdog.walltime, Some(Duration::from_secs(600)));
        assert!(spec.degrade);
        assert!(spec.fault_plan.is_none(), "injection is never config-reachable");
        // defaults: watchdogs disabled
        let spec = CampaignConfig::default().to_supervisor_spec();
        assert_eq!(spec.retry.max_attempts, 4);
        assert_eq!(spec.watchdog, crate::webots::WatchdogSpec::default());
    }

    #[test]
    fn fabric_keys_roundtrip_and_validate() {
        let cfg = CampaignConfig::parse("heartbeat_ms = 100\nlease_ttl_ms = 400\n").unwrap();
        let fabric = cfg.to_fabric_config();
        assert_eq!(fabric.heartbeat_ms, 100);
        assert_eq!(fabric.lease_ttl_ms, 400);
        assert_eq!(fabric.port, 0, "port is always OS-assigned from config");
        assert!(fabric.stop_after_completions.is_none(), "kill seam never config-reachable");
        // a TTL a healthy worker would trip is a config error
        assert!(CampaignConfig::parse("heartbeat_ms = 500\nlease_ttl_ms = 600\n").is_err());
        assert!(CampaignConfig::parse("heartbeat_ms = 0\n").is_err());
        assert!(CampaignConfig::parse("lease_ttl_ms = 0\n").is_err());
        // defaults satisfy their own validation
        let d = CampaignConfig::default();
        assert_eq!((d.heartbeat_ms, d.lease_ttl_ms), (500, 3000));
        d.validate().unwrap();
    }

    #[test]
    fn comments_and_blanks_ignored() {
        let cfg = CampaignConfig::parse("# hi\n\nnodes = 3 # trailing\n").unwrap();
        assert_eq!(cfg.nodes, 3);
    }
}
