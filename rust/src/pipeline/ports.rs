//! TraCI port allocation across parallel simulation copies.
//!
//! §4.2.1: "We tended to increment the default port value of 8873 by 7
//! for each successive parallel simulation and ran into no further
//! issues on this front."  Any positive step works (the ablation bench
//! compares 1 vs 7 vs 0 — step 0 reproduces the crash); the allocator
//! also guards the u16 range.
//!
//! [`PortLease`] is the race-free ephemeral allocator: it binds port 0
//! and *holds the bound listener* until the TraCI server redeems it at
//! spawn time — closing the probe-then-close TOCTOU window the old
//! `free_port` helper documented as "absorbed by retry".

use std::collections::HashMap;
use std::net::TcpListener;
use std::sync::{Mutex, OnceLock};

use crate::traci::{DEFAULT_PORT, PORT_STEP};
use crate::{Error, Result};

/// Listeners held by live [`PortLease`]s, keyed by port.  The launcher
/// redeems from here at the moment the TraCI server would otherwise
/// rebind — same port, zero unbound window.
fn registry() -> &'static Mutex<HashMap<u16, TcpListener>> {
    static REGISTRY: OnceLock<Mutex<HashMap<u16, TcpListener>>> = OnceLock::new();
    REGISTRY.get_or_init(|| Mutex::new(HashMap::new()))
}

fn registry_lock() -> std::sync::MutexGuard<'static, HashMap<u16, TcpListener>> {
    // a poisoned registry only means another thread panicked while
    // holding the map; the map itself (port → listener) stays coherent
    registry().lock().unwrap_or_else(|p| p.into_inner())
}

/// An ephemeral loopback port, leased by *binding* it.
///
/// The OS picks a free port at bind time and this lease keeps the
/// listener alive, so no other process (or sibling slot) can take the
/// port while the lease is held.  [`crate::traci::TraciServer`]
/// redeems the bound listener itself via [`redeem`]; if the lease has
/// already been consumed (a retry after the first launch attempt), the
/// server falls back to a fresh bind — a loss there is a transient
/// `PortInUse`, absorbed by the supervisor's retry.
#[derive(Debug)]
pub struct PortLease {
    port: u16,
}

impl PortLease {
    /// Bind an OS-assigned loopback port and hold it.
    pub fn acquire() -> Result<PortLease> {
        let listener = TcpListener::bind(("127.0.0.1", 0))?;
        let port = listener.local_addr()?.port();
        registry_lock().insert(port, listener);
        Ok(PortLease { port })
    }

    /// The leased port number.
    pub fn port(&self) -> u16 {
        self.port
    }
}

impl Drop for PortLease {
    fn drop(&mut self) {
        // the listener may already have been redeemed by the server —
        // removing a missing entry is fine
        registry_lock().remove(&self.port);
    }
}

/// Take the held listener for `port`, if a live lease holds one.
pub(crate) fn redeem(port: u16) -> Option<TcpListener> {
    registry_lock().remove(&port)
}

/// Deterministic port plan: `port(i) = base + step * i`.
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct PortAllocator {
    pub base: u16,
    pub step: u16,
}

impl Default for PortAllocator {
    fn default() -> Self {
        PortAllocator {
            base: DEFAULT_PORT,
            step: PORT_STEP,
        }
    }
}

impl PortAllocator {
    pub fn new(base: u16, step: u16) -> Self {
        PortAllocator { base, step }
    }

    /// Port of copy `i`.
    pub fn port(&self, i: u16) -> Result<u16> {
        self.base
            .checked_add(self.step.checked_mul(i).ok_or_else(|| {
                Error::Config(format!("port step {} * {i} overflows u16", self.step))
            })?)
            .ok_or_else(|| Error::Config(format!("port {} + {}*{i} overflows u16", self.base, self.step)))
    }

    /// The whole plan for `n` copies, validated collision-free.
    pub fn plan(&self, n: u16) -> Result<Vec<u16>> {
        let ports: Vec<u16> = (0..n).map(|i| self.port(i)).collect::<Result<_>>()?;
        let mut sorted = ports.clone();
        sorted.sort_unstable();
        if sorted.windows(2).any(|w| w[0] == w[1]) {
            // only reachable with step == 0 — the paper's crash
            return Err(Error::PortInUse(sorted[0]));
        }
        Ok(ports)
    }
}

#[cfg(test)]
#[allow(clippy::unwrap_used, clippy::expect_used)]
mod tests {
    use super::*;

    #[test]
    fn paper_plan_8873_step_7() {
        let a = PortAllocator::default();
        let plan = a.plan(8).unwrap();
        assert_eq!(plan, vec![8873, 8880, 8887, 8894, 8901, 8908, 8915, 8922]);
    }

    #[test]
    fn step_zero_reproduces_duplicate_port() {
        let a = PortAllocator::new(8873, 0);
        let err = a.plan(2).unwrap_err();
        assert!(matches!(err, Error::PortInUse(8873)));
    }

    #[test]
    fn step_one_works_too() {
        let a = PortAllocator::new(9000, 1);
        assert_eq!(a.plan(3).unwrap(), vec![9000, 9001, 9002]);
    }

    #[test]
    fn overflow_guarded() {
        let a = PortAllocator::new(65000, 1000);
        assert!(a.port(1).is_err());
        assert!(a.plan(2).is_err());
    }

    #[test]
    fn concurrent_lease_allocators_never_collide() {
        // the TOCTOU regression: two allocators racing must never hand
        // out the same port while both leases are live
        let handles: Vec<_> = (0..2)
            .map(|_| {
                std::thread::spawn(|| {
                    (0..16)
                        .map(|_| PortLease::acquire().unwrap())
                        .collect::<Vec<_>>()
                })
            })
            .collect();
        let leases: Vec<PortLease> = handles
            .into_iter()
            .flat_map(|h| h.join().unwrap())
            .collect();
        let mut ports: Vec<u16> = leases.iter().map(|l| l.port()).collect();
        ports.sort_unstable();
        ports.dedup();
        assert_eq!(ports.len(), leases.len(), "leased ports must be unique");
        // while held, the port cannot be re-bound by anyone else
        let p = leases[0].port();
        assert!(TcpListener::bind(("127.0.0.1", p)).is_err());
    }

    #[test]
    fn redeem_hands_over_the_bound_listener_once() {
        let lease = PortLease::acquire().unwrap();
        let p = lease.port();
        let listener = redeem(p).expect("live lease must redeem");
        assert_eq!(listener.local_addr().unwrap().port(), p);
        // consumed: a second redeem finds nothing
        assert!(redeem(p).is_none());
        // dropping the lease after redemption is a no-op
        drop(lease);
        drop(listener);
    }
}
