//! TraCI port allocation across parallel simulation copies.
//!
//! §4.2.1: "We tended to increment the default port value of 8873 by 7
//! for each successive parallel simulation and ran into no further
//! issues on this front."  Any positive step works (the ablation bench
//! compares 1 vs 7 vs 0 — step 0 reproduces the crash); the allocator
//! also guards the u16 range.

use crate::traci::{DEFAULT_PORT, PORT_STEP};
use crate::{Error, Result};

/// Deterministic port plan: `port(i) = base + step * i`.
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct PortAllocator {
    pub base: u16,
    pub step: u16,
}

impl Default for PortAllocator {
    fn default() -> Self {
        PortAllocator {
            base: DEFAULT_PORT,
            step: PORT_STEP,
        }
    }
}

impl PortAllocator {
    pub fn new(base: u16, step: u16) -> Self {
        PortAllocator { base, step }
    }

    /// Port of copy `i`.
    pub fn port(&self, i: u16) -> Result<u16> {
        self.base
            .checked_add(self.step.checked_mul(i).ok_or_else(|| {
                Error::Config(format!("port step {} * {i} overflows u16", self.step))
            })?)
            .ok_or_else(|| Error::Config(format!("port {} + {}*{i} overflows u16", self.base, self.step)))
    }

    /// The whole plan for `n` copies, validated collision-free.
    pub fn plan(&self, n: u16) -> Result<Vec<u16>> {
        let ports: Vec<u16> = (0..n).map(|i| self.port(i)).collect::<Result<_>>()?;
        let mut sorted = ports.clone();
        sorted.sort_unstable();
        if sorted.windows(2).any(|w| w[0] == w[1]) {
            // only reachable with step == 0 — the paper's crash
            return Err(Error::PortInUse(sorted[0]));
        }
        Ok(ports)
    }
}

#[cfg(test)]
#[allow(clippy::unwrap_used, clippy::expect_used)]
mod tests {
    use super::*;

    #[test]
    fn paper_plan_8873_step_7() {
        let a = PortAllocator::default();
        let plan = a.plan(8).unwrap();
        assert_eq!(plan, vec![8873, 8880, 8887, 8894, 8901, 8908, 8915, 8922]);
    }

    #[test]
    fn step_zero_reproduces_duplicate_port() {
        let a = PortAllocator::new(8873, 0);
        let err = a.plan(2).unwrap_err();
        assert!(matches!(err, Error::PortInUse(8873)));
    }

    #[test]
    fn step_one_works_too() {
        let a = PortAllocator::new(9000, 1);
        assert_eq!(a.plan(3).unwrap(), vec![9000, 9001, 9002]);
    }

    #[test]
    fn overflow_guarded() {
        let a = PortAllocator::new(65000, 1000);
        assert!(a.port(1).is_err());
        assert!(a.plan(2).is_err());
    }
}
