//! The run supervisor: what actually earns §5.1's "100% simulation
//! completion rate".
//!
//! The paper's campaigns run unattended for 12 hours; the pipeline's
//! real failure modes over that window (duarouter flaking under
//! `--seed $RANDOM`, display/port contention between slots, a wedged
//! back-end, a crashed instance) must become *retries*, not holes in
//! the dataset.  [`supervise_instance`] wraps the launcher with:
//!
//! * **panic containment** — `catch_unwind` turns a crashed launch into
//!   [`crate::Error::Panic`], a per-run error instead of a node abort,
//! * **an error taxonomy** — [`classify`] splits errors into transient
//!   (retryable), permanent (config/schema mistakes: retrying burns
//!   walltime reproducing the same failure) and engine (the HLO
//!   runtime),
//! * **bounded retry** with exponential backoff and deterministic
//!   seeded jitter ([`RetryPolicy`]),
//! * **watchdogs** — the per-instance walltime deadline and stall
//!   window of [`crate::webots::WatchdogSpec`], with kills counted,
//! * **graceful degradation** — an engine failure on `PhysicsEngine::
//!   Hlo` relaunches on the native stepper, flagging the dataset
//!   `degraded` so the fallback is visible in the aggregate.
//!
//! [`run_supervised_campaign`] drives a whole campaign through the
//! supervisor against the crash-safe [`super::CampaignLedger`]: every
//! run's terminal state is fsynced before the campaign moves on, per-run
//! CSVs are written atomically *before* the `completed` record, and the
//! final aggregate is assembled from the ledger + disk — so a killed
//! campaign resumes with zero duplicate run_ids and a byte-identical
//! aggregate export.

use std::panic::{catch_unwind, AssertUnwindSafe};
use std::path::PathBuf;
use std::time::{Duration, Instant};

use crate::container::{build_webots_hpc_image, BuildHost, ExecEnv};
use crate::display::DisplayRegistry;
use crate::output::{CampaignDataset, RunDataset};
use crate::pipeline::faults::{FaultInjection, FaultPlan};
use crate::pipeline::ledger::{CampaignLedger, LedgerState};
use crate::pipeline::ports::PortLease;
use crate::pipeline::{
    launch_instance, CampaignResult, InstanceConfig, InstanceResult, PhysicsEngine,
};
use crate::scenario::{FamilyRegistry, ScenarioMatrix, ScenarioRun};
use crate::sumo::{steps_for, FlowFile, MergeScenario};
use crate::telemetry::{self, EventKind};
use crate::util::{Json, Rng64};
use crate::webots::nodes::sample_merge_world;
use crate::webots::WatchdogSpec;
use crate::{Error, Result};

/// The retry taxonomy: what kind of failure is this?
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum ErrorClass {
    /// Environmental flake (port/display contention, duarouter exit,
    /// socket drop, stall/walltime kill, contained panic) — retrying
    /// under backoff is exactly right.
    Transient,
    /// A config/manifest/world mistake: every retry reproduces it.
    /// Never retried — fail fast and say why.
    Permanent,
    /// The HLO engine failed — retryable, but first eligible for the
    /// native-stepper degradation path.
    Engine,
}

impl ErrorClass {
    /// Ledger spelling.
    pub fn name(self) -> &'static str {
        match self {
            ErrorClass::Transient => "transient",
            ErrorClass::Permanent => "permanent",
            ErrorClass::Engine => "engine",
        }
    }
}

/// Classify a launch error for the retry decision.
pub fn classify(e: &Error) -> ErrorClass {
    match e {
        // the engine service failing is its own class: the degradation
        // path answers it before retry does
        Error::Runtime(_) => ErrorClass::Engine,
        // deterministic mistakes: the same inputs fail the same way
        Error::Config(_)
        | Error::World(_)
        | Error::Artifact(_)
        | Error::MissingInImage(_)
        | Error::ImmutableImage(_)
        | Error::PermissionDenied(_)
        | Error::Unschedulable(_)
        | Error::NoSuchJob(_) => ErrorClass::Permanent,
        // everything environmental: port/display races, duarouter,
        // socket I/O and protocol drops, watchdog kills, panics
        _ => ErrorClass::Transient,
    }
}

/// Bounded exponential backoff with deterministic seeded jitter.
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct RetryPolicy {
    /// Total launch attempts per run (first try included).
    pub max_attempts: u32,
    /// Backoff before attempt 2 [ms]; doubles per further attempt.
    pub base_ms: u64,
    /// Backoff ceiling [ms].
    pub cap_ms: u64,
}

impl Default for RetryPolicy {
    fn default() -> Self {
        RetryPolicy {
            max_attempts: 4,
            base_ms: 250,
            cap_ms: 5000,
        }
    }
}

impl RetryPolicy {
    /// Backoff to sleep before launch attempt `attempt` (1-based: the
    /// retry after the first failure is attempt 1's backoff).  The
    /// jitter factor in [0.5, 1.5) is drawn from a seeded generator —
    /// contending slots with different run seeds decorrelate, and the
    /// exact sequence reproduces in a resumed or re-run campaign.
    pub fn backoff_ms(&self, run_seed: u64, attempt: u32) -> u64 {
        let exp = attempt.saturating_sub(1).min(32);
        let nominal = self
            .cap_ms
            .min(self.base_ms.saturating_mul(1u64 << exp));
        let mut rng = Rng64::seed_from_u64(
            run_seed ^ (attempt as u64).wrapping_mul(0x9E37_79B9_7F4A_7C15),
        );
        let jitter = 0.5 + rng.gen_f64();
        (nominal as f64 * jitter) as u64
    }
}

/// Full supervision policy for one campaign.
#[derive(Debug, Clone, Default)]
pub struct SupervisorSpec {
    pub retry: RetryPolicy,
    pub watchdog: WatchdogSpec,
    /// Fall back to the native stepper when the HLO engine fails
    /// (instead of retrying the failing engine).
    pub degrade: bool,
    /// Test seam: injected fault schedule (None in production).
    pub fault_plan: Option<FaultPlan>,
}

/// One failed launch attempt, for the report.
#[derive(Debug, Clone, PartialEq)]
pub struct AttemptRecord {
    /// 0-based attempt index that failed.
    pub attempt: u32,
    pub class: ErrorClass,
    pub error: String,
    /// Backoff slept after this failure [ms] (0 = terminal or
    /// degradation relaunch).
    pub backoff_ms: u64,
}

/// What supervising one run produced.
#[derive(Debug)]
pub struct RunReport {
    pub run_id: String,
    /// Launch attempts made (≥ 1).
    pub attempts: u32,
    /// Every failed attempt, in order.
    pub failures: Vec<AttemptRecord>,
    /// Completed on the native fallback after an engine failure.
    pub degraded: bool,
    /// Attempts killed by the walltime deadline.
    pub killed_walltime: u32,
    /// Attempts killed by the stall watchdog.
    pub killed_stall: u32,
    pub outcome: Result<InstanceResult>,
}

/// Human-readable panic payload (shared with the launcher's per-slot
/// containment).
pub(crate) fn panic_msg(payload: Box<dyn std::any::Any + Send>) -> String {
    if let Some(s) = payload.downcast_ref::<&str>() {
        (*s).to_string()
    } else if let Some(s) = payload.downcast_ref::<String>() {
        s.clone()
    } else {
        "non-string panic payload".to_string()
    }
}

/// Event-stream spelling of the physics engine an attempt runs on.
fn engine_name(physics: &PhysicsEngine) -> &'static str {
    match physics {
        PhysicsEngine::Native => "native",
        PhysicsEngine::Hlo(_) => "hlo",
    }
}

fn contain<F>(f: F) -> Result<InstanceResult>
where
    F: FnOnce() -> Result<InstanceResult>,
{
    match catch_unwind(AssertUnwindSafe(f)) {
        Ok(r) => r,
        Err(payload) => Err(Error::Panic(panic_msg(payload))),
    }
}

/// Run one instance under full supervision: containment, taxonomy,
/// bounded retry, watchdogs, degradation.  Never panics; the terminal
/// state is always a [`RunReport`].
pub fn supervise_instance(
    cfg: &InstanceConfig,
    displays: &DisplayRegistry,
    env: &ExecEnv,
    physics: &PhysicsEngine,
    spec: &SupervisorSpec,
) -> RunReport {
    let mut physics = physics.clone();
    let mut attempt: u32 = 0;
    let mut failures: Vec<AttemptRecord> = Vec::new();
    let mut degraded = false;
    let mut killed_walltime = 0u32;
    let mut killed_stall = 0u32;

    loop {
        let mut attempt_cfg = cfg.clone();
        attempt_cfg.watchdog = spec.watchdog;
        if let Some(plan) = &spec.fault_plan {
            attempt_cfg.faults = Some(FaultInjection {
                plan: plan.clone(),
                attempt,
            });
        }
        if telemetry::enabled() {
            telemetry::emit(EventKind::AttemptBegin {
                run_id: cfg.run_id.clone(),
                attempt: attempt as u64,
                engine: engine_name(&physics).to_string(),
            });
        }
        let outcome = contain(|| launch_instance(&attempt_cfg, displays, env, &physics));
        if telemetry::enabled() {
            telemetry::emit(EventKind::AttemptEnd {
                run_id: cfg.run_id.clone(),
                attempt: attempt as u64,
                ok: outcome.is_ok(),
            });
        }
        match outcome {
            Ok(mut r) => {
                r.dataset.degraded = degraded;
                return RunReport {
                    run_id: cfg.run_id.clone(),
                    attempts: attempt + 1,
                    failures,
                    degraded,
                    killed_walltime,
                    killed_stall,
                    outcome: Ok(r),
                };
            }
            Err(e) => {
                match &e {
                    Error::WalltimeExceeded(_) => killed_walltime += 1,
                    Error::Stalled(_) => killed_stall += 1,
                    _ => {}
                }
                let class = classify(&e);
                // degradation: an engine failure on the HLO path
                // relaunches immediately on the native stepper — no
                // backoff, the engine is not coming back by waiting
                if class == ErrorClass::Engine
                    && spec.degrade
                    && matches!(physics, PhysicsEngine::Hlo(_))
                {
                    if telemetry::enabled() {
                        telemetry::emit(EventKind::Degraded {
                            run_id: cfg.run_id.clone(),
                            attempt: attempt as u64,
                            error: e.to_string(),
                        });
                    }
                    failures.push(AttemptRecord {
                        attempt,
                        class,
                        error: e.to_string(),
                        backoff_ms: 0,
                    });
                    physics = PhysicsEngine::Native;
                    degraded = true;
                    attempt += 1;
                    if attempt >= spec.retry.max_attempts {
                        return RunReport {
                            run_id: cfg.run_id.clone(),
                            attempts: attempt,
                            failures,
                            degraded,
                            killed_walltime,
                            killed_stall,
                            outcome: Err(e),
                        };
                    }
                    continue;
                }
                let terminal =
                    class == ErrorClass::Permanent || attempt + 1 >= spec.retry.max_attempts;
                let backoff_ms = if terminal {
                    0
                } else {
                    spec.retry.backoff_ms(cfg.seed, attempt + 1)
                };
                if !terminal && telemetry::enabled() {
                    telemetry::emit(EventKind::Retry {
                        run_id: cfg.run_id.clone(),
                        attempt: attempt as u64,
                        class: class.name().to_string(),
                        error: e.to_string(),
                        backoff_ms,
                    });
                }
                failures.push(AttemptRecord {
                    attempt,
                    class,
                    error: e.to_string(),
                    backoff_ms,
                });
                attempt += 1;
                if terminal {
                    return RunReport {
                        run_id: cfg.run_id.clone(),
                        attempts: attempt,
                        failures,
                        degraded,
                        killed_walltime,
                        killed_stall,
                        outcome: Err(e),
                    };
                }
                std::thread::sleep(Duration::from_millis(backoff_ms));
            }
        }
    }
}

/// Campaign-level supervision accounting — the evidence behind a
/// completion-rate claim (retries and kills are *visible*, not folded
/// into a smooth 100%).
#[derive(Debug, Clone, Copy, Default, PartialEq)]
pub struct RobustnessStats {
    /// Runs the campaign covered (completed + failed + resumed skips).
    pub runs: u64,
    /// Runs with a terminal `completed` state.
    pub completed: u64,
    /// Runs that failed terminally (permanent error or retry budget).
    pub failed: u64,
    /// Total launch attempts across all runs.
    pub attempts: u64,
    /// Attempts beyond each run's first (the retry bill).
    pub retries: u64,
    /// Total wall time slept in retry backoff across all runs [ms] —
    /// the campaign's waiting bill, next to the retry count it paid for.
    pub backoff_ms_total: u64,
    /// Runs that completed on the native fallback.
    pub degraded: u64,
    /// Attempts killed by the walltime deadline.
    pub killed_walltime: u64,
    /// Attempts killed by the stall watchdog.
    pub killed_stall: u64,
    /// Runs skipped on resume because the ledger already has them.
    pub resumed_skips: u64,
}

impl RobustnessStats {
    /// completed / runs (1.0 for the empty campaign).
    pub fn completion_rate(&self) -> f64 {
        if self.runs == 0 {
            return 1.0;
        }
        self.completed as f64 / self.runs as f64
    }
}

/// A campaign driven through the supervisor + ledger.
#[derive(Debug, Clone)]
pub struct SupervisedCampaignSpec {
    /// Campaign name — the run-id prefix (`{name}-e{epoch}[{slot}]`).
    pub name: String,
    pub nodes: usize,
    pub slots_per_node: u32,
    pub epochs: u64,
    /// Per-run simulated horizon [s] (scenario-matrix runs are clamped
    /// to it).
    pub horizon_s: f32,
    /// Traffic capacity for classic (non-matrix) runs.
    pub capacity: usize,
    /// Base seed; classic run seeds are `seed + run_index`.
    pub seed: u64,
    /// Scenario-matrix mode (None = classic fixed merge world).
    pub matrix: Option<ScenarioMatrix>,
    pub supervisor: SupervisorSpec,
    /// Ledger + per-run CSV directory; reusing it resumes the campaign.
    pub ledger_dir: PathBuf,
    /// On resume, re-run runs whose latest ledger state is a permanent
    /// failure.  Default off: a permanent error (bad config/manifest)
    /// reproduces identically on every attempt, so re-running it each
    /// session just burns walltime — opt in only after fixing the
    /// inputs.
    pub retry_failed: bool,
    /// Test seam: abandon the campaign after launching this many runs
    /// this session (simulates a mid-campaign kill; resumed-skipped
    /// runs don't count as launches).
    pub stop_after_runs: Option<u64>,
}

impl SupervisedCampaignSpec {
    pub fn total_runs(&self) -> u64 {
        self.epochs * self.nodes as u64 * self.slots_per_node as u64
    }
}

/// What a supervised campaign produced.
#[derive(Debug)]
pub struct SupervisedOutcome {
    pub result: CampaignResult,
    /// Aggregate dataset, assembled from the ledger + on-disk CSVs —
    /// deterministic across kill/resume.
    pub dataset: CampaignDataset,
    /// Per-run supervision reports for runs launched *this session*.
    pub reports: Vec<RunReport>,
    /// True when `stop_after_runs` abandoned the campaign mid-flight.
    pub interrupted: bool,
}

/// The coordinates of run `idx` in the campaign grid.
pub(crate) fn grid(spec: &SupervisedCampaignSpec, idx: u64) -> (u32, u32, usize) {
    let per_epoch = spec.nodes as u64 * spec.slots_per_node as u64;
    let epoch = (idx / per_epoch) as u32;
    let slot = (idx % per_epoch) as u32;
    let node = (slot / spec.slots_per_node) as usize;
    (epoch, slot, node)
}

/// Everything the campaign grid determines about run `idx`: its
/// coordinates, identity, seed, and (in matrix mode) the materialized
/// scenario point.  Pure in `(spec, idx)` — any process that agrees on
/// the spec computes the identical plan, which is the contract the
/// distributed fabric leans on to ship coordinates instead of payloads.
#[derive(Debug, Clone)]
pub(crate) struct RunPlan {
    pub epoch: u32,
    pub slot: u32,
    pub node: usize,
    /// `{name}-e{epoch}[{slot}]` — the dataset/CSV identity.
    pub base_id: String,
    /// Ledger identity (`base_id` plus the scenario tag in matrix mode).
    pub run_id: String,
    pub planned: Option<crate::scenario::PlannedRun>,
    pub seed: u64,
}

/// Materialize the plan for run `idx` of `spec`.
pub(crate) fn plan_run(
    spec: &SupervisedCampaignSpec,
    registry: &FamilyRegistry,
    idx: u64,
) -> Result<RunPlan> {
    let (epoch, slot, node) = grid(spec, idx);
    let base_id = format!("{}-e{epoch}[{slot}]", spec.name);
    let planned = match &spec.matrix {
        Some(m) => Some(m.materialize(registry, idx)?),
        None => None,
    };
    let run_id = match &planned {
        Some(p) => {
            let tag = &p.config.tag;
            format!("{base_id}@{}#{}", tag.id, tag.sample_index)
        }
        None => base_id.clone(),
    };
    let seed = match &planned {
        Some(p) => p.assignment.run_seed,
        None => spec.seed + idx,
    };
    Ok(RunPlan {
        epoch,
        slot,
        node,
        base_id,
        run_id,
        planned,
        seed,
    })
}

/// Build the launchable instance config for a planned run, with its
/// TraCI server on `port`.
pub(crate) fn instance_config(
    spec: &SupervisedCampaignSpec,
    plan: &RunPlan,
    port: u16,
) -> InstanceConfig {
    let world = sample_merge_world(port);
    match &plan.planned {
        Some(p) => {
            let mut cfg = InstanceConfig::from_planned(&plan.base_id, plan.node, world, p);
            cfg.horizon_s = cfg.horizon_s.min(spec.horizon_s);
            cfg
        }
        None => {
            let scenario = MergeScenario::default();
            InstanceConfig {
                run_id: plan.base_id.clone(),
                node: plan.node,
                world,
                flows: FlowFile::merge_sample(1200.0, 300.0, spec.horizon_s),
                scenario,
                seed: plan.seed,
                capacity: spec.capacity,
                horizon_s: spec.horizon_s,
                max_steps: steps_for(spec.horizon_s, scenario.dt_s) + 100,
                scenario_run: None,
                chunk_steps: crate::pipeline::ChunkSteps::Auto,
                faults: None,
                watchdog: WatchdogSpec::default(),
            }
        }
    }
}

/// Atomically publish one run's CSV under `runs_dir`: the file lands
/// fully (or not at all) *before* the caller appends the `completed`
/// ledger record — a crash between the two re-runs the instance, never
/// trusts a torn file.
pub(crate) fn publish_run_csv(
    runs_dir: &std::path::Path,
    epoch: u32,
    slot: u32,
    csv: &str,
) -> Result<()> {
    let final_path = runs_dir.join(format!("e{epoch}_s{slot}.csv"));
    let tmp_path = runs_dir.join(format!("e{epoch}_s{slot}.csv.tmp"));
    std::fs::write(&tmp_path, csv)?;
    std::fs::rename(&tmp_path, &final_path)?;
    Ok(())
}

/// Assemble the aggregate dataset purely from ledger + disk, in grid
/// order — the SAME construction whether one process ran every
/// instance, a resumed session finished a killed campaign, or a
/// coordinator collected shards from remote workers.  This shared path
/// is what makes the distributed aggregate byte-identical to the
/// single-process one.
pub(crate) fn assemble_aggregate(
    spec: &SupervisedCampaignSpec,
    registry: &FamilyRegistry,
    ledger: &CampaignLedger,
    runs_dir: &std::path::Path,
) -> Result<CampaignDataset> {
    let mut dataset = CampaignDataset::new();
    for idx in 0..spec.total_runs() {
        let plan = plan_run(spec, registry, idx)?;
        let Some(entry) = ledger.state(&plan.run_id) else {
            continue;
        };
        let LedgerState::Completed { degraded, .. } = entry.state else {
            continue;
        };
        let csv = std::fs::read_to_string(
            runs_dir.join(format!("e{}_s{}.csv", plan.epoch, plan.slot)),
        )?;
        let mut ds = RunDataset::from_csv(&plan.base_id, plan.node, plan.seed, &csv)?;
        if let Some(p) = &plan.planned {
            ds = ds.with_scenario(ScenarioRun::from(&p.config).tag);
        }
        ds.degraded = degraded;
        dataset.add(ds);
    }
    Ok(dataset)
}

/// FNV-1a over the matrix's debug form — a stable spelling of the
/// sweep for the ledger header.
fn matrix_fingerprint(matrix: &Option<ScenarioMatrix>) -> String {
    match matrix {
        None => "none".to_string(),
        Some(m) => {
            let mut h: u64 = 0xcbf2_9ce4_8422_2325;
            for b in format!("{m:?}").bytes() {
                h ^= b as u64;
                h = h.wrapping_mul(0x0000_0100_0000_01b3);
            }
            format!("{h:016x}")
        }
    }
}

/// The campaign-shape fingerprint bound into the ledger header: every
/// field that determines run_ids, seeds, CSV paths, or run content.
/// Resuming a ledger dir under a different shape is refused instead of
/// silently mislabeling the rebuilt aggregate.
pub(crate) fn campaign_fingerprint(spec: &SupervisedCampaignSpec) -> Json {
    Json::obj(vec![
        ("name", Json::str(&spec.name)),
        ("nodes", Json::num(spec.nodes as f64)),
        ("slots_per_node", Json::num(spec.slots_per_node as f64)),
        ("epochs", Json::num(spec.epochs as f64)),
        ("horizon_s", Json::num(spec.horizon_s as f64)),
        ("capacity", Json::num(spec.capacity as f64)),
        // string: u64 seeds don't fit f64 losslessly
        ("seed", Json::str(spec.seed.to_string())),
        ("matrix", Json::str(matrix_fingerprint(&spec.matrix))),
    ])
}

/// Run a campaign end to end under supervision, resuming from whatever
/// the ledger in `spec.ledger_dir` already holds.
pub fn run_supervised_campaign(
    spec: &SupervisedCampaignSpec,
    physics: &PhysicsEngine,
) -> Result<SupervisedOutcome> {
    let mut ledger = CampaignLedger::open(spec.ledger_dir.join("ledger.jsonl"))?;
    ledger.ensure_header(&campaign_fingerprint(spec))?;
    let runs_dir = spec.ledger_dir.join("runs");
    std::fs::create_dir_all(&runs_dir)?;

    let displays = DisplayRegistry::new();
    let sif = build_webots_hpc_image(BuildHost::PersonalComputer)?;
    let env = ExecEnv::new(sif).bind("/tmp", "/tmp");
    let registry = FamilyRegistry::builtin();

    let total = spec.total_runs();
    if telemetry::enabled() {
        telemetry::emit(EventKind::CampaignBegin {
            name: spec.name.clone(),
            nodes: spec.nodes as u64,
            slots_per_node: spec.slots_per_node as u64,
            epochs: spec.epochs,
            runs: total,
        });
    }
    let mut stats = RobustnessStats::default();
    let mut reports: Vec<RunReport> = Vec::new();
    let mut walltimes_s: Vec<f64> = Vec::new();
    let mut interrupted = false;
    let mut launched = 0u64;

    for idx in 0..total {
        let plan = plan_run(spec, &registry, idx)?;
        let (epoch, slot, node) = (plan.epoch, plan.slot, plan.node);
        let run_id = plan.run_id.clone();

        // resume predicate: completed runs are settled; so are
        // permanent failures (unless retry_failed) — a config error
        // reproduces identically, re-running it burns walltime
        let settled = match ledger.state(&run_id).map(|e| &e.state) {
            Some(LedgerState::Completed { .. }) => Some(true),
            Some(LedgerState::Failed { class, .. })
                if class.as_str() == ErrorClass::Permanent.name() && !spec.retry_failed =>
            {
                Some(false)
            }
            _ => None,
        };
        if let Some(completed) = settled {
            stats.runs += 1;
            stats.resumed_skips += 1;
            if completed {
                stats.completed += 1;
            } else {
                stats.failed += 1;
            }
            continue;
        }
        if let Some(stop) = spec.stop_after_runs {
            if launched >= stop {
                interrupted = true;
                break;
            }
        }

        // the lease holds its bound listener until the TraCI server
        // redeems it inside the launcher — no probe-then-close window
        let port_lease = PortLease::acquire()?;
        let cfg = instance_config(spec, &plan, port_lease.port());

        ledger.mark_running(&run_id, epoch, slot, 0)?;
        if telemetry::enabled() {
            telemetry::emit(EventKind::RunBegin {
                run_id: run_id.clone(),
                epoch: epoch as u64,
                slot: slot as u64,
                node: node as u64,
            });
        }
        // pool counters before the run — the per-run delta is what the
        // event stream reports (the campaign-end totals hide which runs
        // actually paid a compile)
        let pool_before = match physics {
            PhysicsEngine::Hlo(service) => service.pool_usage().ok(),
            PhysicsEngine::Native => None,
        };
        let t0 = Instant::now();
        let report = supervise_instance(&cfg, &displays, &env, physics, &spec.supervisor);
        if telemetry::enabled() {
            if let (Some(before), PhysicsEngine::Hlo(service)) = (pool_before, physics) {
                if let Ok(after) = service.pool_usage() {
                    telemetry::emit(EventKind::PoolDelta {
                        run_id: run_id.clone(),
                        hits: after.hits.saturating_sub(before.hits),
                        misses: after.misses.saturating_sub(before.misses),
                        compiled: after.compiled as u64,
                    });
                }
            }
            telemetry::emit(EventKind::RunEnd {
                run_id: run_id.clone(),
                ok: report.outcome.is_ok(),
                attempts: report.attempts as u64,
                degraded: report.degraded,
            });
        }
        launched += 1;
        stats.runs += 1;
        stats.attempts += report.attempts as u64;
        stats.retries += report.attempts.saturating_sub(1) as u64;
        stats.backoff_ms_total += report.failures.iter().map(|f| f.backoff_ms).sum::<u64>();
        stats.killed_walltime += report.killed_walltime as u64;
        stats.killed_stall += report.killed_stall as u64;
        match &report.outcome {
            Ok(r) => {
                publish_run_csv(&runs_dir, epoch, slot, &r.dataset.to_csv())?;
                ledger.mark_completed(&run_id, epoch, slot, report.attempts, report.degraded)?;
                stats.completed += 1;
                if report.degraded {
                    stats.degraded += 1;
                }
                walltimes_s.push(t0.elapsed().as_secs_f64());
            }
            Err(e) => {
                ledger.mark_failed(
                    &run_id,
                    epoch,
                    slot,
                    report.attempts,
                    classify(e).name(),
                    &e.to_string(),
                )?;
                stats.failed += 1;
            }
        }
        reports.push(report);
    }

    if telemetry::enabled() {
        telemetry::emit(EventKind::CampaignEnd {
            name: spec.name.clone(),
            completed: stats.completed,
            failed: stats.failed,
        });
        telemetry::flush_all();
    }

    let dataset = assemble_aggregate(spec, &registry, &ledger, &runs_dir)?;
    let result = crate::pipeline::campaign::supervised_result(
        stats,
        &walltimes_s,
        &dataset,
        spec.nodes,
    );

    Ok(SupervisedOutcome {
        result,
        dataset,
        reports,
        interrupted,
    })
}

#[cfg(test)]
#[allow(clippy::unwrap_used, clippy::expect_used)]
mod tests {
    use super::*;

    #[test]
    fn taxonomy_pins_the_retry_decision() {
        assert_eq!(classify(&Error::PortInUse(8873)), ErrorClass::Transient);
        assert_eq!(classify(&Error::DisplayInUse(99)), ErrorClass::Transient);
        assert_eq!(
            classify(&Error::DuarouterFailed("exit 1".into())),
            ErrorClass::Transient
        );
        assert_eq!(classify(&Error::Stalled(42)), ErrorClass::Transient);
        assert_eq!(
            classify(&Error::WalltimeExceeded("r".into())),
            ErrorClass::Transient
        );
        assert_eq!(classify(&Error::Panic("boom".into())), ErrorClass::Transient);
        assert_eq!(
            classify(&Error::Protocol("socket dropped".into())),
            ErrorClass::Transient
        );
        assert_eq!(
            classify(&Error::Io(std::io::Error::other("reset"))),
            ErrorClass::Transient
        );
        assert_eq!(classify(&Error::Config("bad".into())), ErrorClass::Permanent);
        assert_eq!(classify(&Error::World("bad".into())), ErrorClass::Permanent);
        assert_eq!(
            classify(&Error::Artifact("schema".into())),
            ErrorClass::Permanent
        );
        assert_eq!(classify(&Error::Runtime("pjrt".into())), ErrorClass::Engine);
    }

    #[test]
    fn backoff_grows_exponentially_within_cap() {
        let p = RetryPolicy {
            max_attempts: 8,
            base_ms: 100,
            cap_ms: 1000,
        };
        for attempt in 1..8u32 {
            let nominal = 1000u64.min(100u64 << (attempt - 1));
            let b = p.backoff_ms(7, attempt);
            let lo = nominal / 2;
            let hi = nominal + nominal / 2;
            assert!(
                (lo..=hi).contains(&b),
                "attempt {attempt}: {b} outside [{lo}, {hi}]"
            );
        }
        // deterministic: same (seed, attempt) → same backoff
        assert_eq!(p.backoff_ms(7, 3), p.backoff_ms(7, 3));
        // decorrelated: different seeds jitter differently somewhere
        assert!((1..8).any(|a| p.backoff_ms(7, a) != p.backoff_ms(8, a)));
    }

    #[test]
    fn robustness_stats_completion_rate() {
        let mut s = RobustnessStats::default();
        assert_eq!(s.completion_rate(), 1.0);
        s.runs = 10;
        s.completed = 10;
        assert_eq!(s.completion_rate(), 1.0);
        s.completed = 9;
        s.failed = 1;
        assert!((s.completion_rate() - 0.9).abs() < 1e-12);
    }

    #[test]
    fn grid_coordinates_cover_the_campaign() {
        let spec = SupervisedCampaignSpec {
            name: "g".into(),
            nodes: 3,
            slots_per_node: 2,
            epochs: 2,
            horizon_s: 5.0,
            capacity: 64,
            seed: 1,
            matrix: None,
            supervisor: SupervisorSpec::default(),
            ledger_dir: std::env::temp_dir(),
            retry_failed: false,
            stop_after_runs: None,
        };
        assert_eq!(spec.total_runs(), 12);
        assert_eq!(grid(&spec, 0), (0, 0, 0));
        assert_eq!(grid(&spec, 5), (0, 5, 2));
        assert_eq!(grid(&spec, 6), (1, 0, 0));
        assert_eq!(grid(&spec, 11), (1, 5, 2));
    }
}
