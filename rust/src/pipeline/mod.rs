//! The Webots.HPC pipeline — the paper's contribution.
//!
//! Everything below wires the substrates into the four §3.1
//! functionalities: GUI runs, headless runs, SUMO-coupled runs, and
//! n-instance × m-node parallel campaigns.
//!
//! * [`ports`] — per-copy TraCI port allocation (base 8873, step 7),
//! * [`copies`] — world-copy propagation with unique ports (the §3.1.5
//!   "menial step", automated as the paper suggests),
//! * [`walltime`] — choosing the per-job walltime from the cost model
//!   ("this walltime is specific to the simulation ... and will thus
//!   need to be determined prior to running a large sequence", §5.2),
//! * [`launcher`] — running real instances: container exec → xvfb-run
//!   → webots → TraCI, with physics on the PJRT artifact or the native
//!   stepper,
//! * [`campaign`] — the discrete-event campaign driver that reproduces
//!   the ch. 5 experiments (epoch-locked PBS arrays vs a sequential
//!   personal computer),
//! * [`supervisor`] — per-run supervision: panic containment, error
//!   taxonomy, bounded retry with seeded backoff, watchdog kills,
//!   HLO→native degradation, and the ledger-driven campaign driver that
//!   backs §5.1's completion-rate claim,
//! * [`ledger`] — the crash-safe append-only JSONL campaign ledger
//!   (resume = replay + skip completed),
//! * [`faults`] — deterministic fault injection at the pipeline's real
//!   failure sites (the harness that *proves* the claim).

// This module is the unattended-campaign control plane: a stray panic
// here is a node-wide abort at 3am.  Recoverable failures must flow
// through Result — unwrap/expect are denied outside tests.
#![deny(clippy::unwrap_used, clippy::expect_used)]

pub mod campaign;
pub mod config;
pub mod copies;
pub mod faults;
pub mod launcher;
pub mod ledger;
pub mod ports;
pub mod supervisor;
pub mod walltime;

pub use campaign::{
    pc_campaign, run_cluster_campaign, CampaignResult, CampaignSpec, ThroughputSample,
    PAPER_PC_OVERHEAD_S,
};
pub use config::{CampaignConfig, ChunkSteps};
pub use copies::{propagate_copies, write_copy_tree, SimCopy};
pub use faults::{FaultInjection, FaultPlan, FaultSite};
pub use launcher::{
    launch_instance, launch_node_slots, InstanceConfig, InstanceResult, PhysicsEngine,
};
pub use ledger::{CampaignLedger, LedgerEntry, LedgerState};
pub use ports::{PortAllocator, PortLease};
pub use supervisor::{
    classify, run_supervised_campaign, supervise_instance, AttemptRecord, ErrorClass, RetryPolicy,
    RobustnessStats, RunReport, SupervisedCampaignSpec, SupervisedOutcome, SupervisorSpec,
};
pub use walltime::{pick_walltime, WalltimePolicy};
