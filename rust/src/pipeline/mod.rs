//! The Webots.HPC pipeline — the paper's contribution.
//!
//! Everything below wires the substrates into the four §3.1
//! functionalities: GUI runs, headless runs, SUMO-coupled runs, and
//! n-instance × m-node parallel campaigns.
//!
//! * [`ports`] — per-copy TraCI port allocation (base 8873, step 7),
//! * [`copies`] — world-copy propagation with unique ports (the §3.1.5
//!   "menial step", automated as the paper suggests),
//! * [`walltime`] — choosing the per-job walltime from the cost model
//!   ("this walltime is specific to the simulation ... and will thus
//!   need to be determined prior to running a large sequence", §5.2),
//! * [`launcher`] — running real instances: container exec → xvfb-run
//!   → webots → TraCI, with physics on the PJRT artifact or the native
//!   stepper,
//! * [`campaign`] — the discrete-event campaign driver that reproduces
//!   the ch. 5 experiments (epoch-locked PBS arrays vs a sequential
//!   personal computer).

pub mod campaign;
pub mod config;
pub mod copies;
pub mod launcher;
pub mod ports;
pub mod walltime;

pub use campaign::{
    pc_campaign, run_cluster_campaign, CampaignResult, CampaignSpec, ThroughputSample,
    PAPER_PC_OVERHEAD_S,
};
pub use config::{CampaignConfig, ChunkSteps};
pub use copies::{propagate_copies, write_copy_tree, SimCopy};
pub use launcher::{launch_instance, launch_node_slots, InstanceConfig, InstanceResult, PhysicsEngine};
pub use ports::PortAllocator;
pub use walltime::{pick_walltime, WalltimePolicy};
