//! The instance launcher: physics-fidelity simulation runs.
//!
//! This is the body of the PBS job script, as rust: for each instance,
//! (1) regenerate randomized routes (`duarouter ... --seed $RANDOM`),
//! (2) acquire an Xvfb display (`xvfb-run -a`), (3) boot the SUMO
//! back-end's TraCI server on the copy's unique port, (4) open the
//! Webots front-end, (5) run to the stop condition, (6) emit the output
//! dataset.  `launch_node_slots` runs n instances concurrently on real
//! threads + sockets — one simulated compute node's worth of parallelism.

use crate::container::{BuildHost, ExecEnv};
use crate::display::DisplayRegistry;
use crate::output::RunDataset;
use crate::pipeline::faults::{FaultInjection, FaultSite};
use crate::pipeline::supervisor::panic_msg;
use crate::pipeline::ChunkSteps;
use crate::runtime::{EngineService, HloStepper};
use crate::scenario::{PlannedRun, ScenarioRun};
use crate::sumo::{duarouter, steps_for, FlowFile, MergeScenario, NativeIdmStepper, SumoSim};
use crate::telemetry::{self, EventKind};
use crate::traci::TraciServer;
use crate::webots::{InstanceWatchdog, StopCondition, WatchdogSpec, WebotsSim, World};
use crate::{Error, Result};

/// Which physics engine an instance runs.
#[derive(Debug, Clone)]
pub enum PhysicsEngine {
    /// Pure-rust IDM/MOBIL baseline.
    Native,
    /// The AOT JAX/Pallas artifact via PJRT (production path).
    Hlo(EngineService),
}

/// Everything one instance needs.
#[derive(Debug, Clone)]
pub struct InstanceConfig {
    pub run_id: String,
    pub node: usize,
    /// The world copy (carries the unique TraCI port).
    pub world: World,
    /// Demand definition (routes are regenerated per run from the seed).
    pub flows: FlowFile,
    pub scenario: MergeScenario,
    /// duarouter seed (`$RANDOM` in the paper's script).
    pub seed: u64,
    /// Traffic slot capacity (must equal an AOT bucket for Hlo physics).
    pub capacity: usize,
    /// Simulated horizon before the stop condition fires [s].
    pub horizon_s: f32,
    /// Max steps — the in-process walltime guard.
    pub max_steps: u64,
    /// Scenario-matrix provenance + compiled network (None = the
    /// classic fixed merge world, whose network derives from
    /// `scenario`).
    pub scenario_run: Option<ScenarioRun>,
    /// Fused-chunk policy (`CampaignConfig::chunk_steps`): `Auto` rides
    /// the manifest's whole rollout ladder; `Fixed(k)` is validated
    /// against that ladder at launch.  Live-GUI runs force K=1 at the
    /// `SimMode` site instead — see `examples/gui_session.rs`.  The
    /// native engine has no rollout ladder (it fuses nothing), so the
    /// policy is deliberately inert there — any `Fixed(k)` just
    /// single-steps, with nothing to validate against.
    pub chunk_steps: ChunkSteps,
    /// Seeded fault schedule bound to one attempt (the supervisor's
    /// test seam; None in production launches).
    pub faults: Option<FaultInjection>,
    /// Per-instance walltime deadline + stall window (default: both
    /// disabled).
    pub watchdog: WatchdogSpec,
}

impl InstanceConfig {
    /// Stand up an instance from a materialized scenario-matrix run:
    /// the compiled geometry/flows/network, the assignment's duarouter
    /// seed, and the provenance tag the dataset will carry.
    pub fn from_planned(
        run_id: impl Into<String>,
        node: usize,
        world: World,
        planned: &PlannedRun,
    ) -> InstanceConfig {
        let horizon_s = planned.config.horizon_s;
        InstanceConfig {
            run_id: run_id.into(),
            node,
            world,
            flows: planned.config.flows.clone(),
            scenario: planned.config.geometry,
            seed: planned.assignment.run_seed,
            capacity: planned.config.capacity,
            horizon_s,
            // walltime guard: the SAME step derivation the runtime uses
            // (steps_for), plus slack — planner and sim can't drift
            max_steps: steps_for(horizon_s, planned.config.geometry.dt_s) + 100,
            scenario_run: Some(ScenarioRun::from(&planned.config)),
            chunk_steps: ChunkSteps::Auto,
            faults: None,
            watchdog: WatchdogSpec::default(),
        }
    }

    /// Override the fused-chunk policy (threads the campaign config's
    /// `chunk_steps` key through to this instance).
    pub fn with_chunk_steps(mut self, chunk_steps: ChunkSteps) -> Self {
        self.chunk_steps = chunk_steps;
        self
    }

    /// Does the bound fault schedule fire at `site` for this instance?
    fn fault(&self, site: FaultSite) -> bool {
        self.faults.as_ref().is_some_and(|f| f.fires(site, self.seed))
    }
}

/// What one instance produced.
#[derive(Debug)]
pub struct InstanceResult {
    pub dataset: RunDataset,
    pub display: u32,
    pub port: u16,
    pub steps: u64,
    pub controller_cmds: u64,
}

/// Run one instance end to end on the calling thread.
pub fn launch_instance(
    cfg: &InstanceConfig,
    displays: &DisplayRegistry,
    env: &ExecEnv,
    physics: &PhysicsEngine,
) -> Result<InstanceResult> {
    // watchdog clock starts at launch: setup phases (duarouter, display
    // acquisition) count against the walltime deadline too
    let watchdog = InstanceWatchdog::new(cfg.run_id.clone(), cfg.watchdog);

    // container sanity: the tools the script invokes must exist
    env.exec("duarouter", &[])?;
    env.exec("xvfb-run", &["-a"])?;
    env.exec("webots", &["--batch"])?;

    // (1) randomized routes — against the compiled scenario network
    // when this is a scenario-matrix run.  Destination intent is
    // validated against THIS instance's road here (not only in the
    // family compilers) so XML-loaded or hand-built flows can't smuggle
    // in a gore at/past the road end that would silently never fire.
    cfg.flows.validate_exits(cfg.scenario.road_end_m)?;
    let net = match &cfg.scenario_run {
        Some(sr) => sr.network.clone(),
        None => cfg.scenario.network(),
    };
    if cfg.fault(FaultSite::Duarouter) {
        return Err(Error::DuarouterFailed(format!(
            "injected: exit 1 (seed {})",
            cfg.seed
        )));
    }
    let routes = duarouter(&net, &cfg.flows, cfg.seed)?;

    // (2) headless display — MUST auto-probe for parallel instances
    if cfg.fault(FaultSite::Display) {
        return Err(Error::DisplayInUse(99));
    }
    let display = crate::webots::SimMode::headless(displays, true)?;

    // (3) SUMO back-end on the copy's unique port
    let port = cfg
        .world
        .find("SumoInterface")
        .ok_or_else(|| Error::World("instance world missing SumoInterface".into()))?
        .field_u32("port")
        .ok_or_else(|| Error::World("SumoInterface missing port".into()))? as u16;
    let stepper: Box<dyn crate::sumo::Stepper> = match physics {
        PhysicsEngine::Native => Box::new(NativeIdmStepper {
            scenario: cfg.scenario,
            ..NativeIdmStepper::default()
        }),
        PhysicsEngine::Hlo(service) => {
            if cfg.fault(FaultSite::PjrtDispatch) {
                return Err(Error::Runtime(
                    "injected: PJRT dispatch failure".into(),
                ));
            }
            // geometry is a runtime operand of the schema-2 artifacts:
            // the same pooled executable serves every scenario family,
            // so scenario-matrix runs ride the PJRT fast path too
            let stepper = HloStepper::for_scenario(service.clone(), cfg.capacity, &cfg.scenario)?;
            // an explicit chunk_steps must name a lowered ladder rung —
            // a K nothing was compiled for would silently single-step
            // every chunk, which is exactly the misconfiguration the
            // launch check exists to catch
            if let ChunkSteps::Fixed(k) = cfg.chunk_steps {
                let k = k as usize;
                let ladder = &service.manifest().rollout_steps;
                if k != 1 && !ladder.contains(&k) {
                    return Err(Error::Config(format!(
                        "chunk_steps = {k} is not a lowered rollout rung \
                         (manifest ladder: {ladder:?}); use 'auto', 1, or a \
                         ladder K — or re-run `make artifacts`"
                    )));
                }
            }
            Box::new(stepper)
        }
    };
    // stall injection wraps the stepper so the wedge happens inside a
    // TraCI burst — exactly where the stall watchdog looks
    let stepper = match (&cfg.faults, cfg.fault(FaultSite::Stall)) {
        (Some(f), true) => f.plan.stall_wrap(stepper),
        _ => stepper,
    };
    let mut sim = SumoSim::new(cfg.scenario, cfg.capacity, routes, stepper);
    sim.set_chunk_limit(cfg.chunk_steps.limit());
    if cfg.fault(FaultSite::TraciAccept) {
        return Err(Error::PortInUse(port));
    }
    // a live PortLease hands over its bound listener — the port was
    // never released, so nothing could have stolen it; without a lease
    // (direct callers, retries past the first attempt) fall back to a
    // fresh bind, where a lost race is a transient PortInUse
    let server = match crate::pipeline::ports::redeem(port) {
        Some(listener) => TraciServer::spawn_on(listener, sim)?,
        None => TraciServer::spawn(port, sim)?,
    };

    // setup is done — a deadline blown during it surfaces here, before
    // the front-end opens (display + server drop guards clean up)
    watchdog.check_deadline()?;

    // (4) Webots front-end
    // the run loop inherits the SAME clock: the deadline covers the
    // instance end to end, not just the stepped portion
    let mut webots = WebotsSim::open(&cfg.world)?
        .with_stop_condition(StopCondition::SimTime(cfg.horizon_s))
        .with_watchdog(watchdog);

    if cfg.fault(FaultSite::InRunPanic) {
        // mid-run crash with the display lease and server thread live —
        // the exact state the drop guards + catch_unwind must clean up
        panic!("injected: in-run panic ({})", cfg.run_id);
    }

    // (5) run — TraCI-batched between controller sampling points (§Perf)
    let _end = webots.run(cfg.max_steps)?;
    let mut dataset = RunDataset::new(cfg.run_id.clone(), cfg.node, cfg.seed);
    if let Some(sr) = &cfg.scenario_run {
        // provenance: qualified run id + the generating parameter vector
        dataset = dataset.with_scenario(sr.tag.clone());
    }
    let dt = webots.world_info.basic_time_step_ms as f32 / 1000.0;
    // iterate the history in place — cloning it doubled the per-run
    // memory traffic for long horizons
    for (i, obs) in webots.history.iter().enumerate() {
        dataset.push((i + 1) as f32 * dt, obs);
    }
    let steps = webots.steps();
    // authoritative totals from the back-end before shutdown
    let (_, _, _, spawned) = webots.totals()?;
    dataset.total_spawned = spawned;
    // execution-path provenance: which steps rode the device-resident
    // whole-run dispatch path (0 = host chunk scheduler / native)
    let (_, resident_steps) = webots.run_stats()?;
    dataset.resident_steps = resident_steps;
    let controller_cmds = webots.controller_cmds();
    let display_no = display.display_number();
    webots.close()?;
    server.join()?;

    Ok(InstanceResult {
        dataset,
        display: display_no,
        port,
        steps,
        controller_cmds,
    })
}

/// Run `copies.len()` instances concurrently — one node's slots.  Real
/// threads, real sockets, shared display registry: the full §3.1.5
/// parallel configuration.
pub fn launch_node_slots(
    configs: Vec<InstanceConfig>,
    physics: &PhysicsEngine,
) -> Vec<Result<InstanceResult>> {
    let displays = DisplayRegistry::new();
    let sif = match crate::container::build_webots_hpc_image(BuildHost::PersonalComputer) {
        Ok(sif) => sif,
        Err(e) => {
            // no image, no launches: every slot fails with the same
            // (non-Clone) cause instead of panicking the whole node
            let msg = format!("image build failed: {e}");
            return configs
                .iter()
                .map(|_| Err(Error::Config(msg.clone())))
                .collect();
        }
    };
    std::thread::scope(|scope| {
        let displays = &displays;
        let handles: Vec<_> = configs
            .iter()
            .enumerate()
            .map(|(slot, cfg)| {
                // scoped threads borrow the (Arc-backed) registry
                // directly; the engine handle clone is one channel-sender
                // clone (Sender is not Sync on older toolchains)
                let env = ExecEnv::new(sif.clone()).bind("/tmp", "/tmp");
                let physics = physics.clone();
                scope.spawn(move || {
                    if telemetry::enabled() {
                        telemetry::emit(EventKind::SlotBegin {
                            node: cfg.node as u64,
                            slot: slot as u64,
                            run_id: cfg.run_id.clone(),
                        });
                    }
                    let r = launch_instance(cfg, displays, &env, &physics);
                    if telemetry::enabled() {
                        telemetry::emit(EventKind::SlotEnd {
                            node: cfg.node as u64,
                            slot: slot as u64,
                            run_id: cfg.run_id.clone(),
                            ok: r.is_ok(),
                        });
                    }
                    r
                })
            })
            .collect();
        // a panicked slot is ONE failed result, not a node-wide abort:
        // sibling handles still join and return their own outcomes
        handles
            .into_iter()
            .map(|h| match h.join() {
                Ok(r) => r,
                Err(payload) => Err(Error::Panic(panic_msg(payload))),
            })
            .collect()
    })
}

#[cfg(test)]
#[allow(clippy::unwrap_used, clippy::expect_used)]
mod tests {
    use super::*;
    use crate::pipeline::{propagate_copies, PortAllocator};
    use crate::webots::nodes::sample_merge_world;
    use std::net::TcpListener;

    fn free_base_port() -> u16 {
        TcpListener::bind("127.0.0.1:0")
            .unwrap()
            .local_addr()
            .unwrap()
            .port()
    }

    fn config(run_id: &str, world: World, seed: u64) -> InstanceConfig {
        InstanceConfig {
            run_id: run_id.into(),
            node: 0,
            world,
            flows: FlowFile::merge_sample(1200.0, 300.0, 30.0),
            scenario: MergeScenario::default(),
            seed,
            capacity: 64,
            horizon_s: 20.0,
            max_steps: 1000,
            scenario_run: None,
            chunk_steps: ChunkSteps::Auto,
            faults: None,
            watchdog: WatchdogSpec::default(),
        }
    }

    #[test]
    fn single_instance_native_end_to_end() {
        let world = sample_merge_world(free_base_port());
        let displays = DisplayRegistry::new();
        let env = ExecEnv::new(
            crate::container::build_webots_hpc_image(BuildHost::PersonalComputer).unwrap(),
        );
        let r = launch_instance(&config("t[1]", world, 7), &displays, &env, &PhysicsEngine::Native)
            .unwrap();
        assert!(r.steps >= 199, "ran the horizon: {}", r.steps);
        assert!(!r.dataset.rows.is_empty());
        assert!(r.dataset.total_spawned > 0);
        assert_eq!(r.display, 99);
    }

    #[test]
    fn eight_parallel_slots_one_node() {
        // the 6x8 setup's per-node parallelism, for real: 8 threads, 8
        // ports, 8 displays
        let base = free_base_port();
        let root = sample_merge_world(base);
        let copies = propagate_copies(&root, 8, &PortAllocator::new(base, 7)).unwrap();
        let configs: Vec<InstanceConfig> = copies
            .into_iter()
            .map(|c| {
                let mut cfg = config(&format!("t[{}]", c.index), c.world, c.index as u64 + 1);
                cfg.horizon_s = 5.0;
                cfg
            })
            .collect();
        let results = launch_node_slots(configs, &PhysicsEngine::Native);
        assert_eq!(results.len(), 8);
        let ok: Vec<_> = results.into_iter().map(|r| r.unwrap()).collect();
        // unique displays and ports across the node
        let mut displays: Vec<u32> = ok.iter().map(|r| r.display).collect();
        displays.sort_unstable();
        displays.dedup();
        assert_eq!(displays.len(), 8);
        let mut ports: Vec<u16> = ok.iter().map(|r| r.port).collect();
        ports.sort_unstable();
        ports.dedup();
        assert_eq!(ports.len(), 8);
        // every run produced data with its own seed
        assert!(ok.iter().all(|r| !r.dataset.rows.is_empty()));
    }

    #[test]
    fn scenario_matrix_instance_end_to_end() {
        use crate::scenario::{FamilyRegistry, SamplerKind, ScenarioMatrix};
        let matrix = ScenarioMatrix::new(
            vec!["lane-drop".into()],
            SamplerKind::Lhs { strata: 4 },
            4,
            77,
        );
        let planned = matrix.materialize(&FamilyRegistry::builtin(), 2).unwrap();
        let world = sample_merge_world(free_base_port());
        let mut cfg = InstanceConfig::from_planned("e0[2]", 1, world, &planned);
        cfg.horizon_s = 20.0;
        cfg.max_steps = 400;

        let displays = DisplayRegistry::new();
        let env = ExecEnv::new(
            crate::container::build_webots_hpc_image(BuildHost::PersonalComputer).unwrap(),
        );
        let r = launch_instance(&cfg, &displays, &env, &PhysicsEngine::Native).unwrap();
        // the dataset is self-describing: qualified id + parameter vector
        let ds = &r.dataset;
        assert_eq!(
            ds.run_id,
            format!("e0[2]@lane-drop#{}", planned.assignment.sample_index)
        );
        let tag = ds.scenario.as_ref().expect("scenario provenance");
        assert_eq!(tag.id.as_str(), "lane-drop");
        assert!(ds.param("demand_vph").is_some());
        assert!(!ds.rows.is_empty());
        assert!(ds.total_spawned > 0, "lane-drop traffic spawned");
    }

    /// The ISSUE 3 acceptance path: a scenario-matrix campaign runs end
    /// to end with `PhysicsEngine::Hlo` for all four builtin families —
    /// the launcher guard is gone and the geometry rides the artifact's
    /// runtime operand.  No-ops with a note when `make artifacts` hasn't
    /// run (same convention as the runtime tests).
    #[test]
    fn scenario_matrix_all_families_hlo_end_to_end() {
        use crate::runtime::EngineService;
        use crate::scenario::{FamilyRegistry, SamplerKind, ScenarioMatrix};
        let service = match EngineService::auto() {
            Ok(s) => s,
            Err(e) => {
                eprintln!("skipping HLO scenario-matrix test: {e}");
                return;
            }
        };
        // capacities come from the manifest's own lowered ladder, so
        // every materialized point must ride the PJRT path
        let registry = FamilyRegistry::builtin().with_buckets(&service.manifest().buckets);
        let matrix = ScenarioMatrix::new(
            vec![
                "highway-merge".into(),
                "lane-drop".into(),
                "ramp-weave".into(),
                "ring-shockwave".into(),
            ],
            SamplerKind::Lhs { strata: 4 },
            4,
            2021,
        );
        let displays = DisplayRegistry::new();
        let env = ExecEnv::new(
            crate::container::build_webots_hpc_image(BuildHost::PersonalComputer).unwrap(),
        );
        // run indices 0..4 are family-major round-robin: one run per family
        for run_index in 0..4u64 {
            let planned = matrix.materialize(&registry, run_index).unwrap();
            let family = planned.assignment.family.clone();
            assert!(
                service
                    .manifest()
                    .buckets
                    .contains(&planned.config.capacity),
                "{family}: suggested capacity {} has no lowered bucket ({:?}) — \
                 the ladder-from-manifest wiring regressed",
                planned.config.capacity,
                service.manifest().buckets
            );
            let world = sample_merge_world(free_base_port());
            let mut cfg =
                InstanceConfig::from_planned(format!("hlo[{run_index}]"), 0, world, &planned);
            cfg.horizon_s = cfg.horizon_s.min(20.0);
            cfg.max_steps = 400;
            let r = launch_instance(
                &cfg,
                &displays,
                &env,
                &PhysicsEngine::Hlo(service.clone()),
            )
            .unwrap_or_else(|e| panic!("{family}: {e}"));
            let ds = &r.dataset;
            let tag = ds.scenario.as_ref().expect("scenario provenance");
            assert_eq!(tag.id.as_str(), family, "run {run_index}");
            assert!(!ds.rows.is_empty(), "{family} produced data");
            assert!(ds.total_spawned > 0, "{family} traffic spawned");
        }
        // the pooled executables were shared across the families
        let usage = service.pool_usage().unwrap();
        assert!(usage.hits > 0, "pooled dispatches occurred: {usage:?}");
        service.shutdown();
    }

    /// `chunk_steps` is validated against the live manifest's rollout
    /// ladder at launch: a K nothing was lowered for must fail loudly
    /// (it would silently single-step every chunk), while `auto`, K=1
    /// and real ladder rungs run end to end.
    #[test]
    fn chunk_steps_validated_against_manifest_ladder() {
        use crate::runtime::EngineService;
        let service = match EngineService::auto() {
            Ok(s) => s,
            Err(e) => {
                eprintln!("skipping chunk-steps launch test: {e}");
                return;
            }
        };
        let displays = DisplayRegistry::new();
        let env = ExecEnv::new(
            crate::container::build_webots_hpc_image(BuildHost::PersonalComputer).unwrap(),
        );
        let physics = PhysicsEngine::Hlo(service.clone());
        let mk = |chunk: ChunkSteps, seed: u64| {
            let mut cfg = config("chunk", sample_merge_world(free_base_port()), seed);
            cfg.horizon_s = 5.0;
            cfg.with_chunk_steps(chunk)
        };
        // a rung nothing was compiled for (ladder Ks are powers the aot
        // path lowers; 7 never is)
        let err = launch_instance(&mk(ChunkSteps::Fixed(7), 1), &displays, &env, &physics)
            .unwrap_err()
            .to_string();
        assert!(err.contains("chunk_steps"), "{err}");
        // auto, forced step-by-step, and a real rung all complete
        let mut ok_chunks = vec![ChunkSteps::Auto, ChunkSteps::Fixed(1)];
        if let Some(&k) = service.manifest().rollout_steps.last() {
            ok_chunks.push(ChunkSteps::Fixed(k as u32));
        }
        for (i, chunk) in ok_chunks.into_iter().enumerate() {
            let r = launch_instance(&mk(chunk, 40 + i as u64), &displays, &env, &physics).unwrap();
            assert!(!r.dataset.rows.is_empty());
        }
        // same seed policy per launch — identical runs must produce the
        // identical history regardless of chunk policy
        let a = launch_instance(&mk(ChunkSteps::Auto, 7), &displays, &env, &physics).unwrap();
        let b = launch_instance(&mk(ChunkSteps::Fixed(1), 7), &displays, &env, &physics).unwrap();
        assert_eq!(a.dataset.rows, b.dataset.rows, "chunking changed the physics");
        service.shutdown();
    }

    /// The PR 10 acceptance path: with a sampling period spanning the
    /// horizon (one TraCI burst = the whole run) and a demand schedule
    /// that fits the compiled departure table, the run executes as ONE
    /// device-resident dispatch — and the dataset records it.  With the
    /// default sampling period the bursts are 2 steps, the fast path
    /// cannot engage, and the provenance stamp stays 0 (host chunking)
    /// while the physics stays identical.
    #[test]
    fn whole_run_fast_path_engages_and_stamps_provenance() {
        use crate::runtime::EngineService;
        let service = match EngineService::auto() {
            Ok(s) => s,
            Err(e) => {
                eprintln!("skipping whole-run launch test: {e}");
                return;
            }
        };
        if !service.manifest().runs_available() {
            eprintln!("skipping whole-run launch test: artifacts predate schema 5");
            return;
        }
        let displays = DisplayRegistry::new();
        let env = ExecEnv::new(
            crate::container::build_webots_hpc_image(BuildHost::PersonalComputer).unwrap(),
        );
        let physics = PhysicsEngine::Hlo(service.clone());
        // horizon = the smallest run-ladder rung (200 steps = 20 s)
        let rung = service.manifest().run_steps[0] as u64;
        let mk = |sampling_ms: u32, seed: u64| {
            let mut world = sample_merge_world(free_base_port());
            world
                .find_mut("SumoInterface")
                .unwrap()
                .set_field("samplingPeriod", sampling_ms.to_string());
            let mut cfg = config("resident", world, seed);
            cfg.horizon_s = rung as f32 * 0.1;
            cfg.max_steps = rung;
            cfg
        };
        // sampling period spans the horizon → the first burst is the
        // whole run → the resident fast path takes it in one dispatch
        let span_ms = rung as u32 * 100;
        let fused = launch_instance(&mk(span_ms, 7), &displays, &env, &physics).unwrap();
        assert_eq!(fused.steps, rung);
        assert_eq!(
            fused.dataset.resident_steps, rung,
            "whole horizon should be one device-resident dispatch"
        );
        // a chunk cap below the run rung gates the fast path out →
        // fallback to the PR 5 chunk scheduler, stamped as such.  Same
        // sampling period, so controller actuation boundaries agree.
        let k = *service.manifest().rollout_steps.last().unwrap() as u64;
        assert!(k < rung, "test premise: rollout rung below the run rung");
        let chunked = launch_instance(
            &mk(span_ms, 7).with_chunk_steps(ChunkSteps::Fixed(k as u32)),
            &displays,
            &env,
            &physics,
        )
        .unwrap();
        assert_eq!(
            chunked.dataset.resident_steps, 0,
            "host-chunked runs must stamp 0 resident steps"
        );
        // same seed → the two paths must produce the identical dataset
        assert_eq!(fused.dataset.rows, chunked.dataset.rows, "paths diverged");
        assert_eq!(fused.dataset.total_spawned, chunked.dataset.total_spawned);
        // the default 200 ms sampling period (2-step bursts) also gates
        // the fast path out on its own
        let bursty = launch_instance(&mk(200, 7), &displays, &env, &physics).unwrap();
        assert_eq!(bursty.dataset.resident_steps, 0);
        // native runs always stamp 0
        let native = launch_instance(
            &mk(rung as u32 * 100, 7),
            &displays,
            &env,
            &PhysicsEngine::Native,
        )
        .unwrap();
        assert_eq!(native.dataset.resident_steps, 0);
        service.shutdown();
    }

    #[test]
    fn duplicate_ports_fail_one_instance() {
        // two copies with the SAME port — the §4.2.1 misconfiguration
        let base = free_base_port();
        let root = sample_merge_world(base);
        let configs = vec![
            config("a", root.clone(), 1),
            config("b", root.clone(), 2),
        ];
        let results = launch_node_slots(configs, &PhysicsEngine::Native);
        let failures = results.iter().filter(|r| r.is_err()).count();
        assert_eq!(failures, 1, "exactly one of the two instances crashes");
    }
}
