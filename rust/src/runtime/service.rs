//! The engine service: one PJRT context per node process, shared by all
//! simulation instances over a request channel.
//!
//! The `xla` crate's PJRT handles are not `Send` (internally `Rc` + raw
//! pointers), but the launcher runs 8 instances on 8 threads.  Rather
//! than paying a full client + compile per instance (measured in the
//! `ablations` bench), a single service thread owns the [`Engine`] and
//! instances talk to it over channels — the same shape as a per-node
//! accelerator context shared by co-located workers in a real serving
//! stack.

use std::path::PathBuf;
use std::sync::mpsc::{channel, Sender};

use crate::sumo::state::Traffic;
use crate::sumo::{StepObs, Stepper};
use crate::{Error, Result};

use super::engine::{Engine, StepOutputs};
use super::manifest::Manifest;

enum Request {
    Step {
        bucket: usize,
        state: Vec<f32>,
        params: Vec<f32>,
        reply: Sender<Result<StepOutputs>>,
    },
    Idm {
        bucket: usize,
        state: Vec<f32>,
        params: Vec<f32>,
        reply: Sender<Result<Vec<f32>>>,
    },
    Radar {
        bucket: usize,
        state: Vec<f32>,
        reply: Sender<Result<Vec<f32>>>,
    },
    StepBatched {
        bucket: usize,
        states: Vec<f32>,
        params: Vec<f32>,
        reply: Sender<Result<Vec<StepOutputs>>>,
    },
    Shutdown,
}

/// Serve one Step request, dynamically micro-batching with any other
/// same-bucket Step requests already waiting on the channel (the §Perf
/// optimization: one PJRT dispatch amortized over up to `manifest.batch`
/// co-located instances).  Solo requests take the unbatched path with no
/// added latency — coalescing only ever drains requests that are already
/// queued.
#[allow(clippy::too_many_arguments)]
fn serve_step(
    engine: &Engine,
    rx: &std::sync::mpsc::Receiver<Request>,
    backlog: &mut std::collections::VecDeque<Request>,
    bucket: usize,
    state: Vec<f32>,
    params: Vec<f32>,
    reply: Sender<Result<StepOutputs>>,
) {
    let bmax = engine.manifest().batch;
    let mut batch: Vec<(Vec<f32>, Vec<f32>, Sender<Result<StepOutputs>>)> =
        vec![(state, params, reply)];
    if bmax >= 2 {
        // drain whatever is already queued; stash non-matching requests
        let mut waited = false;
        while batch.len() < bmax {
            match rx.try_recv() {
                Ok(Request::Step {
                    bucket: b2,
                    state,
                    params,
                    reply,
                }) if b2 == bucket => batch.push((state, params, reply)),
                Ok(other) => {
                    backlog.push_back(other);
                    // keep draining: later Steps may still match
                    if backlog.len() > 64 {
                        break;
                    }
                }
                Err(_) => {
                    // once a batch has formed, peers are likely mid-send:
                    // wait one short straggler window (lock-step workers
                    // re-issue immediately after their replies), then stop
                    if waited || batch.len() < 2 {
                        break;
                    }
                    waited = true;
                    match rx.recv_timeout(std::time::Duration::from_micros(60)) {
                        Ok(Request::Step {
                            bucket: b2,
                            state,
                            params,
                            reply,
                        }) if b2 == bucket => batch.push((state, params, reply)),
                        Ok(other) => backlog.push_back(other),
                        Err(_) => break,
                    }
                }
            }
        }
    }

    if batch.len() < 2 {
        let (state, params, reply) = batch.pop().expect("one request");
        let _ = reply.send(engine.step(bucket, &state, &params));
        return;
    }

    // pad to the artifact's batch width with zeroed (inactive) worlds
    let n_live = batch.len();
    let scols = crate::sumo::state::STATE_COLS;
    let pcols = crate::sumo::state::PARAM_COLS;
    let mut states = vec![0.0f32; bmax * bucket * scols];
    let mut params_all = vec![0.0f32; bmax * bucket * pcols];
    for (i, (s, p, _)) in batch.iter().enumerate() {
        states[i * bucket * scols..(i + 1) * bucket * scols].copy_from_slice(s);
        params_all[i * bucket * pcols..(i + 1) * bucket * pcols].copy_from_slice(p);
    }
    match engine.step_batched(bucket, &states, &params_all) {
        Ok(outs) => {
            debug_assert_eq!(outs.len(), bmax);
            debug_assert!(outs.len() >= n_live);
            for ((_, _, reply), out) in batch.into_iter().zip(outs.into_iter()) {
                let _ = reply.send(Ok(out));
            }
        }
        Err(e) => {
            // batched path failed (e.g. old artifacts): fall back to
            // serial execution so callers still get answers
            let msg = e.to_string();
            for (s, p, reply) in batch {
                let r = engine
                    .step(bucket, &s, &p)
                    .map_err(|e2| crate::Error::Runtime(format!("{msg}; serial fallback: {e2}")));
                let _ = reply.send(r);
            }
        }
    }
}

/// A cloneable, `Send` handle to the engine thread.
#[derive(Debug, Clone)]
pub struct EngineService {
    tx: Sender<Request>,
    manifest: Manifest,
    platform: String,
}

impl EngineService {
    /// Boot the engine on a dedicated thread from an artifacts dir.
    pub fn spawn(dir: PathBuf) -> Result<EngineService> {
        let (tx, rx) = channel::<Request>();
        let (boot_tx, boot_rx) = channel::<Result<(Manifest, String)>>();
        std::thread::spawn(move || {
            let engine = match Engine::new(dir) {
                Ok(e) => {
                    let _ = boot_tx.send(Ok((e.manifest().clone(), e.platform())));
                    e
                }
                Err(err) => {
                    let _ = boot_tx.send(Err(err));
                    return;
                }
            };
            // requests drained ahead of their turn while coalescing a batch
            let mut backlog: std::collections::VecDeque<Request> = Default::default();
            loop {
                let req = match backlog.pop_front() {
                    Some(r) => r,
                    None => match rx.recv() {
                        Ok(r) => r,
                        Err(_) => break,
                    },
                };
                match req {
                    Request::Step {
                        bucket,
                        state,
                        params,
                        reply,
                    } => {
                        serve_step(&engine, &rx, &mut backlog, bucket, state, params, reply);
                    }
                    Request::Idm {
                        bucket,
                        state,
                        params,
                        reply,
                    } => {
                        let _ = reply.send(engine.idm(bucket, &state, &params));
                    }
                    Request::Radar {
                        bucket,
                        state,
                        reply,
                    } => {
                        let _ = reply.send(engine.radar(bucket, &state));
                    }
                    Request::StepBatched {
                        bucket,
                        states,
                        params,
                        reply,
                    } => {
                        let _ = reply.send(engine.step_batched(bucket, &states, &params));
                    }
                    Request::Shutdown => break,
                }
            }
        });
        let (manifest, platform) = boot_rx
            .recv()
            .map_err(|_| Error::Runtime("engine thread died during boot".into()))??;
        Ok(EngineService {
            tx,
            manifest,
            platform,
        })
    }

    /// Boot from the auto-located artifacts directory.
    pub fn auto() -> Result<EngineService> {
        let dir = super::find_artifacts_dir()
            .ok_or_else(|| Error::Artifact("artifacts/ not found; run `make artifacts`".into()))?;
        Self::spawn(dir)
    }

    pub fn manifest(&self) -> &Manifest {
        &self.manifest
    }

    pub fn platform(&self) -> &str {
        &self.platform
    }

    pub fn step(&self, bucket: usize, state: &[f32], params: &[f32]) -> Result<StepOutputs> {
        let (reply, rx) = channel();
        self.tx
            .send(Request::Step {
                bucket,
                state: state.to_vec(),
                params: params.to_vec(),
                reply,
            })
            .map_err(|_| Error::Runtime("engine thread gone".into()))?;
        rx.recv()
            .map_err(|_| Error::Runtime("engine thread dropped reply".into()))?
    }

    pub fn idm(&self, bucket: usize, state: &[f32], params: &[f32]) -> Result<Vec<f32>> {
        let (reply, rx) = channel();
        self.tx
            .send(Request::Idm {
                bucket,
                state: state.to_vec(),
                params: params.to_vec(),
                reply,
            })
            .map_err(|_| Error::Runtime("engine thread gone".into()))?;
        rx.recv()
            .map_err(|_| Error::Runtime("engine thread dropped reply".into()))?
    }

    pub fn radar(&self, bucket: usize, state: &[f32]) -> Result<Vec<f32>> {
        let (reply, rx) = channel();
        self.tx
            .send(Request::Radar {
                bucket,
                state: state.to_vec(),
                reply,
            })
            .map_err(|_| Error::Runtime("engine thread gone".into()))?;
        rx.recv()
            .map_err(|_| Error::Runtime("engine thread dropped reply".into()))?
    }

    /// Explicit full-width batched step (benches; the normal path is the
    /// dynamic micro-batcher inside [`serve_step`]).  `states`/`params`
    /// must cover the manifest's full batch width.
    pub fn step_batched(
        &self,
        bucket: usize,
        states: &[f32],
        params: &[f32],
    ) -> Result<Vec<StepOutputs>> {
        let (reply, rx) = channel();
        self.tx
            .send(Request::StepBatched {
                bucket,
                states: states.to_vec(),
                params: params.to_vec(),
                reply,
            })
            .map_err(|_| Error::Runtime("engine thread gone".into()))?;
        rx.recv()
            .map_err(|_| Error::Runtime("engine thread dropped reply".into()))?
    }

    /// Ask the engine thread to exit (also happens when the last handle
    /// drops its sender).
    pub fn shutdown(&self) {
        let _ = self.tx.send(Request::Shutdown);
    }
}

/// [`Stepper`] over the AOT step artifact via the engine service: the
/// production physics engine.  Traffic capacity must equal a lowered
/// bucket.
pub struct HloStepper {
    service: EngineService,
    bucket: usize,
    pub last_obs: StepObs,
}

impl HloStepper {
    pub fn new(service: EngineService, capacity: usize) -> Result<HloStepper> {
        let bucket = service.manifest().bucket_for(capacity)?;
        if bucket != capacity {
            return Err(Error::Artifact(format!(
                "traffic capacity {capacity} must equal a lowered bucket (have {:?})",
                service.manifest().buckets
            )));
        }
        Ok(HloStepper {
            service,
            bucket,
            last_obs: StepObs::default(),
        })
    }
}

impl Stepper for HloStepper {
    fn step(&mut self, traffic: &mut Traffic) -> StepObs {
        // An execution error after successful compile means a corrupted
        // artifact — surface loudly.
        let out = self
            .service
            .step(self.bucket, &traffic.state, &traffic.params)
            .expect("AOT step execution failed");
        traffic.state.copy_from_slice(&out.state);
        let obs = StepObs {
            n_active: out.obs[0],
            mean_speed: out.obs[1],
            flow: out.obs[2],
            n_merged: out.obs[3],
        };
        self.last_obs = obs;
        obs
    }

    fn name(&self) -> &'static str {
        "hlo-pjrt"
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::sumo::state::DriverParams;

    fn service() -> Option<EngineService> {
        match EngineService::auto() {
            Ok(s) => Some(s),
            Err(e) => {
                eprintln!("skipping PJRT service test: {e}");
                None
            }
        }
    }

    #[test]
    fn service_boots_and_steps() {
        let Some(s) = service() else { return };
        assert_eq!(s.platform().to_lowercase(), "cpu");
        let bucket = s.manifest().buckets[0];
        let mut t = Traffic::new(bucket);
        t.spawn(100.0, 20.0, 1.0, DriverParams::default());
        let out = s.step(bucket, &t.state, &t.params).unwrap();
        assert_eq!(out.obs[0], 1.0);
        s.shutdown();
    }

    #[test]
    fn hlo_stepper_advances_traffic() {
        let Some(s) = service() else { return };
        let bucket = s.manifest().buckets[0];
        let mut stepper = HloStepper::new(s, bucket).unwrap();
        let mut t = Traffic::new(bucket);
        t.spawn(100.0, 20.0, 1.0, DriverParams::default());
        let x0 = t.x(0);
        let obs = stepper.step(&mut t);
        assert!(t.x(0) > x0, "vehicle moved");
        assert_eq!(obs.n_active, 1.0);
    }

    #[test]
    fn capacity_must_match_bucket() {
        let Some(s) = service() else { return };
        assert!(HloStepper::new(s, 7).is_err());
    }

    #[test]
    fn service_usable_from_many_threads() {
        let Some(s) = service() else { return };
        let bucket = s.manifest().buckets[0];
        std::thread::scope(|scope| {
            for k in 0..4 {
                let s = s.clone();
                scope.spawn(move || {
                    let mut t = Traffic::new(bucket);
                    t.spawn(10.0 * k as f32, 20.0, 1.0, DriverParams::default());
                    let out = s.step(bucket, &t.state, &t.params).unwrap();
                    assert_eq!(out.obs[0], 1.0);
                });
            }
        });
    }
}
