//! The engine service: one PJRT context per node process, shared by all
//! simulation instances over a request channel.
//!
//! The `xla` crate's PJRT handles are not `Send` (internally `Rc` + raw
//! pointers), but the launcher runs 8 instances on 8 threads.  Rather
//! than paying a full client + compile per instance (measured in the
//! `ablations` bench), a single service thread owns the [`Engine`] and
//! instances talk to it over channels — the same shape as a per-node
//! accelerator context shared by co-located workers in a real serving
//! stack.
//!
//! Two request paths exist (EXPERIMENTS.md §Perf):
//!
//! * [`EngineService::step`] — the simple one-shot API: a fresh reply
//!   channel and input copies per call.  Kept for tests/benches and as
//!   the "before" baseline.
//! * [`EngineSession`] — the production hot path: a persistent
//!   per-instance handle with one long-lived reply channel and pooled
//!   request/output buffers that round-trip through the engine thread,
//!   so steady-state stepping performs **no per-call channel creation
//!   and no input `to_vec()`** — inputs are `copy_from_slice`-class
//!   copies into reused storage (outputs: see
//!   [`super::engine::Engine::step_into`] for the FFI-boundary caveat).
//!
//! Both paths coalesce in the same dynamic micro-batcher, whose padding
//! scratch (`states`/`params`/`geoms`/`outs`) is owned by the engine
//! thread and reused across dispatches.  Since schema 2 the scenario
//! geometry is a per-request operand row ([`GeometryVec`]) rather than
//! a compile-time constant, so instances running *different* scenario
//! families share the pooled executables AND coalesce into the same
//! batched dispatches.

use std::collections::VecDeque;
use std::path::PathBuf;
use std::sync::mpsc::{channel, Receiver, Sender};
use std::sync::Arc;
use std::time::{Duration, Instant};

use crate::metrics::PoolUsage;
use crate::sumo::state::{GeometryVec, Traffic, GEOM_COLS, PARAM_COLS, STATE_COLS};
use crate::sumo::{MergeScenario, StepObs, Stepper};
use crate::telemetry::{self, metrics, EventKind};
use crate::{Error, Result};

use super::engine::{Engine, RolloutOutputs, RunOutputs, StepOutputs};
use super::manifest::Manifest;

use crate::sumo::{DepartureTable, DEP_COLS, DEP_PAD_EPOCH, D_STEP};

/// Where a step reply goes: a per-call channel (one-shot API) or a
/// session's persistent channel (buffers travel back with the reply).
enum StepReply {
    Oneshot(Sender<Result<StepOutputs>>),
    Session(Sender<SessionReply>),
}

/// Where a fused-rollout reply goes (mirrors [`StepReply`]).
enum RolloutReply {
    Oneshot(Sender<Result<RolloutOutputs>>),
    Session(Sender<SessionReply>),
}

/// One step request — input buffers, the scenario geometry row, and the
/// output buffers to fill.  Session requests lend their buffers to the
/// engine thread; the reply returns them for reuse.  The geometry is a
/// `Copy` row (no allocation), travelling per-request exactly like the
/// per-lane `DriverParams` rows do — which is what lets co-located
/// instances running *different* scenario families coalesce into one
/// batched dispatch.
struct StepReq {
    bucket: usize,
    state: Vec<f32>,
    params: Vec<f32>,
    geom: GeometryVec,
    out: StepOutputs,
    reply: StepReply,
    /// When the caller sent the request — dispatch time minus this is
    /// the lane's queue wait (`service.lane.queue_wait_us`).
    enqueued: Instant,
}

/// One fused-rollout request (schema 4): like [`StepReq`] plus the
/// K-ladder rung.  Same-`(bucket, k)` rollouts coalesce into one
/// batched `rolloutb{k}` dispatch; everything else falls back to the
/// solo (or, on artifact errors, the per-request serial) path.
struct RolloutReq {
    bucket: usize,
    /// Fused steps per dispatch — must be a manifest ladder rung.
    k: usize,
    state: Vec<f32>,
    params: Vec<f32>,
    geom: GeometryVec,
    out: RolloutOutputs,
    reply: RolloutReply,
    /// See [`StepReq::enqueued`].
    enqueued: Instant,
}

/// One whole-run request (schema 5): a T-step run as one dispatch, the
/// demand schedule riding along as the flattened departure-table
/// operand.  Same-`(bucket, t)` runs coalesce into one `runb{t}`
/// dispatch — the whole-run micro-batcher lane.  Replies are per-call
/// channels on both the one-shot and session paths: a run amortizes its
/// buffers over T steps, so the per-step zero-allocation discipline of
/// [`StepReq`] buys nothing here.
struct RunReq {
    bucket: usize,
    /// Total steps — must be a manifest run-ladder rung.
    t: usize,
    state: Vec<f32>,
    params: Vec<f32>,
    geom: GeometryVec,
    /// Flattened `f32[D, DEP_COLS]` departure table.
    departures: Vec<f32>,
    out: RunOutputs,
    reply: Sender<Result<RunOutputs>>,
    /// See [`StepReq::enqueued`].
    enqueued: Instant,
}

/// What a session reply carries back besides the input buffers: the
/// single-step outputs or a fused chunk's outputs, depending on which
/// request the session issued.
enum SessionPayload {
    Step(StepOutputs),
    Rollout(RolloutOutputs),
}

/// Reply on a session's persistent channel: the round-tripped buffers
/// (inputs back for reuse, outputs filled) plus the execution status.
struct SessionReply {
    state: Vec<f32>,
    params: Vec<f32>,
    payload: SessionPayload,
    result: Result<()>,
}

enum Request {
    Step(StepReq),
    Rollout(RolloutReq),
    Run(RunReq),
    Idm {
        bucket: usize,
        state: Vec<f32>,
        params: Vec<f32>,
        reply: Sender<Result<Vec<f32>>>,
    },
    Radar {
        bucket: usize,
        state: Vec<f32>,
        reply: Sender<Result<Vec<f32>>>,
    },
    StepBatched {
        bucket: usize,
        states: Vec<f32>,
        params: Vec<f32>,
        geoms: Vec<f32>,
        reply: Sender<Result<Vec<StepOutputs>>>,
    },
    PoolUsage {
        reply: Sender<PoolUsage>,
    },
    Shutdown,
}

/// Cached handles into the global telemetry registry for the
/// micro-batcher's lane series — the exact metrics the ROADMAP's
/// deadline-aware scheduler will be judged on.  Fetched once per
/// engine thread; recording is relaxed atomics only.
struct LaneMetrics {
    queue_wait_us: Arc<crate::telemetry::Histogram>,
    batch_size: Arc<crate::telemetry::Histogram>,
    coalesced: Arc<crate::telemetry::Counter>,
    serial_fallbacks: Arc<crate::telemetry::Counter>,
    backlog_depth: Arc<crate::telemetry::Gauge>,
}

impl LaneMetrics {
    fn new() -> LaneMetrics {
        LaneMetrics {
            queue_wait_us: metrics::histogram("service.lane.queue_wait_us"),
            batch_size: metrics::histogram("service.lane.batch_size"),
            coalesced: metrics::counter("service.lane.coalesced"),
            serial_fallbacks: metrics::counter("service.lane.serial_fallback"),
            backlog_depth: metrics::gauge("service.lane.backlog_depth"),
        }
    }

    /// Record queue waits + batch size for one formed dispatch, and
    /// emit a `Coalesced` event when the batcher actually merged
    /// requests.  `kind`/`k` name the dispatch family.
    fn dispatch_formed(
        &self,
        kind: &'static str,
        bucket: usize,
        k: usize,
        enqueued: impl ExactSizeIterator<Item = Instant>,
    ) {
        let now = Instant::now();
        let batch = enqueued.len();
        for t in enqueued {
            self.queue_wait_us
                .record(now.saturating_duration_since(t).as_micros() as u64);
        }
        self.batch_size.record(batch as u64);
        if batch >= 2 {
            self.coalesced.inc();
            if telemetry::enabled() {
                telemetry::emit(EventKind::Coalesced {
                    kind: kind.into(),
                    bucket: bucket as u64,
                    k: k as u64,
                    batch: batch as u64,
                });
            }
        }
    }

    /// Record one batched-path failure that fell back to per-request
    /// serial execution.
    fn fallback(&self, kind: &'static str, bucket: usize, k: usize, batch: usize, error: &str) {
        self.serial_fallbacks.inc();
        if telemetry::enabled() {
            telemetry::emit(EventKind::SerialFallback {
                kind: kind.into(),
                bucket: bucket as u64,
                k: k as u64,
                batch: batch as u64,
                error: error.into(),
            });
        }
    }
}

/// Engine-thread scratch for the micro-batcher, reused across
/// dispatches: the coalesced request lists (single-step and rollout),
/// the zero-padded input staging buffers (shared — only one dispatch is
/// in flight at a time), and the per-lane output buffers.
#[derive(Default)]
struct BatchScratch {
    batch: Vec<StepReq>,
    rollouts: Vec<RolloutReq>,
    runs: Vec<RunReq>,
    states: Vec<f32>,
    params: Vec<f32>,
    geoms: Vec<f32>,
    /// Departure-table staging for the whole-run lane (padding lanes get
    /// all-[`DEP_PAD_EPOCH`] tables so no phantom spawn lands in a dead
    /// world).
    deps: Vec<f32>,
    outs: Vec<StepOutputs>,
    routs: Vec<RolloutOutputs>,
    runouts: Vec<RunOutputs>,
}

/// Send the finished request back to its caller, routing buffers to the
/// right reply flavor.
fn finish(req: StepReq, result: Result<()>) {
    let StepReq {
        state,
        params,
        out,
        reply,
        .. // bucket + the Copy geometry row need no return trip
    } = req;
    match reply {
        StepReply::Oneshot(tx) => {
            let _ = tx.send(result.map(|()| out));
        }
        StepReply::Session(tx) => {
            let _ = tx.send(SessionReply {
                state,
                params,
                payload: SessionPayload::Step(out),
                result,
            });
        }
    }
}

/// [`finish`] for fused-rollout requests.
fn finish_rollout(req: RolloutReq, result: Result<()>) {
    let RolloutReq {
        state,
        params,
        out,
        reply,
        ..
    } = req;
    match reply {
        RolloutReply::Oneshot(tx) => {
            let _ = tx.send(result.map(|()| out));
        }
        RolloutReply::Session(tx) => {
            let _ = tx.send(SessionReply {
                state,
                params,
                payload: SessionPayload::Rollout(out),
                result,
            });
        }
    }
}

/// Serve one Step request, dynamically micro-batching with any other
/// same-bucket Step requests already waiting on the channel (the §Perf
/// optimization: one PJRT dispatch amortized over up to `manifest.batch`
/// co-located instances).  Geometry deliberately does NOT gate
/// coalescing: rows travel per-lane through the vmapped artifact, so a
/// node running a mixed-family scenario matrix still fills whole
/// batches.  Solo requests take the unbatched path with no added
/// latency — coalescing only ever drains requests that are already
/// queued.
fn serve_step(
    engine: &Engine,
    rx: &Receiver<Request>,
    backlog: &mut VecDeque<Request>,
    scratch: &mut BatchScratch,
    lane: &LaneMetrics,
    first: StepReq,
) {
    let bucket = first.bucket;
    let bmax = engine.manifest().batch;
    let scols = STATE_COLS;
    let pcols = PARAM_COLS;
    // malformed shapes can't be padded into a batch; they take the solo
    // path below, where `step_into` rejects them with a proper error
    let well_formed =
        first.state.len() == bucket * scols && first.params.len() == bucket * pcols;
    scratch.batch.clear();
    scratch.batch.push(first);

    if bmax >= 2 && well_formed {
        // drain whatever is already queued; stash non-matching requests
        let mut waited = false;
        while scratch.batch.len() < bmax {
            match rx.try_recv() {
                Ok(Request::Step(r))
                    if r.bucket == bucket
                        && r.state.len() == bucket * scols
                        && r.params.len() == bucket * pcols =>
                {
                    scratch.batch.push(r)
                }
                Ok(other) => {
                    backlog.push_back(other);
                    // keep draining: later Steps may still match
                    if backlog.len() > 64 {
                        break;
                    }
                }
                Err(_) => {
                    // once a batch has formed, peers are likely mid-send:
                    // wait one short straggler window (lock-step workers
                    // re-issue immediately after their replies), then stop
                    if waited || scratch.batch.len() < 2 {
                        break;
                    }
                    waited = true;
                    match rx.recv_timeout(Duration::from_micros(60)) {
                        Ok(Request::Step(r))
                            if r.bucket == bucket
                                && r.state.len() == bucket * scols
                                && r.params.len() == bucket * pcols =>
                        {
                            scratch.batch.push(r)
                        }
                        Ok(other) => backlog.push_back(other),
                        Err(_) => break,
                    }
                }
            }
        }
    }

    lane.dispatch_formed("step", bucket, 0, scratch.batch.iter().map(|r| r.enqueued));

    if scratch.batch.len() < 2 {
        let Some(mut req) = scratch.batch.pop() else {
            return; // drained by a racing flush; nothing to dispatch
        };
        let result = engine.step_into(bucket, &req.state, &req.params, &req.geom, &mut req.out);
        finish(req, result);
        return;
    }

    // pad to the artifact's batch width with zeroed (inactive) worlds,
    // reusing the thread-owned staging buffers; each live lane carries
    // its own geometry row (mixed-family batches are one dispatch)
    let n_live = scratch.batch.len();
    scratch.states.clear();
    scratch.states.resize(bmax * bucket * scols, 0.0);
    scratch.params.clear();
    scratch.params.resize(bmax * bucket * pcols, 0.0);
    scratch.geoms.clear();
    scratch.geoms.resize(bmax * GEOM_COLS, 0.0);
    for (i, r) in scratch.batch.iter().enumerate() {
        scratch.states[i * bucket * scols..(i + 1) * bucket * scols].copy_from_slice(&r.state);
        scratch.params[i * bucket * pcols..(i + 1) * bucket * pcols].copy_from_slice(&r.params);
        scratch.geoms[i * GEOM_COLS..(i + 1) * GEOM_COLS].copy_from_slice(r.geom.as_slice());
    }
    match engine.step_batched_into(
        bucket,
        &scratch.states,
        &scratch.params,
        &scratch.geoms,
        &mut scratch.outs,
    ) {
        Ok(()) => {
            debug_assert_eq!(scratch.outs.len(), bmax);
            debug_assert!(scratch.outs.len() >= n_live);
            for (i, mut req) in scratch.batch.drain(..).enumerate() {
                // hand the filled lane to the caller and keep its old
                // buffers as next dispatch's scratch (both right-sized)
                std::mem::swap(&mut req.out, &mut scratch.outs[i]);
                finish(req, Ok(()));
            }
        }
        Err(e) => {
            // batched path failed (e.g. old artifacts): fall back to
            // serial execution so callers still get answers
            let msg = e.to_string();
            lane.fallback("step", bucket, 0, n_live, &msg);
            for mut req in scratch.batch.drain(..) {
                let result = engine
                    .step_into(bucket, &req.state, &req.params, &req.geom, &mut req.out)
                    .map_err(|e2| Error::Runtime(format!("{msg}; serial fallback: {e2}")));
                finish(req, result);
            }
        }
    }
}

/// Serve one fused-rollout request, dynamically micro-batching with any
/// other waiting rollout of the SAME `(bucket, k)` into one
/// `rolloutb{k}` dispatch (the chunked analogue of [`serve_step`]): up
/// to `manifest.batch` co-located instances × `k` fused steps ride a
/// single PJRT dispatch.  Requests with a different K (or bucket) stay
/// in the backlog and form their own batches — the chunk scheduler
/// aligns lock-step workers on the same ladder rung, so same-K batches
/// are the common case.  Artifact errors on the batched path fall back
/// to per-request solo rollouts, exactly like the single-step path.
fn serve_rollout(
    engine: &Engine,
    rx: &Receiver<Request>,
    backlog: &mut VecDeque<Request>,
    scratch: &mut BatchScratch,
    lane: &LaneMetrics,
    first: RolloutReq,
) {
    let (bucket, k) = (first.bucket, first.k);
    let bmax = engine.manifest().batch;
    let scols = STATE_COLS;
    let pcols = PARAM_COLS;
    let well_formed =
        first.state.len() == bucket * scols && first.params.len() == bucket * pcols;
    scratch.rollouts.clear();
    scratch.rollouts.push(first);

    if bmax >= 2 && well_formed {
        let mut waited = false;
        while scratch.rollouts.len() < bmax {
            match rx.try_recv() {
                Ok(Request::Rollout(r))
                    if r.bucket == bucket
                        && r.k == k
                        && r.state.len() == bucket * scols
                        && r.params.len() == bucket * pcols =>
                {
                    scratch.rollouts.push(r)
                }
                Ok(other) => {
                    backlog.push_back(other);
                    if backlog.len() > 64 {
                        break;
                    }
                }
                Err(_) => {
                    // same short straggler window as the single-step
                    // batcher: once a batch has formed, lock-step peers
                    // are likely mid-send of the same ladder rung
                    if waited || scratch.rollouts.len() < 2 {
                        break;
                    }
                    waited = true;
                    match rx.recv_timeout(Duration::from_micros(60)) {
                        Ok(Request::Rollout(r))
                            if r.bucket == bucket
                                && r.k == k
                                && r.state.len() == bucket * scols
                                && r.params.len() == bucket * pcols =>
                        {
                            scratch.rollouts.push(r)
                        }
                        Ok(other) => backlog.push_back(other),
                        Err(_) => break,
                    }
                }
            }
        }
    }

    lane.dispatch_formed("rollout", bucket, k, scratch.rollouts.iter().map(|r| r.enqueued));

    if scratch.rollouts.len() < 2 {
        let Some(mut req) = scratch.rollouts.pop() else {
            return; // drained by a racing flush; nothing to dispatch
        };
        let result =
            engine.rollout_into(bucket, k, &req.state, &req.params, &req.geom, &mut req.out);
        finish_rollout(req, result);
        return;
    }

    // pad to the artifact's batch width with zeroed (inactive) worlds —
    // same shared staging scratch as the single-step batcher
    let n_live = scratch.rollouts.len();
    scratch.states.clear();
    scratch.states.resize(bmax * bucket * scols, 0.0);
    scratch.params.clear();
    scratch.params.resize(bmax * bucket * pcols, 0.0);
    scratch.geoms.clear();
    scratch.geoms.resize(bmax * GEOM_COLS, 0.0);
    for (i, r) in scratch.rollouts.iter().enumerate() {
        scratch.states[i * bucket * scols..(i + 1) * bucket * scols].copy_from_slice(&r.state);
        scratch.params[i * bucket * pcols..(i + 1) * bucket * pcols].copy_from_slice(&r.params);
        scratch.geoms[i * GEOM_COLS..(i + 1) * GEOM_COLS].copy_from_slice(r.geom.as_slice());
    }
    match engine.rollout_batched_into(
        bucket,
        k,
        &scratch.states,
        &scratch.params,
        &scratch.geoms,
        &mut scratch.routs,
    ) {
        Ok(()) => {
            debug_assert_eq!(scratch.routs.len(), bmax);
            debug_assert!(scratch.routs.len() >= n_live);
            for (i, mut req) in scratch.rollouts.drain(..).enumerate() {
                std::mem::swap(&mut req.out, &mut scratch.routs[i]);
                finish_rollout(req, Ok(()));
            }
        }
        Err(e) => {
            // batched rollout unavailable (e.g. solo-only artifacts):
            // serve each caller with its own solo rollout
            let msg = e.to_string();
            lane.fallback("rollout", bucket, k, n_live, &msg);
            for mut req in scratch.rollouts.drain(..) {
                let result = engine
                    .rollout_into(bucket, k, &req.state, &req.params, &req.geom, &mut req.out)
                    .map_err(|e2| Error::Runtime(format!("{msg}; serial fallback: {e2}")));
                finish_rollout(req, result);
            }
        }
    }
}

/// Serve one whole-run request, dynamically micro-batching with any
/// other waiting run of the SAME `(bucket, t)` into one `runb{t}`
/// dispatch — the whole-run lane of the micro-batcher: up to
/// `manifest.batch` co-located instances × a WHOLE T-step run each ride
/// a single PJRT dispatch.  The launcher starts co-located instances
/// together and the run ladder pins them to the same T, so same-rung
/// batches are the common case.  Artifact errors on the batched path
/// fall back to per-request solo runs, exactly like the other lanes.
fn serve_run(
    engine: &Engine,
    rx: &Receiver<Request>,
    backlog: &mut VecDeque<Request>,
    scratch: &mut BatchScratch,
    lane: &LaneMetrics,
    first: RunReq,
) {
    let (bucket, t) = (first.bucket, first.t);
    let bmax = engine.manifest().batch;
    let d = engine.manifest().departure_rows;
    let scols = STATE_COLS;
    let pcols = PARAM_COLS;
    let well_formed = first.state.len() == bucket * scols
        && first.params.len() == bucket * pcols
        && first.departures.len() == d * DEP_COLS;
    scratch.runs.clear();
    scratch.runs.push(first);

    if bmax >= 2 && well_formed {
        let mut waited = false;
        while scratch.runs.len() < bmax {
            match rx.try_recv() {
                Ok(Request::Run(r))
                    if r.bucket == bucket
                        && r.t == t
                        && r.state.len() == bucket * scols
                        && r.params.len() == bucket * pcols
                        && r.departures.len() == d * DEP_COLS =>
                {
                    scratch.runs.push(r)
                }
                Ok(other) => {
                    backlog.push_back(other);
                    if backlog.len() > 64 {
                        break;
                    }
                }
                Err(_) => {
                    // a run dispatch is worth a longer straggler wait
                    // than a step (it amortizes over T steps), but peers
                    // launching together are already mid-send — the same
                    // short window keeps the solo path latency-free
                    if waited || scratch.runs.len() < 2 {
                        break;
                    }
                    waited = true;
                    match rx.recv_timeout(Duration::from_micros(60)) {
                        Ok(Request::Run(r))
                            if r.bucket == bucket
                                && r.t == t
                                && r.state.len() == bucket * scols
                                && r.params.len() == bucket * pcols
                                && r.departures.len() == d * DEP_COLS =>
                        {
                            scratch.runs.push(r)
                        }
                        Ok(other) => backlog.push_back(other),
                        Err(_) => break,
                    }
                }
            }
        }
    }

    lane.dispatch_formed("run", bucket, t, scratch.runs.iter().map(|r| r.enqueued));

    if scratch.runs.len() < 2 {
        let Some(mut req) = scratch.runs.pop() else {
            return; // drained by a racing flush; nothing to dispatch
        };
        let result = engine.run_into(
            bucket,
            t,
            &req.state,
            &req.params,
            &req.geom,
            &req.departures,
            &mut req.out,
        );
        let _ = req.reply.send(result.map(|()| req.out));
        return;
    }

    // pad to the artifact's batch width: zeroed (inactive) worlds with
    // all-padding departure tables, so no row ever comes due in a dead
    // lane — same shared staging scratch as the other lanes
    let n_live = scratch.runs.len();
    scratch.states.clear();
    scratch.states.resize(bmax * bucket * scols, 0.0);
    scratch.params.clear();
    scratch.params.resize(bmax * bucket * pcols, 0.0);
    scratch.geoms.clear();
    scratch.geoms.resize(bmax * GEOM_COLS, 0.0);
    scratch.deps.clear();
    scratch.deps.resize(bmax * d * DEP_COLS, 0.0);
    for row in n_live * d..bmax * d {
        scratch.deps[row * DEP_COLS + D_STEP] = DEP_PAD_EPOCH;
    }
    for (i, r) in scratch.runs.iter().enumerate() {
        scratch.states[i * bucket * scols..(i + 1) * bucket * scols].copy_from_slice(&r.state);
        scratch.params[i * bucket * pcols..(i + 1) * bucket * pcols].copy_from_slice(&r.params);
        scratch.geoms[i * GEOM_COLS..(i + 1) * GEOM_COLS].copy_from_slice(r.geom.as_slice());
        scratch.deps[i * d * DEP_COLS..(i + 1) * d * DEP_COLS].copy_from_slice(&r.departures);
    }
    match engine.run_batched_into(
        bucket,
        t,
        &scratch.states,
        &scratch.params,
        &scratch.geoms,
        &scratch.deps,
        &mut scratch.runouts,
    ) {
        Ok(()) => {
            debug_assert_eq!(scratch.runouts.len(), bmax);
            debug_assert!(scratch.runouts.len() >= n_live);
            for (i, mut req) in scratch.runs.drain(..).enumerate() {
                std::mem::swap(&mut req.out, &mut scratch.runouts[i]);
                let _ = req.reply.send(Ok(req.out));
            }
        }
        Err(e) => {
            // batched run unavailable (e.g. solo-only artifacts): serve
            // each caller with its own solo run
            let msg = e.to_string();
            lane.fallback("run", bucket, t, n_live, &msg);
            for mut req in scratch.runs.drain(..) {
                let result = engine
                    .run_into(
                        bucket,
                        t,
                        &req.state,
                        &req.params,
                        &req.geom,
                        &req.departures,
                        &mut req.out,
                    )
                    .map_err(|e2| Error::Runtime(format!("{msg}; serial fallback: {e2}")));
                let _ = req.reply.send(result.map(|()| req.out));
            }
        }
    }
}

/// A cloneable, `Send` handle to the engine thread.
#[derive(Debug, Clone)]
pub struct EngineService {
    tx: Sender<Request>,
    manifest: Manifest,
    platform: String,
}

impl EngineService {
    /// Boot the engine on a dedicated thread from an artifacts dir.
    pub fn spawn(dir: PathBuf) -> Result<EngineService> {
        let (tx, rx) = channel::<Request>();
        let (boot_tx, boot_rx) = channel::<Result<(Manifest, String)>>();
        std::thread::spawn(move || {
            let engine = match Engine::new(dir) {
                Ok(e) => {
                    let _ = boot_tx.send(Ok((e.manifest().clone(), e.platform())));
                    e
                }
                Err(err) => {
                    let _ = boot_tx.send(Err(err));
                    return;
                }
            };
            // requests drained ahead of their turn while coalescing a batch
            let mut backlog: VecDeque<Request> = VecDeque::new();
            let mut scratch = BatchScratch::default();
            let lane = LaneMetrics::new();
            loop {
                lane.backlog_depth.set(backlog.len() as i64);
                let req = match backlog.pop_front() {
                    Some(r) => r,
                    None => match rx.recv() {
                        Ok(r) => r,
                        Err(_) => break,
                    },
                };
                match req {
                    Request::Step(r) => {
                        serve_step(&engine, &rx, &mut backlog, &mut scratch, &lane, r);
                    }
                    Request::Rollout(r) => {
                        serve_rollout(&engine, &rx, &mut backlog, &mut scratch, &lane, r);
                    }
                    Request::Run(r) => {
                        serve_run(&engine, &rx, &mut backlog, &mut scratch, &lane, r);
                    }
                    Request::Idm {
                        bucket,
                        state,
                        params,
                        reply,
                    } => {
                        let _ = reply.send(engine.idm(bucket, &state, &params));
                    }
                    Request::Radar {
                        bucket,
                        state,
                        reply,
                    } => {
                        let _ = reply.send(engine.radar(bucket, &state));
                    }
                    Request::StepBatched {
                        bucket,
                        states,
                        params,
                        geoms,
                        reply,
                    } => {
                        let _ = reply.send(engine.step_batched(bucket, &states, &params, &geoms));
                    }
                    Request::PoolUsage { reply } => {
                        let _ = reply.send(engine.pool_usage());
                    }
                    Request::Shutdown => break,
                }
            }
        });
        let (manifest, platform) = boot_rx
            .recv()
            .map_err(|_| Error::Runtime("engine thread died during boot".into()))??;
        Ok(EngineService {
            tx,
            manifest,
            platform,
        })
    }

    /// Boot from the auto-located artifacts directory.
    pub fn auto() -> Result<EngineService> {
        let dir = super::find_artifacts_dir()
            .ok_or_else(|| Error::Artifact("artifacts/ not found; run `make artifacts`".into()))?;
        Self::spawn(dir)
    }

    pub fn manifest(&self) -> &Manifest {
        &self.manifest
    }

    pub fn platform(&self) -> &str {
        &self.platform
    }

    /// Open a persistent stepping session at `bucket` capacity under the
    /// default merge geometry.  See [`EngineService::session_for`].
    pub fn session(&self, bucket: usize) -> Result<EngineSession> {
        self.session_for(bucket, GeometryVec::default())
    }

    /// Open a persistent stepping session at `bucket` capacity for a
    /// specific scenario geometry — the allocation-free hot path.  One
    /// session per simulation instance; sessions from many threads (and
    /// *different geometries*) still coalesce in the micro-batcher.
    pub fn session_for(&self, bucket: usize, geom: GeometryVec) -> Result<EngineSession> {
        if !self.manifest.buckets.contains(&bucket) {
            return Err(Error::Artifact(format!(
                "no lowered bucket {bucket} (have {:?})",
                self.manifest.buckets
            )));
        }
        let (reply_tx, reply_rx) = channel();
        Ok(EngineSession {
            tx: self.tx.clone(),
            bucket,
            geom,
            reply_tx,
            reply_rx,
            state_buf: Vec::with_capacity(bucket * STATE_COLS),
            params_buf: Vec::with_capacity(bucket * PARAM_COLS),
            out: StepOutputs::default(),
            rollout_out: RolloutOutputs::default(),
            run_out: RunOutputs::default(),
        })
    }

    /// One-shot step under the default merge geometry.  Prefer
    /// [`EngineService::session_for`] on the hot path.
    pub fn step(&self, bucket: usize, state: &[f32], params: &[f32]) -> Result<StepOutputs> {
        self.step_geom(bucket, state, params, GeometryVec::default())
    }

    /// One-shot step under an explicit scenario geometry: fresh reply
    /// channel + input copies per call (tests/benches; the production
    /// path is a persistent session).
    pub fn step_geom(
        &self,
        bucket: usize,
        state: &[f32],
        params: &[f32],
        geom: GeometryVec,
    ) -> Result<StepOutputs> {
        let (reply, rx) = channel();
        self.tx
            .send(Request::Step(StepReq {
                bucket,
                state: state.to_vec(),
                params: params.to_vec(),
                geom,
                out: StepOutputs::default(),
                reply: StepReply::Oneshot(reply),
                enqueued: Instant::now(),
            }))
            .map_err(|_| Error::Runtime("engine thread gone".into()))?;
        rx.recv()
            .map_err(|_| Error::Runtime("engine thread dropped reply".into()))?
    }

    /// One-shot fused K-step rollout under an explicit scenario
    /// geometry (tests/benches; the production path is
    /// [`EngineSession::step_many`]).  `k` must be a rung of the
    /// manifest's rollout ladder ([`Manifest::rollout_steps`]).
    pub fn rollout_geom(
        &self,
        bucket: usize,
        k: usize,
        state: &[f32],
        params: &[f32],
        geom: GeometryVec,
    ) -> Result<RolloutOutputs> {
        let (reply, rx) = channel();
        self.tx
            .send(Request::Rollout(RolloutReq {
                bucket,
                k,
                state: state.to_vec(),
                params: params.to_vec(),
                geom,
                out: RolloutOutputs::default(),
                reply: RolloutReply::Oneshot(reply),
                enqueued: Instant::now(),
            }))
            .map_err(|_| Error::Runtime("engine thread gone".into()))?;
        rx.recv()
            .map_err(|_| Error::Runtime("engine thread dropped reply".into()))?
    }

    /// One-shot whole-run execution under an explicit scenario geometry
    /// (schema 5): a T-step run as ONE dispatch, demand riding along as
    /// the flattened `f32[D, DEP_COLS]` departure table.  `t` must be a
    /// rung of the manifest's run ladder ([`Manifest::run_steps`]).
    pub fn run_geom(
        &self,
        bucket: usize,
        t: usize,
        state: &[f32],
        params: &[f32],
        geom: GeometryVec,
        departures: &[f32],
    ) -> Result<RunOutputs> {
        let (reply, rx) = channel();
        self.tx
            .send(Request::Run(RunReq {
                bucket,
                t,
                state: state.to_vec(),
                params: params.to_vec(),
                geom,
                departures: departures.to_vec(),
                out: RunOutputs::default(),
                reply,
                enqueued: Instant::now(),
            }))
            .map_err(|_| Error::Runtime("engine thread gone".into()))?;
        rx.recv()
            .map_err(|_| Error::Runtime("engine thread dropped reply".into()))?
    }

    pub fn idm(&self, bucket: usize, state: &[f32], params: &[f32]) -> Result<Vec<f32>> {
        let (reply, rx) = channel();
        self.tx
            .send(Request::Idm {
                bucket,
                state: state.to_vec(),
                params: params.to_vec(),
                reply,
            })
            .map_err(|_| Error::Runtime("engine thread gone".into()))?;
        rx.recv()
            .map_err(|_| Error::Runtime("engine thread dropped reply".into()))?
    }

    pub fn radar(&self, bucket: usize, state: &[f32]) -> Result<Vec<f32>> {
        let (reply, rx) = channel();
        self.tx
            .send(Request::Radar {
                bucket,
                state: state.to_vec(),
                reply,
            })
            .map_err(|_| Error::Runtime("engine thread gone".into()))?;
        rx.recv()
            .map_err(|_| Error::Runtime("engine thread dropped reply".into()))?
    }

    /// Explicit full-width batched step under the default geometry for
    /// every lane (benches; the normal path is the dynamic micro-batcher
    /// inside [`serve_step`]).  `states`/`params` must cover the
    /// manifest's full batch width.
    pub fn step_batched(
        &self,
        bucket: usize,
        states: &[f32],
        params: &[f32],
    ) -> Result<Vec<StepOutputs>> {
        let b = self.manifest.batch.max(1);
        let mut geoms = Vec::with_capacity(b * GEOM_COLS);
        for _ in 0..b {
            geoms.extend_from_slice(GeometryVec::default().as_slice());
        }
        self.step_batched_geom(bucket, states, params, &geoms)
    }

    /// Explicit full-width batched step with per-lane geometry rows
    /// (`geoms` is `batch × GEOM_COLS` — one row per lane, so a single
    /// dispatch can carry a mixed-family batch).
    pub fn step_batched_geom(
        &self,
        bucket: usize,
        states: &[f32],
        params: &[f32],
        geoms: &[f32],
    ) -> Result<Vec<StepOutputs>> {
        let (reply, rx) = channel();
        self.tx
            .send(Request::StepBatched {
                bucket,
                states: states.to_vec(),
                params: params.to_vec(),
                geoms: geoms.to_vec(),
                reply,
            })
            .map_err(|_| Error::Runtime("engine thread gone".into()))?;
        rx.recv()
            .map_err(|_| Error::Runtime("engine thread dropped reply".into()))?
    }

    /// Executable-pool hit/miss counters from the engine thread — the
    /// campaign-summary observability of the pooled fast path.
    pub fn pool_usage(&self) -> Result<PoolUsage> {
        let (reply, rx) = channel();
        self.tx
            .send(Request::PoolUsage { reply })
            .map_err(|_| Error::Runtime("engine thread gone".into()))?;
        rx.recv()
            .map_err(|_| Error::Runtime("engine thread dropped reply".into()))
    }

    /// Ask the engine thread to exit (also happens when the last handle
    /// drops its sender).
    pub fn shutdown(&self) {
        let _ = self.tx.send(Request::Shutdown);
    }
}

/// A persistent per-instance stepping handle (EXPERIMENTS.md §Perf).
///
/// Steady-state [`EngineSession::step`] performs **zero allocations on
/// the caller side**: the input scratch and the reply channel are
/// created once at [`EngineService::session`] time, and all buffers
/// round-trip between this handle and the engine thread (on coalesced
/// dispatches the output lanes are refilled scratch; on solo dispatches
/// the engine swaps in the PJRT result vectors).
pub struct EngineSession {
    tx: Sender<Request>,
    bucket: usize,
    /// The session's scenario geometry row, sent with every request (a
    /// `Copy`, so the hot path stays allocation-free).
    geom: GeometryVec,
    reply_tx: Sender<SessionReply>,
    reply_rx: Receiver<SessionReply>,
    state_buf: Vec<f32>,
    params_buf: Vec<f32>,
    out: StepOutputs,
    /// Pooled fused-chunk outputs (round-trips through
    /// [`EngineSession::step_many`] like `out` does through `step`).
    rollout_out: RolloutOutputs,
    /// Pooled whole-run outputs ([`EngineSession::run`]).
    run_out: RunOutputs,
}

impl EngineSession {
    pub fn bucket(&self) -> usize {
        self.bucket
    }

    pub fn geometry(&self) -> GeometryVec {
        self.geom
    }

    /// Execute one step.  Copies `state`/`params` into the session's
    /// pooled buffers (no `to_vec`), sends them to the engine thread,
    /// and blocks on the session's persistent reply channel.  The
    /// returned reference is valid until the next `step` call.
    pub fn step(&mut self, state: &[f32], params: &[f32]) -> Result<&StepOutputs> {
        let mut sbuf = std::mem::take(&mut self.state_buf);
        let mut pbuf = std::mem::take(&mut self.params_buf);
        let out = std::mem::take(&mut self.out);
        sbuf.clear();
        sbuf.extend_from_slice(state);
        pbuf.clear();
        pbuf.extend_from_slice(params);
        self.tx
            .send(Request::Step(StepReq {
                bucket: self.bucket,
                state: sbuf,
                params: pbuf,
                geom: self.geom,
                out,
                reply: StepReply::Session(self.reply_tx.clone()),
                enqueued: Instant::now(),
            }))
            .map_err(|_| Error::Runtime("engine thread gone".into()))?;
        let reply = self
            .reply_rx
            .recv()
            .map_err(|_| Error::Runtime("engine thread dropped reply".into()))?;
        self.state_buf = reply.state;
        self.params_buf = reply.params;
        match reply.payload {
            SessionPayload::Step(out) => self.out = out,
            // unreachable: one request in flight per session, and Step
            // requests reply with Step payloads
            SessionPayload::Rollout(r) => self.rollout_out = r,
        }
        reply.result?;
        Ok(&self.out)
    }

    /// Execute one fused K-step chunk (schema 4): the engine advances
    /// the world by `k` physics steps in ONE dispatch and returns the
    /// final state plus the per-step obs trace — bit-identical to `k`
    /// [`EngineSession::step`] calls, minus `k - 1` host round-trips.
    /// Buffer discipline is identical to `step` (zero steady-state
    /// allocations on the caller side); the returned reference is valid
    /// until the next `step`/`step_many` call.  `k` must be a rung of
    /// the manifest's rollout ladder.
    pub fn step_many(
        &mut self,
        state: &[f32],
        params: &[f32],
        k: usize,
    ) -> Result<&RolloutOutputs> {
        let mut sbuf = std::mem::take(&mut self.state_buf);
        let mut pbuf = std::mem::take(&mut self.params_buf);
        let out = std::mem::take(&mut self.rollout_out);
        sbuf.clear();
        sbuf.extend_from_slice(state);
        pbuf.clear();
        pbuf.extend_from_slice(params);
        self.tx
            .send(Request::Rollout(RolloutReq {
                bucket: self.bucket,
                k,
                state: sbuf,
                params: pbuf,
                geom: self.geom,
                out,
                reply: RolloutReply::Session(self.reply_tx.clone()),
                enqueued: Instant::now(),
            }))
            .map_err(|_| Error::Runtime("engine thread gone".into()))?;
        let reply = self
            .reply_rx
            .recv()
            .map_err(|_| Error::Runtime("engine thread dropped reply".into()))?;
        self.state_buf = reply.state;
        self.params_buf = reply.params;
        match reply.payload {
            SessionPayload::Rollout(r) => self.rollout_out = r,
            SessionPayload::Step(out) => self.out = out,
        }
        reply.result?;
        Ok(&self.rollout_out)
    }

    /// Execute a WHOLE T-step run as one dispatch (schema 5): demand
    /// rides in as the flattened departure table, insertion happens
    /// in-kernel, and the reply carries final state + params, the whole
    /// per-step obs trace, and the inserted mask.  Unlike
    /// `step`/`step_many`, inputs are plain copies and the reply channel
    /// is per-call — a run amortizes them over T steps, so the per-step
    /// zero-allocation discipline buys nothing.  The returned reference
    /// is valid until the next `run` call.  `t` must be a rung of the
    /// manifest's run ladder.
    pub fn run(
        &mut self,
        state: &[f32],
        params: &[f32],
        departures: &[f32],
        t: usize,
    ) -> Result<&RunOutputs> {
        let (reply, rx) = channel();
        self.tx
            .send(Request::Run(RunReq {
                bucket: self.bucket,
                t,
                state: state.to_vec(),
                params: params.to_vec(),
                geom: self.geom,
                departures: departures.to_vec(),
                out: std::mem::take(&mut self.run_out),
                reply,
                enqueued: Instant::now(),
            }))
            .map_err(|_| Error::Runtime("engine thread gone".into()))?;
        self.run_out = rx
            .recv()
            .map_err(|_| Error::Runtime("engine thread dropped reply".into()))??;
        Ok(&self.run_out)
    }

    /// The outputs of the most recent successful [`EngineSession::step`].
    pub fn last(&self) -> &StepOutputs {
        &self.out
    }
}

/// [`Stepper`] over the AOT step artifact via a persistent
/// [`EngineSession`]: the production physics engine for ANY scenario
/// geometry (the executable takes the geometry as a runtime operand).
/// Traffic capacity must equal a lowered bucket.
///
/// With schema-4 artifacts the stepper also advertises the manifest's
/// fused-rollout K ladder through [`Stepper::chunk_ladder`], and
/// [`Stepper::step_many`] executes a whole chunk in ONE dispatch — the
/// `SumoSim` chunk scheduler is what decides how far ahead it may fuse.
pub struct HloStepper {
    session: EngineSession,
    /// Fusible chunk sizes, descending, always ending in 1 — the
    /// manifest's rollout ladder (`[1]` for schema-3 artifacts).  The
    /// chunk CAP is not stored here: `SumoSim::chunk_limit` is the
    /// single enforcement point for `chunk_steps`/live-GUI limits.
    ladder: Vec<usize>,
    /// Whole-run total-steps ladder, ascending — the manifest's run
    /// ladder (empty for schema <= 4 artifacts: the device-resident run
    /// path is simply unavailable and `SumoSim` stays on chunking).
    run_ladder: Vec<usize>,
    /// Departure-table row capacity of the run entries (0 = none).
    table_rows: usize,
    pub last_obs: StepObs,
}

impl HloStepper {
    /// A stepper for the classic default merge geometry.
    pub fn new(service: EngineService, capacity: usize) -> Result<HloStepper> {
        Self::for_scenario(service, capacity, &MergeScenario::default())
    }

    /// A stepper for an arbitrary scenario geometry — what the launcher
    /// uses for scenario-matrix runs (lane-drop, ramp-weave,
    /// ring-shockwave, parametrized merges) on the pooled PJRT fast
    /// path, with no per-geometry recompile.
    pub fn for_scenario(
        service: EngineService,
        capacity: usize,
        scenario: &MergeScenario,
    ) -> Result<HloStepper> {
        let bucket = service.manifest().bucket_for(capacity)?;
        if bucket != capacity {
            return Err(Error::Artifact(format!(
                "traffic capacity {capacity} must equal a lowered bucket (have {:?})",
                service.manifest().buckets
            )));
        }
        let mut ladder: Vec<usize> = if service.manifest().rollouts_available() {
            service.manifest().rollout_steps.clone()
        } else {
            vec![1]
        };
        ladder.sort_unstable_by(|a, b| b.cmp(a));
        if ladder.last() != Some(&1) {
            ladder.push(1);
        }
        let (run_ladder, table_rows) = if service.manifest().runs_available() {
            (
                service.manifest().run_steps.clone(),
                service.manifest().departure_rows,
            )
        } else {
            (Vec::new(), 0)
        };
        Ok(HloStepper {
            session: service.session_for(bucket, scenario.geometry_vec())?,
            ladder,
            run_ladder,
            table_rows,
            last_obs: StepObs::default(),
        })
    }
}

impl Stepper for HloStepper {
    // The Stepper trait is infallible by design (the native stepper
    // cannot fail); an execution error after a successful compile means
    // a corrupted artifact, and aborting the run is the correct
    // response — supervise_instance's catch_unwind contains it and the
    // retry taxonomy classes it as an engine fault.  Allowlisted in
    // rust/xtask/lint.allow with the same argument.
    #[allow(clippy::expect_used)]
    fn step(&mut self, traffic: &mut Traffic) -> StepObs {
        let out = self
            .session
            .step(&traffic.state, &traffic.params)
            .expect("AOT step execution failed");
        traffic.state.copy_from_slice(&out.state);
        let obs = StepObs {
            n_active: out.obs[0],
            mean_speed: out.obs[1],
            flow: out.obs[2],
            n_merged: out.obs[3],
            n_exited: out.obs[4],
        };
        self.last_obs = obs;
        obs
    }

    fn chunk_ladder(&self) -> &[usize] {
        &self.ladder
    }

    // same corrupted-artifact argument as step() above
    #[allow(clippy::expect_used)]
    fn step_many(&mut self, traffic: &mut Traffic, k: usize, out: &mut Vec<StepObs>) {
        if k <= 1 {
            out.push(self.step(traffic));
            return;
        }
        // one dispatch for the whole chunk: K steps of physics, one
        // host round-trip (bit-identical to K step() calls — asserted
        // by rust/tests/runtime_numerics.rs against live artifacts)
        let rollout = self
            .session
            .step_many(&traffic.state, &traffic.params, k)
            .expect("AOT rollout execution failed");
        traffic.state.copy_from_slice(&rollout.state);
        debug_assert_eq!(rollout.steps(), k);
        for i in 0..k {
            let row = rollout.obs_row(i);
            out.push(StepObs {
                n_active: row[0],
                mean_speed: row[1],
                flow: row[2],
                n_merged: row[3],
                n_exited: row[4],
            });
        }
        if let Some(last) = out.last() {
            self.last_obs = *last;
        }
    }

    fn run_ladder(&self) -> &[usize] {
        &self.run_ladder
    }

    fn run_table_rows(&self) -> usize {
        self.table_rows
    }

    // Unlike step()/step_many(), a failed whole-run dispatch is NOT a
    // panic: `SumoSim::try_run_resident` treats any error as "path
    // unavailable" and falls back to the chunk scheduler, so the error
    // is surfaced, not fatal.
    fn run_resident(
        &mut self,
        traffic: &mut Traffic,
        table: &DepartureTable,
        t_steps: usize,
        out: &mut Vec<StepObs>,
    ) -> Result<Vec<bool>> {
        let run = self
            .session
            .run(&traffic.state, &traffic.params, &table.rows, t_steps)?;
        if run.steps() != t_steps {
            return Err(Error::Runtime(format!(
                "run entry returned {} obs rows, expected {t_steps}",
                run.steps()
            )));
        }
        traffic.state.copy_from_slice(&run.state);
        // in-kernel spawns wrote their driver-params rows
        traffic.params.copy_from_slice(&run.params);
        for i in 0..t_steps {
            let row = run.obs_row(i);
            out.push(StepObs {
                n_active: row[0],
                mean_speed: row[1],
                flow: row[2],
                n_merged: row[3],
                n_exited: row[4],
            });
        }
        if let Some(last) = out.last() {
            self.last_obs = *last;
        }
        Ok(run.inserted[..table.count].iter().map(|&m| m > 0.5).collect())
    }

    fn name(&self) -> &'static str {
        "hlo-pjrt"
    }
}

#[cfg(test)]
#[allow(clippy::unwrap_used, clippy::expect_used)]
mod tests {
    use super::*;
    use crate::sumo::state::DriverParams;

    fn service() -> Option<EngineService> {
        match EngineService::auto() {
            Ok(s) => Some(s),
            Err(e) => {
                eprintln!("skipping PJRT service test: {e}");
                None
            }
        }
    }

    #[test]
    fn service_boots_and_steps() {
        let Some(s) = service() else { return };
        assert_eq!(s.platform().to_lowercase(), "cpu");
        let bucket = s.manifest().buckets[0];
        let mut t = Traffic::new(bucket);
        t.spawn(100.0, 20.0, 1.0, DriverParams::default());
        let out = s.step(bucket, &t.state, &t.params).unwrap();
        assert_eq!(out.obs[0], 1.0);
        s.shutdown();
    }

    #[test]
    fn session_matches_oneshot_across_repeats() {
        let Some(s) = service() else { return };
        let bucket = s.manifest().buckets[0];
        let mut t = Traffic::new(bucket);
        t.spawn(100.0, 20.0, 1.0, DriverParams::default());
        t.spawn(160.0, 15.0, 1.0, DriverParams::default());
        let expect = s.step(bucket, &t.state, &t.params).unwrap();
        let mut sess = s.session(bucket).unwrap();
        // steady state: the round-tripped buffers keep producing the
        // same numbers (no stale data, no cross-call leakage)
        for _ in 0..3 {
            let out = sess.step(&t.state, &t.params).unwrap();
            assert_eq!(*out, expect);
        }
        assert_eq!(*sess.last(), expect);
    }

    #[test]
    fn session_rejects_unknown_bucket() {
        let Some(s) = service() else { return };
        assert!(s.session(7).is_err());
        assert!(s.session_for(7, GeometryVec::default()).is_err());
    }

    #[test]
    fn session_geometry_is_honoured() {
        // two sessions at the SAME bucket (same pooled executable),
        // different geometry rows: the road end moves per session
        let Some(s) = service() else { return };
        let bucket = s.manifest().buckets[0];
        let mut t = Traffic::new(bucket);
        t.spawn(390.0, 30.0, 1.0, DriverParams::default());
        let mut default_sess = s.session(bucket).unwrap();
        let near = MergeScenario {
            road_end_m: 392.0,
            ..MergeScenario::default()
        };
        let mut near_sess = s.session_for(bucket, near.geometry_vec()).unwrap();
        let far = default_sess.step(&t.state, &t.params).unwrap();
        assert_eq!(far.obs[2], 0.0, "default road end: no flow yet");
        let out = near_sess.step(&t.state, &t.params).unwrap();
        assert_eq!(out.obs[2], 1.0, "session geometry retires the vehicle");
        assert_eq!(near_sess.geometry(), near.geometry_vec());
    }

    #[test]
    fn pool_usage_surfaces_hits_and_misses() {
        let Some(s) = service() else { return };
        let bucket = s.manifest().buckets[0];
        let mut t = Traffic::new(bucket);
        t.spawn(100.0, 20.0, 1.0, DriverParams::default());
        for _ in 0..3 {
            let _ = s.step(bucket, &t.state, &t.params).unwrap();
        }
        let usage = s.pool_usage().unwrap();
        // one compile for (step, bucket), then steady-state hits — the
        // pooled fast path's whole point, now observable
        assert!(usage.misses >= 1, "{usage:?}");
        assert!(usage.hits >= 2, "{usage:?}");
        assert!(usage.compiled >= 1, "{usage:?}");
        assert!(usage.hit_rate() > 0.0);
        // a different geometry at the same bucket must NOT compile a new
        // executable (geometry is an operand, not a pool key)
        let ring = MergeScenario {
            road_end_m: 1800.0,
            merge_start_m: 0.0,
            merge_end_m: 0.0,
            num_main_lanes: 1,
            ..MergeScenario::default()
        };
        let before = s.pool_usage().unwrap().compiled;
        let _ = s
            .step_geom(bucket, &t.state, &t.params, ring.geometry_vec())
            .unwrap();
        let after = s.pool_usage().unwrap();
        assert_eq!(
            after.compiled, before,
            "geometry change must not grow the pool: {after:?}"
        );
        s.shutdown();
    }

    #[test]
    fn session_surfaces_shape_errors_and_recovers() {
        let Some(s) = service() else { return };
        let bucket = s.manifest().buckets[0];
        let mut sess = s.session(bucket).unwrap();
        assert!(sess.step(&[0.0; 4], &[0.0; 6]).is_err());
        // the session stays usable after an error
        let mut t = Traffic::new(bucket);
        t.spawn(50.0, 10.0, 1.0, DriverParams::default());
        assert!(sess.step(&t.state, &t.params).is_ok());
    }

    #[test]
    fn hlo_stepper_advances_traffic() {
        let Some(s) = service() else { return };
        let bucket = s.manifest().buckets[0];
        let mut stepper = HloStepper::new(s, bucket).unwrap();
        let mut t = Traffic::new(bucket);
        t.spawn(100.0, 20.0, 1.0, DriverParams::default());
        let x0 = t.x(0);
        let obs = stepper.step(&mut t);
        assert!(t.x(0) > x0, "vehicle moved");
        assert_eq!(obs.n_active, 1.0);
    }

    #[test]
    fn capacity_must_match_bucket() {
        let Some(s) = service() else { return };
        assert!(HloStepper::new(s, 7).is_err());
    }

    #[test]
    fn service_usable_from_many_threads() {
        let Some(s) = service() else { return };
        let bucket = s.manifest().buckets[0];
        std::thread::scope(|scope| {
            for k in 0..4 {
                let s = s.clone();
                scope.spawn(move || {
                    let mut t = Traffic::new(bucket);
                    t.spawn(10.0 * k as f32, 20.0, 1.0, DriverParams::default());
                    let out = s.step(bucket, &t.state, &t.params).unwrap();
                    assert_eq!(out.obs[0], 1.0);
                });
            }
        });
    }

    /// Non-Step requests drained into the backlog while a batch
    /// coalesces must still be served (in issue order per caller) after
    /// the coalesced dispatch — a lost or reordered backlog entry shows
    /// up here as a wrong reply or a hang.
    #[test]
    fn backlog_requests_survive_coalescing_and_serve_in_order() {
        let Some(s) = service() else { return };
        let bucket = s.manifest().buckets[0];
        let mut t = Traffic::new(bucket);
        t.spawn(80.0, 18.0, 1.0, DriverParams::default());
        t.spawn(140.0, 9.0, 1.0, DriverParams::default());
        // solo references, computed before any contention
        let step_ref = s.step(bucket, &t.state, &t.params).unwrap();
        let idm_ref = s.idm(bucket, &t.state, &t.params).unwrap();
        let radar_ref = s.radar(bucket, &t.state).unwrap();
        std::thread::scope(|scope| {
            for k in 0..8 {
                let svc = s.clone();
                let (t, step_ref, idm_ref, radar_ref) = (&t, &step_ref, &idm_ref, &radar_ref);
                scope.spawn(move || {
                    for round in 0..10 {
                        // steppers coalesce; idm/radar requests land in
                        // the backlog mid-coalesce on the engine thread
                        let out = svc.step(bucket, &t.state, &t.params).unwrap();
                        assert_eq!(&out, step_ref, "thread {k} round {round}: step");
                        if k % 2 == 0 {
                            let idm = svc.idm(bucket, &t.state, &t.params).unwrap();
                            assert_eq!(&idm, idm_ref, "thread {k} round {round}: idm");
                        } else {
                            let radar = svc.radar(bucket, &t.state).unwrap();
                            assert_eq!(&radar, radar_ref, "thread {k} round {round}: radar");
                        }
                    }
                });
            }
        });
    }

    /// With a manifest that advertises a batch width but ships no
    /// `stepb` artifact (the "old artifacts" situation), the coalesced
    /// dispatch must fall back to serial execution and still hand every
    /// caller its own correct result.
    #[test]
    fn serial_fallback_when_batched_artifact_missing() {
        use crate::util::{Json, TempDir};
        let Some(dir) = super::super::find_artifacts_dir() else {
            eprintln!("skipping serial-fallback test: no artifacts");
            return;
        };
        let text = std::fs::read_to_string(dir.join("manifest.json")).unwrap();
        let mut j = Json::parse(&text).unwrap();
        let Json::Obj(top) = &mut j else {
            panic!("manifest is not an object")
        };
        // claim a batch width the (filtered) artifacts can't honor
        top.insert("batch".into(), Json::Num(8.0));
        let mut kept_files = Vec::new();
        if let Some(Json::Obj(entries)) = top.get_mut("entries") {
            let stepb_keys: Vec<String> = entries
                .keys()
                .filter(|k| k.starts_with("stepb"))
                .cloned()
                .collect();
            for k in stepb_keys {
                entries.remove(&k);
            }
            for e in entries.values() {
                kept_files.push(e.get("file").unwrap().as_str().unwrap().to_string());
            }
        }
        let tmp = TempDir::new("webots-hpc-fallback-artifacts").unwrap();
        std::fs::write(tmp.path().join("manifest.json"), j.to_pretty_string()).unwrap();
        for f in &kept_files {
            std::fs::copy(dir.join(f), tmp.path().join(f)).unwrap();
        }

        let s = EngineService::spawn(tmp.path().to_path_buf()).unwrap();
        assert!(s.manifest().batch >= 2, "test premise: batching enabled");
        let bucket = s.manifest().buckets[0];
        // the batched artifact really is gone
        let b = s.manifest().batch;
        let states = vec![0.0f32; b * bucket * STATE_COLS];
        let params = vec![0.0f32; b * bucket * PARAM_COLS];
        assert!(s.step_batched(bucket, &states, &params).is_err());

        // distinct worlds + solo references
        let worlds: Vec<Traffic> = (0..8)
            .map(|k| {
                let mut t = Traffic::new(bucket);
                t.spawn(15.0 + 25.0 * k as f32, 3.0 + 2.0 * k as f32, 1.0, DriverParams::default());
                t
            })
            .collect();
        let expect: Vec<StepOutputs> = worlds
            .iter()
            .map(|w| s.step(bucket, &w.state, &w.params).unwrap())
            .collect();
        // concurrent sessions force coalescing; every dispatch must
        // fall back serially and stay world-correct
        for _ in 0..3 {
            std::thread::scope(|scope| {
                for (w, e) in worlds.iter().zip(expect.iter()) {
                    let svc = s.clone();
                    scope.spawn(move || {
                        let mut sess = svc.session(bucket).unwrap();
                        for _ in 0..5 {
                            let out = sess.step(&w.state, &w.params).unwrap();
                            assert_eq!(out, e, "serial fallback contaminated a world");
                        }
                    });
                }
            });
        }
        s.shutdown();
    }

    #[test]
    fn session_step_many_matches_sequential_steps() {
        // the chunked hot path through the full service stack: one
        // fused dispatch == K session steps, bit for bit
        let Some(s) = service() else { return };
        if !s.manifest().rollouts_available() {
            eprintln!("skipping: artifacts predate schema 4");
            return;
        }
        let bucket = s.manifest().buckets[0];
        let mut t = Traffic::new(bucket);
        t.spawn(100.0, 20.0, 1.0, DriverParams::default());
        t.spawn(430.0, 28.0, 1.0, DriverParams::default().with_exit(450.0));
        for &k in &s.manifest().rollout_steps.clone() {
            let mut seq_sess = s.session(bucket).unwrap();
            let mut state = t.state.clone();
            let mut seq_obs = Vec::new();
            for _ in 0..k {
                let out = seq_sess.step(&state, &t.params).unwrap();
                state.copy_from_slice(&out.state);
                seq_obs.extend_from_slice(&out.obs);
            }
            let mut sess = s.session(bucket).unwrap();
            let out = sess.step_many(&t.state, &t.params, k).unwrap();
            assert_eq!(out.state, state, "K={k}");
            assert_eq!(out.obs, seq_obs, "K={k}");
        }
        s.shutdown();
    }

    #[test]
    fn session_interleaves_steps_and_chunks() {
        // a session may alternate between single steps and fused chunks
        // on the same pooled buffers without cross-talk
        let Some(s) = service() else { return };
        if !s.manifest().rollouts_available() {
            return;
        }
        let bucket = s.manifest().buckets[0];
        let k = *s.manifest().rollout_steps.last().unwrap();
        let mut t = Traffic::new(bucket);
        t.spawn(50.0, 15.0, 1.0, DriverParams::default());
        let step_ref = s.step(bucket, &t.state, &t.params).unwrap();
        let roll_ref = s
            .rollout_geom(bucket, k, &t.state, &t.params, GeometryVec::default())
            .unwrap();
        let mut sess = s.session(bucket).unwrap();
        for _ in 0..3 {
            assert_eq!(*sess.step(&t.state, &t.params).unwrap(), step_ref);
            assert_eq!(*sess.step_many(&t.state, &t.params, k).unwrap(), roll_ref);
        }
        // an unlowered K errors but leaves the session usable
        assert!(sess.step_many(&t.state, &t.params, 7).is_err());
        assert_eq!(*sess.step(&t.state, &t.params).unwrap(), step_ref);
        s.shutdown();
    }

    /// Mixed-K contention: sessions issuing different ladder rungs (and
    /// plain steps) concurrently.  Same-K requests may coalesce into
    /// batched rollout dispatches; different-K requests must form their
    /// own batches via the backlog — and every caller must still get
    /// its own world's exact result.
    #[test]
    fn mixed_k_rollouts_coalesce_without_contamination() {
        let Some(s) = service() else { return };
        if !s.manifest().rollouts_available() {
            return;
        }
        let bucket = s.manifest().buckets[0];
        let ladder = s.manifest().rollout_steps.clone();
        let worlds: Vec<Traffic> = (0..8)
            .map(|i| {
                let mut t = Traffic::new(bucket);
                t.spawn(20.0 + 30.0 * i as f32, 5.0 + i as f32, 1.0, DriverParams::default());
                t
            })
            .collect();
        // solo references per (world, k), computed without contention
        let refs: Vec<(usize, crate::runtime::RolloutOutputs)> = worlds
            .iter()
            .enumerate()
            .map(|(i, w)| {
                let k = ladder[i % ladder.len()];
                (
                    k,
                    s.rollout_geom(bucket, k, &w.state, &w.params, GeometryVec::default())
                        .unwrap(),
                )
            })
            .collect();
        // coalesced chunks ride the vmapped `rolloutb` executable, whose
        // lowering may round differently from the solo references — so
        // "no contamination" is |d| <= 1e-3, which cross-world traffic
        // (worlds are tens of metres apart) would violate by orders of
        // magnitude
        fn close(a: &[f32], b: &[f32]) -> bool {
            a.len() == b.len() && a.iter().zip(b).all(|(x, y)| (x - y).abs() <= 1e-3)
        }
        for _ in 0..3 {
            std::thread::scope(|scope| {
                for (w, (k, expect)) in worlds.iter().zip(refs.iter()) {
                    let svc = s.clone();
                    scope.spawn(move || {
                        let mut sess = svc.session(bucket).unwrap();
                        for _ in 0..5 {
                            let out = sess.step_many(&w.state, &w.params, *k).unwrap();
                            assert!(close(&out.state, &expect.state), "K={k}: wrong world");
                            assert!(close(&out.obs, &expect.obs), "K={k}: wrong obs");
                        }
                    });
                }
            });
        }
        s.shutdown();
    }

    #[test]
    fn sessions_coalesce_without_contamination() {
        // 8 threads with persistent sessions stepping DIFFERENT worlds:
        // every thread must get its own world's result even when the
        // micro-batcher coalesces the requests.
        let Some(s) = service() else { return };
        let bucket = s.manifest().buckets[0];
        let worlds: Vec<Traffic> = (0..8)
            .map(|k| {
                let mut t = Traffic::new(bucket);
                t.spawn(20.0 + 30.0 * k as f32, 5.0 + k as f32, 1.0, DriverParams::default());
                t
            })
            .collect();
        let expect: Vec<StepOutputs> = worlds
            .iter()
            .map(|w| s.step(bucket, &w.state, &w.params).unwrap())
            .collect();
        std::thread::scope(|scope| {
            for (w, e) in worlds.iter().zip(expect.iter()) {
                let svc = s.clone();
                scope.spawn(move || {
                    let mut sess = svc.session(bucket).unwrap();
                    for _ in 0..10 {
                        let out = sess.step(&w.state, &w.params).unwrap();
                        assert_eq!(out, e, "session got another world's result");
                    }
                });
            }
        });
    }

    /// A small schema-5 departure table: two spawns due at steps 5 and
    /// 40 onto the main lane, padding rows beyond.
    fn run_test_table(s: &EngineService, t_steps: u64) -> DepartureTable {
        use crate::sumo::duarouter::Departure;
        use crate::sumo::VehicleType;
        let dep = |time_s: f32, pos_m: f32, speed: f32| Departure {
            id: String::new(),
            time_s,
            route: Vec::new(),
            lane: 1,
            pos_m,
            speed,
            params: DriverParams::default(),
            vtype: VehicleType::Human,
        };
        DepartureTable::build(
            &[dep(0.5, 5.0, 15.0), dep(4.0, 2.0, 12.0)],
            0.1,
            t_steps,
            s.manifest().departure_rows,
        )
        .unwrap()
    }

    #[test]
    fn session_run_matches_oneshot_and_recovers_from_errors() {
        let Some(s) = service() else { return };
        if !s.manifest().runs_available() {
            eprintln!("skipping: artifacts predate schema 5");
            return;
        }
        let bucket = s.manifest().buckets[0];
        let t_steps = s.manifest().run_steps[0];
        let table = run_test_table(&s, t_steps as u64);
        let mut t = Traffic::new(bucket);
        t.spawn(100.0, 20.0, 1.0, DriverParams::default());
        let expect = s
            .run_geom(
                bucket,
                t_steps,
                &t.state,
                &t.params,
                GeometryVec::default(),
                &table.rows,
            )
            .unwrap();
        assert_eq!(expect.steps(), t_steps);
        assert_eq!(
            expect.inserted.iter().filter(|&&m| m > 0.5).count(),
            table.count,
            "both table spawns must land in an idle world"
        );
        let mut sess = s.session(bucket).unwrap();
        // repeats reproduce bit-for-bit on the round-tripped buffers
        for _ in 0..3 {
            let out = sess.run(&t.state, &t.params, &table.rows, t_steps).unwrap();
            assert_eq!(*out, expect);
        }
        // an unlowered T and a malformed table error but leave the
        // session usable
        assert!(sess.run(&t.state, &t.params, &table.rows, 7).is_err());
        assert!(sess.run(&t.state, &t.params, &table.rows[1..], t_steps).is_err());
        let out = sess.run(&t.state, &t.params, &table.rows, t_steps).unwrap();
        assert_eq!(*out, expect);
        s.shutdown();
    }

    /// Concurrent same-T runs may coalesce into `runb` dispatches;
    /// every caller must still get its own world's result.  Tolerance
    /// mirrors `mixed_k_rollouts_coalesce_without_contamination`: the
    /// vmapped lowering may round differently from the solo entry, but
    /// cross-world contamination is off by whole vehicle positions.
    #[test]
    fn runs_coalesce_without_contamination() {
        let Some(s) = service() else { return };
        if !s.manifest().runs_available() {
            return;
        }
        let bucket = s.manifest().buckets[0];
        let t_steps = s.manifest().run_steps[0];
        let table = run_test_table(&s, t_steps as u64);
        let worlds: Vec<Traffic> = (0..4)
            .map(|i| {
                let mut t = Traffic::new(bucket);
                t.spawn(60.0 + 40.0 * i as f32, 8.0 + 2.0 * i as f32, 1.0, DriverParams::default());
                t
            })
            .collect();
        let refs: Vec<RunOutputs> = worlds
            .iter()
            .map(|w| {
                s.run_geom(
                    bucket,
                    t_steps,
                    &w.state,
                    &w.params,
                    GeometryVec::default(),
                    &table.rows,
                )
                .unwrap()
            })
            .collect();
        fn close(a: &[f32], b: &[f32]) -> bool {
            a.len() == b.len() && a.iter().zip(b).all(|(x, y)| (x - y).abs() <= 1e-3)
        }
        std::thread::scope(|scope| {
            for (w, expect) in worlds.iter().zip(refs.iter()) {
                let svc = s.clone();
                let table = &table;
                scope.spawn(move || {
                    let mut sess = svc.session(bucket).unwrap();
                    for _ in 0..2 {
                        let out = sess.run(&w.state, &w.params, &table.rows, t_steps).unwrap();
                        assert!(close(&out.state, &expect.state), "wrong world state");
                        assert!(close(&out.obs, &expect.obs), "wrong world obs");
                        assert_eq!(out.inserted, expect.inserted, "wrong inserted mask");
                    }
                });
            }
        });
        s.shutdown();
    }

    #[test]
    fn hlo_stepper_advertises_run_entry_points() {
        let Some(s) = service() else { return };
        let (run_steps, rows, available) = (
            s.manifest().run_steps.clone(),
            s.manifest().departure_rows,
            s.manifest().runs_available(),
        );
        let bucket = s.manifest().buckets[0];
        let stepper = HloStepper::new(s, bucket).unwrap();
        if available {
            assert_eq!(stepper.run_ladder(), &run_steps[..]);
            assert_eq!(stepper.run_table_rows(), rows);
        } else {
            assert!(stepper.run_ladder().is_empty());
            assert_eq!(stepper.run_table_rows(), 0);
        }
    }
}
