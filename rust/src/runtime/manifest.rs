//! `artifacts/manifest.json`: the contract between the compile path and
//! the runtime (shapes, buckets, road constants).  Parsed with the
//! dependency-free [`crate::util::Json`] parser.

use std::collections::BTreeMap;
use std::path::Path;

use crate::sumo::MergeScenario;
use crate::util::Json;
use crate::{Error, Result};

/// The geometry-operand layout the rust side is compiled against —
/// must equal the manifest's `geometry_columns` (and
/// `python/compile/model.py` `GEOM_COLUMNS`; see `sumo::state::G_*`).
pub const GEOMETRY_COLUMNS: [&str; crate::sumo::state::GEOM_COLS] =
    ["road_end", "merge_start", "merge_end", "num_main_lanes", "dt"];

/// The schema-3 params-row layout (`model.py PARAM_COLUMNS`; see
/// `sumo::state::P_*`): six driver columns plus the per-vehicle
/// destination intent the destination-aware artifacts consume.
pub const PARAM_COLUMNS: [&str; crate::sumo::state::PARAM_COLS] = [
    "v0", "T", "a_max", "b", "s0", "length", "exit_pos", "exit_flag",
];

/// The schema-3 observables layout (`model.py OBS_COLUMNS`): off-ramp
/// exits are counted separately from road-end flow.
pub const OBS_COLUMNS: [&str; crate::sumo::state::OBS_COLS] =
    ["n_active", "mean_speed", "flow", "n_merged", "n_exited"];

/// The fused-rollout K ladder the compile path lowers per bucket
/// (`aot.py ROLLOUT_STEPS`) — the expected default for schema-4
/// artifacts, pinned across model.py/aot.py/artifacts by
/// `scripts/check_manifest.py`.  The runtime itself is data-driven
/// ([`Manifest::rollout_steps`] is what gets executed); this constant
/// only documents and gates the shipped ladder.
pub const ROLLOUT_LADDER: [usize; 3] = [1, 8, 32];

/// Entry-name stems of the schema-4 rollout artifacts: `rollout{K}_{N}`
/// (solo) and `rolloutb{K}_{N}` (micro-batched).
pub const ROLLOUT_ENTRY_POINTS: [&str; 2] = ["rollout", "rolloutb"];

/// The schema-5 departure-table row layout (`model.py DEP_COLUMNS`; see
/// `sumo::simulation::DEP_*`): the epoch step index at which a departure
/// becomes due, then the full spawn payload — state row `[x, v, lane]`
/// plus the eight [`PARAM_COLUMNS`].  Demand compiled into an operand is
/// what makes a whole run one dispatch.
pub const DEPARTURE_COLUMNS: [&str; crate::sumo::DEP_COLS] = [
    "step", "x", "v", "lane", "v0", "T", "a_max", "b", "s0", "length", "exit_pos", "exit_flag",
];

/// The whole-run total-steps ladder the compile path lowers per bucket
/// (`aot.py RUN_STEPS`) — exact step counts, not upper bounds: 1200 and
/// 1800 are the scenario families' horizons at DT=0.1, 200 the short
/// validation horizon.  Like [`ROLLOUT_LADDER`], the runtime is
/// data-driven ([`Manifest::run_steps`]); this constant documents and
/// gates the shipped ladder (`scripts/check_manifest.py`).
pub const RUN_LADDER: [usize; 3] = [200, 1200, 1800];

/// Entry-name stems of the schema-5 whole-run artifacts: `run{T}_{N}`
/// (solo) and `runb{T}_{N}` (micro-batched).
pub const RUN_ENTRY_POINTS: [&str; 2] = ["run", "runb"];

/// One lowered artifact.
#[derive(Debug, Clone, PartialEq)]
pub struct ArtifactEntry {
    pub file: String,
    /// Vehicle-count bucket.
    pub n: usize,
    /// Number of tuple outputs.
    pub outputs: usize,
    /// Number of input operands (0 = not recorded, schema-1 manifests).
    pub operands: usize,
    /// Fused steps per dispatch (rollout entries, schema 4); 0 for
    /// single-step artifacts.
    pub k: usize,
    /// Total physics steps of a whole-run entry (schema 5); 0 for
    /// everything else.
    pub k_total: usize,
}

/// The whole manifest (see `python/compile/aot.py`).
#[derive(Debug, Clone)]
pub struct Manifest {
    pub format: String,
    /// Artifact schema version: 1 = constant-geometry artifacts (legacy),
    /// 2 = geometry-generic (step/stepb take the f32[GEOM_COLS] operand),
    /// 3 = destination-aware (params carry the `[exit_pos, exit_flag]`
    /// columns, obs gains `n_exited`), 4 = fused rollouts (adds the
    /// `rollout{K}_{N}`/`rolloutb{K}_{N}` entry points over a K ladder),
    /// 5 = whole-run entries (`run{T}_{N}`/`runb{T}_{N}` over a
    /// total-steps ladder, demand as a departure-table operand).
    /// The runtime executes single-step entries on schema >= 3; the
    /// rollout fast path is gated on schema >= 4
    /// ([`Manifest::rollouts_available`]), the whole-run fast path on
    /// schema >= 5 ([`Manifest::runs_available`]).
    pub schema: u32,
    pub state_columns: Vec<String>,
    pub param_columns: Vec<String>,
    pub obs_columns: Vec<String>,
    /// Operand layout of the geometry vector (schema >= 2).
    pub geometry_columns: Vec<String>,
    pub dt: f32,
    pub road_end: f32,
    pub merge_start: f32,
    pub merge_end: f32,
    pub num_main_lanes: u32,
    pub buckets: Vec<usize>,
    /// Batch width of the vmapped `stepb_*` artifacts (1 = not lowered).
    pub batch: usize,
    /// The fused-rollout K ladder (schema 4; empty = no rollouts
    /// lowered).  Sorted ascending, mirrored from `aot.py ROLLOUT_STEPS`.
    pub rollout_steps: Vec<usize>,
    /// Entry-name stems of the rollout artifacts (schema 4; normally
    /// [`ROLLOUT_ENTRY_POINTS`]).
    pub rollout_entry_points: Vec<String>,
    /// The whole-run total-steps ladder (schema 5; empty = no run
    /// entries lowered).  Sorted ascending, mirrored from
    /// `aot.py RUN_STEPS` — exact step counts, not upper bounds.
    pub run_steps: Vec<usize>,
    /// Entry-name stems of the whole-run artifacts (schema 5; normally
    /// [`RUN_ENTRY_POINTS`]).
    pub run_entry_points: Vec<String>,
    /// Departure-table operand layout (schema 5; normally
    /// [`DEPARTURE_COLUMNS`]).
    pub departure_columns: Vec<String>,
    /// Departure-table row capacity per run entry (schema 5; 0 = none
    /// lowered).  Schedules with more due rows fall back to chunking.
    pub departure_rows: usize,
    pub entries: BTreeMap<String, ArtifactEntry>,
}

fn str_vec(j: &Json) -> Result<Vec<String>> {
    j.as_arr()?
        .iter()
        .map(|v| v.as_str().map(String::from))
        .collect()
}

impl Manifest {
    pub fn load(dir: &Path) -> Result<Manifest> {
        let text = std::fs::read_to_string(dir.join("manifest.json"))?;
        Self::parse(&text)
    }

    pub fn parse(text: &str) -> Result<Manifest> {
        let j = Json::parse(text)?;
        let format = j.get("format")?.as_str()?.to_string();
        if format != "hlo-text" {
            return Err(Error::Artifact(format!(
                "unsupported artifact format '{format}'"
            )));
        }
        let mut entries = BTreeMap::new();
        for (key, e) in j.get("entries")?.as_obj()? {
            entries.insert(
                key.clone(),
                ArtifactEntry {
                    file: e.get("file")?.as_str()?.to_string(),
                    n: e.get("n")?.as_usize()?,
                    outputs: e.get("outputs")?.as_usize()?,
                    operands: e.get("operands").and_then(|v| v.as_usize()).unwrap_or(0),
                    k: e.get("k").and_then(|v| v.as_usize()).unwrap_or(0),
                    k_total: e.get("k_total").and_then(|v| v.as_usize()).unwrap_or(0),
                },
            );
        }
        Ok(Manifest {
            format,
            schema: j.get("schema").and_then(|v| v.as_usize()).unwrap_or(1) as u32,
            state_columns: str_vec(j.get("state_columns")?)?,
            param_columns: str_vec(j.get("param_columns")?)?,
            obs_columns: str_vec(j.get("obs_columns")?)?,
            geometry_columns: match j.get("geometry_columns") {
                Ok(v) => str_vec(v)?,
                Err(_) => Vec::new(),
            },
            dt: j.get("dt")?.as_f64()? as f32,
            road_end: j.get("road_end")?.as_f64()? as f32,
            merge_start: j.get("merge_start")?.as_f64()? as f32,
            merge_end: j.get("merge_end")?.as_f64()? as f32,
            num_main_lanes: j.get("num_main_lanes")?.as_usize()? as u32,
            batch: j.get("batch").and_then(|v| v.as_usize()).unwrap_or(1),
            rollout_steps: match j.get("rollout_steps") {
                Ok(v) => v
                    .as_arr()?
                    .iter()
                    .map(|v| v.as_usize())
                    .collect::<Result<_>>()?,
                Err(_) => Vec::new(),
            },
            rollout_entry_points: match j.get("rollout_entry_points") {
                Ok(v) => str_vec(v)?,
                Err(_) => Vec::new(),
            },
            run_steps: match j.get("run_steps") {
                Ok(v) => v
                    .as_arr()?
                    .iter()
                    .map(|v| v.as_usize())
                    .collect::<Result<_>>()?,
                Err(_) => Vec::new(),
            },
            run_entry_points: match j.get("run_entry_points") {
                Ok(v) => str_vec(v)?,
                Err(_) => Vec::new(),
            },
            departure_columns: match j.get("departure_columns") {
                Ok(v) => str_vec(v)?,
                Err(_) => Vec::new(),
            },
            departure_rows: j.get("departure_rows").and_then(|v| v.as_usize()).unwrap_or(0),
            buckets: j
                .get("buckets")?
                .as_arr()?
                .iter()
                .map(|v| v.as_usize())
                .collect::<Result<_>>()?,
            entries,
        })
    }

    /// Smallest bucket that can hold `n` live vehicles.
    pub fn bucket_for(&self, n: usize) -> Result<usize> {
        self.buckets
            .iter()
            .copied()
            .find(|&b| b >= n)
            .ok_or_else(|| {
                Error::Artifact(format!(
                    "no bucket >= {n} (available: {:?})",
                    self.buckets
                ))
            })
    }

    pub fn entry(&self, name: &str, bucket: usize) -> Result<&ArtifactEntry> {
        let key = format!("{name}_{bucket}");
        self.entries
            .get(&key)
            .ok_or_else(|| Error::Artifact(format!("no artifact entry '{key}'")))
    }

    /// The fused-rollout entry `{stem}{k}_{bucket}` (schema 4), e.g.
    /// `rollout32_256` or `rolloutb8_64`.
    pub fn rollout_entry(&self, stem: &str, k: usize, bucket: usize) -> Result<&ArtifactEntry> {
        let key = format!("{stem}{k}_{bucket}");
        self.entries
            .get(&key)
            .ok_or_else(|| Error::Artifact(format!("no artifact entry '{key}'")))
    }

    /// The whole-run entry `{stem}{t}_{bucket}` (schema 5), e.g.
    /// `run1200_64` or `runb200_16`.
    pub fn run_entry(&self, stem: &str, t: usize, bucket: usize) -> Result<&ArtifactEntry> {
        let key = format!("{stem}{t}_{bucket}");
        self.entries
            .get(&key)
            .ok_or_else(|| Error::Artifact(format!("no artifact entry '{key}'")))
    }

    /// The scenario constants the artifact was lowered with — must agree
    /// with the rust-side [`MergeScenario`].
    pub fn scenario(&self) -> MergeScenario {
        MergeScenario {
            road_end_m: self.road_end,
            merge_start_m: self.merge_start,
            merge_end_m: self.merge_end,
            num_main_lanes: self.num_main_lanes,
            dt_s: self.dt,
        }
    }

    /// Do the step artifacts take the runtime geometry operand?
    pub fn geometry_generic(&self) -> bool {
        self.schema >= 2
    }

    /// Do the artifacts consume the destination-aware params row
    /// (`[exit_pos, exit_flag]` columns, `n_exited` observable)?
    pub fn destination_aware(&self) -> bool {
        self.schema >= 3
    }

    /// Do the artifacts ship fused K-step rollout entry points?  Schema
    /// <= 3 artifacts still load and serve single steps; the chunked
    /// fast path simply stays off ([`crate::runtime::HloStepper`] falls
    /// back to a `[1]` ladder).
    pub fn rollouts_available(&self) -> bool {
        self.schema >= 4 && !self.rollout_steps.is_empty()
    }

    /// Do the artifacts ship whole-run entry points (demand as a
    /// departure-table operand)?  Schema <= 4 artifacts still serve
    /// steps and rollouts; the device-resident run fast path simply
    /// stays off and `SumoSim` keeps its PR 5 chunk scheduler.
    pub fn runs_available(&self) -> bool {
        self.schema >= 5 && !self.run_steps.is_empty() && self.departure_rows > 0
    }

    /// Assert the compile-path constants match the rust defaults; a
    /// drifted constant silently corrupts every experiment, so this is
    /// checked at engine construction.  (With schema 2 the constants are
    /// only the *recorded defaults* — geometry is a runtime operand —
    /// but drift between `model.py` and [`MergeScenario::default`] still
    /// flags a compile path that was edited without the rust side.)
    pub fn validate_against_default_scenario(&self) -> Result<()> {
        let a = self.scenario();
        let b = MergeScenario::default();
        if a != b {
            return Err(Error::Artifact(format!(
                "artifact scenario {a:?} != rust default {b:?}; re-run `make artifacts`"
            )));
        }
        if self.state_columns != ["x", "v", "lane", "active"] {
            return Err(Error::Artifact(format!(
                "unexpected state layout {:?}",
                self.state_columns
            )));
        }
        Ok(())
    }

    /// Assert the operand contract of schema-3 artifacts: the geometry
    /// layout matches [`GEOMETRY_COLUMNS`] and every step/stepb entry
    /// records the three-operand signature.  Schema-1 *and* schema-2
    /// manifests are rejected outright — the runtime no longer carries a
    /// constant-geometry or destination-blind code path (`Engine::new`
    /// enforces this together with [`Self::validate_param_layout`]).
    pub fn validate_geometry_layout(&self) -> Result<()> {
        if !self.destination_aware() {
            return Err(Error::Artifact(format!(
                "artifacts are schema {} ({}); the runtime needs \
                 destination-aware schema 3 artifacts — re-run `make artifacts`",
                self.schema,
                if self.geometry_generic() {
                    "destination-blind params row"
                } else {
                    "constant geometry"
                }
            )));
        }
        if self.geometry_columns != GEOMETRY_COLUMNS {
            return Err(Error::Artifact(format!(
                "geometry operand layout {:?} != expected {:?}; re-run `make artifacts`",
                self.geometry_columns, GEOMETRY_COLUMNS
            )));
        }
        for (key, e) in &self.entries {
            let expect = match key.split('_').next().unwrap_or("") {
                "step" | "stepb" => 3,
                "idm" => 2,
                "radar" => 1,
                _ => continue,
            };
            // operands == 0 means "not recorded": tolerated for the bare
            // kernels, never for the geometry-carrying step artifacts
            if e.operands != expect && !(e.operands == 0 && expect < 3) {
                return Err(Error::Artifact(format!(
                    "artifact entry '{key}' records {} operands, expected {expect}",
                    e.operands
                )));
            }
        }
        Ok(())
    }

    /// Operand/shape contract of the schema-4 rollout entry points: the
    /// K ladder must be sorted, start at 1 (the chunk scheduler's
    /// degenerate rung), and every (stem, K, bucket) triple must be
    /// lowered with the three-operand, two-output signature and a
    /// matching per-entry `k`.  A no-op for schema <= 3 manifests (no
    /// rollouts to validate — single-step execution stays available).
    pub fn validate_rollout_layout(&self) -> Result<()> {
        if !self.rollouts_available() {
            return Ok(());
        }
        let mut sorted = self.rollout_steps.clone();
        sorted.sort_unstable();
        sorted.dedup();
        if sorted != self.rollout_steps || self.rollout_steps.first() != Some(&1) {
            return Err(Error::Artifact(format!(
                "rollout K ladder {:?} must be strictly ascending and start \
                 at 1; re-run `make artifacts`",
                self.rollout_steps
            )));
        }
        if !self.rollout_entry_points.iter().any(|s| s == "rollout") {
            return Err(Error::Artifact(format!(
                "schema-4 manifest lists no 'rollout' entry point \
                 (rollout_entry_points = {:?}); re-run `make artifacts`",
                self.rollout_entry_points
            )));
        }
        for stem in &self.rollout_entry_points {
            if !ROLLOUT_ENTRY_POINTS.contains(&stem.as_str()) {
                return Err(Error::Artifact(format!(
                    "unknown rollout entry point '{stem}' (expected {ROLLOUT_ENTRY_POINTS:?})"
                )));
            }
            // the batched stem is only a contract when batching is on
            if *stem == "rolloutb" && self.batch < 2 {
                continue;
            }
            for &k in &self.rollout_steps {
                for &b in &self.buckets {
                    let e = self.rollout_entry(stem, k, b)?;
                    if e.operands != 3 || e.outputs != 2 || e.k != k || e.n != b {
                        return Err(Error::Artifact(format!(
                            "rollout entry '{stem}{k}_{b}' records operands={} \
                             outputs={} k={} n={}, expected 3/2/{k}/{b}; \
                             re-run `make artifacts`",
                            e.operands, e.outputs, e.k, e.n
                        )));
                    }
                }
            }
        }
        Ok(())
    }

    /// Operand/shape contract of the schema-5 whole-run entry points:
    /// the total-steps ladder must be sorted strictly ascending, the
    /// departure-table layout must match [`DEPARTURE_COLUMNS`] (a
    /// drifted column scrambles every compiled-in spawn), and every
    /// (stem, T, bucket) triple must be lowered with the four-operand
    /// (state, params, geom, departures), four-output (state, params,
    /// obs trace, inserted mask) signature and a matching per-entry
    /// `k_total`.  A no-op for schema <= 4 manifests.
    pub fn validate_departure_layout(&self) -> Result<()> {
        if !self.runs_available() {
            return Ok(());
        }
        let mut sorted = self.run_steps.clone();
        sorted.sort_unstable();
        sorted.dedup();
        if sorted != self.run_steps {
            return Err(Error::Artifact(format!(
                "run total-steps ladder {:?} must be strictly ascending; \
                 re-run `make artifacts`",
                self.run_steps
            )));
        }
        if self.departure_columns != DEPARTURE_COLUMNS {
            return Err(Error::Artifact(format!(
                "departure-table layout {:?} != expected {:?}; re-run `make artifacts`",
                self.departure_columns, DEPARTURE_COLUMNS
            )));
        }
        if !self.run_entry_points.iter().any(|s| s == "run") {
            return Err(Error::Artifact(format!(
                "schema-5 manifest lists no 'run' entry point \
                 (run_entry_points = {:?}); re-run `make artifacts`",
                self.run_entry_points
            )));
        }
        for stem in &self.run_entry_points {
            if !RUN_ENTRY_POINTS.contains(&stem.as_str()) {
                return Err(Error::Artifact(format!(
                    "unknown run entry point '{stem}' (expected {RUN_ENTRY_POINTS:?})"
                )));
            }
            // the batched stem is only a contract when batching is on
            if *stem == "runb" && self.batch < 2 {
                continue;
            }
            for &t in &self.run_steps {
                for &b in &self.buckets {
                    let e = self.run_entry(stem, t, b)?;
                    if e.operands != 4 || e.outputs != 4 || e.k_total != t || e.n != b {
                        return Err(Error::Artifact(format!(
                            "run entry '{stem}{t}_{b}' records operands={} \
                             outputs={} k_total={} n={}, expected 4/4/{t}/{b}; \
                             re-run `make artifacts`",
                            e.operands, e.outputs, e.k_total, e.n
                        )));
                    }
                }
            }
        }
        Ok(())
    }

    /// Per-column validation of the schema-3 params/obs layouts: the
    /// manifest must record exactly [`PARAM_COLUMNS`] and
    /// [`OBS_COLUMNS`] — a drifted or reordered column silently
    /// scrambles every vehicle's calibration (or its destination), so
    /// this is checked at engine construction alongside
    /// [`Self::validate_geometry_layout`].
    pub fn validate_param_layout(&self) -> Result<()> {
        if self.param_columns != PARAM_COLUMNS {
            return Err(Error::Artifact(format!(
                "params-row layout {:?} != expected {:?}; re-run `make artifacts`",
                self.param_columns, PARAM_COLUMNS
            )));
        }
        if self.obs_columns != OBS_COLUMNS {
            return Err(Error::Artifact(format!(
                "obs layout {:?} != expected {:?}; re-run `make artifacts`",
                self.obs_columns, OBS_COLUMNS
            )));
        }
        Ok(())
    }
}

#[cfg(test)]
#[allow(clippy::unwrap_used, clippy::expect_used)]
mod tests {
    use super::*;
    use crate::runtime::find_artifacts_dir;

    fn manifest() -> Option<Manifest> {
        find_artifacts_dir().map(|d| Manifest::load(&d).expect("manifest parses"))
    }

    #[test]
    fn loads_and_validates() {
        let Some(m) = manifest() else {
            eprintln!("skipping: run `make artifacts` first");
            return;
        };
        m.validate_against_default_scenario().unwrap();
        m.validate_geometry_layout().unwrap();
        m.validate_param_layout().unwrap();
        m.validate_rollout_layout().unwrap();
        m.validate_departure_layout().unwrap();
        assert!(m.geometry_generic());
        assert!(m.destination_aware());
        assert!(m.rollouts_available());
        assert!(m.runs_available());
        assert_eq!(m.rollout_steps, ROLLOUT_LADDER);
        assert_eq!(m.run_steps, RUN_LADDER);
        assert_eq!(m.departure_columns, DEPARTURE_COLUMNS);
        assert!(m.departure_rows > 0);
        assert!(!m.buckets.is_empty());
    }

    #[test]
    fn run_entries_exist_for_every_ladder_rung() {
        let Some(m) = manifest() else { return };
        if !m.runs_available() {
            eprintln!("skipping: artifacts predate schema 5");
            return;
        }
        for &b in &m.buckets {
            for &t in &m.run_steps {
                let e = m.run_entry("run", t, b).unwrap();
                assert_eq!((e.n, e.k_total, e.outputs, e.operands), (b, t, 4, 4));
                if m.batch >= 2 {
                    let eb = m.run_entry("runb", t, b).unwrap();
                    assert_eq!((eb.n, eb.k_total), (b, t));
                }
            }
        }
        assert!(m.run_entry("run", 7, m.buckets[0]).is_err());
    }

    #[test]
    fn rollout_entries_exist_for_every_ladder_rung() {
        let Some(m) = manifest() else { return };
        for &b in &m.buckets {
            for &k in &m.rollout_steps {
                let e = m.rollout_entry("rollout", k, b).unwrap();
                assert_eq!((e.n, e.k, e.outputs, e.operands), (b, k, 2, 3));
                if m.batch >= 2 {
                    let eb = m.rollout_entry("rolloutb", k, b).unwrap();
                    assert_eq!((eb.n, eb.k), (b, k));
                }
            }
        }
        assert!(m.rollout_entry("rollout", 7, m.buckets[0]).is_err());
    }

    #[test]
    fn bucket_selection() {
        let Some(m) = manifest() else { return };
        assert_eq!(m.bucket_for(1).unwrap(), m.buckets[0]);
        let largest = *m.buckets.last().unwrap();
        assert_eq!(m.bucket_for(largest).unwrap(), largest);
        assert!(m.bucket_for(largest + 1).is_err());
    }

    #[test]
    fn entries_exist_for_every_bucket() {
        let Some(m) = manifest() else { return };
        for &b in &m.buckets {
            for name in ["step", "idm", "radar"] {
                let e = m.entry(name, b).unwrap();
                assert_eq!(e.n, b);
            }
        }
        assert!(m.entry("step", 9999).is_err());
    }

    #[test]
    fn parse_rejects_wrong_format() {
        let text = r#"{"format": "proto", "entries": {}}"#;
        assert!(Manifest::parse(text).is_err());
    }

    /// A minimal valid schema-3 manifest for the synthetic tests.
    fn synthetic_schema3() -> String {
        r#"{
          "format": "hlo-text",
          "schema": 3,
          "state_columns": ["x", "v", "lane", "active"],
          "param_columns": ["v0", "T", "a_max", "b", "s0", "length", "exit_pos", "exit_flag"],
          "obs_columns": ["n_active", "mean_speed", "flow", "n_merged", "n_exited"],
          "geometry_columns": ["road_end", "merge_start", "merge_end", "num_main_lanes", "dt"],
          "dt": 0.1, "road_end": 1000.0, "merge_start": 300.0,
          "merge_end": 500.0, "num_main_lanes": 2,
          "buckets": [16],
          "entries": {"step_16": {"file": "step_16.hlo.txt", "n": 16, "outputs": 4, "operands": 3}}
        }"#
        .to_string()
    }

    /// A minimal valid schema-4 manifest: schema 3 plus a [1, 8] rollout
    /// ladder (solo entries only; batch 1 keeps `rolloutb` optional).
    fn synthetic_schema4() -> String {
        synthetic_schema3()
            .replace(r#""schema": 3"#, r#""schema": 4"#)
            .replace(
                r#""buckets": [16],"#,
                r#""buckets": [16],
          "rollout_steps": [1, 8],
          "rollout_entry_points": ["rollout"],"#,
            )
            .replace(
                r#""entries": {"step_16": {"file": "step_16.hlo.txt", "n": 16, "outputs": 4, "operands": 3}}"#,
                r#""entries": {
            "step_16": {"file": "step_16.hlo.txt", "n": 16, "outputs": 4, "operands": 3},
            "rollout1_16": {"file": "rollout1_16.hlo.txt", "n": 16, "k": 1, "outputs": 2, "operands": 3},
            "rollout8_16": {"file": "rollout8_16.hlo.txt", "n": 16, "k": 8, "outputs": 2, "operands": 3}
          }"#,
            )
    }

    #[test]
    fn parse_synthetic_manifest() {
        let m = Manifest::parse(&synthetic_schema3()).unwrap();
        m.validate_against_default_scenario().unwrap();
        m.validate_geometry_layout().unwrap();
        m.validate_param_layout().unwrap();
        assert!(m.destination_aware());
        assert_eq!(m.entry("step", 16).unwrap().outputs, 4);
        assert_eq!(m.entry("step", 16).unwrap().operands, 3);
    }

    #[test]
    fn schema3_loads_without_rollouts() {
        // schema-3 artifacts still serve single steps; the rollout fast
        // path is simply unavailable
        let m = Manifest::parse(&synthetic_schema3()).unwrap();
        assert!(!m.rollouts_available());
        m.validate_rollout_layout().unwrap();
        assert!(m.rollout_entry("rollout", 8, 16).is_err());
    }

    /// A minimal valid schema-5 manifest: schema 4 plus a single-rung
    /// run ladder with a compiled-in departure table (solo entries only;
    /// batch 1 keeps `runb` optional).
    fn synthetic_schema5() -> String {
        synthetic_schema4()
            .replace(r#""schema": 4"#, r#""schema": 5"#)
            .replace(
                r#""rollout_entry_points": ["rollout"],"#,
                r#""rollout_entry_points": ["rollout"],
          "run_steps": [200],
          "run_entry_points": ["run"],
          "departure_columns": ["step", "x", "v", "lane", "v0", "T", "a_max", "b", "s0", "length", "exit_pos", "exit_flag"],
          "departure_rows": 8,"#,
            )
            .replace(
                r#""rollout8_16": {"file": "rollout8_16.hlo.txt", "n": 16, "k": 8, "outputs": 2, "operands": 3}"#,
                r#""rollout8_16": {"file": "rollout8_16.hlo.txt", "n": 16, "k": 8, "outputs": 2, "operands": 3},
            "run200_16": {"file": "run200_16.hlo.txt", "n": 16, "k_total": 200, "outputs": 4, "operands": 4}"#,
            )
    }

    #[test]
    fn parse_synthetic_schema4_manifest() {
        let m = Manifest::parse(&synthetic_schema4()).unwrap();
        m.validate_against_default_scenario().unwrap();
        m.validate_geometry_layout().unwrap();
        m.validate_param_layout().unwrap();
        m.validate_rollout_layout().unwrap();
        assert!(m.rollouts_available());
        assert_eq!(m.rollout_steps, [1, 8]);
        let e = m.rollout_entry("rollout", 8, 16).unwrap();
        assert_eq!((e.k, e.outputs, e.operands), (8, 2, 3));
    }

    #[test]
    fn malformed_rollout_layouts_rejected() {
        // a ladder that does not start at 1 starves the chunk scheduler
        // of its degenerate rung
        let text = synthetic_schema4().replace(
            r#""rollout_steps": [1, 8]"#,
            r#""rollout_steps": [8, 1]"#,
        );
        let m = Manifest::parse(&text).unwrap();
        assert!(m.validate_rollout_layout().is_err());
        // a missing ladder rung entry
        let text = synthetic_schema4().replace(
            r#""rollout_steps": [1, 8]"#,
            r#""rollout_steps": [1, 8, 32]"#,
        );
        let m = Manifest::parse(&text).unwrap();
        assert!(m.validate_rollout_layout().is_err());
        // a rollout entry with the wrong fused-step count
        let text = synthetic_schema4().replace(
            r#""rollout8_16": {"file": "rollout8_16.hlo.txt", "n": 16, "k": 8, "outputs": 2, "operands": 3}"#,
            r#""rollout8_16": {"file": "rollout8_16.hlo.txt", "n": 16, "k": 4, "outputs": 2, "operands": 3}"#,
        );
        let m = Manifest::parse(&text).unwrap();
        assert!(m.validate_rollout_layout().is_err());
        // a schema-4 manifest that forgot its entry points entirely
        let text = synthetic_schema4().replace(
            r#""rollout_entry_points": ["rollout"]"#,
            r#""rollout_entry_points": []"#,
        );
        let m = Manifest::parse(&text).unwrap();
        assert!(m.validate_rollout_layout().is_err());
    }

    #[test]
    fn schema4_loads_without_runs() {
        // schema-4 artifacts still serve steps and rollouts; the
        // whole-run fast path is simply unavailable
        let m = Manifest::parse(&synthetic_schema4()).unwrap();
        assert!(!m.runs_available());
        m.validate_departure_layout().unwrap();
        assert!(m.run_entry("run", 200, 16).is_err());
    }

    #[test]
    fn parse_synthetic_schema5_manifest() {
        let m = Manifest::parse(&synthetic_schema5()).unwrap();
        m.validate_rollout_layout().unwrap();
        m.validate_departure_layout().unwrap();
        assert!(m.runs_available());
        assert_eq!(m.run_steps, [200]);
        assert_eq!(m.departure_rows, 8);
        assert_eq!(m.departure_columns, DEPARTURE_COLUMNS);
        let e = m.run_entry("run", 200, 16).unwrap();
        assert_eq!((e.k_total, e.outputs, e.operands), (200, 4, 4));
    }

    #[test]
    fn malformed_departure_layouts_rejected() {
        // a drifted departure column scrambles every spawn payload
        let text = synthetic_schema5().replace(
            r#""step", "x", "v", "lane""#,
            r#""step", "v", "x", "lane""#,
        );
        let m = Manifest::parse(&text).unwrap();
        let err = m.validate_departure_layout().unwrap_err().to_string();
        assert!(err.contains("departure"), "{err}");
        // a missing run entry for a declared ladder rung
        let text = synthetic_schema5().replace(
            r#""run_steps": [200]"#,
            r#""run_steps": [200, 1200]"#,
        );
        let m = Manifest::parse(&text).unwrap();
        assert!(m.validate_departure_layout().is_err());
        // a run entry whose compiled-in step count disagrees with its rung
        let text = synthetic_schema5().replace(
            r#""k_total": 200, "outputs": 4, "operands": 4"#,
            r#""k_total": 100, "outputs": 4, "operands": 4"#,
        );
        let m = Manifest::parse(&text).unwrap();
        assert!(m.validate_departure_layout().is_err());
        // a run entry missing the departure-table operand
        let text = synthetic_schema5().replace(
            r#""k_total": 200, "outputs": 4, "operands": 4"#,
            r#""k_total": 200, "outputs": 4, "operands": 3"#,
        );
        let m = Manifest::parse(&text).unwrap();
        assert!(m.validate_departure_layout().is_err());
        // a schema-5 manifest that forgot the "run" stem
        let text = synthetic_schema5().replace(
            r#""run_entry_points": ["run"]"#,
            r#""run_entry_points": []"#,
        );
        let m = Manifest::parse(&text).unwrap();
        assert!(m.validate_departure_layout().is_err());
    }

    #[test]
    fn schema2_rejected_like_schema1() {
        // destination-blind schema-2 artifacts (6 param columns) parse
        // but must be refused at Engine::new, exactly like schema 1
        let text = synthetic_schema3()
            .replace(r#""schema": 3"#, r#""schema": 2"#)
            .replace(r#", "exit_pos", "exit_flag""#, "")
            .replace(r#", "n_exited""#, "");
        let m = Manifest::parse(&text).unwrap();
        assert!(m.geometry_generic());
        assert!(!m.destination_aware());
        let err = m.validate_geometry_layout().unwrap_err().to_string();
        assert!(err.contains("schema 2"), "{err}");
        assert!(m.validate_param_layout().is_err());
    }

    #[test]
    fn drifted_param_or_obs_columns_rejected() {
        // a reordered params column scrambles every calibration row
        let text = synthetic_schema3().replace(
            r#""exit_pos", "exit_flag""#,
            r#""exit_flag", "exit_pos""#,
        );
        let m = Manifest::parse(&text).unwrap();
        let err = m.validate_param_layout().unwrap_err().to_string();
        assert!(err.contains("params-row layout"), "{err}");
        // ...and so is a missing n_exited observable
        let text = synthetic_schema3().replace(r#", "n_exited""#, "");
        let m = Manifest::parse(&text).unwrap();
        let err = m.validate_param_layout().unwrap_err().to_string();
        assert!(err.contains("obs layout"), "{err}");
    }

    #[test]
    fn legacy_schema_rejected_by_geometry_check() {
        // a schema-1 manifest (no schema/geometry_columns keys) parses —
        // but the runtime must refuse to execute it
        let text = r#"{
          "format": "hlo-text",
          "state_columns": ["x", "v", "lane", "active"],
          "param_columns": ["v0", "T", "a_max", "b", "s0", "length"],
          "obs_columns": ["n_active", "mean_speed", "flow", "n_merged"],
          "dt": 0.1, "road_end": 1000.0, "merge_start": 300.0,
          "merge_end": 500.0, "num_main_lanes": 2,
          "buckets": [16],
          "entries": {"step_16": {"file": "step_16.hlo.txt", "n": 16, "outputs": 4}}
        }"#;
        let m = Manifest::parse(text).unwrap();
        assert_eq!(m.schema, 1);
        assert!(!m.geometry_generic());
        m.validate_against_default_scenario().unwrap();
        let err = m.validate_geometry_layout().unwrap_err().to_string();
        assert!(err.contains("schema 1"), "{err}");
    }

    #[test]
    fn wrong_geometry_layout_rejected() {
        let text = synthetic_schema3().replace(
            r#""geometry_columns": ["road_end", "merge_start", "merge_end", "num_main_lanes", "dt"]"#,
            r#""geometry_columns": ["dt", "road_end"]"#,
        );
        let m = Manifest::parse(&text).unwrap();
        assert!(m.validate_geometry_layout().is_err());
        // ...and so is a step entry missing its geometry operand
        let text = synthetic_schema3().replace(r#""operands": 3"#, r#""operands": 2"#);
        let m = Manifest::parse(&text).unwrap();
        assert!(m.validate_geometry_layout().is_err());
    }
}
